//! Serving demo: a quantized model behind the threaded request scheduler.
//!
//! The worker thread owns the PJRT state (clients/executables are not
//! `Send`); requests flow in over a channel, completions flow back with
//! per-request latency — the shape of a real single-GPU serving node, with
//! the paper's W4A8 quantized weights + KV cache underneath (Table 6).
//!
//! Run: cargo run --release --example serve_quantized

use anyhow::Result;
use spinquant::config::{Bits, Method, PipelineConfig};
use spinquant::coordinator::Pipeline;
use spinquant::model::Manifest;
use spinquant::runtime::Runtime;
use spinquant::serve::{GenerationSession, Request, Server};

fn main() -> Result<()> {
    let mut cfg = PipelineConfig::default();
    cfg.model = "sq-2m".into();
    cfg.method = Method::SpinQuantNoHad; // W4A8: zero inference-time changes
    cfg.bits = Bits::parse("4-8-8")?;
    cfg.use_gptq = false;
    cfg.cayley_iters = 20;

    // The worker builds its own runtime + session (PJRT is thread-pinned).
    let mut server = Server::spawn(move || {
        let manifest = Manifest::load(&cfg.artifacts_dir)?;
        let rt = Runtime::cpu()?;
        let pipe = Pipeline::new(&rt, &manifest, cfg.clone())?;
        let qm = pipe.quantize()?;
        let exe = rt.load(&manifest, &cfg.model, "decode_nohad")?;
        // Everything below is moved into the request-serving closure.
        Ok(move |req: &Request| {
            let mut session = GenerationSession::new(&exe, &qm.weights, Some(qm.qcfg))?;
            let out = session.generate(&req.prompt, req.max_new_tokens)?;
            Ok((out, session.ms_per_token()))
        })
    });

    let prompts: Vec<&[u8]> = vec![b"The ", b"Alpha beta ", b"Some words ", b"Q: "];
    println!("submitting {} requests to the quantized server...\n", prompts.len());
    for p in &prompts {
        server.submit(Request { prompt: p.to_vec(), max_new_tokens: 32 })?;
    }
    let mut total_ms = 0.0;
    for _ in 0..prompts.len() {
        let resp = server.recv()?;
        total_ms += resp.latency_ms;
        println!(
            "request {}: {:>7.1} ms total, {:>5.2} ms/token -> {:?}",
            resp.id,
            resp.latency_ms,
            resp.ms_per_token,
            String::from_utf8_lossy(&resp.completion)
        );
    }
    println!("\nmean request latency: {:.1} ms", total_ms / prompts.len() as f64);
    Ok(())
}
