//! Rotation-efficacy analysis (paper §2, Figs. 2-4 in miniature):
//!
//!   1. measure per-layer activation kurtosis + 4-bit quantization error of
//!      the pretrained model (planted outlier channels => kappa >> 3);
//!   2. merge a random Hadamard rotation and re-measure (kappa -> ~3);
//!   3. show the *variance* of quantized accuracy across random rotation
//!      seeds — the paper's core observation motivating learned rotations;
//!   4. learn the rotation with Cayley SGD and show it beating the random
//!      draws.
//!
//! Run: cargo run --release --example rotation_analysis

use anyhow::Result;
use spinquant::config::{Bits, Method, PipelineConfig};
use spinquant::coordinator::Pipeline;
use spinquant::eval::capture_stats;
use spinquant::model::Manifest;
use spinquant::rotation::{fold_norm_scales, merge, RotationKind, RotationSet};
use spinquant::runtime::Runtime;

fn main() -> Result<()> {
    let mut cfg = PipelineConfig::default();
    cfg.model = "sq-2m".into();
    cfg.method = Method::SpinQuantNoHad;
    cfg.bits = Bits::parse("4-4-16")?;
    cfg.use_gptq = false;
    cfg.eval_windows = Some(16);
    cfg.task_items = 8;
    cfg.cayley_iters = 40;

    let manifest = Manifest::load(&cfg.artifacts_dir)?;
    let rt = Runtime::cpu()?;
    let pipe = Pipeline::new(&rt, &manifest, cfg.clone())?;
    let folded = fold_norm_scales(&pipe.load_base_weights()?, &pipe.model_cfg)?;

    // --- 1 & 2: kurtosis / quant error before vs after rotation -----------
    println!("== per-layer residual-read activations (site: resid_in) ==");
    let rot = RotationSet::build(&pipe.model_cfg, RotationKind::RandomHadamard, 7);
    let merged = merge(&folded, &pipe.model_cfg, &rot, false)?;
    let before = pipe.collect_stats(&folded, 2)?;
    let after = pipe.collect_stats(&merged, 2)?;
    println!("{:<6} {:>16} {:>16} {:>14} {:>14}", "layer", "kurtosis before", "kurtosis after",
             "4b MSE before", "4b MSE after");
    let sb = capture_stats("resid_in", &before.captures["resid_in"]);
    let sa = capture_stats("resid_in", &after.captures["resid_in"]);
    for (b, a) in sb.iter().zip(&sa) {
        println!(
            "{:<6} {:>16.1} {:>16.1} {:>14.5} {:>14.5}",
            b.layer, b.kurtosis, a.kurtosis, b.quant_mse_4bit, a.quant_mse_4bit
        );
    }

    // --- 3: variance across random rotations ------------------------------
    println!("\n== W4A4 accuracy across random rotations (the Fig. 4 effect) ==");
    let mut accs = Vec::new();
    for seed in 0..6u64 {
        let qm = pipe.quantize_rotated(RotationKind::RandomHadamard, seed * 17 + 1, false, false)?;
        let res = pipe.evaluate(&qm)?;
        println!("  random Hadamard seed {seed}: acc {:.1}%  ppl {:.2}", res.acc_pct(), res.ppl);
        accs.push(res.acc_pct());
    }
    let spread = accs.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
        - accs.iter().cloned().fold(f64::INFINITY, f64::min);
    println!("  spread across seeds: {spread:.1} points");

    // --- 4: learned rotation ----------------------------------------------
    let qm = pipe.quantize_rotated(RotationKind::RandomHadamard, 1, true, false)?;
    let res = pipe.evaluate(&qm)?;
    println!("\nCayley-learned rotation: acc {:.1}%  ppl {:.2}", res.acc_pct(), res.ppl);
    println!("(expected: learned >= best random draw, with no seed lottery)");
    Ok(())
}
