//! Quickstart: quantize a pretrained tiny-LLaMA with SpinQuant and compare
//! against the FP baseline and naive RTN — the 60-second tour of the API.
//!
//! Run (after `make artifacts`):
//!     cargo run --release --example quickstart

use anyhow::Result;
use spinquant::config::{Bits, Method, PipelineConfig};
use spinquant::coordinator::Pipeline;
use spinquant::model::Manifest;
use spinquant::runtime::Runtime;

fn main() -> Result<()> {
    // 1. Load the AOT artifacts (HLO text + weights + corpora), built once
    //    by `make artifacts`; python never runs again after that.
    let mut cfg = PipelineConfig::default();
    cfg.model = "sq-2m".into();
    cfg.bits = Bits::parse("4-4-4")?; // W4A4KV4 — the paper's hardest setting
    cfg.eval_windows = Some(24); // small eval slice for a fast demo
    cfg.task_items = 8;
    cfg.cayley_iters = 30;

    let manifest = Manifest::load(&cfg.artifacts_dir)?;
    let rt = Runtime::cpu()?;

    println!("== SpinQuant quickstart: {} at {} ==\n", cfg.model, cfg.bits.label());
    for method in [Method::Float, Method::Rtn, Method::SpinQuantHad] {
        let mut c = cfg.clone();
        c.method = method;
        if method == Method::Float {
            c.bits = Bits::fp();
        }
        // 2. The pipeline: fold norms -> (learn + merge rotations) ->
        //    RTN/GPTQ weights -> ready-to-serve quantized model.
        let pipe = Pipeline::new(&rt, &manifest, c)?;
        let qm = pipe.quantize()?;
        // 3. Evaluate: Wiki-syn perplexity + 0-shot^8 accuracy.
        let res = pipe.evaluate(&qm)?;
        println!(
            "{:<18} acc {:>5.1}%   wiki ppl {:>6.2}",
            method.name(),
            res.acc_pct(),
            res.ppl
        );
        if let Some(rot) = &qm.rotation {
            println!(
                "{:<18} rotation orthonormality error: {:.2e}",
                "",
                rot.orthonormality_error()
            );
        }
    }
    println!("\nExpected ordering: FloatingPoint >= SpinQuant_had > RTN.");
    Ok(())
}
