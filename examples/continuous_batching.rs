//! Continuous-batching demo: requests join and leave a running batch.
//!
//! Spins up the `serve` scheduler over 4 KV-cache slots, floods it with a
//! burst of mixed-size requests, then injects a latecomer mid-decode — the
//! latecomer is admitted the step after a slot frees and finishes while
//! longer requests are still generating, which is the whole point of
//! continuous batching: no request waits for the batch to drain.
//!
//! Runs against the PJRT engine when `make artifacts` has been run, and
//! against the deterministic in-process mock engine otherwise, so the demo
//! works in a fresh checkout too.
//!
//! Run: cargo run --release --example continuous_batching

use anyhow::Result;
use spinquant::eval::QcfgVec;
use spinquant::model::{Manifest, Weights};
use spinquant::runtime::Runtime;
use spinquant::serve::{
    DecodeEngine, DecodeVariant, GenRequest, MockEngine, PjrtEngine, Sampler, Scheduler,
};

const BATCH: usize = 4;

fn demo<E: DecodeEngine>(engine: E, engine_name: &str) -> Result<()> {
    let mut sched = Scheduler::new(engine, 64)?;
    println!(
        "engine: {engine_name} ({} slots, {} cache positions)\n",
        sched.slot_capacity(),
        sched.engine().max_seq()
    );

    // A burst: more requests than slots, mixed budgets.
    let burst: &[(&[u8], usize)] = &[
        (b"The quick ", 40),
        (b"Alpha beta ", 6),
        (b"Some words ", 24),
        (b"Q: what is ", 8),
        (b"Lorem ipsum ", 12),
        (b"Hello ", 4),
    ];
    for (i, (prompt, budget)) in burst.iter().enumerate() {
        let id = sched.submit(GenRequest::sampled(
            prompt,
            *budget,
            Sampler::top_k(8, 0.8),
            7 + i as u64,
        ))?;
        println!("submitted request {id} ({budget} tokens) {:?}", String::from_utf8_lossy(prompt));
    }

    // Decode a while, then inject a latecomer mid-flight.
    let mut finished = Vec::new();
    for _ in 0..6 {
        finished.extend(sched.step()?);
    }
    let late = sched.submit(GenRequest::sampled(b"LATE! ", 5, Sampler::top_k(8, 0.8), 99))?;
    println!(
        "\n>>> request {late} submitted mid-decode (queue {}, in flight {}/{})\n",
        sched.queue_depth(),
        sched.in_flight(),
        sched.slot_capacity()
    );
    while !sched.is_idle() {
        for c in sched.step()? {
            println!(
                "finished request {:>2}: {:>3} tokens, ttft {:>7.2} ms, total {:>8.2} ms{}",
                c.id,
                c.completion.len(),
                c.ttft_ms.unwrap_or(f64::NAN),
                c.latency_ms,
                if c.id == late { "   <- the latecomer" } else { "" }
            );
            finished.push(c);
        }
    }

    let long_finished_last = finished.last().map(|c| c.id == 0).unwrap_or(false);
    println!(
        "\nthe latecomer {} the longest request to drain",
        if long_finished_last { "did not wait for" } else { "finished around" }
    );
    println!("\n{}", sched.metrics.table("serving metrics").to_markdown());
    Ok(())
}

fn main() -> Result<()> {
    // PJRT when artifacts exist, mock otherwise.
    if let Ok(manifest) = Manifest::load(std::path::Path::new("artifacts")) {
        let rt = Runtime::cpu()?;
        let artifact = DecodeVariant::QuantNoHad.artifact_batched(BATCH);
        match rt.load(&manifest, "sq-2m", &artifact) {
            Ok(exe) => {
                let weights = Weights::load(&manifest.weights_path("sq-2m"))?;
                let qcfg = QcfgVec::fp().with_a_bits(8.0).with_kv_bits(8.0);
                let mut engine = PjrtEngine::new(exe, &weights, Some(qcfg))?;
                // Batched prefill when the artifact exists: prompts reach
                // their first token in ceil(len/16) calls instead of len.
                let pname = DecodeVariant::QuantNoHad.artifact_prefill(BATCH, 16);
                match rt.load(&manifest, "sq-2m", &pname) {
                    Ok(pexe) => engine = engine.with_prefill(pexe, &weights, Some(qcfg))?,
                    Err(_) => eprintln!("no {pname} artifact; prompts use the decode loop"),
                }
                return demo(engine, "pjrt decode_nohad_b4 (W16A8KV8)");
            }
            Err(e) => eprintln!("no {artifact} artifact ({e:#}); falling back to the mock engine"),
        }
    } else {
        eprintln!("no artifacts (run `make artifacts`); using the mock engine");
    }
    demo(
        MockEngine::new(BATCH, 128, 256).with_prefill_chunk(8),
        "deterministic mock (8-token prefill chunks)",
    )
}
