//! End-to-end driver (the DESIGN.md validation gate #3): runs the FULL
//! SpinQuant system on a real small workload, proving all layers compose:
//!
//!   pretrained tiny-LLaMA (trained at build time on the synthetic corpus,
//!   loss curve in artifacts/pretrain_log_*.json)
//!     -> RMSNorm folding
//!     -> Cayley-SGD rotation learning on the Stiefel manifold
//!        (gradients from the AOT `cayley_had` artifact via PJRT)
//!     -> R1/R2 merge + R4 H-merge
//!     -> GPTQ weight quantization (Hessians from `fwd_stats` captures)
//!     -> W4A4KV4 evaluation: Wiki-syn perplexity + 0-shot^8 accuracy
//!     -> quantized greedy generation through the decode artifact
//!
//! Results are appended to EXPERIMENTS.md.
//!
//! Run: cargo run --release --example e2e_pipeline [-- <model>]

use anyhow::Result;
use spinquant::config::{Bits, Method, PipelineConfig};
use spinquant::coordinator::Pipeline;
use spinquant::model::Manifest;
use spinquant::report::{append_experiments, Table};
use spinquant::runtime::Runtime;
use spinquant::serve;

fn main() -> Result<()> {
    let model = std::env::args().nth(1).unwrap_or_else(|| "sq-2m".to_string());
    let mut cfg = PipelineConfig::default();
    cfg.model = model.clone();
    cfg.method = Method::SpinQuantHad;
    cfg.bits = Bits::parse("4-4-4")?;
    cfg.cayley_iters = 60;
    cfg.eval_windows = Some(48);
    cfg.task_items = 16;

    let manifest = Manifest::load(&cfg.artifacts_dir)?;
    let rt = Runtime::cpu()?;
    println!("PJRT platform: {}", rt.platform());

    // Pretraining evidence (the build-time training run).
    let log_path = cfg.artifacts_dir.join(format!("pretrain_log_{model}.json"));
    let pretrain_summary = match std::fs::read_to_string(&log_path) {
        Ok(text) => {
            let j = spinquant::util::json::Json::parse(&text)?;
            let curve = j.req("curve")?.as_arr().unwrap_or(&[]).to_vec();
            let first = curve.first().and_then(|e| e.req("loss").ok()?.as_f64());
            let last = curve.last().and_then(|e| e.req("loss").ok()?.as_f64());
            format!(
                "pretraining: {} steps, loss {:.2} -> {:.2} (ppl {:.1} -> {:.1})",
                j.req("steps")?.as_usize().unwrap_or(0),
                first.unwrap_or(f64::NAN),
                last.unwrap_or(f64::NAN),
                first.map(f64::exp).unwrap_or(f64::NAN),
                last.map(f64::exp).unwrap_or(f64::NAN),
            )
        }
        Err(_) => "pretraining log missing".to_string(),
    };
    println!("{pretrain_summary}");

    // FP reference.
    let fp = {
        let mut c = cfg.clone();
        c.method = Method::Float;
        c.bits = Bits::fp();
        let pipe = Pipeline::new(&rt, &manifest, c)?;
        let qm = pipe.quantize()?;
        pipe.evaluate(&qm)?
    };
    println!("FP16 baseline:    acc {:.1}%  ppl {:.2}", fp.acc_pct(), fp.ppl);

    // The full SpinQuant pipeline.
    let pipe = Pipeline::new(&rt, &manifest, cfg.clone())?;
    let t0 = std::time::Instant::now();
    let qm = pipe.quantize()?;
    let quant_secs = t0.elapsed().as_secs_f64();
    println!(
        "pipeline done in {quant_secs:.1}s  (cayley loss {:.3} -> {:.3}, orth err {:.1e})",
        qm.meta.get("cayley_loss_first").copied().unwrap_or(f64::NAN),
        qm.meta.get("cayley_loss_last").copied().unwrap_or(f64::NAN),
        qm.meta.get("cayley_orth_error").copied().unwrap_or(f64::NAN),
    );
    let res = pipe.evaluate(&qm)?;
    println!("SpinQuant_had:    acc {:.1}%  ppl {:.2}", res.acc_pct(), res.ppl);
    for (suite, acc) in &res.per_suite {
        println!("   {suite:<10} {:.1}%", acc * 100.0);
    }

    // Quantized serving through the decode artifact.
    let exe = rt.load(&manifest, &model, serve::DecodeVariant::QuantHad.artifact())?;
    let mut session = serve::GenerationSession::new(&exe, &qm.weights, Some(qm.qcfg))?;
    let completion = session.generate(b"The ", 48)?;
    println!(
        "\nquantized generation @ {:.2} ms/token:\n  {:?}",
        session.ms_per_token(),
        String::from_utf8_lossy(&completion)
    );

    // Record the run.
    let mut t = Table::new(
        &format!("examples/e2e_pipeline — {model} W4A4KV4 (SpinQuant_had + GPTQ)"),
        &["Config", "0-shot^8 acc (%)", "Wiki-syn ppl"],
    );
    t.row(vec!["FP16".into(), format!("{:.1}", fp.acc_pct()), format!("{:.2}", fp.ppl)]);
    t.row(vec![
        "SpinQuant_had 4-4-4".into(),
        format!("{:.1}", res.acc_pct()),
        format!("{:.2}", res.ppl),
    ]);
    let section = format!(
        "\n## examples/e2e_pipeline ({model})\n\n{pretrain_summary}\n\n{}\nquantization pipeline: {quant_secs:.1}s; \
         quantized decode: {:.2} ms/token.\n",
        t.to_markdown(),
        session.ms_per_token()
    );
    append_experiments(std::path::Path::new("."), &section)?;
    println!("\nappended results to EXPERIMENTS.md");
    Ok(())
}
