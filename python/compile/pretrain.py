"""Build-time pretraining of the tiny-LLaMA zoo on the synthetic corpus.

Runs ONCE inside `make artifacts` (python never touches the request path):
  1. generates the wiki-syn / c4-syn corpora (data.py) into artifacts/data/,
  2. initializes each model in the planted-outlier basis (model.init_params),
  3. trains with Adam for a few hundred steps on wiki-syn.train,
  4. saves weights to artifacts/weights/<model>.sqt + a loss-curve JSON
     (the end-to-end training-run evidence recorded in EXPERIMENTS.md).

Usage: python -m compile.pretrain --models sq-2m,sq-4m --steps 300 --out ../artifacts
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import data as data_mod
from . import model as model_mod
from .sqt import write_sqt


def batches(corpus: np.ndarray, rng: np.random.RandomState, batch: int, seq: int):
    """Yield random (batch, seq) int32 windows of the byte corpus forever."""
    n = len(corpus) - seq - 1
    while True:
        idx = rng.randint(0, n, size=batch)
        yield np.stack([corpus[i : i + seq] for i in idx]).astype(np.int32)


def adam_init(params):
    z = lambda p: jax.tree_util.tree_map(jnp.zeros_like, p)
    return {"m": z(params), "v": z(params), "t": jnp.zeros(())}


def make_train_step(cfg: model_mod.Config, lr: float):
    def loss_fn(params, toks):
        logits = model_mod.forward(params, toks, cfg)
        return model_mod.next_token_loss(logits, toks)

    @jax.jit
    def step(params, opt, toks):
        loss, grads = jax.value_and_grad(loss_fn)(params, toks)
        t = opt["t"] + 1.0
        b1, b2, eps = 0.9, 0.95, 1e-8
        m = jax.tree_util.tree_map(lambda m_, g: b1 * m_ + (1 - b1) * g, opt["m"], grads)
        v = jax.tree_util.tree_map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, opt["v"], grads)
        mhat = jax.tree_util.tree_map(lambda m_: m_ / (1 - b1**t), m)
        vhat = jax.tree_util.tree_map(lambda v_: v_ / (1 - b2**t), v)
        params = jax.tree_util.tree_map(
            lambda p, mh, vh: p - lr * mh / (jnp.sqrt(vh) + eps), params, mhat, vhat
        )
        return params, {"m": m, "v": v, "t": t}, loss

    return step


def pretrain_model(name: str, corpus: np.ndarray, steps: int, batch: int, seq: int,
                   lr: float, seed: int = 0):
    cfg = model_mod.CONFIGS[name]
    params = model_mod.init_params(jax.random.PRNGKey(seed), cfg)
    opt = adam_init(params)
    step = make_train_step(cfg, lr)
    rng = np.random.RandomState(seed + 7)
    gen = batches(corpus, rng, batch, seq)
    log = []
    t0 = time.time()
    for s in range(steps):
        params, opt, loss = step(params, opt, next(gen))
        if s % 10 == 0 or s == steps - 1:
            loss_v = float(loss)
            log.append({"step": s, "loss": loss_v, "elapsed_s": time.time() - t0})
            print(f"[{name}] step {s:4d} loss {loss_v:.4f} "
                  f"ppl {np.exp(loss_v):.2f} ({time.time()-t0:.0f}s)", flush=True)
    return params, log


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--models", default="sq-2m")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1.5e-3)
    ap.add_argument("--out", default="../artifacts")
    args = ap.parse_args()

    os.makedirs(os.path.join(args.out, "data"), exist_ok=True)
    os.makedirs(os.path.join(args.out, "weights"), exist_ok=True)

    # 1. Corpora.
    for cname in data_mod.CORPORA:
        train, test = data_mod.build_corpus(cname)
        for split, blob in [("train", train), ("test", test)]:
            p = os.path.join(args.out, "data", f"{cname}.{split}.bin")
            with open(p, "wb") as f:
                f.write(blob)
            print(f"wrote {p} ({len(blob)} bytes)")

    wiki_train = np.frombuffer(
        open(os.path.join(args.out, "data", "wiki-syn.train.bin"), "rb").read(),
        dtype=np.uint8,
    )

    # 2-4. Train each requested model.
    for name in args.models.split(","):
        name = name.strip()
        params, log = pretrain_model(
            name, wiki_train, args.steps, args.batch, args.seq, args.lr
        )
        wpath = os.path.join(args.out, "weights", f"{name}.sqt")
        write_sqt(wpath, {k: np.asarray(v) for k, v in params.items()})
        print(f"wrote {wpath}")
        with open(os.path.join(args.out, f"pretrain_log_{name}.json"), "w") as f:
            json.dump(
                {
                    "model": name,
                    "steps": args.steps,
                    "batch": args.batch,
                    "seq": args.seq,
                    "lr": args.lr,
                    "curve": log,
                    "n_params": model_mod.CONFIGS[name].n_params,
                },
                f,
                indent=1,
            )


if __name__ == "__main__":
    main()
