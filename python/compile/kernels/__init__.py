"""L1 Pallas kernels for SpinQuant (build-time only; interpret=True on CPU).

`USE_PALLAS=0` in the environment swaps every kernel for its pure-jnp oracle
in `ref.py` — useful for fast artifact builds; pytest validates both paths
against each other so the swap is behaviour-preserving.
"""

import os

from . import ref  # noqa: F401

USE_PALLAS = os.environ.get("USE_PALLAS", "1") != "0"

if USE_PALLAS:
    from .fake_quant import fake_quant, fake_quant_ste  # noqa: F401
    from .hadamard import fwht  # noqa: F401
    from .qmatmul import qmatmul, quantize_cols_sym, quantize_rows  # noqa: F401
else:  # pragma: no cover - exercised via USE_PALLAS=0 builds
    import jax

    def fake_quant(x, bits, symmetric=0.0, clip_ratio=1.0, interpret=True):
        return ref.fake_quant_ref(x, bits, axis=-1, symmetric=symmetric, clip_ratio=clip_ratio)

    @jax.custom_vjp
    def fake_quant_ste(x, bits, symmetric, clip_ratio):
        return fake_quant(x, bits, symmetric, clip_ratio)

    def _ste_fwd(x, bits, symmetric, clip_ratio):
        return fake_quant_ste(x, bits, symmetric, clip_ratio), None

    def _ste_bwd(_, g):
        return g, None, None, None

    fake_quant_ste.defvjp(_ste_fwd, _ste_bwd)

    def fwht(x, interpret=True):
        return ref.fwht_ref(x)

    from .qmatmul import qmatmul, quantize_cols_sym, quantize_rows  # noqa: F401
