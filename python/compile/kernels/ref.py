"""Pure-jnp reference oracles for the Pallas kernels (L1 correctness ground truth).

Every kernel in this package has a `*_ref` twin here. python/tests asserts
allclose between the Pallas (interpret-mode) kernels and these oracles over
hypothesis-generated shape/value sweeps; the same formulas are re-implemented
in rust/src/quant and rust/src/hadamard and cross-checked by integration
tests through the PJRT runtime.

Quantization formulation (paper Eq. 1):
    symmetric:   alpha = max|x| / (2^(N-1) - 1),  beta = 0
    asymmetric:  alpha = (max x - min x) / (2^N - 1),  beta = min x
    x_q = alpha * round((x - beta) / alpha) + beta

Bit-widths are *runtime scalars* so one AOT artifact serves every W-A-KV
configuration in Table 1: bits >= 16 means pass-through (no quantization).
"""

from __future__ import annotations

import jax.numpy as jnp

EPS = 1e-8


def fake_quant_ref(
    x,
    bits,
    axis: int = -1,
    symmetric=False,
    clip_ratio=1.0,
):
    """Quantize-dequantize `x` along `axis` (per-token / per-channel groups).

    Args:
      x: float array.
      bits: scalar (python or traced). bits >= 16 -> identity.
      axis: reduction axis defining the quantization group (e.g. -1 for
        per-token quantization of (batch, seq, d) activations).
      symmetric: scalar bool/0-1 flag (may be traced). True -> symmetric.
      clip_ratio: scalar in (0, 1]; scales the min/max range (Atom-style
        clipping, Table 12).

    Returns: dequantized array, same shape/dtype as x.
    """
    bits = jnp.asarray(bits, dtype=jnp.float32)
    symmetric = jnp.asarray(symmetric, dtype=jnp.float32)
    clip_ratio = jnp.asarray(clip_ratio, dtype=jnp.float32)

    xmin = jnp.min(x, axis=axis, keepdims=True) * clip_ratio
    xmax = jnp.max(x, axis=axis, keepdims=True) * clip_ratio

    # Asymmetric branch.
    n_asym = jnp.exp2(bits) - 1.0
    scale_a = jnp.maximum((xmax - xmin) / n_asym, EPS)
    q_a = jnp.round((x - xmin) / scale_a)
    q_a = jnp.clip(q_a, 0.0, n_asym)
    dq_a = q_a * scale_a + xmin

    # Symmetric branch.
    absmax = jnp.maximum(jnp.abs(xmin), jnp.abs(xmax))
    n_sym = jnp.exp2(bits - 1.0) - 1.0
    scale_s = jnp.maximum(absmax / n_sym, EPS)
    q_s = jnp.round(x / scale_s)
    q_s = jnp.clip(q_s, -n_sym - 1.0, n_sym)
    dq_s = q_s * scale_s

    dq = jnp.where(symmetric > 0.5, dq_s, dq_a)
    return jnp.where(bits >= 16.0, x, dq).astype(x.dtype)


def fwht_ref(x):
    """Normalized fast Walsh-Hadamard transform along the last axis.

    x.shape[-1] must be a power of two. Equivalent to x @ H_n / sqrt(n)
    with H_n the Sylvester Hadamard matrix (symmetric, H H^T = n I), so the
    normalized transform is orthonormal and an involution.
    """
    n = x.shape[-1]
    assert n & (n - 1) == 0, f"FWHT size must be a power of two, got {n}"
    orig_shape = x.shape
    x = x.reshape(-1, n)
    h = 1
    while h < n:
        x = x.reshape(-1, n // (2 * h), 2, h)
        a = x[:, :, 0, :]
        b = x[:, :, 1, :]
        x = jnp.stack([a + b, a - b], axis=2).reshape(-1, n)
        h *= 2
    return (x / jnp.sqrt(jnp.asarray(n, x.dtype))).reshape(orig_shape)


def hadamard_matrix_ref(n):
    """Dense normalized Sylvester Hadamard matrix (for cross-checks)."""
    assert n & (n - 1) == 0
    H = jnp.ones((1, 1), dtype=jnp.float32)
    while H.shape[0] < n:
        H = jnp.concatenate(
            [jnp.concatenate([H, H], axis=1), jnp.concatenate([H, -H], axis=1)],
            axis=0,
        )
    return H / jnp.sqrt(jnp.asarray(n, jnp.float32))


def qmatmul_ref(x, w, x_bits, w_bits, x_symmetric=False, w_symmetric=True):
    """Quantized matmul oracle: fake-quant x per-token (rows) and w
    per-output-channel, then matmul.  x: (m, k), w: (k, n) -> (m, n).
    """
    xq = fake_quant_ref(x, x_bits, axis=-1, symmetric=x_symmetric)
    # Per-output-channel weight quant: group along k (axis 0 of w).
    wq = fake_quant_ref(w, w_bits, axis=0, symmetric=w_symmetric)
    return xq @ wq


def kurtosis_ref(x, axis=None):
    """Pearson kurtosis (not excess): E[(x-mu)^4] / E[(x-mu)^2]^2.

    ~3 for Gaussian; large values indicate outliers (paper Fig. 3a).
    """
    mu = jnp.mean(x, axis=axis, keepdims=True)
    c = x - mu
    m2 = jnp.mean(c**2, axis=axis)
    m4 = jnp.mean(c**4, axis=axis)
    return m4 / jnp.maximum(m2**2, EPS)
