"""L1 Pallas kernel: fast Walsh-Hadamard transform (the online R3/R4 rotations).

SpinQuant_had applies two *online* Hadamard rotations per block: R3 on the
per-head queries/keys (protects 4-bit KV-cache quantization) and R4 on the
input of `down_proj` (kills the MLP activation outliers).  QuaRot/QuIP# do
this with a CUDA warp-butterfly kernel; the TPU rethink (DESIGN.md
§Hardware-Adaptation) keeps a `(BLOCK_ROWS, n)` tile resident in VMEM and
runs the log2(n) butterfly stages as reshaped VPU add/sub sweeps — the data
makes exactly one HBM round-trip, so the op is bandwidth-bound like the CUDA
original.

The transform is the *normalized Sylvester* Hadamard (symmetric, involutive,
orthonormal): H = H^T = H^{-1}, so merging the inverse into a weight matrix
is the same FWHT applied to the weight's input axis.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_ROWS = 128


def _fwht_kernel(x_ref, o_ref, *, n):
    """Butterfly stages over a VMEM tile; n static so the loop unrolls."""
    rows = x_ref.shape[0]
    x = x_ref[...]
    h = 1
    while h < n:
        x = x.reshape(rows, n // (2 * h), 2, h)
        a = x[:, :, 0, :]
        b = x[:, :, 1, :]
        x = jnp.stack([a + b, a - b], axis=2).reshape(rows, n)
        h *= 2
    o_ref[...] = x * (1.0 / (n**0.5))


@functools.partial(jax.jit, static_argnames=("interpret",))
def fwht_2d(x, interpret=True):
    rows, n = x.shape
    assert n & (n - 1) == 0, f"FWHT size must be a power of two, got {n}"
    block_rows = min(BLOCK_ROWS, rows)
    grid = (pl.cdiv(rows, block_rows),)
    return pl.pallas_call(
        functools.partial(_fwht_kernel, n=n),
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows, n), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_rows, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, n), x.dtype),
        interpret=interpret,
    )(x)


def fwht(x, interpret=True):
    """Normalized FWHT along the last axis of an arbitrary-rank array."""
    shape = x.shape
    return fwht_2d(x.reshape(-1, shape[-1]), interpret=interpret).reshape(shape)
