"""L1 Pallas kernel: tiled quantized matmul (dequant-on-load GEMM).

The paper's W4A4 inference multiplies int4 activations by int4 weights.  On
GPU that is a WMMA int4 tensor-core GEMM; the TPU rethink (DESIGN.md
§Hardware-Adaptation) instead streams 4-bit-footprint tiles HBM->VMEM,
dequantizes on load inside VMEM, and feeds the MXU systolic array at its
native float precision: the memory system sees quantized data, the MXU sees
floats.  BlockSpec plays the role of the CUDA threadblock tiling.

Here the "quantized" operands are (q, scale, zero) triples with q stored as
f32 integer values (interpret mode / CPU PJRT has no packed-int4 dtype); the
packing math lives in rust/src/quant (the runtime side).  The kernel fuses:

    out[bm, bn] = sum_k (qx*sx+zx)[bm, bk] @ (qw*sw)[bk, bn]

accumulating into the revisited output tile across the innermost k grid axis
(the output block index map is k-independent, the standard Pallas
multiple-visit accumulation pattern).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BM, BK, BN = 128, 128, 128


def _qmm_kernel(qx_ref, sx_ref, zx_ref, qw_ref, sw_ref, o_ref, *, nk):
    """One (bm, bn) output tile; k is the innermost grid axis."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # Dequant-on-load: activations per-row asym, weights per-column sym.
    x = qx_ref[...] * sx_ref[...] + zx_ref[...]
    w = qw_ref[...] * sw_ref[...]
    o_ref[...] += jnp.dot(x, w, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def qmatmul(qx, sx, zx, qw, sw, interpret=True):
    """(m,k) quantized activations x (k,n) quantized weights -> (m,n) f32.

    qx: integer-valued f32 codes; sx, zx: (m, 1) per-row scale / zero-point.
    qw: integer-valued f32 codes; sw: (1, n) per-column scale.
    """
    m, k = qx.shape
    k2, n = qw.shape
    assert k == k2
    bm, bk, bn = min(BM, m), min(BK, k), min(BN, n)

    # Zero-pad to tile multiples: interpret mode pads out-of-bounds loads
    # with NaN, which would poison the k-axis accumulation. Zero codes with
    # zero scales/zeros contribute exactly 0 to the dot product.
    def _pad(a, mults):
        pads = [(0, -dim % mult) for dim, mult in zip(a.shape, mults)]
        return jnp.pad(a, pads) if any(p[1] for p in pads) else a

    qx, sx, zx = _pad(qx, (bm, bk)), _pad(sx, (bm, 1)), _pad(zx, (bm, 1))
    qw, sw = _pad(qw, (bk, bn)), _pad(sw, (1, bn))
    mp, kp = qx.shape
    np_ = qw.shape[1]

    nk = pl.cdiv(kp, bk)
    grid = (pl.cdiv(mp, bm), pl.cdiv(np_, bn), nk)
    out = pl.pallas_call(
        functools.partial(_qmm_kernel, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bm, 1), lambda i, j, kk: (i, 0)),
            pl.BlockSpec((bm, 1), lambda i, j, kk: (i, 0)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=interpret,
    )(qx, sx, zx, qw, sw)
    return out[:m, :n]


def quantize_rows(x, bits):
    """Produce (q, scale, zero) per-row asymmetric codes for qmatmul."""
    n_levels = 2.0 ** bits - 1.0
    xmin = jnp.min(x, axis=-1, keepdims=True)
    xmax = jnp.max(x, axis=-1, keepdims=True)
    scale = jnp.maximum((xmax - xmin) / n_levels, 1e-8)
    q = jnp.clip(jnp.round((x - xmin) / scale), 0.0, n_levels)
    return q, scale, xmin


def quantize_cols_sym(w, bits):
    """Produce (q, scale) per-column symmetric codes for qmatmul."""
    n_sym = 2.0 ** (bits - 1.0) - 1.0
    absmax = jnp.max(jnp.abs(w), axis=0, keepdims=True)
    scale = jnp.maximum(absmax / n_sym, 1e-8)
    q = jnp.clip(jnp.round(w / scale), -n_sym - 1.0, n_sym)
    return q, scale
