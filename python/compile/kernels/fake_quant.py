"""L1 Pallas kernel: fused fake-quantization (quantize-dequantize).

The compute hot-spot of every quantized forward pass: for each token row,
reduce min/max, derive scale/zero-point, round, clamp, dequantize — one
fused pass over a VMEM-resident tile.

TPU mapping (DESIGN.md §Hardware-Adaptation): the CUDA implementations in
QuaRot/Atom do a warp reduction + elementwise pass in shared memory; here
BlockSpec streams `(BLOCK_ROWS, n)` row tiles HBM->VMEM and the VPU does the
row reduction and the elementwise quant math in one pass — no second trip to
HBM for the scales.  Runs with `interpret=True` (CPU PJRT cannot execute
Mosaic custom-calls), so correctness is validated here and on-TPU efficiency
is argued structurally (one HBM round-trip per tile).

`bits`, `symmetric` and `clip_ratio` are runtime scalars (SMEM operands) so
one lowered artifact serves every W-A-KV configuration of paper Table 1;
`bits >= 16` is a pass-through.

A custom-vjp straight-through estimator (`fake_quant_ste`) wraps the kernel
for the Cayley-SGD gradient artifact: dL/dx passes through the rounding,
which is exactly what makes paper Eq. 5 non-zero only under quantization.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

BLOCK_ROWS = 128


def _fake_quant_kernel(cfg_ref, x_ref, o_ref):
    """One (BLOCK_ROWS, n) tile: rowwise min/max -> scale/zp -> q -> dq."""
    x = x_ref[...]
    bits = cfg_ref[0]
    symmetric = cfg_ref[1]
    clip_ratio = cfg_ref[2]

    xmin = jnp.min(x, axis=-1, keepdims=True) * clip_ratio
    xmax = jnp.max(x, axis=-1, keepdims=True) * clip_ratio

    # Asymmetric path (paper Eq. 1, beta = min).
    n_asym = jnp.exp2(bits) - 1.0
    scale_a = jnp.maximum((xmax - xmin) / n_asym, ref.EPS)
    dq_a = jnp.clip(jnp.round((x - xmin) / scale_a), 0.0, n_asym) * scale_a + xmin

    # Symmetric path (beta = 0).
    absmax = jnp.maximum(jnp.abs(xmin), jnp.abs(xmax))
    n_sym = jnp.exp2(bits - 1.0) - 1.0
    scale_s = jnp.maximum(absmax / n_sym, ref.EPS)
    dq_s = jnp.clip(jnp.round(x / scale_s), -n_sym - 1.0, n_sym) * scale_s

    dq = jnp.where(symmetric > 0.5, dq_s, dq_a)
    o_ref[...] = jnp.where(bits >= 16.0, x, dq)


@functools.partial(jax.jit, static_argnames=("interpret",))
def fake_quant_2d(x, bits, symmetric, clip_ratio, interpret=True):
    """Pallas fake-quant over a 2D (rows, n) array, per-row groups."""
    rows, n = x.shape
    block_rows = min(BLOCK_ROWS, rows)
    # Grid over row tiles; pallas masks the remainder tile.
    grid = (pl.cdiv(rows, block_rows),)
    cfg = jnp.stack(
        [
            jnp.asarray(bits, jnp.float32),
            jnp.asarray(symmetric, jnp.float32),
            jnp.asarray(clip_ratio, jnp.float32),
        ]
    )
    return pl.pallas_call(
        _fake_quant_kernel,
        grid=grid,
        in_specs=[
            # cfg scalars: every tile reads the same 3-vector.
            pl.BlockSpec((3,), lambda i: (0,)),
            pl.BlockSpec((block_rows, n), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, n), x.dtype),
        interpret=interpret,
    )(cfg, x)


def fake_quant(x, bits, symmetric=0.0, clip_ratio=1.0, interpret=True):
    """Fake-quantize `x` along its last axis (per-token groups).

    Works for any rank: collapses leading dims to rows, calls the 2D kernel.
    """
    shape = x.shape
    y = fake_quant_2d(
        x.reshape(-1, shape[-1]), bits, symmetric, clip_ratio, interpret=interpret
    )
    return y.reshape(shape)


# ---------------------------------------------------------------------------
# Straight-through estimator wrapper for the Cayley gradient graph.
# ---------------------------------------------------------------------------


@jax.custom_vjp
def fake_quant_ste(x, bits, symmetric, clip_ratio):
    return fake_quant(x, bits, symmetric, clip_ratio)


def _ste_fwd(x, bits, symmetric, clip_ratio):
    return fake_quant_ste(x, bits, symmetric, clip_ratio), None


def _ste_bwd(_, g):
    # Pass-through: d(fake_quant)/dx := I. No gradient to the quant config.
    return g, None, None, None


fake_quant_ste.defvjp(_ste_fwd, _ste_bwd)
