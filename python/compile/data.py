"""Synthetic corpora — the WikiText-2 / C4 stand-ins (DESIGN.md §3).

A seeded order-1 Markov word process with a Zipf-distributed vocabulary and
light sentence structure: enough statistical regularity for a byte-level
tiny-LLaMA to learn (ppl well below the uniform-256 baseline), deterministic
across runs, and two distinct "datasets" (wiki-syn / c4-syn use different
seeds, vocabulary sizes and Zipf exponents) for the calibration-robustness
ablation (paper Table 13).

Generated once at build time by pretrain.py; rust/src/data reads the .bin
byte streams (train/test splits) directly.
"""

from __future__ import annotations

import numpy as np

LETTERS = "abcdefghijklmnopqrstuvwxyz"


class SynthCorpus:
    def __init__(self, seed: int, n_words: int = 1500, zipf_a: float = 1.15,
                 branching: int = 6):
        self.rng = np.random.RandomState(seed)
        self.n_words = n_words
        self.zipf_a = zipf_a
        self.branching = branching
        self.words = self._make_vocab()
        self.trans = self._make_transitions()
        # Zipf-ish unigram distribution over rank.
        ranks = np.arange(1, n_words + 1, dtype=np.float64)
        p = ranks ** (-zipf_a)
        self.unigram = p / p.sum()

    def _make_vocab(self):
        words, seen = [], set()
        while len(words) < self.n_words:
            ln = self.rng.randint(2, 10)
            w = "".join(LETTERS[self.rng.randint(0, 26)] for _ in range(ln))
            if w not in seen:
                seen.add(w)
                words.append(w)
        return words

    def _make_transitions(self):
        """Sparse successor sets: each word prefers `branching` successors."""
        trans = self.rng.randint(0, self.n_words, size=(self.n_words, self.branching))
        return trans

    def generate(self, n_bytes: int) -> bytes:
        out = []
        total = 0
        w = int(self.rng.randint(0, self.n_words))
        sent_len = 0
        target_sent = int(self.rng.randint(5, 15))
        first = True
        while total < n_bytes:
            word = self.words[w]
            if first:
                word = word.capitalize()
                first = False
            piece = word
            sent_len += 1
            if sent_len >= target_sent:
                piece += "."
                sent_len = 0
                target_sent = int(self.rng.randint(5, 15))
                first = True
                piece += "\n" if self.rng.rand() < 0.15 else " "
            else:
                piece += " "
            out.append(piece)
            total += len(piece)
            # 85%: Markov successor; 15%: fresh Zipf draw (keeps entropy up).
            if self.rng.rand() < 0.85:
                w = int(self.trans[w, self.rng.randint(0, self.branching)])
            else:
                w = int(self.rng.choice(self.n_words, p=self.unigram))
        return "".join(out).encode("ascii")[:n_bytes]


CORPORA = {
    # name: (seed, n_words, zipf_a, branching)
    "wiki-syn": (1001, 1500, 1.15, 6),
    "c4-syn": (2002, 2200, 1.05, 9),
}


def build_corpus(name: str, train_bytes: int = 393216, test_bytes: int = 49152):
    seed, n_words, zipf_a, branching = CORPORA[name]
    c = SynthCorpus(seed, n_words, zipf_a, branching)
    train = c.generate(train_bytes)
    test = c.generate(test_bytes)
    return train, test
