"""AOT lowering: every L2 graph -> HLO *text* artifact + manifest.json.

HLO text (not `.serialize()`): jax >= 0.5 emits HloModuleProto with 64-bit
instruction ids which xla_extension 0.5.1 (the version behind the published
`xla` 0.1.6 rust crate) rejects; the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Artifact zoo per model (DESIGN.md §6) — bit-widths are runtime scalars so a
single lowered module covers all W-A-KV rows of paper Table 1:

  fwd_eval_{nohad,had}   (B=8,  S=64) -> logits          perplexity engine
  fwd_task_{nohad,had}   (B=16, S=32) -> logits          zero-shot harness
  fwd_stats              (B=8,  S=64) -> logits + taps   Figs. 2/3/8 stats
  cayley_{nohad,had}     (B=4,  S=64) -> loss, dR1, dR2  rotation learning
  decode_{fp,nohad,had}  (B=1, cache=max_seq) -> logits  serving / Table 6
  decode_*_b{4,8}        (B slots, per-slot pos) -> logits   continuous
                         batching (rust/src/serve scheduler + slot manager)
  prefill_*_b{4,8}_t{16,64}  (B slots, T tokens/slot, per-slot pos +
                         n_valid) -> last-valid logits    batched prompt
                         prefill: ceil(len/T) calls to first token
  decode_*_paged_b{4,8}  (block-pool cache (L, n_blocks, bs, H, dh) +
                         per-slot block table) -> logits  paged KV serving:
                         memory scales with tokens in flight, not slots
  prefill_*_paged_b{4,8}_t16  paged twin of the prefill graphs

Quantized KV pages (`serve --kv-bits`) need no artifacts of their own: the
paged quant variants already take the qcfg vector as a runtime input, and
the graphs fake-quant K/V at qcfg[1] bits before scattering to physical
pages, so one lowered module covers 4/8/16-bit KV storage (16 = exact
pass-through).

The manifest records the exact input ABI (names, shapes, dtypes, order) for
each artifact; rust/src/runtime asserts against it at load time.

Usage: python -m compile.aot --models sq-2m --out ../artifacts
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as model_mod

EVAL_B, EVAL_S = 8, 64
TASK_B, TASK_S = 16, 32
CAYLEY_B, CAYLEY_S = 4, 64
DECODE_B = 1
# Slot counts for the continuous-batching decode artifacts (the serving
# bench sweeps batch \in {1, 4, 8}; 1 reuses the scalar-pos artifact).
DECODE_BATCHES = (4, 8)
# Chunk sizes for the batched multi-token prefill artifacts: a prompt is
# consumed in ceil(len/T) prefill calls instead of len decode calls.
PREFILL_TS = (16, 64)
# Paged KV cache: page granularity (tokens per physical block) and the
# physical pool size per batched artifact. n_blocks = batch * max_seq / bs
# makes the identity block table exactly memory-equivalent to the dense
# cache (the rust scheduler can still admit against a smaller token budget
# via `serve --kv-blocks`).
KV_BLOCK_SIZE = 16
# Chunk sizes lowered for the *paged* prefill artifacts (t16 only: the
# paged serving path chunks at the page size).
PREFILL_PAGED_TS = (16,)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def build_artifacts(cfg: model_mod.Config):
    """Return {artifact_name: (fn, [input specs], [input names], [output names])}."""
    names = model_mod.param_order(cfg)
    shapes = model_mod.param_shapes(cfg)
    n_params = len(names)
    pspecs = [_spec(shapes[n]) for n in names]

    def unpack(args):
        return dict(zip(names, args[:n_params])), args[n_params:]

    arts = {}

    def fwd_factory(had, B, S):
        def fn(*args):
            params, rest = unpack(args)
            tokens, qcfg = rest
            return (model_mod.forward(params, tokens, cfg, qcfg=qcfg, had=had),)

        specs = pspecs + [_spec((B, S), jnp.int32), _spec((model_mod.QCFG_LEN,))]
        innames = names + ["tokens", "qcfg"]
        return fn, specs, innames, ["logits"]

    arts["fwd_eval_nohad"] = fwd_factory(False, EVAL_B, EVAL_S)
    arts["fwd_eval_had"] = fwd_factory(True, EVAL_B, EVAL_S)
    arts["fwd_task_nohad"] = fwd_factory(False, TASK_B, TASK_S)
    arts["fwd_task_had"] = fwd_factory(True, TASK_B, TASK_S)

    def stats_fn(*args):
        params, rest = unpack(args)
        (tokens,) = rest
        logits, caps = model_mod.forward(params, tokens, cfg, capture=True)
        return (logits, caps["resid_in"], caps["oproj_in"], caps["ffn_in"],
                caps["down_in"], caps["k"], caps["v"], caps["head_in"])

    arts["fwd_stats"] = (
        stats_fn,
        pspecs + [_spec((EVAL_B, EVAL_S), jnp.int32)],
        names + ["tokens"],
        ["logits", "resid_in", "oproj_in", "ffn_in", "down_in", "k", "v", "head_in"],
    )

    def cayley_factory(had):
        def fn(*args):
            params, rest = unpack(args)
            r1, r2s, tokens, qcfg = rest
            loss, g1, g2 = model_mod.cayley_loss_and_grads(
                params, r1, r2s, tokens, cfg, qcfg, had
            )
            return (loss, g1, g2)

        d, dh, L = cfg.d_model, cfg.d_head, cfg.n_layers
        specs = pspecs + [
            _spec((d, d)),
            _spec((L, dh, dh)),
            _spec((CAYLEY_B, CAYLEY_S), jnp.int32),
            _spec((model_mod.QCFG_LEN,)),
        ]
        innames = names + ["r1", "r2s", "tokens", "qcfg"]
        return fn, specs, innames, ["loss", "grad_r1", "grad_r2s"]

    arts["cayley_nohad"] = cayley_factory(False)
    arts["cayley_had"] = cayley_factory(True)

    def qat_fn(*args):
        params, rest = unpack(args)
        tokens, qcfg = rest
        loss, grads = model_mod.qat_loss_and_grads(params, tokens, cfg, qcfg)
        return (loss,) + tuple(grads[n] for n in names)

    arts["qat_grads"] = (
        qat_fn,
        pspecs + [_spec((CAYLEY_B, CAYLEY_S), jnp.int32), _spec((model_mod.QCFG_LEN,))],
        names + ["tokens", "qcfg"],
        ["loss"] + [f"grad_{n}" for n in names],
    )

    cache_shape = (cfg.n_layers, DECODE_B, cfg.max_seq, cfg.n_heads, cfg.d_head)

    def decode_factory(quant, had):
        def fn(*args):
            params, rest = unpack(args)
            if quant:
                token, pos, ck, cv, qcfg = rest
            else:
                token, pos, ck, cv = rest
                qcfg = None
            return model_mod.decode_step(
                params, cfg, token, pos, ck, cv, qcfg=qcfg, had=had
            )

        specs = pspecs + [
            _spec((DECODE_B,), jnp.int32),
            _spec((), jnp.int32),
            _spec(cache_shape),
            _spec(cache_shape),
        ]
        innames = names + ["token", "pos", "cache_k", "cache_v"]
        if quant:
            specs.append(_spec((model_mod.QCFG_LEN,)))
            innames.append("qcfg")
        return fn, specs, innames, ["logits", "cache_k", "cache_v"]

    arts["decode_fp"] = decode_factory(False, False)
    arts["decode_nohad"] = decode_factory(True, False)
    arts["decode_had"] = decode_factory(True, True)

    def decode_batched_factory(quant, had, batch):
        cache_shape_b = (cfg.n_layers, batch, cfg.max_seq, cfg.n_heads, cfg.d_head)

        def fn(*args):
            params, rest = unpack(args)
            if quant:
                token, pos, ck, cv, qcfg = rest
            else:
                token, pos, ck, cv = rest
                qcfg = None
            return model_mod.decode_step_batched(
                params, cfg, token, pos, ck, cv, qcfg=qcfg, had=had
            )

        specs = pspecs + [
            _spec((batch,), jnp.int32),
            _spec((batch,), jnp.int32),
            _spec(cache_shape_b),
            _spec(cache_shape_b),
        ]
        innames = names + ["token", "pos", "cache_k", "cache_v"]
        if quant:
            specs.append(_spec((model_mod.QCFG_LEN,)))
            innames.append("qcfg")
        return fn, specs, innames, ["logits", "cache_k", "cache_v"]

    for batch in DECODE_BATCHES:
        arts[f"decode_fp_b{batch}"] = decode_batched_factory(False, False, batch)
        arts[f"decode_nohad_b{batch}"] = decode_batched_factory(True, False, batch)
        arts[f"decode_had_b{batch}"] = decode_batched_factory(True, True, batch)

    def prefill_factory(quant, had, batch, t_chunk):
        cache_shape_b = (cfg.n_layers, batch, cfg.max_seq, cfg.n_heads, cfg.d_head)

        def fn(*args):
            params, rest = unpack(args)
            if quant:
                tokens, pos, n_valid, ck, cv, qcfg = rest
            else:
                tokens, pos, n_valid, ck, cv = rest
                qcfg = None
            return model_mod.prefill_batched(
                params, cfg, tokens, pos, n_valid, ck, cv, qcfg=qcfg, had=had
            )

        specs = pspecs + [
            _spec((batch, t_chunk), jnp.int32),
            _spec((batch,), jnp.int32),
            _spec((batch,), jnp.int32),
            _spec(cache_shape_b),
            _spec(cache_shape_b),
        ]
        innames = names + ["tokens", "pos", "n_valid", "cache_k", "cache_v"]
        if quant:
            specs.append(_spec((model_mod.QCFG_LEN,)))
            innames.append("qcfg")
        return fn, specs, innames, ["logits", "cache_k", "cache_v"]

    for batch in DECODE_BATCHES:
        for t_chunk in PREFILL_TS:
            arts[f"prefill_fp_b{batch}_t{t_chunk}"] = prefill_factory(False, False, batch, t_chunk)
            arts[f"prefill_nohad_b{batch}_t{t_chunk}"] = prefill_factory(True, False, batch, t_chunk)
            arts[f"prefill_had_b{batch}_t{t_chunk}"] = prefill_factory(True, True, batch, t_chunk)

    # -- paged KV cache (block-pool) twins ---------------------------------
    assert cfg.max_seq % KV_BLOCK_SIZE == 0
    n_logical = cfg.max_seq // KV_BLOCK_SIZE

    def decode_paged_factory(quant, had, batch):
        n_blocks = batch * n_logical
        cache_shape_p = (cfg.n_layers, n_blocks, KV_BLOCK_SIZE, cfg.n_heads, cfg.d_head)

        def fn(*args):
            params, rest = unpack(args)
            if quant:
                token, pos, table, ck, cv, qcfg = rest
            else:
                token, pos, table, ck, cv = rest
                qcfg = None
            return model_mod.decode_paged(
                params, cfg, token, pos, table, ck, cv, qcfg=qcfg, had=had
            )

        specs = pspecs + [
            _spec((batch,), jnp.int32),
            _spec((batch,), jnp.int32),
            _spec((batch, n_logical), jnp.int32),
            _spec(cache_shape_p),
            _spec(cache_shape_p),
        ]
        innames = names + ["token", "pos", "block_table", "cache_k", "cache_v"]
        if quant:
            specs.append(_spec((model_mod.QCFG_LEN,)))
            innames.append("qcfg")
        return fn, specs, innames, ["logits", "cache_k", "cache_v"]

    def prefill_paged_factory(quant, had, batch, t_chunk):
        n_blocks = batch * n_logical
        cache_shape_p = (cfg.n_layers, n_blocks, KV_BLOCK_SIZE, cfg.n_heads, cfg.d_head)

        def fn(*args):
            params, rest = unpack(args)
            if quant:
                tokens, pos, n_valid, table, ck, cv, qcfg = rest
            else:
                tokens, pos, n_valid, table, ck, cv = rest
                qcfg = None
            return model_mod.prefill_paged(
                params, cfg, tokens, pos, n_valid, table, ck, cv, qcfg=qcfg, had=had
            )

        specs = pspecs + [
            _spec((batch, t_chunk), jnp.int32),
            _spec((batch,), jnp.int32),
            _spec((batch,), jnp.int32),
            _spec((batch, n_logical), jnp.int32),
            _spec(cache_shape_p),
            _spec(cache_shape_p),
        ]
        innames = names + ["tokens", "pos", "n_valid", "block_table", "cache_k", "cache_v"]
        if quant:
            specs.append(_spec((model_mod.QCFG_LEN,)))
            innames.append("qcfg")
        return fn, specs, innames, ["logits", "cache_k", "cache_v"]

    for batch in DECODE_BATCHES:
        arts[f"decode_fp_paged_b{batch}"] = decode_paged_factory(False, False, batch)
        arts[f"decode_nohad_paged_b{batch}"] = decode_paged_factory(True, False, batch)
        arts[f"decode_had_paged_b{batch}"] = decode_paged_factory(True, True, batch)
        for t_chunk in PREFILL_PAGED_TS:
            arts[f"prefill_fp_paged_b{batch}_t{t_chunk}"] = prefill_paged_factory(
                False, False, batch, t_chunk)
            arts[f"prefill_nohad_paged_b{batch}_t{t_chunk}"] = prefill_paged_factory(
                True, False, batch, t_chunk)
            arts[f"prefill_had_paged_b{batch}_t{t_chunk}"] = prefill_paged_factory(
                True, True, batch, t_chunk)

    return arts


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--models", default="sq-2m")
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--only", default="", help="comma list of artifact names")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    manifest_path = os.path.join(args.out, "manifest.json")
    manifest = {"models": {}}
    if os.path.exists(manifest_path):
        with open(manifest_path) as f:
            manifest = json.load(f)

    only = {a for a in args.only.split(",") if a}

    for mname in args.models.split(","):
        mname = mname.strip()
        cfg = model_mod.CONFIGS[mname]
        arts = build_artifacts(cfg)
        mentry = manifest["models"].setdefault(mname, {})
        mentry["config"] = {
            "vocab": cfg.vocab, "d_model": cfg.d_model, "n_layers": cfg.n_layers,
            "n_heads": cfg.n_heads, "d_head": cfg.d_head, "d_ffn": cfg.d_ffn,
            "rope_theta": cfg.rope_theta, "max_seq": cfg.max_seq,
            "n_params": cfg.n_params,
        }
        mentry["param_order"] = model_mod.param_order(cfg)
        mentry.setdefault("artifacts", {})
        mentry["shapes"] = {
            "eval": [EVAL_B, EVAL_S], "task": [TASK_B, TASK_S],
            "cayley": [CAYLEY_B, CAYLEY_S], "decode_batch": DECODE_B,
            "decode_batches": list(DECODE_BATCHES),
            "prefill_ts": list(PREFILL_TS),
            # Paged KV cache: page size in tokens and physical pool size per
            # batched paged artifact (n_blocks = batch * max_seq / bs).
            "kv_block_size": KV_BLOCK_SIZE,
            "kv_blocks": {str(b): b * (cfg.max_seq // KV_BLOCK_SIZE)
                          for b in DECODE_BATCHES},
            "prefill_paged_ts": list(PREFILL_PAGED_TS),
        }
        for aname, (fn, specs, innames, outnames) in arts.items():
            if only and aname not in only:
                continue
            fname = f"{mname}_{aname}.hlo.txt"
            path = os.path.join(args.out, fname)
            print(f"lowering {fname} ...", flush=True)
            text = to_hlo_text(jax.jit(fn).lower(*specs))
            with open(path, "w") as f:
                f.write(text)
            mentry["artifacts"][aname] = {
                "file": fname,
                "inputs": [
                    {"name": n, "shape": list(s.shape), "dtype": str(s.dtype)}
                    for n, s in zip(innames, specs)
                ],
                "outputs": outnames,
            }
            print(f"  wrote {path} ({len(text)} chars)", flush=True)

        with open(manifest_path, "w") as f:
            json.dump(manifest, f, indent=1)
    print(f"wrote {manifest_path}")


if __name__ == "__main__":
    main()
