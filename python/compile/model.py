"""L2: tiny-LLaMA in JAX with SpinQuant rotation/quantization insertion points.

Architecture class matches LLaMA (pre-norm RMSNorm, RoPE, SwiGLU, causal
attention, untied head) so the paper's rotational-invariance algebra holds
exactly; sizes are scaled to the 1-core CPU testbed (see DESIGN.md §3).

One forward function serves every artifact variant:

* quantization config is a vector of *runtime scalars* (bits >= 16 means
  pass-through), so a single lowered module covers all W-A-KV settings of
  paper Table 1 — weights arrive already quantize-dequantized (RTN/GPTQ
  happen offline in rust), activations/KV are fake-quantized in-graph via
  the L1 Pallas kernel;
* `had=True` inserts the online R3 (q/k head-wise) and R4 (down_proj input)
  Hadamard rotations — `SpinQuant_had` / QuaRot inference path. The matching
  H-merge of `w_down` happens offline in rust (or in-graph for the Cayley
  artifact);
* `rot=(R1, R2_stack)` rotates weights *in-graph* (differentiably) — the
  Cayley-SGD loss/grad artifact optimizes R1/R2 through this path with STE
  fake-quant, paper Eq. 2-5;
* `capture=True` additionally returns the residual-read activations and
  KV tensors for the kurtosis / distribution / SNR analyses (Figs. 2, 3, 8).

Python runs only at build time: `aot.py` lowers everything here to HLO text
that the rust runtime loads via PJRT.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from .kernels import fake_quant_ste, fwht
from .kernels import ref as kref


@dataclasses.dataclass(frozen=True)
class Config:
    name: str
    vocab: int = 256
    d_model: int = 128
    n_layers: int = 4
    n_heads: int = 4
    d_head: int = 32
    d_ffn: int = 512
    rope_theta: float = 10000.0
    max_seq: int = 128

    @property
    def n_params(self) -> int:
        d, f = self.d_model, self.d_ffn
        per_layer = 4 * d * d + 3 * d * f + 2 * d
        return self.vocab * d * 2 + self.n_layers * per_layer + d


# The model zoo (DESIGN.md §3). All dims are powers of two so Sylvester
# Hadamard matrices exist for R1 (d_model), R2/R3 (d_head), R4 (d_ffn).
CONFIGS = {
    "sq-2m": Config("sq-2m", d_model=128, n_layers=4, n_heads=4, d_head=32, d_ffn=512),
    "sq-4m": Config("sq-4m", d_model=256, n_layers=4, n_heads=4, d_head=64, d_ffn=1024),
    "sq-9m": Config("sq-9m", d_model=256, n_layers=8, n_heads=8, d_head=32, d_ffn=1024),
}


def param_order(cfg: Config):
    """Canonical parameter ordering — the artifact input ABI.

    rust/src/model/mod.rs mirrors this order; aot.py also writes it into
    artifacts/manifest.json so the rust side can assert agreement.
    """
    names = ["emb"]
    for i in range(cfg.n_layers):
        p = f"layers.{i}."
        names += [
            p + "attn_norm",
            p + "wq",
            p + "wk",
            p + "wv",
            p + "wo",
            p + "ffn_norm",
            p + "wgate",
            p + "wup",
            p + "wdown",
        ]
    names += ["final_norm", "head"]
    return names


def param_shapes(cfg: Config):
    d, f, v = cfg.d_model, cfg.d_ffn, cfg.vocab
    hd = cfg.n_heads * cfg.d_head
    shapes = {"emb": (v, d), "final_norm": (d,), "head": (d, v)}
    for i in range(cfg.n_layers):
        p = f"layers.{i}."
        shapes[p + "attn_norm"] = (d,)
        shapes[p + "wq"] = (d, hd)
        shapes[p + "wk"] = (d, hd)
        shapes[p + "wv"] = (d, hd)
        shapes[p + "wo"] = (hd, d)
        shapes[p + "ffn_norm"] = (d,)
        shapes[p + "wgate"] = (d, f)
        shapes[p + "wup"] = (d, f)
        shapes[p + "wdown"] = (f, d)
    return shapes


# ---------------------------------------------------------------------------
# Quantization config vector (runtime scalars). Index ABI shared with rust.
# ---------------------------------------------------------------------------
# [0] a_bits   [1] kv_bits  [2] a_sym   [3] kv_sym  [4] a_clip  [5] kv_clip
# [6] w_bits   [7] w_sym    — in-graph weight fake-quant; w_bits=16 (the
# default) is exact pass-through because RTN/GPTQ weight quantization
# happens offline in rust. Only the LLM-QAT baseline trains with w_bits<16.
QCFG_LEN = 8


def qcfg_vector(a_bits=16.0, kv_bits=16.0, a_sym=0.0, kv_sym=0.0, a_clip=1.0,
                kv_clip=1.0, w_bits=16.0, w_sym=1.0):
    return jnp.asarray(
        [a_bits, kv_bits, a_sym, kv_sym, a_clip, kv_clip, w_bits, w_sym],
        jnp.float32,
    )


def _aq(x, qcfg):
    """Activation fake-quant (per-token, last axis) with STE."""
    return fake_quant_ste(x, qcfg[0], qcfg[2], qcfg[4])


def _kvq(x, qcfg):
    """KV-cache fake-quant (per-token per-head, last axis = d_head) with STE."""
    return fake_quant_ste(x, qcfg[1], qcfg[3], qcfg[5])


def _wq(w, qcfg):
    """Weight fake-quant, per-output-channel groups (reduce over the input
    dim), with STE — used by the in-graph QAT path; pass-through at 16."""
    return fake_quant_ste(w.T, qcfg[6], qcfg[7], 1.0).T


# ---------------------------------------------------------------------------
# Differentiable online Hadamard (custom vjp: H is symmetric orthogonal, so
# the pullback of x |-> fwht(x) is fwht itself; avoids AD through pallas).
# ---------------------------------------------------------------------------


@jax.custom_vjp
def fwht_diff(x):
    return fwht(x)


def _fwht_fwd(x):
    return fwht_diff(x), None


def _fwht_bwd(_, g):
    return (fwht_diff(g),)


fwht_diff.defvjp(_fwht_fwd, _fwht_bwd)


# ---------------------------------------------------------------------------
# Building blocks
# ---------------------------------------------------------------------------


def rmsnorm(x, gamma, eps=1e-5):
    rms = jnp.sqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return x / rms * gamma


def rope_angles(cfg: Config, positions):
    """positions: (S,) int32 -> cos/sin of shape (S, d_head/2)."""
    half = cfg.d_head // 2
    freqs = cfg.rope_theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[:, None] * freqs[None, :]
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x: (B, S, H, dh); rotate consecutive pairs."""
    b, s, h, dh = x.shape
    x = x.reshape(b, s, h, dh // 2, 2)
    x0, x1 = x[..., 0], x[..., 1]
    c = cos[None, :, None, :]
    sn = sin[None, :, None, :]
    y0 = x0 * c - x1 * sn
    y1 = x0 * sn + x1 * c
    return jnp.stack([y0, y1], axis=-1).reshape(b, s, h, dh)


def _rotate_weights_ingraph(params, cfg: Config, r1, r2s, had: bool):
    """Differentiable R1/R2 (and constant R4-merge when had) weight rotation.

    Mirrors the offline merge in rust/src/rotation: input-side reads get
    R1^T W, output-side writes get W R1, W_v gets R2 per head on its output,
    W_o gets R2^T per head on its input, w_down additionally gets the
    Hadamard merge on its input axis when the online R4 is active.
    Assumes RMSNorm scales have been folded (gamma == 1).
    """
    out = dict(params)
    out["emb"] = params["emb"] @ r1
    out["head"] = r1.T @ params["head"]
    h, dh = cfg.n_heads, cfg.d_head
    for i in range(cfg.n_layers):
        p = f"layers.{i}."
        r2 = r2s[i]
        out[p + "wq"] = r1.T @ params[p + "wq"]
        out[p + "wk"] = r1.T @ params[p + "wk"]
        wv = (r1.T @ params[p + "wv"]).reshape(cfg.d_model, h, dh)
        out[p + "wv"] = jnp.einsum("dhk,kj->dhj", wv, r2).reshape(cfg.d_model, h * dh)
        wo = params[p + "wo"].reshape(h, dh, cfg.d_model)
        wo = jnp.einsum("jk,hkd->hjd", r2.T, wo).reshape(h * dh, cfg.d_model)
        out[p + "wo"] = wo @ r1
        out[p + "wgate"] = r1.T @ params[p + "wgate"]
        out[p + "wup"] = r1.T @ params[p + "wup"]
        wdown = params[p + "wdown"]
        if had:
            # H-merge on the input axis (H symmetric => H @ w == fwht rows).
            wdown = fwht_diff(wdown.T).T
        out[p + "wdown"] = wdown @ r1
    return out


def forward(
    params: dict,
    tokens,
    cfg: Config,
    qcfg=None,
    had: bool = False,
    rot: Optional[tuple] = None,
    capture: bool = False,
):
    """Full-sequence forward -> logits (B, S, V).

    qcfg: (QCFG_LEN,) runtime-scalar vector or None (no quant ops at all).
    had:  online R3 (q/k) + R4 (down input) Hadamard rotations in-graph.
    rot:  optional (R1, R2_stack) for differentiable in-graph rotation.
    capture: also return dict of residual-read activations + kv for stats.
    """
    if rot is not None:
        params = _rotate_weights_ingraph(params, cfg, rot[0], rot[1], had)

    B, S = tokens.shape
    h, dh = cfg.n_heads, cfg.d_head
    x = params["emb"][tokens]
    cos, sin = rope_angles(cfg, jnp.arange(S))
    mask = jnp.tril(jnp.ones((S, S), jnp.float32))
    neg = jnp.asarray(-1e9, jnp.float32)

    caps = {"resid_in": [], "oproj_in": [], "ffn_in": [], "down_in": [], "k": [], "v": []}
    head_in = None

    def aq(t):
        return _aq(t, qcfg) if qcfg is not None else t

    def kvq(t):
        return _kvq(t, qcfg) if qcfg is not None else t

    def wq(t):
        return _wq(t, qcfg) if qcfg is not None else t

    for i in range(cfg.n_layers):
        p = f"layers.{i}."
        hsrc = rmsnorm(x, params[p + "attn_norm"])
        if capture:
            caps["resid_in"].append(hsrc)
        hq = aq(hsrc)
        q = (hq @ wq(params[p + "wq"])).reshape(B, S, h, dh)
        k = (hq @ wq(params[p + "wk"])).reshape(B, S, h, dh)
        v = (hq @ wq(params[p + "wv"])).reshape(B, S, h, dh)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
        if had:
            # R3: head-wise online Hadamard on q and k; cancels in q k^T but
            # Gaussianizes the cached k for low-bit KV quantization.
            q = fwht_diff(q)
            k = fwht_diff(k)
        if capture:
            caps["k"].append(k)
            caps["v"].append(v)
        k = kvq(k)
        v = kvq(v)
        att = jnp.einsum("bqhd,bkhd->bhqk", q, k) / jnp.sqrt(jnp.asarray(dh, jnp.float32))
        att = jnp.where(mask[None, None, :, :] > 0, att, neg)
        att = jax.nn.softmax(att, axis=-1)
        o = jnp.einsum("bhqk,bkhd->bqhd", att, v).reshape(B, S, h * dh)
        if capture:
            caps["oproj_in"].append(o)
        oq = aq(o)
        x = x + oq @ wq(params[p + "wo"])

        h2 = rmsnorm(x, params[p + "ffn_norm"])
        if capture:
            caps["ffn_in"].append(h2)
        h2q = aq(h2)
        g = h2q @ wq(params[p + "wgate"])
        u = h2q @ wq(params[p + "wup"])
        m = jax.nn.silu(g) * u
        if had:
            m = fwht_diff(m)  # R4: online Hadamard before down_proj.
        if capture:
            caps["down_in"].append(m)
        mq = aq(m)
        x = x + mq @ wq(params[p + "wdown"])

    hf = rmsnorm(x, params["final_norm"])
    if capture:
        head_in = hf
    logits = aq(hf) @ wq(params["head"])

    if capture:
        stacked = {name: jnp.stack(vals) for name, vals in caps.items()}
        stacked["head_in"] = head_in
        return logits, stacked
    return logits


def next_token_loss(logits, tokens):
    """Mean cross-entropy of logits[:, :-1] predicting tokens[:, 1:]."""
    logp = jax.nn.log_softmax(logits[:, :-1, :], axis=-1)
    tgt = tokens[:, 1:]
    nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


def cayley_loss_and_grads(params, r1, r2s, tokens, cfg: Config, qcfg, had: bool):
    """Paper Eq. 2: L_Q(R1, R2 | W, X) and its gradients on the rotations.

    Weights stay full precision (Table 3: "Cayley on 16-4-KV" wins);
    activations/KV are STE-fake-quantized in-graph. Returns
    (loss, dL/dR1, dL/dR2_stack); the Stiefel retraction (Cayley transform)
    is applied by the rust coordinator (rust/src/cayley).
    """

    def loss_fn(r1_, r2s_):
        logits = forward(params, tokens, cfg, qcfg=qcfg, had=had, rot=(r1_, r2s_))
        return next_token_loss(logits, tokens)

    loss, grads = jax.value_and_grad(loss_fn, argnums=(0, 1))(r1, r2s)
    return loss, grads[0], grads[1]


def qat_loss_and_grads(params, tokens, cfg: Config, qcfg):
    """Loss + STE gradients w.r.t. *all weights* of the fully fake-quantized
    network (weights via qcfg[6:8], activations/KV via qcfg[0:6]).

    This powers the LLM-QAT baseline (rust/src/llmqat drives Adam over these
    gradients) — quantization-aware training, the strongest non-rotation
    baseline in paper Table 1.
    """

    def loss_fn(p):
        logits = forward(p, tokens, cfg, qcfg=qcfg)
        return next_token_loss(logits, tokens)

    loss, grads = jax.value_and_grad(loss_fn)(params)
    return loss, grads


# ---------------------------------------------------------------------------
# Single-token decode with a quantized KV-cache (serving path, Table 6/Fig 7)
# ---------------------------------------------------------------------------


def decode_step(params, cfg: Config, token, pos, cache_k, cache_v, qcfg=None, had=False):
    """One decode step with a shared position across the batch.

    token: (B,) int32; pos: scalar int32 (0-based position of `token`).
    cache_k/v: (L, B, max_seq, H, dh) — already quantize-dequantized values.
    Returns (logits (B, V), new_cache_k, new_cache_v).

    Thin wrapper over `decode_step_batched` (the single implementation of
    the decode math): the scalar position is broadcast to every slot. The
    scalar-`pos` input ABI of the `decode_{fp,nohad,had}` artifacts is
    unchanged.
    """
    B = token.shape[0]
    pos_vec = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,))
    return decode_step_batched(
        params, cfg, token, pos_vec, cache_k, cache_v, qcfg=qcfg, had=had
    )


def decode_step_batched(params, cfg: Config, token, pos, cache_k, cache_v,
                        qcfg=None, had=False):
    """One decode step over B independent KV-cache *slots*.

    The continuous-batching serving engine (rust/src/serve) drives this:
    every slot advances one token per call, but slots are at *independent*
    positions — a request admitted mid-flight starts at pos 0 while its
    neighbours keep decoding. Per-slot RoPE angles and per-slot causal
    masks (`idx <= pos[b]`) keep the lanes fully isolated, which is also
    what makes slot reuse safe without zeroing the cache: a fresh request
    can never attend past its own position into a previous occupant's
    stale keys/values.

    token: (B,) int32; pos: (B,) int32 (0-based position of each token).
    cache_k/v: (L, B, max_seq, H, dh) — already quantize-dequantized values.
    Returns (logits (B, V), new_cache_k, new_cache_v).
    """
    B = token.shape[0]
    h, dh = cfg.n_heads, cfg.d_head
    x = params["emb"][token]  # (B, D)
    half = dh // 2
    freqs = cfg.rope_theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = pos.astype(jnp.float32)[:, None] * freqs[None, :]  # (B, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    idx = jnp.arange(cfg.max_seq)
    attend = (idx[None, :] <= pos[:, None]).astype(jnp.float32)  # (B, max_seq)
    neg = jnp.asarray(-1e9, jnp.float32)
    lanes = jnp.arange(B)

    def rope1(t):
        """Per-slot RoPE on a single position; t: (B, h, dh)."""
        tr = t.reshape(B, h, dh // 2, 2)
        t0, t1 = tr[..., 0], tr[..., 1]
        c = cos[:, None, :]
        sn = sin[:, None, :]
        y0 = t0 * c - t1 * sn
        y1 = t0 * sn + t1 * c
        return jnp.stack([y0, y1], axis=-1).reshape(B, h, dh)

    def aq(t):
        return _aq(t, qcfg) if qcfg is not None else t

    def kvq(t):
        return _kvq(t, qcfg) if qcfg is not None else t

    def wq(t):
        return _wq(t, qcfg) if qcfg is not None else t

    for i in range(cfg.n_layers):
        p = f"layers.{i}."
        hsrc = rmsnorm(x, params[p + "attn_norm"])
        hq = aq(hsrc)
        q = (hq @ wq(params[p + "wq"])).reshape(B, h, dh)
        k = (hq @ wq(params[p + "wk"])).reshape(B, h, dh)
        v = (hq @ wq(params[p + "wv"])).reshape(B, h, dh)
        q = rope1(q)
        k = rope1(k)
        if had:
            q = fwht_diff(q)
            k = fwht_diff(k)
        k = kvq(k)
        v = kvq(v)
        cache_k = cache_k.at[i, lanes, pos].set(k)
        cache_v = cache_v.at[i, lanes, pos].set(v)
        ck = cache_k[i]  # (B, max_seq, h, dh)
        cv = cache_v[i]
        att = jnp.einsum("bhd,bkhd->bhk", q, ck) / jnp.sqrt(jnp.asarray(dh, jnp.float32))
        att = jnp.where(attend[:, None, :] > 0, att, neg)
        att = jax.nn.softmax(att, axis=-1)
        o = jnp.einsum("bhk,bkhd->bhd", att, cv).reshape(B, h * dh)
        x = x + aq(o) @ wq(params[p + "wo"])

        h2 = rmsnorm(x, params[p + "ffn_norm"])
        h2q = aq(h2)
        m = jax.nn.silu(h2q @ wq(params[p + "wgate"])) * (h2q @ wq(params[p + "wup"]))
        if had:
            m = fwht_diff(m)
        x = x + aq(m) @ wq(params[p + "wdown"])

    hf = rmsnorm(x, params["final_norm"])
    logits = aq(hf) @ wq(params["head"])
    return logits, cache_k, cache_v


# ---------------------------------------------------------------------------
# Batched multi-token prefill (serving TTFT path)
# ---------------------------------------------------------------------------


def prefill_batched(params, cfg: Config, tokens, pos0, n_valid, cache_k, cache_v,
                    qcfg=None, had=False):
    """Consume a chunk of `T` prompt tokens per slot in one call.

    The continuous-batching scheduler admits a request by prefilling its
    whole prompt in ceil(len/T) chunks through this graph before the
    request enters the per-token decode batch — time-to-first-token then
    scales with ceil(len/T) engine calls instead of len (rust/src/serve).

    Semantically this is exactly `T` sequential `decode_step_batched`
    calls: all `T` KV entries are written at once (scatter at
    `pos0[b] + t`), each chunk position attends causally to the existing
    cache *and* to earlier positions of its own chunk via the per-slot
    `idx <= pos` mask, and RoPE angles are per (slot, position).

    tokens:  (B, T) int32 — prompt chunk per slot (rows past n_valid are
             padding and are neither written to the cache nor attended).
    pos0:    (B,)   int32 — cache position of tokens[:, 0] per slot.
    n_valid: (B,)   int32 — valid tokens per slot; 0 marks an inactive
             slot (nothing written, returned logits are garbage there).
    cache_k/v: (L, B, max_seq, H, dh) — already quantize-dequantized.
    Returns (logits (B, V) at each slot's last valid position,
             new_cache_k, new_cache_v).
    """
    B, T = tokens.shape
    h, dh = cfg.n_heads, cfg.d_head
    x = params["emb"][tokens]  # (B, T, D)
    half = dh // 2
    freqs = cfg.rope_theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    pos_bt = pos0[:, None] + jnp.arange(T, dtype=jnp.int32)[None, :]  # (B, T)
    ang = pos_bt.astype(jnp.float32)[..., None] * freqs[None, None, :]  # (B, T, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    valid = jnp.arange(T, dtype=jnp.int32)[None, :] < n_valid[:, None]  # (B, T)
    # Scatter target per chunk row; invalid rows are pushed out of range and
    # dropped, so padding can never corrupt a future occupant's cache.
    write_pos = jnp.where(valid, pos_bt, cfg.max_seq)
    idx = jnp.arange(cfg.max_seq)
    attend = (idx[None, None, :] <= pos_bt[:, :, None]).astype(jnp.float32)  # (B, T, max_seq)
    neg = jnp.asarray(-1e9, jnp.float32)
    lanes = jnp.arange(B)

    def ropeT(t):
        """Per-(slot, position) RoPE; t: (B, T, h, dh)."""
        tr = t.reshape(B, T, h, dh // 2, 2)
        t0, t1 = tr[..., 0], tr[..., 1]
        c = cos[:, :, None, :]
        sn = sin[:, :, None, :]
        y0 = t0 * c - t1 * sn
        y1 = t0 * sn + t1 * c
        return jnp.stack([y0, y1], axis=-1).reshape(B, T, h, dh)

    def aq(t):
        return _aq(t, qcfg) if qcfg is not None else t

    def kvq(t):
        return _kvq(t, qcfg) if qcfg is not None else t

    def wq(t):
        return _wq(t, qcfg) if qcfg is not None else t

    for i in range(cfg.n_layers):
        p = f"layers.{i}."
        hsrc = rmsnorm(x, params[p + "attn_norm"])
        hq = aq(hsrc)
        q = (hq @ wq(params[p + "wq"])).reshape(B, T, h, dh)
        k = (hq @ wq(params[p + "wk"])).reshape(B, T, h, dh)
        v = (hq @ wq(params[p + "wv"])).reshape(B, T, h, dh)
        q = ropeT(q)
        k = ropeT(k)
        if had:
            q = fwht_diff(q)
            k = fwht_diff(k)
        k = kvq(k)
        v = kvq(v)
        cache_k = cache_k.at[i, lanes[:, None], write_pos].set(k, mode="drop")
        cache_v = cache_v.at[i, lanes[:, None], write_pos].set(v, mode="drop")
        ck = cache_k[i]  # (B, max_seq, h, dh)
        cv = cache_v[i]
        att = jnp.einsum("bqhd,bkhd->bhqk", q, ck) / jnp.sqrt(jnp.asarray(dh, jnp.float32))
        att = jnp.where(attend[:, None, :, :] > 0, att, neg)
        att = jax.nn.softmax(att, axis=-1)
        o = jnp.einsum("bhqk,bkhd->bqhd", att, cv).reshape(B, T, h * dh)
        x = x + aq(o) @ wq(params[p + "wo"])

        h2 = rmsnorm(x, params[p + "ffn_norm"])
        h2q = aq(h2)
        m = jax.nn.silu(h2q @ wq(params[p + "wgate"])) * (h2q @ wq(params[p + "wup"]))
        if had:
            m = fwht_diff(m)
        x = x + aq(m) @ wq(params[p + "wdown"])

    hf = rmsnorm(x, params["final_norm"])
    logits_all = aq(hf) @ wq(params["head"])  # (B, T, V)
    last = jnp.clip(n_valid - 1, 0, T - 1)
    logits = jnp.take_along_axis(logits_all, last[:, None, None], axis=1)[:, 0, :]
    return logits, cache_k, cache_v


# ---------------------------------------------------------------------------
# Paged KV cache (block-pool serving path)
# ---------------------------------------------------------------------------
#
# The dense decode/prefill graphs above address the cache as
# (L, B, max_seq, H, dh): every slot owns a full max_seq region, so resident
# KV memory scales with slots x max_seq no matter how short the requests
# are. The paged twins below address a *block pool* instead:
#
#   cache_k/v: (L, n_blocks, block_size, H, dh)   physical pages
#   block_table: (B, max_seq // block_size) int32  logical -> physical
#
# Each slot's logical cache is the concatenation of its table's physical
# blocks; position p lives at (block_table[b, p // bs], p % bs). The rust
# scheduler (rust/src/serve/blocks.rs) allocates pages lazily and admits by
# free-page token budget, so memory scales with tokens in flight. Table
# entries >= n_blocks mark unallocated/inactive pages: scatter writes there
# are dropped (mode="drop") and gathers are clipped — garbage read through a
# clipped entry is unreachable anyway because attention is masked to
# `idx <= pos`, which never passes the allocated prefix.
#
# With the identity table (block_table[b, j] = b * (max_seq // bs) + j and
# n_blocks = B * max_seq // bs) the gathered logical view *is* the dense
# cache, element for element, so logits and (reshaped) caches are bit-equal
# to the dense graphs — tested in test_model.py.
#
# Quantized KV storage (`serve --kv-bits {4,8,16}`): K/V pass through
# `_kvq` *before* the scatter, so physical pages hold quantize->dequantize
# round-tripped values at qcfg[1] bits — the page is the storage grid, not a
# staging buffer for full-precision rows. `qcfg` is a runtime input, so the
# same lowered artifact serves every KV width; kv_bits >= 16 is an exact
# pass-through (pages bit-equal to the fp path), and 4/8-bit pages drift
# from fp by a bounded, grid-sized amount (tested in test_model.py). The
# rust MockEngine (rust/src/serve/engine.rs) mirrors exactly this model
# when it packs its own pages.


def _paged_gather(cache_layer, block_table, n_blocks):
    """Logical per-slot view of one layer's physical pages.

    cache_layer: (n_blocks, bs, H, dh); block_table: (B, n_logical) ->
    (B, n_logical * bs, H, dh). Out-of-range entries are clipped (the mask
    keeps whatever they alias unreachable)."""
    safe = jnp.clip(block_table, 0, n_blocks - 1)
    g = cache_layer[safe]  # (B, n_logical, bs, H, dh)
    b, nl, bs = g.shape[0], g.shape[1], g.shape[2]
    return g.reshape(b, nl * bs, *g.shape[3:])


def decode_paged(params, cfg: Config, token, pos, block_table, cache_k, cache_v,
                 qcfg=None, had=False):
    """One decode step over B slots with a paged (block-pool) KV cache.

    Semantically identical to `decode_step_batched` — same per-slot RoPE,
    same `idx <= pos` mask, same quant insertion points — but K/V are
    scattered to / gathered from physical pages through `block_table`.

    token: (B,) int32; pos: (B,) int32.
    block_table: (B, max_seq // block_size) int32; entries >= n_blocks mark
        unallocated pages (writes dropped, reads clipped).
    cache_k/v: (L, n_blocks, block_size, H, dh).
    Returns (logits (B, V), new_cache_k, new_cache_v).
    """
    B = token.shape[0]
    n_blocks, block_size = cache_k.shape[1], cache_k.shape[2]
    n_logical = block_table.shape[1]
    h, dh = cfg.n_heads, cfg.d_head
    x = params["emb"][token]  # (B, D)
    half = dh // 2
    freqs = cfg.rope_theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = pos.astype(jnp.float32)[:, None] * freqs[None, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    idx = jnp.arange(n_logical * block_size)
    attend = (idx[None, :] <= pos[:, None]).astype(jnp.float32)  # (B, max_seq)
    neg = jnp.asarray(-1e9, jnp.float32)
    # Physical write target of position `pos` per slot.
    blk = jnp.take_along_axis(
        block_table, jnp.clip(pos // block_size, 0, n_logical - 1)[:, None], axis=1
    )[:, 0]
    off = pos % block_size

    def rope1(t):
        tr = t.reshape(B, h, dh // 2, 2)
        t0, t1 = tr[..., 0], tr[..., 1]
        c = cos[:, None, :]
        sn = sin[:, None, :]
        y0 = t0 * c - t1 * sn
        y1 = t0 * sn + t1 * c
        return jnp.stack([y0, y1], axis=-1).reshape(B, h, dh)

    def aq(t):
        return _aq(t, qcfg) if qcfg is not None else t

    def kvq(t):
        return _kvq(t, qcfg) if qcfg is not None else t

    def wq(t):
        return _wq(t, qcfg) if qcfg is not None else t

    for i in range(cfg.n_layers):
        p = f"layers.{i}."
        hsrc = rmsnorm(x, params[p + "attn_norm"])
        hq = aq(hsrc)
        q = (hq @ wq(params[p + "wq"])).reshape(B, h, dh)
        k = (hq @ wq(params[p + "wk"])).reshape(B, h, dh)
        v = (hq @ wq(params[p + "wv"])).reshape(B, h, dh)
        q = rope1(q)
        k = rope1(k)
        if had:
            q = fwht_diff(q)
            k = fwht_diff(k)
        k = kvq(k)
        v = kvq(v)
        cache_k = cache_k.at[i, blk, off].set(k, mode="drop")
        cache_v = cache_v.at[i, blk, off].set(v, mode="drop")
        ck = _paged_gather(cache_k[i], block_table, n_blocks)  # (B, max_seq, h, dh)
        cv = _paged_gather(cache_v[i], block_table, n_blocks)
        att = jnp.einsum("bhd,bkhd->bhk", q, ck) / jnp.sqrt(jnp.asarray(dh, jnp.float32))
        att = jnp.where(attend[:, None, :] > 0, att, neg)
        att = jax.nn.softmax(att, axis=-1)
        o = jnp.einsum("bhk,bkhd->bhd", att, cv).reshape(B, h * dh)
        x = x + aq(o) @ wq(params[p + "wo"])

        h2 = rmsnorm(x, params[p + "ffn_norm"])
        h2q = aq(h2)
        m = jax.nn.silu(h2q @ wq(params[p + "wgate"])) * (h2q @ wq(params[p + "wup"]))
        if had:
            m = fwht_diff(m)
        x = x + aq(m) @ wq(params[p + "wdown"])

    hf = rmsnorm(x, params["final_norm"])
    logits = aq(hf) @ wq(params["head"])
    return logits, cache_k, cache_v


def prefill_paged(params, cfg: Config, tokens, pos0, n_valid, block_table,
                  cache_k, cache_v, qcfg=None, had=False):
    """Batched multi-token prefill over a paged (block-pool) KV cache.

    Semantically identical to `prefill_batched` (same intra-chunk causal
    mask, padding rows never written) with K/V scattered to physical pages
    through `block_table`; a chunk may span several pages.

    tokens: (B, T) int32; pos0/n_valid: (B,) int32.
    block_table: (B, max_seq // block_size) int32 (>= n_blocks = hole).
    cache_k/v: (L, n_blocks, block_size, H, dh).
    Returns (logits (B, V) at each slot's last valid position,
             new_cache_k, new_cache_v).
    """
    B, T = tokens.shape
    n_blocks, block_size = cache_k.shape[1], cache_k.shape[2]
    n_logical = block_table.shape[1]
    h, dh = cfg.n_heads, cfg.d_head
    x = params["emb"][tokens]  # (B, T, D)
    half = dh // 2
    freqs = cfg.rope_theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    pos_bt = pos0[:, None] + jnp.arange(T, dtype=jnp.int32)[None, :]  # (B, T)
    ang = pos_bt.astype(jnp.float32)[..., None] * freqs[None, None, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    valid = jnp.arange(T, dtype=jnp.int32)[None, :] < n_valid[:, None]  # (B, T)
    # Physical write target per chunk row; invalid rows are forced out of
    # range and dropped, exactly like the dense prefill's write_pos.
    blk = jnp.take_along_axis(
        block_table, jnp.clip(pos_bt // block_size, 0, n_logical - 1), axis=1
    )  # (B, T)
    blk = jnp.where(valid, blk, n_blocks)
    off = pos_bt % block_size
    idx = jnp.arange(n_logical * block_size)
    attend = (idx[None, None, :] <= pos_bt[:, :, None]).astype(jnp.float32)
    neg = jnp.asarray(-1e9, jnp.float32)

    def ropeT(t):
        tr = t.reshape(B, T, h, dh // 2, 2)
        t0, t1 = tr[..., 0], tr[..., 1]
        c = cos[:, :, None, :]
        sn = sin[:, :, None, :]
        y0 = t0 * c - t1 * sn
        y1 = t0 * sn + t1 * c
        return jnp.stack([y0, y1], axis=-1).reshape(B, T, h, dh)

    def aq(t):
        return _aq(t, qcfg) if qcfg is not None else t

    def kvq(t):
        return _kvq(t, qcfg) if qcfg is not None else t

    def wq(t):
        return _wq(t, qcfg) if qcfg is not None else t

    for i in range(cfg.n_layers):
        p = f"layers.{i}."
        hsrc = rmsnorm(x, params[p + "attn_norm"])
        hq = aq(hsrc)
        q = (hq @ wq(params[p + "wq"])).reshape(B, T, h, dh)
        k = (hq @ wq(params[p + "wk"])).reshape(B, T, h, dh)
        v = (hq @ wq(params[p + "wv"])).reshape(B, T, h, dh)
        q = ropeT(q)
        k = ropeT(k)
        if had:
            q = fwht_diff(q)
            k = fwht_diff(k)
        k = kvq(k)
        v = kvq(v)
        cache_k = cache_k.at[i, blk, off].set(k, mode="drop")
        cache_v = cache_v.at[i, blk, off].set(v, mode="drop")
        ck = _paged_gather(cache_k[i], block_table, n_blocks)  # (B, max_seq, h, dh)
        cv = _paged_gather(cache_v[i], block_table, n_blocks)
        att = jnp.einsum("bqhd,bkhd->bhqk", q, ck) / jnp.sqrt(jnp.asarray(dh, jnp.float32))
        att = jnp.where(attend[:, None, :, :] > 0, att, neg)
        att = jax.nn.softmax(att, axis=-1)
        o = jnp.einsum("bhqk,bkhd->bqhd", att, cv).reshape(B, T, h * dh)
        x = x + aq(o) @ wq(params[p + "wo"])

        h2 = rmsnorm(x, params[p + "ffn_norm"])
        h2q = aq(h2)
        m = jax.nn.silu(h2q @ wq(params[p + "wgate"])) * (h2q @ wq(params[p + "wup"]))
        if had:
            m = fwht_diff(m)
        x = x + aq(m) @ wq(params[p + "wdown"])

    hf = rmsnorm(x, params["final_norm"])
    logits_all = aq(hf) @ wq(params["head"])  # (B, T, V)
    last = jnp.clip(n_valid - 1, 0, T - 1)
    logits = jnp.take_along_axis(logits_all, last[:, None, None], axis=1)[:, 0, :]
    return logits, cache_k, cache_v


# ---------------------------------------------------------------------------
# Initialization (with planted outlier basis — DESIGN.md §3)
# ---------------------------------------------------------------------------


def init_params(key, cfg: Config, outlier_channels: int = 8, outlier_scale: float = 8.0):
    """Initialize with a heavy-tailed per-channel residual basis.

    Short CPU pretraining cannot develop LLaMA's emergent outlier channels,
    so we *train in an outlier basis from step 0*: every write into the
    residual stream (emb, wo, wdown output columns) is scaled per-channel,
    with `outlier_channels` channels boosted by ~`outlier_scale`. Training
    proceeds normally in this basis, so the final function is genuine while
    activation kurtosis matches the phenomenon rotation must fix (Fig. 2/3).
    A few d_ffn and kv channels are boosted too (targets for R4 / R2-R3).
    """
    keys = jax.random.split(key, 4 + cfg.n_layers * 9)
    d, f, v = cfg.d_model, cfg.d_ffn, cfg.vocab
    h, dh = cfg.n_heads, cfg.d_head

    def scale_vec(k, n, n_out, boost):
        s = jnp.ones((n,))
        idx = jax.random.choice(k, n, (n_out,), replace=False)
        mag = boost * (0.75 + 0.5 * jax.random.uniform(k, (n_out,)))
        return s.at[idx].set(mag)

    d_scale = scale_vec(keys[0], d, outlier_channels, outlier_scale)
    f_scale = scale_vec(keys[1], f, max(2, outlier_channels // 2), outlier_scale * 0.5)
    kv_scale = scale_vec(keys[2], h * dh, max(2, outlier_channels // 2), outlier_scale * 0.4)

    def norm(k, shape, gain=1.0):
        fan_in = shape[0]
        return gain * jax.random.normal(k, shape) / jnp.sqrt(jnp.asarray(fan_in, jnp.float32))

    params = {}
    ki = 3
    params["emb"] = jax.random.normal(keys[ki], (v, d)) * 0.02 * d_scale[None, :]
    ki += 1
    for i in range(cfg.n_layers):
        p = f"layers.{i}."
        params[p + "attn_norm"] = jnp.ones((d,))
        params[p + "wq"] = norm(keys[ki], (d, h * dh)); ki += 1
        params[p + "wk"] = norm(keys[ki], (d, h * dh)) * kv_scale[None, :]; ki += 1
        params[p + "wv"] = norm(keys[ki], (d, h * dh)) * kv_scale[None, :]; ki += 1
        params[p + "wo"] = norm(keys[ki], (h * dh, d), 0.5) * d_scale[None, :]; ki += 1
        params[p + "ffn_norm"] = jnp.ones((d,))
        params[p + "wgate"] = norm(keys[ki], (d, f)) * f_scale[None, :]; ki += 1
        params[p + "wup"] = norm(keys[ki], (d, f)) * f_scale[None, :]; ki += 1
        params[p + "wdown"] = norm(keys[ki], (f, d), 0.5) * d_scale[None, :]; ki += 1
    params["final_norm"] = jnp.ones((d,))
    params["head"] = norm(keys[ki], (d, v))
    return params


def fold_norm_scales(params, cfg: Config):
    """Fold RMSNorm gammas into the following linears (paper footnote 3).

    After folding the network is rotation-invariant; gammas become ones.
    Mirrors rust/src/rotation/fold.rs.
    """
    out = dict(params)
    for i in range(cfg.n_layers):
        p = f"layers.{i}."
        g_att = params[p + "attn_norm"][:, None]
        out[p + "wq"] = params[p + "wq"] * g_att
        out[p + "wk"] = params[p + "wk"] * g_att
        out[p + "wv"] = params[p + "wv"] * g_att
        out[p + "attn_norm"] = jnp.ones_like(params[p + "attn_norm"])
        g_ffn = params[p + "ffn_norm"][:, None]
        out[p + "wgate"] = params[p + "wgate"] * g_ffn
        out[p + "wup"] = params[p + "wup"] * g_ffn
        out[p + "ffn_norm"] = jnp.ones_like(params[p + "ffn_norm"])
    out["head"] = params["head"] * params["final_norm"][:, None]
    out["final_norm"] = jnp.ones_like(params["final_norm"])
    return out


def merge_rotations(params, cfg: Config, r1, r2s, merge_r4: bool = False):
    """Offline (numpy-side) R1/R2 merge — the non-differentiable twin of
    `_rotate_weights_ingraph`, used by python tests; rust/src/rotation is the
    production implementation. Requires folded norms."""
    return jax.tree_util.tree_map(
        lambda a: a, _rotate_weights_ingraph(params, cfg, r1, r2s, merge_r4)
    )
