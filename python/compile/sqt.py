"""SQT — the trivial tensor container shared between python (writer) and
rust (rust/src/model/sqt.rs, reader+writer).

Layout (little-endian):
    magic   b"SQT1"
    u32     n_tensors
    per tensor:
        u16   name_len, name bytes (utf-8)
        u8    ndim
        u32 x ndim   dims
        f32 x prod(dims)   data (C order)
"""

from __future__ import annotations

import struct

import numpy as np


def write_sqt(path: str, tensors: dict):
    """tensors: name -> np.ndarray (converted to f32, C order)."""
    with open(path, "wb") as f:
        f.write(b"SQT1")
        f.write(struct.pack("<I", len(tensors)))
        for name, arr in tensors.items():
            arr = np.ascontiguousarray(np.asarray(arr), dtype=np.float32)
            nb = name.encode("utf-8")
            f.write(struct.pack("<H", len(nb)))
            f.write(nb)
            f.write(struct.pack("<B", arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<I", d))
            f.write(arr.tobytes())


def read_sqt(path: str) -> dict:
    out = {}
    with open(path, "rb") as f:
        assert f.read(4) == b"SQT1", f"{path}: bad magic"
        (n,) = struct.unpack("<I", f.read(4))
        for _ in range(n):
            (ln,) = struct.unpack("<H", f.read(2))
            name = f.read(ln).decode("utf-8")
            (nd,) = struct.unpack("<B", f.read(1))
            dims = struct.unpack(f"<{nd}I", f.read(4 * nd))
            count = int(np.prod(dims)) if nd else 1
            data = np.frombuffer(f.read(4 * count), dtype="<f4").reshape(dims)
            out[name] = data.copy()
    return out
