"""L1 kernel correctness: Pallas (interpret) vs pure-jnp oracles.

Hypothesis sweeps shapes/bit-widths/flags — the CORE correctness signal for
everything the artifacts quantize at runtime.
"""

import jax.numpy as jnp
import numpy as np
import pytest

# Hypothesis drives the shape/bit sweeps in CI; environments without it
# (e.g. the offline build container) still collect and run the rest of the
# suite instead of failing at import.
hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from compile.kernels import (
    fake_quant,
    fake_quant_ste,
    fwht,
    qmatmul,
    quantize_cols_sym,
    quantize_rows,
    ref,
)

import jax

SET = dict(deadline=None, max_examples=12)


def rand(shape, seed=0, scale=3.0):
    return jnp.asarray(np.random.RandomState(seed).randn(*shape).astype(np.float32) * scale)


# ---------------------------------------------------------------------------
# fake_quant
# ---------------------------------------------------------------------------


@settings(**SET)
@given(
    rows=st.integers(1, 200),
    cols=st.integers(2, 96),
    bits=st.sampled_from([2.0, 3.0, 4.0, 8.0, 16.0]),
    sym=st.sampled_from([0.0, 1.0]),
    clip=st.sampled_from([1.0, 0.9]),
    seed=st.integers(0, 10_000),
)
def test_fake_quant_matches_ref(rows, cols, bits, sym, clip, seed):
    x = rand((rows, cols), seed)
    got = fake_quant(x, bits, sym, clip)
    want = ref.fake_quant_ref(x, bits, axis=-1, symmetric=sym, clip_ratio=clip)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_fake_quant_16_bits_is_identity():
    x = rand((64, 32), 1)
    np.testing.assert_array_equal(fake_quant(x, 16.0), x)


def test_fake_quant_reduces_levels():
    x = rand((16, 64), 2)
    y = np.asarray(fake_quant(x, 3.0))
    # At 3 bits each row can hold at most 2^3 distinct values.
    for row in y:
        assert len(np.unique(row)) <= 8


def test_fake_quant_error_shrinks_with_bits():
    x = rand((32, 64), 3)
    errs = [float(jnp.mean((fake_quant(x, b) - x) ** 2)) for b in (2.0, 4.0, 8.0)]
    assert errs[0] > errs[1] > errs[2]


def test_fake_quant_rank3():
    x = rand((4, 8, 32), 4)
    got = fake_quant(x, 4.0)
    want = ref.fake_quant_ref(x, 4.0, axis=-1)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_symmetric_zero_maps_to_zero():
    x = rand((8, 32), 5)
    x = x.at[:, 0].set(0.0)
    y = np.asarray(fake_quant(x, 4.0, symmetric=1.0))
    np.testing.assert_allclose(y[:, 0], 0.0, atol=1e-6)


def test_ste_gradient_passthrough():
    x = rand((8, 32), 6)

    def f(x_):
        return jnp.sum(fake_quant_ste(x_, 4.0, 0.0, 1.0) ** 2)

    g = jax.grad(f)(x)
    # STE: d/dx sum(q(x)^2) = 2*q(x) under the identity jacobian.
    np.testing.assert_allclose(g, 2 * fake_quant(x, 4.0), rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# fwht
# ---------------------------------------------------------------------------


@settings(**SET)
@given(
    rows=st.integers(1, 150),
    logn=st.integers(1, 8),
    seed=st.integers(0, 10_000),
)
def test_fwht_matches_dense_hadamard(rows, logn, seed):
    n = 2**logn
    x = rand((rows, n), seed)
    got = fwht(x)
    want = x @ ref.hadamard_matrix_ref(n)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(**SET)
@given(logn=st.integers(1, 8), seed=st.integers(0, 100))
def test_fwht_is_involution_and_isometry(logn, seed):
    x = rand((9, 2**logn), seed)
    y = fwht(x)
    np.testing.assert_allclose(fwht(y), x, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(
        jnp.sum(y * y, axis=-1), jnp.sum(x * x, axis=-1), rtol=1e-4
    )


def test_fwht_rank4():
    x = rand((2, 3, 4, 32), 7)
    np.testing.assert_allclose(fwht(x), ref.fwht_ref(x), rtol=1e-4, atol=1e-5)


def test_fwht_gaussianizes_outliers():
    """The paper's core motivation (Fig. 3a): rotation drives kurtosis to ~3."""
    rng = np.random.RandomState(0)
    x = rng.randn(512, 128).astype(np.float32)
    x[:, 5] *= 30.0  # planted outlier channel
    x[:, 77] *= 18.0
    k_before = float(ref.kurtosis_ref(jnp.asarray(x)))
    k_after = float(ref.kurtosis_ref(fwht(jnp.asarray(x))))
    assert k_before > 20.0
    assert k_after < 5.0


def test_fwht_reduces_quant_error_on_outliers():
    rng = np.random.RandomState(1)
    x = rng.randn(256, 128).astype(np.float32)
    x[:, 3] *= 25.0
    x = jnp.asarray(x)
    err_plain = float(jnp.mean((fake_quant(x, 4.0) - x) ** 2))
    xr = fwht(x)
    err_rot = float(jnp.mean((fake_quant(xr, 4.0) - xr) ** 2))
    assert err_rot < err_plain * 0.5


# ---------------------------------------------------------------------------
# qmatmul
# ---------------------------------------------------------------------------


@settings(deadline=None, max_examples=8)
@given(
    m=st.integers(1, 140),
    k=st.sampled_from([16, 64, 130, 200]),
    n=st.integers(1, 140),
    bits=st.sampled_from([4.0, 8.0]),
    seed=st.integers(0, 1000),
)
def test_qmatmul_matches_ref(m, k, n, bits, seed):
    x = rand((m, k), seed)
    w = rand((k, n), seed + 1, scale=0.5)
    q, s, z = quantize_rows(x, bits)
    qw, sw = quantize_cols_sym(w, bits)
    got = qmatmul(q, s, z, qw, sw)
    want = ref.qmatmul_ref(x, w, bits, bits)
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


def test_qmatmul_8bit_close_to_exact():
    x = rand((64, 128), 11)
    w = rand((128, 64), 12, scale=0.3)
    q, s, z = quantize_rows(x, 8.0)
    qw, sw = quantize_cols_sym(w, 8.0)
    got = np.asarray(qmatmul(q, s, z, qw, sw))
    exact = np.asarray(x @ w)
    rel = np.abs(got - exact).mean() / (np.abs(exact).mean() + 1e-9)
    assert rel < 0.02
