"""Build-path plumbing tests: SQT container, synthetic corpora, AOT helpers."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, data, model as model_mod
from compile.sqt import read_sqt, write_sqt


def test_sqt_roundtrip():
    tensors = {
        "a": np.arange(6, dtype=np.float32).reshape(2, 3),
        "b.nested/name": np.float32(-2.5).reshape(()),
        "c": np.zeros((4,), dtype=np.float32),
    }
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "t.sqt")
        write_sqt(path, tensors)
        back = read_sqt(path)
    assert set(back) == set(tensors)
    for k in tensors:
        np.testing.assert_array_equal(np.asarray(tensors[k], np.float32), back[k])


def test_sqt_rejects_bad_magic():
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "bad.sqt")
        with open(path, "wb") as f:
            f.write(b"NOPE1234")
        with pytest.raises(AssertionError):
            read_sqt(path)


def test_corpora_deterministic_and_distinct():
    t1, e1 = data.build_corpus("wiki-syn", train_bytes=4096, test_bytes=1024)
    t2, e2 = data.build_corpus("wiki-syn", train_bytes=4096, test_bytes=1024)
    assert t1 == t2 and e1 == e2
    c1, _ = data.build_corpus("c4-syn", train_bytes=4096, test_bytes=1024)
    assert c1 != t1
    assert len(t1) == 4096 and len(e1) == 1024


def test_corpus_is_ascii_text():
    t, _ = data.build_corpus("wiki-syn", train_bytes=2048, test_bytes=256)
    assert all(32 <= b < 127 or b == 10 for b in t)
    # word structure: spaces and periods present
    assert b" " in t and b"." in t


def test_corpus_learnable_statistics():
    """The Markov structure must make bigrams non-uniform (learnable)."""
    t, _ = data.build_corpus("wiki-syn", train_bytes=65536, test_bytes=256)
    arr = np.frombuffer(t, dtype=np.uint8)
    # unigram entropy must be far below log2(96) for ASCII text
    counts = np.bincount(arr, minlength=256).astype(np.float64)
    p = counts / counts.sum()
    ent = -(p[p > 0] * np.log2(p[p > 0])).sum()
    assert ent < 5.0, f"unigram entropy {ent}"


def test_aot_builds_all_artifact_specs():
    cfg = model_mod.CONFIGS["sq-2m"]
    arts = aot.build_artifacts(cfg)
    expected = {
        "fwd_eval_nohad", "fwd_eval_had", "fwd_task_nohad", "fwd_task_had",
        "fwd_stats", "cayley_nohad", "cayley_had", "qat_grads",
        "decode_fp", "decode_nohad", "decode_had",
    }
    # Continuous-batching decode artifacts (rust/src/serve), per batch size.
    for b in aot.DECODE_BATCHES:
        expected |= {f"decode_fp_b{b}", f"decode_nohad_b{b}", f"decode_had_b{b}"}
        # Batched multi-token prefill artifacts, per chunk size.
        for t in aot.PREFILL_TS:
            expected |= {
                f"prefill_fp_b{b}_t{t}",
                f"prefill_nohad_b{b}_t{t}",
                f"prefill_had_b{b}_t{t}",
            }
        # Paged (block-pool) twins.
        expected |= {
            f"decode_fp_paged_b{b}", f"decode_nohad_paged_b{b}",
            f"decode_had_paged_b{b}",
        }
        for t in aot.PREFILL_PAGED_TS:
            expected |= {
                f"prefill_fp_paged_b{b}_t{t}",
                f"prefill_nohad_paged_b{b}_t{t}",
                f"prefill_had_paged_b{b}_t{t}",
            }
    assert set(arts) == expected
    # Input ABI: params first (in order), extras after.
    names = model_mod.param_order(cfg)
    for aname, (_, specs, innames, outnames) in arts.items():
        assert innames[: len(names)] == names, aname
        assert len(specs) == len(innames), aname
        assert outnames, aname
    # Batched decode ABI: token and pos are per-slot vectors, caches carry
    # the slot dimension.
    for b in aot.DECODE_BATCHES:
        _, specs, innames, outnames = arts[f"decode_nohad_b{b}"]
        byname = dict(zip(innames, specs))
        assert byname["token"].shape == (b,)
        assert byname["pos"].shape == (b,)
        assert byname["cache_k"].shape == (
            cfg.n_layers, b, cfg.max_seq, cfg.n_heads, cfg.d_head
        )
        assert outnames == ["logits", "cache_k", "cache_v"]
    # Prefill ABI: a (B, T) token block plus per-slot pos/n_valid vectors;
    # same cache shape and outputs as decode so the rust engine can hand
    # the cache literals back and forth between the two bindings.
    for b in aot.DECODE_BATCHES:
        for t in aot.PREFILL_TS:
            _, specs, innames, outnames = arts[f"prefill_had_b{b}_t{t}"]
            byname = dict(zip(innames, specs))
            assert byname["tokens"].shape == (b, t)
            assert byname["pos"].shape == (b,)
            assert byname["n_valid"].shape == (b,)
            assert byname["cache_k"].shape == (
                cfg.n_layers, b, cfg.max_seq, cfg.n_heads, cfg.d_head
            )
            assert innames[-1] == "qcfg"
            assert outnames == ["logits", "cache_k", "cache_v"]
            _, _, innames_fp, _ = arts[f"prefill_fp_b{b}_t{t}"]
            assert "qcfg" not in innames_fp
    # Paged ABI: block-pool cache (L, n_blocks, bs, H, dh) with a per-slot
    # block table; n_blocks = b * max_seq / bs so the identity table is
    # exactly memory-equivalent to the dense cache.
    n_logical = cfg.max_seq // aot.KV_BLOCK_SIZE
    for b in aot.DECODE_BATCHES:
        _, specs, innames, outnames = arts[f"decode_nohad_paged_b{b}"]
        byname = dict(zip(innames, specs))
        assert byname["token"].shape == (b,)
        assert byname["pos"].shape == (b,)
        assert byname["block_table"].shape == (b, n_logical)
        assert byname["cache_k"].shape == (
            cfg.n_layers, b * n_logical, aot.KV_BLOCK_SIZE, cfg.n_heads, cfg.d_head
        )
        assert outnames == ["logits", "cache_k", "cache_v"]
        for t in aot.PREFILL_PAGED_TS:
            _, specs, innames, _ = arts[f"prefill_had_paged_b{b}_t{t}"]
            byname = dict(zip(innames, specs))
            assert byname["tokens"].shape == (b, t)
            assert byname["n_valid"].shape == (b,)
            assert byname["block_table"].shape == (b, n_logical)
            assert byname["cache_k"].shape == (
                cfg.n_layers, b * n_logical, aot.KV_BLOCK_SIZE, cfg.n_heads,
                cfg.d_head
            )


def test_aot_lowering_produces_hlo_text():
    """Lower the smallest artifact end-to-end and sanity-check the text."""
    cfg = model_mod.Config("tiny", vocab=17, d_model=8, n_layers=1, n_heads=1,
                           d_head=8, d_ffn=16, max_seq=8)
    names = model_mod.param_order(cfg)
    shapes = model_mod.param_shapes(cfg)

    def fn(*args):
        params = dict(zip(names, args[:-1]))
        return (model_mod.forward(params, args[-1], cfg),)

    specs = [jax.ShapeDtypeStruct(shapes[n], jnp.float32) for n in names]
    specs.append(jax.ShapeDtypeStruct((1, 4), jnp.int32))
    text = aot.to_hlo_text(jax.jit(fn).lower(*specs))
    assert "HloModule" in text
    assert "f32[17,8]" in text  # the embedding parameter shape


def test_qat_grads_cover_all_params():
    cfg = model_mod.Config("tiny", vocab=13, d_model=8, n_layers=1, n_heads=1,
                           d_head=8, d_ffn=16, max_seq=8)
    params = model_mod.init_params(jax.random.PRNGKey(0), cfg, outlier_channels=2)
    toks = jnp.zeros((1, 8), jnp.int32)
    qcfg = model_mod.qcfg_vector(a_bits=4, kv_bits=4, w_bits=4)
    loss, grads = model_mod.qat_loss_and_grads(params, toks, cfg, qcfg)
    assert np.isfinite(float(loss))
    assert set(grads) == set(params)
    total = sum(float(jnp.sum(jnp.abs(g))) for g in grads.values())
    assert total > 0.0
