"""L2 model invariants — the algebra the whole paper rests on.

* rotational invariance: R1/R2 (and online R3/R4 with the H-merged w_down)
  leave the full-precision logits numerically unchanged (paper §3.1);
* RMSNorm gamma folding preserves the function (paper footnote 3);
* quantization breaks invariance (that is the point) and bits=16 is exact
  pass-through, so one artifact serves FP rows too;
* Cayley gradients vanish without quantization and are non-zero with it
  (paper Eq. 5 / §B.1).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as model_mod
from compile.kernels import ref

CFG = model_mod.Config("test", vocab=61, d_model=32, n_layers=2, n_heads=2,
                       d_head=16, d_ffn=64, max_seq=32)


def make_params(seed=0):
    return model_mod.init_params(jax.random.PRNGKey(seed), CFG,
                                 outlier_channels=4, outlier_scale=6.0)


def tokens(seed=0, b=2, s=16):
    return jnp.asarray(
        np.random.RandomState(seed).randint(0, CFG.vocab, (b, s)), jnp.int32
    )


def random_orthogonal(n, seed):
    a = np.random.RandomState(seed).randn(n, n)
    q, r = np.linalg.qr(a)
    q = q * np.sign(np.diag(r))[None, :]
    return jnp.asarray(q.astype(np.float32))


def rotations(seed=0):
    r1 = random_orthogonal(CFG.d_model, seed)
    r2s = jnp.stack(
        [random_orthogonal(CFG.d_head, seed + 1 + i) for i in range(CFG.n_layers)]
    )
    return r1, r2s


# ---------------------------------------------------------------------------


def test_gamma_folding_preserves_logits():
    params = make_params()
    # make gammas non-trivial
    params = {
        k: (v * 1.7 + 0.1 if k.endswith("norm") else v) for k, v in params.items()
    }
    folded = model_mod.fold_norm_scales(params, CFG)
    t = tokens()
    a = model_mod.forward(params, t, CFG)
    b = model_mod.forward(folded, t, CFG)
    np.testing.assert_allclose(a, b, rtol=2e-4, atol=2e-4)
    for k, v in folded.items():
        if k.endswith("norm"):
            np.testing.assert_array_equal(v, jnp.ones_like(v))


def test_r1_r2_rotation_invariance_fp():
    params = model_mod.fold_norm_scales(make_params(), CFG)
    r1, r2s = rotations()
    t = tokens()
    base = model_mod.forward(params, t, CFG)
    rot = model_mod.forward(params, t, CFG, rot=(r1, r2s))
    np.testing.assert_allclose(base, rot, rtol=2e-3, atol=2e-3)


def test_online_hadamard_invariance_fp():
    """R3/R4 (had=True with in-graph H-merge of w_down) keep FP logits."""
    params = model_mod.fold_norm_scales(make_params(), CFG)
    r1, r2s = rotations()
    t = tokens()
    base = model_mod.forward(params, t, CFG)
    rot = model_mod.forward(params, t, CFG, rot=(r1, r2s), had=True)
    np.testing.assert_allclose(base, rot, rtol=2e-3, atol=2e-3)


def test_identity_rotation_is_noop():
    params = model_mod.fold_norm_scales(make_params(), CFG)
    r1 = jnp.eye(CFG.d_model)
    r2s = jnp.stack([jnp.eye(CFG.d_head)] * CFG.n_layers)
    t = tokens()
    a = model_mod.forward(params, t, CFG)
    b = model_mod.forward(params, t, CFG, rot=(r1, r2s))
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)


def test_bits16_qcfg_equals_fp():
    params = make_params()
    t = tokens()
    fp = model_mod.forward(params, t, CFG)
    q16 = model_mod.forward(params, t, CFG, qcfg=model_mod.qcfg_vector())
    np.testing.assert_array_equal(fp, q16)


def test_quantization_changes_output_and_rotation_helps():
    params = model_mod.fold_norm_scales(make_params(), CFG)
    t = tokens()
    fp = model_mod.forward(params, t, CFG)
    q4 = model_mod.forward(params, t, CFG, qcfg=model_mod.qcfg_vector(a_bits=4, kv_bits=4))
    assert float(jnp.mean((q4 - fp) ** 2)) > 1e-6


def test_quantized_loss_rotation_dependence():
    """Different rotations -> different quantized loss (the Fig. 4 variance)."""
    params = model_mod.fold_norm_scales(make_params(), CFG)
    t = tokens(3, b=4, s=32)
    qcfg = model_mod.qcfg_vector(a_bits=4, kv_bits=4)
    losses = []
    for seed in range(3):
        r1, r2s = rotations(seed * 10)
        logits = model_mod.forward(params, t, CFG, qcfg=qcfg, rot=(r1, r2s))
        losses.append(float(model_mod.next_token_loss(logits, t)))
    assert np.std(losses) > 1e-4


def test_cayley_grads_zero_without_quant_nonzero_with():
    params = model_mod.fold_norm_scales(make_params(), CFG)
    r1, r2s = rotations(5)
    t = tokens(1, b=2, s=16)
    loss16, g1_16, g2_16 = model_mod.cayley_loss_and_grads(
        params, r1, r2s, t, CFG, model_mod.qcfg_vector(), had=False
    )
    loss4, g1_4, g2_4 = model_mod.cayley_loss_and_grads(
        params, r1, r2s, t, CFG, model_mod.qcfg_vector(a_bits=4, kv_bits=4), had=False
    )
    def riem(g, r):
        # Riemannian gradient on the Stiefel manifold: skew(G R^T). The raw
        # Euclidean gradient is non-zero even for an invariant function
        # (invariance only holds *on* the manifold); Cayley SGD moves along
        # the skew projection, which is what Eq. 5 predicts vanishes.
        y = g @ r.T
        return float(jnp.max(jnp.abs(y - y.T)))

    scale16 = riem(g1_16, r1)
    scale4 = riem(g1_4, r1)
    assert scale16 < 1e-2
    assert scale4 > 10 * max(scale16, 1e-9)
    # Both losses are finite and well-formed.
    assert np.isfinite(float(loss16)) and np.isfinite(float(loss4))


def test_capture_shapes():
    params = make_params()
    t = tokens()
    logits, caps = model_mod.forward(params, t, CFG, capture=True)
    B, S = t.shape
    assert logits.shape == (B, S, CFG.vocab)
    assert caps["resid_in"].shape == (CFG.n_layers, B, S, CFG.d_model)
    assert caps["down_in"].shape == (CFG.n_layers, B, S, CFG.d_ffn)
    assert caps["k"].shape == (CFG.n_layers, B, S, CFG.n_heads, CFG.d_head)


def test_planted_outliers_raise_kurtosis_and_rotation_fixes_it():
    """End-to-end Fig. 3(a) shape on the untrained model."""
    params = model_mod.fold_norm_scales(make_params(), CFG)
    t = tokens(7, b=4, s=32)
    _, caps = model_mod.forward(params, t, CFG, capture=True)
    x = caps["resid_in"][0].reshape(-1, CFG.d_model)
    k_before = float(ref.kurtosis_ref(x))
    r1, r2s = rotations(11)
    merged = model_mod.merge_rotations(params, CFG, r1, r2s)
    _, caps_r = model_mod.forward(merged, t, CFG, capture=True)
    xr = caps_r["resid_in"][0].reshape(-1, CFG.d_model)
    k_after = float(ref.kurtosis_ref(xr))
    assert k_before > 2 * k_after


def test_decode_matches_full_forward_fp():
    params = make_params()
    t = tokens(9, b=1, s=8)
    full = model_mod.forward(params, t, CFG)
    cache_shape = (CFG.n_layers, 1, CFG.max_seq, CFG.n_heads, CFG.d_head)
    ck = jnp.zeros(cache_shape)
    cv = jnp.zeros(cache_shape)
    outs = []
    for pos in range(t.shape[1]):
        logits, ck, cv = model_mod.decode_step(
            params, CFG, t[:, pos], jnp.asarray(pos, jnp.int32), ck, cv
        )
        outs.append(logits)
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(dec, full, rtol=2e-3, atol=2e-3)


def test_decode_matches_full_forward_quant_had():
    params = make_params()
    qcfg = model_mod.qcfg_vector(a_bits=8, kv_bits=8)
    t = tokens(13, b=1, s=8)
    full = model_mod.forward(params, t, CFG, qcfg=qcfg, had=True)
    cache_shape = (CFG.n_layers, 1, CFG.max_seq, CFG.n_heads, CFG.d_head)
    ck = jnp.zeros(cache_shape)
    cv = jnp.zeros(cache_shape)
    outs = []
    for pos in range(t.shape[1]):
        logits, ck, cv = model_mod.decode_step(
            params, CFG, t[:, pos], jnp.asarray(pos, jnp.int32), ck, cv,
            qcfg=qcfg, had=True,
        )
        outs.append(logits)
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(dec, full, rtol=5e-3, atol=5e-3)


def test_batched_decode_matches_full_forward_fp():
    """Every lane of decode_step_batched reproduces the full forward."""
    params = make_params()
    B, S = 3, 8
    t = tokens(17, b=B, s=S)
    full = model_mod.forward(params, t, CFG)
    cache_shape = (CFG.n_layers, B, CFG.max_seq, CFG.n_heads, CFG.d_head)
    ck = jnp.zeros(cache_shape)
    cv = jnp.zeros(cache_shape)
    outs = []
    for pos in range(S):
        logits, ck, cv = model_mod.decode_step_batched(
            params, CFG, t[:, pos], jnp.full((B,), pos, jnp.int32), ck, cv
        )
        outs.append(logits)
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(dec, full, rtol=2e-3, atol=2e-3)


def test_batched_decode_slots_are_independent_at_staggered_positions():
    """Continuous-batching semantics: a slot that joins mid-flight (pos
    restarting at 0 while its neighbour is ahead, stale garbage in its
    cache) decodes exactly as it would alone."""
    params = make_params()
    B, S = 2, 6
    t = tokens(23, b=B, s=S)
    cache_shape = (CFG.n_layers, B, CFG.max_seq, CFG.n_heads, CFG.d_head)
    # Poison slot 1's cache to prove masking hides stale entries.
    rs = np.random.RandomState(5)
    ck = jnp.asarray(rs.randn(*cache_shape).astype(np.float32))
    cv = jnp.asarray(rs.randn(*cache_shape).astype(np.float32))
    lag = 3  # slot 1 joins after slot 0 has decoded `lag` tokens
    logits1 = []
    for step in range(S + lag):
        pos0 = min(step, S - 1)  # slot 0 idles at its last token once done
        pos1 = step - lag
        tok = jnp.asarray([t[0, pos0], t[1, max(pos1, 0)]], jnp.int32)
        pos = jnp.asarray([pos0, max(pos1, 0)], jnp.int32)
        logits, ck, cv = model_mod.decode_step_batched(
            params, CFG, tok, pos, ck, cv
        )
        if pos1 >= 0:
            logits1.append(logits[1])
    # Reference: slot 1's sequence decoded alone through the B=1 path.
    cache_shape1 = (CFG.n_layers, 1, CFG.max_seq, CFG.n_heads, CFG.d_head)
    ck1 = jnp.zeros(cache_shape1)
    cv1 = jnp.zeros(cache_shape1)
    ref_logits = []
    for pos in range(S):
        logits, ck1, cv1 = model_mod.decode_step(
            params, CFG, t[1:2, pos], jnp.asarray(pos, jnp.int32), ck1, cv1
        )
        ref_logits.append(logits[0])
    np.testing.assert_allclose(
        jnp.stack(logits1), jnp.stack(ref_logits), rtol=2e-3, atol=2e-3
    )


def test_batched_decode_quant_had_matches_full_forward():
    params = make_params()
    qcfg = model_mod.qcfg_vector(a_bits=8, kv_bits=8)
    B, S = 2, 8
    t = tokens(29, b=B, s=S)
    full = model_mod.forward(params, t, CFG, qcfg=qcfg, had=True)
    cache_shape = (CFG.n_layers, B, CFG.max_seq, CFG.n_heads, CFG.d_head)
    ck = jnp.zeros(cache_shape)
    cv = jnp.zeros(cache_shape)
    outs = []
    for pos in range(S):
        logits, ck, cv = model_mod.decode_step_batched(
            params, CFG, t[:, pos], jnp.full((B,), pos, jnp.int32), ck, cv,
            qcfg=qcfg, had=True,
        )
        outs.append(logits)
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(dec, full, rtol=5e-3, atol=5e-3)


def test_param_order_matches_shapes():
    names = model_mod.param_order(CFG)
    shapes = model_mod.param_shapes(CFG)
    assert set(names) == set(shapes.keys())
    assert names[0] == "emb" and names[-1] == "head"


# ---------------------------------------------------------------------------
# Batched multi-token prefill (serving TTFT path)
# ---------------------------------------------------------------------------


def _zero_caches(b):
    shape = (CFG.n_layers, b, CFG.max_seq, CFG.n_heads, CFG.d_head)
    return jnp.zeros(shape), jnp.zeros(shape)


def test_prefill_equals_sequential_decode_bitexact_fp():
    # prefill_batched(T) IS T decode_step_batched calls: on the fp path the
    # logits at the last position and every written KV entry must match
    # bit-for-bit (same batch width => same XLA reduction shapes).
    params = make_params()
    B, T = 4, 8
    t = tokens(11, b=B, s=T)
    ck0, cv0 = _zero_caches(B)
    lg, ck_r, cv_r = None, ck0, cv0
    for step in range(T):
        lg, ck_r, cv_r = model_mod.decode_step_batched(
            params, CFG, t[:, step], jnp.full((B,), step, jnp.int32), ck_r, cv_r
        )
    lgp, ckp, cvp = model_mod.prefill_batched(
        params, CFG, t, jnp.zeros((B,), jnp.int32), jnp.full((B,), T, jnp.int32),
        ck0, cv0,
    )
    assert np.array_equal(np.asarray(lgp), np.asarray(lg))
    assert np.array_equal(np.asarray(ckp), np.asarray(ck_r))
    assert np.array_equal(np.asarray(cvp), np.asarray(cv_r))


@pytest.mark.parametrize("had", [False, True])
def test_prefill_equals_sequential_decode_quant(had):
    # Quantized paths (nohad/had): same equivalence within tolerance (the
    # fake-quant thresholds can flip a grid cell under float reordering).
    params = make_params()
    qcfg = model_mod.qcfg_vector(a_bits=8, kv_bits=8)
    B, T = 4, 6
    t = tokens(13, b=B, s=T)
    ck0, cv0 = _zero_caches(B)
    lg, ck_r, cv_r = None, ck0, cv0
    for step in range(T):
        lg, ck_r, cv_r = model_mod.decode_step_batched(
            params, CFG, t[:, step], jnp.full((B,), step, jnp.int32), ck_r, cv_r,
            qcfg=qcfg, had=had,
        )
    lgp, ckp, cvp = model_mod.prefill_batched(
        params, CFG, t, jnp.zeros((B,), jnp.int32), jnp.full((B,), T, jnp.int32),
        ck0, cv0, qcfg=qcfg, had=had,
    )
    np.testing.assert_allclose(np.asarray(lgp), np.asarray(lg), rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(ckp), np.asarray(ck_r), rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(cvp), np.asarray(cv_r), rtol=2e-3, atol=2e-3)


def test_prefill_staggered_positions_and_partial_chunks():
    # Slots at independent cache depths (mid-flight join) with ragged
    # n_valid: each slot must match its own sequential decode, and rows
    # past n_valid must leave the cache untouched.
    params = make_params()
    B, T = 4, 8
    npre = 3  # every slot already holds `npre` cache entries
    pre = tokens(17, b=B, s=npre)
    t = tokens(19, b=B, s=T)
    n_valid = jnp.asarray([T, 5, 1, 3], jnp.int32)
    ck, cv = _zero_caches(B)
    for step in range(npre):
        _, ck, cv = model_mod.decode_step_batched(
            params, CFG, pre[:, step], jnp.full((B,), step, jnp.int32), ck, cv
        )
    # Sequential reference: keep feeding slots their chunk tokens while
    # valid; slots that ran out re-write their last entry at a frozen pos
    # (decode_step_batched has no lane mask), which matches what their
    # cache already held, so lanes stay independent.
    lg, ck_r, cv_r = None, ck, cv
    last = {b: None for b in range(B)}
    for step in range(T):
        tok = jnp.asarray(
            [t[b, min(step, int(n_valid[b]) - 1)] for b in range(B)], jnp.int32
        )
        pos = jnp.asarray(
            [npre + min(step, int(n_valid[b]) - 1) for b in range(B)], jnp.int32
        )
        lg, ck_r, cv_r = model_mod.decode_step_batched(
            params, CFG, tok, pos, ck_r, cv_r
        )
        for b in range(B):
            if step == int(n_valid[b]) - 1:
                last[b] = np.asarray(lg[b])
    lgp, ckp, cvp = model_mod.prefill_batched(
        params, CFG, t, jnp.full((B,), npre, jnp.int32), n_valid, ck, cv
    )
    for b in range(B):
        np.testing.assert_allclose(
            np.asarray(lgp[b]), last[b], rtol=2e-3, atol=2e-3,
            err_msg=f"slot {b}",
        )
    # Positions beyond npre + n_valid[b] were never written.
    ckp_np, cvp_np = np.asarray(ckp), np.asarray(cvp)
    for b in range(B):
        end = npre + int(n_valid[b])
        assert np.all(ckp_np[:, b, end:] == 0.0), f"slot {b} cache_k leaked"
        assert np.all(cvp_np[:, b, end:] == 0.0), f"slot {b} cache_v leaked"


# ---------------------------------------------------------------------------
# Paged KV cache (block-pool serving path)
# ---------------------------------------------------------------------------

BS = 8  # block size for paged tests; CFG.max_seq = 32 -> 4 logical blocks


def _paged_caches(n_blocks, seed=None):
    shape = (CFG.n_layers, n_blocks, BS, CFG.n_heads, CFG.d_head)
    if seed is None:
        return jnp.zeros(shape), jnp.zeros(shape)
    rs = np.random.RandomState(seed)
    return (jnp.asarray(rs.randn(*shape).astype(np.float32)),
            jnp.asarray(rs.randn(*shape).astype(np.float32)))


def _identity_table(b):
    nl = CFG.max_seq // BS
    return jnp.asarray(
        [[s * nl + j for j in range(nl)] for s in range(b)], jnp.int32
    )


def _dense_view(paged_cache, b):
    """Reshape a (L, B*nl, BS, H, dh) pool under the identity table into the
    dense (L, B, max_seq, H, dh) layout."""
    a = np.asarray(paged_cache)
    L = a.shape[0]
    return a.reshape(L, b, CFG.max_seq, CFG.n_heads, CFG.d_head)


def test_decode_paged_identity_table_bitexact_fp():
    # With the identity table the paged graph IS the dense graph: logits and
    # caches (reshaped) must match bit for bit, step after step.
    params = make_params()
    B, S = 3, 8
    t = tokens(31, b=B, s=S)
    ck_d, cv_d = _zero_caches(B)
    ck_p, cv_p = _paged_caches(B * (CFG.max_seq // BS))
    table = _identity_table(B)
    for pos in range(S):
        pv = jnp.full((B,), pos, jnp.int32)
        lg_d, ck_d, cv_d = model_mod.decode_step_batched(
            params, CFG, t[:, pos], pv, ck_d, cv_d
        )
        lg_p, ck_p, cv_p = model_mod.decode_paged(
            params, CFG, t[:, pos], pv, table, ck_p, cv_p
        )
        assert np.array_equal(np.asarray(lg_p), np.asarray(lg_d)), f"pos {pos}"
        assert np.array_equal(_dense_view(ck_p, B), np.asarray(ck_d))
        assert np.array_equal(_dense_view(cv_p, B), np.asarray(cv_d))


@pytest.mark.parametrize("had", [False, True])
def test_decode_paged_identity_table_quant(had):
    params = make_params()
    qcfg = model_mod.qcfg_vector(a_bits=8, kv_bits=8)
    B, S = 2, 6
    t = tokens(37, b=B, s=S)
    ck_d, cv_d = _zero_caches(B)
    ck_p, cv_p = _paged_caches(B * (CFG.max_seq // BS))
    table = _identity_table(B)
    for pos in range(S):
        pv = jnp.full((B,), pos, jnp.int32)
        lg_d, ck_d, cv_d = model_mod.decode_step_batched(
            params, CFG, t[:, pos], pv, ck_d, cv_d, qcfg=qcfg, had=had
        )
        lg_p, ck_p, cv_p = model_mod.decode_paged(
            params, CFG, t[:, pos], pv, table, ck_p, cv_p, qcfg=qcfg, had=had
        )
        np.testing.assert_allclose(
            np.asarray(lg_p), np.asarray(lg_d), rtol=2e-3, atol=2e-3
        )


def test_decode_paged_scattered_table_matches_dense():
    # A scrambled physical layout must not change the math: gathering the
    # logical view restores the same operands, so logits stay bit-equal to
    # the dense path even though every page lives somewhere else.
    params = make_params()
    B, S = 2, 8
    nl = CFG.max_seq // BS
    n_blocks = B * nl + 3  # spare pages, never referenced
    t = tokens(41, b=B, s=S)
    ck_d, cv_d = _zero_caches(B)
    # Poison the pool: untouched garbage everywhere, tables pick scattered
    # pages out of it.
    ck_p, cv_p = _paged_caches(n_blocks, seed=3)
    perm = np.random.RandomState(9).permutation(B * nl)
    table = jnp.asarray(perm.reshape(B, nl), jnp.int32)
    for pos in range(S):
        pv = jnp.full((B,), pos, jnp.int32)
        lg_d, ck_d, cv_d = model_mod.decode_step_batched(
            params, CFG, t[:, pos], pv, ck_d, cv_d
        )
        lg_p, ck_p, cv_p = model_mod.decode_paged(
            params, CFG, t[:, pos], pv, table, ck_p, cv_p
        )
        assert np.array_equal(np.asarray(lg_p), np.asarray(lg_d)), f"pos {pos}"
    # Written pages hold exactly the dense cache rows, page by page.
    ck_p_np, ck_d_np = np.asarray(ck_p), np.asarray(ck_d)
    for b in range(B):
        for j in range((S + BS - 1) // BS):
            phys = int(table[b, j])
            n = min(BS, S - j * BS)
            assert np.array_equal(
                ck_p_np[:, phys, :n], ck_d_np[:, b, j * BS:j * BS + n]
            ), f"slot {b} page {j}"


def test_decode_paged_staggered_positions_and_hole_safety():
    # Slots at independent positions (mid-flight join) with unallocated
    # table entries marked by the out-of-range sentinel: writes through a
    # hole are dropped, and the sentinel pages never leak into the logits.
    params = make_params()
    B, S = 2, 6
    nl = CFG.max_seq // BS
    n_blocks = B * nl
    t = tokens(43, b=B, s=S)
    sentinel = n_blocks  # >= n_blocks marks a hole
    # Slot 0 owns pages [0..nl); slot 1 only its first page — the rest holes.
    table = np.full((B, nl), sentinel, np.int32)
    table[0, :] = np.arange(nl)
    table[1, 0] = nl  # one allocated page (S=6 <= BS=8 fits in it)
    table = jnp.asarray(table)
    ck_p, cv_p = _paged_caches(n_blocks, seed=11)
    ck1, cv1 = _zero_caches(1)
    lag = 2
    paged_logits1 = []
    ref_logits1 = []
    for step in range(S + lag):
        pos0 = min(step, S - 1)
        pos1 = step - lag
        tok = jnp.asarray([t[0, pos0], t[1, max(pos1, 0)]], jnp.int32)
        pos = jnp.asarray([pos0, max(pos1, 0)], jnp.int32)
        lg, ck_p, cv_p = model_mod.decode_paged(
            params, CFG, tok, pos, table, ck_p, cv_p
        )
        if pos1 >= 0:
            paged_logits1.append(lg[1])
    for pos in range(S):
        lg, ck1, cv1 = model_mod.decode_step(
            params, CFG, t[1:2, pos], jnp.asarray(pos, jnp.int32), ck1, cv1
        )
        ref_logits1.append(lg[0])
    np.testing.assert_allclose(
        jnp.stack(paged_logits1), jnp.stack(ref_logits1), rtol=2e-3, atol=2e-3
    )


def test_prefill_paged_identity_table_bitexact_fp():
    params = make_params()
    B, T = 4, 8
    t = tokens(47, b=B, s=T)
    ck_d, cv_d = _zero_caches(B)
    ck_p, cv_p = _paged_caches(B * (CFG.max_seq // BS))
    table = _identity_table(B)
    zeros, full = jnp.zeros((B,), jnp.int32), jnp.full((B,), T, jnp.int32)
    lg_d, ck_d, cv_d = model_mod.prefill_batched(
        params, CFG, t, zeros, full, ck_d, cv_d
    )
    lg_p, ck_p, cv_p = model_mod.prefill_paged(
        params, CFG, t, zeros, full, table, ck_p, cv_p
    )
    assert np.array_equal(np.asarray(lg_p), np.asarray(lg_d))
    assert np.array_equal(_dense_view(ck_p, B), np.asarray(ck_d))
    assert np.array_equal(_dense_view(cv_p, B), np.asarray(cv_d))


def test_prefill_paged_ragged_chunks_cross_page_boundaries():
    # Ragged n_valid with pos0 > 0 so chunks straddle page boundaries; each
    # slot must match the dense prefill, and pages of inactive slots (or
    # past the written prefix) must come back untouched.
    params = make_params()
    B, T = 4, 8
    npre = 3
    pre = tokens(53, b=B, s=npre)
    t = tokens(59, b=B, s=T)
    n_valid = jnp.asarray([T, 5, 0, 3], jnp.int32)
    ck_d, cv_d = _zero_caches(B)
    for step in range(npre):
        _, ck_d, cv_d = model_mod.decode_step_batched(
            params, CFG, pre[:, step], jnp.full((B,), step, jnp.int32), ck_d, cv_d
        )
    n_blocks = B * (CFG.max_seq // BS)
    table = _identity_table(B)
    ck_p, cv_p = (
        jnp.asarray(_dense_view(c, B).reshape(
            CFG.n_layers, n_blocks, BS, CFG.n_heads, CFG.d_head
        )) for c in (ck_d, cv_d)
    )
    pos0 = jnp.full((B,), npre, jnp.int32)
    lg_d, ck_d, cv_d = model_mod.prefill_batched(
        params, CFG, t, pos0, n_valid, ck_d, cv_d
    )
    lg_p, ck_p, cv_p = model_mod.prefill_paged(
        params, CFG, t, pos0, n_valid, table, ck_p, cv_p
    )
    for b in range(B):
        if int(n_valid[b]) == 0:
            continue  # inactive slot: dense returns garbage logits there too
        assert np.array_equal(np.asarray(lg_p[b]), np.asarray(lg_d[b])), f"slot {b}"
    assert np.array_equal(_dense_view(ck_p, B), np.asarray(ck_d))
    assert np.array_equal(_dense_view(cv_p, B), np.asarray(cv_d))


# ---------------------------------------------------------------------------
# Quantized KV page storage (`serve --kv-bits {4,8,16}`)
# ---------------------------------------------------------------------------
# The paged graphs run `_kvq` on K/V *before* the scatter, so physical pages
# hold quantize->dequantize round-tripped values on the kv_bits grid — the
# page is the storage format, not a staging buffer. These tests pin the
# three properties the rust serving stack builds on: 16-bit is bit-exact
# pass-through (one artifact serves fp rows), 4/8-bit pages agree exactly
# with the dense graph under the same qcfg (storage is where the error is
# introduced, not the layout), and the end-to-end drift is bounded and
# ordered by width.


def test_paged_qcfg16_bit_equal_to_no_qcfg():
    # kv_bits >= 16 is exact pass-through in fake_quant_ste, so the
    # all-default qcfg vector through the quant paged graphs must be
    # bit-identical to qcfg=None — `--kv-bits 16` is the pre-PR paged path.
    params = make_params()
    B, S, T = 2, 4, 8
    t = tokens(61, b=B, s=S)
    table = _identity_table(B)
    q16 = model_mod.qcfg_vector()
    ck_a, cv_a = _paged_caches(B * (CFG.max_seq // BS))
    ck_b, cv_b = ck_a, cv_a
    for pos in range(S):
        pv = jnp.full((B,), pos, jnp.int32)
        lg_a, ck_a, cv_a = model_mod.decode_paged(
            params, CFG, t[:, pos], pv, table, ck_a, cv_a
        )
        lg_b, ck_b, cv_b = model_mod.decode_paged(
            params, CFG, t[:, pos], pv, table, ck_b, cv_b, qcfg=q16
        )
        assert np.array_equal(np.asarray(lg_b), np.asarray(lg_a)), f"pos {pos}"
    assert np.array_equal(np.asarray(ck_b), np.asarray(ck_a))
    assert np.array_equal(np.asarray(cv_b), np.asarray(cv_a))
    tp = tokens(67, b=B, s=T)
    ck0, cv0 = _paged_caches(B * (CFG.max_seq // BS))
    zeros, full = jnp.zeros((B,), jnp.int32), jnp.full((B,), T, jnp.int32)
    lgp_a, ckp_a, cvp_a = model_mod.prefill_paged(
        params, CFG, tp, zeros, full, table, ck0, cv0
    )
    lgp_b, ckp_b, cvp_b = model_mod.prefill_paged(
        params, CFG, tp, zeros, full, table, ck0, cv0, qcfg=q16
    )
    assert np.array_equal(np.asarray(lgp_b), np.asarray(lgp_a))
    assert np.array_equal(np.asarray(ckp_b), np.asarray(ckp_a))
    assert np.array_equal(np.asarray(cvp_b), np.asarray(cvp_a))


@pytest.mark.parametrize("kv_bits", [4.0, 8.0])
def test_decode_paged_kv_only_quant_pages_hold_storage_grid(kv_bits):
    # KV-only qcfg (a/w stay at 16): under the identity table the paged
    # graph must agree bit-for-bit with the dense decode — both insert the
    # same `_kvq` before the cache write — and the page contents must equal
    # the dense quantized cache. Then the written pages must be a fixed
    # point of `_kvq`: re-quantizing storage-grid values changes nothing,
    # which is what lets the rust engine treat a page as the ground truth.
    params = make_params()
    qcfg = model_mod.qcfg_vector(kv_bits=kv_bits, kv_sym=1.0)
    B, S = 2, 8
    t = tokens(71, b=B, s=S)
    ck_d, cv_d = _zero_caches(B)
    ck_p, cv_p = _paged_caches(B * (CFG.max_seq // BS))
    table = _identity_table(B)
    for pos in range(S):
        pv = jnp.full((B,), pos, jnp.int32)
        lg_d, ck_d, cv_d = model_mod.decode_step_batched(
            params, CFG, t[:, pos], pv, ck_d, cv_d, qcfg=qcfg
        )
        lg_p, ck_p, cv_p = model_mod.decode_paged(
            params, CFG, t[:, pos], pv, table, ck_p, cv_p, qcfg=qcfg
        )
        assert np.array_equal(np.asarray(lg_p), np.asarray(lg_d)), f"pos {pos}"
    assert np.array_equal(_dense_view(ck_p, B), np.asarray(ck_d))
    assert np.array_equal(_dense_view(cv_p, B), np.asarray(cv_d))
    written = _dense_view(ck_p, B)[:, :, :S]
    requant = np.asarray(model_mod._kvq(jnp.asarray(written), qcfg))
    np.testing.assert_allclose(requant, written, rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("kv_bits", [4.0, 8.0])
def test_prefill_paged_kv_only_quant_matches_dense(kv_bits):
    params = make_params()
    qcfg = model_mod.qcfg_vector(kv_bits=kv_bits, kv_sym=1.0)
    B, T = 4, 8
    t = tokens(73, b=B, s=T)
    ck_d, cv_d = _zero_caches(B)
    ck_p, cv_p = _paged_caches(B * (CFG.max_seq // BS))
    table = _identity_table(B)
    zeros, full = jnp.zeros((B,), jnp.int32), jnp.full((B,), T, jnp.int32)
    lg_d, ck_d, cv_d = model_mod.prefill_batched(
        params, CFG, t, zeros, full, ck_d, cv_d, qcfg=qcfg
    )
    lg_p, ck_p, cv_p = model_mod.prefill_paged(
        params, CFG, t, zeros, full, table, ck_p, cv_p, qcfg=qcfg
    )
    assert np.array_equal(np.asarray(lg_p), np.asarray(lg_d))
    assert np.array_equal(_dense_view(ck_p, B), np.asarray(ck_d))
    assert np.array_equal(_dense_view(cv_p, B), np.asarray(cv_d))


def test_paged_kv_quant_drift_bounded():
    # End-to-end logit drift from quantized KV storage is zero at 16 bits
    # and ordered by grid width below that: 0 < mse(kv8) < mse(kv4).
    params = make_params()
    B, S = 2, 8
    t = tokens(79, b=B, s=S)
    table = _identity_table(B)

    def run(qcfg):
        ck, cv = _paged_caches(B * (CFG.max_seq // BS))
        outs = []
        for pos in range(S):
            pv = jnp.full((B,), pos, jnp.int32)
            lg, ck, cv = model_mod.decode_paged(
                params, CFG, t[:, pos], pv, table, ck, cv, qcfg=qcfg
            )
            outs.append(np.asarray(lg))
        return np.stack(outs, axis=1)

    fp = run(None)
    mse = {
        b: float(np.mean(
            (run(model_mod.qcfg_vector(kv_bits=b, kv_sym=1.0)) - fp) ** 2
        ))
        for b in (4.0, 8.0, 16.0)
    }
    assert mse[16.0] == 0.0
    assert 0.0 < mse[8.0] < mse[4.0]


def test_prefill_inactive_slot_untouched():
    # n_valid = 0 marks an inactive slot: its cache must come back
    # bit-identical (padding rows are scatter-dropped, never written).
    params = make_params()
    B, T = 2, 4
    t = tokens(23, b=B, s=T)
    rs = np.random.RandomState(7)
    shape = (CFG.n_layers, B, CFG.max_seq, CFG.n_heads, CFG.d_head)
    ck = jnp.asarray(rs.randn(*shape).astype(np.float32))
    cv = jnp.asarray(rs.randn(*shape).astype(np.float32))
    n_valid = jnp.asarray([T, 0], jnp.int32)
    _, ckp, cvp = model_mod.prefill_batched(
        params, CFG, t, jnp.zeros((B,), jnp.int32), n_valid, ck, cv
    )
    assert np.array_equal(np.asarray(ckp[:, 1]), np.asarray(ck[:, 1]))
    assert np.array_equal(np.asarray(cvp[:, 1]), np.asarray(cv[:, 1]))
