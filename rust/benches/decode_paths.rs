//! §Perf experiment: decode-loop KV-cache handling.
//!
//! BEFORE (naive): every decode step converts the returned KV-cache buffers
//! to host tensors and back to literals for the next step.
//! AFTER (shipped, spinquant::serve): the cache stays as PJRT literals
//! between steps — zero host round-trips on the steady-state path.
//!
//! Run: cargo bench --bench decode_paths   (needs `make artifacts`)

use spinquant::eval::QcfgVec;
use spinquant::model::{Manifest, Weights};
use spinquant::runtime::{literal_to_tensor, Executable, Value};
use spinquant::util::timer::Samples;

fn main() {
    let manifest = match Manifest::load(std::path::Path::new("artifacts")) {
        Ok(m) => m,
        Err(_) => {
            eprintln!("run `make artifacts` first");
            return;
        }
    };
    let rt = spinquant::runtime::Runtime::cpu().expect("pjrt");
    let model = "sq-2m";
    let w = Weights::load(&manifest.weights_path(model)).unwrap();
    let exe = rt.load(&manifest, model, "decode_nohad").unwrap();
    let steps = 64;

    println!("decode path comparison ({model}, {steps} steps, W4A8KV8):");
    let naive = run_naive(&exe, &w, steps);
    println!("  naive (cache -> host tensor -> literal each step): {naive:.3} ms/token");
    let cached = run_cached(&exe, &w, steps);
    println!("  shipped (cache stays as PJRT literals):            {cached:.3} ms/token");
    println!("  speedup: {:.2}x", naive / cached);
}

fn base_literals(exe: &Executable, w: &Weights) -> (Vec<xla::Literal>, usize, usize, usize, usize) {
    let (mut ti, mut pi, mut ki, mut vi) = (0, 0, 0, 0);
    let mut values = Vec::new();
    for (i, (name, shape, _)) in exe.spec.inputs.iter().enumerate() {
        let v = match name.as_str() {
            "token" => {
                ti = i;
                Value::I32(vec![0; 1], shape.clone())
            }
            "pos" => {
                pi = i;
                Value::ScalarI32(0)
            }
            "cache_k" => {
                ki = i;
                Value::F32(spinquant::Tensor::zeros(shape))
            }
            "cache_v" => {
                vi = i;
                Value::F32(spinquant::Tensor::zeros(shape))
            }
            "qcfg" => Value::F32(QcfgVec::fp().with_a_bits(8.0).with_kv_bits(8.0).tensor()),
            _ => Value::F32(w.get(name).unwrap().clone()),
        };
        values.push(v);
    }
    (exe.prepare(&values).unwrap(), ti, pi, ki, vi)
}

fn run_cached(exe: &Executable, w: &Weights, steps: usize) -> f64 {
    let (mut literals, ti, pi, ki, vi) = base_literals(exe, w);
    let mut samples = Samples::new();
    for pos in 0..steps {
        samples.time(|| {
            literals[ti] = xla::Literal::vec1(&[65i32]).reshape(&[1]).unwrap();
            literals[pi] = xla::Literal::scalar(pos as i32);
            let bufs = exe.run_literals_raw(&literals).unwrap();
            let mut parts = bufs[0][0].to_literal_sync().unwrap().to_tuple().unwrap();
            let cv = parts.pop().unwrap();
            let ck = parts.pop().unwrap();
            literals[ki] = ck;
            literals[vi] = cv;
        });
    }
    samples.mean_us() / 1e3
}

fn run_naive(exe: &Executable, w: &Weights, steps: usize) -> f64 {
    let (mut literals, ti, pi, ki, vi) = base_literals(exe, w);
    let mut samples = Samples::new();
    for pos in 0..steps {
        samples.time(|| {
            literals[ti] = xla::Literal::vec1(&[65i32]).reshape(&[1]).unwrap();
            literals[pi] = xla::Literal::scalar(pos as i32);
            // run_literals converts every output (incl. both caches) to host
            // tensors; we then pay the tensor->literal conversion again.
            let outs = exe.run_literals(&literals).unwrap();
            let ck = &outs[1];
            let cv = &outs[2];
            let dims: Vec<i64> = ck.shape.iter().map(|&d| d as i64).collect();
            literals[ki] = xla::Literal::vec1(&ck.data).reshape(&dims).unwrap();
            literals[vi] = xla::Literal::vec1(&cv.data).reshape(&dims).unwrap();
        });
    }
    let _ = literal_to_tensor;
    samples.mean_us() / 1e3
}
