//! Serving throughput bench: continuous batching at batch sizes {1, 4, 8}.
//!
//! Drives the `serve` scheduler over a fixed synthetic workload and reports
//! tokens/sec + latency percentiles per batch size, leaving a
//! machine-readable trajectory in `BENCH_serving.json` so later PRs can be
//! compared against this one. A second sweep compares time-to-first-token
//! on 64-token prompts between the batched prefill path (T = 16, so 4
//! engine calls to first token) and the legacy token-by-token loop (64
//! calls) — the `ttft` object in the JSON.
//!
//! Engine selection: the PJRT engine is used when `make artifacts` has run
//! (batch 1 via `decode_nohad`, batch N via `decode_nohad_b{N}`, prefill
//! via `prefill_nohad_b{N}_t16`); otherwise the deterministic mock engine
//! benches the scheduler itself, so this target always produces numbers.
//! TTFT rows come in engine-coherent pairs: if either leg of a
//! prefill-vs-loop comparison can't run on PJRT (batch 1 has no prefill
//! artifact; aot emits b{4,8} only), both legs run on the mock.
//!
//! Run: cargo bench --bench serving

use spinquant::eval::QcfgVec;
use spinquant::model::{Manifest, Weights};
use spinquant::report;
use spinquant::runtime::Runtime;
use spinquant::serve::{
    DecodeVariant, GenRequest, MockEngine, PjrtEngine, Sampler, Scheduler, ServingMetrics,
};
use spinquant::util::json::{self, Json};

const BATCHES: [usize; 3] = [1, 4, 8];
const MODEL: &str = "sq-2m";
const N_REQUESTS: usize = 32;
const MAX_NEW: usize = 24;
// TTFT sweep: long prompts where prompt ingestion dominates latency.
const TTFT_PROMPT_LEN: usize = 64;
const TTFT_CHUNK: usize = 16;
const TTFT_REQUESTS: usize = 16;
const TTFT_MAX_NEW: usize = 8;

/// The fixed workload: byte prompts of varying length, seeded top-k
/// sampling so every engine sees the same request stream.
fn workload() -> Vec<GenRequest> {
    (0..N_REQUESTS)
        .map(|i| {
            let len = 4 + (i % 6);
            let prompt: Vec<u8> = (0..len).map(|j| (32 + ((i * 17 + j * 5) % 90)) as u8).collect();
            GenRequest::sampled(&prompt, MAX_NEW, Sampler::top_k(8, 0.8), 1000 + i as u64)
        })
        .collect()
}

fn run_mock(batch: usize) -> anyhow::Result<ServingMetrics> {
    let engine = MockEngine::new(batch, 128, 256);
    let mut sched = Scheduler::new(engine, N_REQUESTS)?;
    sched.serve_all(workload())?;
    Ok(sched.metrics)
}

fn run_pjrt(manifest: &Manifest, rt: &Runtime, batch: usize) -> anyhow::Result<ServingMetrics> {
    let artifact = DecodeVariant::QuantNoHad.artifact_batched(batch);
    let exe = rt.load(manifest, MODEL, &artifact)?;
    let weights = Weights::load(&manifest.weights_path(MODEL))?;
    // W-quant is offline; serve the raw weights at A8/KV8 like the Table 6
    // harness — the bench measures serving throughput, not quality.
    let qcfg = QcfgVec::fp().with_a_bits(8.0).with_kv_bits(8.0);
    let engine = PjrtEngine::new(exe, &weights, Some(qcfg))?;
    let mut sched = Scheduler::new(engine, N_REQUESTS)?;
    sched.serve_all(workload())?;
    Ok(sched.metrics)
}

// -- TTFT: batched prefill vs the token-by-token loop -----------------------

/// Long-prompt workload: TTFT is dominated by prompt ingestion here.
fn ttft_workload() -> Vec<GenRequest> {
    (0..TTFT_REQUESTS)
        .map(|i| {
            let prompt: Vec<u8> = (0..TTFT_PROMPT_LEN)
                .map(|j| (32 + ((i * 13 + j * 7) % 90)) as u8)
                .collect();
            GenRequest::sampled(&prompt, TTFT_MAX_NEW, Sampler::top_k(8, 0.8), 2000 + i as u64)
        })
        .collect()
}

/// `chunk > 1`: the batched prefill path; `chunk == 1`: the token loop.
fn run_mock_ttft(batch: usize, chunk: usize) -> anyhow::Result<ServingMetrics> {
    let engine = MockEngine::new(batch, 128, 256).with_prefill_chunk(chunk);
    let mut sched = Scheduler::new(engine, TTFT_REQUESTS)?;
    sched.serve_all(ttft_workload())?;
    Ok(sched.metrics)
}

fn run_pjrt_ttft(
    manifest: &Manifest,
    rt: &Runtime,
    batch: usize,
    chunk: usize,
) -> anyhow::Result<ServingMetrics> {
    let weights = Weights::load(&manifest.weights_path(MODEL))?;
    let qcfg = QcfgVec::fp().with_a_bits(8.0).with_kv_bits(8.0);
    let exe = rt.load(manifest, MODEL, &DecodeVariant::QuantNoHad.artifact_batched(batch))?;
    let mut engine = PjrtEngine::new(exe, &weights, Some(qcfg))?;
    if chunk > 1 {
        // No artifact (e.g. batch 1) => error; the caller falls back to the
        // mock so the prefill-vs-loop row always exists.
        let pre = rt.load(
            manifest,
            MODEL,
            &DecodeVariant::QuantNoHad.artifact_prefill(batch, chunk),
        )?;
        engine = engine.with_prefill(pre, &weights, Some(qcfg))?;
    }
    let mut sched = Scheduler::new(engine, TTFT_REQUESTS)?;
    sched.serve_all(ttft_workload())?;
    Ok(sched.metrics)
}

/// The TTFT rows come as an engine-coherent `(prefill, token_loop)` pair:
/// the prefill-vs-loop delta is only meaningful when both rows ran on the
/// same engine, so if either PJRT leg is unavailable (no artifacts, no
/// prefill graph for this batch, or batch 1 which has none) the whole pair
/// runs on the mock.
fn ttft_pair(
    pjrt_ctx: &Option<(Manifest, Runtime)>,
    batch: usize,
) -> (&'static str, ServingMetrics, ServingMetrics) {
    if batch > 1 {
        if let Some((manifest, rt)) = pjrt_ctx {
            match run_pjrt_ttft(manifest, rt, batch, TTFT_CHUNK)
                .and_then(|pre| run_pjrt_ttft(manifest, rt, batch, 1).map(|lp| (pre, lp)))
            {
                Ok((pre, lp)) => return ("pjrt", pre, lp),
                Err(e) => eprintln!(
                    "ttft batch {batch}: PJRT pair unavailable ({e:#}); using mock for both"
                ),
            }
        }
    }
    (
        "mock",
        run_mock_ttft(batch, TTFT_CHUNK).expect("mock engine"),
        run_mock_ttft(batch, 1).expect("mock engine"),
    )
}

fn main() {
    let pjrt_ctx = Manifest::load(std::path::Path::new("artifacts"))
        .ok()
        .and_then(|m| Runtime::cpu().ok().map(|rt| (m, rt)));
    if pjrt_ctx.is_none() {
        eprintln!("no artifacts (run `make artifacts`); benching the mock engine instead");
    }

    let labels: Vec<String> = BATCHES.iter().map(|b| format!("batch_{b}")).collect();
    let mut rows: Vec<(&str, Json)> = Vec::new();
    let mut engines_used: Vec<&str> = Vec::new();
    println!(
        "{:<10} {:>8} {:>10} {:>12} {:>12} {:>12} {:>12}",
        "batch", "engine", "tokens", "tok/s", "p50 ms/tok", "p95", "p99"
    );
    for (i, &batch) in BATCHES.iter().enumerate() {
        let (label, metrics) = match &pjrt_ctx {
            Some((manifest, rt)) => match run_pjrt(manifest, rt, batch) {
                Ok(m) => ("pjrt", m),
                Err(e) => {
                    eprintln!("batch {batch}: PJRT engine unavailable ({e:#}); using mock");
                    ("mock", run_mock(batch).expect("mock engine"))
                }
            },
            None => ("mock", run_mock(batch).expect("mock engine")),
        };
        engines_used.push(label);
        println!(
            "{:<10} {:>8} {:>10} {:>12.1} {:>12.3} {:>12.3} {:>12.3}",
            batch,
            label,
            metrics.tokens_generated,
            metrics.tokens_per_sec(),
            metrics.token_ms_p50(),
            metrics.token_ms_p95(),
            metrics.token_ms_p99()
        );
        let mut row = metrics.to_json();
        if let Json::Obj(m) = &mut row {
            m.insert("engine".to_string(), json::s(label));
            m.insert("batch".to_string(), json::num(batch as f64));
        }
        rows.push((labels[i].as_str(), row));
    }

    // TTFT: prefill path vs token loop on 64-token prompts.
    println!();
    println!(
        "{:<10} {:>10} {:>8} {:>14} {:>14} {:>14}",
        "batch", "path", "engine", "ttft p50 ms", "ttft p95 ms", "prefill calls"
    );
    let mut ttft_rows: Vec<(String, Json)> = Vec::new();
    for &batch in BATCHES.iter() {
        let mut entry: Vec<(&str, Json)> = Vec::new();
        let (label, m_pre, m_loop) = ttft_pair(&pjrt_ctx, batch);
        for (path, chunk, m) in
            [("prefill", TTFT_CHUNK, &m_pre), ("token_loop", 1, &m_loop)]
        {
            println!(
                "{:<10} {:>10} {:>8} {:>14.3} {:>14.3} {:>14}",
                batch,
                path,
                label,
                m.ttft_ms_p50(),
                m.ttft_ms_p95(),
                m.prefill_us.len()
            );
            entry.push((
                path,
                json::obj(vec![
                    ("engine", json::s(label)),
                    ("chunk", json::num(chunk as f64)),
                    ("ttft_ms_p50", json::num(m.ttft_ms_p50())),
                    ("ttft_ms_p95", json::num(m.ttft_ms_p95())),
                    ("prefill_calls", json::num(m.prefill_us.len() as f64)),
                    ("tokens_prefilled", json::num(m.tokens_prefilled as f64)),
                    ("tokens_per_sec", json::num(m.tokens_per_sec())),
                ]),
            ));
        }
        ttft_rows.push((format!("batch_{batch}"), json::obj(entry)));
    }

    // Top-level engine label is only non-"mixed" when every batch size ran
    // on the same engine; per-batch rows always carry their own label.
    let engine_label = match engines_used.first() {
        Some(first) if engines_used.iter().all(|e| e == first) => *first,
        Some(_) => "mixed",
        None => "none",
    };
    let out = json::obj(vec![
        ("bench", json::s("serving")),
        ("model", json::s(MODEL)),
        ("engine", json::s(engine_label)),
        ("requests", json::num(N_REQUESTS as f64)),
        ("max_new_tokens", json::num(MAX_NEW as f64)),
        ("batches", json::obj(rows.iter().map(|(k, v)| (*k, v.clone())).collect())),
        (
            "ttft",
            json::obj(
                std::iter::once((
                    "config",
                    json::obj(vec![
                        ("prompt_len", json::num(TTFT_PROMPT_LEN as f64)),
                        ("chunk", json::num(TTFT_CHUNK as f64)),
                        ("requests", json::num(TTFT_REQUESTS as f64)),
                        ("max_new_tokens", json::num(TTFT_MAX_NEW as f64)),
                    ]),
                ))
                .chain(ttft_rows.iter().map(|(k, v)| (k.as_str(), v.clone())))
                .collect(),
            ),
        ),
    ]);
    let path = std::path::Path::new("BENCH_serving.json");
    match report::write_json(path, &out) {
        Ok(()) => println!("\nwrote {}", path.display()),
        Err(e) => eprintln!("failed to write {}: {e:#}", path.display()),
    }
}
