//! Serving throughput bench: continuous batching at batch sizes {1, 4, 8}.
//!
//! Drives the `serve` scheduler over a fixed synthetic workload and reports
//! tokens/sec + latency percentiles per batch size, leaving a
//! machine-readable trajectory in `BENCH_serving.json` so later PRs can be
//! compared against this one.
//!
//! Engine selection: the PJRT engine is used when `make artifacts` has run
//! (batch 1 via `decode_nohad`, batch N via `decode_nohad_b{N}`); otherwise
//! the deterministic mock engine benches the scheduler itself, so this
//! target always produces numbers.
//!
//! Run: cargo bench --bench serving

use spinquant::eval::QcfgVec;
use spinquant::model::{Manifest, Weights};
use spinquant::report;
use spinquant::runtime::Runtime;
use spinquant::serve::{
    DecodeVariant, GenRequest, MockEngine, PjrtEngine, Sampler, Scheduler, ServingMetrics,
};
use spinquant::util::json::{self, Json};

const BATCHES: [usize; 3] = [1, 4, 8];
const MODEL: &str = "sq-2m";
const N_REQUESTS: usize = 32;
const MAX_NEW: usize = 24;

/// The fixed workload: byte prompts of varying length, seeded top-k
/// sampling so every engine sees the same request stream.
fn workload() -> Vec<GenRequest> {
    (0..N_REQUESTS)
        .map(|i| {
            let len = 4 + (i % 6);
            let prompt: Vec<u8> = (0..len).map(|j| (32 + ((i * 17 + j * 5) % 90)) as u8).collect();
            GenRequest::sampled(&prompt, MAX_NEW, Sampler::top_k(8, 0.8), 1000 + i as u64)
        })
        .collect()
}

fn run_mock(batch: usize) -> anyhow::Result<ServingMetrics> {
    let engine = MockEngine::new(batch, 128, 256);
    let mut sched = Scheduler::new(engine, N_REQUESTS)?;
    sched.serve_all(workload())?;
    Ok(sched.metrics)
}

fn run_pjrt(manifest: &Manifest, rt: &Runtime, batch: usize) -> anyhow::Result<ServingMetrics> {
    let artifact = DecodeVariant::QuantNoHad.artifact_batched(batch);
    let exe = rt.load(manifest, MODEL, &artifact)?;
    let weights = Weights::load(&manifest.weights_path(MODEL))?;
    // W-quant is offline; serve the raw weights at A8/KV8 like the Table 6
    // harness — the bench measures serving throughput, not quality.
    let qcfg = QcfgVec::fp().with_a_bits(8.0).with_kv_bits(8.0);
    let engine = PjrtEngine::new(exe, &weights, Some(qcfg))?;
    let mut sched = Scheduler::new(engine, N_REQUESTS)?;
    sched.serve_all(workload())?;
    Ok(sched.metrics)
}

fn main() {
    let pjrt_ctx = Manifest::load(std::path::Path::new("artifacts"))
        .ok()
        .and_then(|m| Runtime::cpu().ok().map(|rt| (m, rt)));
    if pjrt_ctx.is_none() {
        eprintln!("no artifacts (run `make artifacts`); benching the mock engine instead");
    }

    let labels: Vec<String> = BATCHES.iter().map(|b| format!("batch_{b}")).collect();
    let mut rows: Vec<(&str, Json)> = Vec::new();
    let mut engines_used: Vec<&str> = Vec::new();
    println!(
        "{:<10} {:>8} {:>10} {:>12} {:>12} {:>12} {:>12}",
        "batch", "engine", "tokens", "tok/s", "p50 ms/tok", "p95", "p99"
    );
    for (i, &batch) in BATCHES.iter().enumerate() {
        let (label, metrics) = match &pjrt_ctx {
            Some((manifest, rt)) => match run_pjrt(manifest, rt, batch) {
                Ok(m) => ("pjrt", m),
                Err(e) => {
                    eprintln!("batch {batch}: PJRT engine unavailable ({e:#}); using mock");
                    ("mock", run_mock(batch).expect("mock engine"))
                }
            },
            None => ("mock", run_mock(batch).expect("mock engine")),
        };
        engines_used.push(label);
        println!(
            "{:<10} {:>8} {:>10} {:>12.1} {:>12.3} {:>12.3} {:>12.3}",
            batch,
            label,
            metrics.tokens_generated,
            metrics.tokens_per_sec(),
            metrics.token_ms_p50(),
            metrics.token_ms_p95(),
            metrics.token_ms_p99()
        );
        let mut row = metrics.to_json();
        if let Json::Obj(m) = &mut row {
            m.insert("engine".to_string(), json::s(label));
            m.insert("batch".to_string(), json::num(batch as f64));
        }
        rows.push((labels[i].as_str(), row));
    }

    // Top-level engine label is only non-"mixed" when every batch size ran
    // on the same engine; per-batch rows always carry their own label.
    let engine_label = match engines_used.first() {
        Some(first) if engines_used.iter().all(|e| e == first) => *first,
        Some(_) => "mixed",
        None => "none",
    };
    let out = json::obj(vec![
        ("bench", json::s("serving")),
        ("model", json::s(MODEL)),
        ("engine", json::s(engine_label)),
        ("requests", json::num(N_REQUESTS as f64)),
        ("max_new_tokens", json::num(MAX_NEW as f64)),
        ("batches", json::obj(rows.iter().map(|(k, v)| (*k, v.clone())).collect())),
    ]);
    let path = std::path::Path::new("BENCH_serving.json");
    match report::write_json(path, &out) {
        Ok(()) => println!("\nwrote {}", path.display()),
        Err(e) => eprintln!("failed to write {}: {e:#}", path.display()),
    }
}
