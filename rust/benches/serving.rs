//! Serving throughput bench: continuous batching at batch sizes {1, 4, 8}.
//!
//! Drives the `serve` scheduler over a fixed synthetic workload and reports
//! tokens/sec + latency percentiles per batch size, leaving a
//! machine-readable trajectory in `BENCH_serving.json` so later PRs can be
//! compared against this one. A second sweep compares time-to-first-token
//! on 64-token prompts between the batched prefill path (T = 16, so 4
//! engine calls to first token) and the legacy token-by-token loop (64
//! calls) — the `ttft` object in the JSON.
//!
//! Two more sections:
//!
//! * `paged` — paged (block-pool) vs dense KV cache at an *equal memory
//!   budget* on a mixed-length trace. Resident KV bytes for a pool are
//!   per packed page: `blocks x 2 (K,V) x n_layers x
//!   (ceil(block_size x n_heads x d_head x kv_bits / 8) + per-group
//!   scale metadata)` (`serve::blocks::kv_memory_bytes`); the dense
//!   comparator gets the same token budget as `budget_tokens / max_seq`
//!   full slots. Token-budget admission sustains several times the
//!   concurrent requests (the `concurrency_x` field; the acceptance bar
//!   is >= 2x) with bit-identical generations — checked request by
//!   request, enforced by the sim harness in CI.
//! * `kv_quant` — quantized KV page storage (`--kv-bits`) measured two
//!   ways. Capacity: the same uniform 2-page workload served at an equal
//!   page-*byte* budget (540 KiB of sq-2m-shaped pages = 16 fp16 pages
//!   vs 60 int4 pages), mean in-flight sampled while the backlog
//!   persists; the `concurrency_multiple` acceptance bar is >= 3.5x for
//!   int4 vs fp16. Quality: pinned greedy traces replayed at kv 16/8/4
//!   bits against the pre-PR fp engine — 16-bit is asserted
//!   byte-identical, int8 is asserted token-identical (its accumulated
//!   round-trip error stays under half the mock's guaranteed logit gap),
//!   and the int4 token-match fraction is recorded. Resident page bytes
//!   are *measured* from the pools (`MockEngine::resident_kv_bytes`) and
//!   cross-checked against the accounting formula exactly.
//! * `prefix_cache` — the shared-system-prompt sweep: N users whose
//!   prompts repeat one system prefix, served over the same paged pool
//!   with the refcounted copy-on-write prefix cache on vs off. Records
//!   reused prompt tokens / hit rate / prefill calls, TTFT for cache-warm
//!   requests, admitted concurrency at the identical page budget, and a
//!   hard `bit_identical` completions check (the cache must only remove
//!   recomputation).
//! * `decode_stall` — the step-composer sweep: one 512-token prompt joins
//!   7 active decodes, at `--step-budget` {off, 16, 32, 64}. Records the
//!   worst decode stall (off: the full ceil(512/64) = 8-call burst; any
//!   budget: 0 — asserted), the resulting inter-token p99, the newcomer's
//!   TTFT plus its queue/spread split, and a hard bit-identical check
//!   (the budget reshapes the schedule, never the bytes).
//! * `sampler` — per-draw top-k / top-p cost before (full vocabulary sort,
//!   the pre-PR implementation, inlined here as the baseline) and after
//!   (partial selection via `select_nth_unstable_by`).
//! * `fault_recovery` — the chaos sweep: the identical seeded workload
//!   served through the `FaultInjector` at fault rates {0, 0.01, 0.05}
//!   (pinned schedule seed). Every leg audits the pool/slot bookkeeping
//!   invariants after every step, every surviving request (anything not
//!   quarantined) is asserted byte-identical to the fault-free leg — the
//!   error kernel may reshape the schedule, never the bytes — and the
//!   JSON records goodput (successfully delivered tokens per engine
//!   step) vs fault rate plus the fault/retry/recovery/quarantine
//!   counter set.
//! * `spec_decode` — the speculative-decoding sweep: a pinned repetitive
//!   greedy trace (the mock's greedy stream is exactly 128-periodic, so
//!   the prompt-lookup drafter locks on after one cycle) served on one
//!   lane at `--spec-k` {0, 2, 4, 8}. Records accept rate and tokens per
//!   engine call per K (the acceptance bar is > 1.5x at K = 4), asserts
//!   every speculative leg byte-identical to the K = 0 leg and the max
//!   decode stall no worse, and adds one engine-drafter leg (a second
//!   same-fidelity mock rung, so greedy acceptance must be 100% — the
//!   drafter rung's own calls are free here and are not counted).
//! * `serving_load` — the open-loop RPS sweep over the *real* HTTP/SSE
//!   front on loopback: at each fixed offered-RPS point a seeded Poisson
//!   schedule (mixed prompt/output lengths, 1/(rank+1) tenant skew) drives
//!   `POST /generate` streams against a MockEngine scheduler behind a
//!   shed watermark, recording goodput, TTFT p50/p99 (charged from the
//!   *scheduled* arrival — no coordinated omission) and inter-token p99.
//!   Quick mode shrinks the arrival window, never the point list or key
//!   set (the CI jq schema pins both). A `byte_identical` leg asserts
//!   that completions streamed through the front equal the same requests
//!   run directly through `Scheduler::serve_all`.
//! * `trace` — the flight recorder audited two ways on the decode-stall
//!   scenario: (1) overhead — the identical leg with tracing off vs on
//!   (ring capacity 2^20), mean step latency side by side, plus a
//!   bit-identical completions check (recording must never reshape the
//!   schedule; with the sink off the emission sites are one enum branch,
//!   so the off numbers are the real hot path, not an instrumented one);
//!   (2) fidelity — the traced leg's per-request timelines are folded
//!   back and cross-checked against `ServingMetrics`
//!   (`serve::verify_against_metrics`: TTFT = queue + spread per request,
//!   stall histogram identical — asserted, exported as
//!   `spans_match_metrics`), and the raw ring is exported as a Chrome
//!   trace-event / Perfetto timeline in `BENCH_decode_stall_trace.json`.
//!
//! Engine selection: the PJRT engine is used when `make artifacts` has run
//! (batch 1 via `decode_nohad`, batch N via `decode_nohad_b{N}`, prefill
//! via `prefill_nohad_b{N}_t16`); otherwise the deterministic mock engine
//! benches the scheduler itself, so this target always produces numbers.
//! TTFT rows come in engine-coherent pairs: if either leg of a
//! prefill-vs-loop comparison can't run on PJRT (batch 1 has no prefill
//! artifact; aot emits b{4,8} only), both legs run on the mock. The paged
//! and sampler sections always run on the mock/CPU so CI can track them
//! (`SPINQUANT_BENCH_QUICK=1` shrinks every section for the CI quick pass).
//!
//! Run: cargo bench --bench serving

use spinquant::bench::bench;
use spinquant::eval::QcfgVec;
use spinquant::model::{Manifest, Weights};
use spinquant::report;
use spinquant::runtime::Runtime;
use spinquant::serve::http::blocking_request;
use spinquant::serve::{
    blocks, chrome_trace, run_open_loop, verify_against_metrics, DecodeVariant, FaultInjector,
    FinishReason, GenRequest, HttpFront, HttpFrontConfig, LoadGenConfig, MockEngine, PjrtEngine,
    Sampler, Scheduler, ServingMetrics, SpecDraft, TraceRecord,
};
use spinquant::util::json::{self, Json};
use spinquant::util::prng::Prng;

const BATCHES: [usize; 3] = [1, 4, 8];
const MODEL: &str = "sq-2m";
const N_REQUESTS: usize = 32;
const MAX_NEW: usize = 24;
// TTFT sweep: long prompts where prompt ingestion dominates latency.
const TTFT_PROMPT_LEN: usize = 64;
const TTFT_CHUNK: usize = 16;
const TTFT_REQUESTS: usize = 16;
const TTFT_MAX_NEW: usize = 8;
// Paged sweep: sq-2m-shaped cache, a 2-dense-slot memory budget, 8 lanes.
const PAGED_MAX_SEQ: usize = 128;
const PAGED_BLOCK_SIZE: usize = 16;
const PAGED_BUDGET_SLOTS: usize = 2; // dense slots the budget equals
const PAGED_LANES: usize = 8;
const PAGED_REQUESTS: usize = 48;

/// CI quick mode: reduced request counts / iterations, same JSON shape.
fn quick() -> bool {
    std::env::var("SPINQUANT_BENCH_QUICK").map_or(false, |v| !v.is_empty() && v != "0")
}

fn scaled(n: usize) -> usize {
    if quick() {
        (n / 4).max(4)
    } else {
        n
    }
}

/// The fixed workload: byte prompts of varying length, seeded top-k
/// sampling so every engine sees the same request stream.
fn workload() -> Vec<GenRequest> {
    (0..scaled(N_REQUESTS))
        .map(|i| {
            let len = 4 + (i % 6);
            let prompt: Vec<u8> = (0..len).map(|j| (32 + ((i * 17 + j * 5) % 90)) as u8).collect();
            GenRequest::sampled(&prompt, MAX_NEW, Sampler::top_k(8, 0.8), 1000 + i as u64)
        })
        .collect()
}

fn run_mock(batch: usize) -> anyhow::Result<ServingMetrics> {
    let engine = MockEngine::new(batch, 128, 256);
    let mut sched = Scheduler::new(engine, N_REQUESTS)?;
    sched.serve_all(workload())?;
    Ok(sched.metrics)
}

fn run_pjrt(manifest: &Manifest, rt: &Runtime, batch: usize) -> anyhow::Result<ServingMetrics> {
    let artifact = DecodeVariant::QuantNoHad.artifact_batched(batch);
    let exe = rt.load(manifest, MODEL, &artifact)?;
    let weights = Weights::load(&manifest.weights_path(MODEL))?;
    // W-quant is offline; serve the raw weights at A8/KV8 like the Table 6
    // harness — the bench measures serving throughput, not quality.
    let qcfg = QcfgVec::fp().with_a_bits(8.0).with_kv_bits(8.0);
    let engine = PjrtEngine::new(exe, &weights, Some(qcfg))?;
    let mut sched = Scheduler::new(engine, N_REQUESTS)?;
    sched.serve_all(workload())?;
    Ok(sched.metrics)
}

// -- TTFT: batched prefill vs the token-by-token loop -----------------------

/// Long-prompt workload: TTFT is dominated by prompt ingestion here.
fn ttft_workload() -> Vec<GenRequest> {
    (0..scaled(TTFT_REQUESTS))
        .map(|i| {
            let prompt: Vec<u8> = (0..TTFT_PROMPT_LEN)
                .map(|j| (32 + ((i * 13 + j * 7) % 90)) as u8)
                .collect();
            GenRequest::sampled(&prompt, TTFT_MAX_NEW, Sampler::top_k(8, 0.8), 2000 + i as u64)
        })
        .collect()
}

/// `chunk > 1`: the batched prefill path; `chunk == 1`: the token loop.
fn run_mock_ttft(batch: usize, chunk: usize) -> anyhow::Result<ServingMetrics> {
    let engine = MockEngine::new(batch, 128, 256).with_prefill_chunk(chunk);
    let mut sched = Scheduler::new(engine, TTFT_REQUESTS)?;
    sched.serve_all(ttft_workload())?;
    Ok(sched.metrics)
}

fn run_pjrt_ttft(
    manifest: &Manifest,
    rt: &Runtime,
    batch: usize,
    chunk: usize,
) -> anyhow::Result<ServingMetrics> {
    let weights = Weights::load(&manifest.weights_path(MODEL))?;
    let qcfg = QcfgVec::fp().with_a_bits(8.0).with_kv_bits(8.0);
    let exe = rt.load(manifest, MODEL, &DecodeVariant::QuantNoHad.artifact_batched(batch))?;
    let mut engine = PjrtEngine::new(exe, &weights, Some(qcfg))?;
    if chunk > 1 {
        // No artifact (e.g. batch 1) => error; the caller falls back to the
        // mock so the prefill-vs-loop row always exists.
        let pre = rt.load(
            manifest,
            MODEL,
            &DecodeVariant::QuantNoHad.artifact_prefill(batch, chunk),
        )?;
        engine = engine.with_prefill(pre, &weights, Some(qcfg))?;
    }
    let mut sched = Scheduler::new(engine, TTFT_REQUESTS)?;
    sched.serve_all(ttft_workload())?;
    Ok(sched.metrics)
}

/// The TTFT rows come as an engine-coherent `(prefill, token_loop)` pair:
/// the prefill-vs-loop delta is only meaningful when both rows ran on the
/// same engine, so if either PJRT leg is unavailable (no artifacts, no
/// prefill graph for this batch, or batch 1 which has none) the whole pair
/// runs on the mock.
fn ttft_pair(
    pjrt_ctx: &Option<(Manifest, Runtime)>,
    batch: usize,
) -> (&'static str, ServingMetrics, ServingMetrics) {
    if batch > 1 {
        if let Some((manifest, rt)) = pjrt_ctx {
            match run_pjrt_ttft(manifest, rt, batch, TTFT_CHUNK)
                .and_then(|pre| run_pjrt_ttft(manifest, rt, batch, 1).map(|lp| (pre, lp)))
            {
                Ok((pre, lp)) => return ("pjrt", pre, lp),
                Err(e) => eprintln!(
                    "ttft batch {batch}: PJRT pair unavailable ({e:#}); using mock for both"
                ),
            }
        }
    }
    (
        "mock",
        run_mock_ttft(batch, TTFT_CHUNK).expect("mock engine"),
        run_mock_ttft(batch, 1).expect("mock engine"),
    )
}

// -- paged vs dense at an equal KV-memory budget -----------------------------

/// Mixed-length trace: short chats to medium completions, 1..=4 pages per
/// request, seeded samplers so the paged and dense runs are comparable
/// request by request.
fn paged_workload() -> Vec<GenRequest> {
    (0..scaled(PAGED_REQUESTS))
        .map(|i| {
            let len = 4 + (i * 5) % 25; // 4..=28 prompt tokens
            let prompt: Vec<u8> = (0..len).map(|j| (32 + ((i * 11 + j * 3) % 90)) as u8).collect();
            let max_new = 6 + (i * 7) % 17; // 6..=22 generated tokens
            GenRequest::sampled(&prompt, max_new, Sampler::top_k(8, 0.8), 3000 + i as u64)
        })
        .collect()
}

struct PagedLeg {
    label: &'static str,
    slots: usize,
    metrics: ServingMetrics,
    completions: Vec<(u64, Vec<u8>)>,
}

fn run_paged_leg(label: &'static str, paged: bool) -> PagedLeg {
    let budget_blocks = PAGED_BUDGET_SLOTS * PAGED_MAX_SEQ / PAGED_BLOCK_SIZE;
    let vocab = 256;
    let (slots, engine) = if paged {
        (
            PAGED_LANES,
            MockEngine::new(PAGED_LANES, PAGED_MAX_SEQ, vocab)
                .with_block_pool(budget_blocks, PAGED_BLOCK_SIZE),
        )
    } else {
        // Same memory: budget_tokens / max_seq full dense slots.
        (PAGED_BUDGET_SLOTS, MockEngine::new(PAGED_BUDGET_SLOTS, PAGED_MAX_SEQ, vocab))
    };
    let mut sched = Scheduler::new(engine, scaled(PAGED_REQUESTS)).expect("scheduler");
    let done = sched.serve_all(paged_workload()).expect("serve");
    let mut completions: Vec<(u64, Vec<u8>)> =
        done.into_iter().map(|c| (c.id, c.completion)).collect();
    completions.sort();
    PagedLeg { label, slots, metrics: sched.metrics, completions }
}

fn paged_sweep() -> Json {
    let budget_blocks = PAGED_BUDGET_SLOTS * PAGED_MAX_SEQ / PAGED_BLOCK_SIZE;
    let budget_tokens = budget_blocks * PAGED_BLOCK_SIZE;
    let dense = run_paged_leg("dense", false);
    let paged = run_paged_leg("paged", true);
    let bit_identical = dense.completions == paged.completions;
    let ratio = paged.metrics.mean_in_flight() / dense.metrics.mean_in_flight().max(1e-9);
    println!();
    println!(
        "paged vs dense at {} KV tokens ({} pages x {}): sq-2m fp16 KV = {} bytes resident \
         (quantized pages: see kv_quant)",
        budget_tokens,
        budget_blocks,
        PAGED_BLOCK_SIZE,
        blocks::kv_memory_bytes(budget_blocks, PAGED_BLOCK_SIZE, 4, 4, 32, 16.0, true)
    );
    println!(
        "{:<8} {:>6} {:>10} {:>14} {:>10} {:>10} {:>10}",
        "path", "slots", "requests", "mean in-flight", "steps", "tok/s", "evicted"
    );
    for leg in [&dense, &paged] {
        println!(
            "{:<8} {:>6} {:>10} {:>14.2} {:>10} {:>10.1} {:>10}",
            leg.label,
            leg.slots,
            leg.metrics.requests_completed,
            leg.metrics.mean_in_flight(),
            leg.metrics.step_us.len(),
            leg.metrics.tokens_per_sec(),
            leg.metrics.requests_evicted,
        );
    }
    println!(
        "concurrency {ratio:.2}x at equal memory; completions bit-identical: {bit_identical}"
    );
    let leg_json = |leg: &PagedLeg| {
        json::obj(vec![
            ("slots", json::num(leg.slots as f64)),
            ("requests", json::num(leg.metrics.requests_completed as f64)),
            ("mean_in_flight", json::num(leg.metrics.mean_in_flight())),
            ("steps", json::num(leg.metrics.step_us.len() as f64)),
            ("tokens_per_sec", json::num(leg.metrics.tokens_per_sec())),
            ("evictions", json::num(leg.metrics.requests_evicted as f64)),
            ("token_ms_p50", json::num(leg.metrics.token_ms_p50())),
        ])
    };
    json::obj(vec![
        (
            "config",
            json::obj(vec![
                ("max_seq", json::num(PAGED_MAX_SEQ as f64)),
                ("block_size", json::num(PAGED_BLOCK_SIZE as f64)),
                ("budget_blocks", json::num(budget_blocks as f64)),
                ("budget_tokens", json::num(budget_tokens as f64)),
                ("requests", json::num(scaled(PAGED_REQUESTS) as f64)),
                // Resident KV bytes at this budget for the sq-2m shape
                // (L=4, H=4, dh=32), full precision. Quantized-page
                // figures live in `kv_quant`, *measured* from real pools.
                (
                    "kv_bytes_fp16",
                    json::num(blocks::kv_memory_bytes(
                        budget_blocks,
                        PAGED_BLOCK_SIZE,
                        4,
                        4,
                        32,
                        16.0,
                        true,
                    ) as f64),
                ),
            ]),
        ),
        ("dense", leg_json(&dense)),
        ("paged", leg_json(&paged)),
        ("concurrency_x", json::num(ratio)),
        ("bit_identical", Json::Bool(bit_identical)),
    ])
}

// -- kv_quant: quantized KV page storage, capacity + drift -------------------

const KVQ_BLOCK_SIZE: usize = 16;
const KVQ_MAX_SEQ: usize = 128;
// Equal page-BYTE budget for the capacity legs: 540 KiB of sq-2m-shaped
// KV pages = exactly 60 int4 pages (9216 B each), 31 int8 pages
// (17408 B), or 16 fp16 pages (32768 B).
const KVQ_BUDGET_BYTES: usize = 552_960;
const KVQ_LANES: usize = 32;
// Not `scaled()`: the concurrency ratio needs a persistent backlog, and
// the mock serves 96 tiny requests in well under a second.
const KVQ_REQUESTS: usize = 96;
const KVQ_PROMPT: usize = 26;
const KVQ_MAX_NEW: usize = 6; // 26 + 6 = 32 tokens = exactly 2 pages
// Drift legs: pinned greedy traces, long enough that int4's accumulated
// round-trip error visibly crosses the mock's guaranteed logit gap while
// int8's provably cannot.
const KVQ_DRIFT_REQUESTS: usize = 6;
const KVQ_DRIFT_PROMPT: usize = 12;
const KVQ_DRIFT_MAX_NEW: usize = 80;

/// Bytes of one sq-2m-shaped KV page (L=4, H=4, dh=32) at `bits`.
fn kvq_page_bytes(bits: f64) -> usize {
    blocks::kv_memory_bytes(1, KVQ_BLOCK_SIZE, 4, 4, 32, bits, true)
}

struct KvLeg {
    completions: Vec<(u64, Vec<u8>)>,
    peak_resident: usize,
    mean_in_flight: f64,
}

/// Submit everything up front, then step to completion, sampling
/// `in_flight` while the backlog persists (fewer than `window` requests
/// done) and tracking the pool's peak measured resident KV bytes.
fn run_kv_leg(engine: MockEngine, reqs: Vec<GenRequest>, window: usize) -> KvLeg {
    let n = reqs.len();
    let mut sched = Scheduler::new(engine, n).expect("scheduler");
    for r in reqs {
        sched.submit(r).expect("submit");
    }
    let mut completions: Vec<(u64, Vec<u8>)> = Vec::with_capacity(n);
    let mut peak_resident = 0usize;
    let mut samples: Vec<usize> = Vec::new();
    while !sched.is_idle() {
        let done = sched.step().expect("step");
        completions.extend(done.into_iter().map(|c| (c.id, c.completion)));
        peak_resident = peak_resident.max(sched.engine().resident_kv_bytes());
        if completions.len() < window {
            samples.push(sched.in_flight());
        }
    }
    completions.sort();
    let mean_in_flight = if samples.is_empty() {
        0.0
    } else {
        samples.iter().sum::<usize>() as f64 / samples.len() as f64
    };
    KvLeg { completions, peak_resident, mean_in_flight }
}

/// Uniform 2-page requests: capacity is then purely pages-per-request.
fn kvq_capacity_workload() -> Vec<GenRequest> {
    (0..KVQ_REQUESTS)
        .map(|i| {
            let prompt: Vec<u8> =
                (0..KVQ_PROMPT).map(|j| (32 + ((i * 19 + j * 3) % 90)) as u8).collect();
            GenRequest::greedy(&prompt, KVQ_MAX_NEW)
        })
        .collect()
}

/// Pinned prompts for the greedy drift comparison.
fn kvq_drift_workload() -> Vec<GenRequest> {
    (0..KVQ_DRIFT_REQUESTS)
        .map(|i| {
            let prompt: Vec<u8> =
                (0..KVQ_DRIFT_PROMPT).map(|j| (40 + ((i * 7 + j * 11) % 80)) as u8).collect();
            GenRequest::greedy(&prompt, KVQ_DRIFT_MAX_NEW)
        })
        .collect()
}

/// Ample identical pool for every drift leg; `None` keeps the engine's
/// default construction — the pre-PR fp paged path the 16-bit leg must
/// reproduce byte for byte.
fn kvq_drift_engine(kv_bits: Option<f32>) -> MockEngine {
    let pool = KVQ_DRIFT_REQUESTS
        * (KVQ_DRIFT_PROMPT + KVQ_DRIFT_MAX_NEW).div_ceil(KVQ_BLOCK_SIZE);
    let mut e = MockEngine::new(KVQ_DRIFT_REQUESTS, KVQ_MAX_SEQ, 256)
        .with_block_pool(pool, KVQ_BLOCK_SIZE);
    if let Some(b) = kv_bits {
        e = e.with_kv_bits(b);
    }
    e
}

/// Fraction of generated bytes that agree position by position.
fn token_match(a: &[(u64, Vec<u8>)], b: &[(u64, Vec<u8>)]) -> f64 {
    let mut matched = 0usize;
    let mut total = 0usize;
    for ((ia, ta), (ib, tb)) in a.iter().zip(b) {
        assert_eq!(ia, ib, "drift legs must complete the same request ids");
        total += ta.len().max(tb.len());
        matched += ta.iter().zip(tb).filter(|(x, y)| x == y).count();
    }
    matched as f64 / total.max(1) as f64
}

fn kv_quant_sweep() -> Json {
    // Capacity: the pool each width affords at the same byte budget.
    let blocks_at = |bits: f64| KVQ_BUDGET_BYTES / kvq_page_bytes(bits);
    let capacity_leg = |bits: f32| {
        let engine = MockEngine::new(KVQ_LANES, KVQ_MAX_SEQ, 256)
            .with_block_pool(blocks_at(bits as f64), KVQ_BLOCK_SIZE)
            .with_kv_bits(bits);
        run_kv_leg(engine, kvq_capacity_workload(), KVQ_REQUESTS / 2)
    };
    let cap16 = capacity_leg(16.0);
    let cap8 = capacity_leg(8.0);
    let cap4 = capacity_leg(4.0);
    let multiple = cap4.mean_in_flight / cap16.mean_in_flight.max(1e-9);
    println!();
    println!(
        "kv_quant: {} uniform 2-page requests at a {} KiB page-byte budget ({} lanes)",
        KVQ_REQUESTS,
        KVQ_BUDGET_BYTES / 1024,
        KVQ_LANES
    );
    println!(
        "{:<8} {:>12} {:>8} {:>16} {:>20}",
        "kv bits", "page bytes", "pages", "mean in-flight", "peak resident"
    );
    for (bits, leg) in [(16.0, &cap16), (8.0, &cap8), (4.0, &cap4)] {
        println!(
            "{:<8} {:>12} {:>8} {:>16.2} {:>20}",
            bits,
            kvq_page_bytes(bits),
            blocks_at(bits),
            leg.mean_in_flight,
            leg.peak_resident
        );
    }
    println!("int4 concurrency multiple vs fp16 at equal bytes: {multiple:.2}x (bar: 3.5x)");
    assert!(
        multiple >= 3.5,
        "int4 must sustain >= 3.5x fp16 in-flight at an equal page-byte budget, \
         got {multiple:.2}x"
    );

    // Quality on pinned greedy traces: the pre-PR engine construction (fp)
    // vs explicit kv 16/8/4-bit page storage over the identical pool.
    let fp = run_kv_leg(kvq_drift_engine(None), kvq_drift_workload(), 0);
    let kv16 = run_kv_leg(kvq_drift_engine(Some(16.0)), kvq_drift_workload(), 0);
    let kv8 = run_kv_leg(kvq_drift_engine(Some(8.0)), kvq_drift_workload(), 0);
    let kv4 = run_kv_leg(kvq_drift_engine(Some(4.0)), kvq_drift_workload(), 0);
    let bit_identical_16 = kv16.completions == fp.completions;
    assert!(bit_identical_16, "16-bit KV pages must match the pre-PR paged path byte for byte");
    let int8_match = token_match(&kv8.completions, &fp.completions);
    let int4_match = token_match(&kv4.completions, &fp.completions);
    // Int8's accumulated round-trip error keeps every logit within half
    // the mock's guaranteed greedy gap, so this is an identity — not a
    // tolerance.
    assert!(int8_match == 1.0, "int8 KV must stay greedy-identical to fp, got {int8_match:.4}");
    println!(
        "drift on {} pinned {}-token greedy traces: kv16 bit-identical {}, \
         int8 token match {:.4}, int4 token match {:.4}",
        KVQ_DRIFT_REQUESTS, KVQ_DRIFT_MAX_NEW, bit_identical_16, int8_match, int4_match
    );

    // Measured resident bytes must match the accounting formula exactly:
    // every leg walks the identical token trajectory, so the measured
    // peaks relate as the per-page formula bytes do (cross-multiplied to
    // stay integral).
    assert_eq!(kv16.peak_resident, fp.peak_resident);
    for (bits, leg) in [(8.0, &kv8), (4.0, &kv4)] {
        assert_eq!(
            fp.peak_resident * kvq_page_bytes(bits),
            leg.peak_resident * kvq_page_bytes(16.0),
            "measured fp16/int{bits} resident ratio must equal kv_memory_bytes"
        );
    }

    let cap_json = |bits: f64, leg: &KvLeg| {
        json::obj(vec![
            ("pool_blocks", json::num(blocks_at(bits) as f64)),
            ("page_bytes", json::num(kvq_page_bytes(bits) as f64)),
            ("pool_bytes", json::num((blocks_at(bits) * kvq_page_bytes(bits)) as f64)),
            ("mean_in_flight", json::num(leg.mean_in_flight)),
        ])
    };
    json::obj(vec![
        (
            "config",
            json::obj(vec![
                ("block_size", json::num(KVQ_BLOCK_SIZE as f64)),
                ("budget_bytes", json::num(KVQ_BUDGET_BYTES as f64)),
                ("lanes", json::num(KVQ_LANES as f64)),
                ("requests", json::num(KVQ_REQUESTS as f64)),
                ("prompt_len", json::num(KVQ_PROMPT as f64)),
                ("max_new_tokens", json::num(KVQ_MAX_NEW as f64)),
                ("drift_requests", json::num(KVQ_DRIFT_REQUESTS as f64)),
                ("drift_max_new", json::num(KVQ_DRIFT_MAX_NEW as f64)),
            ]),
        ),
        ("fp16", cap_json(16.0, &cap16)),
        ("int8", cap_json(8.0, &cap8)),
        ("int4", cap_json(4.0, &cap4)),
        ("concurrency_multiple", json::num(multiple)),
        ("bit_identical_16", Json::Bool(bit_identical_16)),
        ("int8_token_match", json::num(int8_match)),
        ("int4_token_match", json::num(int4_match)),
        ("peak_resident_fp16", json::num(fp.peak_resident as f64)),
        ("peak_resident_int8", json::num(kv8.peak_resident as f64)),
        ("peak_resident_int4", json::num(kv4.peak_resident as f64)),
        ("resident_matches_formula", Json::Bool(true)),
    ])
}

// -- prefix cache: N users x one shared system prompt ------------------------

const PREFIX_MAX_SEQ: usize = 128;
const PREFIX_BLOCK_SIZE: usize = 16;
const PREFIX_LANES: usize = 8;
const PREFIX_POOL: usize = 20; // pages: tight enough that admission staggers
const PREFIX_REQUESTS: usize = 24;
const PREFIX_SHARED: usize = 32; // shared system-prompt tokens (2 full pages)
const PREFIX_SUFFIX: usize = 8; // per-user tail
const PREFIX_MAX_NEW: usize = 16;

/// N users, one system prompt: identical 32-token prefix, 8 unique tokens.
fn prefix_workload() -> Vec<GenRequest> {
    (0..scaled(PREFIX_REQUESTS))
        .map(|i| {
            let mut p: Vec<u8> = (0..PREFIX_SHARED).map(|j| (32 + (j * 7) % 90) as u8).collect();
            p.extend((0..PREFIX_SUFFIX).map(|j| (32 + ((i * 13 + j * 5) % 90)) as u8));
            GenRequest::sampled(&p, PREFIX_MAX_NEW, Sampler::top_k(8, 0.8), 4000 + i as u64)
        })
        .collect()
}

struct PrefixLeg {
    metrics: ServingMetrics,
    completions: Vec<(u64, Vec<u8>)>,
}

fn run_prefix_leg(cache_on: bool) -> PrefixLeg {
    let engine = MockEngine::new(PREFIX_LANES, PREFIX_MAX_SEQ, 256)
        .with_block_pool(PREFIX_POOL, PREFIX_BLOCK_SIZE)
        .with_prefill_chunk(PREFIX_BLOCK_SIZE);
    let mut sched = Scheduler::new(engine, scaled(PREFIX_REQUESTS)).expect("scheduler");
    if cache_on {
        sched = sched.with_prefix_cache().expect("paged engine");
    }
    let done = sched.serve_all(prefix_workload()).expect("serve");
    let mut completions: Vec<(u64, Vec<u8>)> =
        done.into_iter().map(|c| (c.id, c.completion)).collect();
    completions.sort();
    PrefixLeg { metrics: sched.metrics, completions }
}

fn prefix_sweep() -> Json {
    let off = run_prefix_leg(false);
    let on = run_prefix_leg(true);
    let bit_identical = off.completions == on.completions;
    let reuse_x = on.metrics.tokens_reused as f64 / PREFIX_SHARED as f64;
    let concurrency_x =
        on.metrics.mean_in_flight() / off.metrics.mean_in_flight().max(1e-9);
    println!();
    println!(
        "prefix cache: {} users x {}-token shared prompt (+{} unique), {} pages x {} tokens",
        scaled(PREFIX_REQUESTS),
        PREFIX_SHARED,
        PREFIX_SUFFIX,
        PREFIX_POOL,
        PREFIX_BLOCK_SIZE
    );
    println!(
        "{:<8} {:>12} {:>10} {:>14} {:>14} {:>14} {:>10}",
        "cache",
        "reused toks",
        "hit rate",
        "prefill calls",
        "ttft p50 ms",
        "mean in-flight",
        "evicted"
    );
    for (label, leg) in [("off", &off), ("on", &on)] {
        println!(
            "{:<8} {:>12} {:>10.3} {:>14} {:>14.3} {:>14.2} {:>10}",
            label,
            leg.metrics.tokens_reused,
            leg.metrics.prefix_hit_rate(),
            leg.metrics.prefill_us.len(),
            leg.metrics.ttft_ms_p50(),
            leg.metrics.mean_in_flight(),
            leg.metrics.requests_evicted,
        );
    }
    println!(
        "shared pages reused {reuse_x:.1}x; concurrency {concurrency_x:.2}x at the same \
         page budget; completions bit-identical: {bit_identical}"
    );
    // Deterministic mock + seeded samplers: byte-divergence here is a real
    // correctness bug, not noise — fail the bench loudly (after printing
    // the table above for diagnosis).
    assert!(bit_identical, "prefix cache changed generated bytes");
    let leg_json = |leg: &PrefixLeg| {
        json::obj(vec![
            ("requests", json::num(leg.metrics.requests_completed as f64)),
            ("tokens_reused", json::num(leg.metrics.tokens_reused as f64)),
            ("prefix_hits", json::num(leg.metrics.prefix_hits as f64)),
            ("prefix_hit_rate", json::num(leg.metrics.prefix_hit_rate())),
            ("prefill_calls", json::num(leg.metrics.prefill_us.len() as f64)),
            ("ttft_ms_p50", json::num(leg.metrics.ttft_ms_p50())),
            ("ttft_ms_p95", json::num(leg.metrics.ttft_ms_p95())),
            ("mean_in_flight", json::num(leg.metrics.mean_in_flight())),
            ("evictions", json::num(leg.metrics.requests_evicted as f64)),
            ("tokens_per_sec", json::num(leg.metrics.tokens_per_sec())),
        ])
    };
    json::obj(vec![
        (
            "config",
            json::obj(vec![
                ("max_seq", json::num(PREFIX_MAX_SEQ as f64)),
                ("block_size", json::num(PREFIX_BLOCK_SIZE as f64)),
                ("lanes", json::num(PREFIX_LANES as f64)),
                ("pool_blocks", json::num(PREFIX_POOL as f64)),
                ("requests", json::num(scaled(PREFIX_REQUESTS) as f64)),
                ("shared_tokens", json::num(PREFIX_SHARED as f64)),
                ("suffix_tokens", json::num(PREFIX_SUFFIX as f64)),
                ("max_new_tokens", json::num(PREFIX_MAX_NEW as f64)),
            ]),
        ),
        ("off", leg_json(&off)),
        ("on", leg_json(&on)),
        ("reuse_x", json::num(reuse_x)),
        ("concurrency_x", json::num(concurrency_x)),
        ("bit_identical", Json::Bool(bit_identical)),
    ])
}

// -- decode stall: one long prompt joining a full decode batch ---------------

const STALL_LANES: usize = 8;
const STALL_MAX_SEQ: usize = 1024;
const STALL_CHUNK: usize = 64;
const STALL_PROMPT: usize = 512; // the newcomer: 8 chunk-64 prefill calls
const STALL_DECODERS: usize = 7;
const STALL_DECODER_NEW: usize = 32;
const STALL_NEWCOMER_NEW: usize = 16;
const STALL_BUDGETS: [usize; 4] = [0, 16, 32, 64];

struct StallLeg {
    metrics: ServingMetrics,
    newcomer_ttft_ms: f64,
    completions: Vec<(u64, Vec<u8>)>,
    steps: usize,
    prefill_calls: usize,
    trace_records: Vec<TraceRecord>,
    trace_dropped: u64,
}

/// 7 active decodes, then one 512-token prompt joins. `budget == 0` is the
/// drain-prefill-then-decode baseline (the newcomer's whole prompt stalls
/// every decoder for ceil(512/64) = 8 consecutive calls); `budget > 0`
/// composes each step, so the decoders never stall — at the price of a
/// slower (more spread-out) newcomer prefill. Both honest numbers land in
/// the JSON. `trace_capacity > 0` turns the flight recorder on (the
/// `trace` section compares this leg against the untraced one).
fn run_stall_leg(budget: usize, trace_capacity: usize) -> StallLeg {
    let engine =
        MockEngine::new(STALL_LANES, STALL_MAX_SEQ, 256).with_prefill_chunk(STALL_CHUNK);
    let mut sched = Scheduler::new(engine, 64).expect("scheduler");
    if budget > 0 {
        sched = sched.with_step_budget(budget).expect("prefill engine");
    }
    if trace_capacity > 0 {
        sched = sched.with_trace(trace_capacity);
    }
    for i in 0..STALL_DECODERS {
        let prompt: Vec<u8> = (0..4).map(|j| (40 + i * 7 + j * 3) as u8).collect();
        sched
            .submit(GenRequest::sampled(
                &prompt,
                STALL_DECODER_NEW,
                Sampler::top_k(8, 0.8),
                5000 + i as u64,
            ))
            .expect("submit");
    }
    // Warm up until all 7 are decoding (one chunk each covers a 4-token
    // prompt; the budgeted legs may need a few more steps).
    for _ in 0..64 {
        if sched.metrics.tokens_generated >= STALL_DECODERS {
            break;
        }
        sched.step().expect("step");
    }
    assert_eq!(sched.in_flight(), STALL_DECODERS, "warmup must leave 7 decoders running");
    let prompt: Vec<u8> = (0..STALL_PROMPT).map(|j| (32 + (j * 11) % 90) as u8).collect();
    let newcomer = sched
        .submit(GenRequest::sampled(
            &prompt,
            STALL_NEWCOMER_NEW,
            Sampler::top_k(8, 0.8),
            6000,
        ))
        .expect("submit");
    let done = sched.run().expect("run");
    let newcomer_ttft_ms =
        done.iter().find(|c| c.id == newcomer).and_then(|c| c.ttft_ms).unwrap_or(f64::NAN);
    let mut completions: Vec<(u64, Vec<u8>)> =
        done.into_iter().map(|c| (c.id, c.completion)).collect();
    completions.sort();
    StallLeg {
        newcomer_ttft_ms,
        completions,
        steps: sched.engine().steps,
        prefill_calls: sched.engine().prefill_calls,
        trace_records: sched.trace_records(),
        trace_dropped: sched.trace_dropped_events(),
        metrics: sched.metrics,
    }
}

fn decode_stall_sweep() -> Json {
    println!();
    println!(
        "decode stall: one {STALL_PROMPT}-token prompt joins {STALL_DECODERS} active decodes \
         (chunk {STALL_CHUNK}; budget 0 = composer off)"
    );
    println!(
        "{:<10} {:>12} {:>16} {:>14} {:>12} {:>12} {:>12}",
        "budget", "max stall", "inter-tok p99 ms", "newcomer ttft", "mixed", "steps", "prefill"
    );
    let legs: Vec<(usize, StallLeg)> =
        STALL_BUDGETS.iter().map(|&b| (b, run_stall_leg(b, 0))).collect();
    for (budget, leg) in &legs {
        println!(
            "{:<10} {:>12} {:>16.3} {:>14.3} {:>12} {:>12} {:>12}",
            if *budget == 0 { "off".to_string() } else { budget.to_string() },
            leg.metrics.max_decode_stall_steps(),
            leg.metrics.inter_token_ms_p99(),
            leg.newcomer_ttft_ms,
            leg.metrics.mixed_steps,
            leg.steps,
            leg.prefill_calls,
        );
    }
    let off = &legs[0].1;
    // Deterministic acceptance: the composer removes the stall entirely
    // (the off leg shows the full ceil(512/64) = 8-call burst), and the
    // schedule change never changes a generated byte.
    assert_eq!(off.metrics.max_decode_stall_steps(), 8, "off leg must show the full burst");
    let bit_identical = legs.iter().all(|(_, l)| l.completions == off.completions);
    assert!(bit_identical, "step budget changed generated bytes");
    for (budget, leg) in &legs[1..] {
        assert_eq!(
            leg.metrics.max_decode_stall_steps(),
            0,
            "budget {budget}: decode priority must leave no stall"
        );
    }
    let leg_json = |leg: &StallLeg| {
        json::obj(vec![
            ("max_decode_stall_steps", json::num(leg.metrics.max_decode_stall_steps() as f64)),
            ("inter_token_ms_p99", json::num(leg.metrics.inter_token_ms_p99())),
            ("newcomer_ttft_ms", json::num(leg.newcomer_ttft_ms)),
            ("queue_ms_p50", json::num(leg.metrics.queue_ms_p50())),
            ("prefill_spread_ms_p50", json::num(leg.metrics.prefill_spread_ms_p50())),
            ("mean_prefill_share", json::num(leg.metrics.mean_prefill_share())),
            ("mixed_steps", json::num(leg.metrics.mixed_steps as f64)),
            ("steps", json::num(leg.steps as f64)),
            ("prefill_calls", json::num(leg.prefill_calls as f64)),
            ("tokens_per_sec", json::num(leg.metrics.tokens_per_sec())),
        ])
    };
    let mut out: Vec<(String, Json)> = vec![(
        "config".to_string(),
        json::obj(vec![
            ("lanes", json::num(STALL_LANES as f64)),
            ("prompt_len", json::num(STALL_PROMPT as f64)),
            ("chunk", json::num(STALL_CHUNK as f64)),
            ("decoders", json::num(STALL_DECODERS as f64)),
            ("decoder_max_new", json::num(STALL_DECODER_NEW as f64)),
            ("newcomer_max_new", json::num(STALL_NEWCOMER_NEW as f64)),
        ]),
    )];
    for (budget, leg) in &legs {
        out.push((format!("budget_{budget}"), leg_json(leg)));
    }
    out.push(("bit_identical".to_string(), Json::Bool(bit_identical)));
    json::obj(out.iter().map(|(k, v)| (k.as_str(), v.clone())).collect())
}

// -- flight recorder: overhead when off, fidelity when on --------------------

const TRACE_RING: usize = 1 << 20;

/// The decode-stall off leg, untraced vs traced. The off leg re-runs here
/// (instead of reusing the sweep above) so both step-latency numbers come
/// from adjacent runs of identical work on the same machine state.
fn trace_sweep() -> Json {
    let off = run_stall_leg(0, 0);
    let on = run_stall_leg(0, TRACE_RING);
    let bit_identical = off.completions == on.completions;
    assert!(bit_identical, "tracing changed generated bytes");
    assert!(off.trace_records.is_empty(), "untraced leg must record nothing");
    assert_eq!(on.trace_dropped, 0, "2^20-event ring must hold the whole stall leg");
    // Fold the recorded timelines back and hold them to the aggregate
    // metrics: per-request TTFT = queue wait + prefill spread, identical
    // stall histogram, token / completion / eviction / reuse counts.
    let spans = verify_against_metrics(&on.trace_records, &on.metrics);
    if let Err(e) = &spans {
        eprintln!("trace verification failed: {e}");
    }
    assert!(spans.is_ok(), "trace timelines must agree with ServingMetrics");
    let chrome = chrome_trace(&on.trace_records, on.trace_dropped);
    let n_events = match &chrome {
        Json::Obj(m) => match m.get("traceEvents") {
            Some(Json::Arr(a)) => a.len(),
            _ => 0,
        },
        _ => 0,
    };
    assert!(n_events > 0, "chrome export must carry events");
    let trace_path = std::path::Path::new("BENCH_decode_stall_trace.json");
    if let Err(e) = report::write_json(trace_path, &chrome) {
        eprintln!("failed to write {}: {e:#}", trace_path.display());
    }
    let off_step = off.metrics.step_us.mean_us();
    let on_step = on.metrics.step_us.mean_us();
    println!();
    println!(
        "flight recorder (decode-stall leg): step {off_step:.3} us untraced vs \
         {on_step:.3} us traced; {} events recorded, {} dropped; timelines agree \
         with metrics; wrote {}",
        on.trace_records.len(),
        on.trace_dropped,
        trace_path.display()
    );
    json::obj(vec![
        ("ring_capacity", json::num(TRACE_RING as f64)),
        ("off_step_us_mean", json::num(off_step)),
        ("on_step_us_mean", json::num(on_step)),
        ("overhead_x", json::num(on_step / off_step.max(1e-9))),
        ("events", json::num(on.trace_records.len() as f64)),
        ("dropped_events", json::num(on.trace_dropped as f64)),
        ("chrome_events", json::num(n_events as f64)),
        ("spans_match_metrics", Json::Bool(spans.is_ok())),
        ("bit_identical", Json::Bool(bit_identical)),
        ("chrome_trace", json::s("BENCH_decode_stall_trace.json")),
    ])
}

// -- fault_recovery: chaos sweep over the error-kernel step loop -------------

const FAULT_RATES: [f64; 3] = [0.0, 0.01, 0.05];
const FAULT_SEED: u64 = 0xC405;
const FAULT_LANES: usize = 4;
const FAULT_MAX_SEQ: usize = 128;
const FAULT_POOL: usize = 48; // pages x 8 tokens: tight enough to page
const FAULT_BLOCK: usize = 8;
const FAULT_CHUNK: usize = 8;
const FAULT_REQUESTS: usize = 32;
const FAULT_MAX_NEW: usize = 16;

/// Seeded mixed-length workload, identical across every fault rate: the
/// clean leg is the byte-identity reference for the faulty survivors.
fn fault_workload() -> Vec<GenRequest> {
    (0..scaled(FAULT_REQUESTS))
        .map(|i| {
            let len = 4 + (i * 3) % 12;
            let prompt: Vec<u8> =
                (0..len).map(|j| (32 + ((i * 23 + j * 7) % 90)) as u8).collect();
            GenRequest::sampled(&prompt, FAULT_MAX_NEW, Sampler::top_k(8, 0.8), 7000 + i as u64)
        })
        .collect()
}

struct FaultLeg {
    completions: std::collections::BTreeMap<u64, (Vec<u8>, FinishReason)>,
    steps: usize,
    metrics: ServingMetrics,
}

/// One chaos leg: the paged + chunked-prefill scheduler driven to drain
/// through a seeded `FaultInjector` at `rate`, auditing the full
/// bookkeeping invariants (`free + Σ(refcount > 0) == total`, slot and
/// position accounting) after every single step.
fn run_fault_leg(rate: f64) -> FaultLeg {
    let n = scaled(FAULT_REQUESTS);
    let engine = MockEngine::new(FAULT_LANES, FAULT_MAX_SEQ, 256)
        .with_block_pool(FAULT_POOL, FAULT_BLOCK)
        .with_prefill_chunk(FAULT_CHUNK);
    let injector = FaultInjector::new(engine, FAULT_SEED, rate);
    let mut sched = Scheduler::new(injector, n).expect("scheduler");
    for r in fault_workload() {
        sched.submit(r).expect("submit");
    }
    let mut completions = std::collections::BTreeMap::new();
    while !sched.is_idle() {
        for c in sched.step().expect("step must survive injected faults") {
            let dup = completions.insert(c.id, (c.completion, c.reason)).is_some();
            assert!(!dup, "request {} terminated twice at fault rate {rate}", c.id);
        }
        sched.check_invariants().expect("bookkeeping invariants under faults");
    }
    let steps = sched.engine().inner().steps;
    FaultLeg { completions, steps, metrics: sched.metrics }
}

fn fault_recovery_sweep() -> Json {
    let n = scaled(FAULT_REQUESTS);
    let legs: Vec<(f64, FaultLeg)> =
        FAULT_RATES.iter().map(|&r| (r, run_fault_leg(r))).collect();
    let clean = &legs[0].1;
    assert_eq!(clean.completions.len(), n, "clean leg must finish every request");
    assert_eq!(
        clean.metrics.step_faults + clean.metrics.slot_faults,
        0,
        "rate-0 injector must never fire"
    );
    println!();
    println!(
        "fault_recovery: {n} requests through the seeded FaultInjector (seed {FAULT_SEED:#x}, \
         {FAULT_LANES} lanes, {FAULT_POOL} pages x {FAULT_BLOCK})"
    );
    println!(
        "{:<8} {:>8} {:>12} {:>12} {:>10} {:>12} {:>10} {:>14}",
        "rate", "steps", "step faults", "slot faults", "retries", "quarantined", "ok", "goodput t/s"
    );
    let mut rows: Vec<(String, Json)> = vec![(
        "config".to_string(),
        json::obj(vec![
            ("seed", json::num(FAULT_SEED as f64)),
            ("lanes", json::num(FAULT_LANES as f64)),
            ("pool_blocks", json::num(FAULT_POOL as f64)),
            ("block_size", json::num(FAULT_BLOCK as f64)),
            ("prefill_chunk", json::num(FAULT_CHUNK as f64)),
            ("requests", json::num(n as f64)),
            ("max_new_tokens", json::num(FAULT_MAX_NEW as f64)),
        ]),
    )];
    for (rate, leg) in &legs {
        // Liveness: every request terminates exactly once, fault rate or
        // not — recovered, quarantined, but never lost or duplicated.
        assert_eq!(leg.completions.len(), n, "rate {rate}: a request was lost");
        // The error kernel may reshape the schedule (retries, evictions,
        // warm restarts) but never the bytes: every survivor must match
        // the fault-free leg exactly.
        let mut ok_tokens = 0usize;
        let mut quarantined = 0usize;
        let mut survivors_bit_identical = true;
        for (id, (bytes, reason)) in &leg.completions {
            if matches!(reason, FinishReason::Quarantined | FinishReason::DeadlineExpired) {
                quarantined += 1;
                continue;
            }
            ok_tokens += bytes.len();
            let (clean_bytes, _) = &clean.completions[id];
            if bytes != clean_bytes {
                survivors_bit_identical = false;
            }
        }
        assert!(
            survivors_bit_identical,
            "rate {rate}: a surviving request diverged from the fault-free run"
        );
        let goodput = ok_tokens as f64 / (leg.steps as f64).max(1.0);
        println!(
            "{:<8} {:>8} {:>12} {:>12} {:>10} {:>12} {:>10} {:>14.3}",
            rate,
            leg.steps,
            leg.metrics.step_faults,
            leg.metrics.slot_faults,
            leg.metrics.retries_scheduled,
            quarantined,
            n - quarantined,
            goodput,
        );
        let key = format!("rate_{}", format!("{rate}").replace('.', "_"));
        rows.push((
            key,
            json::obj(vec![
                ("rate", json::num(*rate)),
                ("steps", json::num(leg.steps as f64)),
                ("step_faults", json::num(leg.metrics.step_faults as f64)),
                ("slot_faults", json::num(leg.metrics.slot_faults as f64)),
                ("retries_scheduled", json::num(leg.metrics.retries_scheduled as f64)),
                ("slots_recovered", json::num(leg.metrics.slots_recovered as f64)),
                ("requests_quarantined", json::num(leg.metrics.requests_quarantined as f64)),
                ("requests_fault_evicted", json::num(leg.metrics.requests_fault_evicted as f64)),
                ("completed_ok", json::num((n - quarantined) as f64)),
                ("goodput_tokens_per_step", json::num(goodput)),
                ("survivors_bit_identical", Json::Bool(survivors_bit_identical)),
            ]),
        ));
    }
    json::obj(rows.iter().map(|(k, v)| (k.as_str(), v.clone())).collect())
}

// -- speculative decoding: draft cheap, verify once --------------------------

const SPEC_MAX_SEQ: usize = 512;
const SPEC_MAX_NEW: usize = 360; // nearly three full 128-token greedy cycles
const SPEC_REQUESTS: usize = 3;
const SPEC_KS: [usize; 4] = [0, 2, 4, 8];

/// The pinned repetitive trace: greedy requests on the mock engine, whose
/// greedy continuation is *exactly* 128-periodic — so once the first cycle
/// has been generated, the prompt-lookup drafter proposes from history and
/// is right every time. Short distinct prompts keep the streams distinct.
fn spec_workload() -> Vec<GenRequest> {
    (0..SPEC_REQUESTS)
        .map(|i| {
            let prompt: Vec<u8> = (0..2 + i).map(|j| (97 + ((i * 5 + j) % 26)) as u8).collect();
            GenRequest::greedy(&prompt, SPEC_MAX_NEW)
        })
        .collect()
}

struct SpecLeg {
    completions: std::collections::BTreeMap<u64, Vec<u8>>,
    engine_calls: usize,
    metrics: ServingMetrics,
}

/// One leg of the sweep on a single lane (so tokens-per-engine-call is the
/// speculation multiplier itself, not diluted by batching). `k == 0` is the
/// plain decode loop; otherwise drafting comes from prompt lookup or, with
/// `engine_drafter`, a second same-shape mock rung (whose own calls are not
/// counted — a real drafter rung sits lower on the quantization ladder and
/// is priced separately).
fn run_spec_leg(k: usize, engine_drafter: bool) -> SpecLeg {
    let engine = MockEngine::new(1, SPEC_MAX_SEQ, 64);
    let mut sched = Scheduler::new(engine, SPEC_REQUESTS).expect("scheduler");
    if k > 0 {
        let draft = if engine_drafter {
            SpecDraft::Engine(Box::new(MockEngine::new(1, SPEC_MAX_SEQ, 64)))
        } else {
            SpecDraft::NGram
        };
        sched = sched.with_speculation(k, draft).expect("speculation config");
    }
    for r in spec_workload() {
        sched.submit(r).expect("submit");
    }
    let mut completions = std::collections::BTreeMap::new();
    while !sched.is_idle() {
        for c in sched.step().expect("step") {
            assert_eq!(c.reason, FinishReason::BudgetExhausted, "request {} cut short", c.id);
            let dup = completions.insert(c.id, c.completion).is_some();
            assert!(!dup, "request {} terminated twice at spec-k {k}", c.id);
        }
        sched.check_invariants().expect("bookkeeping invariants under speculation");
    }
    let e = sched.engine();
    let engine_calls = e.steps + e.prefill_calls + e.verify_calls;
    SpecLeg { completions, engine_calls, metrics: sched.metrics }
}

fn spec_leg_json(leg: &SpecLeg, tok_per_call: f64) -> Json {
    json::obj(vec![
        ("engine_calls", json::num(leg.engine_calls as f64)),
        ("tokens_generated", json::num(leg.metrics.tokens_generated as f64)),
        ("tokens_per_engine_call", json::num(tok_per_call)),
        ("verify_calls", json::num(leg.metrics.verify_calls as f64)),
        ("draft_tokens_proposed", json::num(leg.metrics.draft_tokens_proposed as f64)),
        ("draft_tokens_accepted", json::num(leg.metrics.draft_tokens_accepted as f64)),
        ("accept_rate", json::num(leg.metrics.accept_rate())),
        ("max_decode_stall_steps", json::num(leg.metrics.max_decode_stall_steps() as f64)),
    ])
}

fn spec_decode_sweep() -> Json {
    println!();
    println!(
        "spec_decode: {SPEC_REQUESTS} greedy requests x {SPEC_MAX_NEW} tokens on one lane \
         (mock greedy stream is 128-periodic, so the prompt-lookup drafter locks on)"
    );
    println!(
        "{:<10} {:>8} {:>10} {:>10} {:>10} {:>12} {:>12}",
        "spec-k", "draft", "calls", "tok/call", "accept", "verify", "max stall"
    );
    let legs: Vec<(usize, SpecLeg)> =
        SPEC_KS.iter().map(|&k| (k, run_spec_leg(k, false))).collect();
    let baseline = &legs[0].1;
    assert_eq!(
        baseline.completions.len(),
        SPEC_REQUESTS,
        "the k = 0 leg must finish every request"
    );
    assert_eq!(baseline.metrics.verify_calls, 0, "--spec-k 0 must never touch the verify path");
    let mut rows: Vec<(String, Json)> = vec![(
        "config".to_string(),
        json::obj(vec![
            ("requests", json::num(SPEC_REQUESTS as f64)),
            ("max_new_tokens", json::num(SPEC_MAX_NEW as f64)),
            ("max_seq", json::num(SPEC_MAX_SEQ as f64)),
            ("lanes", json::num(1.0)),
        ]),
    )];
    let print_row = |k: usize, draft: &str, leg: &SpecLeg, tok_per_call: f64| {
        println!(
            "{:<10} {:>8} {:>10} {:>10.3} {:>10.3} {:>12} {:>12}",
            k,
            draft,
            leg.engine_calls,
            tok_per_call,
            leg.metrics.accept_rate(),
            leg.metrics.verify_calls,
            leg.metrics.max_decode_stall_steps(),
        );
    };
    for (k, leg) in &legs {
        // Speculation reshapes the call schedule, never the bytes: every
        // request must match the plain decode loop exactly, and decode
        // stall must be no worse than running without speculation.
        assert_eq!(
            leg.completions, baseline.completions,
            "spec-k {k}: speculative decoding changed generated bytes"
        );
        assert!(
            leg.metrics.max_decode_stall_steps() <= baseline.metrics.max_decode_stall_steps(),
            "spec-k {k}: speculation must not worsen decode stall"
        );
        let tok_per_call =
            leg.metrics.tokens_generated as f64 / (leg.engine_calls as f64).max(1.0);
        print_row(*k, if *k == 0 { "-" } else { "ngram" }, leg, tok_per_call);
        if *k == 4 {
            // The headline number: once the drafter has one full cycle of
            // history, most verify calls commit several tokens at once.
            assert!(
                tok_per_call > 1.5,
                "spec-k 4 must clear 1.5 tokens per engine call on the repetitive \
                 trace (got {tok_per_call:.3})"
            );
            assert!(
                leg.metrics.accept_rate() > 0.3,
                "spec-k 4: the locked-on drafter must land well over 0.3 accept rate"
            );
        }
        rows.push((format!("k_{k}"), spec_leg_json(leg, tok_per_call)));
    }
    // The ladder rung: a second same-fidelity mock rung drafts, so greedy
    // verification must accept every proposal (identical argmax).
    let rung = run_spec_leg(4, true);
    assert_eq!(
        rung.completions, baseline.completions,
        "engine drafter changed generated bytes"
    );
    assert!(
        (rung.metrics.accept_rate() - 1.0).abs() < 1e-12,
        "a same-parameters drafter rung must be accepted verbatim under greedy"
    );
    assert_eq!(rung.metrics.draft_tokens_accepted, rung.metrics.draft_tokens_proposed);
    let rung_tok_per_call =
        rung.metrics.tokens_generated as f64 / (rung.engine_calls as f64).max(1.0);
    print_row(4, "engine", &rung, rung_tok_per_call);
    rows.push(("engine_drafter_k4".to_string(), spec_leg_json(&rung, rung_tok_per_call)));
    rows.push(("bit_identical".to_string(), Json::Bool(true)));
    json::obj(rows.iter().map(|(k, v)| (k.as_str(), v.clone())).collect())
}

// -- sampler cost: full-sort baseline vs partial selection -------------------

/// The pre-PR sampler: full descending sort of the vocabulary every draw.
/// Kept here as the "before" leg of the satellite perf fix.
fn full_sort_sample(kind: &Sampler, logits: &[f32], rng: &mut Prng) -> usize {
    use spinquant::serve::SamplerKind;
    let mut idx: Vec<usize> = (0..logits.len()).filter(|&i| !logits[i].is_nan()).collect();
    idx.sort_by(|&a, &b| logits[b].total_cmp(&logits[a]));
    let m = logits[idx[0]];
    let mut ws: Vec<f32> =
        idx.iter().map(|&i| ((logits[i] - m) / kind.temperature).exp()).collect();
    match kind.kind {
        SamplerKind::TopK(k) => {
            let k = k.clamp(1, idx.len());
            idx.truncate(k);
            ws.truncate(k);
        }
        SamplerKind::TopP(p) => {
            let total: f32 = ws.iter().sum();
            let target = p.clamp(0.0, 1.0) * total;
            let mut cum = 0.0f32;
            let mut cut = ws.len();
            for (j, &w) in ws.iter().enumerate() {
                cum += w;
                if cum >= target {
                    cut = j + 1;
                    break;
                }
            }
            idx.truncate(cut);
            ws.truncate(cut);
        }
        _ => {}
    }
    let sum: f32 = ws.iter().sum();
    if sum <= 0.0 || !sum.is_finite() {
        return idx[0];
    }
    let mut r = rng.uniform() * sum;
    for (j, &w) in ws.iter().enumerate() {
        if r < w {
            return idx[j];
        }
        r -= w;
    }
    *idx.last().unwrap()
}

fn sampler_cost() -> Json {
    let iters = if quick() { 400 } else { 4000 };
    println!();
    println!("per-draw sampler cost (before = full vocab sort, after = partial selection):");
    let mut rows: Vec<(String, Json)> = Vec::new();
    for vocab in [256usize, 4096] {
        let mut p = Prng::new(0x5a);
        let logits: Vec<f32> = (0..vocab).map(|_| p.normal() * 3.0).collect();
        for (name, s) in [("top_k8", Sampler::top_k(8, 0.8)), ("top_p95", Sampler::top_p(0.95, 0.8))]
        {
            let mut rng = Prng::new(1);
            let before = bench(&format!("{name} v{vocab} full_sort"), 20, iters, || {
                full_sort_sample(&s, &logits, &mut rng)
            });
            let mut rng = Prng::new(1);
            let after = bench(&format!("{name} v{vocab} partial"), 20, iters, || {
                s.sample(&logits, &mut rng)
            });
            println!("{}", before.report());
            println!("{}", after.report());
            rows.push((
                format!("{name}_v{vocab}"),
                json::obj(vec![
                    ("full_sort_us", json::num(before.mean_us)),
                    ("partial_us", json::num(after.mean_us)),
                    ("speedup_x", json::num(before.mean_us / after.mean_us.max(1e-9))),
                ]),
            ));
        }
    }
    json::obj(rows.iter().map(|(k, v)| (k.as_str(), v.clone())).collect())
}

// -- serving_load: open-loop RPS sweep over the real HTTP/SSE front ---------

/// Fixed offered-RPS points. Identical in quick and full mode — the CI jq
/// schema requires every point's keys, so quick mode shrinks the arrival
/// window (`LOAD_WINDOW_SECS`), never this list.
const LOAD_RPS_POINTS: [f64; 3] = [50.0, 150.0, 400.0];
const LOAD_SHED_DEPTH: usize = 32;

fn load_window_secs() -> f64 {
    if quick() { 0.25 } else { 1.5 }
}

fn serving_load_sweep() -> Json {
    let window = load_window_secs();
    println!();
    println!(
        "{:>12} {:>9} {:>9} {:>9} {:>12} {:>12} {:>16}",
        "offered rps", "offered", "done", "shed 429", "goodput", "ttft p99 ms", "intertok p99 ms"
    );
    let mut points = Vec::new();
    for (i, &rps) in LOAD_RPS_POINTS.iter().enumerate() {
        let mut sched = Scheduler::new(MockEngine::new(4, 256, 64), 64).expect("scheduler");
        let mut front = HttpFront::bind(
            "127.0.0.1:0",
            HttpFrontConfig { rate_per_sec: None, burst: 8.0, shed_depth: LOAD_SHED_DEPTH },
        )
        .expect("bind loopback front");
        front.install_token_hook(&mut sched);
        let cfg = LoadGenConfig {
            rps,
            duration_secs: window,
            seed: 4242 + i as u64,
            tenants: 4,
            prompt_len: (8, 24),
            max_new: (4, 12),
            timeout_secs: 20.0,
        };
        let r = run_open_loop(&mut front, &mut sched, &cfg).expect("open-loop run");
        println!(
            "{:>12.0} {:>9} {:>9} {:>9} {:>12.1} {:>12.2} {:>16.3}",
            rps,
            r.offered,
            r.completed,
            r.shed,
            r.goodput_rps,
            r.ttft_us.percentile_us(99.0) / 1e3,
            r.inter_token_us.percentile_us(99.0) / 1e3,
        );
        assert_eq!(r.errors, 0, "loopback load run must not drop requests");
        points.push(r.to_json(rps));
    }
    let byte_identical = load_byte_identity_leg();
    assert!(byte_identical, "front-streamed completions diverged from the direct run");
    json::obj(vec![
        ("window_secs", json::num(window)),
        ("shed_depth", json::num(LOAD_SHED_DEPTH as f64)),
        ("points", json::arr(points)),
        ("byte_identical", Json::Bool(byte_identical)),
    ])
}

/// Stream a fixed request set through the front from worker threads and
/// compare bytes against the identical requests run straight through
/// `Scheduler::serve_all` on a fresh scheduler.
fn load_byte_identity_leg() -> bool {
    let prompts =
        ["alpha alpha alpha", "bravo bravo bravo", "charlie charlie", "delta delta delta"];
    let mut direct = Scheduler::new(MockEngine::new(2, 64, 64), 16).expect("scheduler");
    let baseline = direct
        .serve_all(prompts.iter().enumerate().map(|(i, p)| {
            GenRequest::sampled(p.as_bytes(), 10, Sampler::top_k(4, 0.7), 7 + i as u64)
        }))
        .expect("direct run");

    let mut sched = Scheduler::new(MockEngine::new(2, 64, 64), 16).expect("scheduler");
    let mut front =
        HttpFront::bind("127.0.0.1:0", HttpFrontConfig::default()).expect("bind front");
    front.install_token_hook(&mut sched);
    let addr = front.local_addr().expect("front addr");

    let (tx, rx) = std::sync::mpsc::channel();
    let mut handles = Vec::new();
    for (i, p) in prompts.iter().enumerate() {
        let body = format!(
            "{{\"prompt\":\"{p}\",\"max_new_tokens\":10,\"seed\":{},\
             \"sampler\":\"top-k\",\"top_k\":4,\"temperature\":0.7}}",
            7 + i
        );
        let tx = tx.clone();
        handles.push(std::thread::spawn(move || {
            let out = blocking_request(addr, &body, "bench", std::time::Duration::from_secs(20));
            let _ = tx.send((i, out));
        }));
    }
    drop(tx);
    let mut got: Vec<Option<_>> = (0..prompts.len()).map(|_| None).collect();
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(30);
    let mut resolved = 0;
    while resolved < prompts.len() && std::time::Instant::now() < deadline {
        front.poll(&mut sched).expect("front poll");
        while let Ok((i, out)) = rx.try_recv() {
            got[i] = Some(out);
            resolved += 1;
        }
    }
    for h in handles {
        let _ = h.join();
    }
    while let Ok((i, out)) = rx.try_recv() {
        if got[i].is_none() {
            got[i] = Some(out);
        }
    }
    prompts.iter().enumerate().all(|(i, p)| match &got[i] {
        Some(Ok(o)) if o.status == 200 && o.done.is_some() => {
            let want = baseline
                .iter()
                .find(|c| c.prompt == p.as_bytes())
                .expect("baseline completion");
            o.bytes == want.completion
        }
        _ => false,
    })
}

fn main() {
    let pjrt_ctx = Manifest::load(std::path::Path::new("artifacts"))
        .ok()
        .and_then(|m| Runtime::cpu().ok().map(|rt| (m, rt)));
    if pjrt_ctx.is_none() {
        eprintln!("no artifacts (run `make artifacts`); benching the mock engine instead");
    }

    let labels: Vec<String> = BATCHES.iter().map(|b| format!("batch_{b}")).collect();
    let mut rows: Vec<(&str, Json)> = Vec::new();
    let mut engines_used: Vec<&str> = Vec::new();
    println!(
        "{:<10} {:>8} {:>10} {:>12} {:>12} {:>12} {:>12}",
        "batch", "engine", "tokens", "tok/s", "p50 ms/tok", "p95", "p99"
    );
    for (i, &batch) in BATCHES.iter().enumerate() {
        let (label, metrics) = match &pjrt_ctx {
            Some((manifest, rt)) => match run_pjrt(manifest, rt, batch) {
                Ok(m) => ("pjrt", m),
                Err(e) => {
                    eprintln!("batch {batch}: PJRT engine unavailable ({e:#}); using mock");
                    ("mock", run_mock(batch).expect("mock engine"))
                }
            },
            None => ("mock", run_mock(batch).expect("mock engine")),
        };
        engines_used.push(label);
        println!(
            "{:<10} {:>8} {:>10} {:>12.1} {:>12.3} {:>12.3} {:>12.3}",
            batch,
            label,
            metrics.tokens_generated,
            metrics.tokens_per_sec(),
            metrics.token_ms_p50(),
            metrics.token_ms_p95(),
            metrics.token_ms_p99()
        );
        let mut row = metrics.to_json();
        if let Json::Obj(m) = &mut row {
            m.insert("engine".to_string(), json::s(label));
            m.insert("batch".to_string(), json::num(batch as f64));
        }
        rows.push((labels[i].as_str(), row));
    }

    // TTFT: prefill path vs token loop on 64-token prompts.
    println!();
    println!(
        "{:<10} {:>10} {:>8} {:>14} {:>14} {:>14}",
        "batch", "path", "engine", "ttft p50 ms", "ttft p95 ms", "prefill calls"
    );
    let mut ttft_rows: Vec<(String, Json)> = Vec::new();
    for &batch in BATCHES.iter() {
        let mut entry: Vec<(&str, Json)> = Vec::new();
        let (label, m_pre, m_loop) = ttft_pair(&pjrt_ctx, batch);
        for (path, chunk, m) in
            [("prefill", TTFT_CHUNK, &m_pre), ("token_loop", 1, &m_loop)]
        {
            println!(
                "{:<10} {:>10} {:>8} {:>14.3} {:>14.3} {:>14}",
                batch,
                path,
                label,
                m.ttft_ms_p50(),
                m.ttft_ms_p95(),
                m.prefill_us.len()
            );
            entry.push((
                path,
                json::obj(vec![
                    ("engine", json::s(label)),
                    ("chunk", json::num(chunk as f64)),
                    ("ttft_ms_p50", json::num(m.ttft_ms_p50())),
                    ("ttft_ms_p95", json::num(m.ttft_ms_p95())),
                    ("prefill_calls", json::num(m.prefill_us.len() as f64)),
                    ("tokens_prefilled", json::num(m.tokens_prefilled as f64)),
                    ("tokens_per_sec", json::num(m.tokens_per_sec())),
                ]),
            ));
        }
        ttft_rows.push((format!("batch_{batch}"), json::obj(entry)));
    }

    // Top-level engine label is only non-"mixed" when every batch size ran
    // on the same engine; per-batch rows always carry their own label.
    let engine_label = match engines_used.first() {
        Some(first) if engines_used.iter().all(|e| e == first) => *first,
        Some(_) => "mixed",
        None => "none",
    };
    let paged = paged_sweep();
    let kv_quant = kv_quant_sweep();
    let prefix_cache = prefix_sweep();
    let decode_stall = decode_stall_sweep();
    let trace = trace_sweep();
    let fault_recovery = fault_recovery_sweep();
    let spec_decode = spec_decode_sweep();
    let sampler = sampler_cost();
    let serving_load = serving_load_sweep();

    let out = json::obj(vec![
        ("bench", json::s("serving")),
        ("model", json::s(MODEL)),
        ("engine", json::s(engine_label)),
        ("quick", Json::Bool(quick())),
        ("requests", json::num(scaled(N_REQUESTS) as f64)),
        ("max_new_tokens", json::num(MAX_NEW as f64)),
        ("batches", json::obj(rows.iter().map(|(k, v)| (*k, v.clone())).collect())),
        ("paged", paged),
        ("kv_quant", kv_quant),
        ("prefix_cache", prefix_cache),
        ("decode_stall", decode_stall),
        ("trace", trace),
        ("fault_recovery", fault_recovery),
        ("spec_decode", spec_decode),
        ("sampler", sampler),
        ("serving_load", serving_load),
        (
            "ttft",
            json::obj(
                std::iter::once((
                    "config",
                    json::obj(vec![
                        ("prompt_len", json::num(TTFT_PROMPT_LEN as f64)),
                        ("chunk", json::num(TTFT_CHUNK as f64)),
                        ("requests", json::num(scaled(TTFT_REQUESTS) as f64)),
                        ("max_new_tokens", json::num(TTFT_MAX_NEW as f64)),
                    ]),
                ))
                .chain(ttft_rows.iter().map(|(k, v)| (k.as_str(), v.clone())))
                .collect(),
            ),
        ),
    ]);
    let path = std::path::Path::new("BENCH_serving.json");
    match report::write_json(path, &out) {
        Ok(()) => println!("\nwrote {}", path.display()),
        Err(e) => eprintln!("failed to write {}: {e:#}", path.display()),
    }
}
