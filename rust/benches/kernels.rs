//! Micro-benchmarks of the L3 hot-path substrates (std-only harness;
//! criterion is unavailable offline). Run with `cargo bench`.
//!
//! These are the knobs the §Perf pass in EXPERIMENTS.md iterates on: FWHT
//! (the online-Hadamard cost model for Fig. 7), fake-quant, matmul (rotation
//! merging), GPTQ, and one Cayley retraction.

use spinquant::bench::bench;
use spinquant::hadamard;
use spinquant::linalg;
use spinquant::quant::{fake_quant, Granularity, QuantSpec};
use spinquant::tensor::Tensor;
use spinquant::util::prng::Prng;

fn randn(shape: &[usize], seed: u64) -> Tensor {
    let mut p = Prng::new(seed);
    let n: usize = shape.iter().product();
    Tensor::new(shape.to_vec(), (0..n).map(|_| p.normal()).collect())
}

fn main() {
    println!("== spinquant micro-benchmarks (1 iteration = 1 op) ==");

    // FWHT at the model's R3/R4 sizes.
    for n in [32usize, 128, 512, 1024] {
        let mut x = randn(&[n], 1);
        let r = bench(&format!("fwht_row n={n}"), 50, 2000, || {
            hadamard::fwht_row(&mut x.data);
        });
        println!("{}  ({:.1} Melem/s)", r.report(), r.per_second(n as f64) / 1e6);
    }
    {
        let x = randn(&[512, 512], 2);
        let r = bench("fwht_last_axis 512x512", 3, 60, || hadamard::fwht_last_axis(&x));
        println!("{}", r.report());
    }

    // Fake-quant (per-token) at eval-batch shapes.
    for (rows, d) in [(512usize, 128usize), (512, 512)] {
        let x = randn(&[rows, d], 3);
        let spec = QuantSpec {
            bits: 4.0,
            symmetric: false,
            clip_ratio: 1.0,
            granularity: Granularity::PerRow,
        };
        let r = bench(&format!("fake_quant {rows}x{d} 4b"), 3, 100, || fake_quant(&x, &spec));
        println!("{}  ({:.1} Melem/s)", r.report(), r.per_second((rows * d) as f64) / 1e6);
    }

    // Fake-quant (per-output-channel weight grids): the two-pass row-major
    // column path — no strided gather/scatter copies.
    for (rows, d) in [(512usize, 128usize), (512, 512)] {
        let x = randn(&[rows, d], 3);
        let spec = QuantSpec {
            bits: 4.0,
            symmetric: true,
            clip_ratio: 1.0,
            granularity: Granularity::PerColumn,
        };
        let r = bench(&format!("fake_quant percol {rows}x{d} 4b"), 3, 100, || {
            fake_quant(&x, &spec)
        });
        println!("{}  ({:.1} Melem/s)", r.report(), r.per_second((rows * d) as f64) / 1e6);
    }

    // Matmul at rotation-merge sizes.
    for n in [128usize, 256] {
        let a = randn(&[n, n], 4);
        let b = randn(&[n, n], 5);
        let r = bench(&format!("matmul {n}x{n}"), 3, 50, || linalg::matmul(&a, &b));
        let flops = 2.0 * (n as f64).powi(3);
        println!("{}  ({:.2} GFLOP/s)", r.report(), r.per_second(flops) / 1e9);
    }

    // GPTQ on one layer.
    {
        let k = 256;
        let w = randn(&[k, 128], 6);
        let x = randn(&[512, k], 7);
        let mut acc = spinquant::gptq::HessianAccum::new(k);
        acc.add_batch(&x);
        let r = bench("gptq_quantize 256x128 4b", 1, 8, || {
            spinquant::gptq::gptq_quantize(&w, &acc, 4.0, 0.01).unwrap()
        });
        println!("{}", r.report());
    }

    // One Cayley retraction at R1 size.
    {
        let n = 128;
        let g0 = randn(&[n, n], 8);
        let rot = linalg::qr_orthogonal(&randn(&[n, n], 9));
        let r = bench("cayley step (exact) n=128", 2, 20, || {
            let y = spinquant::cayley::skew_direction(&rot, &g0);
            spinquant::cayley::cayley_step(&rot, &y, 0.05, spinquant::cayley::Solver::Exact)
                .unwrap()
        });
        println!("{}", r.report());
        let r = bench("cayley step (fixed-point 4) n=128", 2, 20, || {
            let y = spinquant::cayley::skew_direction(&rot, &g0);
            spinquant::cayley::cayley_step(&rot, &y, 0.05, spinquant::cayley::Solver::FixedPoint(4))
                .unwrap()
        });
        println!("{}", r.report());
    }
}
