//! Paper table/figure regeneration as a `cargo bench` target.
//!
//! Every exhibit of the paper's evaluation is covered by a harness in
//! `spinquant::benches_impl` (DESIGN.md §7). A full sweep takes hours on
//! this 1-core testbed, so `cargo bench` runs a representative fast set by
//! default; set `SPINQUANT_BENCH_IDS=table1,table2,...` (or `all`) and
//! `SPINQUANT_BENCH_MODELS=sq-2m,sq-4m,sq-9m` for the full reproduction.
//! Results append to EXPERIMENTS.md.

use spinquant::benches_impl::run_bench;
use spinquant::config::PipelineConfig;

const ALL_IDS: &[&str] = &[
    "table1", "table2", "table3", "table4", "table5", "table6", "table10", "table11",
    "table12", "table13", "fig2", "fig4", "fig7", "fig8",
];

fn main() {
    let ids_env = std::env::var("SPINQUANT_BENCH_IDS").unwrap_or_default();
    let ids: Vec<String> = if ids_env == "all" {
        ALL_IDS.iter().map(|s| s.to_string()).collect()
    } else if !ids_env.is_empty() {
        ids_env.split(',').map(str::to_string).collect()
    } else {
        // Fast representative set: distributions + speed + learned-vs-random.
        vec!["fig2".into(), "table6".into(), "fig7".into(), "table5".into()]
    };
    let models: Vec<String> = std::env::var("SPINQUANT_BENCH_MODELS")
        .unwrap_or_else(|_| "sq-2m".into())
        .split(',')
        .map(str::to_string)
        .collect();

    let mut cfg = PipelineConfig::default();
    // Bench-sized eval (override via SPINQUANT_BENCH_FULL=1 for full eval).
    if std::env::var("SPINQUANT_BENCH_FULL").is_err() {
        cfg.eval_windows = Some(24);
        cfg.task_items = 12;
        cfg.cayley_iters = 40;
    }
    let trials: usize = std::env::var("SPINQUANT_BENCH_TRIALS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(8);

    for id in &ids {
        eprintln!("=== bench {id} (models: {models:?}) ===");
        if let Err(e) = run_bench(&cfg, id, &models, trials, Some(".")) {
            eprintln!("bench {id} failed: {e:#}");
            std::process::exit(1);
        }
    }
}
