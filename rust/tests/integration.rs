//! Cross-layer integration tests: rust substrates x AOT artifacts x PJRT.
//!
//! These need `make artifacts` to have run (they are skipped with a notice
//! otherwise, so `cargo test` works in a fresh checkout too).
//!
//! The crown jewels here are the *invariance* tests: the rust-side rotation
//! merge must leave the FP logits of the real lowered artifact unchanged —
//! that single check exercises the L3 merge algebra, the manifest ABI, the
//! literal conversion and the L2 graph together.

use spinquant::config::{Bits, Method, PipelineConfig};
use spinquant::coordinator::Pipeline;
use spinquant::eval::{EvalSession, QcfgVec};
use spinquant::model::Manifest;
use spinquant::rotation::{fold_norm_scales, merge, RotationKind, RotationSet};
use spinquant::runtime::Runtime;
use spinquant::serve;
use spinquant::Tensor;

const MODEL: &str = "sq-2m";

fn setup() -> Option<(Manifest, Runtime)> {
    let dir = std::path::PathBuf::from("artifacts");
    let manifest = match Manifest::load(&dir) {
        Ok(m) => m,
        Err(_) => {
            eprintln!("skipping integration test: run `make artifacts` first");
            return None;
        }
    };
    let rt = Runtime::cpu().expect("PJRT CPU client");
    Some((manifest, rt))
}

fn test_windows(n: usize, seq: usize) -> Vec<Vec<i32>> {
    // Deterministic fake byte windows (any bytes are valid tokens).
    (0..n)
        .map(|i| (0..seq).map(|j| ((i * 31 + j * 7) % 96 + 32) as i32).collect())
        .collect()
}

#[test]
fn manifest_and_weights_agree_with_python() {
    let Some((manifest, _)) = setup() else { return };
    for model in manifest.models() {
        let cfg = manifest.config(&model).unwrap();
        manifest.check_param_order(&cfg).unwrap();
        let w = spinquant::model::Weights::load(&manifest.weights_path(&model)).unwrap();
        w.validate(&cfg).unwrap();
    }
}

#[test]
fn fp_forward_produces_finite_logits() {
    let Some((manifest, rt)) = setup() else { return };
    let exe = rt.load(&manifest, MODEL, "fwd_eval_nohad").unwrap();
    let w = spinquant::model::Weights::load(&manifest.weights_path(MODEL)).unwrap();
    let mut s = EvalSession::new(&exe, &w, Some(QcfgVec::fp())).unwrap();
    let windows = test_windows(s.batch, s.seq);
    let logits = s.logits(&windows).unwrap();
    assert_eq!(logits.shape, vec![s.batch, s.seq, 256]);
    assert!(logits.data.iter().all(|v| v.is_finite()));
}

#[test]
fn rust_rotation_merge_preserves_fp_logits_through_pjrt() {
    // THE invariance check (paper §3.1) through the real artifact.
    let Some((manifest, rt)) = setup() else { return };
    let mcfg = manifest.config(MODEL).unwrap();
    let exe = rt.load(&manifest, MODEL, "fwd_eval_nohad").unwrap();
    let base = spinquant::model::Weights::load(&manifest.weights_path(MODEL)).unwrap();
    let folded = fold_norm_scales(&base, &mcfg).unwrap();
    let windows = test_windows(8, 64);

    let mut s0 = EvalSession::new(&exe, &base, Some(QcfgVec::fp())).unwrap();
    let l_base = s0.logits(&windows).unwrap();
    drop(s0);

    // Folding alone must be exact-ish.
    let mut s1 = EvalSession::new(&exe, &folded, Some(QcfgVec::fp())).unwrap();
    let l_folded = s1.logits(&windows).unwrap();
    drop(s1);
    let fold_err = l_base.sub(&l_folded).max_abs();
    assert!(fold_err < 5e-3, "gamma folding changed logits by {fold_err}");

    // Rotation merge must be invariant too.
    for kind in [RotationKind::RandomHadamard, RotationKind::RandomOrthogonal] {
        let rot = RotationSet::build(&mcfg, kind, 3);
        let merged = merge(&folded, &mcfg, &rot, false).unwrap();
        let mut s2 = EvalSession::new(&exe, &merged, Some(QcfgVec::fp())).unwrap();
        let l_rot = s2.logits(&windows).unwrap();
        let err = l_base.sub(&l_rot).max_abs();
        let scale = l_base.max_abs();
        assert!(
            err < 2e-2 * scale.max(1.0),
            "{kind:?}: rotation broke FP invariance: err {err} (scale {scale})"
        );
    }
}

#[test]
fn online_hadamard_artifact_matches_nohad_in_fp() {
    // fwd_eval_had(H-merged w_down) == fwd_eval_nohad(plain) at FP:
    // R3 cancels inside attention, R4 cancels against the merged H.
    let Some((manifest, rt)) = setup() else { return };
    let mcfg = manifest.config(MODEL).unwrap();
    let base = spinquant::model::Weights::load(&manifest.weights_path(MODEL)).unwrap();
    let folded = fold_norm_scales(&base, &mcfg).unwrap();
    let windows = test_windows(8, 64);

    let exe_no = rt.load(&manifest, MODEL, "fwd_eval_nohad").unwrap();
    let mut s_no = EvalSession::new(&exe_no, &folded, Some(QcfgVec::fp())).unwrap();
    let l_no = s_no.logits(&windows).unwrap();
    drop(s_no);

    let rot = RotationSet::identity(&mcfg);
    let merged_h = merge(&folded, &mcfg, &rot, true).unwrap(); // only the H-merge
    let exe_had = rt.load(&manifest, MODEL, "fwd_eval_had").unwrap();
    let mut s_had = EvalSession::new(&exe_had, &merged_h, Some(QcfgVec::fp())).unwrap();
    let l_had = s_had.logits(&windows).unwrap();

    let err = l_no.sub(&l_had).max_abs();
    assert!(err < 2e-2 * l_no.max_abs().max(1.0), "online Hadamard not invariant: {err}");
}

#[test]
fn rust_quantizer_matches_pallas_kernel_through_pjrt() {
    // Run the task artifact at a_bits=16 vs 4: the in-graph (Pallas-lowered)
    // fake-quant must alter logits; and per-row rust fake_quant of a capture
    // must be idempotent with the kernel's output grid.
    let Some((manifest, rt)) = setup() else { return };
    let w = spinquant::model::Weights::load(&manifest.weights_path(MODEL)).unwrap();
    let exe = rt.load(&manifest, MODEL, "fwd_task_nohad").unwrap();
    let windows = test_windows(16, 32);
    let mut fp = EvalSession::new(&exe, &w, Some(QcfgVec::fp())).unwrap();
    let l16 = fp.logits(&windows).unwrap();
    drop(fp);
    let mut q = EvalSession::new(&exe, &w, Some(QcfgVec::fp().with_a_bits(4.0))).unwrap();
    let l4 = q.logits(&windows).unwrap();
    assert!(l16.sub(&l4).max_abs() > 1e-4, "4-bit activations must perturb logits");
    // And the kv path too.
    drop(q);
    let mut qkv = EvalSession::new(&exe, &w, Some(QcfgVec::fp().with_kv_bits(3.0))).unwrap();
    let lkv = qkv.logits(&windows).unwrap();
    assert!(l16.sub(&lkv).max_abs() > 1e-4, "3-bit KV must perturb logits");
}

#[test]
fn decode_agrees_with_full_forward() {
    // Token-by-token decode with the KV cache must reproduce the full-seq
    // forward logits (same FP weights).
    let Some((manifest, rt)) = setup() else { return };
    let w = spinquant::model::Weights::load(&manifest.weights_path(MODEL)).unwrap();
    let exe_full = rt.load(&manifest, MODEL, "fwd_eval_nohad").unwrap();
    let mut s = EvalSession::new(&exe_full, &w, Some(QcfgVec::fp())).unwrap();
    let prompt: Vec<i32> = b"Alpha beta gamma".iter().map(|&b| b as i32).collect();
    let mut window = prompt.clone();
    window.resize(s.seq, b' ' as i32);
    let full = s.logits(&std::iter::repeat(window.clone()).take(s.batch).collect::<Vec<_>>())
        .unwrap();
    drop(s);

    let exe_dec = rt.load(&manifest, MODEL, "decode_fp").unwrap();
    let mut gen = serve::GenerationSession::new(&exe_dec, &w, None).unwrap();
    let mut last = Vec::new();
    for &t in prompt.iter() {
        last = gen.step(t as u8).unwrap();
    }
    // Compare logits at the last prompt position.
    let pos = prompt.len() - 1;
    let v = 256;
    let full_row = &full.data[pos * v..(pos + 1) * v];
    let mut max_err = 0.0f32;
    for (a, b) in full_row.iter().zip(&last) {
        max_err = max_err.max((a - b).abs());
    }
    assert!(max_err < 2e-3 * full.max_abs().max(1.0), "decode mismatch {max_err}");
}

#[test]
fn batched_decode_engine_matches_single_slot_generation() {
    // Continuous batching through the real artifact. Two claims, checked
    // at the right strictness each:
    //  (a) the batched graph agrees with the B=1 graph on *logits* within
    //      tolerance (separately compiled XLA graphs may reduce in a
    //      different order, so byte-exact token equality would be fragile);
    //  (b) within ONE compiled graph, all four slots — including one that
    //      joins late into a dirty slot — produce byte-identical greedy
    //      completions.
    use spinquant::serve::DecodeEngine as _;

    let Some((manifest, rt)) = setup() else { return };
    let batched = serve::DecodeVariant::Fp.artifact_batched(4);
    let Ok(exe_b) = rt.load(&manifest, MODEL, &batched) else {
        eprintln!("skipping: no {batched} artifact (re-run `make artifacts`)");
        return;
    };
    let w = spinquant::model::Weights::load(&manifest.weights_path(MODEL)).unwrap();
    let prompt = b"Alpha beta";

    // Reference logits at the last prompt position from the B=1 path.
    let exe_1 = rt.load(&manifest, MODEL, "decode_fp").unwrap();
    let mut gen = serve::GenerationSession::new(&exe_1, &w, None).unwrap();
    let mut ref_logits = Vec::new();
    for &t in prompt.iter() {
        ref_logits = gen.step(t).unwrap();
    }
    drop(gen);

    // (a) Drive the batched engine through the same prompt in all slots.
    let mut engine = serve::PjrtEngine::new(exe_b, &w, None).unwrap();
    let mut last = Vec::new();
    for (p, &t) in prompt.iter().enumerate() {
        let toks = [t as i32; 4];
        let pos = [p as i32; 4];
        last = engine.step(&toks, &pos, &[true; 4]).unwrap();
    }
    let scale = ref_logits.iter().fold(0.0f32, |a, &b| a.max(b.abs())).max(1.0);
    for (slot, lane) in last.iter().enumerate() {
        let mut err = 0.0f32;
        for (a, b) in lane.iter().zip(&ref_logits) {
            err = err.max((a - b).abs());
        }
        assert!(err < 2e-3 * scale, "slot {slot} logits drifted {err} from B=1 path");
    }

    // (b) Same engine (caches now dirty), same compiled graph: scheduler
    // runs four greedy requests, one joining mid-flight into a reused
    // slot; every completion must be byte-identical.
    let mut sched = serve::Scheduler::new(engine, 16).unwrap();
    for _ in 0..3 {
        sched.submit(serve::GenRequest::greedy(prompt, 12)).unwrap();
    }
    for _ in 0..4 {
        sched.step().unwrap(); // three slots mid-flight...
    }
    sched.submit(serve::GenRequest::greedy(prompt, 12)).unwrap(); // ...one joins late
    let done = sched.run().unwrap();
    assert_eq!(done.len(), 4);
    for c in &done {
        assert_eq!(c.completion.len(), 12);
        assert_eq!(
            c.completion, done[0].completion,
            "slots diverged within one compiled graph (req {})",
            c.id
        );
    }
    assert!(sched.is_idle());
}

#[test]
fn pjrt_prefill_matches_decode_loop_and_serves_long_prompts() {
    // The batched multi-token prefill artifact against the decode loop:
    //  (a) one prefill call over staggered chunk lengths must reproduce
    //      the logits of feeding the same tokens through the decode
    //      artifact one at a time (the rust twin of the L2 pytest);
    //  (b) a scheduler over the prefill-capable engine must serve a long
    //      prompt in ceil(len/T) prefill calls and keep decoding from the
    //      prefill-written cache (byte-identical greedy completion).
    use spinquant::serve::DecodeEngine as _;

    let Some((manifest, rt)) = setup() else { return };
    let batched = serve::DecodeVariant::Fp.artifact_batched(4);
    let prefill = serve::DecodeVariant::Fp.artifact_prefill(4, 16);
    let (Ok(exe_dec), Ok(exe_pre)) =
        (rt.load(&manifest, MODEL, &batched), rt.load(&manifest, MODEL, &prefill))
    else {
        eprintln!("skipping: no {batched}/{prefill} artifacts (re-run `make artifacts`)");
        return;
    };
    let w = spinquant::model::Weights::load(&manifest.weights_path(MODEL)).unwrap();
    let mut eng_pre = serve::PjrtEngine::new(exe_dec, &w, None)
        .unwrap()
        .with_prefill(exe_pre, &w, None)
        .unwrap();
    assert_eq!(eng_pre.prefill_chunk(), 16);
    let mut eng_loop =
        serve::PjrtEngine::new(rt.load(&manifest, MODEL, &batched).unwrap(), &w, None).unwrap();

    // (a) Staggered chunk lengths in one call (slot 2 inactive).
    let chunks: [&[u8]; 4] = [b"Alpha beta gamma", b"Some words", b"", b"Q: x"];
    let tokens: Vec<Vec<i32>> =
        chunks.iter().map(|c| c.iter().map(|&b| b as i32).collect()).collect();
    let active = [true, true, false, true];
    let la = eng_pre.prefill(&tokens, &[0; 4], &active).unwrap();
    let lb = eng_loop.prefill(&tokens, &[0; 4], &active).unwrap(); // default: decode loop
    for b in [0usize, 1, 3] {
        let scale = lb[b].iter().fold(1.0f32, |a, &v| a.max(v.abs()));
        let mut err = 0.0f32;
        for (x, y) in la[b].iter().zip(&lb[b]) {
            err = err.max((x - y).abs());
        }
        assert!(err < 2e-3 * scale, "slot {b}: prefill drifted {err} from decode loop");
    }

    // (b) Long prompt through the scheduler: 64 tokens at T=16 => 4
    // prefill calls, then ordinary decode; the loop engine must agree.
    let prompt: Vec<u8> = (0..64u8).map(|i| b' ' + (i % 90)).collect();
    let mut sched_pre = serve::Scheduler::new(eng_pre, 8).unwrap();
    sched_pre.submit(serve::GenRequest::greedy(&prompt, 10)).unwrap();
    let done_pre = sched_pre.run().unwrap();
    assert_eq!(sched_pre.metrics.prefill_us.len(), 4, "expected ceil(64/16) prefill calls");
    assert_eq!(sched_pre.metrics.tokens_prefilled, 64);

    let mut sched_loop = serve::Scheduler::new(eng_loop, 8).unwrap();
    sched_loop.submit(serve::GenRequest::greedy(&prompt, 10)).unwrap();
    let done_loop = sched_loop.run().unwrap();
    assert_eq!(done_pre[0].completion.len(), 10);
    assert_eq!(
        done_pre[0].completion, done_loop[0].completion,
        "prefill path changed the greedy completion"
    );
}

#[test]
fn full_rtn_pipeline_beats_nothing_and_spinquant_beats_rtn_on_ppl() {
    // Small-scale end-to-end ordering check (the Table 1 shape):
    // FP <= SpinQuant_no_had <= RTN on perplexity at W4A4.
    let Some((manifest, rt)) = setup() else { return };
    let mut cfg = PipelineConfig::default();
    cfg.model = MODEL.into();
    cfg.bits = Bits::parse("4-4-16").unwrap();
    cfg.use_gptq = false;
    cfg.eval_windows = Some(12);
    cfg.task_items = 4;
    cfg.cayley_iters = 25;

    let run = |method: Method| -> f64 {
        let mut c = cfg.clone();
        c.method = method;
        if method == Method::Float {
            c.bits = Bits::fp();
        }
        let pipe = Pipeline::new(&rt, &manifest, c).unwrap();
        let qm = pipe.quantize().unwrap();
        pipe.evaluate(&qm).unwrap().ppl
    };
    let fp = run(Method::Float);
    let rtn = run(Method::Rtn);
    let spin = run(Method::SpinQuantNoHad);
    assert!(fp < rtn, "fp {fp} should beat rtn {rtn}");
    assert!(
        spin < rtn + 0.05,
        "spinquant ({spin}) should not lose to plain RTN ({rtn}) at W4A4"
    );
}

#[test]
fn quantized_weights_are_on_grid() {
    let Some((manifest, rt)) = setup() else { return };
    let mut cfg = PipelineConfig::default();
    cfg.model = MODEL.into();
    cfg.method = Method::Rtn;
    cfg.bits = Bits::parse("4-8-8").unwrap();
    let pipe = Pipeline::new(&rt, &manifest, cfg).unwrap();
    let qm = pipe.quantize().unwrap();
    // Every linear weight column must have at most 2^4 distinct values.
    let w = qm.weights.get("layers.0.wq").unwrap();
    let (rows, cols) = (w.shape[0], w.shape[1]);
    for c in 0..cols.min(8) {
        let mut vals: Vec<i64> =
            (0..rows).map(|r| (w.data[r * cols + c] * 1e5).round() as i64).collect();
        vals.sort_unstable();
        vals.dedup();
        assert!(vals.len() <= 16, "column {c} has {} levels", vals.len());
    }
    let _ = Tensor::zeros(&[1]);
}
