//! Data plumbing: corpus loading, evaluation chunking, calibration
//! sampling, and the synthetic zero-shot task suites.
//!
//! The corpora (wiki-syn / c4-syn) are generated once at build time by
//! `python/compile/data.py`; this module only *reads* the byte streams —
//! python never runs at evaluation time.
//!
//! ## Zero-shot tasks
//!
//! The paper evaluates on 8 commonsense suites (BoolQ, PIQA, SIQA,
//! HellaSwag, WinoGrande, ARC-e/c, OBQA) scored lm-eval-harness style:
//! each item has one true continuation and distractors, the model picks the
//! choice with the highest length-normalized logprob. We build 8 synthetic
//! suites from the held-out corpus with one corruption family per suite
//! (difficulty varies per family, like the real benchmark spread); the
//! *relative* accuracy of quantization methods tracks logprob fidelity,
//! which is the quantity the paper's tables compare.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::prng::Prng;

/// A byte-level corpus (vocab = 256).
#[derive(Clone, Debug)]
pub struct Corpus {
    pub bytes: Vec<u8>,
    pub name: String,
}

impl Corpus {
    pub fn load(path: &Path) -> Result<Self> {
        let bytes =
            std::fs::read(path).with_context(|| format!("reading corpus {path:?}"))?;
        if bytes.is_empty() {
            bail!("corpus {path:?} is empty");
        }
        let name = path.file_name().unwrap_or_default().to_string_lossy().to_string();
        Ok(Self { bytes, name })
    }

    pub fn from_bytes(name: &str, bytes: Vec<u8>) -> Self {
        Self { bytes, name: name.to_string() }
    }

    pub fn len(&self) -> usize {
        self.bytes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.bytes.is_empty()
    }

    /// Non-overlapping evaluation windows of length `seq` (perplexity eval).
    pub fn eval_windows(&self, seq: usize, limit: Option<usize>) -> Vec<Vec<i32>> {
        let mut out = Vec::new();
        let mut i = 0;
        while i + seq <= self.bytes.len() {
            out.push(self.bytes[i..i + seq].iter().map(|&b| b as i32).collect());
            i += seq;
            if let Some(l) = limit {
                if out.len() >= l {
                    break;
                }
            }
        }
        out
    }

    /// Random calibration windows (rotation learning / GPTQ / QAT).
    pub fn calib_windows(&self, seq: usize, count: usize, seed: u64) -> Vec<Vec<i32>> {
        let mut rng = Prng::new(seed ^ 0xCA11B);
        let max_start = self.bytes.len().saturating_sub(seq + 1);
        (0..count)
            .map(|_| {
                let s = rng.below(max_start.max(1));
                self.bytes[s..s + seq].iter().map(|&b| b as i32).collect()
            })
            .collect()
    }
}

/// One multiple-choice item: a shared context and `n_choices` continuations
/// (choice 0 is always the true one pre-shuffle; `correct` gives its
/// post-shuffle index).
#[derive(Clone, Debug)]
pub struct TaskItem {
    pub context: Vec<i32>,
    pub choices: Vec<Vec<i32>>,
    pub correct: usize,
}

/// A task suite (one corruption family).
#[derive(Clone, Debug)]
pub struct TaskSuite {
    pub name: String,
    pub items: Vec<TaskItem>,
}

/// The 8 corruption families standing in for the paper's 8 benchmarks.
pub const TASK_NAMES: [&str; 8] = [
    "shuffle", "random", "reverse", "elsewhere", "caseflip", "noise", "shift", "crossdom",
];

fn corrupt(
    family: &str,
    truth: &[u8],
    corpus: &Corpus,
    other: Option<&Corpus>,
    rng: &mut Prng,
) -> Vec<u8> {
    let n = truth.len();
    match family {
        "shuffle" => {
            let mut v = truth.to_vec();
            rng.shuffle(&mut v);
            v
        }
        "random" => (0..n).map(|_| (32 + rng.below(95)) as u8).collect(),
        "reverse" => truth.iter().rev().copied().collect(),
        "elsewhere" => {
            let s = rng.below(corpus.len().saturating_sub(n + 1).max(1));
            corpus.bytes[s..s + n].to_vec()
        }
        "caseflip" => truth
            .iter()
            .map(|&b| match b {
                b'a'..=b'z' => b - 32,
                b'A'..=b'Z' => b + 32,
                _ => b,
            })
            .collect(),
        "noise" => truth
            .iter()
            .map(|&b| {
                if rng.uniform() < 0.3 {
                    (32 + rng.below(95)) as u8
                } else {
                    b
                }
            })
            .collect(),
        "shift" => {
            // continuation shifted one byte late — locally plausible text,
            // misaligned with the context.
            let mut v = truth.to_vec();
            v.rotate_right(1);
            v
        }
        "crossdom" => {
            let src = other.unwrap_or(corpus);
            let s = rng.below(src.len().saturating_sub(n + 1).max(1));
            src.bytes[s..s + n].to_vec()
        }
        _ => unreachable!("unknown corruption family {family}"),
    }
}

/// Build all 8 suites from a held-out corpus.
///
/// `ctx_len + choice_len` must fit the task artifact's sequence length.
pub fn build_task_suites(
    corpus: &Corpus,
    other: Option<&Corpus>,
    items_per_suite: usize,
    ctx_len: usize,
    choice_len: usize,
    n_choices: usize,
    seed: u64,
) -> Vec<TaskSuite> {
    let mut suites = Vec::new();
    for (si, family) in TASK_NAMES.iter().enumerate() {
        let mut rng = Prng::new(seed.wrapping_add(si as u64 * 7919));
        let mut items = Vec::new();
        let span = ctx_len + choice_len;
        for _ in 0..items_per_suite {
            let start = rng.below(corpus.len().saturating_sub(span + 1).max(1));
            let context: Vec<i32> =
                corpus.bytes[start..start + ctx_len].iter().map(|&b| b as i32).collect();
            let truth = &corpus.bytes[start + ctx_len..start + span];
            let mut choices: Vec<Vec<i32>> =
                vec![truth.iter().map(|&b| b as i32).collect()];
            while choices.len() < n_choices {
                let c = corrupt(family, truth, corpus, other, &mut rng);
                choices.push(c.iter().map(|&b| b as i32).collect());
            }
            // Shuffle choice order, track the truth.
            let mut order: Vec<usize> = (0..n_choices).collect();
            rng.shuffle(&mut order);
            let correct = order.iter().position(|&o| o == 0).unwrap();
            let choices = order.iter().map(|&o| choices[o].clone()).collect();
            items.push(TaskItem { context, choices, correct });
        }
        suites.push(TaskSuite { name: family.to_string(), items });
    }
    suites
}

#[cfg(test)]
mod tests {
    use super::*;

    fn corpus() -> Corpus {
        let mut p = Prng::new(3);
        let words = ["alpha ", "beta ", "gamma ", "delta. ", "epsilon "];
        let mut s = String::new();
        while s.len() < 20_000 {
            s.push_str(words[p.below(words.len())]);
        }
        Corpus::from_bytes("test", s.into_bytes())
    }

    #[test]
    fn eval_windows_cover_non_overlapping() {
        let c = corpus();
        let w = c.eval_windows(64, None);
        assert_eq!(w.len(), c.len() / 64);
        assert!(w.iter().all(|x| x.len() == 64));
        assert_ne!(w[0], w[1]);
        let limited = c.eval_windows(64, Some(5));
        assert_eq!(limited.len(), 5);
    }

    #[test]
    fn calib_windows_deterministic_per_seed() {
        let c = corpus();
        let a = c.calib_windows(32, 10, 7);
        let b = c.calib_windows(32, 10, 7);
        let d = c.calib_windows(32, 10, 8);
        assert_eq!(a, b);
        assert_ne!(a, d);
    }

    #[test]
    fn token_range_is_byte_range() {
        let c = corpus();
        for w in c.eval_windows(32, Some(20)) {
            assert!(w.iter().all(|&t| (0..256).contains(&t)));
        }
    }

    #[test]
    fn suites_have_all_families_and_valid_items() {
        let c = corpus();
        let suites = build_task_suites(&c, None, 6, 16, 16, 4, 1);
        assert_eq!(suites.len(), 8);
        for s in &suites {
            assert_eq!(s.items.len(), 6);
            for item in &s.items {
                assert_eq!(item.context.len(), 16);
                assert_eq!(item.choices.len(), 4);
                assert!(item.correct < 4);
                assert!(item.choices.iter().all(|c| c.len() == 16));
            }
        }
    }

    #[test]
    fn truth_choice_is_real_continuation() {
        let c = corpus();
        let suites = build_task_suites(&c, None, 4, 16, 16, 4, 2);
        // For the "random" family the distractors are ASCII noise, so the
        // correct choice must differ from all distractors.
        let suite = suites.iter().find(|s| s.name == "random").unwrap();
        for item in &suite.items {
            let truth = &item.choices[item.correct];
            for (i, ch) in item.choices.iter().enumerate() {
                if i != item.correct {
                    assert_ne!(truth, ch);
                }
            }
        }
    }

    #[test]
    fn corruptions_preserve_length() {
        let c = corpus();
        let mut rng = Prng::new(5);
        let truth = &c.bytes[100..116];
        for fam in TASK_NAMES {
            let corrupted = corrupt(fam, truth, &c, None, &mut rng);
            assert_eq!(corrupted.len(), truth.len(), "{fam}");
        }
    }

    #[test]
    fn deterministic_suites() {
        let c = corpus();
        let a = build_task_suites(&c, None, 3, 8, 8, 4, 9);
        let b = build_task_suites(&c, None, 3, 8, 8, 4, 9);
        for (x, y) in a.iter().zip(&b) {
            for (i, j) in x.items.iter().zip(&y.items) {
                assert_eq!(i.context, j.context);
                assert_eq!(i.correct, j.correct);
            }
        }
    }
}
