//! Rotation parameterization (paper §3.1): construction, RMSNorm folding,
//! and the offline merge of R1/R2 (and the R4 H-merge) into model weights.
//!
//! This is the production twin of `python/compile/model.py::
//! _rotate_weights_ingraph` — the python version is differentiable and used
//! by the Cayley grad artifact; this one rewrites the stored weights so the
//! *unmodified* `fwd_*_nohad` / `fwd_*_had` artifacts execute the rotated
//! network (SpinQuant_no_had needs zero inference changes, §4.2).
//!
//! Merge algebra (pre-norm transformer with folded gammas):
//!   emb    <- emb R1            (residual writes rotated)
//!   wq,wk,wv,wgate,wup <- R1^T W   (residual reads unrotated)
//!   wo,wdown           <- W R1     (block outputs rotated back into stream)
//!   head   <- R1^T head
//!   wv     <- wv R2 (per head)   wo <- R2^T wo (per head)
//!   wdown  <- H wdown            (iff the online R4 Hadamard is active)

use anyhow::Result;

use crate::hadamard;
use crate::linalg::{matmul, matmul_tn};
use crate::model::{ModelConfig, Weights};
use crate::tensor::Tensor;
use crate::util::prng::Prng;

/// How to build a rotation matrix.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RotationKind {
    Identity,
    /// Haar-random orthogonal (QR of a Gaussian) — "random FP rotation".
    RandomOrthogonal,
    /// Randomized Hadamard H·diag(±1) — paper footnote 2.
    RandomHadamard,
}

/// The full rotation set for one model: R1 (d_model) + per-layer R2 (d_head).
/// R3/R4 are online Hadamards (never materialized; the `_had` artifacts
/// apply them in-graph, and `merge` folds the R4 inverse into w_down).
#[derive(Clone, Debug)]
pub struct RotationSet {
    pub r1: Tensor,
    pub r2s: Vec<Tensor>,
}

impl RotationSet {
    pub fn identity(cfg: &ModelConfig) -> Self {
        Self {
            r1: Tensor::eye(cfg.d_model),
            r2s: vec![Tensor::eye(cfg.d_head); cfg.n_layers],
        }
    }

    pub fn build(cfg: &ModelConfig, kind: RotationKind, seed: u64) -> Self {
        let make = |n: usize, s: u64| -> Tensor {
            match kind {
                RotationKind::Identity => Tensor::eye(n),
                RotationKind::RandomOrthogonal => {
                    let mut p = Prng::new(s);
                    let g = Tensor::new(
                        vec![n, n],
                        (0..n * n).map(|_| p.normal()).collect(),
                    );
                    crate::linalg::qr_orthogonal(&g)
                }
                RotationKind::RandomHadamard => hadamard::random_hadamard(n, s),
            }
        };
        Self {
            r1: make(cfg.d_model, seed),
            r2s: (0..cfg.n_layers)
                .map(|i| make(cfg.d_head, seed.wrapping_add(1000 + i as u64)))
                .collect(),
        }
    }

    pub fn orthonormality_error(&self) -> f32 {
        let mut e = crate::linalg::orthonormality_error(&self.r1);
        for r2 in &self.r2s {
            e = e.max(crate::linalg::orthonormality_error(r2));
        }
        e
    }
}

/// Fold RMSNorm gammas into the following linears (paper footnote 3).
/// After folding every `*_norm` weight is all-ones and the network is
/// rotation-invariant. Mirrors python `fold_norm_scales`.
pub fn fold_norm_scales(w: &Weights, cfg: &ModelConfig) -> Result<Weights> {
    let mut out = w.clone();
    let scale_rows = |t: &Tensor, g: &Tensor| -> Tensor {
        // t: (d, n), g: (d,) -> diag(g) @ t
        let (d, n) = (t.shape[0], t.shape[1]);
        let mut r = t.clone();
        for i in 0..d {
            let gi = g.data[i];
            for j in 0..n {
                r.data[i * n + j] *= gi;
            }
        }
        r
    };
    for i in 0..cfg.n_layers {
        let p = format!("layers.{i}.");
        let g_att = w.get(&format!("{p}attn_norm"))?.clone();
        for name in ["wq", "wk", "wv"] {
            let t = scale_rows(w.get(&format!("{p}{name}"))?, &g_att);
            out.set(&format!("{p}{name}"), t);
        }
        out.set(&format!("{p}attn_norm"), Tensor::ones(&[cfg.d_model]));
        let g_ffn = w.get(&format!("{p}ffn_norm"))?.clone();
        for name in ["wgate", "wup"] {
            let t = scale_rows(w.get(&format!("{p}{name}"))?, &g_ffn);
            out.set(&format!("{p}{name}"), t);
        }
        out.set(&format!("{p}ffn_norm"), Tensor::ones(&[cfg.d_model]));
    }
    let g_final = w.get("final_norm")?.clone();
    out.set("head", scale_rows(w.get("head")?, &g_final));
    out.set("final_norm", Tensor::ones(&[cfg.d_model]));
    Ok(out)
}

/// Apply R2 to w_v's output, per head: wv (d, H*dh) -> wv · blockdiag(R2).
fn rotate_wv(wv: &Tensor, r2: &Tensor, n_heads: usize, d_head: usize) -> Tensor {
    let d = wv.shape[0];
    let mut out = Tensor::zeros(&[d, n_heads * d_head]);
    for row in 0..d {
        for h in 0..n_heads {
            let base = h * d_head;
            for j in 0..d_head {
                let mut s = 0.0f32;
                for k in 0..d_head {
                    s += wv.data[row * n_heads * d_head + base + k] * r2.data[k * d_head + j];
                }
                out.data[row * n_heads * d_head + base + j] = s;
            }
        }
    }
    out
}

/// Apply R2^T to w_o's input, per head: wo (H*dh, d) -> blockdiag(R2)^T · wo.
fn rotate_wo(wo: &Tensor, r2: &Tensor, n_heads: usize, d_head: usize) -> Tensor {
    let d = wo.shape[1];
    let mut out = Tensor::zeros(&[n_heads * d_head, d]);
    for h in 0..n_heads {
        let base = h * d_head;
        for j in 0..d_head {
            for col in 0..d {
                let mut s = 0.0f32;
                for k in 0..d_head {
                    // (R2^T)[j,k] = R2[k,j]
                    s += r2.data[k * d_head + j] * wo.data[(base + k) * d + col];
                }
                out.data[(base + j) * d + col] = s;
            }
        }
    }
    out
}

/// Merge the rotation set into the weights (requires folded norms).
/// `merge_r4`: additionally left-multiply every w_down by H (use with the
/// `_had` artifacts, which apply the online R4 to the activation).
pub fn merge(w: &Weights, cfg: &ModelConfig, rot: &RotationSet, merge_r4: bool) -> Result<Weights> {
    let mut out = w.clone();
    let r1 = &rot.r1;
    out.set("emb", matmul(w.get("emb")?, r1));
    out.set("head", matmul_tn(r1, w.get("head")?));
    for i in 0..cfg.n_layers {
        let p = format!("layers.{i}.");
        let r2 = &rot.r2s[i];
        for name in ["wq", "wk", "wgate", "wup"] {
            let t = matmul_tn(r1, w.get(&format!("{p}{name}"))?);
            out.set(&format!("{p}{name}"), t);
        }
        let wv = matmul_tn(r1, w.get(&format!("{p}wv"))?);
        out.set(&format!("{p}wv"), rotate_wv(&wv, r2, cfg.n_heads, cfg.d_head));
        let wo = rotate_wo(w.get(&format!("{p}wo"))?, r2, cfg.n_heads, cfg.d_head);
        out.set(&format!("{p}wo"), matmul(&wo, r1));
        let mut wdown = w.get(&format!("{p}wdown"))?.clone();
        if merge_r4 {
            wdown = hadamard::fwht_rows(&wdown);
        }
        out.set(&format!("{p}wdown"), matmul(&wdown, r1));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ModelConfig {
        ModelConfig {
            name: "t".into(),
            vocab: 13,
            d_model: 16,
            n_layers: 2,
            n_heads: 2,
            d_head: 8,
            d_ffn: 32,
            rope_theta: 10000.0,
            max_seq: 16,
            n_params: 0,
        }
    }

    fn random_weights(cfg: &ModelConfig, seed: u64) -> Weights {
        let mut p = Prng::new(seed);
        let mut w = Weights::new();
        for name in cfg.param_order() {
            let shape = cfg.param_shape(&name).unwrap();
            let n: usize = shape.iter().product();
            let data: Vec<f32> = if name.ends_with("norm") {
                (0..n).map(|_| 1.0 + 0.3 * p.normal()).collect()
            } else {
                (0..n).map(|_| p.normal() * 0.1).collect()
            };
            w.set(&name, Tensor::new(shape, data));
        }
        w
    }

    #[test]
    fn rotation_kinds_are_orthonormal() {
        let c = cfg();
        for kind in [
            RotationKind::Identity,
            RotationKind::RandomOrthogonal,
            RotationKind::RandomHadamard,
        ] {
            for seed in 0..3 {
                let r = RotationSet::build(&c, kind, seed);
                assert!(r.orthonormality_error() < 1e-4, "{kind:?} seed {seed}");
            }
        }
    }

    #[test]
    fn identity_merge_is_noop() {
        let c = cfg();
        let w = random_weights(&c, 1);
        let folded = fold_norm_scales(&w, &c).unwrap();
        let merged = merge(&folded, &c, &RotationSet::identity(&c), false).unwrap();
        for name in c.param_order() {
            let a = folded.get(&name).unwrap();
            let b = merged.get(&name).unwrap();
            assert!(a.sub(b).max_abs() < 1e-4, "{name}");
        }
    }

    #[test]
    fn fold_makes_gammas_one() {
        let c = cfg();
        let w = random_weights(&c, 2);
        let folded = fold_norm_scales(&w, &c).unwrap();
        for name in c.param_order() {
            if name.ends_with("norm") {
                let t = folded.get(&name).unwrap();
                assert!(t.sub(&Tensor::ones(&t.shape.clone())).max_abs() < 1e-6);
            }
        }
    }

    #[test]
    fn merge_preserves_shapes() {
        let c = cfg();
        let w = fold_norm_scales(&random_weights(&c, 3), &c).unwrap();
        let rot = RotationSet::build(&c, RotationKind::RandomHadamard, 4);
        for merge_r4 in [false, true] {
            let m = merge(&w, &c, &rot, merge_r4).unwrap();
            m.validate(&c).unwrap();
        }
    }

    #[test]
    fn double_merge_with_inverse_restores() {
        // Merging R then R^T (as a new rotation) must restore the original
        // weights: checks the full left/right multiply bookkeeping.
        let c = cfg();
        let w = fold_norm_scales(&random_weights(&c, 5), &c).unwrap();
        let rot = RotationSet::build(&c, RotationKind::RandomOrthogonal, 6);
        let inv = RotationSet {
            r1: crate::linalg::transpose(&rot.r1),
            r2s: rot.r2s.iter().map(crate::linalg::transpose).collect(),
        };
        let merged = merge(&w, &c, &rot, false).unwrap();
        let back = merge(&merged, &c, &inv, false).unwrap();
        for name in c.param_order() {
            let a = w.get(&name).unwrap();
            let b = back.get(&name).unwrap();
            assert!(a.sub(b).max_abs() < 1e-3, "{name}: {}", a.sub(b).max_abs());
        }
    }

    #[test]
    fn r2_blockdiag_roundtrip() {
        let _c = cfg();
        let mut p = Prng::new(7);
        let wv = Tensor::new(vec![16, 16], (0..256).map(|_| p.normal()).collect());
        let r2 = crate::hadamard::random_hadamard(8, 3);
        let rot = rotate_wv(&wv, &r2, 2, 8);
        let back = rotate_wv(&rot, &crate::linalg::transpose(&r2), 2, 8);
        assert!(wv.sub(&back).max_abs() < 1e-4);
    }

    #[test]
    fn wv_wo_pair_cancels() {
        // (x wv R2)(R2^T wo) == (x wv) wo for every head: the paper's Fig. 5.
        let _c = cfg();
        let mut p = Prng::new(8);
        let wv = Tensor::new(vec![16, 16], (0..256).map(|_| p.normal()).collect());
        let wo = Tensor::new(vec![16, 16], (0..256).map(|_| p.normal()).collect());
        let x = Tensor::new(vec![5, 16], (0..80).map(|_| p.normal()).collect());
        let r2 = crate::hadamard::random_hadamard(8, 9);
        let base = matmul(&matmul(&x, &wv), &wo);
        let wv_r = rotate_wv(&wv, &r2, 2, 8);
        let wo_r = rotate_wo(&wo, &r2, 2, 8);
        let rot = matmul(&matmul(&x, &wv_r), &wo_r);
        assert!(base.sub(&rot).max_abs() < 1e-3);
    }

    #[test]
    fn merged_weights_have_lower_kurtosis() {
        // Rotation blends the planted outlier columns of emb into all
        // channels (paper Fig. 2 on the weight side).
        let c = cfg();
        let mut w = random_weights(&c, 10);
        // plant outlier output channels on emb
        let emb = w.get("emb").unwrap().clone();
        let mut emb2 = emb.clone();
        for r in 0..emb2.shape[0] {
            emb2.data[r * c.d_model + 3] *= 20.0;
        }
        w.set("emb", emb2);
        let folded = fold_norm_scales(&w, &c).unwrap();
        let rot = RotationSet::build(&c, RotationKind::RandomHadamard, 11);
        let merged = merge(&folded, &c, &rot, false).unwrap();
        let k_before = folded.get("emb").unwrap().kurtosis();
        let k_after = merged.get("emb").unwrap().kurtosis();
        assert!(k_before > 2.0 * k_after, "before={k_before} after={k_after}");
    }
}
