//! `spinquant` — the L3 leader binary.
//!
//! Subcommands:
//!   quantize     run the PTQ pipeline, save quantized weights (.sqt)
//!   eval         quantize + evaluate (Wiki ppl, 0-shot^8 avg)
//!   optimize     learn rotations only; report loss curve + orthonormality
//!   serve        continuous-batching serving demo over the quantized KV
//!                cache (rust/src/serve): `--batch N` slots, seeded
//!                `--sampler greedy|temperature|top-k|top-p` with
//!                `--temperature/--top-k/--top-p/--seed`, per-request
//!                `--max-new-tokens`, `--prompt "a|b|c"` (one request per
//!                `|`-separated prompt), `--prefill-chunk T` (batched
//!                multi-token prefill: ceil(len/T) engine calls to first
//!                token; 1 = token-by-token loop), `--block-size N`
//!                (paged KV cache via the `decode_*_paged_b{B}` artifacts:
//!                memory scales with tokens in flight, admission by
//!                free-page token budget) + `--kv-blocks M` (restrict the
//!                page budget to M pages) + `--kv-bits {4,8,16}` (quantized
//!                KV page storage: cached K/V held at 4 or 8 bits on the
//!                symmetric per-group grid, ~3.6x / ~1.9x more tokens per
//!                page byte than fp16; 16 = full precision; rides the
//!                runtime qcfg vector, so no extra artifacts — falls back
//!                with a warning on the fp variant or the dense cache) +
//!                `--prefix-cache 1` (refcounted
//!                copy-on-write prefix sharing: requests repeating a
//!                system prompt map its cached pages read-only instead of
//!                recomputing them — bit-identical output, lower TTFT,
//!                more concurrency per page) + `--step-budget B`
//!                (decode-priority step composer: every step runs the
//!                full decode batch first, then at most B-ish prompt
//!                tokens of prefill, so one long prompt can no longer
//!                stall every in-flight decode for a whole prefill burst;
//!                0/off = the classic drain-prefill-then-decode loop;
//!                needs `--prefill-chunk > 1`) + `--spec-k K`
//!                (self-speculative decoding: each running slot drafts up
//!                to K tokens and the target engine verifies all K+1
//!                positions in one ragged call — greedy acceptance keeps
//!                the longest agreeing prefix plus a free correction
//!                token, rejections roll back pages and positions, and
//!                output stays byte-identical to `--spec-k 0` with any
//!                sampler; only tokens-per-engine-call changes) +
//!                `--spec-draft ngram|engine` (draft source: `ngram` =
//!                zero-cost prompt lookup over the slot's own history,
//!                the default; `engine` = a second lower-fidelity drafter
//!                rung — the demo binary has no second artifact set wired,
//!                so it says so and falls back to ngram; the
//!                `Scheduler::with_speculation` API takes any
//!                `DecodeEngine` drafter) + `--trace out.json`
//!                (flight recorder: record every scheduler decision —
//!                Enqueued/Admitted/PrefixHit/PrefillChunk/TokenDecoded/
//!                Evicted/Completed, page alloc/retain/release, composer
//!                plans, per-step counters — and export a Chrome
//!                trace-event / Perfetto JSON timeline: one track per
//!                slot plus counter tracks for queue depth, free pages,
//!                in-flight, and token mix; open in chrome://tracing or
//!                ui.perfetto.dev) + `--trace-buffer N` (ring capacity in
//!                events, default 2^20; drop-oldest, with the drop count
//!                reported in the export) + fault tolerance:
//!                `--fault-rate R` (chaos mode: wrap the engine in the
//!                seeded fault injector, so a fraction R of engine calls
//!                fail with transient or per-slot errors and the
//!                scheduler's error kernel recovers), `--fault-seed S` /
//!                `--fault-burst K` (deterministic schedule; K correlated
//!                faults per trigger), `--retry-budget N` (deterministic
//!                step-counted backoff; a request is quarantined after N
//!                individual faults, a streak of N step-wide faults
//!                evicts to the queue front for warm restart; 0 = keep
//!                the default), `--deadline-ms D` (shed requests older
//!                than D ms, queued or mid-flight; 0 = none); prints
//!                completions (with quarantine/deadline markers) +
//!                TTFT / latency-percentile / tokens-per-sec metrics
//!   bench-table  regenerate one paper table/figure (see --id list)
//!   selftest     end-to-end smoke: artifacts load + tiny eval
//!   info         list models/artifacts found in artifacts/
//!
//! Flags are `--key value` pairs matching config::PipelineConfig keys, plus
//! `--config file.toml`. Example:
//!   spinquant eval --model sq-2m --method spinquant-had --bits 4-4-4
//!   spinquant serve --model sq-2m --batch 4 --sampler top-k --temperature 0.8

use std::collections::VecDeque;

use anyhow::{anyhow, Context, Result};
use spinquant::config::{PipelineConfig, Toml};
use spinquant::coordinator::Pipeline;
use spinquant::info;
use spinquant::model::Manifest;
use spinquant::report::{fmt_acc, fmt_ppl, Table};
use spinquant::runtime::Runtime;
use spinquant::serve;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn usage() -> ! {
    eprintln!(
        "usage: spinquant <quantize|eval|optimize|serve|loadgen|bench-table|selftest|info> [--key value ...]\n\
         common flags: --model sq-2m --method spinquant-had --bits 4-4-4 --config run.toml\n\
         serve:        --batch 1|4|8 --sampler greedy|temperature|top-k|top-p --temperature 0.8\n\
                       --top-k 40 --top-p 0.95 --seed 0 --max-new-tokens 48 --prompt \"a|b|c\"\n\
                       --prefill-chunk 16|64 (batched prompt prefill; 1 = per-token loop)\n\
                       --block-size 16 (paged KV cache) --kv-blocks M (page budget)\n\
                       --kv-bits 4|8|16 (quantized KV page storage; 16 = full precision)\n\
                       --prefix-cache 1 (copy-on-write sharing of repeated prompt prefixes)\n\
                       --step-budget B (decode-priority step composer: bound the decode\n\
                       hiccup a long prompt's prefill causes; 0 = off)\n\
                       --spec-k K (speculative decoding: draft up to K tokens per slot,\n\
                       verify in one call; byte-identical output, fewer engine calls)\n\
                       --spec-draft ngram|engine (draft source; default ngram prompt lookup)\n\
                       --trace out.json (flight recorder -> Chrome/Perfetto trace JSON)\n\
                       --trace-buffer N (trace ring capacity in events, default 2^20)\n\
                       --fault-rate R (chaos mode: seeded engine-fault injection at rate R)\n\
                       --fault-seed S --fault-burst K (fault schedule seed / burst length)\n\
                       --retry-budget N (faults per request before quarantine; 0 = default)\n\
                       --deadline-ms D (shed requests older than D ms; 0 = none)\n\
                       --http PORT (HTTP/1.1 + SSE front on 127.0.0.1:PORT: POST /generate\n\
                       streams one SSE event per token, GET /healthz; runs until killed)\n\
                       --rate-limit N (per-tenant token bucket, N req/s sustained; tenant =\n\
                       x-tenant header) --burst B (bucket capacity, default 8)\n\
                       --shed-depth D (429 once queue depth reaches D; default 64)\n\
         loadgen:      --rps R --duration SECS --seed S --tenants N (open-loop seeded\n\
                       Poisson load against a loopback front over a MockEngine scheduler;\n\
                       prints goodput/TTFT/inter-token JSON) --slots K --max-queue M\n\
                       --rate-limit/--burst/--shed-depth as above [--out report.json]\n\
         bench-table:  --id table1|table2|table3|table4|table5|table6|table10|table11|table12|table13|fig2|fig3|fig4|fig7|fig8 [--models a,b] [--out EXPERIMENTS.md]"
    );
    std::process::exit(2);
}

struct Args {
    cmd: String,
    flags: Vec<(String, String)>,
}

fn parse_args() -> Result<Args> {
    let mut argv: VecDeque<String> = std::env::args().skip(1).collect();
    let cmd = argv.pop_front().unwrap_or_default();
    if cmd.is_empty() || cmd == "-h" || cmd == "--help" {
        usage();
    }
    let mut flags = Vec::new();
    while let Some(a) = argv.pop_front() {
        let key = a
            .strip_prefix("--")
            .ok_or_else(|| anyhow!("expected --flag, got {a:?}"))?
            .to_string();
        let val = argv.pop_front().ok_or_else(|| anyhow!("flag --{key} needs a value"))?;
        flags.push((key, val));
    }
    Ok(Args { cmd, flags })
}

fn build_config(args: &Args) -> Result<(PipelineConfig, Vec<(String, String)>)> {
    let mut cfg = PipelineConfig::default();
    // config file first, then CLI overrides.
    if let Some((_, path)) = args.flags.iter().find(|(k, _)| k == "config") {
        let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
        cfg.apply_toml(&Toml::parse(&text)?)?;
    }
    let mut extra = Vec::new();
    for (k, v) in &args.flags {
        if k == "config" {
            continue;
        }
        if cfg.apply_kv(k, v).is_err() {
            extra.push((k.clone(), v.clone()));
        }
    }
    Ok((cfg, extra))
}

fn get_extra<'a>(extra: &'a [(String, String)], key: &str) -> Option<&'a str> {
    extra.iter().find(|(k, _)| k == key).map(|(_, v)| v.as_str())
}

fn run() -> Result<()> {
    let args = parse_args()?;
    let (cfg, extra) = build_config(&args)?;

    match args.cmd.as_str() {
        "info" => cmd_info(&cfg),
        "selftest" => cmd_selftest(&cfg),
        "quantize" => cmd_quantize(&cfg, &extra),
        "eval" => cmd_eval(&cfg),
        "optimize" => cmd_optimize(&cfg),
        "serve" => cmd_serve(&cfg, &extra),
        "loadgen" => cmd_loadgen(&extra),
        "bench-table" => {
            let id = get_extra(&extra, "id").ok_or_else(|| anyhow!("bench-table needs --id"))?;
            let models: Vec<String> = get_extra(&extra, "models")
                .unwrap_or(&cfg.model)
                .split(',')
                .map(str::to_string)
                .collect();
            let trials: usize =
                get_extra(&extra, "trials").map(|v| v.parse()).transpose()?.unwrap_or(24);
            spinquant_benches::run_bench(&cfg, id, &models, trials, get_extra(&extra, "out"))
        }
        _ => usage(),
    }
}

fn cmd_info(cfg: &PipelineConfig) -> Result<()> {
    let manifest = Manifest::load(&cfg.artifacts_dir)?;
    println!("artifacts dir: {:?}", cfg.artifacts_dir);
    for m in manifest.models() {
        let mc = manifest.config(&m)?;
        println!(
            "model {m}: d_model={} layers={} heads={} d_ffn={} (~{:.1}M params)",
            mc.d_model,
            mc.n_layers,
            mc.n_heads,
            mc.d_ffn,
            mc.n_params as f64 / 1e6
        );
    }
    Ok(())
}

fn cmd_selftest(cfg: &PipelineConfig) -> Result<()> {
    let manifest = Manifest::load(&cfg.artifacts_dir)?;
    let rt = Runtime::cpu()?;
    println!("PJRT platform: {}", rt.platform());
    let mut fast = cfg.clone();
    fast.eval_windows = Some(4);
    fast.task_items = 4;
    fast.method = spinquant::config::Method::Rtn;
    let pipe = Pipeline::new(&rt, &manifest, fast)?;
    let qm = pipe.quantize()?;
    let res = pipe.evaluate(&qm)?;
    println!("selftest OK: ppl={:.2} acc={:.1}%", res.ppl, res.acc_pct());
    Ok(())
}

fn cmd_quantize(cfg: &PipelineConfig, extra: &[(String, String)]) -> Result<()> {
    let manifest = Manifest::load(&cfg.artifacts_dir)?;
    let rt = Runtime::cpu()?;
    let pipe = Pipeline::new(&rt, &manifest, cfg.clone())?;
    let qm = pipe.quantize()?;
    let out = get_extra(extra, "save")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|| {
            cfg.artifacts_dir.join(format!(
                "{}_{}_{}.quant.sqt",
                cfg.model,
                cfg.method.name(),
                cfg.bits.label()
            ))
        });
    qm.weights.save(&out)?;
    info!("saved quantized weights to {out:?}");
    for (k, v) in &qm.meta {
        println!("  {k}: {v:.4}");
    }
    Ok(())
}

fn cmd_eval(cfg: &PipelineConfig) -> Result<()> {
    let manifest = Manifest::load(&cfg.artifacts_dir)?;
    let rt = Runtime::cpu()?;
    let pipe = Pipeline::new(&rt, &manifest, cfg.clone())?;
    let qm = pipe.quantize()?;
    let res = pipe.evaluate(&qm)?;
    let mut t = Table::new(
        &format!("{} {} ({})", cfg.model, cfg.method.name(), cfg.bits.label()),
        &["0-shot^8 Avg (%)", "Wiki ppl"],
    );
    t.row(vec![fmt_acc(res.acc_pct()), fmt_ppl(res.ppl)]);
    println!("{}", t.to_markdown());
    for (name, acc) in &res.per_suite {
        println!("  {name:<10} {:.1}%", acc * 100.0);
    }
    Ok(())
}

fn cmd_optimize(cfg: &PipelineConfig) -> Result<()> {
    use spinquant::coordinator::cayley_driver;
    use spinquant::rotation::{fold_norm_scales, RotationKind, RotationSet};
    let manifest = Manifest::load(&cfg.artifacts_dir)?;
    let rt = Runtime::cpu()?;
    let pipe = Pipeline::new(&rt, &manifest, cfg.clone())?;
    let base = pipe.load_base_weights()?;
    let folded = fold_norm_scales(&base, &pipe.model_cfg)?;
    let init = RotationSet::build(&pipe.model_cfg, RotationKind::RandomHadamard, cfg.rotation_seed);
    let had = cfg.method.uses_online_hadamard();
    let (rot, run) = cayley_driver::learn_rotations_detailed(&pipe, &folded, init, had)?;
    println!(
        "cayley: {} iters, loss {:.4} -> {:.4}, orthonormality error {:.2e}",
        run.losses.len(),
        run.losses.first().unwrap_or(&f32::NAN),
        run.losses.last().unwrap_or(&f32::NAN),
        run.final_orth_error
    );
    let out = cfg.artifacts_dir.join(format!("{}_rotations.sqt", cfg.model));
    let mut tensors = std::collections::BTreeMap::new();
    tensors.insert("r1".to_string(), rot.r1.clone());
    for (i, r2) in rot.r2s.iter().enumerate() {
        tensors.insert(format!("r2.{i}"), r2.clone());
    }
    spinquant::model::sqt::write_sqt(&out, &tensors)?;
    info!("saved learned rotations to {out:?}");
    Ok(())
}

fn cmd_serve(cfg: &PipelineConfig, extra: &[(String, String)]) -> Result<()> {
    use spinquant::serve::{PjrtEngine, Sampler};

    let manifest = Manifest::load(&cfg.artifacts_dir)?;
    let rt = Runtime::cpu()?;
    let pipe = Pipeline::new(&rt, &manifest, cfg.clone())?;
    let qm = pipe.quantize()?;
    let variant = match (cfg.method, qm.had) {
        (spinquant::config::Method::Float, _) => serve::DecodeVariant::Fp,
        (_, true) => serve::DecodeVariant::QuantHad,
        (_, false) => serve::DecodeVariant::QuantNoHad,
    };

    // Serving knobs.
    let mut batch: usize =
        get_extra(extra, "batch").map(|v| v.parse()).transpose()?.unwrap_or(1);
    let temperature: f32 =
        get_extra(extra, "temperature").map(|v| v.parse()).transpose()?.unwrap_or(0.8);
    let top_k: usize = get_extra(extra, "top-k").map(|v| v.parse()).transpose()?.unwrap_or(40);
    let top_p: f32 = get_extra(extra, "top-p").map(|v| v.parse()).transpose()?.unwrap_or(0.95);
    let seed: u64 = get_extra(extra, "seed").map(|v| v.parse()).transpose()?.unwrap_or(0);
    let sampler = Sampler::parse(
        get_extra(extra, "sampler").unwrap_or("greedy"),
        temperature,
        top_k,
        top_p,
    )?;
    let n_new: usize = get_extra(extra, "max-new-tokens")
        .or_else(|| get_extra(extra, "tokens"))
        .map(|v| v.parse())
        .transpose()?
        .unwrap_or(48);
    // `|`-separated prompts become independent requests.
    let prompts: Vec<Vec<u8>> = get_extra(extra, "prompt")
        .unwrap_or("The |Alpha beta |Some words |Q: ")
        .split('|')
        .filter(|p| !p.is_empty())
        .map(|p| p.as_bytes().to_vec())
        .collect();

    // Paged (block-pool) KV cache: `--block-size N` switches to the
    // `decode_*_paged_b{B}` artifacts (page granularity is baked into the
    // artifact; N must match), and `--kv-blocks M` restricts the admission
    // budget to M pages of KV memory (default: the artifact's whole pool).
    let block_size: usize =
        get_extra(extra, "block-size").map(|v| v.parse()).transpose()?.unwrap_or(0);
    let kv_blocks: usize =
        get_extra(extra, "kv-blocks").map(|v| v.parse()).transpose()?.unwrap_or(0);
    // Quantized KV page storage: `--kv-bits {4,8,16}` stores cached K/V at
    // the requested width (16 = full precision, the pre-existing path).
    // The width rides the runtime qcfg vector, so no new artifact shapes
    // are needed; sub-byte storage uses the symmetric grid (R3's head-wise
    // Hadamard Gaussianizes cached K, so a zero-point buys nothing and the
    // per-group metadata halves).
    let kv_bits: f32 =
        get_extra(extra, "kv-bits").map(|v| v.parse()).transpose()?.unwrap_or(16.0);
    if kv_bits != 4.0 && kv_bits != 8.0 && kv_bits != 16.0 {
        anyhow::bail!("--kv-bits {kv_bits}: expected 4, 8, or 16");
    }
    // A page budget only makes sense on the paged path, so --kv-blocks
    // implies it (page granularity then comes from the artifact).
    let mut paged = block_size > 0 || kv_blocks > 0;
    if paged && batch <= 1 {
        eprintln!("note: paged serving needs --batch > 1 (no b1 paged artifact); serving dense");
        paged = false;
    }

    // Load the decode artifact: paged when requested (dense fallback), and
    // batch-1 fallback when the artifact set predates continuous batching.
    let mut paged_exe = None;
    if paged {
        match rt.load(&manifest, &cfg.model, &variant.artifact_paged(batch)) {
            Ok(e) => paged_exe = Some(e),
            Err(e) => {
                eprintln!(
                    "note: cannot use {} ({e:#}); serving the dense KV cache \
                     (re-run `make artifacts` for paged decode)",
                    variant.artifact_paged(batch)
                );
                paged = false;
            }
        }
    }
    let exe = match paged_exe {
        Some(e) => e,
        None => match rt.load(&manifest, &cfg.model, &variant.artifact_batched(batch)) {
            Ok(e) => e,
            Err(e) if batch > 1 => {
                eprintln!(
                    "note: no {} artifact ({e:#}); falling back to batch 1 \
                     (re-run `make artifacts` for batched decode)",
                    variant.artifact_batched(batch)
                );
                batch = 1;
                rt.load(&manifest, &cfg.model, variant.artifact())?
            }
            Err(e) => return Err(e),
        },
    };
    let mut qcfg = if variant == serve::DecodeVariant::Fp { None } else { Some(qm.qcfg) };
    if kv_bits < 16.0 {
        match qcfg {
            Some(q) => qcfg = Some(q.with_kv_bits(kv_bits).with_kv_sym(1.0)),
            None => eprintln!(
                "note: --kv-bits {kv_bits:.0} NOT enforced — the fp variant has no \
                 quantization config input (pick a quantized --method)"
            ),
        }
    }
    let mut engine = PjrtEngine::new(exe, &qm.weights, qcfg)?;
    {
        use spinquant::serve::DecodeEngine as _;
        if paged && block_size > 0 {
            let actual = engine.kv_block_size().unwrap_or(0);
            if actual != block_size {
                eprintln!(
                    "note: artifact pages are {actual} tokens (--block-size {block_size} \
                     is informational; the artifact's granularity wins)"
                );
            }
        }
    }

    // Batched multi-token prefill: a prompt costs ceil(len/chunk) engine
    // calls to first token instead of len. `--prefill-chunk 1` (or a
    // missing artifact) falls back to the token-by-token decode loop.
    let prefill_chunk: usize = get_extra(extra, "prefill-chunk")
        .map(|v| v.parse())
        .transpose()?
        .unwrap_or(16);
    if prefill_chunk == 0 {
        anyhow::bail!("--prefill-chunk must be >= 1 (1 = per-token decode loop)");
    }
    if prefill_chunk > 1 {
        if batch > 1 {
            let pname = if paged {
                variant.artifact_prefill_paged(batch, prefill_chunk)
            } else {
                variant.artifact_prefill(batch, prefill_chunk)
            };
            match rt.load(&manifest, &cfg.model, &pname) {
                Ok(pexe) => engine = engine.with_prefill(pexe, &qm.weights, qcfg)?,
                Err(e) => {
                    // The manifest is the source of truth for which chunk
                    // sizes this build emitted — list them instead of
                    // guessing why the load failed.
                    let avail: Vec<String> = manifest
                        .artifact_names(&cfg.model)
                        .into_iter()
                        .filter(|n| n.starts_with("prefill_"))
                        .collect();
                    eprintln!(
                        "note: cannot use {pname} ({e:#}); prompts prefill through \
                         the decode loop (prefill artifacts in this build: {avail:?})"
                    );
                }
            }
        } else {
            eprintln!(
                "note: batched prefill needs --batch > 1 (no b1 prefill artifact); \
                 prompts prefill through the decode loop"
            );
        }
    }
    // Fault tolerance: `--fault-rate R` wraps the engine in the seeded
    // FaultInjector, so every engine call may fail with a transient or
    // per-slot ServeError — chaos-testing the scheduler's error kernel
    // over the real artifacts. `--fault-seed S` / `--fault-burst K` shape
    // the deterministic schedule; the recovery knobs (`--retry-budget`,
    // `--deadline-ms`, parsed in the serve loop) apply either way.
    let fault_rate: f64 =
        get_extra(extra, "fault-rate").map(|v| v.parse()).transpose()?.unwrap_or(0.0);
    if !(0.0..=1.0).contains(&fault_rate) {
        anyhow::bail!("--fault-rate {fault_rate}: expected a probability in [0, 1]");
    }
    let fault_seed: u64 =
        get_extra(extra, "fault-seed").map(|v| v.parse()).transpose()?.unwrap_or(0);
    let fault_burst: usize =
        get_extra(extra, "fault-burst").map(|v| v.parse()).transpose()?.unwrap_or(1);
    let knobs = ServeKnobs {
        extra,
        prompts,
        sampler,
        seed,
        n_new,
        batch,
        paged,
        block_size,
        kv_blocks,
        kv_bits,
        kv_quantized: qcfg.is_some(),
    };
    if fault_rate > 0.0 {
        eprintln!(
            "note: chaos mode — injecting engine faults at rate {fault_rate} \
             (seed {fault_seed}, burst {fault_burst})"
        );
        serve_with(
            serve::FaultInjector::new(engine, fault_seed, fault_rate).with_burst(fault_burst),
            &knobs,
        )
    } else {
        if get_extra(extra, "fault-seed").is_some() || get_extra(extra, "fault-burst").is_some() {
            eprintln!("note: --fault-seed/--fault-burst have no effect without --fault-rate > 0");
        }
        serve_with(engine, &knobs)
    }
}

/// Serving knobs that outlive engine construction, bundled so the generic
/// serve loop below takes one parameter instead of a dozen.
struct ServeKnobs<'a> {
    extra: &'a [(String, String)],
    prompts: Vec<Vec<u8>>,
    sampler: serve::Sampler,
    seed: u64,
    n_new: usize,
    batch: usize,
    paged: bool,
    block_size: usize,
    kv_blocks: usize,
    kv_bits: f32,
    kv_quantized: bool,
}

/// The serve loop proper, generic over the engine so chaos mode
/// (`--fault-rate`: engine wrapped in [`serve::FaultInjector`]) runs the
/// exact same scheduler path as normal serving.
fn serve_with<E: serve::DecodeEngine>(engine: E, k: &ServeKnobs) -> Result<()> {
    use spinquant::serve::{FinishReason, GenRequest, Scheduler, SpecDraft};

    let chunk_in_use = engine.prefill_chunk();
    let pool_desc = match engine.kv_block_size() {
        Some(bs) => {
            let budget = if k.kv_blocks > 0 { k.kv_blocks } else { engine.kv_blocks() };
            format!(", paged KV: {budget} pages x {bs} tokens")
        }
        None => String::new(),
    };
    // Refcounted copy-on-write prefix sharing: `--prefix-cache 1` makes
    // requests repeating a system prompt map its pages instead of
    // recomputing them (paged path only; completions are bit-identical
    // either way).
    let prefix_cache: bool = match get_extra(k.extra, "prefix-cache") {
        None => false,
        Some("1" | "true" | "on" | "yes") => true,
        Some("0" | "false" | "off" | "no") => false,
        Some(other) => anyhow::bail!(
            "--prefix-cache {other:?}: expected 1/0, true/false, or on/off"
        ),
    };
    let mut sched = Scheduler::new(engine, 1024)?;
    if k.kv_blocks > 0 {
        if k.paged {
            sched = sched.with_kv_block_budget(k.kv_blocks)?;
        } else {
            // Never drop a requested memory cap silently.
            eprintln!(
                "note: --kv-blocks {} NOT enforced — serving fell back to the \
                 dense KV cache (see notes above)",
                k.kv_blocks
            );
        }
    }
    // Same contract for --kv-bits: the width still quantizes the KV values
    // (the qcfg vector reaches the artifact either way), but without the
    // paged pool there are no packed pages, so the page-byte savings the
    // flag exists for are not realized. Never silent.
    if k.kv_bits < 16.0 && k.kv_quantized && (k.block_size > 0 || k.kv_blocks > 0) && !k.paged {
        eprintln!(
            "note: --kv-bits {:.0} quantizes KV values, but serving fell back to \
             the dense KV cache (see notes above) — no packed pages, so the page-byte \
             savings are not realized",
            k.kv_bits
        );
    }
    if prefix_cache {
        if k.paged {
            sched = sched.with_prefix_cache()?;
        } else {
            eprintln!(
                "note: --prefix-cache NOT enforced — it shares pages over the paged KV \
                 cache, and serving fell back to the dense path (see notes above)"
            );
        }
    }
    // Decode-priority step composer: `--step-budget B` runs the full
    // decode batch every step and caps the prefill share, bounding the
    // hiccup a long prompt causes for in-flight requests (0 = off, the
    // classic drain-prefill-then-decode loop). Needs a multi-token
    // prefill path; never silently dropped.
    let step_budget: usize =
        get_extra(k.extra, "step-budget").map(|v| v.parse()).transpose()?.unwrap_or(0);
    let composing = step_budget > 0 && chunk_in_use > 1;
    if step_budget > 0 {
        if composing {
            sched = sched.with_step_budget(step_budget)?;
        } else {
            eprintln!(
                "note: --step-budget {step_budget} NOT enforced — it composes budgeted \
                 prefill chunks, and prompts are feeding through the per-token decode \
                 loop (see notes above; pass --prefill-chunk > 1)"
            );
        }
    }
    // Self-speculative decoding: `--spec-k K` drafts up to K tokens per
    // running slot and verifies the whole window in one ragged engine
    // call — greedy acceptance keeps the longest agreeing prefix plus one
    // free correction token; rejections roll back positions and pages.
    // Output is byte-identical to --spec-k 0; only tokens-per-engine-call
    // changes. `--spec-draft ngram` (default) proposes from the slot's own
    // history at zero cost; `engine` wants a second, lower-fidelity
    // drafter rung, which this demo binary has no second artifact set
    // wired for — never silently: it says so and drafts via ngram (the
    // `Scheduler::with_speculation` API takes any `DecodeEngine` drafter).
    let spec_k: usize =
        get_extra(k.extra, "spec-k").map(|v| v.parse()).transpose()?.unwrap_or(0);
    let spec_draft = get_extra(k.extra, "spec-draft");
    if spec_k > 0 {
        match spec_draft.unwrap_or("ngram") {
            "ngram" => {}
            "engine" => eprintln!(
                "note: --spec-draft engine needs a second (lower-bit) drafter artifact, \
                 which this binary does not wire up — drafting via prompt lookup (ngram) \
                 instead"
            ),
            other => anyhow::bail!("--spec-draft {other:?}: expected ngram or engine"),
        }
        sched = sched.with_speculation(spec_k, SpecDraft::NGram)?;
    } else if spec_draft.is_some() {
        eprintln!("note: --spec-draft has no effect without --spec-k >= 1");
    }
    // Error-kernel recovery: `--retry-budget N` quarantines a request
    // after N individual engine faults and evicts a call's participants
    // for warm restart after a streak of N step-wide faults (backoff is
    // counted in scheduler steps, deterministically). 0 = keep the
    // default.
    let retry_budget: usize =
        get_extra(k.extra, "retry-budget").map(|v| v.parse()).transpose()?.unwrap_or(0);
    if retry_budget > 0 {
        sched = sched.with_retry_budget(retry_budget)?;
    }
    // `--deadline-ms D` sheds any request older than D ms — still queued
    // (nothing spent on it) or mid-flight (partial output returned).
    let deadline_ms: f64 =
        get_extra(k.extra, "deadline-ms").map(|v| v.parse()).transpose()?.unwrap_or(0.0);
    if deadline_ms < 0.0 {
        anyhow::bail!("--deadline-ms {deadline_ms}: expected >= 0 (0 = no deadline)");
    }
    // Flight recorder: `--trace out.json` records every scheduler decision
    // into a bounded ring and exports a Chrome trace-event / Perfetto JSON
    // timeline after the run. `--trace-buffer N` sizes the ring (events;
    // drop-oldest beyond that, counted in the export). Off by default: the
    // sink is then a unit enum variant and the hot loop pays one branch.
    let trace_path = get_extra(k.extra, "trace");
    let trace_buffer: usize = get_extra(k.extra, "trace-buffer")
        .map(|v| v.parse())
        .transpose()?
        .unwrap_or(1 << 20);
    if trace_buffer == 0 {
        anyhow::bail!("--trace-buffer must be >= 1 (events retained in the ring)");
    }
    if trace_path.is_some() {
        sched = sched.with_trace(trace_buffer);
    } else if get_extra(k.extra, "trace-buffer").is_some() {
        eprintln!("note: --trace-buffer has no effect without --trace out.json");
    }

    // HTTP/SSE network front: `--http PORT` swaps the one-shot prompt list
    // for a socket front serving `POST /generate` streams until killed.
    // The scheduler stays on this thread (PJRT handles are not `Send`);
    // the front multiplexes sockets around it. `--rate-limit N` (req/s
    // sustained per tenant, capacity `--burst B`) and `--shed-depth D`
    // turn overload into fast 429s instead of queue growth.
    if let Some(port) = get_extra(k.extra, "http") {
        let port: u16 = port.parse()?;
        let rate_per_sec: Option<f64> =
            get_extra(k.extra, "rate-limit").map(|v| v.parse()).transpose()?;
        let burst: f64 =
            get_extra(k.extra, "burst").map(|v| v.parse()).transpose()?.unwrap_or(8.0);
        let shed_depth: usize =
            get_extra(k.extra, "shed-depth").map(|v| v.parse()).transpose()?.unwrap_or(64);
        let mut front = serve::HttpFront::bind(
            &format!("127.0.0.1:{port}"),
            serve::HttpFrontConfig { rate_per_sec, burst, shed_depth },
        )?;
        front.install_token_hook(&mut sched);
        if get_extra(k.extra, "prompt").is_some() {
            eprintln!("note: --prompt is ignored with --http — prompts arrive in request bodies");
        }
        println!(
            "listening on http://{} (POST /generate streams SSE tokens, GET /healthz; \
             rate limit {}, shed depth {shed_depth}; Ctrl-C to stop)",
            front.local_addr()?,
            match rate_per_sec {
                Some(r) => format!("{r} req/s per tenant, burst {burst}"),
                None => "off".to_string(),
            },
        );
        loop {
            front.poll(&mut sched)?;
            if sched.is_idle() && front.conn_count() == 0 {
                std::thread::sleep(std::time::Duration::from_millis(2));
            }
        }
    } else if get_extra(k.extra, "rate-limit").is_some()
        || get_extra(k.extra, "burst").is_some()
        || get_extra(k.extra, "shed-depth").is_some()
    {
        eprintln!(
            "note: --rate-limit/--burst/--shed-depth shape the HTTP front and have \
             no effect without --http PORT"
        );
    }

    println!(
        "serving {} request(s) on {} slot(s), sampler {}, max {} new tokens, \
         prefill chunk {}{}{}{}{}{}{}",
        k.prompts.len(),
        k.batch,
        k.sampler.name(),
        k.n_new,
        chunk_in_use,
        pool_desc,
        if k.kv_bits < 16.0 && k.kv_quantized {
            format!(", kv {:.0}-bit", k.kv_bits)
        } else {
            String::new()
        },
        if prefix_cache && k.paged { ", prefix cache on" } else { "" },
        if composing { format!(", step budget {step_budget}") } else { String::new() },
        if spec_k > 0 { format!(", spec window {spec_k} (ngram)") } else { String::new() },
        if deadline_ms > 0.0 { format!(", deadline {deadline_ms:.0} ms") } else { String::new() }
    );
    let reqs = k.prompts.iter().enumerate().map(|(i, p)| {
        let r = GenRequest::sampled(p, k.n_new, k.sampler, k.seed.wrapping_add(i as u64));
        if deadline_ms > 0.0 {
            r.with_deadline_ms(deadline_ms)
        } else {
            r
        }
    });
    let mut done = sched.serve_all(reqs)?;
    done.sort_by_key(|c| c.id);
    for c in &done {
        let status = match c.reason {
            FinishReason::Quarantined => "  [quarantined: engine faults]",
            FinishReason::DeadlineExpired => "  [deadline expired]",
            _ => "",
        };
        println!(
            "request {}: ttft {:>7.2} ms, total {:>8.1} ms  {:?} -> {:?}{status}",
            c.id,
            c.ttft_ms.unwrap_or(f64::NAN),
            c.latency_ms,
            String::from_utf8_lossy(&c.prompt),
            String::from_utf8_lossy(&c.completion)
        );
    }
    println!();
    println!(
        "{}",
        sched.metrics.table(&format!("serving metrics (batch={})", k.batch)).to_markdown()
    );
    if spec_k > 0 {
        println!(
            "speculation: {} verify calls, {}/{} draft tokens accepted (accept rate {:.2})",
            sched.metrics.verify_calls,
            sched.metrics.draft_tokens_accepted,
            sched.metrics.draft_tokens_proposed,
            sched.metrics.accept_rate()
        );
    }
    if let Some(path) = trace_path {
        let records = sched.trace_records();
        let dropped = sched.trace_dropped_events();
        let json = serve::chrome_trace(&records, dropped);
        spinquant::report::write_json(std::path::Path::new(path), &json)?;
        println!(
            "trace: {} events -> {path} ({dropped} dropped; open in chrome://tracing \
             or ui.perfetto.dev)",
            records.len()
        );
    }
    Ok(())
}

/// Open-loop load harness (`spinquant loadgen`): seeded Poisson arrivals
/// with tenant skew against a loopback HTTP/SSE front. Drives a
/// deterministic [`serve::MockEngine`] scheduler — the harness measures
/// the serving stack (scheduling + transport), not the model, and so runs
/// without artifacts.
fn cmd_loadgen(extra: &[(String, String)]) -> Result<()> {
    let rps: f64 = get_extra(extra, "rps").map(|v| v.parse()).transpose()?.unwrap_or(50.0);
    let duration: f64 =
        get_extra(extra, "duration").map(|v| v.parse()).transpose()?.unwrap_or(2.0);
    if rps <= 0.0 || duration <= 0.0 {
        anyhow::bail!("loadgen needs --rps > 0 and --duration > 0 (got {rps}, {duration})");
    }
    let seed: u64 = get_extra(extra, "seed").map(|v| v.parse()).transpose()?.unwrap_or(0);
    let tenants: usize =
        get_extra(extra, "tenants").map(|v| v.parse()).transpose()?.unwrap_or(4);
    let slots: usize = get_extra(extra, "slots").map(|v| v.parse()).transpose()?.unwrap_or(8);
    let max_queue: usize =
        get_extra(extra, "max-queue").map(|v| v.parse()).transpose()?.unwrap_or(256);
    let rate_per_sec: Option<f64> =
        get_extra(extra, "rate-limit").map(|v| v.parse()).transpose()?;
    let burst: f64 = get_extra(extra, "burst").map(|v| v.parse()).transpose()?.unwrap_or(8.0);
    let shed_depth: usize =
        get_extra(extra, "shed-depth").map(|v| v.parse()).transpose()?.unwrap_or(64);

    let mut sched = serve::Scheduler::new(serve::MockEngine::new(slots, 512, 64), max_queue)?;
    let mut front = serve::HttpFront::bind(
        "127.0.0.1:0",
        serve::HttpFrontConfig { rate_per_sec, burst, shed_depth },
    )?;
    front.install_token_hook(&mut sched);
    let cfg = serve::LoadGenConfig {
        rps,
        duration_secs: duration,
        seed,
        tenants,
        ..serve::LoadGenConfig::default()
    };
    eprintln!(
        "loadgen: offering {rps} req/s for {duration}s (seed {seed}, {tenants} tenants, \
         {slots} slots) over http://{}",
        front.local_addr()?
    );
    let report = serve::run_open_loop(&mut front, &mut sched, &cfg)?;
    let j = report.to_json(rps);
    println!("{}", j.to_string());
    if let Some(path) = get_extra(extra, "out") {
        spinquant::report::write_json(std::path::Path::new(path), &j)?;
        eprintln!("report -> {path}");
    }
    Ok(())
}

/// Paper-table harnesses live in the library-adjacent module below so both
/// `spinquant bench-table` and `cargo bench` share the exact same code.
mod spinquant_benches {
    pub use spinquant::benches_impl::run_bench;
}
