//! Configuration system: typed pipeline/eval configs, a TOML-subset parser
//! for config files, and the `W-A-KV` bit-spec grammar used throughout the
//! paper's tables.
//!
//! Precedence: defaults < config file (`--config run.toml`) < CLI flags.

use std::collections::BTreeMap;
use std::path::PathBuf;

use anyhow::{anyhow, bail, Result};

/// Quantization method — every row family in paper Table 1 plus QuaRot.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    Float,
    Rtn,
    SmoothQuant,
    Gptq,
    LlmQat,
    /// Random-rotation baseline (Ashkboos et al.): random Hadamard R1/R2 +
    /// online R3/R4, NO Cayley learning.
    QuaRot,
    /// Learned R1/R2 only, fully merged (zero inference overhead).
    SpinQuantNoHad,
    /// Learned R1/R2 + online Hadamard R3/R4.
    SpinQuantHad,
}

impl Method {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "float" | "fp" | "fp16" | "fullprecision" => Method::Float,
            "rtn" => Method::Rtn,
            "smoothquant" | "sq" => Method::SmoothQuant,
            "gptq" => Method::Gptq,
            "llm-qat" | "llmqat" | "qat" => Method::LlmQat,
            "quarot" => Method::QuaRot,
            "spinquant-nohad" | "spinquant_no_had" | "nohad" => Method::SpinQuantNoHad,
            "spinquant-had" | "spinquant_had" | "had" | "spinquant" => Method::SpinQuantHad,
            other => bail!("unknown method {other:?}"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Method::Float => "FloatingPoint",
            Method::Rtn => "RTN",
            Method::SmoothQuant => "SmoothQuant",
            Method::Gptq => "GPTQ",
            Method::LlmQat => "LLM-QAT",
            Method::QuaRot => "QuaRot",
            Method::SpinQuantNoHad => "SpinQuant_no_had",
            Method::SpinQuantHad => "SpinQuant_had",
        }
    }

    /// Does this method run the `_had` (online R3/R4) artifacts?
    pub fn uses_online_hadamard(&self) -> bool {
        matches!(self, Method::QuaRot | Method::SpinQuantHad)
    }

    /// Does this method learn R1/R2 with Cayley SGD?
    pub fn learns_rotation(&self) -> bool {
        matches!(self, Method::SpinQuantNoHad | Method::SpinQuantHad)
    }

    pub fn uses_rotation(&self) -> bool {
        matches!(self, Method::QuaRot | Method::SpinQuantNoHad | Method::SpinQuantHad)
    }
}

/// `W-A-KV` bit widths, e.g. "4-8-16" (16 = full precision).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Bits {
    pub w: f32,
    pub a: f32,
    pub kv: f32,
}

impl Bits {
    pub fn parse(s: &str) -> Result<Self> {
        let parts: Vec<&str> = s.split('-').collect();
        if parts.len() != 3 {
            bail!("bit spec must be W-A-KV, got {s:?}");
        }
        let p = |x: &str| -> Result<f32> {
            let v: f32 = x.parse().map_err(|_| anyhow!("bad bit width {x:?}"))?;
            if !(2.0..=16.0).contains(&v) {
                bail!("bit width {v} out of range [2,16]");
            }
            Ok(v)
        };
        Ok(Self { w: p(parts[0])?, a: p(parts[1])?, kv: p(parts[2])? })
    }

    pub fn label(&self) -> String {
        format!("{}-{}-{}", self.w as u32, self.a as u32, self.kv as u32)
    }

    pub fn fp() -> Self {
        Self { w: 16.0, a: 16.0, kv: 16.0 }
    }
}

/// Full pipeline configuration.
#[derive(Clone, Debug)]
pub struct PipelineConfig {
    pub artifacts_dir: PathBuf,
    pub model: String,
    pub method: Method,
    pub bits: Bits,
    /// Weight quantizer used after rotation (GPTQ per the paper's main
    /// tables; RTN for the ablations).
    pub use_gptq: bool,
    /// Activation quant: asymmetric (paper default) + optional clip.
    pub a_sym: bool,
    pub a_clip: f32,
    pub kv_sym: bool,
    pub kv_clip: f32,
    /// Rotation init: "hadamard" (paper default) or "orthogonal".
    pub rotation_init: String,
    pub rotation_seed: u64,
    /// Cayley SGD (paper §4.1: lr 1.5 linearly decayed, 100 iters).
    pub cayley_iters: usize,
    pub cayley_lr: f32,
    pub cayley_samples: usize,
    /// Optimize rotations against W16 ("16-a-kv", Table 3 winner) or the
    /// weight-quantized net.
    pub cayley_on_quant_weights: bool,
    pub calib_corpus: String,
    pub calib_seed: u64,
    /// GPTQ calibration batches (through fwd_stats).
    pub gptq_batches: usize,
    pub gptq_percdamp: f32,
    /// LLM-QAT driver.
    pub qat_steps: usize,
    pub qat_lr: f32,
    /// Eval sizing.
    pub eval_windows: Option<usize>,
    pub task_items: usize,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        Self {
            artifacts_dir: PathBuf::from("artifacts"),
            model: "sq-2m".into(),
            method: Method::SpinQuantHad,
            bits: Bits { w: 4.0, a: 4.0, kv: 4.0 },
            use_gptq: true,
            a_sym: false,
            a_clip: 1.0,
            kv_sym: false,
            kv_clip: 1.0,
            rotation_init: "hadamard".into(),
            rotation_seed: 0,
            cayley_iters: 100,
            cayley_lr: 1.5,
            cayley_samples: 256,
            cayley_on_quant_weights: false,
            calib_corpus: "wiki-syn".into(),
            calib_seed: 0,
            gptq_batches: 8,
            gptq_percdamp: 0.01,
            qat_steps: 120,
            qat_lr: 1e-3,
            eval_windows: None,
            task_items: 24,
        }
    }
}

impl PipelineConfig {
    /// Apply `key = value` pairs from a parsed TOML table.
    pub fn apply_toml(&mut self, toml: &Toml) -> Result<()> {
        for (key, v) in toml.flat() {
            self.apply_kv(&key, &v.as_string())?;
        }
        Ok(())
    }

    /// Apply one override (shared by TOML and `--key value` CLI flags).
    pub fn apply_kv(&mut self, key: &str, value: &str) -> Result<()> {
        match key {
            "artifacts_dir" => self.artifacts_dir = PathBuf::from(value),
            "model" => self.model = value.to_string(),
            "method" => self.method = Method::parse(value)?,
            "bits" => self.bits = Bits::parse(value)?,
            "use_gptq" => self.use_gptq = parse_bool(value)?,
            "a_sym" => self.a_sym = parse_bool(value)?,
            "a_clip" => self.a_clip = value.parse()?,
            "kv_sym" => self.kv_sym = parse_bool(value)?,
            "kv_clip" => self.kv_clip = value.parse()?,
            "rotation_init" => self.rotation_init = value.to_string(),
            "rotation_seed" => self.rotation_seed = value.parse()?,
            "cayley_iters" => self.cayley_iters = value.parse()?,
            "cayley_lr" => self.cayley_lr = value.parse()?,
            "cayley_samples" => self.cayley_samples = value.parse()?,
            "cayley_on_quant_weights" => self.cayley_on_quant_weights = parse_bool(value)?,
            "calib_corpus" => self.calib_corpus = value.to_string(),
            "calib_seed" => self.calib_seed = value.parse()?,
            "gptq_batches" => self.gptq_batches = value.parse()?,
            "gptq_percdamp" => self.gptq_percdamp = value.parse()?,
            "qat_steps" => self.qat_steps = value.parse()?,
            "qat_lr" => self.qat_lr = value.parse()?,
            "eval_windows" => {
                self.eval_windows = if value == "all" { None } else { Some(value.parse()?) }
            }
            "task_items" => self.task_items = value.parse()?,
            other => bail!("unknown config key {other:?}"),
        }
        Ok(())
    }
}

fn parse_bool(s: &str) -> Result<bool> {
    match s {
        "true" | "1" | "yes" => Ok(true),
        "false" | "0" | "no" => Ok(false),
        _ => bail!("expected bool, got {s:?}"),
    }
}

// ---------------------------------------------------------------------------
// TOML subset parser: [sections], key = value (string/number/bool/array).
// ---------------------------------------------------------------------------

#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    Str(String),
    Num(f64),
    Bool(bool),
    Arr(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_string(&self) -> String {
        match self {
            TomlValue::Str(s) => s.clone(),
            TomlValue::Num(n) => {
                if n.fract() == 0.0 {
                    format!("{}", *n as i64)
                } else {
                    format!("{n}")
                }
            }
            TomlValue::Bool(b) => b.to_string(),
            TomlValue::Arr(a) => {
                a.iter().map(|v| v.as_string()).collect::<Vec<_>>().join(",")
            }
        }
    }
}

#[derive(Clone, Debug, Default)]
pub struct Toml {
    /// section -> key -> value ("" = top level).
    pub sections: BTreeMap<String, BTreeMap<String, TomlValue>>,
}

impl Toml {
    pub fn parse(src: &str) -> Result<Self> {
        let mut out = Toml::default();
        let mut section = String::new();
        for (lineno, raw) in src.lines().enumerate() {
            let line = strip_comment(raw).trim().to_string();
            if line.is_empty() {
                continue;
            }
            if line.starts_with('[') {
                if !line.ends_with(']') {
                    bail!("line {}: bad section header", lineno + 1);
                }
                section = line[1..line.len() - 1].trim().to_string();
                continue;
            }
            let eq = line
                .find('=')
                .ok_or_else(|| anyhow!("line {}: expected key = value", lineno + 1))?;
            let key = line[..eq].trim().to_string();
            let value = parse_value(line[eq + 1..].trim())
                .map_err(|e| anyhow!("line {}: {e}", lineno + 1))?;
            out.sections.entry(section.clone()).or_default().insert(key, value);
        }
        Ok(out)
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&TomlValue> {
        self.sections.get(section)?.get(key)
    }

    /// Flattened `section.key` (top-level keys stay bare) -> value.
    pub fn flat(&self) -> Vec<(String, TomlValue)> {
        let mut out = Vec::new();
        for (sec, map) in &self.sections {
            for (k, v) in map {
                let key = if sec.is_empty() { k.clone() } else { format!("{sec}.{k}") };
                out.push((key, v.clone()));
            }
        }
        out
    }
}

fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<TomlValue> {
    if s.starts_with('"') && s.ends_with('"') && s.len() >= 2 {
        return Ok(TomlValue::Str(s[1..s.len() - 1].to_string()));
    }
    if s == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if s == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if s.starts_with('[') && s.ends_with(']') {
        let inner = &s[1..s.len() - 1];
        let mut items = Vec::new();
        if !inner.trim().is_empty() {
            for part in inner.split(',') {
                items.push(parse_value(part.trim())?);
            }
        }
        return Ok(TomlValue::Arr(items));
    }
    if let Ok(n) = s.parse::<f64>() {
        return Ok(TomlValue::Num(n));
    }
    // Bare strings (method names etc.) are accepted for ergonomics.
    Ok(TomlValue::Str(s.to_string()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn method_parse_roundtrip() {
        for (s, m) in [
            ("rtn", Method::Rtn),
            ("spinquant-had", Method::SpinQuantHad),
            ("SPINQUANT-NOHAD", Method::SpinQuantNoHad),
            ("quarot", Method::QuaRot),
            ("fp", Method::Float),
        ] {
            assert_eq!(Method::parse(s).unwrap(), m);
        }
        assert!(Method::parse("bogus").is_err());
    }

    #[test]
    fn bits_parse() {
        let b = Bits::parse("4-8-16").unwrap();
        assert_eq!((b.w, b.a, b.kv), (4.0, 8.0, 16.0));
        assert_eq!(b.label(), "4-8-16");
        assert!(Bits::parse("4-8").is_err());
        assert!(Bits::parse("1-8-8").is_err());
        assert!(Bits::parse("4-x-8").is_err());
    }

    #[test]
    fn toml_parses_sections_and_types() {
        let src = r#"
            # experiment config
            model = "sq-2m"
            bits = "4-4-4"     # W-A-KV
            [cayley]
            iters = 100
            lr = 1.5
            on = true
            seeds = [1, 2, 3]
        "#;
        let t = Toml::parse(src).unwrap();
        assert_eq!(t.get("", "model"), Some(&TomlValue::Str("sq-2m".into())));
        assert_eq!(t.get("cayley", "iters"), Some(&TomlValue::Num(100.0)));
        assert_eq!(t.get("cayley", "on"), Some(&TomlValue::Bool(true)));
        assert_eq!(
            t.get("cayley", "seeds"),
            Some(&TomlValue::Arr(vec![
                TomlValue::Num(1.0),
                TomlValue::Num(2.0),
                TomlValue::Num(3.0)
            ]))
        );
    }

    #[test]
    fn toml_errors() {
        assert!(Toml::parse("[unclosed").is_err());
        assert!(Toml::parse("novalue").is_err());
    }

    #[test]
    fn pipeline_overrides() {
        let mut c = PipelineConfig::default();
        c.apply_kv("method", "gptq").unwrap();
        c.apply_kv("bits", "3-8-8").unwrap();
        c.apply_kv("cayley_iters", "10").unwrap();
        assert_eq!(c.method, Method::Gptq);
        assert_eq!(c.bits.w, 3.0);
        assert_eq!(c.cayley_iters, 10);
        assert!(c.apply_kv("nope", "1").is_err());
    }

    #[test]
    fn method_properties() {
        assert!(Method::SpinQuantHad.uses_online_hadamard());
        assert!(!Method::SpinQuantNoHad.uses_online_hadamard());
        assert!(Method::SpinQuantNoHad.learns_rotation());
        assert!(Method::QuaRot.uses_rotation());
        assert!(!Method::QuaRot.learns_rotation());
        assert!(!Method::Gptq.uses_rotation());
    }
}
