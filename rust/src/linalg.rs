//! Dense linear algebra substrate (BLAS/LAPACK-free, cache-tiled).
//!
//! Sized for this project's matrices (d_model <= 256, d_ffn <= 1024):
//! matmul variants, Householder QR (random orthogonal rotations), Cholesky
//! (GPTQ Hessian), triangular solves, and Gaussian elimination inverse
//! (exact Cayley transform). Everything is f32 in row-major order.

use anyhow::{bail, Result};

use crate::tensor::Tensor;

const TILE: usize = 64;

/// C = A(m,k) @ B(k,n), cache-tiled i-k-j loop order.
pub fn matmul(a: &Tensor, b: &Tensor) -> Tensor {
    assert_eq!(a.ndim(), 2);
    assert_eq!(b.ndim(), 2);
    let (m, k) = (a.shape[0], a.shape[1]);
    let (k2, n) = (b.shape[0], b.shape[1]);
    assert_eq!(k, k2, "matmul inner dims {k} vs {k2}");
    let mut c = Tensor::zeros(&[m, n]);
    let (ad, bd, cd) = (&a.data, &b.data, &mut c.data);
    for i0 in (0..m).step_by(TILE) {
        let i1 = (i0 + TILE).min(m);
        for k0 in (0..k).step_by(TILE) {
            let k1 = (k0 + TILE).min(k);
            for i in i0..i1 {
                let crow = &mut cd[i * n..(i + 1) * n];
                for kk in k0..k1 {
                    let aik = ad[i * k + kk];
                    if aik == 0.0 {
                        continue;
                    }
                    let brow = &bd[kk * n..(kk + 1) * n];
                    for (cv, bv) in crow.iter_mut().zip(brow) {
                        *cv += aik * bv;
                    }
                }
            }
        }
    }
    c
}

/// C = A^T(m,k) @ B(m,n) — A stored as (m, k).
pub fn matmul_tn(a: &Tensor, b: &Tensor) -> Tensor {
    matmul(&transpose(a), b)
}

/// C = A(m,k) @ B^T(n,k) — B stored as (n, k).
pub fn matmul_nt(a: &Tensor, b: &Tensor) -> Tensor {
    assert_eq!(a.ndim(), 2);
    assert_eq!(b.ndim(), 2);
    let (m, k) = (a.shape[0], a.shape[1]);
    let (n, k2) = (b.shape[0], b.shape[1]);
    assert_eq!(k, k2);
    let mut c = Tensor::zeros(&[m, n]);
    for i in 0..m {
        let arow = a.row(i);
        for j in 0..n {
            let brow = b.row(j);
            let mut s = 0.0f32;
            for t in 0..k {
                s += arow[t] * brow[t];
            }
            c.data[i * n + j] = s;
        }
    }
    c
}

pub fn transpose(a: &Tensor) -> Tensor {
    assert_eq!(a.ndim(), 2);
    let (m, n) = (a.shape[0], a.shape[1]);
    let mut t = Tensor::zeros(&[n, m]);
    for i in 0..m {
        for j in 0..n {
            t.data[j * m + i] = a.data[i * n + j];
        }
    }
    t
}

/// y = x @ A for a single row vector x (len k), A (k, n).
pub fn vecmat(x: &[f32], a: &Tensor) -> Vec<f32> {
    let (k, n) = (a.shape[0], a.shape[1]);
    assert_eq!(x.len(), k);
    let mut y = vec![0.0f32; n];
    for (kk, &xv) in x.iter().enumerate() {
        if xv == 0.0 {
            continue;
        }
        let row = a.row(kk);
        for (yv, av) in y.iter_mut().zip(row) {
            *yv += xv * av;
        }
    }
    let _ = k;
    y
}

/// Householder QR; returns Q (m, m) with det-sign fixup so the distribution
/// over Q is Haar when A is Gaussian (random rotation construction, §2.2).
pub fn qr_orthogonal(a: &Tensor) -> Tensor {
    assert_eq!(a.ndim(), 2);
    let n = a.shape[0];
    assert_eq!(n, a.shape[1], "square input required");
    let mut r = a.clone();
    let mut q = Tensor::eye(n);
    for col in 0..n - 1 {
        // Householder vector for column `col` below the diagonal.
        let mut norm = 0.0f32;
        for i in col..n {
            let v = r.at2(i, col);
            norm += v * v;
        }
        norm = norm.sqrt();
        if norm < 1e-12 {
            continue;
        }
        let r0 = r.at2(col, col);
        let alpha = if r0 >= 0.0 { -norm } else { norm };
        let mut v = vec![0.0f32; n];
        v[col] = r0 - alpha;
        for i in col + 1..n {
            v[i] = r.at2(i, col);
        }
        let vtv: f32 = v.iter().map(|x| x * x).sum();
        if vtv < 1e-20 {
            continue;
        }
        let beta = 2.0 / vtv;
        // R <- (I - beta v v^T) R
        for j in col..n {
            let mut dot = 0.0f32;
            for i in col..n {
                dot += v[i] * r.at2(i, j);
            }
            let f = beta * dot;
            for i in col..n {
                let cur = r.at2(i, j);
                r.set2(i, j, cur - f * v[i]);
            }
        }
        // Q <- Q (I - beta v v^T)
        for i in 0..n {
            let mut dot = 0.0f32;
            for jj in col..n {
                dot += q.at2(i, jj) * v[jj];
            }
            let f = beta * dot;
            for jj in col..n {
                let cur = q.at2(i, jj);
                q.set2(i, jj, cur - f * v[jj]);
            }
        }
    }
    // Sign fixup: make diag(R) positive so Q is Haar-distributed.
    for j in 0..n {
        if r.at2(j, j) < 0.0 {
            for i in 0..n {
                let cur = q.at2(i, j);
                q.set2(i, j, -cur);
            }
        }
    }
    q
}

/// Cholesky factorization A = L L^T (lower). Errors if not SPD.
pub fn cholesky(a: &Tensor) -> Result<Tensor> {
    assert_eq!(a.ndim(), 2);
    let n = a.shape[0];
    assert_eq!(n, a.shape[1]);
    let mut l = Tensor::zeros(&[n, n]);
    for i in 0..n {
        for j in 0..=i {
            let mut s = a.at2(i, j);
            for k in 0..j {
                s -= l.at2(i, k) * l.at2(j, k);
            }
            if i == j {
                if s <= 0.0 {
                    bail!("matrix not SPD at pivot {i} (s={s})");
                }
                l.set2(i, j, s.sqrt());
            } else {
                l.set2(i, j, s / l.at2(j, j));
            }
        }
    }
    Ok(l)
}

/// Inverse of an SPD matrix via Cholesky.
pub fn spd_inverse(a: &Tensor) -> Result<Tensor> {
    let n = a.shape[0];
    let l = cholesky(a)?;
    // Solve L L^T X = I column by column.
    let mut inv = Tensor::zeros(&[n, n]);
    let mut y = vec![0.0f32; n];
    for col in 0..n {
        // forward solve L y = e_col
        for i in 0..n {
            let mut s = if i == col { 1.0 } else { 0.0 };
            for k in 0..i {
                s -= l.at2(i, k) * y[k];
            }
            y[i] = s / l.at2(i, i);
        }
        // back solve L^T x = y
        for i in (0..n).rev() {
            let mut s = y[i];
            for k in i + 1..n {
                s -= l.at2(k, i) * inv.at2(k, col);
            }
            inv.set2(i, col, s / l.at2(i, i));
        }
    }
    Ok(inv)
}

/// General matrix inverse by Gauss-Jordan with partial pivoting.
pub fn inverse(a: &Tensor) -> Result<Tensor> {
    assert_eq!(a.ndim(), 2);
    let n = a.shape[0];
    assert_eq!(n, a.shape[1]);
    let mut m = a.clone();
    let mut inv = Tensor::eye(n);
    for col in 0..n {
        // pivot
        let mut p = col;
        let mut best = m.at2(col, col).abs();
        for r in col + 1..n {
            let v = m.at2(r, col).abs();
            if v > best {
                best = v;
                p = r;
            }
        }
        if best < 1e-12 {
            bail!("singular matrix at column {col}");
        }
        if p != col {
            for j in 0..n {
                let (a1, a2) = (m.at2(col, j), m.at2(p, j));
                m.set2(col, j, a2);
                m.set2(p, j, a1);
                let (b1, b2) = (inv.at2(col, j), inv.at2(p, j));
                inv.set2(col, j, b2);
                inv.set2(p, j, b1);
            }
        }
        let d = m.at2(col, col);
        for j in 0..n {
            m.set2(col, j, m.at2(col, j) / d);
            inv.set2(col, j, inv.at2(col, j) / d);
        }
        for r in 0..n {
            if r == col {
                continue;
            }
            let f = m.at2(r, col);
            if f == 0.0 {
                continue;
            }
            for j in 0..n {
                let mv = m.at2(r, j) - f * m.at2(col, j);
                m.set2(r, j, mv);
                let iv = inv.at2(r, j) - f * inv.at2(col, j);
                inv.set2(r, j, iv);
            }
        }
    }
    Ok(inv)
}

/// || A^T A - I ||_inf — orthonormality check used by rotation/cayley tests.
pub fn orthonormality_error(a: &Tensor) -> f32 {
    let n = a.shape[0];
    let gram = matmul_tn(a, a);
    let mut err = 0.0f32;
    for i in 0..n {
        for j in 0..n {
            let target = if i == j { 1.0 } else { 0.0 };
            err = err.max((gram.at2(i, j) - target).abs());
        }
    }
    err
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Prng;

    fn randn(shape: &[usize], seed: u64) -> Tensor {
        let mut p = Prng::new(seed);
        let n: usize = shape.iter().product();
        Tensor::new(shape.to_vec(), (0..n).map(|_| p.normal()).collect())
    }

    #[test]
    fn matmul_small() {
        let a = Tensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let b = Tensor::new(vec![3, 2], vec![7., 8., 9., 10., 11., 12.]);
        let c = matmul(&a, &b);
        assert_eq!(c.data, vec![58., 64., 139., 154.]);
    }

    #[test]
    fn matmul_identity() {
        let a = randn(&[17, 17], 1);
        let c = matmul(&a, &Tensor::eye(17));
        for (x, y) in a.data.iter().zip(&c.data) {
            assert!((x - y).abs() < 1e-5);
        }
    }

    #[test]
    fn matmul_variants_agree() {
        let a = randn(&[9, 13], 2);
        let b = randn(&[13, 7], 3);
        let c1 = matmul(&a, &b);
        let c2 = matmul_nt(&a, &transpose(&b));
        let c3 = matmul_tn(&transpose(&a), &b);
        for ((x, y), z) in c1.data.iter().zip(&c2.data).zip(&c3.data) {
            assert!((x - y).abs() < 1e-4);
            assert!((x - z).abs() < 1e-4);
        }
    }

    #[test]
    fn vecmat_matches_matmul() {
        let a = randn(&[6, 5], 4);
        let x: Vec<f32> = (0..6).map(|i| i as f32 * 0.3 - 1.0).collect();
        let xm = Tensor::new(vec![1, 6], x.clone());
        let want = matmul(&xm, &a);
        let got = vecmat(&x, &a);
        for (g, w) in got.iter().zip(&want.data) {
            assert!((g - w).abs() < 1e-5);
        }
    }

    #[test]
    fn qr_produces_orthogonal() {
        for seed in 0..4 {
            let a = randn(&[32, 32], seed);
            let q = qr_orthogonal(&a);
            assert!(orthonormality_error(&q) < 1e-4, "seed {seed}");
        }
    }

    #[test]
    fn cholesky_roundtrip() {
        let a = randn(&[12, 20], 5);
        // SPD: A A^T + I
        let mut spd = matmul_nt(&a, &a);
        for i in 0..12 {
            let v = spd.at2(i, i) + 1.0;
            spd.set2(i, i, v);
        }
        let l = cholesky(&spd).unwrap();
        let back = matmul_nt(&l, &l);
        for (x, y) in spd.data.iter().zip(&back.data) {
            assert!((x - y).abs() < 1e-3);
        }
    }

    #[test]
    fn cholesky_rejects_non_spd() {
        let m = Tensor::new(vec![2, 2], vec![1.0, 2.0, 2.0, 1.0]); // eigenvalues 3, -1
        assert!(cholesky(&m).is_err());
    }

    #[test]
    fn spd_inverse_works() {
        let a = randn(&[10, 16], 6);
        let mut spd = matmul_nt(&a, &a);
        for i in 0..10 {
            let v = spd.at2(i, i) + 2.0;
            spd.set2(i, i, v);
        }
        let inv = spd_inverse(&spd).unwrap();
        let prod = matmul(&spd, &inv);
        for i in 0..10 {
            for j in 0..10 {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((prod.at2(i, j) - want).abs() < 1e-3);
            }
        }
    }

    #[test]
    fn general_inverse_works() {
        let mut a = randn(&[14, 14], 7);
        for i in 0..14 {
            let v = a.at2(i, i) + 4.0;
            a.set2(i, i, v); // diagonally dominant => nonsingular
        }
        let inv = inverse(&a).unwrap();
        let prod = matmul(&a, &inv);
        for i in 0..14 {
            for j in 0..14 {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((prod.at2(i, j) - want).abs() < 1e-3);
            }
        }
    }

    #[test]
    fn inverse_rejects_singular() {
        let a = Tensor::new(vec![2, 2], vec![1., 2., 2., 4.]);
        assert!(inverse(&a).is_err());
    }
}
