//! Quantization core (paper Eq. 1) — the L3 twin of the Pallas fake-quant
//! kernel, bit-exact with `python/compile/kernels/ref.py` (same EPS, same
//! round-half-to-even), verified end-to-end through PJRT by integration
//! tests.
//!
//! Covers: symmetric/asymmetric grids, per-tensor / per-row(-token) /
//! per-column(-output-channel) granularity, range clipping (Table 12),
//! integer code emission + int4/int8 packing (memory accounting for the
//! serving path), and the error metrics used across Figs. 3/8.

use crate::tensor::Tensor;

pub const EPS: f32 = 1e-8;

/// Quantization grid granularity.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Granularity {
    /// One scale for the whole tensor.
    PerTensor,
    /// One scale per row (per-token activation quantization).
    PerRow,
    /// One scale per column (per-output-channel weight quantization).
    PerColumn,
}

/// Full quantizer specification.
#[derive(Clone, Copy, Debug)]
pub struct QuantSpec {
    pub bits: f32,
    pub symmetric: bool,
    pub clip_ratio: f32,
    pub granularity: Granularity,
}

impl QuantSpec {
    pub fn weight(bits: f32) -> Self {
        // Paper default: per-output-channel symmetric weight grids.
        Self { bits, symmetric: true, clip_ratio: 1.0, granularity: Granularity::PerColumn }
    }

    pub fn activation(bits: f32) -> Self {
        // Paper default (Table 12): per-token asymmetric, no clipping.
        Self { bits, symmetric: false, clip_ratio: 1.0, granularity: Granularity::PerRow }
    }

    pub fn kv(bits: f32) -> Self {
        Self { bits, symmetric: false, clip_ratio: 1.0, granularity: Granularity::PerRow }
    }

    pub fn is_noop(&self) -> bool {
        self.bits >= 16.0
    }
}

/// Quantize-dequantize one contiguous group in place.
/// Matches ref.py: asymmetric levels 2^b - 1 (zero-point = min), symmetric
/// levels ±(2^(b-1)-1) with clamp at -2^(b-1).
fn fake_quant_group(xs: &mut [f32], bits: f32, symmetric: bool, clip: f32) {
    if xs.is_empty() {
        return;
    }
    let mut mn = f32::INFINITY;
    let mut mx = f32::NEG_INFINITY;
    for &x in xs.iter() {
        mn = mn.min(x);
        mx = mx.max(x);
    }
    mn *= clip;
    mx *= clip;
    if symmetric {
        let absmax = mn.abs().max(mx.abs());
        let n_sym = (bits - 1.0).exp2() - 1.0;
        let scale = (absmax / n_sym).max(EPS);
        for x in xs.iter_mut() {
            let q = (*x / scale).round_ties_even().clamp(-n_sym - 1.0, n_sym);
            *x = q * scale;
        }
    } else {
        let n_asym = bits.exp2() - 1.0;
        let scale = ((mx - mn) / n_asym).max(EPS);
        for x in xs.iter_mut() {
            let q = ((*x - mn) / scale).round_ties_even().clamp(0.0, n_asym);
            *x = q * scale + mn;
        }
    }
}

/// Quantize-dequantize every column of a row-major `rows x cols` buffer in
/// place, one grid per column — without the old per-column strided
/// gather/scatter copy. Two row-major passes instead: per-column ranges
/// first, then per-column grids applied element-wise. Same arithmetic per
/// element as [`fake_quant_group`] on the gathered column (tested against
/// the transposed per-row path), but cache-friendly and allocation-lean.
fn fake_quant_columns(
    data: &mut [f32],
    rows: usize,
    cols: usize,
    bits: f32,
    symmetric: bool,
    clip: f32,
) {
    if rows == 0 || cols == 0 {
        return;
    }
    // Pass 1 (row-major): per-column min/max.
    let mut mn = vec![f32::INFINITY; cols];
    let mut mx = vec![f32::NEG_INFINITY; cols];
    for r in 0..rows {
        let row = &data[r * cols..(r + 1) * cols];
        for (c, &x) in row.iter().enumerate() {
            mn[c] = mn[c].min(x);
            mx[c] = mx[c].max(x);
        }
    }
    // Per-column grid parameters, exactly as fake_quant_group derives them.
    if symmetric {
        let n_sym = (bits - 1.0).exp2() - 1.0;
        let scale: Vec<f32> = (0..cols)
            .map(|c| {
                let absmax = (mn[c] * clip).abs().max((mx[c] * clip).abs());
                (absmax / n_sym).max(EPS)
            })
            .collect();
        // Pass 2 (row-major): snap to the column's grid.
        for r in 0..rows {
            let row = &mut data[r * cols..(r + 1) * cols];
            for (c, x) in row.iter_mut().enumerate() {
                let q = (*x / scale[c]).round_ties_even().clamp(-n_sym - 1.0, n_sym);
                *x = q * scale[c];
            }
        }
    } else {
        let n_asym = bits.exp2() - 1.0;
        let (zero, scale): (Vec<f32>, Vec<f32>) = (0..cols)
            .map(|c| {
                let (lo, hi) = (mn[c] * clip, mx[c] * clip);
                (lo, ((hi - lo) / n_asym).max(EPS))
            })
            .unzip();
        for r in 0..rows {
            let row = &mut data[r * cols..(r + 1) * cols];
            for (c, x) in row.iter_mut().enumerate() {
                let q = ((*x - zero[c]) / scale[c]).round_ties_even().clamp(0.0, n_asym);
                *x = q * scale[c] + zero[c];
            }
        }
    }
}

/// Quantize-dequantize a tensor according to `spec`.
pub fn fake_quant(t: &Tensor, spec: &QuantSpec) -> Tensor {
    if spec.is_noop() {
        return t.clone();
    }
    let mut out = t.clone();
    match spec.granularity {
        Granularity::PerTensor => {
            fake_quant_group(&mut out.data, spec.bits, spec.symmetric, spec.clip_ratio);
        }
        Granularity::PerRow => {
            let n = out.last_dim();
            let rows = out.rows_2d();
            for r in 0..rows {
                fake_quant_group(
                    &mut out.data[r * n..(r + 1) * n],
                    spec.bits,
                    spec.symmetric,
                    spec.clip_ratio,
                );
            }
        }
        Granularity::PerColumn => {
            assert_eq!(t.ndim(), 2, "per-column quantization expects 2D weights");
            let (rows, cols) = (t.shape[0], t.shape[1]);
            fake_quant_columns(
                &mut out.data,
                rows,
                cols,
                spec.bits,
                spec.symmetric,
                spec.clip_ratio,
            );
        }
    }
    out
}

/// Quantize one group to integer codes + (scale, zero) metadata.
pub fn quantize_group_codes(xs: &[f32], bits: f32, symmetric: bool) -> (Vec<i32>, f32, f32) {
    let mut mn = f32::INFINITY;
    let mut mx = f32::NEG_INFINITY;
    for &x in xs {
        mn = mn.min(x);
        mx = mx.max(x);
    }
    if symmetric {
        let n_sym = (bits - 1.0).exp2() - 1.0;
        let scale = (mn.abs().max(mx.abs()) / n_sym).max(EPS);
        let codes = xs
            .iter()
            .map(|&x| (x / scale).round_ties_even().clamp(-n_sym - 1.0, n_sym) as i32)
            .collect();
        (codes, scale, 0.0)
    } else {
        let n_asym = bits.exp2() - 1.0;
        let scale = ((mx - mn) / n_asym).max(EPS);
        let codes = xs
            .iter()
            .map(|&x| ((x - mn) / scale).round_ties_even().clamp(0.0, n_asym) as i32)
            .collect();
        (codes, scale, mn)
    }
}

pub fn dequantize_codes(codes: &[i32], scale: f32, zero: f32) -> Vec<f32> {
    codes.iter().map(|&q| q as f32 * scale + zero).collect()
}

/// Pack unsigned 4-bit codes two-per-byte (low nibble first) — the storage
/// format the serving path would ship; used for memory-footprint accounting.
pub fn pack_int4(codes: &[i32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(codes.len().div_ceil(2));
    for pair in codes.chunks(2) {
        let lo = (pair[0].clamp(0, 15)) as u8;
        let hi = if pair.len() > 1 { (pair[1].clamp(0, 15)) as u8 } else { 0 };
        out.push(lo | (hi << 4));
    }
    out
}

pub fn unpack_int4(bytes: &[u8], n: usize) -> Vec<i32> {
    let mut out = Vec::with_capacity(n);
    for &b in bytes {
        out.push((b & 0x0F) as i32);
        if out.len() < n {
            out.push((b >> 4) as i32);
        }
        if out.len() >= n {
            break;
        }
    }
    out.truncate(n);
    out
}

/// Pack *symmetric* (signed) 4-bit codes in [-8, 7] two-per-byte using an
/// offset-binary nibble (code + 8, so -8 -> 0x0 and 7 -> 0xF; low nibble
/// first). `pack_int4` is the unsigned twin and clamps negatives to 0 —
/// feeding it symmetric codes silently destroys the whole negative half of
/// the grid, which is why the quantized KV pages use this pair instead.
pub fn pack_int4_symmetric(codes: &[i32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(codes.len().div_ceil(2));
    for pair in codes.chunks(2) {
        let lo = (pair[0].clamp(-8, 7) + 8) as u8;
        let hi = if pair.len() > 1 { (pair[1].clamp(-8, 7) + 8) as u8 } else { 0 };
        out.push(lo | (hi << 4));
    }
    out
}

pub fn unpack_int4_symmetric(bytes: &[u8], n: usize) -> Vec<i32> {
    let mut out = Vec::with_capacity(n);
    for &b in bytes {
        out.push((b & 0x0F) as i32 - 8);
        if out.len() < n {
            out.push((b >> 4) as i32 - 8);
        }
        if out.len() >= n {
            break;
        }
    }
    out.truncate(n);
    out
}

/// Bytes needed to store a tensor at `bits` (+ per-group scale/zero in f16
/// equivalents) — the memory-saving headline of PTQ.
pub fn quantized_size_bytes(numel: usize, groups: usize, bits: f32, symmetric: bool) -> usize {
    let payload = (numel as f64 * bits as f64 / 8.0).ceil() as usize;
    let meta_per_group = if symmetric { 2 } else { 4 }; // f16 scale (+ zero)
    payload + groups * meta_per_group
}

/// Quantization error metrics (Fig. 3b/c).
pub fn quant_error_mse(t: &Tensor, spec: &QuantSpec) -> f32 {
    t.mse(&fake_quant(t, spec))
}

/// Signal-to-quantization-noise ratio in dB.
pub fn sqnr_db(t: &Tensor, spec: &QuantSpec) -> f32 {
    Tensor::snr_db(t, &fake_quant(t, spec))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop::{forall, Gen};

    fn spec(bits: f32, sym: bool, g: Granularity) -> QuantSpec {
        QuantSpec { bits, symmetric: sym, clip_ratio: 1.0, granularity: g }
    }

    #[test]
    fn noop_at_16_bits() {
        let mut g = Gen { rng: crate::util::prng::Prng::new(1) };
        let t = g.tensor(&[8, 16], 3.0);
        let q = fake_quant(&t, &spec(16.0, false, Granularity::PerRow));
        assert_eq!(t, q);
    }

    #[test]
    fn prop_idempotent() {
        // fake_quant(fake_quant(x)) == fake_quant(x): quantized values lie on
        // the grid, so re-quantizing with the same spec is a fixed point.
        forall(11, 40, |g: &mut Gen| {
            let rows = g.int(1, 20);
            let cols = g.int(2, 40);
            let scale = g.f32(0.1, 8.0);
            let t = g.tensor(&[rows, cols], scale);
            let sp = spec(
                *g.pick(&[2.0, 3.0, 4.0, 8.0]),
                g.bool(),
                *g.pick(&[Granularity::PerTensor, Granularity::PerRow]),
            );
            let q1 = fake_quant(&t, &sp);
            let q2 = fake_quant(&q1, &sp);
            if q1.sub(&q2).max_abs() > 1e-4 * (1.0 + q1.max_abs()) {
                return Err("not idempotent".into());
            }
            Ok(())
        });
    }

    #[test]
    fn prop_level_count_bound() {
        forall(12, 30, |g: &mut Gen| {
            let bits = *g.pick(&[2.0f32, 3.0, 4.0]);
            let t = g.tensor(&[1, 64], 5.0);
            let q = fake_quant(&t, &spec(bits, false, Granularity::PerRow));
            let mut vals: Vec<i64> = q.data.iter().map(|&x| (x * 1e4).round() as i64).collect();
            vals.sort_unstable();
            vals.dedup();
            let max_levels = (bits.exp2() as usize) + 1; // rounding slack
            if vals.len() > max_levels {
                return Err(format!("{} distinct values for {} bits", vals.len(), bits));
            }
            Ok(())
        });
    }

    #[test]
    fn prop_error_decreases_with_bits() {
        forall(13, 30, |g: &mut Gen| {
            let t = g.tensor(&[4, 32], 2.0);
            let e2 = quant_error_mse(&t, &spec(2.0, false, Granularity::PerRow));
            let e4 = quant_error_mse(&t, &spec(4.0, false, Granularity::PerRow));
            let e8 = quant_error_mse(&t, &spec(8.0, false, Granularity::PerRow));
            if !(e2 >= e4 && e4 >= e8) {
                return Err(format!("e2={e2} e4={e4} e8={e8}"));
            }
            Ok(())
        });
    }

    #[test]
    fn prop_values_within_range() {
        forall(14, 40, |g: &mut Gen| {
            let t = g.tensor(&[3, 24], 4.0);
            let sp = spec(4.0, false, Granularity::PerRow);
            let q = fake_quant(&t, &sp);
            for r in 0..3 {
                let row = t.row(r);
                let (mn, mx) = row
                    .iter()
                    .fold((f32::INFINITY, f32::NEG_INFINITY), |(a, b), &x| (a.min(x), b.max(x)));
                for &v in q.row(r) {
                    if v < mn - 1e-4 || v > mx + 1e-4 {
                        return Err(format!("value {v} outside [{mn},{mx}]"));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn symmetric_preserves_zero() {
        let t = Tensor::new(vec![1, 4], vec![0.0, 1.0, -2.0, 3.0]);
        let q = fake_quant(&t, &spec(4.0, true, Granularity::PerRow));
        assert_eq!(q.data[0], 0.0);
    }

    #[test]
    fn prop_per_column_matches_strided_reference_bitexact() {
        // The two-pass row-major implementation must reproduce the old
        // per-column gather/scatter loop bit for bit — it is a pure memory
        // access-pattern change, not a numerics change.
        forall(15, 60, |g: &mut Gen| {
            let rows = g.int(1, 24);
            let cols = g.int(1, 24);
            let scale = g.f32(0.1, 6.0);
            let t = g.tensor(&[rows, cols], scale);
            let sym = g.bool();
            let sp = spec(*g.pick(&[2.0, 4.0, 8.0]), sym, Granularity::PerColumn);
            let fast = fake_quant(&t, &sp);
            // Reference: the old strided gather/scatter column loop.
            let mut reference = t.clone();
            let mut col = vec![0.0f32; rows];
            for c in 0..cols {
                for r in 0..rows {
                    col[r] = reference.data[r * cols + c];
                }
                fake_quant_group(&mut col, sp.bits, sp.symmetric, sp.clip_ratio);
                for r in 0..rows {
                    reference.data[r * cols + c] = col[r];
                }
            }
            if fast.data != reference.data {
                return Err(format!("{rows}x{cols} {sp:?}: diverged from strided reference"));
            }
            Ok(())
        });
    }

    #[test]
    fn per_column_matches_transposed_per_row() {
        let mut g = Gen { rng: crate::util::prng::Prng::new(5) };
        let t = g.tensor(&[12, 7], 2.0);
        let qc = fake_quant(&t, &spec(4.0, true, Granularity::PerColumn));
        let tr = crate::linalg::transpose(&t);
        let qr = fake_quant(&tr, &spec(4.0, true, Granularity::PerRow));
        let qr_t = crate::linalg::transpose(&qr);
        assert!(qc.sub(&qr_t).max_abs() < 1e-6);
    }

    #[test]
    fn codes_roundtrip() {
        let mut g = Gen { rng: crate::util::prng::Prng::new(6) };
        let t = g.tensor(&[64], 3.0);
        for sym in [false, true] {
            let (codes, scale, zero) = quantize_group_codes(&t.data, 4.0, sym);
            let deq = dequantize_codes(&codes, scale, zero);
            let direct = {
                let mut v = t.data.clone();
                fake_quant_group(&mut v, 4.0, sym, 1.0);
                v
            };
            for (a, b) in deq.iter().zip(&direct) {
                assert!((a - b).abs() < 1e-5, "sym={sym}");
            }
        }
    }

    #[test]
    fn int4_pack_roundtrip() {
        let codes: Vec<i32> = (0..31).map(|i| i % 16).collect();
        let packed = pack_int4(&codes);
        assert_eq!(packed.len(), 16);
        assert_eq!(unpack_int4(&packed, 31), codes);
    }

    #[test]
    fn prop_int4_pack_roundtrips_any_length() {
        // Odd lengths exercise the half-filled trailing byte.
        forall(21, 60, |g: &mut Gen| {
            let n = g.int(1, 65);
            let codes: Vec<i32> = (0..n).map(|_| g.int(0, 15) as i32).collect();
            let packed = pack_int4(&codes);
            if packed.len() != n.div_ceil(2) {
                return Err(format!("{n} codes packed into {} bytes", packed.len()));
            }
            let back = unpack_int4(&packed, n);
            if back != codes {
                return Err(format!("roundtrip mismatch at n={n}"));
            }
            Ok(())
        });
    }

    #[test]
    fn int4_symmetric_pack_preserves_negative_codes() {
        // The regression this PR fixes: the unsigned packer clamps the
        // negative half of a symmetric grid to 0; the offset-binary pair
        // must round-trip the full [-8, 7] range instead.
        let codes: Vec<i32> = (-8..8).collect();
        let clamped = unpack_int4(&pack_int4(&codes), codes.len());
        assert!(clamped[..8].iter().all(|&c| c == 0), "unsigned packer zeroes negatives");
        let packed = pack_int4_symmetric(&codes);
        assert_eq!(packed.len(), 8);
        assert_eq!(unpack_int4_symmetric(&packed, codes.len()), codes);
    }

    #[test]
    fn prop_int4_symmetric_pack_roundtrips_codes_and_values_any_length() {
        // Quantize real values symmetrically at 4 bits, pack, unpack,
        // dequantize: codes must survive exactly (odd lengths exercise the
        // half-filled trailing byte) and the dequantized values must equal
        // dequantizing the original codes — i.e. packing is lossless.
        forall(23, 60, |g: &mut Gen| {
            let n = g.int(1, 65);
            let scale = g.f32(0.05, 6.0);
            let t = g.tensor(&[n], scale);
            let (codes, s, z) = quantize_group_codes(&t.data, 4.0, true);
            let packed = pack_int4_symmetric(&codes);
            if packed.len() != n.div_ceil(2) {
                return Err(format!("{n} codes packed into {} bytes", packed.len()));
            }
            let back = unpack_int4_symmetric(&packed, n);
            if back != codes {
                return Err(format!("code roundtrip mismatch at n={n}"));
            }
            if dequantize_codes(&back, s, z) != dequantize_codes(&codes, s, z) {
                return Err(format!("dequantized values diverged at n={n}"));
            }
            Ok(())
        });
    }

    #[test]
    fn prop_codes_roundtrip_within_one_step() {
        // dequantize(quantize(x)) must stay within half a quantization step
        // of x for every in-range value, symmetric and asymmetric alike.
        forall(22, 60, |g: &mut Gen| {
            let n = g.int(2, 96);
            let scale = g.f32(0.05, 6.0);
            let t = g.tensor(&[n], scale);
            let bits = *g.pick(&[3.0f32, 4.0, 8.0]);
            let sym = g.bool();
            let (codes, s, z) = quantize_group_codes(&t.data, bits, sym);
            let deq = dequantize_codes(&codes, s, z);
            // Codes must fit the advertised integer grid.
            let (lo, hi) = if sym {
                let m = (bits - 1.0).exp2() as i32;
                (-m, m - 1)
            } else {
                (0, bits.exp2() as i32 - 1)
            };
            for &c in &codes {
                if c < lo || c > hi {
                    return Err(format!("code {c} outside [{lo},{hi}] at {bits} bits"));
                }
            }
            let tol = 0.5 * s * (1.0 + 1e-3) + 1e-6;
            for (&x, &d) in t.data.iter().zip(&deq) {
                if (x - d).abs() > tol {
                    return Err(format!(
                        "sym={sym} bits={bits}: |{x} - {d}| = {} > step/2 = {tol}",
                        (x - d).abs()
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn size_accounting() {
        // 4-bit, per-row groups of 128: 1M weights -> ~0.5MB + metadata.
        let bytes = quantized_size_bytes(1 << 20, (1 << 20) / 128, 4.0, true);
        assert!(bytes > (1 << 19) && bytes < (1 << 19) + 40_000);
    }

    #[test]
    fn clipping_reduces_range() {
        let t = Tensor::new(vec![1, 5], vec![-10.0, -1.0, 0.0, 1.0, 10.0]);
        let clipped = QuantSpec {
            bits: 8.0,
            symmetric: false,
            clip_ratio: 0.5,
            granularity: Granularity::PerRow,
        };
        let q = fake_quant(&t, &clipped);
        assert!(q.max_abs() <= 5.0 + 1e-4);
    }

    #[test]
    fn rotation_improves_sqnr_on_outliers() {
        // Integration of quant + hadamard: the paper's mechanism end-to-end.
        let mut g = Gen { rng: crate::util::prng::Prng::new(77) };
        let x = g.outlier_tensor(128, 64, 25.0);
        let sp = spec(4.0, false, Granularity::PerRow);
        let before = sqnr_db(&x, &sp);
        let after = sqnr_db(&crate::hadamard::fwht_last_axis(&x), &sp);
        assert!(after > before + 3.0, "before={before} after={after}");
    }
}
