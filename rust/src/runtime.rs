//! PJRT runtime: loads the AOT HLO-text artifacts and executes them from
//! the rust request path (the only place model compute ever happens at
//! run time — python is build-time only).
//!
//! Pattern (see /opt/xla-example/load_hlo): `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute::<Literal>`. HLO *text* is the interchange
//! format because xla_extension 0.5.1 rejects jax≥0.5's 64-bit-id protos.
//!
//! The manifest (written by aot.py) pins the input ABI; [`Executable::run`]
//! validates count/shape/dtype before dispatch so a drifted artifact fails
//! loudly instead of producing garbage.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::model::{ArtifactSpec, Manifest};
use crate::tensor::Tensor;

/// An input value for artifact execution.
#[derive(Clone, Debug)]
pub enum Value {
    F32(Tensor),
    I32(Vec<i32>, Vec<usize>),
    ScalarI32(i32),
}

impl Value {
    pub fn tokens(batch: &[Vec<i32>], b: usize, s: usize) -> Value {
        let mut flat = Vec::with_capacity(b * s);
        for row in batch.iter().take(b) {
            assert_eq!(row.len(), s);
            flat.extend_from_slice(row);
        }
        // Pad missing rows by repeating the last one (callers mask them out).
        while flat.len() < b * s {
            let start = flat.len() - s;
            let repeat: Vec<i32> = flat[start..].to_vec();
            flat.extend(repeat);
        }
        Value::I32(flat, vec![b, s])
    }

    fn shape(&self) -> Vec<usize> {
        match self {
            Value::F32(t) => t.shape.clone(),
            Value::I32(_, s) => s.clone(),
            Value::ScalarI32(_) => vec![],
        }
    }

    fn dtype(&self) -> &'static str {
        match self {
            Value::F32(_) => "float32",
            Value::I32(..) | Value::ScalarI32(_) => "int32",
        }
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        Ok(match self {
            Value::F32(t) => {
                let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(&t.data).reshape(&dims)?
            }
            Value::I32(v, shape) => {
                let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(v).reshape(&dims)?
            }
            Value::ScalarI32(v) => xla::Literal::scalar(*v),
        })
    }
}

/// The PJRT client (one per process).
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile one artifact.
    pub fn load(&self, manifest: &Manifest, model: &str, artifact: &str) -> Result<Executable> {
        let spec = manifest.artifact(model, artifact)?;
        let path = manifest.artifact_path(&spec);
        self.load_spec(&path, spec, &format!("{model}/{artifact}"))
    }

    pub fn load_spec(&self, path: &Path, spec: ArtifactSpec, label: &str) -> Result<Executable> {
        let t0 = std::time::Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).with_context(|| format!("compiling {label}"))?;
        crate::debug!("compiled {label} in {:.1}ms", t0.elapsed().as_secs_f64() * 1e3);
        Ok(Executable { exe, spec, label: label.to_string() })
    }
}

/// A compiled artifact + its manifest ABI.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub spec: ArtifactSpec,
    pub label: String,
}

impl Executable {
    pub fn n_inputs(&self) -> usize {
        self.spec.inputs.len()
    }

    /// Validate inputs against the manifest ABI.
    fn validate(&self, inputs: &[Value]) -> Result<()> {
        if inputs.len() != self.spec.inputs.len() {
            bail!(
                "{}: {} inputs provided, artifact expects {}",
                self.label,
                inputs.len(),
                self.spec.inputs.len()
            );
        }
        for (v, (name, shape, dtype)) in inputs.iter().zip(&self.spec.inputs) {
            if &v.shape() != shape {
                bail!(
                    "{}: input {name:?} shape {:?}, artifact expects {:?}",
                    self.label,
                    v.shape(),
                    shape
                );
            }
            if v.dtype() != dtype {
                bail!("{}: input {name:?} dtype {} != {}", self.label, v.dtype(), dtype);
            }
        }
        Ok(())
    }

    /// Execute with validation; returns output tensors in manifest order.
    pub fn run(&self, inputs: &[Value]) -> Result<Vec<Tensor>> {
        self.validate(inputs)?;
        let literals: Vec<xla::Literal> =
            inputs.iter().map(|v| v.to_literal()).collect::<Result<_>>()?;
        self.run_literals(&literals)
    }

    /// Execute pre-converted literals (hot path: callers cache the weight
    /// literals across calls and only rebuild the small dynamic inputs).
    pub fn run_literals(&self, literals: &[xla::Literal]) -> Result<Vec<Tensor>> {
        let bufs = self.exe.execute::<xla::Literal>(literals)?;
        let result = bufs[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True: always a tuple.
        let parts = result.to_tuple()?;
        let mut out = Vec::with_capacity(parts.len());
        for p in parts {
            out.push(literal_to_tensor(&p)?);
        }
        Ok(out)
    }

    /// Execute and return the raw output buffers (serving hot path: the
    /// decode loop keeps the KV cache as literals without tensor round
    /// trips; see crate::serve::engine).
    pub fn run_literals_raw(
        &self,
        literals: &[xla::Literal],
    ) -> Result<Vec<Vec<xla::PjRtBuffer>>> {
        Ok(self.exe.execute::<xla::Literal>(literals)?)
    }

    /// Convert values to literals without running (for cached hot loops).
    pub fn prepare(&self, inputs: &[Value]) -> Result<Vec<xla::Literal>> {
        self.validate(inputs)?;
        inputs.iter().map(|v| v.to_literal()).collect()
    }

    pub fn input_index(&self, name: &str) -> Result<usize> {
        self.spec
            .inputs
            .iter()
            .position(|(n, _, _)| n == name)
            .ok_or_else(|| anyhow!("{}: no input named {name:?}", self.label))
    }
}

pub fn literal_to_tensor(lit: &xla::Literal) -> Result<Tensor> {
    let shape = lit.array_shape()?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let data: Vec<f32> = match lit.ty()? {
        xla::ElementType::F32 => lit.to_vec::<f32>()?,
        xla::ElementType::S32 => lit.to_vec::<i32>()?.into_iter().map(|v| v as f32).collect(),
        other => bail!("unsupported output element type {other:?}"),
    };
    Ok(Tensor::new(dims, data))
}

/// Convenience: convert an i32 token literal back (used by tests).
pub fn tensor_to_tokens(t: &Tensor) -> Vec<i32> {
    t.data.iter().map(|&v| v as i32).collect()
}

/// Cache of compiled executables keyed by (model, artifact).
pub struct ExecutableCache<'rt> {
    rt: &'rt Runtime,
    manifest: &'rt Manifest,
    cache: BTreeMap<(String, String), std::rc::Rc<Executable>>,
}

impl<'rt> ExecutableCache<'rt> {
    pub fn new(rt: &'rt Runtime, manifest: &'rt Manifest) -> Self {
        Self { rt, manifest, cache: BTreeMap::new() }
    }

    pub fn get(&mut self, model: &str, artifact: &str) -> Result<std::rc::Rc<Executable>> {
        let key = (model.to_string(), artifact.to_string());
        if let Some(e) = self.cache.get(&key) {
            return Ok(e.clone());
        }
        let e = std::rc::Rc::new(self.rt.load(self.manifest, model, artifact)?);
        self.cache.insert(key, e.clone());
        Ok(e)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn value_shapes_and_dtypes() {
        let v = Value::F32(Tensor::zeros(&[2, 3]));
        assert_eq!(v.shape(), vec![2, 3]);
        assert_eq!(v.dtype(), "float32");
        let t = Value::tokens(&[vec![1, 2], vec![3, 4]], 2, 2);
        assert_eq!(t.shape(), vec![2, 2]);
        assert_eq!(t.dtype(), "int32");
        assert_eq!(Value::ScalarI32(5).shape(), Vec::<usize>::new());
    }

    #[test]
    fn tokens_pads_short_batches() {
        let t = Value::tokens(&[vec![1, 2, 3]], 3, 3);
        if let Value::I32(flat, shape) = t {
            assert_eq!(shape, vec![3, 3]);
            assert_eq!(flat.len(), 9);
            assert_eq!(&flat[..3], &[1, 2, 3]);
            assert_eq!(&flat[3..6], &[1, 2, 3]);
        } else {
            panic!("wrong variant");
        }
    }
}
