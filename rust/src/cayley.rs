//! Cayley SGD on the Stiefel manifold (Li et al. 2020; paper §3.2, Eq. 3-4).
//!
//! The L2 `cayley_*` artifact returns the Euclidean gradients dL/dR1,
//! dL/dR2_i of the quantized-network loss; this module turns them into a
//! retraction that stays exactly on the manifold:
//!
//!   Ĝ = G Rᵀ − ½ R (Rᵀ G Rᵀ)          (projection, Eq. 4)
//!   Y = Ĝ − Ĝᵀ                         (skew-symmetric direction)
//!   R' = (I − α/2 Y)⁻¹ (I + α/2 Y) R   (Cayley transform, Eq. 3)
//!
//! Two solvers: an exact Gauss-Jordan inverse and the paper's fixed-point
//! iteration `X ← R + α/2 · Y (R + X)` (two matmuls per iteration); both
//! preserve ‖R'ᵀR' − I‖ ≈ 0, property-tested below. Momentum follows the
//! reference implementation of Cayley SGD.

use anyhow::Result;

use crate::linalg::{inverse, matmul, matmul_nt, matmul_tn, transpose};
use crate::tensor::Tensor;

/// Solver used for the Cayley transform.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Solver {
    Exact,
    /// Fixed-point iteration with this many steps (paper uses ~2-5).
    FixedPoint(usize),
}

/// Project the Euclidean gradient onto the skew direction Y (Eq. 4).
pub fn skew_direction(r: &Tensor, g: &Tensor) -> Tensor {
    // Ĝ = G Rᵀ − ½ R Rᵀ G Rᵀ
    let grt = matmul_nt(g, r);
    let rtg = matmul(&matmul_tn(r, g), &transpose(r)); // Rᵀ G Rᵀ
    let half = matmul(r, &rtg).scale(0.5);
    let ghat = grt.sub(&half);
    ghat.sub(&transpose(&ghat))
}

/// One Cayley retraction step: R' = (I − α/2 Y)⁻¹ (I + α/2 Y) R.
pub fn cayley_step(r: &Tensor, y: &Tensor, alpha: f32, solver: Solver) -> Result<Tensor> {
    let n = r.shape[0];
    let half = 0.5 * alpha;
    match solver {
        Solver::Exact => {
            let mut a = y.scale(-half); // I − α/2 Y
            let mut b = y.scale(half); // I + α/2 Y
            for i in 0..n {
                a.data[i * n + i] += 1.0;
                b.data[i * n + i] += 1.0;
            }
            let ainv = inverse(&a)?;
            Ok(matmul(&ainv, &matmul(&b, r)))
        }
        Solver::FixedPoint(iters) => {
            // X ← R + α/2 · Y (R + X), X₀ = R  (converges for small α‖Y‖).
            let mut x = r.clone();
            for _ in 0..iters {
                let rx = r.add(&x);
                x = r.add(&matmul(y, &rx).scale(half));
            }
            Ok(x)
        }
    }
}

/// Cayley SGD optimizer with momentum for one rotation matrix.
#[derive(Clone, Debug)]
pub struct CayleySgd {
    pub lr: f32,
    pub momentum: f32,
    pub solver: Solver,
    velocity: Option<Tensor>,
}

impl CayleySgd {
    pub fn new(lr: f32, momentum: f32, solver: Solver) -> Self {
        Self { lr, momentum, solver, velocity: None }
    }

    /// Update R in place given the Euclidean gradient G; returns ‖Y‖∞.
    pub fn step(&mut self, r: &mut Tensor, g: &Tensor, lr: f32) -> Result<f32> {
        let y = skew_direction(r, g);
        let dir = match (&self.velocity, self.momentum > 0.0) {
            (Some(v), true) => {
                let d = v.scale(self.momentum).add(&y);
                self.velocity = Some(d.clone());
                d
            }
            (None, true) => {
                self.velocity = Some(y.clone());
                y
            }
            _ => y,
        };
        let ymax = dir.max_abs();
        // Descent: move along −Y.
        *r = cayley_step(r, &dir, -lr, self.solver)?;
        Ok(ymax)
    }
}

/// Linear-decay learning-rate schedule (paper §4.1: 1.5 → 0).
pub fn linear_decay_lr(base: f32, iter: usize, total: usize) -> f32 {
    if total <= 1 {
        return base;
    }
    base * (1.0 - iter as f32 / total as f32)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::orthonormality_error;
    use crate::testing::prop::{forall, Gen};
    use crate::util::prng::Prng;

    fn random_rotation(n: usize, seed: u64) -> Tensor {
        let mut p = Prng::new(seed);
        let g = Tensor::new(vec![n, n], (0..n * n).map(|_| p.normal()).collect());
        crate::linalg::qr_orthogonal(&g)
    }

    #[test]
    fn skew_direction_is_skew() {
        let mut g = Gen { rng: Prng::new(1) };
        let r = random_rotation(12, 2);
        let grad = g.tensor(&[12, 12], 1.0);
        let y = skew_direction(&r, &grad);
        let yt = transpose(&y);
        assert!(y.add(&yt).max_abs() < 1e-4);
    }

    #[test]
    fn prop_cayley_step_stays_on_manifold() {
        forall(3, 25, |g: &mut Gen| {
            let n = *g.pick(&[4usize, 8, 16, 32]);
            let r = random_rotation(n, g.rng.next_u64());
            let scale = g.f32(0.1, 3.0);
            let grad = g.tensor(&[n, n], scale);
            let y = skew_direction(&r, &grad);
            let alpha = g.f32(0.001, 0.2);
            let r2 = cayley_step(&r, &y, alpha, Solver::Exact).unwrap();
            let err = orthonormality_error(&r2);
            if err > 1e-3 {
                return Err(format!("orthonormality error {err} (n={n}, a={alpha})"));
            }
            Ok(())
        });
    }

    #[test]
    fn fixed_point_approximates_exact() {
        let n = 16;
        let r = random_rotation(n, 7);
        let mut g = Gen { rng: Prng::new(8) };
        let grad = g.tensor(&[n, n], 0.5);
        let y = skew_direction(&r, &grad);
        let alpha = 0.02;
        let exact = cayley_step(&r, &y, alpha, Solver::Exact).unwrap();
        let fp = cayley_step(&r, &y, alpha, Solver::FixedPoint(5)).unwrap();
        assert!(exact.sub(&fp).max_abs() < 1e-3);
    }

    #[test]
    fn optimizer_descends_quadratic_on_manifold() {
        // Minimize L(R) = ||R - T||_F^2 over rotations, T itself a rotation:
        // optimum is R = T with L = 0.
        let n = 8;
        let target = random_rotation(n, 21);
        let mut r = random_rotation(n, 22);
        let mut opt = CayleySgd::new(0.2, 0.0, Solver::Exact);
        let loss = |r: &Tensor| r.sub(&target).frob_norm();
        let l0 = loss(&r);
        for it in 0..200 {
            let g = r.sub(&target).scale(2.0); // dL/dR
            let lr = linear_decay_lr(0.2, it, 200);
            opt.step(&mut r, &g, lr).unwrap();
            assert!(orthonormality_error(&r) < 1e-2);
        }
        let l1 = loss(&r);
        assert!(l1 < 0.3 * l0, "l0={l0} l1={l1}");
    }

    #[test]
    fn momentum_accelerates() {
        let n = 8;
        let target = random_rotation(n, 31);
        let run = |momentum: f32| {
            let mut r = random_rotation(n, 32);
            let mut opt = CayleySgd::new(0.05, momentum, Solver::Exact);
            for it in 0..60 {
                let g = r.sub(&target).scale(2.0);
                let lr = linear_decay_lr(0.05, it, 60);
                opt.step(&mut r, &g, lr).unwrap();
            }
            r.sub(&target).frob_norm()
        };
        // With momentum we should do at least as well (typically better).
        assert!(run(0.9) <= run(0.0) * 1.5);
    }

    #[test]
    fn lr_schedule() {
        assert_eq!(linear_decay_lr(1.5, 0, 100), 1.5);
        assert!((linear_decay_lr(1.5, 50, 100) - 0.75).abs() < 1e-6);
        assert!(linear_decay_lr(1.5, 99, 100) > 0.0);
    }
}
