//! Paper-table/figure harnesses — one entry per exhibit in the paper's
//! evaluation (DESIGN.md §7 experiment index).
//!
//! Shared by `spinquant bench-table --id <ID>` and the `cargo bench`
//! targets in `rust/benches/`. Each harness regenerates the rows/series of
//! its exhibit on the tiny-LLaMA zoo; absolute numbers differ from the
//! paper (different scale/testbed) but the *shape* — method orderings,
//! variance of random rotations, Hadamard overhead percentage — is the
//! reproduction target.

use anyhow::{bail, Result};

use crate::config::{Bits, Method, PipelineConfig};
use crate::coordinator::Pipeline;
use crate::eval::{self, EvalSession, QcfgVec};
use crate::serve;
use crate::model::Manifest;
use crate::report::{fmt_acc, fmt_ppl, Table};
use crate::rotation::RotationKind;
use crate::runtime::Runtime;

/// Run one paper-table harness. `out`: optional path of the markdown log to
/// append to (e.g. EXPERIMENTS.md).
pub fn run_bench(
    cfg: &PipelineConfig,
    id: &str,
    models: &[String],
    trials: usize,
    out: Option<&str>,
) -> Result<()> {
    let manifest = Manifest::load(&cfg.artifacts_dir)?;
    let rt = Runtime::cpu()?;
    let ctx = BenchCtx { rt: &rt, manifest: &manifest, base: cfg.clone() };

    let md = match id {
        "table1" => table1(&ctx, models)?,
        "table2" => table2(&ctx, models)?,
        "table3" => table3(&ctx, models)?,
        "table4" => table4(&ctx, models, trials.min(4).max(2))?,
        "table5" => table5(&ctx, models)?,
        "table6" => table6(&ctx, models)?,
        "table10" => table10(&ctx, models)?,
        "table11" => table11(&ctx, models)?,
        "table12" => table12(&ctx, models)?,
        "table13" => table13(&ctx, models)?,
        "fig2" | "fig3" => fig23(&ctx, models)?,
        "fig4" => fig4(&ctx, models, trials)?,
        "fig7" => fig7(&ctx, models)?,
        "fig8" | "table14" => fig8(&ctx, models)?,
        other => bail!("unknown bench id {other:?} (see --help)"),
    };
    println!("{md}");
    if let Some(path) = out {
        // `--out <dir>` appends the section to <dir>/EXPERIMENTS.md.
        let root = std::path::Path::new(path);
        let root = if root.is_dir() { root } else { std::path::Path::new(".") };
        crate::report::append_experiments(root, &md)?;
    }
    Ok(())
}

struct BenchCtx<'a> {
    rt: &'a Runtime,
    manifest: &'a Manifest,
    base: PipelineConfig,
}

impl<'a> BenchCtx<'a> {
    fn pipe(&self, model: &str, f: impl FnOnce(&mut PipelineConfig)) -> Result<Pipeline<'a>> {
        let mut cfg = self.base.clone();
        cfg.model = model.to_string();
        f(&mut cfg);
        Pipeline::new(self.rt, self.manifest, cfg)
    }

    /// Quantize + evaluate one (model, method, bits) cell.
    fn cell(
        &self,
        model: &str,
        method: Method,
        bits: Bits,
        f: impl FnOnce(&mut PipelineConfig),
    ) -> Result<crate::coordinator::EvalResult> {
        let pipe = self.pipe(model, |c| {
            c.method = method;
            c.bits = bits;
            f(c);
        })?;
        let qm = pipe.quantize()?;
        let res = pipe.evaluate(&qm)?;
        crate::info!(
            "{model} {} {}: acc {:.1} ppl {:.2}",
            method.name(),
            bits.label(),
            res.acc_pct(),
            res.ppl
        );
        Ok(res)
    }
}

fn fmt_cell(res: &crate::coordinator::EvalResult) -> (String, String) {
    (fmt_acc(res.acc_pct()), fmt_ppl(res.ppl))
}

// ---------------------------------------------------------------------------
// Table 1 (+ appendix tables 7/8/9): the main result grid.
// ---------------------------------------------------------------------------

fn table1(ctx: &BenchCtx, models: &[String]) -> Result<String> {
    let bit_rows = ["4-8-16", "4-8-8", "4-4-16", "4-4-4"];
    let methods = [
        Method::Float,
        Method::Rtn,
        Method::SmoothQuant,
        Method::Gptq,
        Method::LlmQat,
        Method::SpinQuantNoHad,
        Method::SpinQuantHad,
    ];
    let mut headers = vec!["#Bits (W-A-KV)".to_string(), "Method".to_string()];
    for m in models {
        headers.push(format!("{m} 0-shot^8 Avg"));
        headers.push(format!("{m} Wiki ppl"));
    }
    let mut t = Table::new(
        "Table 1 — main results: zero-shot avg (up) and WikiText-syn ppl (down)",
        &headers.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
    );
    // FP row first (bits label 16-16-16).
    {
        let mut row = vec!["16-16-16".to_string(), "FloatingPoint".to_string()];
        for model in models {
            let res = ctx.cell(model, Method::Float, Bits::fp(), |_| {})?;
            let (a, p) = fmt_cell(&res);
            row.push(a);
            row.push(p);
        }
        t.row(row);
    }
    for bits_s in bit_rows {
        let bits = Bits::parse(bits_s)?;
        for method in methods.iter().skip(1) {
            let mut row = vec![bits_s.to_string(), method.name().to_string()];
            for model in models {
                let res = ctx.cell(model, *method, bits, |c| {
                    // GPTQ row is GPTQ-only; rotation methods follow cfg.
                    c.use_gptq = matches!(
                        method,
                        Method::Gptq | Method::SpinQuantNoHad | Method::SpinQuantHad
                    );
                })?;
                let (a, p) = fmt_cell(&res);
                row.push(a);
                row.push(p);
            }
            t.row(row);
        }
    }
    Ok(section("table1", t.to_markdown()))
}

// ---------------------------------------------------------------------------
// Table 2: learned vs random rotations.
// ---------------------------------------------------------------------------

fn table2(ctx: &BenchCtx, models: &[String]) -> Result<String> {
    let bit_rows = ["4-4-16", "4-4-4"];
    let mut headers = vec!["Setting".to_string()];
    for m in models {
        for b in bit_rows {
            headers.push(format!("{m} {b}"));
        }
    }
    let mut t = Table::new(
        "Table 2 — random Hadamard vs learned rotations (0-shot^8 avg)",
        &headers.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
    );
    // (label, learn, had)
    let arms = [
        ("Random Hadamard R{1,2}", false, false),
        ("SpinQuant_no_had R{1,2}", true, false),
        ("Random Hadamard R{1,2,3,4} (QuaRot)", false, true),
        ("SpinQuant_had R{1,2,3,4}", true, true),
    ];
    for (label, learn, had) in arms {
        let mut row = vec![label.to_string()];
        for model in models {
            for b in bit_rows {
                let bits = Bits::parse(b)?;
                let pipe = ctx.pipe(model, |c| {
                    c.bits = bits;
                })?;
                let qm = pipe.quantize_rotated(
                    RotationKind::RandomHadamard,
                    ctx.base.rotation_seed,
                    learn,
                    had,
                )?;
                let res = pipe.evaluate(&qm)?;
                crate::info!("{model} {label} {b}: acc {:.1}", res.acc_pct());
                row.push(fmt_acc(res.acc_pct()));
            }
        }
        t.row(row);
    }
    Ok(section("table2", t.to_markdown()))
}

// ---------------------------------------------------------------------------
// Table 3: Cayley against act-only vs act+weight-quantized network.
// ---------------------------------------------------------------------------

fn table3(ctx: &BenchCtx, models: &[String]) -> Result<String> {
    let mut t = Table::new(
        "Table 3 — GPTQ compatibility: optimize rotation on 4-4-KV vs 16-4-KV",
        &["#Bits", "Model", "Cayley on 4-4-KV (acc / ppl)", "Cayley on 16-4-KV (acc / ppl)"],
    );
    for bits_s in ["4-4-16", "4-4-4"] {
        let bits = Bits::parse(bits_s)?;
        for model in models {
            let mut cells = Vec::new();
            for on_quant in [true, false] {
                let res = ctx.cell(model, Method::SpinQuantHad, bits, |c| {
                    c.cayley_on_quant_weights = on_quant;
                    c.use_gptq = true;
                })?;
                cells.push(format!("{} / {}", fmt_acc(res.acc_pct()), fmt_ppl(res.ppl)));
            }
            t.row(vec![bits_s.into(), model.clone(), cells[0].clone(), cells[1].clone()]);
        }
    }
    Ok(section("table3", t.to_markdown()))
}

// ---------------------------------------------------------------------------
// Table 4: FP rotation vs Hadamard rotation, ± Cayley (RTN weights).
// ---------------------------------------------------------------------------

fn table4(ctx: &BenchCtx, models: &[String], seeds: usize) -> Result<String> {
    let mut t = Table::new(
        "Table 4 — rotation type ± Cayley (RTN; mean±std over seeds; acc / ppl)",
        &["#Bits", "Model", "FP (no Cayley)", "Hadamard (no Cayley)", "FP init + Cayley",
          "Hadamard init + Cayley"],
    );
    for bits_s in ["4-16-16", "4-4-16", "4-4-4"] {
        let bits = Bits::parse(bits_s)?;
        for model in models {
            let mut cells = Vec::new();
            for (kind, learn) in [
                (RotationKind::RandomOrthogonal, false),
                (RotationKind::RandomHadamard, false),
                (RotationKind::RandomOrthogonal, true),
                (RotationKind::RandomHadamard, true),
            ] {
                let mut accs = Vec::new();
                let mut ppls = Vec::new();
                for seed in 0..seeds as u64 {
                    let pipe = ctx.pipe(model, |c| {
                        c.bits = bits;
                        c.use_gptq = false; // RTN per the paper's Table 4
                    })?;
                    let qm = pipe.quantize_rotated(kind, seed * 31 + 5, learn, false)?;
                    let res = pipe.evaluate(&qm)?;
                    accs.push(res.acc_pct());
                    ppls.push(res.ppl);
                }
                cells.push(format!(
                    "{:.1}±{:.1} / {:.1}±{:.1}",
                    mean(&accs),
                    std(&accs),
                    mean(&ppls),
                    std(&ppls)
                ));
            }
            let mut row = vec![bits_s.to_string(), model.clone()];
            row.extend(cells);
            t.row(row);
        }
    }
    Ok(section("table4", t.to_markdown()))
}

// ---------------------------------------------------------------------------
// Table 5: QuaRot vs SpinQuant_had, RTN and GPTQ.
// ---------------------------------------------------------------------------

fn table5(ctx: &BenchCtx, models: &[String]) -> Result<String> {
    let bit_rows = ["4-4-16", "4-4-4"];
    let mut headers = vec!["Method".to_string()];
    for m in models {
        for b in bit_rows {
            headers.push(format!("{m} {b} (acc / ppl)"));
        }
    }
    let mut t = Table::new(
        "Table 5 — QuaRot (random) vs SpinQuant_had (learned)",
        &headers.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
    );
    for (label, method, gptq) in [
        ("QuaRot+RTN", Method::QuaRot, false),
        ("SpinQuant_had+RTN", Method::SpinQuantHad, false),
        ("QuaRot+GPTQ", Method::QuaRot, true),
        ("SpinQuant_had+GPTQ", Method::SpinQuantHad, true),
    ] {
        let mut row = vec![label.to_string()];
        for model in models {
            for b in bit_rows {
                let res = ctx.cell(model, method, Bits::parse(b)?, |c| c.use_gptq = gptq)?;
                row.push(format!("{} / {}", fmt_acc(res.acc_pct()), fmt_ppl(res.ppl)));
            }
        }
        t.row(row);
    }
    Ok(section("table5", t.to_markdown()))
}

// ---------------------------------------------------------------------------
// Table 6: end-to-end decode speed (FP16 vs W4A8, no_had vs had).
// ---------------------------------------------------------------------------

fn table6(ctx: &BenchCtx, models: &[String]) -> Result<String> {
    let mut t = Table::new(
        "Table 6 — decode speed (this testbed: PJRT CPU, 1 core)",
        &["Model", "Method", "#Bits (W-A)", "ms/token", "vs FP"],
    );
    for model in models {
        let pipe = ctx.pipe(model, |c| {
            c.method = Method::SpinQuantNoHad;
            c.bits = Bits::parse("4-8-8").unwrap();
            c.use_gptq = false; // weight grid irrelevant for timing
            c.cayley_iters = 4; // timing run; rotation quality irrelevant
        })?;
        let qm = pipe.quantize()?;
        let mut fp_ms = 0.0;
        for (label, variant, bits_label) in [
            ("FloatingPoint", serve::DecodeVariant::Fp, "16-16"),
            ("SpinQuant_no_had", serve::DecodeVariant::QuantNoHad, "4-8"),
            ("SpinQuant_had", serve::DecodeVariant::QuantHad, "4-8"),
        ] {
            let exe = ctx.rt.load(ctx.manifest, model, variant.artifact())?;
            let qcfg = if variant == serve::DecodeVariant::Fp { None } else { Some(qm.qcfg) };
            let mut session = serve::GenerationSession::new(&exe, &qm.weights, qcfg)?;
            let _ = session.generate(b"The ", 56)?;
            let ms = session.ms_per_token();
            if variant == serve::DecodeVariant::Fp {
                fp_ms = ms;
            }
            t.row(vec![
                model.clone(),
                label.to_string(),
                bits_label.to_string(),
                format!("{ms:.2}"),
                format!("{:.2}x", fp_ms / ms),
            ]);
        }
    }
    Ok(section(
        "table6",
        format!(
            "{}\nNote: on this CPU testbed the quantized path runs the same f32 GEMMs plus\n\
             in-graph fake-quant ops, so unlike the paper's M1 (int4 kernels) quantization\n\
             does not speed decoding up; the reproduced *shape* is the small online-Hadamard\n\
             overhead of `had` vs `no_had`.\n",
            t.to_markdown()
        ),
    ))
}

// ---------------------------------------------------------------------------
// Table 10: 3-bit weights (W3A8KV8).
// ---------------------------------------------------------------------------

fn table10(ctx: &BenchCtx, models: &[String]) -> Result<String> {
    let bits = Bits::parse("3-8-8")?;
    let mut headers = vec!["Method".to_string()];
    for m in models {
        headers.push(format!("{m} (acc / ppl)"));
    }
    let mut t = Table::new(
        "Table 10 — 3-bit weight quantization (W3A8KV8)",
        &headers.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
    );
    for (method, gptq) in [
        (Method::Float, false),
        (Method::Rtn, false),
        (Method::SmoothQuant, false),
        (Method::Gptq, true),
        (Method::SpinQuantHad, true),
    ] {
        let mut row = vec![method.name().to_string()];
        for model in models {
            let b = if method == Method::Float { Bits::fp() } else { bits };
            let res = ctx.cell(model, method, b, |c| c.use_gptq = gptq)?;
            row.push(format!("{} / {}", fmt_acc(res.acc_pct()), fmt_ppl(res.ppl)));
        }
        t.row(row);
    }
    Ok(section("table10", t.to_markdown()))
}

// ---------------------------------------------------------------------------
// Table 11: Cayley sample/iteration ablation.
// ---------------------------------------------------------------------------

fn table11(ctx: &BenchCtx, models: &[String]) -> Result<String> {
    let mut t = Table::new(
        "Table 11 — Cayley optimization budget (Wiki ppl at 4-4-4)",
        &["Model", "Axis", "Setting", "Wiki ppl"],
    );
    let bits = Bits::parse("4-4-4")?;
    for model in models {
        for samples in [64usize, 256] {
            let res = ctx.cell(model, Method::SpinQuantHad, bits, |c| {
                c.cayley_samples = samples;
            })?;
            t.row(vec![
                model.clone(),
                "#samples".into(),
                samples.to_string(),
                fmt_ppl(res.ppl),
            ]);
        }
        for iters in [10usize, 25, 50, 100] {
            let res = ctx.cell(model, Method::SpinQuantHad, bits, |c| {
                c.cayley_iters = iters;
            })?;
            t.row(vec![model.clone(), "#iters".into(), iters.to_string(), fmt_ppl(res.ppl)]);
        }
    }
    Ok(section("table11", t.to_markdown()))
}

// ---------------------------------------------------------------------------
// Table 12: symmetric/asymmetric + clipping ablation.
// ---------------------------------------------------------------------------

fn table12(ctx: &BenchCtx, models: &[String]) -> Result<String> {
    let mut t = Table::new(
        "Table 12 — activation/KV quantizer ablation (SpinQuant_had)",
        &["Model", "#Bits", "A asym", "A clip", "KV asym", "KV clip", "acc", "Wiki ppl"],
    );
    for model in models {
        for (bits_s, a_sym, a_clip, kv_sym, kv_clip) in [
            ("4-4-16", true, 1.0f32, false, 1.0f32), // A symmetric
            ("4-4-16", false, 1.0, false, 1.0),      // A asymmetric (default)
            ("4-4-16", false, 0.9, false, 1.0),      // + clip
            ("4-4-4", false, 1.0, true, 1.0),        // KV symmetric
            ("4-4-4", false, 1.0, false, 1.0),       // KV asymmetric
            ("4-4-4", false, 1.0, false, 0.95),      // + clip
        ] {
            let res = ctx.cell(model, Method::SpinQuantHad, Bits::parse(bits_s)?, |c| {
                c.a_sym = a_sym;
                c.a_clip = a_clip;
                c.kv_sym = kv_sym;
                c.kv_clip = kv_clip;
            })?;
            t.row(vec![
                model.clone(),
                bits_s.into(),
                (!a_sym).to_string(),
                a_clip.to_string(),
                (!kv_sym).to_string(),
                kv_clip.to_string(),
                fmt_acc(res.acc_pct()),
                fmt_ppl(res.ppl),
            ]);
        }
    }
    Ok(section("table12", t.to_markdown()))
}

// ---------------------------------------------------------------------------
// Table 13: calibration-corpus robustness.
// ---------------------------------------------------------------------------

fn table13(ctx: &BenchCtx, models: &[String]) -> Result<String> {
    let mut t = Table::new(
        "Table 13 — calibration data choice (SpinQuant_had)",
        &["Model", "Calib corpus", "#Bits", "acc", "Wiki ppl"],
    );
    for model in models {
        for corpus in ["wiki-syn", "c4-syn"] {
            for bits_s in ["4-4-16", "4-4-4"] {
                let res = ctx.cell(model, Method::SpinQuantHad, Bits::parse(bits_s)?, |c| {
                    c.calib_corpus = corpus.to_string();
                })?;
                t.row(vec![
                    model.clone(),
                    corpus.into(),
                    bits_s.into(),
                    fmt_acc(res.acc_pct()),
                    fmt_ppl(res.ppl),
                ]);
            }
        }
    }
    Ok(section("table13", t.to_markdown()))
}

// ---------------------------------------------------------------------------
// Figs. 2 & 3: activation distributions / kurtosis / quant error per layer.
// ---------------------------------------------------------------------------

fn fig23(ctx: &BenchCtx, models: &[String]) -> Result<String> {
    let mut out = String::new();
    for model in models {
        let pipe = ctx.pipe(model, |c| c.method = Method::Float)?;
        let base = pipe.load_base_weights()?;
        let folded = crate::rotation::fold_norm_scales(&base, &pipe.model_cfg)?;
        let rot = crate::rotation::RotationSet::build(
            &pipe.model_cfg,
            RotationKind::RandomHadamard,
            ctx.base.rotation_seed,
        );
        let merged = crate::rotation::merge(&folded, &pipe.model_cfg, &rot, false)?;

        let mut t = Table::new(
            &format!("Fig. 2/3 — {model}: per-layer activation stats before/after rotation"),
            &["Site", "Layer", "kurtosis before", "kurtosis after", "4b MSE before",
              "4b MSE after", "max|ch| before", "max|ch| after"],
        );
        let stats_b = pipe.collect_stats(&folded, 2)?;
        let stats_a = pipe.collect_stats(&merged, 2)?;
        for site in ["resid_in", "down_in"] {
            let sb = eval::capture_stats(site, &stats_b.captures[site]);
            let sa = eval::capture_stats(site, &stats_a.captures[site]);
            for (b, a) in sb.iter().zip(&sa) {
                let maxb = b.channel_absmax.iter().cloned().fold(0.0f32, f32::max);
                let maxa = a.channel_absmax.iter().cloned().fold(0.0f32, f32::max);
                t.row(vec![
                    site.into(),
                    b.layer.to_string(),
                    format!("{:.1}", b.kurtosis),
                    format!("{:.1}", a.kurtosis),
                    format!("{:.4}", b.quant_mse_4bit),
                    format!("{:.4}", a.quant_mse_4bit),
                    format!("{maxb:.1}"),
                    format!("{maxa:.1}"),
                ]);
            }
        }
        out.push_str(&t.to_markdown());
        out.push('\n');
    }
    Ok(section("fig2/fig3", out))
}

// ---------------------------------------------------------------------------
// Fig. 4: accuracy distribution over random rotations vs Cayley.
// ---------------------------------------------------------------------------

fn fig4(ctx: &BenchCtx, models: &[String], trials: usize) -> Result<String> {
    let bits = Bits::parse("4-4-16")?;
    let mut out = String::new();
    for model in models {
        let mut t = Table::new(
            &format!(
                "Fig. 4 — {model}: W4A4 0-shot^8 over {trials} random trials (RTN weights)"
            ),
            &["Rotation family", "min", "mean", "max", "std"],
        );
        let run_family = |kind: RotationKind, learn: bool, n: usize| -> Result<Vec<f64>> {
            let mut accs = Vec::new();
            for seed in 0..n as u64 {
                let pipe = ctx.pipe(model, |c| {
                    c.bits = bits;
                    c.use_gptq = false;
                })?;
                let qm = pipe.quantize_rotated(kind, 101 + seed * 13, learn, false)?;
                let res = pipe.evaluate(&qm)?;
                crate::info!(
                    "fig4 {model} {kind:?} learn={learn} seed {seed}: {:.1}",
                    res.acc_pct()
                );
                accs.push(res.acc_pct());
            }
            Ok(accs)
        };
        let fam = [
            ("Random rotation (FP)", RotationKind::RandomOrthogonal, false, trials),
            ("Random Hadamard", RotationKind::RandomHadamard, false, trials),
            ("Cayley-optimized (SpinQuant)", RotationKind::RandomHadamard, true, trials.div_ceil(4).max(2)),
        ];
        for (label, kind, learn, n) in fam {
            let accs = run_family(kind, learn, n)?;
            t.row(vec![
                label.to_string(),
                format!("{:.1}", accs.iter().cloned().fold(f64::INFINITY, f64::min)),
                format!("{:.1}", mean(&accs)),
                format!("{:.1}", accs.iter().cloned().fold(f64::NEG_INFINITY, f64::max)),
                format!("{:.2}", std(&accs)),
            ]);
        }
        out.push_str(&t.to_markdown());
        out.push('\n');
    }
    Ok(section("fig4", out))
}

// ---------------------------------------------------------------------------
// Fig. 7: decode latency breakdown (hadamard / fake-quant shares).
// ---------------------------------------------------------------------------

fn fig7(ctx: &BenchCtx, models: &[String]) -> Result<String> {
    let mut out = String::new();
    for model in models {
        let pipe = ctx.pipe(model, |c| {
            c.method = Method::SpinQuantNoHad;
            c.bits = Bits::parse("4-8-8").unwrap();
            c.use_gptq = false;
            c.cayley_iters = 2;
        })?;
        let qm = pipe.quantize()?;
        let time_variant = |variant: serve::DecodeVariant| -> Result<f64> {
            let exe = ctx.rt.load(ctx.manifest, model, variant.artifact())?;
            let qcfg = if variant == serve::DecodeVariant::Fp { None } else { Some(qm.qcfg) };
            let mut s = serve::GenerationSession::new(&exe, &qm.weights, qcfg)?;
            let _ = s.generate(b"Alpha ", 48)?;
            Ok(s.ms_per_token())
        };
        let fp = time_variant(serve::DecodeVariant::Fp)?;
        let nohad = time_variant(serve::DecodeVariant::QuantNoHad)?;
        let had = time_variant(serve::DecodeVariant::QuantHad)?;
        // Rust-side FWHT microbench for the per-op hadamard cost.
        let mcfg = ctx.manifest.config(model)?;
        let mut x = crate::tensor::Tensor::ones(&[1, mcfg.d_ffn]);
        let fwht_us = crate::bench::bench("fwht", 20, 400, || {
            crate::hadamard::fwht_row(&mut x.data);
        })
        .mean_us;
        let mut t = Table::new(
            &format!("Fig. 7 — {model}: decode-step latency decomposition"),
            &["Component", "ms/token", "share of quantized step"],
        );
        t.row(vec!["decode fp (total)".into(), format!("{fp:.3}"), "-".into()]);
        t.row(vec!["decode quant no_had (total)".into(), format!("{nohad:.3}"), "100%".into()]);
        t.row(vec![
            "fake-quant ops (nohad - fp)".into(),
            format!("{:.3}", nohad - fp),
            format!("{:.1}%", (nohad - fp) / nohad * 100.0),
        ]);
        t.row(vec![
            "online Hadamard R3/R4 (had - nohad)".into(),
            format!("{:.3}", had - nohad),
            format!("{:.1}%", (had - nohad) / had * 100.0),
        ]);
        t.row(vec![
            format!("rust FWHT reference (n={})", mcfg.d_ffn),
            format!("{:.5}", fwht_us / 1e3),
            "-".into(),
        ]);
        out.push_str(&t.to_markdown());
        out.push('\n');
    }
    Ok(section("fig7", out))
}

// ---------------------------------------------------------------------------
// Fig. 8 / Table 14: end-to-end + per-layer quantization SNR.
// ---------------------------------------------------------------------------

fn fig8(ctx: &BenchCtx, models: &[String]) -> Result<String> {
    let mut out = String::new();
    for model in models {
        let pipe = ctx.pipe(model, |c| {
            c.bits = Bits::parse("4-4-16").unwrap();
            c.use_gptq = false;
        })?;
        let base = pipe.load_base_weights()?;
        let folded = crate::rotation::fold_norm_scales(&base, &pipe.model_cfg)?;

        // Three networks: no rotation / random R / learned R — all evaluated
        // with 4-bit activations against the FP logits of the same weights.
        let rand_rot = pipe.quantize_rotated(RotationKind::RandomHadamard, 3, false, false)?;
        let learned = pipe.quantize_rotated(RotationKind::RandomHadamard, 3, true, false)?;

        let exe = ctx.rt.load(ctx.manifest, model, "fwd_eval_nohad")?;
        let corpus = pipe.load_corpus("test")?;
        let qv = QcfgVec::from_pipeline(&pipe.cfg);
        let snr_of = |weights: &crate::model::Weights| -> Result<f32> {
            let windows = corpus.eval_windows(64, Some(8));
            let mut fp_sess = EvalSession::new(&exe, weights, Some(QcfgVec::fp()))?;
            let mut q_sess = EvalSession::new(&exe, weights, Some(qv))?;
            let mut snrs = Vec::new();
            for chunk in windows.chunks(8) {
                let fp = fp_sess.logits(chunk)?;
                let q = q_sess.logits(chunk)?;
                snrs.push(eval::e2e_snr_db(&fp, &q) as f64);
            }
            Ok(mean(&snrs) as f32)
        };
        let s_none = snr_of(&folded)?;
        let s_rand = snr_of(&rand_rot.weights)?;
        let s_learn = snr_of(&learned.weights)?;
        let mut t = Table::new(
            &format!("Table 14 / Fig. 8 — {model}: end-to-end quantization SNR (dB), W16A4"),
            &["No rotation", "Random Hadamard R", "Learned R (SpinQuant)"],
        );
        t.row(vec![format!("{s_none:.1}"), format!("{s_rand:.1}"), format!("{s_learn:.1}")]);
        out.push_str(&t.to_markdown());

        // Per-layer activation SQNR improvement (Fig. 8c).
        let stats_r = pipe.collect_stats(&rand_rot.weights, 2)?;
        let stats_l = pipe.collect_stats(&learned.weights, 2)?;
        let mut t2 = Table::new(
            &format!("Fig. 8c — {model}: per-layer 4-bit activation SQNR (dB), random vs learned R"),
            &["Layer", "random R", "learned R", "delta"],
        );
        let sr = eval::capture_stats("resid_in", &stats_r.captures["resid_in"]);
        let sl = eval::capture_stats("resid_in", &stats_l.captures["resid_in"]);
        for (r, l) in sr.iter().zip(&sl) {
            t2.row(vec![
                r.layer.to_string(),
                format!("{:.1}", r.sqnr_db_4bit),
                format!("{:.1}", l.sqnr_db_4bit),
                format!("{:+.1}", l.sqnr_db_4bit - r.sqnr_db_4bit),
            ]);
        }
        out.push_str(&t2.to_markdown());
        out.push('\n');
    }
    Ok(section("fig8", out))
}

// ---------------------------------------------------------------------------

fn section(id: &str, body: String) -> String {
    format!("\n## bench {id} ({})\n\n{body}\n", chrono_lite())
}

/// Timestamp without a chrono dependency.
fn chrono_lite() -> String {
    let secs = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    format!("unix {secs}")
}

fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

fn std(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_helpers() {
        assert_eq!(mean(&[1.0, 3.0]), 2.0);
        assert!((std(&[1.0, 3.0]) - std::f64::consts::SQRT_2).abs() < 1e-9);
        assert_eq!(std(&[1.0]), 0.0);
    }

    #[test]
    fn section_format() {
        let s = section("tableX", "body".into());
        assert!(s.contains("## bench tableX"));
        assert!(s.contains("body"));
    }
}
