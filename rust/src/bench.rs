//! Micro-benchmark harness (criterion is not in the offline vendor set).
//!
//! `cargo bench` targets use [`Bench`] for warmup + timed iterations with
//! mean/median/p95 reporting; the paper-table harnesses live in
//! `rust/benches/` and print the same rows the paper reports.

use std::time::Instant;

use crate::util::timer::Samples;

pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_us: f64,
    pub median_us: f64,
    pub p95_us: f64,
    pub min_us: f64,
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>8} iters  mean {:>10.2}us  median {:>10.2}us  p95 {:>10.2}us  min {:>10.2}us",
            self.name, self.iters, self.mean_us, self.median_us, self.p95_us, self.min_us
        )
    }

    /// Throughput helper: items/second given items per iteration.
    pub fn per_second(&self, items_per_iter: f64) -> f64 {
        items_per_iter / (self.mean_us / 1e6)
    }
}

/// Time `f` for `iters` iterations after `warmup` untimed runs.
pub fn bench<T>(name: &str, warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> BenchResult {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples = Samples::new();
    for _ in 0..iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed().as_secs_f64() * 1e6);
    }
    BenchResult {
        name: name.to_string(),
        iters,
        mean_us: samples.mean_us(),
        median_us: samples.median_us(),
        p95_us: samples.percentile_us(95.0),
        min_us: samples.min_us(),
    }
}

/// Adaptive variant: run for roughly `budget_ms` total.
pub fn bench_for_ms<T>(name: &str, budget_ms: f64, mut f: impl FnMut() -> T) -> BenchResult {
    // Calibrate with one run.
    let t0 = Instant::now();
    std::hint::black_box(f());
    let one = t0.elapsed().as_secs_f64() * 1e3;
    let iters = ((budget_ms / one.max(1e-3)) as usize).clamp(3, 10_000);
    bench(name, 1, iters, f)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_numbers() {
        let r = bench("spin", 2, 20, || {
            let mut s = 0u64;
            for i in 0..1000u64 {
                s = s.wrapping_add(i * i);
            }
            s
        });
        assert_eq!(r.iters, 20);
        assert!(r.mean_us > 0.0);
        assert!(r.min_us <= r.median_us);
        assert!(r.median_us <= r.p95_us + 1e-9);
        assert!(r.per_second(1000.0) > 0.0);
        assert!(r.report().contains("spin"));
    }

    #[test]
    fn adaptive_bench_bounds_iters() {
        let r = bench_for_ms("quick", 5.0, || std::hint::black_box(1 + 1));
        assert!(r.iters >= 3);
    }
}
