//! # SpinQuant — LLM quantization with learned rotations
//!
//! Rust + JAX + Pallas reproduction of *"SpinQuant: LLM Quantization with
//! Learned Rotations"* (ICLR 2025). Three-layer architecture:
//!
//! * **L1** (build time): Pallas kernels — fused fake-quant, fast
//!   Walsh-Hadamard transform, dequant-on-load matmul (`python/compile/kernels`).
//! * **L2** (build time): tiny-LLaMA forward/backward graphs with rotation
//!   and quantization insertion points, AOT-lowered to HLO text
//!   (`python/compile/model.py`, `aot.py`).
//! * **L3** (run time, this crate): the SpinQuant pipeline — RTN/GPTQ
//!   weight quantization, rotation construction and merging, Cayley-SGD
//!   rotation learning on the Stiefel manifold, baselines (SmoothQuant,
//!   QuaRot, LLM-QAT), a PJRT runtime that loads the AOT artifacts, a
//!   batched evaluation engine (perplexity + zero-shot tasks), a
//!   continuous-batching serving engine (`serve`: slot-based KV-cache
//!   manager, a *refcounted* paged KV-cache block pool (`serve::blocks`)
//!   with token-budget admission and evict-to-queue so resident cache
//!   memory scales with tokens in flight rather than `slots x max_seq`,
//!   copy-on-write prefix sharing over that pool (`serve::prefix`: a
//!   content-addressed index of full prompt pages, so N requests
//!   repeating one system prompt store and prefill it once —
//!   bit-identical output, admission charged only for non-shared pages),
//!   admission scheduler with batched multi-token prompt prefill
//!   (`ceil(len/T)` calls to first token) and mid-flight join, a
//!   decode-priority step composer (`serve --step-budget B`: every step
//!   runs the whole decode batch first, then fills the remaining budget
//!   with prompt chunks split at arbitrary boundaries over the ragged
//!   `n_valid` prefill graphs, so one long prompt can no longer stall
//!   every in-flight decode — worst-case decode stall drops from
//!   `ceil(len/T)` engine calls to zero, byte-identical output),
//!   quantized KV page storage (`serve --kv-bits {4,8,16}`: the paged
//!   graphs fake-quant K/V before the page scatter, so pages hold
//!   symmetric per-group storage-grid values and an equal page-byte
//!   budget holds ~3.6x more tokens at int4 than fp16 — 16-bit is exact
//!   pass-through, and `serve::blocks::kv_memory_bytes` prices the
//!   packed payload plus scale metadata), seeded
//!   greedy/temperature/top-k/top-p samplers with partial candidate
//!   selection (no full-vocabulary sorts on the hot path), and serving
//!   metrics — TTFT from enqueue split into queue wait vs prefill
//!   spread, latency percentiles, decode-stall and log-bucketed latency
//!   histograms, inter-token p99, tokens/sec, evictions — plus a
//!   flight-recorder event trace (`serve::trace`: a bounded ring of
//!   typed, step-indexed scheduler events — request lifecycle, page
//!   alloc/retain/release, prefix donations/hits, composer plans —
//!   enabled with `serve --trace out.json`, exported as a Chrome
//!   trace-event/Perfetto timeline, folded into per-request timelines
//!   that are cross-checked against the aggregate metrics, and replayed
//!   event-for-event by the scheduler oracle), and a fault-tolerant
//!   **error-kernel** step loop (`serve --fault-rate R --fault-seed S
//!   --retry-budget N --deadline-ms D`: engine failures are classified
//!   transient / per-slot / fatal, every engine-touching path is
//!   failure-atomic under the pool invariant `free + Σ(refcount>0) ==
//!   total`, recovery retries with deterministic step-counted backoff,
//!   exhausted step-wide streaks evict to the queue front for warm
//!   restart, repeat offenders are quarantined, expired deadlines are
//!   shed queued or mid-flight, and a seeded `FaultInjector` plus
//!   chaos-mode oracle suites CI-check that surviving requests are
//!   byte-identical to a fault-free run), and self-speculative decoding
//!   over the quantization ladder (`serve --spec-k K --spec-draft
//!   {ngram,engine}`: each running slot drafts up to K tokens — zero-cost
//!   prompt lookup, or a second lower-fidelity `DecodeEngine` rung — and
//!   the target verifies all K+1 positions in one ragged call; greedy
//!   acceptance keeps the longest agreeing prefix plus a free correction
//!   token, rejections roll back positions *and* pages, and output is
//!   byte-identical to `--spec-k 0` with any sampler — only
//!   tokens-per-engine-call changes)), the seeded
//!   scheduler-simulation oracle (`testing::sim`, dense / paged /
//!   prefix-cached / composed / fault-injected, including exact
//!   trace-event-stream equivalence), and the benchmark harnesses that
//!   regenerate every table and figure of the paper.
//!
//! Python never runs on the request path: `make artifacts` runs once, then
//! the `spinquant` binary is self-contained.
//!
//! Quick start (after `make artifacts`):
//! ```bash
//! spinquant quantize --model sq-2m --method spinquant-had --bits 4-4-4
//! spinquant eval     --model sq-2m --method spinquant-had --bits 4-4-4
//! spinquant serve    --model sq-2m --batch 4 --sampler top-k \
//!                    --temperature 0.8 --max-new-tokens 48
//! spinquant bench-table --id table1 --models sq-2m
//! ```

pub mod bench;
pub mod benches_impl;
pub mod cayley;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod gptq;
pub mod hadamard;
pub mod linalg;
pub mod model;
pub mod quant;
pub mod report;
pub mod rotation;
pub mod runtime;
pub mod serve;
pub mod smoothquant;
pub mod tensor;
pub mod testing;
pub mod util;

pub use tensor::Tensor;

/// Crate-wide result alias (anyhow is the only error dependency available
/// in the offline vendor set; thiserror-style enums are overkill here).
pub type Result<T> = anyhow::Result<T>;
