//! Dense row-major f32 tensor — the substrate every L3 algorithm works on.
//!
//! Deliberately minimal (no ndarray in the offline vendor set): shapes are
//! `Vec<usize>`, storage is a flat `Vec<f32>` in C order, and the linear
//! algebra lives in [`crate::linalg`]. Conversion to/from PJRT literals is
//! in [`crate::runtime`].

use anyhow::{bail, Result};

#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {:?} does not match data length {}",
            shape,
            data.len()
        );
        Self { shape, data }
    }

    pub fn zeros(shape: &[usize]) -> Self {
        Self { shape: shape.to_vec(), data: vec![0.0; shape.iter().product()] }
    }

    pub fn ones(shape: &[usize]) -> Self {
        Self { shape: shape.to_vec(), data: vec![1.0; shape.iter().product()] }
    }

    pub fn full(shape: &[usize], v: f32) -> Self {
        Self { shape: shape.to_vec(), data: vec![v; shape.iter().product()] }
    }

    pub fn scalar(v: f32) -> Self {
        Self { shape: vec![], data: vec![v] }
    }

    pub fn from_vec(data: Vec<f32>) -> Self {
        Self { shape: vec![data.len()], data }
    }

    /// Identity matrix.
    pub fn eye(n: usize) -> Self {
        let mut t = Self::zeros(&[n, n]);
        for i in 0..n {
            t.data[i * n + i] = 1.0;
        }
        t
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn ndim(&self) -> usize {
        self.shape.len()
    }

    /// Number of rows when viewed as (rows, last_dim).
    pub fn rows_2d(&self) -> usize {
        let last = *self.shape.last().unwrap_or(&1);
        if last == 0 { 0 } else { self.numel() / last }
    }

    pub fn last_dim(&self) -> usize {
        *self.shape.last().unwrap_or(&1)
    }

    pub fn reshape(mut self, shape: &[usize]) -> Result<Self> {
        if shape.iter().product::<usize>() != self.numel() {
            bail!("cannot reshape {:?} -> {:?}", self.shape, shape);
        }
        self.shape = shape.to_vec();
        Ok(self)
    }

    /// 2D element access (row-major).
    #[inline]
    pub fn at2(&self, i: usize, j: usize) -> f32 {
        debug_assert_eq!(self.ndim(), 2);
        self.data[i * self.shape[1] + j]
    }

    #[inline]
    pub fn set2(&mut self, i: usize, j: usize, v: f32) {
        debug_assert_eq!(self.ndim(), 2);
        let c = self.shape[1];
        self.data[i * c + j] = v;
    }

    /// Borrow row `i` of a 2D tensor.
    pub fn row(&self, i: usize) -> &[f32] {
        let c = *self.shape.last().unwrap();
        &self.data[i * c..(i + 1) * c]
    }

    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        let c = *self.shape.last().unwrap();
        &mut self.data[i * c..(i + 1) * c]
    }

    /// Elementwise map into a new tensor.
    pub fn map(&self, f: impl Fn(f32) -> f32) -> Self {
        Self { shape: self.shape.clone(), data: self.data.iter().map(|&x| f(x)).collect() }
    }

    pub fn scale(&self, s: f32) -> Self {
        self.map(|x| x * s)
    }

    pub fn add(&self, other: &Self) -> Self {
        assert_eq!(self.shape, other.shape);
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a + b).collect();
        Self { shape: self.shape.clone(), data }
    }

    pub fn sub(&self, other: &Self) -> Self {
        assert_eq!(self.shape, other.shape);
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a - b).collect();
        Self { shape: self.shape.clone(), data }
    }

    pub fn mul_elem(&self, other: &Self) -> Self {
        assert_eq!(self.shape, other.shape);
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a * b).collect();
        Self { shape: self.shape.clone(), data }
    }

    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, &x| m.max(x.abs()))
    }

    pub fn mean(&self) -> f32 {
        if self.data.is_empty() {
            return 0.0;
        }
        self.data.iter().sum::<f32>() / self.numel() as f32
    }

    pub fn sum(&self) -> f32 {
        self.data.iter().sum()
    }

    pub fn frob_norm(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum::<f32>().sqrt()
    }

    /// Mean squared difference — the quantization-error metric of Fig. 3b/c.
    pub fn mse(&self, other: &Self) -> f32 {
        assert_eq!(self.shape, other.shape);
        if self.data.is_empty() {
            return 0.0;
        }
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| {
                let d = a - b;
                d * d
            })
            .sum::<f32>()
            / self.numel() as f32
    }

    /// Signal-to-noise ratio in dB against a reference (paper Table 14).
    pub fn snr_db(reference: &Self, noisy: &Self) -> f32 {
        let sig: f32 = reference.data.iter().map(|x| x * x).sum();
        let noise: f32 = reference
            .data
            .iter()
            .zip(&noisy.data)
            .map(|(a, b)| {
                let d = a - b;
                d * d
            })
            .sum();
        10.0 * (sig / noise.max(1e-20)).log10()
    }

    /// Pearson kurtosis over all entries (~3 for Gaussian; paper Fig. 3a).
    pub fn kurtosis(&self) -> f32 {
        let n = self.numel() as f64;
        if n < 2.0 {
            return 0.0;
        }
        let mu = self.data.iter().map(|&x| x as f64).sum::<f64>() / n;
        let (mut m2, mut m4) = (0.0f64, 0.0f64);
        for &x in &self.data {
            let c = x as f64 - mu;
            let c2 = c * c;
            m2 += c2;
            m4 += c2 * c2;
        }
        m2 /= n;
        m4 /= n;
        (m4 / (m2 * m2).max(1e-24)) as f32
    }

    /// Extract subtensor `t[idx]` along axis 0.
    pub fn index0(&self, idx: usize) -> Tensor {
        assert!(self.ndim() >= 1 && idx < self.shape[0]);
        let inner: usize = self.shape[1..].iter().product();
        Tensor::new(
            self.shape[1..].to_vec(),
            self.data[idx * inner..(idx + 1) * inner].to_vec(),
        )
    }

    /// Flatten to (rows, last_dim) view parameters.
    pub fn as_2d(&self) -> (usize, usize) {
        (self.rows_2d(), self.last_dim())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construct_and_index() {
        let t = Tensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(t.at2(1, 2), 6.0);
        assert_eq!(t.row(0), &[1., 2., 3.]);
        assert_eq!(t.rows_2d(), 2);
        assert_eq!(t.last_dim(), 3);
    }

    #[test]
    fn eye_and_reshape() {
        let i = Tensor::eye(3);
        assert_eq!(i.at2(1, 1), 1.0);
        assert_eq!(i.at2(0, 2), 0.0);
        let r = i.reshape(&[9]).unwrap();
        assert_eq!(r.shape, vec![9]);
        assert!(Tensor::eye(2).reshape(&[3]).is_err());
    }

    #[test]
    fn arithmetic() {
        let a = Tensor::from_vec(vec![1., 2., 3.]);
        let b = Tensor::from_vec(vec![4., 5., 6.]);
        assert_eq!(a.add(&b).data, vec![5., 7., 9.]);
        assert_eq!(b.sub(&a).data, vec![3., 3., 3.]);
        assert_eq!(a.mul_elem(&b).data, vec![4., 10., 18.]);
        assert_eq!(a.scale(2.0).data, vec![2., 4., 6.]);
    }

    #[test]
    fn stats() {
        let a = Tensor::from_vec(vec![3., -4.]);
        assert_eq!(a.max_abs(), 4.0);
        assert_eq!(a.frob_norm(), 5.0);
        let b = Tensor::from_vec(vec![3., -4.]);
        assert_eq!(a.mse(&b), 0.0);
        assert!(Tensor::snr_db(&a, &b) > 100.0);
    }

    #[test]
    fn kurtosis_gaussian_vs_outlier() {
        let mut p = crate::util::prng::Prng::new(0);
        let g: Vec<f32> = (0..10_000).map(|_| p.normal()).collect();
        let kg = Tensor::from_vec(g.clone()).kurtosis();
        assert!((kg - 3.0).abs() < 0.3, "gaussian kurtosis {kg}");
        let mut o = g;
        for i in 0..20 {
            o[i * 37] *= 40.0;
        }
        let ko = Tensor::from_vec(o).kurtosis();
        assert!(ko > 30.0, "outlier kurtosis {ko}");
    }

    #[test]
    fn index0_extracts() {
        let t = Tensor::new(vec![2, 2, 2], (0..8).map(|x| x as f32).collect());
        let s = t.index0(1);
        assert_eq!(s.shape, vec![2, 2]);
        assert_eq!(s.data, vec![4., 5., 6., 7.]);
    }
}
