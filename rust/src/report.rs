//! Report writers: markdown tables (paper-style rows) and JSON result
//! files (e.g. the `BENCH_serving.json` perf trajectory), plus the
//! EXPERIMENTS.md appender used by the bench harnesses.

use std::fmt::Write as _;
use std::path::Path;

use anyhow::{Context, Result};

use crate::util::json::Json;

/// A simple markdown table builder.
#[derive(Clone, Debug, Default)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    pub fn to_markdown(&self) -> String {
        let mut out = String::new();
        if !self.title.is_empty() {
            let _ = writeln!(out, "### {}\n", self.title);
        }
        let widths: Vec<usize> = self
            .headers
            .iter()
            .enumerate()
            .map(|(i, h)| {
                self.rows
                    .iter()
                    .map(|r| r[i].len())
                    .chain(std::iter::once(h.len()))
                    .max()
                    .unwrap_or(3)
            })
            .collect();
        let line = |cells: &[String]| -> String {
            let mut s = String::from("|");
            for (c, w) in cells.iter().zip(&widths) {
                let _ = write!(s, " {c:w$} |");
            }
            s
        };
        let _ = writeln!(out, "{}", line(&self.headers));
        let mut sep = String::from("|");
        for w in &widths {
            let _ = write!(sep, "{:-<1$}|", "", w + 2);
        }
        let _ = writeln!(out, "{sep}");
        for r in &self.rows {
            let _ = writeln!(out, "{}", line(r));
        }
        out
    }
}

/// Format helpers matching the paper's precision conventions.
pub fn fmt_acc(v: f64) -> String {
    format!("{v:.1}")
}

pub fn fmt_ppl(v: f64) -> String {
    if !v.is_finite() {
        return "inf".to_string();
    }
    if v >= 100.0 {
        // Paper writes 1e2/2e3 for blown-up perplexities.
        let exp = v.log10().floor();
        let mant = (v / 10f64.powf(exp)).round();
        format!("{}e{}", mant as i64, exp as i64)
    } else {
        format!("{v:.1}")
    }
}

/// Write a JSON report file (used by the bench harnesses to leave
/// machine-readable perf trajectories like `BENCH_serving.json`).
pub fn write_json(path: &Path, value: &Json) -> Result<()> {
    std::fs::write(path, value.to_string() + "\n")
        .with_context(|| format!("writing {path:?}"))?;
    Ok(())
}

/// Append a section to EXPERIMENTS.md (creates the file if missing).
pub fn append_experiments(repo_root: &Path, section: &str) -> Result<()> {
    let path = repo_root.join("EXPERIMENTS.md");
    let mut existing = std::fs::read_to_string(&path).unwrap_or_default();
    if existing.is_empty() {
        existing.push_str("# SpinQuant — Experiment Log\n\n");
    }
    existing.push_str(section);
    if !section.ends_with('\n') {
        existing.push('\n');
    }
    std::fs::write(&path, existing)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_markdown() {
        let mut t = Table::new("Demo", &["Method", "Acc", "Wiki"]);
        t.row(vec!["RTN".into(), "35.6".into(), "2e3".into()]);
        t.row(vec!["SpinQuant_had".into(), "64.0".into(), "5.9".into()]);
        let md = t.to_markdown();
        assert!(md.contains("### Demo"));
        assert!(md.contains("| Method"));
        assert!(md.contains("| SpinQuant_had | 64.0 | 5.9"));
        let seps = md.lines().nth(3).unwrap();
        assert!(seps.starts_with('|'));
    }

    #[test]
    fn ppl_formatting() {
        assert_eq!(fmt_ppl(5.86), "5.9");
        assert_eq!(fmt_ppl(2047.0), "2e3");
        assert_eq!(fmt_ppl(132.0), "1e2");
        assert_eq!(fmt_ppl(f64::INFINITY), "inf");
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn arity_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["1".into()]);
    }

    #[test]
    fn write_json_roundtrips() {
        let dir = std::env::temp_dir().join("spinquant_report_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("out.json");
        let j = crate::util::json::obj(vec![
            ("tokens_per_sec", crate::util::json::num(123.5)),
            ("engine", crate::util::json::s("mock")),
        ]);
        write_json(&path, &j).unwrap();
        let back = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(back.req("tokens_per_sec").unwrap().as_f64(), Some(123.5));
        let _ = std::fs::remove_file(&path);
    }
}
