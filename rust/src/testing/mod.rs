//! In-repo mini property-testing framework (proptest is not in the offline
//! vendor set). See [`prop`].

pub mod prop;
