//! In-repo test harnesses: a mini property-testing framework ([`prop`] —
//! proptest is not in the offline vendor set) and a seeded reference
//! simulator for the serving scheduler ([`sim`]), whose randomized trace
//! tests hold the real `serve::Scheduler` to a pure bookkeeping oracle.

pub mod prop;
pub mod sim;
