//! Seeded reference simulator ("oracle") for the continuous-batching
//! scheduler.
//!
//! [`simulate`] replays a trace of submit/step/cancel events against a
//! *pure bookkeeping* model of the scheduler: FIFO admission into the
//! lowest free slot, bounded queue with backpressure, batched multi-token
//! prefill (`ceil(len/chunk)` calls) or the chunk-1 interleaved path,
//! per-request generation budgets, cache-capacity truncation, and
//! mid-flight eviction. With `kv_blocks > 0` it also models the *paged*
//! KV path: free-page token-budget admission (a watermark, head-of-queue
//! only), one page claimed at admission, lazy growth at page boundaries in
//! slot order, and youngest-first evict-to-queue-front on pool exhaustion.
//! With `prefix_cache` it additionally models **refcounted copy-on-write
//! prefix sharing**: a content-addressed index of full prompt pages
//! (entries keyed by exact token prefixes — deliberately *not* the hash
//! chain the real [`crate::serve::prefix::PrefixIndex`] uses, so the two
//! implementations stay independent), LRU-clock touch on lookup, donation
//! the moment a prompt page fills, per-entry slot reference counts, a
//! watermark that charges only the non-shared remainder, and pool-pressure
//! eviction of LRU unreferenced entries. With `step_budget > 0` it models
//! the **decode-priority step composer**: the phase partition (running vs
//! warming slots), the full decode batch first, budgeted prefill takes in
//! slot order under the starvation guard, fixed (non-redistributed) plans
//! across mid-growth evictions, and the mixed-step decode-call/prefill-call
//! accounting. It also predicts `max_decode_stall_steps` — the worst
//! number of engine-call iterations any running slot waited between its
//! own tokens — for *every* configuration, which is the observable the
//! composer exists to pin at zero. With `fault_rate > 0` it additionally
//! models the **seeded fault injector and the scheduler's error kernel**:
//! the injector's three-draw schedule over every intercepted engine call
//! (trigger, per-slot-vs-step-wide, victim pick — plus correlated bursts),
//! per-slot cooldown/quarantine recovery, the step-wide pause and
//! fault-evict streak, admission (`adopt_prefix`) fault rollback, and
//! step-counted deadline shedding — so recovery *decisions* are
//! trace-checked observables too, not just the happy path. No engine, no
//! logits, no clocks — just the admission/join/evict/budget/reuse/recovery
//! arithmetic the real [`crate::serve::Scheduler`] must implement.
//!
//! The oracle also emits the scheduler's **flight-recorder event stream**
//! ([`crate::serve::trace::TraceEvent`]) from its bookkeeping — request
//! lifecycle (`Enqueued`/`Admitted`/`PrefixHit`/`PrefillChunk`/
//! `TokenDecoded`/`Evicted`/`Completed`), donations, and composer plans —
//! in the exact order the real scheduler's instrumented hot path emits
//! them (pre-call batch-build emissions, then post-call per-slot
//! processing). The physical page plane (`PageAllocated`/`Retained`/
//! `Released`) and counter samples are deliberately *not* modeled; the
//! comparison filters them out via [`TraceEvent::in_oracle_scope`].
//!
//! The randomized trace tests at the bottom generate hundreds of seeded
//! traces, run each against both the oracle and the real scheduler over
//! [`crate::serve::MockEngine`], and require them to agree on accepted
//! ids, completion order, per-request token counts, per-step slot
//! occupancy and queue depth, the exact number of decode steps and
//! prefill calls, and — event by event — the trace stream itself. The shared-prefix suites additionally require the real
//! scheduler's completions to be **byte-identical with the prefix cache on
//! and off**. Speculative decoding (`spec_k > 0`) is deliberately outside
//! the oracle's scope — acceptance depends on logit values, and this model
//! has none — so the speculation suites are real-scheduler-only: spec-on
//! runs must be **byte-identical to spec-off** at any window size, with
//! either draft source, across dense / paged / prefix-cached / composed /
//! fault-injected shapes, and the scheduler's n-gram drafting rule is
//! cross-checked against an independent mirror implementation. Failures print the seed/case (via [`super::prop::forall`])
//! so any divergence is reproducible. CI pins the seeds (see
//! `.github/workflows/ci.yml`) so trace-equivalence regressions fail the
//! build.

use std::collections::{BTreeMap, VecDeque};

use crate::serve::trace::{EvictReason, FinishReason, TraceEvent};
use crate::serve::DEFAULT_RETRY_BUDGET;
use crate::util::prng::Prng;

/// One generation request, reduced to what the bookkeeping depends on —
/// plus just enough *content* structure to express shared prompt prefixes:
/// the first `shared_len` prompt bytes are a pure function of `group` (the
/// "system prompt"), the rest a function of `tag`.
#[derive(Clone, Copy, Debug)]
pub struct SimRequest {
    pub prompt_len: usize,
    pub max_new: usize,
    pub shared_len: usize,
    pub group: u64,
    pub tag: u64,
    /// Step-counted deadline (0 = none): the request is shed — queued or
    /// mid-flight — once `step_index - submit_step >= deadline_steps`.
    pub deadline_steps: u64,
}

impl SimRequest {
    /// A request whose content doesn't matter (dense / plain paged traces).
    pub fn plain(prompt_len: usize, max_new: usize) -> Self {
        Self { prompt_len, max_new, shared_len: 0, group: 0, tag: 0, deadline_steps: 0 }
    }

    /// The deterministic prompt bytes both the oracle and the real run
    /// derive from this request.
    pub fn prompt(&self) -> Vec<u8> {
        (0..self.prompt_len)
            .map(|i| {
                let (seed, mul) =
                    if i < self.shared_len { (self.group, 31) } else { (self.tag, 13) };
                (32 + ((seed.wrapping_mul(mul).wrapping_add(i as u64 * 7)) % 90)) as u8
            })
            .collect()
    }
}

/// Scheduler shape under simulation.
#[derive(Clone, Copy, Debug)]
pub struct SimConfig {
    pub slots: usize,
    pub max_seq: usize,
    pub max_queue: usize,
    /// Engine prefill chunk; 1 = the interleaved token-by-token path.
    pub prefill_chunk: usize,
    /// Paged KV pool size in pages; 0 = the dense path.
    pub kv_blocks: usize,
    /// Tokens per page (ignored when `kv_blocks == 0`).
    pub block_size: usize,
    /// Model the content-addressed prefix cache (needs `kv_blocks > 0`).
    pub prefix_cache: bool,
    /// Per-step token budget of the decode-priority step composer; 0 = off
    /// (the classic drain-prefill-then-decode loop). Needs
    /// `prefill_chunk > 1`, like the real scheduler.
    pub step_budget: usize,
    /// KV storage width in bits for the engine (16 = full precision).
    /// The oracle's bookkeeping is width-independent — quantized KV only
    /// perturbs logit *values*, never admission, paging, or step counts —
    /// so traces must stay exact at any width.
    pub kv_bits: f64,
    /// Fault probability per intercepted engine call (0.0 = fault-free);
    /// mirrors [`crate::serve::FaultInjector`]'s schedule exactly.
    pub fault_rate: f64,
    /// Seed of the modeled fault schedule.
    pub fault_seed: u64,
    /// Correlated-failure burst length (1 = isolated faults).
    pub fault_burst: usize,
    /// Faults a request (or step-wide streak) survives before quarantine
    /// (or warm-restart eviction) — `Scheduler::with_retry_budget`.
    pub retry_budget: usize,
    /// Speculative window (`--spec-k`); 0 = speculation off. The oracle
    /// deliberately does **not** model speculation — acceptance depends on
    /// logit values, which the bookkeeping model has none of — so
    /// oracle-equivalence traces keep this 0; `spec_k > 0` configurations
    /// are consumed by the real-scheduler-only byte-identity suites
    /// (speculation must reshape call counts, never bytes).
    pub spec_k: usize,
    /// Draft source when `spec_k > 0`: n-gram prompt lookup (`true`) or a
    /// same-shape dense engine drafter (`false`).
    pub spec_ngram: bool,
}

impl SimConfig {
    /// Dense configuration (no paging, composer off).
    pub fn dense(slots: usize, max_seq: usize, max_queue: usize, prefill_chunk: usize) -> Self {
        Self {
            slots,
            max_seq,
            max_queue,
            prefill_chunk,
            kv_blocks: 0,
            block_size: 1,
            prefix_cache: false,
            step_budget: 0,
            kv_bits: 16.0,
            fault_rate: 0.0,
            fault_seed: 0,
            fault_burst: 1,
            retry_budget: DEFAULT_RETRY_BUDGET,
            spec_k: 0,
            spec_ngram: true,
        }
    }

    /// The composer's starvation guard — must match
    /// `Scheduler::prefill_guard` exactly.
    fn prefill_guard(budget: usize) -> usize {
        (budget / 4).max(1)
    }

    /// The error kernel's step-counted backoff — must match
    /// `Scheduler::backoff` exactly (1, 2, 4, ... capped at 64).
    fn backoff(attempt: usize) -> u64 {
        1u64 << attempt.saturating_sub(1).min(6)
    }
}

/// Trace events, mirroring the public scheduler API.
#[derive(Clone, Debug)]
pub enum SimEvent {
    Submit(SimRequest),
    Step,
    Cancel(u64),
}

/// Everything the oracle predicts for one trace (the trailing drain to
/// idle is included).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct SimResult {
    /// Outcome per `Submit` event: `Some(id)` or `None` (rejected — queue
    /// full or invalid prompt; rejected submits consume no id).
    pub submits: Vec<Option<u64>>,
    /// Outcome per `Cancel` event (`true` = found and removed).
    pub cancels: Vec<bool>,
    /// Request ids in completion order.
    pub completion_order: Vec<u64>,
    /// Generated-token count per completed id (truncation included).
    pub generated: BTreeMap<u64, usize>,
    /// (occupied slots, queue depth) after every non-idle step.
    pub occupancy: Vec<(usize, usize)>,
    pub decode_steps: usize,
    pub prefill_calls: usize,
    /// Paged only: pool-exhaustion evictions back to the queue.
    pub evictions: usize,
    /// Prefix cache only: prompt tokens mapped from cached pages.
    pub tokens_reused: usize,
    /// Worst decode stall: the most engine-call iterations any running
    /// slot (prompt fully fed) sat through without producing a token
    /// between two of its own tokens. Budget-off chunked prefill drives
    /// this to `ceil(len/chunk)` during a long prompt; the composer pins
    /// it at 0.
    pub max_decode_stall_steps: usize,
    /// Fault plane (all zero on fault-free traces): step-wide and per-slot
    /// engine faults the modeled injector returned, retries the error
    /// kernel scheduled, slots that recovered on their next successful
    /// call, requests quarantined at the retry budget, requests evicted by
    /// a step-wide fault streak, and deadline sheds (queued / mid-flight).
    /// Mirror the eight `ServingMetrics` fault counters exactly.
    pub step_faults: usize,
    pub slot_faults: usize,
    pub retries: usize,
    pub recovered: usize,
    pub quarantined: usize,
    pub fault_evictions: usize,
    pub shed_queued: usize,
    pub shed_inflight: usize,
    /// The oracle's flight-recorder stream: every logical scheduling event
    /// (request lifecycle + composer plans) in emission order, mirroring
    /// what the real scheduler's trace emits — minus the physical page
    /// plane and counter samples, which [`TraceEvent::in_oracle_scope`]
    /// filters from the real stream before comparison. The equivalence
    /// suites require *exact sequence equality*, so scheduler decisions
    /// themselves (not just their aggregates) are a checked observable.
    pub events: Vec<TraceEvent>,
}

#[derive(Clone, Debug)]
struct SimSlot {
    id: u64,
    req: SimRequest,
    /// Prompt bytes (the content keys pages are donated/matched under).
    prompt: Vec<u8>,
    fed: usize,
    gen: usize,
    pos: usize,
    /// Paged: pages this slot owns exclusively (no index reference).
    own_pages: usize,
    /// Prefix: index entries this slot references — mapped at admission or
    /// donated by this slot (counts toward its table coverage).
    refs: Vec<u64>,
    /// Engine-call iterations this slot idled through since its last token
    /// (only ticks while running — mirrors `Active::stall_steps`).
    stall: usize,
    /// Individual faults charged to this request (quarantine at
    /// `retry_budget`) — survives evictions, mirrors `Active::faults`.
    faults: usize,
    /// Steps left before this slot may rejoin engine calls.
    cooldown: u64,
    /// Waiting for its first successful call after a fault.
    recovering: bool,
    /// Step the request was submitted on (step deadlines count from here).
    submit_step: u64,
}

/// A queued request plus the recovery bookkeeping that rides with it
/// (mirrors the real scheduler's `Queued` fault fields).
#[derive(Clone, Copy, Debug)]
struct SimQueued {
    id: u64,
    req: SimRequest,
    faults: usize,
    /// Admission is blocked while `step_index < not_before_step` — the
    /// head on backoff blocks the whole (FIFO) queue.
    not_before_step: u64,
    submit_step: u64,
}

/// One cached page in the oracle's index: its exact token-prefix key, LRU
/// clock, and how many live slots reference it (its pool refcount is
/// `1 + slot_refs`).
#[derive(Clone, Debug)]
struct CacheEntry {
    key: Vec<u8>,
    clock: u64,
    slot_refs: usize,
}

struct SimState {
    cfg: SimConfig,
    slots: Vec<Option<SimSlot>>,
    pending: VecDeque<SimQueued>,
    next_id: u64,
    /// Paged: free pages in the pool (refcount 0).
    free_pages: usize,
    /// Prefix: cached pages by entry id (each holds one resident page).
    index: BTreeMap<u64, CacheEntry>,
    next_entry: u64,
    clock: u64,
    /// Modeled `FaultInjector` schedule: same PRNG, same three draws per
    /// intercepted call, same burst arming.
    rng: Prng,
    burst_left: usize,
    /// Mirrors `Scheduler::step_index` / `pause_until` /
    /// `step_fault_streak` — the error kernel's step-counted clock.
    step_index: u64,
    pause_until: u64,
    step_fault_streak: usize,
}

impl SimState {
    fn occupied(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    fn is_idle(&self) -> bool {
        self.pending.is_empty() && self.occupied() == 0
    }

    fn paged(&self) -> bool {
        self.cfg.kv_blocks > 0
    }

    /// Pages a request needs end to end (prompt + budget, capped at the
    /// logical capacity) — the admission demand, computed once per request
    /// in the real scheduler too.
    fn pages_needed(&self, r: &SimRequest) -> usize {
        (r.prompt_len + r.max_new).min(self.cfg.max_seq).div_ceil(self.cfg.block_size)
    }

    fn covered_pages(s: &SimSlot) -> usize {
        s.refs.len() + s.own_pages
    }

    fn find_entry(&self, key: &[u8]) -> Option<u64> {
        self.index.iter().find(|(_, e)| e.key == key).map(|(&id, _)| id)
    }

    /// Entries no live slot references — resident but reclaimable.
    fn evictable_count(&self) -> usize {
        self.index.values().filter(|e| e.slot_refs == 0).count()
    }

    /// Mirror of the real `PrefixIndex::lookup`: walk the prompt's full
    /// pages (capped one token short of the prompt), touching LRU clocks
    /// as it matches; touches persist even if the admission then fails its
    /// watermark.
    fn lookup_touch(&mut self, prompt: &[u8]) -> Vec<u64> {
        let bs = self.cfg.block_size;
        let max_pages = if prompt.is_empty() { 0 } else { (prompt.len() - 1) / bs };
        let mut out = Vec::new();
        for j in 0..max_pages {
            let Some(id) = self.find_entry(&prompt[..(j + 1) * bs]) else { break };
            self.clock += 1;
            self.index.get_mut(&id).expect("found").clock = self.clock;
            out.push(id);
        }
        out
    }

    /// Mirror of `SlotMap::allocate_page`: a free page, else the LRU
    /// unreferenced index entry is evicted to make one.
    fn claim_page(&mut self) -> bool {
        if self.free_pages > 0 {
            self.free_pages -= 1;
            return true;
        }
        let Some((&id, _)) = self
            .index
            .iter()
            .filter(|(_, e)| e.slot_refs == 0)
            .min_by_key(|(_, e)| e.clock)
        else {
            return false;
        };
        self.index.remove(&id);
        true
    }

    fn submit(&mut self, r: SimRequest) -> Option<u64> {
        if r.prompt_len == 0 || r.prompt_len >= self.cfg.max_seq {
            return None;
        }
        if self.paged() && self.pages_needed(&r) > self.cfg.kv_blocks {
            return None;
        }
        if self.pending.len() >= self.cfg.max_queue {
            return None;
        }
        let id = self.next_id;
        self.next_id += 1;
        self.pending.push_back(SimQueued {
            id,
            req: r,
            faults: 0,
            not_before_step: 0,
            submit_step: self.step_index,
        });
        Some(id)
    }

    /// Mirror of `FaultInjector::roll`: exactly three schedule draws per
    /// intercepted engine call — `(fault, per_slot, pick)`. Forced burst
    /// follow-ups consume their draws too.
    fn roll(&mut self) -> (bool, bool, f32) {
        let trigger = (self.rng.uniform() as f64) < self.cfg.fault_rate;
        let per_slot = self.rng.uniform() < 0.5;
        let pick = self.rng.uniform();
        let fault = if self.burst_left > 0 {
            self.burst_left -= 1;
            true
        } else if trigger {
            self.burst_left = self.cfg.fault_burst.max(1) - 1;
            true
        } else {
            false
        };
        (fault, per_slot, pick)
    }

    /// Mirror of `FaultInjector::decide` for a batch call over `active`
    /// lanes: `Some(Some(slot))` = per-slot fault, `Some(None)` =
    /// step-wide, `None` = the call succeeds. Fault-free configurations
    /// consume no draws (the real run uses no injector then).
    fn decide(&mut self, active: &[bool]) -> Option<Option<usize>> {
        if self.cfg.fault_rate <= 0.0 {
            return None;
        }
        let (fault, per_slot, pick) = self.roll();
        if !fault {
            return None;
        }
        let victims: Vec<usize> = (0..active.len()).filter(|&b| active[b]).collect();
        if per_slot && !victims.is_empty() {
            let k = ((pick * victims.len() as f32) as usize).min(victims.len() - 1);
            Some(Some(victims[k]))
        } else {
            Some(None)
        }
    }

    /// Mirror of `FaultInjector::decide_adopt`: an `adopt_prefix` call is
    /// always blamed on the adopting slot (draws 2 and 3 consumed and
    /// ignored).
    fn decide_adopt(&mut self) -> bool {
        if self.cfg.fault_rate <= 0.0 {
            return false;
        }
        self.roll().0
    }

    /// Mirror of `Scheduler::retire_failed`: free the slot, count the
    /// terminal outcome — but emit no `Completed` event (failures have
    /// their own records, emitted by the caller).
    fn retire_failed(&mut self, b: usize, res: &mut SimResult) {
        let s = self.slots[b].take().expect("retiring an occupied slot");
        self.release_slot_pages(&s);
        res.completion_order.push(s.id);
        res.generated.insert(s.id, s.gen);
    }

    /// Mirror of `Scheduler::evict_for_fault`: warm-restart eviction to
    /// the queue front after a step-wide fault streak — the request keeps
    /// its individual fault charge and is re-admissible immediately.
    fn evict_for_fault(&mut self, b: usize, res: &mut SimResult) {
        let s = self.slots[b].take().expect("fault-evicting an occupied slot");
        self.release_slot_pages(&s);
        res.fault_evictions += 1;
        res.events.push(TraceEvent::Evicted { id: s.id, slot: b, reason: EvictReason::Fault });
        self.pending.push_front(SimQueued {
            id: s.id,
            req: s.req,
            faults: s.faults,
            not_before_step: 0,
            submit_step: s.submit_step,
        });
    }

    /// Mirror of `Scheduler::handle_fault`: `fault` is `Some(slot)`
    /// (per-slot) or `None` (step-wide); `participants` marks the lanes of
    /// the abandoned call. Nothing advanced — not advancing the
    /// bookkeeping *is* the rollback.
    fn handle_fault(
        &mut self,
        fault: Option<usize>,
        participants: &[bool],
        res: &mut SimResult,
    ) {
        match fault {
            Some(slot) => {
                res.slot_faults += 1;
                res.events.push(TraceEvent::FaultInjected { slot: Some(slot) });
                let s = self.slots[slot].as_mut().expect("blamed slot is occupied");
                s.faults += 1;
                let attempt = s.faults;
                let id = s.id;
                if attempt >= self.cfg.retry_budget {
                    res.quarantined += 1;
                    res.events.push(TraceEvent::RequestFailed {
                        id,
                        slot: Some(slot),
                        faults: attempt,
                    });
                    self.retire_failed(slot, res);
                } else {
                    let backoff = SimConfig::backoff(attempt);
                    let s = self.slots[slot].as_mut().expect("occupied");
                    s.cooldown = backoff;
                    s.recovering = true;
                    res.retries += 1;
                    res.events.push(TraceEvent::RetryScheduled {
                        slot: Some(slot),
                        backoff_steps: backoff as usize,
                        attempt,
                    });
                }
            }
            None => {
                res.step_faults += 1;
                res.events.push(TraceEvent::FaultInjected { slot: None });
                self.step_fault_streak += 1;
                let attempt = self.step_fault_streak;
                if attempt >= self.cfg.retry_budget {
                    self.step_fault_streak = 0;
                    // Descending slot order, so the queue ends up in
                    // ascending slot order — same as the real kernel.
                    for b in (0..participants.len()).rev() {
                        if participants[b] && self.slots[b].is_some() {
                            self.evict_for_fault(b, res);
                        }
                    }
                } else {
                    let backoff = SimConfig::backoff(attempt);
                    self.pause_until = self.step_index + 1 + backoff;
                    for b in 0..participants.len() {
                        if participants[b] {
                            if let Some(s) = self.slots[b].as_mut() {
                                s.recovering = true;
                            }
                        }
                    }
                    res.retries += 1;
                    res.events.push(TraceEvent::RetryScheduled {
                        slot: None,
                        backoff_steps: backoff as usize,
                        attempt,
                    });
                }
            }
        }
    }

    /// Mirror of `Scheduler::note_engine_success`: a successful call
    /// resets the step-wide streak and recovers its waiting participants
    /// (ascending slot order).
    fn note_success(&mut self, participants: &[bool], res: &mut SimResult) {
        self.step_fault_streak = 0;
        for b in 0..participants.len() {
            if !participants[b] {
                continue;
            }
            if let Some(s) = self.slots[b].as_mut() {
                if s.recovering {
                    s.recovering = false;
                    res.recovered += 1;
                    res.events.push(TraceEvent::SlotRecovered { id: s.id, slot: b });
                }
            }
        }
    }

    /// Mirror of `Scheduler::shed_expired`: queued requests first (in
    /// queue order), then mid-flight slots (ascending). Runs before the
    /// pause gate — deadlines fire even while the engine backs off.
    fn shed_expired(&mut self, res: &mut SimResult) {
        let mut i = 0;
        while i < self.pending.len() {
            let q = self.pending[i];
            if q.req.deadline_steps > 0
                && self.step_index.saturating_sub(q.submit_step) >= q.req.deadline_steps
            {
                self.pending.remove(i).expect("index in range");
                res.shed_queued += 1;
                res.events.push(TraceEvent::DeadlineExpired { id: q.id, queued: true });
                res.completion_order.push(q.id);
                res.generated.insert(q.id, 0);
            } else {
                i += 1;
            }
        }
        for b in 0..self.cfg.slots {
            let expired = self.slots[b].as_ref().is_some_and(|s| {
                s.req.deadline_steps > 0
                    && self.step_index.saturating_sub(s.submit_step) >= s.req.deadline_steps
            });
            if expired {
                let id = self.slots[b].as_ref().expect("checked above").id;
                res.shed_inflight += 1;
                res.events.push(TraceEvent::DeadlineExpired { id, queued: false });
                self.retire_failed(b, res);
            }
        }
    }

    /// Drop a slot's page references: exclusive pages free, index entries
    /// lose one slot reference (the pages stay resident).
    fn release_slot_pages(&mut self, s: &SimSlot) {
        self.free_pages += s.own_pages;
        for id in &s.refs {
            self.index.get_mut(id).expect("referenced entry").slot_refs -= 1;
        }
    }

    fn cancel(&mut self, id: u64, res: &mut SimResult) -> bool {
        if let Some(i) = self.pending.iter().position(|q| q.id == id) {
            self.pending.remove(i);
            return true;
        }
        for b in 0..self.cfg.slots {
            if self.slots[b].as_ref().map(|s| s.id) == Some(id) {
                let s = self.slots[b].take().expect("occupied");
                self.release_slot_pages(&s);
                res.events.push(TraceEvent::Evicted {
                    id,
                    slot: b,
                    reason: EvictReason::Cancelled,
                });
                return true;
            }
        }
        false
    }

    fn admit(&mut self, res: &mut SimResult) {
        while !self.pending.is_empty() {
            let Some(b) = self.slots.iter().position(|s| s.is_none()) else { break };
            // A head on fault backoff blocks the (FIFO) queue.
            if self.pending.front().expect("non-empty").not_before_step > self.step_index {
                break;
            }
            let r = self.pending.front().expect("non-empty").req;
            let (matched, cached) = if self.paged() && self.cfg.prefix_cache {
                let m = self.lookup_touch(&r.prompt());
                let cached = m.len() * self.cfg.block_size;
                (m, cached)
            } else {
                (Vec::new(), 0)
            };
            if self.paged() {
                // Retain the matched entries, then check the watermark over
                // the non-shared remainder; roll the refs back on failure
                // (the LRU touches persist — same as the real index).
                for id in &matched {
                    self.index.get_mut(id).expect("matched").slot_refs += 1;
                }
                let needed_fresh = self.pages_needed(&r).saturating_sub(matched.len());
                if self.free_pages + self.evictable_count() < needed_fresh {
                    for id in &matched {
                        self.index.get_mut(id).expect("matched").slot_refs -= 1;
                    }
                    break;
                }
            }
            let q = self.pending.pop_front().expect("non-empty");
            let (id, r) = (q.id, q.req);
            let own_pages = if self.paged() {
                // First writable page claimed now (watermark guarantees
                // needed_fresh >= 1 is claimable).
                assert!(self.claim_page(), "watermark passed but no page claimable");
                1
            } else {
                0
            };
            // A nonzero cached prefix means the real scheduler calls
            // `adopt_prefix` — an intercepted call the injector may fail.
            // On a fault the admission rolls back completely (the claimed
            // page frees, the prefix refcounts drop; LRU touches and any
            // page-claim eviction persist) and the request is requeued at
            // the front on backoff, or quarantined at the budget.
            if cached > 0 && self.decide_adopt() {
                self.free_pages += own_pages;
                for eid in &matched {
                    self.index.get_mut(eid).expect("matched").slot_refs -= 1;
                }
                res.slot_faults += 1;
                res.events.push(TraceEvent::FaultInjected { slot: Some(b) });
                let attempt = q.faults + 1;
                if attempt >= self.cfg.retry_budget {
                    res.quarantined += 1;
                    res.events.push(TraceEvent::RequestFailed {
                        id,
                        slot: Some(b),
                        faults: attempt,
                    });
                    res.completion_order.push(id);
                    res.generated.insert(id, 0);
                } else {
                    let backoff = SimConfig::backoff(attempt);
                    res.retries += 1;
                    res.events.push(TraceEvent::RetryScheduled {
                        slot: Some(b),
                        backoff_steps: backoff as usize,
                        attempt,
                    });
                    self.pending.push_front(SimQueued {
                        faults: attempt,
                        not_before_step: self.step_index + backoff,
                        ..q
                    });
                }
                continue;
            }
            res.tokens_reused += cached;
            // Mirror of the scheduler's Admitted emission: end-to-end page
            // demand minus the whole pages the prefix cache mapped.
            let pages_charged =
                if self.paged() { self.pages_needed(&r) - matched.len() } else { 0 };
            res.events.push(TraceEvent::Admitted {
                id,
                slot: b,
                pages_charged,
                tokens_reused: cached,
            });
            if cached > 0 {
                res.events.push(TraceEvent::PrefixHit { id, slot: b, pages: matched.len() });
            }
            self.slots[b] = Some(SimSlot {
                id,
                req: r,
                prompt: r.prompt(),
                fed: cached,
                gen: 0,
                pos: cached,
                own_pages,
                refs: matched,
                stall: 0,
                faults: q.faults,
                cooldown: 0,
                recovering: false,
                submit_step: q.submit_step,
            });
        }
    }

    fn retire(&mut self, b: usize, res: &mut SimResult) {
        let s = self.slots[b].take().expect("retiring an occupied slot");
        self.release_slot_pages(&s);
        let reason = if s.gen >= s.req.max_new {
            FinishReason::BudgetExhausted
        } else {
            FinishReason::CacheFull
        };
        res.events.push(TraceEvent::Completed { id: s.id, slot: b, reason });
        res.completion_order.push(s.id);
        res.generated.insert(s.id, s.gen);
    }

    /// Mirror of `Scheduler::evict_youngest`: drop the largest-id slot's
    /// page references and requeue it (reset) at the queue front.
    fn evict_youngest(&mut self, res: &mut SimResult) {
        let victim = (0..self.cfg.slots)
            .filter(|&b| self.slots[b].is_some())
            .max_by_key(|&b| self.slots[b].as_ref().expect("occupied").id)
            .expect("pool exhausted with nothing in flight");
        let s = self.slots[victim].take().expect("occupied");
        self.release_slot_pages(&s);
        res.evictions += 1;
        res.events.push(TraceEvent::Evicted {
            id: s.id,
            slot: victim,
            reason: EvictReason::PoolExhausted,
        });
        self.pending.push_front(SimQueued {
            id: s.id,
            req: s.req,
            faults: s.faults,
            not_before_step: 0,
            submit_step: s.submit_step,
        });
    }

    /// Mirror of `Scheduler::grow_or_evict`: grow slot `b` to cover
    /// `[0, target)` — free pages first, then LRU index eviction, then
    /// youngest-first scheduler eviction while the pool stays dry.
    fn grow_or_evict(&mut self, b: usize, target: usize, res: &mut SimResult) {
        loop {
            let Some(s) = self.slots[b].as_ref() else { return };
            let needed = target.div_ceil(self.cfg.block_size);
            if Self::covered_pages(s) >= needed {
                return;
            }
            if self.claim_page() {
                self.slots[b].as_mut().expect("occupied").own_pages += 1;
            } else {
                self.evict_youngest(res);
            }
        }
    }

    /// Mirror of the donation inside `SlotMap::advance_by`: every page that
    /// filled in `(old_pos, new_pos]` wholly inside the prompt enters the
    /// index (duplicates keep the existing entry; the page stays owned).
    fn donate(&mut self, b: usize, old_pos: usize, new_pos: usize, res: &mut SimResult) {
        if !self.cfg.prefix_cache {
            return;
        }
        let bs = self.cfg.block_size;
        let prompt = self.slots[b].as_ref().expect("occupied").prompt.clone();
        let mut donated = 0usize;
        for j in (old_pos / bs)..(new_pos / bs) {
            if (j + 1) * bs > prompt.len() {
                continue;
            }
            if self.find_entry(&prompt[..(j + 1) * bs]).is_some() {
                continue;
            }
            self.clock += 1;
            let id = self.next_entry;
            self.next_entry += 1;
            let key = prompt[..(j + 1) * bs].to_vec();
            self.index.insert(id, CacheEntry { key, clock: self.clock, slot_refs: 1 });
            let s = self.slots[b].as_mut().expect("occupied");
            s.own_pages -= 1;
            s.refs.push(id);
            donated += 1;
        }
        if donated > 0 {
            res.events.push(TraceEvent::PrefixDonated { slot: b, pages: donated });
        }
    }

    /// Mirror of `Scheduler::step`: tick the step clock (cooldowns, pause,
    /// deadlines — all counted in steps, never wall clock), shed expired
    /// requests, then admit and — with a step budget — one composed
    /// decode-priority iteration, otherwise one prefill call or one decode
    /// step; retire finished slots in slot order. Every modeled engine
    /// call first consults the modeled injector: a faulted call advances
    /// nothing and routes through the mirrored error kernel instead.
    fn step(&mut self, res: &mut SimResult) {
        // The harness records occupancy after every step that did not
        // *start* idle — mirror that from the same pre-step snapshot (a
        // queue head on fault backoff keeps the scheduler non-idle even
        // when nothing runs).
        let was_idle = self.is_idle();
        self.step_index += 1;
        for s in self.slots.iter_mut().flatten() {
            if s.cooldown > 0 {
                s.cooldown -= 1;
            }
        }
        self.shed_expired(res);
        if self.step_index < self.pause_until {
            // Step-wide backoff: the engine is left alone this step.
            if !was_idle {
                res.occupancy.push((self.occupied(), self.pending.len()));
            }
            return;
        }
        self.admit(res);
        let chunk = self.cfg.prefill_chunk.max(1);
        // Running snapshot, taken (like the real scheduler's) before any
        // growth can evict a slot; cooling slots are excluded — they join
        // no engine call until their backoff expires.
        let running: Vec<bool> = self
            .slots
            .iter()
            .map(|s| {
                s.as_ref().is_some_and(|s| s.fed >= s.req.prompt_len && s.cooldown == 0)
            })
            .collect();
        if self.cfg.step_budget > 0 {
            self.composed_step(chunk, &running, was_idle, res);
            return;
        }
        let owes = |s: &Option<SimSlot>| {
            s.as_ref().is_some_and(|s| s.cooldown == 0 && s.fed < s.req.prompt_len)
        };
        let prefilling = chunk > 1 && self.slots.iter().any(owes);
        if prefilling {
            if self.paged() {
                for b in 0..self.cfg.slots {
                    let take = match self.slots[b].as_ref() {
                        Some(s) if s.cooldown == 0 && s.fed < s.req.prompt_len => {
                            chunk.min(s.req.prompt_len - s.fed)
                        }
                        _ => continue,
                    };
                    let target = self.slots[b].as_ref().expect("occupied").pos + take;
                    self.grow_or_evict(b, target, res);
                }
                if !self.slots.iter().any(owes) {
                    // Every prefiller was evicted: the real scheduler skips
                    // the engine call this iteration (no stall tick — no
                    // call ran).
                    res.occupancy.push((self.occupied(), self.pending.len()));
                    return;
                }
            }
            // The real scheduler emits every PrefillChunk while *building*
            // the batched call, then processes the results — two passes, so
            // the oracle's emissions must split the same way. On a fault
            // the build-time events stay and the processing never runs.
            let mut pactive = vec![false; self.cfg.slots];
            for b in 0..self.cfg.slots {
                if let Some(s) = self.slots[b].as_ref() {
                    if s.cooldown == 0 && s.fed < s.req.prompt_len {
                        pactive[b] = true;
                        let take = chunk.min(s.req.prompt_len - s.fed);
                        res.events.push(TraceEvent::PrefillChunk {
                            id: s.id,
                            slot: b,
                            pos0: s.pos,
                            take,
                        });
                    }
                }
            }
            if let Some(fault) = self.decide(&pactive) {
                self.handle_fault(fault, &pactive, res);
                res.occupancy.push((self.occupied(), self.pending.len()));
                return;
            }
            res.prefill_calls += 1;
            self.note_success(&pactive, res);
            for b in 0..self.cfg.slots {
                if !pactive[b] {
                    continue;
                }
                let advanced = match self.slots[b].as_mut() {
                    Some(s) if s.fed < s.req.prompt_len => {
                        let take = chunk.min(s.req.prompt_len - s.fed);
                        let old_pos = s.pos;
                        s.fed += take;
                        s.pos += take;
                        let mut sampled = false;
                        let mut fin = false;
                        if s.fed >= s.req.prompt_len {
                            if s.gen < s.req.max_new {
                                s.gen += 1;
                                sampled = true;
                            }
                            if s.gen >= s.req.max_new {
                                fin = true;
                            }
                        }
                        Some((s.id, old_pos, s.pos, sampled, fin || s.pos >= self.cfg.max_seq))
                    }
                    _ => continue,
                };
                if let Some((id, old_pos, new_pos, sampled, finished)) = advanced {
                    self.donate(b, old_pos, new_pos, res);
                    if sampled {
                        // First token, sampled off the chunk that completed
                        // the prompt — not a decode-set token, so no stall.
                        res.events.push(TraceEvent::TokenDecoded {
                            id,
                            slot: b,
                            stall_steps: None,
                        });
                    }
                    if finished {
                        self.retire(b, res);
                    }
                }
            }
            // Running slots idled through this prefill call: that is the
            // decode hiccup, one stall tick each.
            for b in 0..self.cfg.slots {
                if running[b] {
                    if let Some(s) = self.slots[b].as_mut() {
                        s.stall += 1;
                    }
                }
            }
        } else {
            if self.paged() {
                for b in 0..self.cfg.slots {
                    // Cooling slots are skipped by `grow_for_decode` too.
                    let pos = match self.slots[b].as_ref() {
                        Some(s) if s.cooldown == 0 => s.pos,
                        _ => continue,
                    };
                    self.grow_or_evict(b, pos + 1, res);
                }
            }
            if self.occupied() == 0 {
                // The real scheduler returns without an engine call; the
                // harness records occupancy only if the step started
                // non-idle (possible with a queue head on backoff).
                if !was_idle {
                    res.occupancy.push((self.occupied(), self.pending.len()));
                }
                return;
            }
            let dactive: Vec<bool> = self
                .slots
                .iter()
                .map(|s| s.as_ref().is_some_and(|s| s.cooldown == 0))
                .collect();
            if !dactive.iter().any(|&a| a) {
                // Every occupied slot is cooling: no engine call runs this
                // step (the real decode pass bails before calling).
                res.occupancy.push((self.occupied(), self.pending.len()));
                return;
            }
            // Pre-call pass, mirroring the real batch-build loop: a warming
            // lane on the interleaved path feeds one prompt token per call —
            // a PrefillChunk of take 1, emitted before any result lands.
            for b in 0..self.cfg.slots {
                if !dactive[b] {
                    continue;
                }
                if let Some(s) = self.slots[b].as_ref() {
                    if s.fed < s.req.prompt_len {
                        res.events.push(TraceEvent::PrefillChunk {
                            id: s.id,
                            slot: b,
                            pos0: s.pos,
                            take: 1,
                        });
                    }
                }
            }
            if let Some(fault) = self.decide(&dactive) {
                self.handle_fault(fault, &dactive, res);
                res.occupancy.push((self.occupied(), self.pending.len()));
                return;
            }
            res.decode_steps += 1;
            self.note_success(&dactive, res);
            for b in 0..self.cfg.slots {
                if !dactive[b] {
                    continue;
                }
                let advanced = match self.slots[b].as_mut() {
                    Some(s) => {
                        let old_pos = s.pos;
                        s.pos += 1;
                        if s.fed < s.req.prompt_len {
                            s.fed += 1;
                        }
                        let mut sampled = false;
                        let mut fin = false;
                        if s.fed >= s.req.prompt_len {
                            if s.gen < s.req.max_new {
                                s.gen += 1;
                                sampled = true;
                            }
                            if s.gen >= s.req.max_new {
                                fin = true;
                            }
                        }
                        let stall = if running[b] {
                            // A running slot always samples on a decode
                            // step: its accumulated stall is recorded.
                            res.max_decode_stall_steps =
                                res.max_decode_stall_steps.max(s.stall);
                            let stall = s.stall;
                            s.stall = 0;
                            Some(stall)
                        } else {
                            None
                        };
                        Some((s.id, old_pos, s.pos, sampled, stall, fin || s.pos >= self.cfg.max_seq))
                    }
                    None => continue,
                };
                if let Some((id, old_pos, new_pos, sampled, stall, finished)) = advanced {
                    self.donate(b, old_pos, new_pos, res);
                    if sampled {
                        res.events.push(TraceEvent::TokenDecoded {
                            id,
                            slot: b,
                            stall_steps: stall,
                        });
                    }
                    if finished {
                        self.retire(b, res);
                    }
                }
            }
        }
        res.occupancy.push((self.occupied(), self.pending.len()));
    }

    /// Mirror of `Scheduler::composed_step`: partition by phase, run the
    /// whole decode set, then fill what remains of the budget (floored by
    /// the starvation guard) with prefill takes in slot order. Growth runs
    /// decode slots first; an eviction drops its slot from the fixed plan.
    /// A fault on the decode call abandons the whole step (the planned
    /// prefill included); a fault on the prefill call keeps the decode
    /// half's results — exactly the real composer's two hazard points.
    fn composed_step(
        &mut self,
        chunk: usize,
        running: &[bool],
        was_idle: bool,
        res: &mut SimResult,
    ) {
        if self.occupied() == 0 {
            // No engine call; occupancy recorded only if the step started
            // non-idle (possible with a queue head on fault backoff — with
            // every slot free the watermark itself always passes).
            if !was_idle {
                res.occupancy.push((self.occupied(), self.pending.len()));
            }
            return;
        }
        let budget = self.cfg.step_budget;
        let decode_tokens = running.iter().filter(|&&r| r).count();
        // Cooling slots sit the step out entirely: not in the decode set
        // (the running snapshot excluded them), not prefill candidates.
        let any_warming = self.slots.iter().any(|s| {
            s.as_ref().is_some_and(|s| s.cooldown == 0 && s.fed < s.req.prompt_len)
        });
        let mut prefill_left = if any_warming {
            budget.saturating_sub(decode_tokens).max(SimConfig::prefill_guard(budget))
        } else {
            0
        };
        let mut takes = vec![0usize; self.cfg.slots];
        for b in 0..self.cfg.slots {
            if prefill_left == 0 {
                break;
            }
            if let Some(s) = self.slots[b].as_ref() {
                if s.cooldown == 0 && s.fed < s.req.prompt_len {
                    let take = chunk.min(s.req.prompt_len - s.fed).min(prefill_left);
                    takes[b] = take;
                    prefill_left -= take;
                }
            }
        }
        // The plan is fixed here — record it before growth can shrink the
        // surviving set, like the real composer does.
        let planned_take: usize = takes.iter().sum();
        if decode_tokens + planned_take > 0 {
            res.events.push(TraceEvent::StepComposed {
                decode_lanes: decode_tokens,
                prefill_take: planned_take,
                budget,
            });
        }
        if self.paged() {
            for b in 0..self.cfg.slots {
                if running[b] && self.slots[b].is_some() {
                    let target = self.slots[b].as_ref().expect("occupied").pos + 1;
                    self.grow_or_evict(b, target, res);
                }
            }
            for b in 0..self.cfg.slots {
                if takes[b] > 0 && self.slots[b].is_some() {
                    let target = self.slots[b].as_ref().expect("occupied").pos + takes[b];
                    self.grow_or_evict(b, target, res);
                }
            }
        }
        // -- decode call over the surviving decode set.
        let dactive: Vec<bool> =
            (0..self.cfg.slots).map(|b| running[b] && self.slots[b].is_some()).collect();
        if dactive.iter().any(|&a| a) {
            if let Some(fault) = self.decide(&dactive) {
                // Nothing advanced; the planned prefill half is abandoned
                // with the rest of the step.
                self.handle_fault(fault, &dactive, res);
                res.occupancy.push((self.occupied(), self.pending.len()));
                return;
            }
            res.decode_steps += 1;
            self.note_success(&dactive, res);
            for b in 0..self.cfg.slots {
                if !running[b] {
                    continue;
                }
                let advanced = match self.slots[b].as_mut() {
                    Some(s) => {
                        let old_pos = s.pos;
                        s.pos += 1;
                        let mut sampled = false;
                        let mut fin = false;
                        if s.gen < s.req.max_new {
                            s.gen += 1;
                            sampled = true;
                        }
                        if s.gen >= s.req.max_new {
                            fin = true;
                        }
                        res.max_decode_stall_steps = res.max_decode_stall_steps.max(s.stall);
                        let stall = s.stall;
                        s.stall = 0;
                        Some((
                            s.id,
                            old_pos,
                            s.pos,
                            sampled,
                            Some(stall),
                            fin || s.pos >= self.cfg.max_seq,
                        ))
                    }
                    None => continue,
                };
                if let Some((id, old_pos, new_pos, sampled, stall, finished)) = advanced {
                    self.donate(b, old_pos, new_pos, res);
                    if sampled {
                        res.events.push(TraceEvent::TokenDecoded {
                            id,
                            slot: b,
                            stall_steps: stall,
                        });
                    }
                    if finished {
                        self.retire(b, res);
                    }
                }
            }
        }
        // -- at most one prefill call over the surviving planned takes.
        let pactive: Vec<bool> =
            (0..self.cfg.slots).map(|b| takes[b] > 0 && self.slots[b].is_some()).collect();
        if pactive.iter().any(|&a| a) {
            // Pre-call pass: every surviving planned take is announced
            // before any result is processed (the real batch-build loop);
            // on a fault the announcements stay, the results never land.
            for b in 0..self.cfg.slots {
                if !pactive[b] {
                    continue;
                }
                if let Some(s) = self.slots[b].as_ref() {
                    res.events.push(TraceEvent::PrefillChunk {
                        id: s.id,
                        slot: b,
                        pos0: s.pos,
                        take: takes[b],
                    });
                }
            }
            if let Some(fault) = self.decide(&pactive) {
                // The decode half already ran and retired; only the
                // prefill half is abandoned.
                self.handle_fault(fault, &pactive, res);
                res.occupancy.push((self.occupied(), self.pending.len()));
                return;
            }
            res.prefill_calls += 1;
            self.note_success(&pactive, res);
            for b in 0..self.cfg.slots {
                if takes[b] == 0 {
                    continue;
                }
                let advanced = match self.slots[b].as_mut() {
                    Some(s) => {
                        let take = takes[b];
                        let old_pos = s.pos;
                        s.fed += take;
                        s.pos += take;
                        let mut sampled = false;
                        let mut fin = false;
                        if s.fed >= s.req.prompt_len {
                            if s.gen < s.req.max_new {
                                s.gen += 1;
                                sampled = true;
                            }
                            if s.gen >= s.req.max_new {
                                fin = true;
                            }
                        }
                        Some((s.id, old_pos, s.pos, sampled, fin || s.pos >= self.cfg.max_seq))
                    }
                    None => continue,
                };
                if let Some((id, old_pos, new_pos, sampled, finished)) = advanced {
                    self.donate(b, old_pos, new_pos, res);
                    if sampled {
                        res.events.push(TraceEvent::TokenDecoded {
                            id,
                            slot: b,
                            stall_steps: None,
                        });
                    }
                    if finished {
                        self.retire(b, res);
                    }
                }
            }
        }
        res.occupancy.push((self.occupied(), self.pending.len()));
    }
}

/// Replay `events` against the bookkeeping model, then drain to idle.
pub fn simulate(cfg: &SimConfig, events: &[SimEvent]) -> SimResult {
    let mut st = SimState {
        cfg: *cfg,
        slots: (0..cfg.slots).map(|_| None).collect(),
        pending: VecDeque::new(),
        next_id: 0,
        free_pages: cfg.kv_blocks,
        index: BTreeMap::new(),
        next_entry: 0,
        clock: 0,
        rng: Prng::new(cfg.fault_seed),
        burst_left: 0,
        step_index: 0,
        pause_until: 0,
        step_fault_streak: 0,
    };
    let mut res = SimResult::default();
    for ev in events {
        match ev {
            SimEvent::Submit(r) => {
                let got = st.submit(*r);
                if let Some(id) = got {
                    res.events.push(TraceEvent::Enqueued { id });
                }
                res.submits.push(got);
            }
            SimEvent::Cancel(id) => {
                let got = st.cancel(*id, &mut res);
                res.cancels.push(got);
            }
            SimEvent::Step => st.step(&mut res),
        }
    }
    while !st.is_idle() {
        st.step(&mut res);
    }
    res
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::{DecodeEngine, FaultInjector, GenRequest, MockEngine, Scheduler, SpecDraft};
    use crate::testing::prop::{forall, Gen};
    use std::collections::BTreeMap;

    /// The draft source a `SimConfig` asks for (engine drafters are a
    /// dense same-shape mock — a stand-in for a lower rung of the
    /// quantization ladder).
    fn spec_draft(cfg: &SimConfig) -> SpecDraft {
        if cfg.spec_ngram {
            SpecDraft::NGram
        } else {
            SpecDraft::Engine(Box::new(MockEngine::new(cfg.slots, cfg.max_seq, 64)))
        }
    }

    fn build_scheduler(cfg: &SimConfig) -> Scheduler<MockEngine> {
        let mut engine = MockEngine::new(cfg.slots, cfg.max_seq, 64)
            .with_prefill_chunk(cfg.prefill_chunk)
            .with_kv_bits(cfg.kv_bits as f32);
        if cfg.kv_blocks > 0 {
            engine = engine.with_block_pool(cfg.kv_blocks, cfg.block_size);
        }
        let mut s = Scheduler::new(engine, cfg.max_queue).expect("scheduler");
        if cfg.prefix_cache {
            s = s.with_prefix_cache().expect("prefix cache over a paged engine");
        }
        if cfg.step_budget > 0 {
            s = s.with_step_budget(cfg.step_budget).expect("budget over a prefill engine");
        }
        if cfg.spec_k > 0 {
            s = s.with_speculation(cfg.spec_k, spec_draft(cfg)).expect("speculation config");
        }
        s
    }

    /// A paged-mode scheduler over a `FaultInjector`-wrapped engine,
    /// configured from the same `SimConfig` knobs the oracle models.
    fn build_fault_scheduler(cfg: &SimConfig) -> Scheduler<FaultInjector<MockEngine>> {
        let mut engine = MockEngine::new(cfg.slots, cfg.max_seq, 64)
            .with_prefill_chunk(cfg.prefill_chunk)
            .with_kv_bits(cfg.kv_bits as f32);
        if cfg.kv_blocks > 0 {
            engine = engine.with_block_pool(cfg.kv_blocks, cfg.block_size);
        }
        let injector =
            FaultInjector::new(engine, cfg.fault_seed, cfg.fault_rate).with_burst(cfg.fault_burst);
        let mut s = Scheduler::new(injector, cfg.max_queue).expect("scheduler");
        if cfg.prefix_cache {
            s = s.with_prefix_cache().expect("prefix cache over a paged engine");
        }
        if cfg.step_budget > 0 {
            s = s.with_step_budget(cfg.step_budget).expect("budget over a prefill engine");
        }
        if cfg.spec_k > 0 {
            s = s.with_speculation(cfg.spec_k, spec_draft(cfg)).expect("speculation config");
        }
        s.with_retry_budget(cfg.retry_budget).expect("retry budget")
    }

    /// Build the `GenRequest` a `SimEvent::Submit` maps to on the real
    /// scheduler (greedy path; deadlines carried over step-counted).
    fn real_request(r: &SimRequest) -> GenRequest {
        let req = GenRequest::greedy(&r.prompt(), r.max_new);
        if r.deadline_steps > 0 {
            req.with_deadline_steps(r.deadline_steps)
        } else {
            req
        }
    }

    /// Drive a REAL scheduler through the same trace the oracle saw,
    /// collecting the same observables — including the flight-recorder
    /// event stream, filtered to the logical (oracle-scope) events for
    /// exact sequence comparison. Generic over the engine so the chaos
    /// suites run the identical harness over a `FaultInjector`-wrapped
    /// `MockEngine`; `counts` reads the underlying mock's call counters
    /// (which only delegated — non-faulted — calls increment). The full
    /// bookkeeping audit runs after every step, so any run through this
    /// harness is also a failure-atomicity check.
    fn drive_real<E: DecodeEngine>(
        mut s: Scheduler<E>,
        events: &[SimEvent],
        counts: impl Fn(&Scheduler<E>) -> (usize, usize),
    ) -> SimResult {
        let mut res = SimResult::default();
        let record = |s: &mut Scheduler<E>, res: &mut SimResult| {
            let was_idle = s.is_idle();
            let done = s.step().expect("step");
            for c in done {
                res.completion_order.push(c.id);
                res.generated.insert(c.id, c.completion.len());
            }
            if !was_idle {
                res.occupancy.push((s.in_flight(), s.queue_depth()));
            }
            s.check_invariants().expect("bookkeeping invariants after step");
        };
        for ev in events {
            match ev {
                SimEvent::Submit(r) => {
                    res.submits.push(s.submit(real_request(r)).ok());
                }
                SimEvent::Cancel(id) => {
                    res.cancels.push(s.cancel(*id).expect("cancel"));
                }
                SimEvent::Step => record(&mut s, &mut res),
            }
        }
        while !s.is_idle() {
            record(&mut s, &mut res);
        }
        let (decode_steps, prefill_calls) = counts(&s);
        res.decode_steps = decode_steps;
        res.prefill_calls = prefill_calls;
        res.evictions = s.metrics.requests_evicted;
        res.tokens_reused = s.metrics.tokens_reused;
        res.max_decode_stall_steps = s.metrics.max_decode_stall_steps();
        res.step_faults = s.metrics.step_faults;
        res.slot_faults = s.metrics.slot_faults;
        res.retries = s.metrics.retries_scheduled;
        res.recovered = s.metrics.slots_recovered;
        res.quarantined = s.metrics.requests_quarantined;
        res.fault_evictions = s.metrics.requests_fault_evicted;
        res.shed_queued = s.metrics.deadline_shed_queued;
        res.shed_inflight = s.metrics.deadline_shed_inflight;
        assert_eq!(
            s.trace_dropped_events(),
            0,
            "equivalence traces must fit the ring buffer entirely"
        );
        res.events = s
            .trace_records()
            .into_iter()
            .map(|r| r.event)
            .filter(TraceEvent::in_oracle_scope)
            .collect();
        res
    }

    fn run_real(cfg: &SimConfig, events: &[SimEvent]) -> SimResult {
        if cfg.fault_rate > 0.0 {
            let s = build_fault_scheduler(cfg).with_trace(1 << 16);
            drive_real(s, events, |s| {
                (s.engine().inner().steps, s.engine().inner().prefill_calls)
            })
        } else {
            let s = build_scheduler(cfg).with_trace(1 << 16);
            drive_real(s, events, |s| (s.engine().steps, s.engine().prefill_calls))
        }
    }

    fn random_events(g: &mut Gen, cfg: &SimConfig) -> Vec<SimEvent> {
        let n_events = g.int(4, 40);
        let mut events = Vec::with_capacity(n_events);
        for _ in 0..n_events {
            match g.int(0, 9) {
                0..=3 => {
                    // Mostly valid prompts; occasionally an invalid one so
                    // the rejection paths are mirrored too.
                    let prompt_len = if g.int(0, 19) == 0 {
                        *g.pick(&[0usize, cfg.max_seq, cfg.max_seq + 3])
                    } else {
                        g.int(1, (cfg.max_seq - 1).min(24))
                    };
                    events.push(SimEvent::Submit(SimRequest::plain(prompt_len, g.int(0, 8))));
                }
                4..=8 => events.push(SimEvent::Step),
                _ => events.push(SimEvent::Cancel(g.int(0, 12) as u64)),
            }
        }
        events
    }

    fn random_trace(g: &mut Gen) -> (SimConfig, Vec<SimEvent>) {
        let cfg = SimConfig::dense(
            g.int(1, 4),
            g.int(4, 48),
            g.int(1, 6),
            *g.pick(&[1usize, 1, 2, 3, 4, 8, 16]),
        );
        let events = random_events(g, &cfg);
        (cfg, events)
    }

    /// Paged trace: a pool small enough that the budget gate, lazy growth
    /// and eviction paths all fire regularly.
    fn random_paged_trace(g: &mut Gen) -> (SimConfig, Vec<SimEvent>) {
        let slots = g.int(1, 4);
        let max_seq = g.int(4, 48);
        let block_size = *g.pick(&[1usize, 2, 3, 4, 8]);
        let full = slots * max_seq.div_ceil(block_size);
        let cfg = SimConfig {
            slots,
            max_seq,
            max_queue: g.int(1, 6),
            prefill_chunk: *g.pick(&[1usize, 1, 2, 4, 8]),
            // From starved (submit-time rejections, constant eviction) to
            // over-provisioned (budget never binds).
            kv_blocks: g.int(1, full.max(2)),
            block_size,
            prefix_cache: false,
            step_budget: 0,
            kv_bits: *g.pick(&[4.0, 8.0, 16.0]),
            fault_rate: 0.0,
            fault_seed: 0,
            fault_burst: 1,
            retry_budget: DEFAULT_RETRY_BUDGET,
            spec_k: 0,
            spec_ngram: true,
        };
        let events = random_events(g, &cfg);
        (cfg, events)
    }

    /// A submit drawn from a small set of prompt "groups" so shared
    /// prefixes (and therefore cache hits, donations, and LRU churn) are
    /// common rather than accidental.
    fn random_shared_submit(g: &mut Gen, cfg: &SimConfig) -> SimEvent {
        let prompt_len = g.int(1, (cfg.max_seq - 1).min(24));
        SimEvent::Submit(SimRequest {
            prompt_len,
            max_new: g.int(0, 8),
            shared_len: g.int(0, prompt_len),
            group: g.int(0, 2) as u64,
            tag: g.int(0, 40) as u64,
            deadline_steps: 0,
        })
    }

    /// Shared-prefix paged trace with the prefix cache on: submits draw
    /// from a few prompt groups, pools range from starved to roomy.
    fn random_prefix_trace(g: &mut Gen) -> (SimConfig, Vec<SimEvent>) {
        let slots = g.int(1, 4);
        let max_seq = g.int(6, 48);
        let block_size = *g.pick(&[1usize, 2, 3, 4, 8]);
        let full = slots * max_seq.div_ceil(block_size);
        let cfg = SimConfig {
            slots,
            max_seq,
            max_queue: g.int(1, 6),
            prefill_chunk: *g.pick(&[1usize, 1, 2, 4, 8]),
            kv_blocks: g.int(1, full.max(2)),
            block_size,
            prefix_cache: true,
            step_budget: 0,
            kv_bits: *g.pick(&[4.0, 8.0, 16.0]),
            fault_rate: 0.0,
            fault_seed: 0,
            fault_burst: 1,
            retry_budget: DEFAULT_RETRY_BUDGET,
            spec_k: 0,
            spec_ngram: true,
        };
        let n_events = g.int(4, 40);
        let mut events = Vec::with_capacity(n_events);
        for _ in 0..n_events {
            match g.int(0, 9) {
                0..=3 => events.push(random_shared_submit(g, &cfg)),
                4..=8 => events.push(SimEvent::Step),
                _ => events.push(SimEvent::Cancel(g.int(0, 12) as u64)),
            }
        }
        (cfg, events)
    }

    fn check_equivalence(g: &mut Gen) -> Result<(), String> {
        let (cfg, events) = random_trace(g);
        check_trace(&cfg, &events)
    }

    fn check_equivalence_paged(g: &mut Gen) -> Result<(), String> {
        let (cfg, events) = random_paged_trace(g);
        check_trace(&cfg, &events)
    }

    fn check_equivalence_prefix(g: &mut Gen) -> Result<(), String> {
        let (cfg, events) = random_prefix_trace(g);
        check_trace(&cfg, &events)
    }

    /// Composer trace: chunk > 1 (the budget needs a prefill graph),
    /// budget from far-below-chunk to far-above, dense or paged (with the
    /// prefix cache sometimes stacked on top) — cancels and backpressure
    /// included, since equivalence only needs matching ids, not matching
    /// bytes across runs.
    fn random_composer_trace(g: &mut Gen) -> (SimConfig, Vec<SimEvent>) {
        let slots = g.int(1, 4);
        let max_seq = g.int(6, 48);
        let chunk = *g.pick(&[2usize, 3, 4, 8, 16]);
        let budget = *g.pick(&[1usize, 2, 3, 4, 8, 16, 32]);
        let paged = g.bool();
        let block_size = *g.pick(&[1usize, 2, 3, 4, 8]);
        let full = slots * max_seq.div_ceil(block_size);
        let cfg = SimConfig {
            slots,
            max_seq,
            max_queue: g.int(1, 6),
            prefill_chunk: chunk,
            kv_blocks: if paged { g.int(1, full.max(2)) } else { 0 },
            block_size,
            prefix_cache: paged && g.bool(),
            step_budget: budget,
            kv_bits: *g.pick(&[4.0, 8.0, 16.0]),
            fault_rate: 0.0,
            fault_seed: 0,
            fault_burst: 1,
            retry_budget: DEFAULT_RETRY_BUDGET,
            spec_k: 0,
            spec_ngram: true,
        };
        let n_events = g.int(4, 40);
        let mut events = Vec::with_capacity(n_events);
        for _ in 0..n_events {
            match g.int(0, 9) {
                0..=3 => {
                    if cfg.prefix_cache {
                        events.push(random_shared_submit(g, &cfg));
                    } else {
                        let prompt_len = if g.int(0, 19) == 0 {
                            *g.pick(&[0usize, cfg.max_seq, cfg.max_seq + 3])
                        } else {
                            g.int(1, (cfg.max_seq - 1).min(24))
                        };
                        events.push(SimEvent::Submit(SimRequest::plain(prompt_len, g.int(0, 8))));
                    }
                }
                4..=8 => events.push(SimEvent::Step),
                _ => events.push(SimEvent::Cancel(g.int(0, 12) as u64)),
            }
        }
        (cfg, events)
    }

    fn check_equivalence_composer(g: &mut Gen) -> Result<(), String> {
        let (cfg, events) = random_composer_trace(g);
        check_trace(&cfg, &events)
    }

    /// The latency-bound + regression-anchor property (satellite): on a
    /// no-cancel, no-backpressure trace, (a) the budgeted run's worst
    /// decode stall respects ceil(chunk/B) — checked inside `check_trace`
    /// and re-checked here directly against the real scheduler — and (b)
    /// every request's *bytes* are identical with the composer on and off
    /// (the budget only reshapes the schedule; budget-off is the verbatim
    /// PR 4 path, so this anchors the composer to it).
    fn check_composer_latency_bound_and_off_anchor(g: &mut Gen) -> Result<(), String> {
        let slots = g.int(1, 4);
        let max_seq = g.int(8, 48);
        let chunk = *g.pick(&[2usize, 4, 8, 16]);
        let paged = g.bool();
        let block_size = *g.pick(&[2usize, 4, 8]);
        let full = slots * max_seq.div_ceil(block_size);
        let on_cfg = SimConfig {
            slots,
            max_seq,
            // No backpressure, no cancels: ids line up run to run.
            max_queue: 64,
            prefill_chunk: chunk,
            kv_blocks: if paged { g.int(2, full.max(3)) } else { 0 },
            block_size,
            prefix_cache: paged && g.bool(),
            step_budget: *g.pick(&[1usize, 2, 4, 8, 16]),
            kv_bits: *g.pick(&[4.0, 8.0, 16.0]),
            fault_rate: 0.0,
            fault_seed: 0,
            fault_burst: 1,
            retry_budget: DEFAULT_RETRY_BUDGET,
            spec_k: 0,
            spec_ngram: true,
        };
        let off_cfg = SimConfig { step_budget: 0, ..on_cfg };
        let n_events = g.int(4, 30);
        let mut events = Vec::with_capacity(n_events);
        for _ in 0..n_events {
            if g.int(0, 2) == 0 {
                events.push(random_shared_submit(g, &on_cfg));
            } else {
                events.push(SimEvent::Step);
            }
        }
        let real_on = run_real(&on_cfg, &events);
        let bound = chunk.div_ceil(on_cfg.step_budget);
        if real_on.max_decode_stall_steps > bound {
            return Err(format!(
                "{on_cfg:?}: stall {} > ceil(chunk/B) = {bound}",
                real_on.max_decode_stall_steps
            ));
        }
        let on = completions_by_id(&on_cfg, &events);
        let off = completions_by_id(&off_cfg, &events);
        if on.len() != off.len() {
            return Err(format!(
                "{on_cfg:?}: {} completions with composer on, {} off",
                on.len(),
                off.len()
            ));
        }
        for (id, bytes) in &on {
            if off.get(id) != Some(bytes) {
                return Err(format!(
                    "{on_cfg:?}: request {id} diverged\non:  {bytes:?}\noff: {:?}",
                    off.get(id)
                ));
            }
        }
        Ok(())
    }

    fn check_trace(cfg: &SimConfig, events: &[SimEvent]) -> Result<(), String> {
        let oracle = simulate(cfg, events);
        let real = run_real(cfg, events);
        if real.submits != oracle.submits {
            return Err(format!(
                "{cfg:?}: submit outcomes {:?} vs oracle {:?}",
                real.submits, oracle.submits
            ));
        }
        if real.cancels != oracle.cancels {
            return Err(format!(
                "{cfg:?}: cancel outcomes {:?} vs oracle {:?}",
                real.cancels, oracle.cancels
            ));
        }
        if real.completion_order != oracle.completion_order {
            return Err(format!(
                "{cfg:?}: completion order {:?} vs oracle {:?}",
                real.completion_order, oracle.completion_order
            ));
        }
        if real.generated != oracle.generated {
            return Err(format!(
                "{cfg:?}: token counts {:?} vs oracle {:?}",
                real.generated, oracle.generated
            ));
        }
        if real.occupancy != oracle.occupancy {
            return Err(format!(
                "{cfg:?}: occupancy trace {:?} vs oracle {:?}",
                real.occupancy, oracle.occupancy
            ));
        }
        if real.decode_steps != oracle.decode_steps
            || real.prefill_calls != oracle.prefill_calls
        {
            return Err(format!(
                "{cfg:?}: {} decode steps / {} prefill calls, oracle says {} / {}",
                real.decode_steps, real.prefill_calls, oracle.decode_steps, oracle.prefill_calls
            ));
        }
        if real.evictions != oracle.evictions {
            return Err(format!(
                "{cfg:?}: {} evictions vs oracle {}",
                real.evictions, oracle.evictions
            ));
        }
        if real.tokens_reused != oracle.tokens_reused {
            return Err(format!(
                "{cfg:?}: {} tokens reused vs oracle {}",
                real.tokens_reused, oracle.tokens_reused
            ));
        }
        if real.max_decode_stall_steps != oracle.max_decode_stall_steps {
            return Err(format!(
                "{cfg:?}: max decode stall {} vs oracle {}",
                real.max_decode_stall_steps, oracle.max_decode_stall_steps
            ));
        }
        // Recovery decisions are observables too: the eight fault/retry/
        // recovery/shed counters must match the modeled error kernel
        // exactly (all zero on fault-free, deadline-free traces).
        let real_fault = (
            real.step_faults,
            real.slot_faults,
            real.retries,
            real.recovered,
            real.quarantined,
            real.fault_evictions,
            real.shed_queued,
            real.shed_inflight,
        );
        let oracle_fault = (
            oracle.step_faults,
            oracle.slot_faults,
            oracle.retries,
            oracle.recovered,
            oracle.quarantined,
            oracle.fault_evictions,
            oracle.shed_queued,
            oracle.shed_inflight,
        );
        if real_fault != oracle_fault {
            return Err(format!(
                "{cfg:?}: fault counters (step, slot, retries, recovered, quarantined, \
                 evicted, shed_q, shed_f) {real_fault:?} vs oracle {oracle_fault:?}"
            ));
        }
        // Event-stream equivalence: the real scheduler's flight-recorder
        // stream (oracle-scope events only) must equal the oracle's event
        // by event — exact sequence, not just aggregate counts. Report the
        // first divergence so a failure pinpoints the decision that split.
        if real.events != oracle.events {
            let i = real
                .events
                .iter()
                .zip(&oracle.events)
                .position(|(a, b)| a != b)
                .unwrap_or(real.events.len().min(oracle.events.len()));
            return Err(format!(
                "{cfg:?}: event streams diverge at index {i} \
                 (real has {} events, oracle {}):\nreal:   {:?}\noracle: {:?}",
                real.events.len(),
                oracle.events.len(),
                real.events.get(i),
                oracle.events.get(i)
            ));
        }
        // THE composer latency guarantee, enforced on every budgeted
        // trace: no running slot ever waits more than ceil(chunk/B) steps
        // between its own tokens (decode priority actually pins it at 0).
        // Injected faults abandon whole composed steps, so the bound only
        // binds on fault-free traces.
        if cfg.step_budget > 0 && cfg.fault_rate == 0.0 {
            let bound = cfg.prefill_chunk.div_ceil(cfg.step_budget);
            if real.max_decode_stall_steps > bound {
                return Err(format!(
                    "{cfg:?}: decode stall {} breaks the ceil(chunk/B) = {bound} bound",
                    real.max_decode_stall_steps
                ));
            }
        }
        Ok(())
    }

    /// Paged scheduler with a *full-size* pool vs the dense scheduler on
    /// the same trace: the token budget never binds, so every observable —
    /// submits, completion order, token counts, occupancy, step counts —
    /// must match the dense path exactly (and no eviction may fire).
    fn check_paged_vs_dense_full_pool(g: &mut Gen) -> Result<(), String> {
        let (dense_cfg, events) = random_trace(g);
        let block_size = *g.pick(&[1usize, 2, 4, 8]);
        let paged_cfg = SimConfig {
            kv_blocks: dense_cfg.slots * dense_cfg.max_seq.div_ceil(block_size),
            block_size,
            ..dense_cfg
        };
        let dense = run_real(&dense_cfg, &events);
        let paged = run_real(&paged_cfg, &events);
        if paged.evictions != 0 {
            return Err(format!("{paged_cfg:?}: full pool evicted {}", paged.evictions));
        }
        if paged.submits != dense.submits
            || paged.completion_order != dense.completion_order
            || paged.generated != dense.generated
            || paged.occupancy != dense.occupancy
            || paged.decode_steps != dense.decode_steps
            || paged.prefill_calls != dense.prefill_calls
        {
            return Err(format!(
                "{paged_cfg:?}: paged(full pool) diverged from dense\n\
                 paged: {paged:?}\ndense: {dense:?}"
            ));
        }
        Ok(())
    }

    /// THE prefix-cache acceptance property (oracle-enforced in CI): on a
    /// shared-prefix trace with no cancels and no backpressure (so request
    /// ids line up run to run), every completed request's *bytes* are
    /// identical with the cache on and off — the cache only removes
    /// recomputation — while the cache-on run actually reuses tokens on
    /// traces with real sharing. Extended for the composer: the same
    /// identity must hold under a step budget (chunk > 1 then, since the
    /// budget needs a prefill graph).
    fn check_prefix_on_off_bit_identical(g: &mut Gen) -> Result<(), String> {
        let slots = g.int(1, 4);
        let max_seq = g.int(8, 48);
        let block_size = *g.pick(&[2usize, 4, 8]);
        let full = slots * max_seq.div_ceil(block_size);
        let step_budget = *g.pick(&[0usize, 0, 2, 4, 8]);
        let chunk = if step_budget > 0 {
            *g.pick(&[2usize, 4, 8])
        } else {
            *g.pick(&[1usize, 2, 4, 8])
        };
        let on_cfg = SimConfig {
            slots,
            max_seq,
            // No backpressure: every submit is accepted (or rejected for
            // size in both runs identically).
            max_queue: 64,
            prefill_chunk: chunk,
            kv_blocks: g.int(2, full.max(3)),
            block_size,
            prefix_cache: true,
            step_budget,
            kv_bits: *g.pick(&[4.0, 8.0, 16.0]),
            fault_rate: 0.0,
            fault_seed: 0,
            fault_burst: 1,
            retry_budget: DEFAULT_RETRY_BUDGET,
            spec_k: 0,
            spec_ngram: true,
        };
        let off_cfg = SimConfig { prefix_cache: false, ..on_cfg };
        let n_events = g.int(4, 30);
        let mut events = Vec::with_capacity(n_events);
        for _ in 0..n_events {
            if g.int(0, 2) == 0 {
                events.push(random_shared_submit(g, &on_cfg));
            } else {
                events.push(SimEvent::Step);
            }
        }
        let on = completions_by_id(&on_cfg, &events);
        let off = completions_by_id(&off_cfg, &events);
        if on.len() != off.len() {
            return Err(format!(
                "{on_cfg:?}: {} completions with cache on, {} off",
                on.len(),
                off.len()
            ));
        }
        for (id, bytes) in &on {
            if off.get(id) != Some(bytes) {
                return Err(format!(
                    "{on_cfg:?}: request {id} diverged\non:  {bytes:?}\noff: {:?}",
                    off.get(id)
                ));
            }
        }
        Ok(())
    }

    /// Run the real scheduler, collecting completion *bytes* per id.
    fn completions_by_id(cfg: &SimConfig, events: &[SimEvent]) -> BTreeMap<u64, Vec<u8>> {
        let mut s = build_scheduler(cfg);
        let mut out = BTreeMap::new();
        let collect = |done: Vec<crate::serve::Completion>, out: &mut BTreeMap<u64, Vec<u8>>| {
            for c in done {
                out.insert(c.id, c.completion);
            }
        };
        for ev in events {
            match ev {
                SimEvent::Submit(r) => {
                    // Seeded sampling keyed off the tag: restarts and
                    // cross-run comparisons stay deterministic.
                    let req = GenRequest::sampled(
                        &r.prompt(),
                        r.max_new,
                        crate::serve::Sampler::top_k(8, 0.9),
                        r.tag,
                    );
                    let _ = s.submit(req);
                }
                SimEvent::Cancel(id) => {
                    let _ = s.cancel(*id);
                }
                SimEvent::Step => collect(s.step().expect("step"), &mut out),
            }
        }
        while !s.is_idle() {
            collect(s.step().expect("step"), &mut out);
        }
        out
    }

    /// Chaos trace: a paged / prefix / composer shape with the seeded
    /// injector armed at `rate` and roughly a quarter of submits carrying
    /// a step-counted deadline — so fault recovery, deadline shedding,
    /// eviction and the prefix cache all interleave on one trace.
    fn random_fault_trace(g: &mut Gen, rate: f64) -> (SimConfig, Vec<SimEvent>) {
        let (mut cfg, mut events) = match g.int(0, 2) {
            0 => random_paged_trace(g),
            1 => random_prefix_trace(g),
            _ => random_composer_trace(g),
        };
        cfg.fault_rate = rate;
        cfg.fault_seed = g.int(0, 1 << 30) as u64;
        cfg.fault_burst = *g.pick(&[1usize, 1, 2, 3]);
        cfg.retry_budget = *g.pick(&[1usize, 2, 3, 4]);
        for ev in events.iter_mut() {
            if let SimEvent::Submit(r) = ev {
                if g.int(0, 3) == 0 {
                    r.deadline_steps = g.int(1, 30) as u64;
                }
            }
        }
        (cfg, events)
    }

    fn check_fault_equivalence(g: &mut Gen, rate: f64) -> Result<(), String> {
        let (cfg, events) = random_fault_trace(g, rate);
        check_trace(&cfg, &events)
    }

    /// Drive a real scheduler to drain, collecting `(bytes, reason)` per
    /// terminated request, failing on a double termination and auditing
    /// the full bookkeeping invariants after every step.
    fn collect_fault_run<E: DecodeEngine>(
        mut s: Scheduler<E>,
        events: &[SimEvent],
    ) -> Result<BTreeMap<u64, (Vec<u8>, FinishReason)>, String> {
        let mut out = BTreeMap::new();
        let drain = |s: &mut Scheduler<E>,
                     out: &mut BTreeMap<u64, (Vec<u8>, FinishReason)>|
         -> Result<(), String> {
            for c in s.step().map_err(|e| format!("step failed: {e}"))? {
                if out.insert(c.id, (c.completion, c.reason)).is_some() {
                    return Err(format!("request {} terminated twice", c.id));
                }
            }
            s.check_invariants().map_err(|e| format!("invariants broke: {e}"))
        };
        for ev in events {
            match ev {
                SimEvent::Submit(r) => {
                    // Seeded sampling keyed off the tag: a warm restart
                    // after a fault eviction must regenerate the same
                    // bytes or the identity check below catches it.
                    let req = GenRequest::sampled(
                        &r.prompt(),
                        r.max_new,
                        crate::serve::Sampler::top_k(8, 0.9),
                        r.tag,
                    );
                    let _ = s.submit(req);
                }
                SimEvent::Cancel(id) => {
                    let _ = s.cancel(*id);
                }
                SimEvent::Step => drain(&mut s, &mut out)?,
            }
        }
        while !s.is_idle() {
            drain(&mut s, &mut out)?;
        }
        Ok(out)
    }

    fn fault_completions_by_id(
        cfg: &SimConfig,
        events: &[SimEvent],
    ) -> Result<BTreeMap<u64, (Vec<u8>, FinishReason)>, String> {
        if cfg.fault_rate > 0.0 {
            collect_fault_run(build_fault_scheduler(cfg), events)
        } else {
            collect_fault_run(build_scheduler(cfg), events)
        }
    }

    /// THE fault-recovery acceptance property (oracle-independent, real
    /// scheduler only): on a no-cancel, no-backpressure, no-deadline
    /// trace (so ids line up run to run), (a) the bookkeeping invariants
    /// hold after every step of the faulty run, (b) no request terminates
    /// twice and none is lost, and (c) every request that *survives* the
    /// faults — finishes with a success reason — produces bytes identical
    /// to the fault-free run: recovery replays, it never corrupts.
    fn check_fault_survivors_bit_identical(g: &mut Gen) -> Result<(), String> {
        let rate = *g.pick(&[0.01f64, 0.05]);
        let slots = g.int(1, 4);
        let max_seq = g.int(8, 48);
        let paged = g.bool();
        let block_size = *g.pick(&[2usize, 4, 8]);
        let full = slots * max_seq.div_ceil(block_size);
        let step_budget = *g.pick(&[0usize, 0, 2, 4]);
        let chunk = if step_budget > 0 {
            *g.pick(&[2usize, 4, 8])
        } else {
            *g.pick(&[1usize, 2, 4, 8])
        };
        let faulty = SimConfig {
            slots,
            max_seq,
            // No backpressure: every submit is accepted in both runs.
            max_queue: 64,
            prefill_chunk: chunk,
            kv_blocks: if paged { g.int(2, full.max(3)) } else { 0 },
            block_size,
            prefix_cache: paged && g.bool(),
            step_budget,
            kv_bits: 16.0,
            fault_rate: rate,
            fault_seed: g.int(0, 1 << 30) as u64,
            fault_burst: *g.pick(&[1usize, 2, 3]),
            retry_budget: *g.pick(&[1usize, 2, 3, 4]),
            spec_k: 0,
            spec_ngram: true,
        };
        let clean = SimConfig { fault_rate: 0.0, ..faulty };
        let n_events = g.int(4, 30);
        let mut events = Vec::with_capacity(n_events);
        for _ in 0..n_events {
            if g.int(0, 2) == 0 {
                if faulty.prefix_cache {
                    events.push(random_shared_submit(g, &faulty));
                } else {
                    events.push(SimEvent::Submit(SimRequest::plain(
                        g.int(1, (max_seq - 1).min(24)),
                        g.int(0, 8),
                    )));
                }
            } else {
                events.push(SimEvent::Step);
            }
        }
        let faulty_out = fault_completions_by_id(&faulty, &events)?;
        let clean_out = fault_completions_by_id(&clean, &events)?;
        if faulty_out.len() != clean_out.len() {
            return Err(format!(
                "{faulty:?}: {} terminations under faults vs {} clean — a request was lost",
                faulty_out.len(),
                clean_out.len()
            ));
        }
        for (id, (bytes, reason)) in &faulty_out {
            if matches!(reason, FinishReason::Quarantined | FinishReason::DeadlineExpired) {
                // Shed by the kernel; its partial output may differ.
                continue;
            }
            match clean_out.get(id) {
                Some((clean_bytes, _)) if clean_bytes == bytes => {}
                other => {
                    return Err(format!(
                        "{faulty:?}: surviving request {id} diverged\n\
                         faulty: {bytes:?}\nclean:  {other:?}"
                    ));
                }
            }
        }
        Ok(())
    }

    /// THE speculative-decoding acceptance property (real scheduler only —
    /// the oracle models no logits, so it cannot model acceptance): on a
    /// no-cancel, no-backpressure trace, every request's *bytes* are
    /// identical with speculation on (any K, either draft source) and off.
    /// Shapes sweep dense, paged (pool-starved, so speculation interleaves
    /// with eviction) and prefix-cached pools, chunked prefill, and the
    /// step composer — speculation reshapes engine calls, never content.
    fn check_spec_on_off_bit_identical(g: &mut Gen) -> Result<(), String> {
        let slots = g.int(1, 4);
        let max_seq = g.int(8, 48);
        let paged = g.bool();
        let block_size = *g.pick(&[2usize, 4, 8]);
        let full = slots * max_seq.div_ceil(block_size);
        let step_budget = *g.pick(&[0usize, 0, 0, 4]);
        let chunk = if step_budget > 0 {
            *g.pick(&[2usize, 4, 8])
        } else {
            *g.pick(&[1usize, 1, 2, 4, 8])
        };
        let on_cfg = SimConfig {
            slots,
            max_seq,
            // No backpressure, no cancels: ids line up run to run.
            max_queue: 64,
            prefill_chunk: chunk,
            kv_blocks: if paged { g.int(2, full.max(3)) } else { 0 },
            block_size,
            prefix_cache: paged && g.bool(),
            step_budget,
            kv_bits: *g.pick(&[4.0, 8.0, 16.0]),
            fault_rate: 0.0,
            fault_seed: 0,
            fault_burst: 1,
            retry_budget: DEFAULT_RETRY_BUDGET,
            spec_k: *g.pick(&[1usize, 2, 4, 8]),
            spec_ngram: g.bool(),
        };
        let off_cfg = SimConfig { spec_k: 0, ..on_cfg };
        let n_events = g.int(4, 30);
        let mut events = Vec::with_capacity(n_events);
        for _ in 0..n_events {
            if g.int(0, 2) == 0 {
                if on_cfg.prefix_cache {
                    events.push(random_shared_submit(g, &on_cfg));
                } else {
                    events.push(SimEvent::Submit(SimRequest::plain(
                        g.int(1, (max_seq - 1).min(24)),
                        g.int(0, 8),
                    )));
                }
            } else {
                events.push(SimEvent::Step);
            }
        }
        let on = completions_by_id(&on_cfg, &events);
        let off = completions_by_id(&off_cfg, &events);
        if on.len() != off.len() {
            return Err(format!(
                "{on_cfg:?}: {} completions with speculation on, {} off",
                on.len(),
                off.len()
            ));
        }
        for (id, bytes) in &on {
            if off.get(id) != Some(bytes) {
                return Err(format!(
                    "{on_cfg:?}: request {id} diverged\nspec on:  {bytes:?}\nspec off: {:?}",
                    off.get(id)
                ));
            }
        }
        Ok(())
    }

    /// Speculation x fault injection: the error kernel must absorb faults
    /// raised by batched *verify* calls exactly as it absorbs decode
    /// faults — a failed window restore-rewinds and retries, so every
    /// surviving request's bytes match the fault-free, speculation-free
    /// run, under the full invariant audit after every step.
    fn check_spec_fault_survivors_bit_identical(g: &mut Gen) -> Result<(), String> {
        let slots = g.int(1, 4);
        let max_seq = g.int(8, 48);
        let paged = g.bool();
        let block_size = *g.pick(&[2usize, 4, 8]);
        let full = slots * max_seq.div_ceil(block_size);
        let faulty = SimConfig {
            slots,
            max_seq,
            max_queue: 64,
            prefill_chunk: *g.pick(&[1usize, 1, 2, 4]),
            kv_blocks: if paged { g.int(2, full.max(3)) } else { 0 },
            block_size,
            prefix_cache: paged && g.bool(),
            step_budget: 0,
            kv_bits: 16.0,
            fault_rate: *g.pick(&[0.01f64, 0.05]),
            fault_seed: g.int(0, 1 << 30) as u64,
            fault_burst: *g.pick(&[1usize, 2, 3]),
            retry_budget: *g.pick(&[2usize, 3, 4]),
            spec_k: *g.pick(&[1usize, 2, 4]),
            spec_ngram: g.bool(),
        };
        let clean = SimConfig { fault_rate: 0.0, spec_k: 0, ..faulty };
        let n_events = g.int(4, 30);
        let mut events = Vec::with_capacity(n_events);
        for _ in 0..n_events {
            if g.int(0, 2) == 0 {
                events.push(SimEvent::Submit(SimRequest::plain(
                    g.int(1, (max_seq - 1).min(24)),
                    g.int(0, 8),
                )));
            } else {
                events.push(SimEvent::Step);
            }
        }
        let faulty_out = fault_completions_by_id(&faulty, &events)?;
        let clean_out = fault_completions_by_id(&clean, &events)?;
        if faulty_out.len() != clean_out.len() {
            return Err(format!(
                "{faulty:?}: {} terminations under faults vs {} clean — a request was lost",
                faulty_out.len(),
                clean_out.len()
            ));
        }
        for (id, (bytes, reason)) in &faulty_out {
            if matches!(reason, FinishReason::Quarantined | FinishReason::DeadlineExpired) {
                continue;
            }
            match clean_out.get(id) {
                Some((clean_bytes, _)) if clean_bytes == bytes => {}
                other => {
                    return Err(format!(
                        "{faulty:?}: surviving request {id} diverged\n\
                         faulty+spec: {bytes:?}\nclean:       {other:?}"
                    ));
                }
            }
        }
        Ok(())
    }

    /// Independent reimplementation of the prompt-lookup rule the
    /// scheduler's `ngram_draft` documents (longest n in 3..=1 with a
    /// recurrence, most recent occurrence wins, continuation capped by k
    /// and by the end of history) — written against the contract, not the
    /// code, so the two stay honest about the rule.
    fn mirror_ngram(toks: &[i32], k: usize) -> Vec<i32> {
        if k == 0 {
            return Vec::new();
        }
        for n in (1..=3).rev() {
            if toks.len() < n + 1 {
                continue;
            }
            let suffix = &toks[toks.len() - n..];
            let mut latest = None;
            for i in 0..toks.len() - n {
                if &toks[i..i + n] == suffix {
                    latest = Some(i);
                }
            }
            if let Some(i) = latest {
                let start = i + n;
                return toks[start..(start + k).min(toks.len())].to_vec();
            }
        }
        Vec::new()
    }

    // Three pinned seeds x 120 traces per suite in CI; any failure prints
    // (seed, case, case_seed) for exact reproduction.

    #[test]
    fn sim_trace_equivalence_seed_a() {
        forall(101, 120, check_equivalence);
    }

    #[test]
    fn sim_trace_equivalence_seed_b() {
        forall(202, 120, check_equivalence);
    }

    #[test]
    fn sim_trace_equivalence_seed_c() {
        forall(303, 120, check_equivalence);
    }

    // Paged traces: three more pinned seeds x 120 = 360 randomized cases
    // over the block-budget admission / lazy-growth / eviction bookkeeping.

    #[test]
    fn sim_trace_equivalence_paged_seed_a() {
        forall(404, 120, check_equivalence_paged);
    }

    #[test]
    fn sim_trace_equivalence_paged_seed_b() {
        forall(505, 120, check_equivalence_paged);
    }

    #[test]
    fn sim_trace_equivalence_paged_seed_c() {
        forall(606, 120, check_equivalence_paged);
    }

    /// Paged-with-full-pool must be observationally identical to dense.
    #[test]
    fn sim_trace_equivalence_paged_vs_dense() {
        forall(707, 120, check_paged_vs_dense_full_pool);
    }

    // Shared-prefix traces with the prefix cache on: three pinned seeds x
    // 120 cases over lookup/donation/LRU/refcount bookkeeping, plus the
    // cache-on-vs-off byte-identity suite.

    #[test]
    fn sim_trace_equivalence_prefix_seed_a() {
        forall(808, 120, check_equivalence_prefix);
    }

    #[test]
    fn sim_trace_equivalence_prefix_seed_b() {
        forall(909, 120, check_equivalence_prefix);
    }

    #[test]
    fn sim_trace_equivalence_prefix_seed_c() {
        forall(1010, 120, check_equivalence_prefix);
    }

    #[test]
    fn sim_trace_equivalence_prefix_on_off_bit_identical() {
        forall(1111, 120, check_prefix_on_off_bit_identical);
    }

    // Step-composer traces (--step-budget): three pinned seeds x 120 = 360
    // randomized cases over the phase partition, budgeted takes, guard,
    // mixed-step call accounting, and the max_decode_stall_steps
    // observable (bounded by ceil(chunk/B) inside check_trace) — dense,
    // paged, and prefix-cached configurations mixed.

    #[test]
    fn sim_trace_equivalence_composer_seed_a() {
        forall(1212, 120, check_equivalence_composer);
    }

    #[test]
    fn sim_trace_equivalence_composer_seed_b() {
        forall(1313, 120, check_equivalence_composer);
    }

    #[test]
    fn sim_trace_equivalence_composer_seed_c() {
        forall(1414, 120, check_equivalence_composer);
    }

    /// Latency-bound property + budget-off regression anchor (satellite).
    #[test]
    fn sim_trace_equivalence_composer_latency_bound_and_off_anchor() {
        forall(1616, 120, check_composer_latency_bound_and_off_anchor);
    }

    // Chaos traces: the seeded injector armed over mixed paged / prefix /
    // composer shapes (deadlines included) at every fault rate the
    // acceptance criteria name — the oracle must reproduce the error
    // kernel's recovery decisions event by event and counter by counter.

    #[test]
    fn sim_trace_equivalence_fault_rate_zero() {
        forall(1717, 80, |g| check_fault_equivalence(g, 0.0));
    }

    #[test]
    fn sim_trace_equivalence_fault_rate_1pct() {
        forall(1818, 80, |g| check_fault_equivalence(g, 0.01));
    }

    #[test]
    fn sim_trace_equivalence_fault_rate_5pct() {
        forall(1919, 80, |g| check_fault_equivalence(g, 0.05));
    }

    /// Fault-recovery byte identity + per-step invariant audit (satellite).
    #[test]
    fn sim_fault_survivors_bit_identical() {
        forall(2020, 120, check_fault_survivors_bit_identical);
    }

    // Speculative decoding: the real-only byte-identity suites (the oracle
    // models no logits, so acceptance is out of its scope by construction).
    // Two pinned seeds x 120 spec-on-vs-off traces over dense / paged /
    // prefix / composer shapes, plus 120 chaos traces where speculation,
    // eviction, the prefix cache and the fault injector all interleave.

    #[test]
    fn sim_spec_on_off_bit_identical_seed_a() {
        forall(2121, 120, check_spec_on_off_bit_identical);
    }

    #[test]
    fn sim_spec_on_off_bit_identical_seed_b() {
        forall(2222, 120, check_spec_on_off_bit_identical);
    }

    #[test]
    fn sim_spec_fault_survivors_bit_identical() {
        forall(2323, 120, check_spec_fault_survivors_bit_identical);
    }

    /// The drafting rule itself, cross-checked against an independent
    /// mirror on random token streams (small vocabularies make recurrences
    /// common, so the longest-n and most-recent tie-breaks really fire).
    #[test]
    fn sim_ngram_mirror_agrees_with_scheduler() {
        forall(2424, 400, |g| {
            let vocab = *g.pick(&[2usize, 3, 8, 64]);
            let len = g.int(0, 40);
            let toks: Vec<i32> = (0..len).map(|_| g.int(0, vocab - 1) as i32).collect();
            let k = g.int(0, 6);
            let real = crate::serve::scheduler::ngram_draft(&toks, k);
            let mine = mirror_ngram(&toks, k);
            if real == mine {
                Ok(())
            } else {
                Err(format!(
                    "ngram_draft({toks:?}, {k}) = {real:?}, mirror says {mine:?}"
                ))
            }
        });
    }

    /// Extra exploration knob: SPINQUANT_SIM_SEED=1234 cargo test — runs
    /// another 120 dense + 120 paged + 120 prefix traces from an arbitrary
    /// seed without a rebuild.
    #[test]
    fn sim_trace_equivalence_env_seed() {
        if let Ok(seed) = std::env::var("SPINQUANT_SIM_SEED") {
            let seed: u64 = seed.parse().expect("SPINQUANT_SIM_SEED must be u64");
            forall(seed, 120, check_equivalence);
            forall(seed ^ 0x9a9a, 120, check_equivalence_paged);
            forall(seed ^ 0x7e1f, 120, check_equivalence_prefix);
            forall(seed ^ 0x51e9, 120, check_equivalence_composer);
            forall(seed ^ 0xfa17, 120, |g| check_fault_equivalence(g, 0.05));
        }
    }

    #[test]
    fn oracle_smoke_single_request() {
        // Hand-checkable trace: one request, prompt 5, budget 2, chunk 4.
        let cfg = SimConfig::dense(1, 32, 4, 4);
        let events =
            [SimEvent::Submit(SimRequest::plain(5, 2)), SimEvent::Step];
        let res = simulate(&cfg, &events);
        // Call 1 feeds 4 prompt tokens; drain: call 2 feeds 1 + samples
        // token 1; one decode step samples token 2 and retires.
        assert_eq!(res.prefill_calls, 2);
        assert_eq!(res.decode_steps, 1);
        assert_eq!(res.completion_order, vec![0]);
        assert_eq!(res.generated.get(&0), Some(&2));
        assert_eq!(res.occupancy, vec![(1, 0), (1, 0), (0, 0)]);
    }

    #[test]
    fn oracle_smoke_deadline_shed() {
        // 1 slot, chunk 4: request 0 (prompt 6) is admitted and prefilled
        // at step 1; request 1 waits behind it. Both carry a 2-step
        // deadline, so at the top of step 2 request 1 is shed from the
        // queue and request 0 mid-flight — before any further engine work.
        let cfg = SimConfig::dense(1, 32, 4, 4);
        let events = [
            SimEvent::Submit(SimRequest { deadline_steps: 2, ..SimRequest::plain(6, 4) }),
            SimEvent::Submit(SimRequest { deadline_steps: 2, tag: 1, ..SimRequest::plain(4, 2) }),
        ];
        let res = simulate(&cfg, &events);
        assert_eq!(res.submits, vec![Some(0), Some(1)]);
        assert_eq!(res.shed_queued, 1);
        assert_eq!(res.shed_inflight, 1);
        // Queue scan first, then in-flight slots in ascending order.
        assert_eq!(res.completion_order, vec![1, 0]);
        assert_eq!(res.generated.get(&0), Some(&0));
        assert_eq!(res.generated.get(&1), Some(&0));
        assert_eq!(res.prefill_calls, 1);
        assert_eq!(res.decode_steps, 0);
        // The real scheduler agrees on the whole trace.
        check_trace(&cfg, &events).unwrap();
    }

    #[test]
    fn oracle_smoke_paged_eviction() {
        // Hand-checkable paged trace: 2 slots, 4 pages of 4 tokens.
        // Two (prompt 4, budget 8) requests each need 3 pages end to end;
        // the watermark admits both, growth exhausts the pool at pos 8,
        // request 1 is evicted, request 0 completes, request 1 restarts
        // and completes — both with their full 8 tokens.
        let cfg = SimConfig {
            slots: 2,
            max_seq: 32,
            max_queue: 4,
            prefill_chunk: 1,
            kv_blocks: 4,
            block_size: 4,
            prefix_cache: false,
            step_budget: 0,
            kv_bits: 4.0,
            fault_rate: 0.0,
            fault_seed: 0,
            fault_burst: 1,
            retry_budget: DEFAULT_RETRY_BUDGET,
            spec_k: 0,
            spec_ngram: true,
        };
        let events = [
            SimEvent::Submit(SimRequest::plain(4, 8)),
            SimEvent::Submit(SimRequest::plain(4, 8)),
        ];
        let res = simulate(&cfg, &events);
        assert_eq!(res.submits, vec![Some(0), Some(1)]);
        assert_eq!(res.evictions, 1);
        assert_eq!(res.completion_order, vec![0, 1]);
        assert_eq!(res.generated.get(&0), Some(&8));
        assert_eq!(res.generated.get(&1), Some(&8));
        // The real scheduler agrees on the whole trace.
        check_trace(&cfg, &events).unwrap();
    }

    #[test]
    fn oracle_smoke_paged_budget_gate() {
        // 1 slot free but only 2 free pages: a request needing 3 pages
        // waits in the queue even though a slot is open.
        let cfg = SimConfig {
            slots: 2,
            max_seq: 32,
            max_queue: 4,
            prefill_chunk: 1,
            kv_blocks: 3,
            block_size: 4,
            prefix_cache: false,
            step_budget: 0,
            kv_bits: 8.0,
            fault_rate: 0.0,
            fault_seed: 0,
            fault_burst: 1,
            retry_budget: DEFAULT_RETRY_BUDGET,
            spec_k: 0,
            spec_ngram: true,
        };
        let events = [
            SimEvent::Submit(SimRequest::plain(2, 1)), // 1 page
            SimEvent::Submit(SimRequest::plain(8, 4)), // 3 pages
            SimEvent::Step,
        ];
        let res = simulate(&cfg, &events);
        assert_eq!(res.submits, vec![Some(0), Some(1)]);
        // After the first step: request 0 in flight, request 1 still queued
        // (2 free pages < 3 needed).
        assert_eq!(res.occupancy.first(), Some(&(1, 1)));
        assert_eq!(res.completion_order, vec![0, 1]);
        check_trace(&cfg, &events).unwrap();
    }

    #[test]
    fn oracle_smoke_composed_step() {
        // Hand-checkable composer trace: 2 slots, chunk 8, budget 4.
        // A (prompt 6, budget 2) and B (prompt 3, budget 2) submitted
        // together; the drain composes:
        //   step 1: prefill A[0..4]                 (budget 4, B starved)
        //   step 2: prefill A[4..6] + B[0..2]       (A's first token)
        //   step 3: decode A (retires) + prefill B[2..3] (B's first token)
        //   step 4: decode B (retires)
        let mut cfg = SimConfig::dense(2, 64, 4, 8);
        cfg.step_budget = 4;
        let events = [
            SimEvent::Submit(SimRequest::plain(6, 2)),
            SimEvent::Submit(SimRequest::plain(3, 2)),
        ];
        let res = simulate(&cfg, &events);
        assert_eq!(res.submits, vec![Some(0), Some(1)]);
        assert_eq!(res.prefill_calls, 3);
        assert_eq!(res.decode_steps, 2);
        assert_eq!(res.completion_order, vec![0, 1]);
        assert_eq!(res.generated.get(&0), Some(&2));
        assert_eq!(res.generated.get(&1), Some(&2));
        assert_eq!(res.occupancy, vec![(2, 0), (2, 0), (1, 0), (0, 0)]);
        assert_eq!(res.max_decode_stall_steps, 0, "decode priority leaves no stall");
        // The real scheduler agrees on the whole composed trace.
        check_trace(&cfg, &events).unwrap();
    }

    #[test]
    fn oracle_smoke_budget_off_stall_is_visible() {
        // The observable the composer exists to remove: budget off, a
        // 20-token prompt (chunk 8 -> 3 prefill calls) joins a running
        // decode, which therefore waits 3 engine calls between tokens.
        let cfg = SimConfig::dense(2, 64, 4, 8);
        let events = [
            SimEvent::Submit(SimRequest::plain(2, 6)),
            SimEvent::Step, // prefill "A", first token
            SimEvent::Step, // decode
            SimEvent::Submit(SimRequest::plain(20, 1)),
        ];
        let res = simulate(&cfg, &events);
        assert_eq!(res.max_decode_stall_steps, 3, "ceil(20/8) = 3 stalled calls");
        check_trace(&cfg, &events).unwrap();
        // Same trace, composed under budget 4: the stall disappears.
        let mut on = cfg;
        on.step_budget = 4;
        let res = simulate(&on, &events);
        assert_eq!(res.max_decode_stall_steps, 0);
        check_trace(&on, &events).unwrap();
    }

    #[test]
    fn oracle_smoke_prefix_reuse() {
        // Hand-checkable prefix trace: pool of 6 pages x 4 tokens. Request
        // 0 (prompt 9 = 2 full shared pages + 1 token, budget 3) donates
        // pages 0 and 1 as they fill; request 1 (same group) then maps
        // both, pays only its third page, and skips 8 prompt tokens.
        let cfg = SimConfig {
            slots: 1,
            max_seq: 32,
            max_queue: 4,
            prefill_chunk: 4,
            kv_blocks: 6,
            block_size: 4,
            prefix_cache: true,
            step_budget: 0,
            kv_bits: 4.0,
            fault_rate: 0.0,
            fault_seed: 0,
            fault_burst: 1,
            retry_budget: DEFAULT_RETRY_BUDGET,
            spec_k: 0,
            spec_ngram: true,
        };
        let shared = SimRequest {
            prompt_len: 9,
            max_new: 3,
            shared_len: 9,
            group: 7,
            tag: 0,
            deadline_steps: 0,
        };
        let events = [
            SimEvent::Submit(shared),
            SimEvent::Submit(SimRequest { tag: 1, ..shared }),
        ];
        let res = simulate(&cfg, &events);
        assert_eq!(res.submits, vec![Some(0), Some(1)]);
        assert_eq!(res.completion_order, vec![0, 1]);
        // Request 0: ceil(9/4) = 3 prefill calls. Request 1: 8 of its 9
        // prompt tokens are cached, so ceil(1/4) = 1 call.
        assert_eq!(res.prefill_calls, 4);
        assert_eq!(res.tokens_reused, 8);
        assert_eq!(res.generated.get(&1), Some(&3));
        // The real scheduler agrees on the whole trace — including the
        // reuse accounting.
        check_trace(&cfg, &events).unwrap();
    }
}
