//! Seeded reference simulator ("oracle") for the continuous-batching
//! scheduler.
//!
//! [`simulate`] replays a trace of submit/step/cancel events against a
//! *pure bookkeeping* model of the scheduler: FIFO admission into the
//! lowest free slot, bounded queue with backpressure, batched multi-token
//! prefill (`ceil(len/chunk)` calls) or the chunk-1 interleaved path,
//! per-request generation budgets, cache-capacity truncation, and
//! mid-flight eviction. With `kv_blocks > 0` it also models the *paged*
//! KV path: free-page token-budget admission (a watermark, head-of-queue
//! only), one page claimed at admission, lazy growth at page boundaries in
//! slot order, and youngest-first evict-to-queue-front on pool exhaustion
//! — page *counts* only, since the oracle needs no physical identities. No
//! engine, no logits, no clocks — just the admission/join/evict/budget
//! arithmetic the real [`crate::serve::Scheduler`] must implement.
//!
//! The randomized trace tests at the bottom generate hundreds of seeded
//! traces, run each against both the oracle and the real scheduler over
//! [`crate::serve::MockEngine`], and require them to agree on accepted
//! ids, completion order, per-request token counts, per-step slot
//! occupancy and queue depth, and the exact number of decode steps and
//! prefill calls. Failures print the seed/case (via [`super::prop::forall`])
//! so any divergence is reproducible. CI pins three seeds (see
//! `.github/workflows/ci.yml`) so trace-equivalence regressions fail the
//! build.

use std::collections::{BTreeMap, VecDeque};

/// One generation request, reduced to what the bookkeeping depends on.
#[derive(Clone, Copy, Debug)]
pub struct SimRequest {
    pub prompt_len: usize,
    pub max_new: usize,
}

/// Scheduler shape under simulation.
#[derive(Clone, Copy, Debug)]
pub struct SimConfig {
    pub slots: usize,
    pub max_seq: usize,
    pub max_queue: usize,
    /// Engine prefill chunk; 1 = the interleaved token-by-token path.
    pub prefill_chunk: usize,
    /// Paged KV pool size in pages; 0 = the dense path.
    pub kv_blocks: usize,
    /// Tokens per page (ignored when `kv_blocks == 0`).
    pub block_size: usize,
}

impl SimConfig {
    /// Dense configuration (no paging).
    pub fn dense(slots: usize, max_seq: usize, max_queue: usize, prefill_chunk: usize) -> Self {
        Self { slots, max_seq, max_queue, prefill_chunk, kv_blocks: 0, block_size: 1 }
    }
}

/// Trace events, mirroring the public scheduler API.
#[derive(Clone, Debug)]
pub enum SimEvent {
    Submit(SimRequest),
    Step,
    Cancel(u64),
}

/// Everything the oracle predicts for one trace (the trailing drain to
/// idle is included).
#[derive(Clone, Debug, Default)]
pub struct SimResult {
    /// Outcome per `Submit` event: `Some(id)` or `None` (rejected — queue
    /// full or invalid prompt; rejected submits consume no id).
    pub submits: Vec<Option<u64>>,
    /// Outcome per `Cancel` event (`true` = found and removed).
    pub cancels: Vec<bool>,
    /// Request ids in completion order.
    pub completion_order: Vec<u64>,
    /// Generated-token count per completed id (truncation included).
    pub generated: BTreeMap<u64, usize>,
    /// (occupied slots, queue depth) after every non-idle step.
    pub occupancy: Vec<(usize, usize)>,
    pub decode_steps: usize,
    pub prefill_calls: usize,
    /// Paged only: pool-exhaustion evictions back to the queue.
    pub evictions: usize,
}

#[derive(Clone, Copy, Debug)]
struct SimSlot {
    id: u64,
    prompt_len: usize,
    max_new: usize,
    fed: usize,
    gen: usize,
    pos: usize,
    /// Paged: pages this slot holds (counts only — the oracle does not
    /// track physical identities).
    pages: usize,
}

struct SimState {
    cfg: SimConfig,
    slots: Vec<Option<SimSlot>>,
    pending: VecDeque<(u64, SimRequest)>,
    next_id: u64,
    /// Paged: free pages in the pool.
    free_pages: usize,
}

impl SimState {
    fn occupied(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    fn is_idle(&self) -> bool {
        self.pending.is_empty() && self.occupied() == 0
    }

    fn paged(&self) -> bool {
        self.cfg.kv_blocks > 0
    }

    /// Pages a request needs end to end (prompt + budget, capped at the
    /// logical capacity) — the admission watermark.
    fn pages_needed(&self, r: &SimRequest) -> usize {
        (r.prompt_len + r.max_new).min(self.cfg.max_seq).div_ceil(self.cfg.block_size)
    }

    fn submit(&mut self, r: SimRequest) -> Option<u64> {
        if r.prompt_len == 0 || r.prompt_len >= self.cfg.max_seq {
            return None;
        }
        if self.paged() && self.pages_needed(&r) > self.cfg.kv_blocks {
            return None;
        }
        if self.pending.len() >= self.cfg.max_queue {
            return None;
        }
        let id = self.next_id;
        self.next_id += 1;
        self.pending.push_back((id, r));
        Some(id)
    }

    fn cancel(&mut self, id: u64) -> bool {
        if let Some(i) = self.pending.iter().position(|(pid, _)| *pid == id) {
            self.pending.remove(i);
            return true;
        }
        for s in self.slots.iter_mut() {
            if s.map(|s| s.id) == Some(id) {
                self.free_pages += s.map(|s| s.pages).unwrap_or(0);
                *s = None;
                return true;
            }
        }
        false
    }

    fn admit(&mut self) {
        while !self.pending.is_empty() {
            let Some(b) = self.slots.iter().position(|s| s.is_none()) else { break };
            if self.paged() {
                // Head-of-queue watermark: enough free pages for the whole
                // request, one page claimed now.
                let (_, r) = self.pending.front().expect("non-empty");
                if self.free_pages < self.pages_needed(r) {
                    break;
                }
            }
            let (id, r) = self.pending.pop_front().expect("non-empty");
            let pages = if self.paged() {
                self.free_pages -= 1;
                1
            } else {
                0
            };
            self.slots[b] = Some(SimSlot {
                id,
                prompt_len: r.prompt_len,
                max_new: r.max_new,
                fed: 0,
                gen: 0,
                pos: 0,
                pages,
            });
        }
    }

    fn retire(&mut self, b: usize, res: &mut SimResult) {
        let s = self.slots[b].take().expect("retiring an occupied slot");
        self.free_pages += s.pages;
        res.completion_order.push(s.id);
        res.generated.insert(s.id, s.gen);
    }

    /// Mirror of `Scheduler::evict_youngest`: free the largest-id slot's
    /// pages and requeue it (reset) at the queue front.
    fn evict_youngest(&mut self, res: &mut SimResult) {
        let victim = (0..self.cfg.slots)
            .filter(|&b| self.slots[b].is_some())
            .max_by_key(|&b| self.slots[b].expect("occupied").id)
            .expect("pool exhausted with nothing in flight");
        let s = self.slots[victim].take().expect("occupied");
        self.free_pages += s.pages;
        res.evictions += 1;
        self.pending.push_front((
            s.id,
            SimRequest { prompt_len: s.prompt_len, max_new: s.max_new },
        ));
    }

    /// Mirror of `Scheduler::grow_or_evict`: grow slot `b` to cover
    /// `[0, target)`, evicting youngest-first while the pool is dry.
    fn grow_or_evict(&mut self, b: usize, target: usize, res: &mut SimResult) {
        loop {
            let Some(s) = self.slots[b] else { return };
            let needed = target.div_ceil(self.cfg.block_size);
            if s.pages >= needed {
                return;
            }
            if self.free_pages > 0 {
                self.free_pages -= 1;
                self.slots[b].as_mut().expect("occupied").pages += 1;
            } else {
                self.evict_youngest(res);
            }
        }
    }

    /// Mirror of `Scheduler::step`: admit, grow (paged), then one prefill
    /// call or one decode step; retire finished slots in slot order.
    fn step(&mut self, res: &mut SimResult) {
        self.admit();
        let chunk = self.cfg.prefill_chunk.max(1);
        let owes = |s: &Option<SimSlot>| s.map_or(false, |s| s.fed < s.prompt_len);
        let prefilling = chunk > 1 && self.slots.iter().any(owes);
        if prefilling {
            if self.paged() {
                for b in 0..self.cfg.slots {
                    let take = match self.slots[b] {
                        Some(s) if s.fed < s.prompt_len => chunk.min(s.prompt_len - s.fed),
                        _ => continue,
                    };
                    let target = self.slots[b].expect("occupied").pos + take;
                    self.grow_or_evict(b, target, res);
                }
                if !self.slots.iter().any(owes) {
                    // Every prefiller was evicted: the real scheduler skips
                    // the engine call this iteration.
                    res.occupancy.push((self.occupied(), self.pending.len()));
                    return;
                }
            }
            res.prefill_calls += 1;
            for b in 0..self.cfg.slots {
                let finished = match self.slots[b].as_mut() {
                    Some(s) if s.fed < s.prompt_len => {
                        let take = chunk.min(s.prompt_len - s.fed);
                        s.fed += take;
                        s.pos += take;
                        let mut fin = false;
                        if s.fed >= s.prompt_len {
                            if s.gen < s.max_new {
                                s.gen += 1;
                            }
                            if s.gen >= s.max_new {
                                fin = true;
                            }
                        }
                        fin || s.pos >= self.cfg.max_seq
                    }
                    _ => continue,
                };
                if finished {
                    self.retire(b, res);
                }
            }
        } else {
            if self.paged() {
                for b in 0..self.cfg.slots {
                    if let Some(s) = self.slots[b] {
                        self.grow_or_evict(b, s.pos + 1, res);
                    }
                }
            }
            if self.occupied() == 0 {
                // The real scheduler returns without an engine call (and
                // without recording occupancy) when nothing is in flight.
                return;
            }
            res.decode_steps += 1;
            for b in 0..self.cfg.slots {
                let finished = match self.slots[b].as_mut() {
                    Some(s) => {
                        s.pos += 1;
                        if s.fed < s.prompt_len {
                            s.fed += 1;
                        }
                        let mut fin = false;
                        if s.fed >= s.prompt_len {
                            if s.gen < s.max_new {
                                s.gen += 1;
                            }
                            if s.gen >= s.max_new {
                                fin = true;
                            }
                        }
                        fin || s.pos >= self.cfg.max_seq
                    }
                    None => continue,
                };
                if finished {
                    self.retire(b, res);
                }
            }
        }
        res.occupancy.push((self.occupied(), self.pending.len()));
    }
}

/// Replay `events` against the bookkeeping model, then drain to idle.
pub fn simulate(cfg: &SimConfig, events: &[SimEvent]) -> SimResult {
    let mut st = SimState {
        cfg: *cfg,
        slots: (0..cfg.slots).map(|_| None).collect(),
        pending: VecDeque::new(),
        next_id: 0,
        free_pages: cfg.kv_blocks,
    };
    let mut res = SimResult::default();
    for ev in events {
        match ev {
            SimEvent::Submit(r) => {
                let got = st.submit(*r);
                res.submits.push(got);
            }
            SimEvent::Cancel(id) => {
                let got = st.cancel(*id);
                res.cancels.push(got);
            }
            SimEvent::Step => st.step(&mut res),
        }
    }
    while !st.is_idle() {
        st.step(&mut res);
    }
    res
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::{GenRequest, MockEngine, Scheduler};
    use crate::testing::prop::{forall, Gen};

    /// Drive the REAL scheduler (over MockEngine) through the same trace
    /// the oracle saw, collecting the same observables.
    fn run_real(cfg: &SimConfig, events: &[SimEvent]) -> SimResult {
        let mut engine = MockEngine::new(cfg.slots, cfg.max_seq, 64)
            .with_prefill_chunk(cfg.prefill_chunk);
        if cfg.kv_blocks > 0 {
            engine = engine.with_block_pool(cfg.kv_blocks, cfg.block_size);
        }
        let mut s = Scheduler::new(engine, cfg.max_queue).expect("scheduler");
        let mut res = SimResult::default();
        let record = |s: &mut Scheduler<MockEngine>, res: &mut SimResult| {
            let was_idle = s.is_idle();
            let done = s.step().expect("step");
            for c in done {
                res.completion_order.push(c.id);
                res.generated.insert(c.id, c.completion.len());
            }
            if !was_idle {
                res.occupancy.push((s.in_flight(), s.queue_depth()));
            }
        };
        for ev in events {
            match ev {
                SimEvent::Submit(r) => {
                    // Deterministic prompt bytes; content never affects the
                    // bookkeeping, only the sampled tokens.
                    let prompt = vec![b'q'; r.prompt_len];
                    res.submits.push(s.submit(GenRequest::greedy(&prompt, r.max_new)).ok());
                }
                SimEvent::Cancel(id) => {
                    res.cancels.push(s.cancel(*id).expect("cancel"));
                }
                SimEvent::Step => record(&mut s, &mut res),
            }
        }
        while !s.is_idle() {
            record(&mut s, &mut res);
        }
        res.decode_steps = s.engine().steps;
        res.prefill_calls = s.engine().prefill_calls;
        res.evictions = s.metrics.requests_evicted;
        res
    }

    fn random_events(g: &mut Gen, cfg: &SimConfig) -> Vec<SimEvent> {
        let n_events = g.int(4, 40);
        let mut events = Vec::with_capacity(n_events);
        for _ in 0..n_events {
            match g.int(0, 9) {
                0..=3 => {
                    // Mostly valid prompts; occasionally an invalid one so
                    // the rejection paths are mirrored too.
                    let prompt_len = if g.int(0, 19) == 0 {
                        *g.pick(&[0usize, cfg.max_seq, cfg.max_seq + 3])
                    } else {
                        g.int(1, (cfg.max_seq - 1).min(24))
                    };
                    events.push(SimEvent::Submit(SimRequest {
                        prompt_len,
                        max_new: g.int(0, 8),
                    }));
                }
                4..=8 => events.push(SimEvent::Step),
                _ => events.push(SimEvent::Cancel(g.int(0, 12) as u64)),
            }
        }
        events
    }

    fn random_trace(g: &mut Gen) -> (SimConfig, Vec<SimEvent>) {
        let cfg = SimConfig::dense(
            g.int(1, 4),
            g.int(4, 48),
            g.int(1, 6),
            *g.pick(&[1usize, 1, 2, 3, 4, 8, 16]),
        );
        let events = random_events(g, &cfg);
        (cfg, events)
    }

    /// Paged trace: a pool small enough that the budget gate, lazy growth
    /// and eviction paths all fire regularly.
    fn random_paged_trace(g: &mut Gen) -> (SimConfig, Vec<SimEvent>) {
        let slots = g.int(1, 4);
        let max_seq = g.int(4, 48);
        let block_size = *g.pick(&[1usize, 2, 3, 4, 8]);
        let full = slots * max_seq.div_ceil(block_size);
        let cfg = SimConfig {
            slots,
            max_seq,
            max_queue: g.int(1, 6),
            prefill_chunk: *g.pick(&[1usize, 1, 2, 4, 8]),
            // From starved (submit-time rejections, constant eviction) to
            // over-provisioned (budget never binds).
            kv_blocks: g.int(1, full.max(2)),
            block_size,
        };
        let events = random_events(g, &cfg);
        (cfg, events)
    }

    fn check_equivalence(g: &mut Gen) -> Result<(), String> {
        let (cfg, events) = random_trace(g);
        check_trace(&cfg, &events)
    }

    fn check_equivalence_paged(g: &mut Gen) -> Result<(), String> {
        let (cfg, events) = random_paged_trace(g);
        check_trace(&cfg, &events)
    }

    fn check_trace(cfg: &SimConfig, events: &[SimEvent]) -> Result<(), String> {
        let oracle = simulate(cfg, events);
        let real = run_real(cfg, events);
        if real.submits != oracle.submits {
            return Err(format!(
                "{cfg:?}: submit outcomes {:?} vs oracle {:?}",
                real.submits, oracle.submits
            ));
        }
        if real.cancels != oracle.cancels {
            return Err(format!(
                "{cfg:?}: cancel outcomes {:?} vs oracle {:?}",
                real.cancels, oracle.cancels
            ));
        }
        if real.completion_order != oracle.completion_order {
            return Err(format!(
                "{cfg:?}: completion order {:?} vs oracle {:?}",
                real.completion_order, oracle.completion_order
            ));
        }
        if real.generated != oracle.generated {
            return Err(format!(
                "{cfg:?}: token counts {:?} vs oracle {:?}",
                real.generated, oracle.generated
            ));
        }
        if real.occupancy != oracle.occupancy {
            return Err(format!(
                "{cfg:?}: occupancy trace {:?} vs oracle {:?}",
                real.occupancy, oracle.occupancy
            ));
        }
        if real.decode_steps != oracle.decode_steps
            || real.prefill_calls != oracle.prefill_calls
        {
            return Err(format!(
                "{cfg:?}: {} decode steps / {} prefill calls, oracle says {} / {}",
                real.decode_steps, real.prefill_calls, oracle.decode_steps, oracle.prefill_calls
            ));
        }
        if real.evictions != oracle.evictions {
            return Err(format!(
                "{cfg:?}: {} evictions vs oracle {}",
                real.evictions, oracle.evictions
            ));
        }
        Ok(())
    }

    /// Paged scheduler with a *full-size* pool vs the dense scheduler on
    /// the same trace: the token budget never binds, so every observable —
    /// submits, completion order, token counts, occupancy, step counts —
    /// must match the dense path exactly (and no eviction may fire).
    fn check_paged_vs_dense_full_pool(g: &mut Gen) -> Result<(), String> {
        let (dense_cfg, events) = random_trace(g);
        let block_size = *g.pick(&[1usize, 2, 4, 8]);
        let paged_cfg = SimConfig {
            kv_blocks: dense_cfg.slots * dense_cfg.max_seq.div_ceil(block_size),
            block_size,
            ..dense_cfg
        };
        let dense = run_real(&dense_cfg, &events);
        let paged = run_real(&paged_cfg, &events);
        if paged.evictions != 0 {
            return Err(format!("{paged_cfg:?}: full pool evicted {}", paged.evictions));
        }
        if paged.submits != dense.submits
            || paged.completion_order != dense.completion_order
            || paged.generated != dense.generated
            || paged.occupancy != dense.occupancy
            || paged.decode_steps != dense.decode_steps
            || paged.prefill_calls != dense.prefill_calls
        {
            return Err(format!(
                "{paged_cfg:?}: paged(full pool) diverged from dense\n\
                 paged: {paged:?}\ndense: {dense:?}"
            ));
        }
        Ok(())
    }

    // Three pinned seeds x 120 traces = 360 randomized cases in CI; any
    // failure prints (seed, case, case_seed) for exact reproduction.

    #[test]
    fn sim_trace_equivalence_seed_a() {
        forall(101, 120, check_equivalence);
    }

    #[test]
    fn sim_trace_equivalence_seed_b() {
        forall(202, 120, check_equivalence);
    }

    #[test]
    fn sim_trace_equivalence_seed_c() {
        forall(303, 120, check_equivalence);
    }

    // Paged traces: three more pinned seeds x 120 = 360 randomized cases
    // over the block-budget admission / lazy-growth / eviction bookkeeping.

    #[test]
    fn sim_trace_equivalence_paged_seed_a() {
        forall(404, 120, check_equivalence_paged);
    }

    #[test]
    fn sim_trace_equivalence_paged_seed_b() {
        forall(505, 120, check_equivalence_paged);
    }

    #[test]
    fn sim_trace_equivalence_paged_seed_c() {
        forall(606, 120, check_equivalence_paged);
    }

    /// Paged-with-full-pool must be observationally identical to dense.
    #[test]
    fn sim_trace_equivalence_paged_vs_dense() {
        forall(707, 120, check_paged_vs_dense_full_pool);
    }

    /// Extra exploration knob: SPINQUANT_SIM_SEED=1234 cargo test — runs
    /// another 120 dense + 120 paged traces from an arbitrary seed without
    /// a rebuild.
    #[test]
    fn sim_trace_equivalence_env_seed() {
        if let Ok(seed) = std::env::var("SPINQUANT_SIM_SEED") {
            let seed: u64 = seed.parse().expect("SPINQUANT_SIM_SEED must be u64");
            forall(seed, 120, check_equivalence);
            forall(seed ^ 0x9a9a, 120, check_equivalence_paged);
        }
    }

    #[test]
    fn oracle_smoke_single_request() {
        // Hand-checkable trace: one request, prompt 5, budget 2, chunk 4.
        let cfg = SimConfig::dense(1, 32, 4, 4);
        let events =
            [SimEvent::Submit(SimRequest { prompt_len: 5, max_new: 2 }), SimEvent::Step];
        let res = simulate(&cfg, &events);
        // Call 1 feeds 4 prompt tokens; drain: call 2 feeds 1 + samples
        // token 1; one decode step samples token 2 and retires.
        assert_eq!(res.prefill_calls, 2);
        assert_eq!(res.decode_steps, 1);
        assert_eq!(res.completion_order, vec![0]);
        assert_eq!(res.generated.get(&0), Some(&2));
        assert_eq!(res.occupancy, vec![(1, 0), (1, 0), (0, 0)]);
    }

    #[test]
    fn oracle_smoke_paged_eviction() {
        // Hand-checkable paged trace: 2 slots, 4 pages of 4 tokens.
        // Two (prompt 4, budget 8) requests each need 3 pages end to end;
        // the watermark admits both, growth exhausts the pool at pos 8,
        // request 1 is evicted, request 0 completes, request 1 restarts
        // and completes — both with their full 8 tokens.
        let cfg = SimConfig {
            slots: 2,
            max_seq: 32,
            max_queue: 4,
            prefill_chunk: 1,
            kv_blocks: 4,
            block_size: 4,
        };
        let events = [
            SimEvent::Submit(SimRequest { prompt_len: 4, max_new: 8 }),
            SimEvent::Submit(SimRequest { prompt_len: 4, max_new: 8 }),
        ];
        let res = simulate(&cfg, &events);
        assert_eq!(res.submits, vec![Some(0), Some(1)]);
        assert_eq!(res.evictions, 1);
        assert_eq!(res.completion_order, vec![0, 1]);
        assert_eq!(res.generated.get(&0), Some(&8));
        assert_eq!(res.generated.get(&1), Some(&8));
        // The real scheduler agrees on the whole trace.
        check_trace(&cfg, &events).unwrap();
    }

    #[test]
    fn oracle_smoke_paged_budget_gate() {
        // 1 slot free but only 2 free pages: a request needing 3 pages
        // waits in the queue even though a slot is open.
        let cfg = SimConfig {
            slots: 2,
            max_seq: 32,
            max_queue: 4,
            prefill_chunk: 1,
            kv_blocks: 3,
            block_size: 4,
        };
        let events = [
            SimEvent::Submit(SimRequest { prompt_len: 2, max_new: 1 }), // 1 page
            SimEvent::Submit(SimRequest { prompt_len: 8, max_new: 4 }), // 3 pages
            SimEvent::Step,
        ];
        let res = simulate(&cfg, &events);
        assert_eq!(res.submits, vec![Some(0), Some(1)]);
        // After the first step: request 0 in flight, request 1 still queued
        // (2 free pages < 3 needed).
        assert_eq!(res.occupancy.first(), Some(&(1, 1)));
        assert_eq!(res.completion_order, vec![0, 1]);
        check_trace(&cfg, &events).unwrap();
    }
}
