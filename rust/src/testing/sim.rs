//! Seeded reference simulator ("oracle") for the continuous-batching
//! scheduler.
//!
//! [`simulate`] replays a trace of submit/step/cancel events against a
//! *pure bookkeeping* model of the scheduler: FIFO admission into the
//! lowest free slot, bounded queue with backpressure, batched multi-token
//! prefill (`ceil(len/chunk)` calls) or the chunk-1 interleaved path,
//! per-request generation budgets, cache-capacity truncation, and
//! mid-flight eviction. No engine, no logits, no clocks — just the
//! admission/join/evict/budget arithmetic the real
//! [`crate::serve::Scheduler`] must implement.
//!
//! The randomized trace tests at the bottom generate hundreds of seeded
//! traces, run each against both the oracle and the real scheduler over
//! [`crate::serve::MockEngine`], and require them to agree on accepted
//! ids, completion order, per-request token counts, per-step slot
//! occupancy and queue depth, and the exact number of decode steps and
//! prefill calls. Failures print the seed/case (via [`super::prop::forall`])
//! so any divergence is reproducible. CI pins three seeds (see
//! `.github/workflows/ci.yml`) so trace-equivalence regressions fail the
//! build.

use std::collections::{BTreeMap, VecDeque};

/// One generation request, reduced to what the bookkeeping depends on.
#[derive(Clone, Copy, Debug)]
pub struct SimRequest {
    pub prompt_len: usize,
    pub max_new: usize,
}

/// Scheduler shape under simulation.
#[derive(Clone, Copy, Debug)]
pub struct SimConfig {
    pub slots: usize,
    pub max_seq: usize,
    pub max_queue: usize,
    /// Engine prefill chunk; 1 = the interleaved token-by-token path.
    pub prefill_chunk: usize,
}

/// Trace events, mirroring the public scheduler API.
#[derive(Clone, Debug)]
pub enum SimEvent {
    Submit(SimRequest),
    Step,
    Cancel(u64),
}

/// Everything the oracle predicts for one trace (the trailing drain to
/// idle is included).
#[derive(Clone, Debug, Default)]
pub struct SimResult {
    /// Outcome per `Submit` event: `Some(id)` or `None` (rejected — queue
    /// full or invalid prompt; rejected submits consume no id).
    pub submits: Vec<Option<u64>>,
    /// Outcome per `Cancel` event (`true` = found and removed).
    pub cancels: Vec<bool>,
    /// Request ids in completion order.
    pub completion_order: Vec<u64>,
    /// Generated-token count per completed id (truncation included).
    pub generated: BTreeMap<u64, usize>,
    /// (occupied slots, queue depth) after every non-idle step.
    pub occupancy: Vec<(usize, usize)>,
    pub decode_steps: usize,
    pub prefill_calls: usize,
}

#[derive(Clone, Copy, Debug)]
struct SimSlot {
    id: u64,
    prompt_len: usize,
    max_new: usize,
    fed: usize,
    gen: usize,
    pos: usize,
}

struct SimState {
    cfg: SimConfig,
    slots: Vec<Option<SimSlot>>,
    pending: VecDeque<(u64, SimRequest)>,
    next_id: u64,
}

impl SimState {
    fn occupied(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    fn is_idle(&self) -> bool {
        self.pending.is_empty() && self.occupied() == 0
    }

    fn submit(&mut self, r: SimRequest) -> Option<u64> {
        if r.prompt_len == 0 || r.prompt_len >= self.cfg.max_seq {
            return None;
        }
        if self.pending.len() >= self.cfg.max_queue {
            return None;
        }
        let id = self.next_id;
        self.next_id += 1;
        self.pending.push_back((id, r));
        Some(id)
    }

    fn cancel(&mut self, id: u64) -> bool {
        if let Some(i) = self.pending.iter().position(|(pid, _)| *pid == id) {
            self.pending.remove(i);
            return true;
        }
        for s in self.slots.iter_mut() {
            if s.map(|s| s.id) == Some(id) {
                *s = None;
                return true;
            }
        }
        false
    }

    fn admit(&mut self) {
        while !self.pending.is_empty() {
            let Some(b) = self.slots.iter().position(|s| s.is_none()) else { break };
            let (id, r) = self.pending.pop_front().expect("non-empty");
            self.slots[b] = Some(SimSlot {
                id,
                prompt_len: r.prompt_len,
                max_new: r.max_new,
                fed: 0,
                gen: 0,
                pos: 0,
            });
        }
    }

    fn retire(&mut self, b: usize, res: &mut SimResult) {
        let s = self.slots[b].take().expect("retiring an occupied slot");
        res.completion_order.push(s.id);
        res.generated.insert(s.id, s.gen);
    }

    /// Mirror of `Scheduler::step`: admit, then one prefill call or one
    /// decode step; retire finished slots in slot order.
    fn step(&mut self, res: &mut SimResult) {
        self.admit();
        let chunk = self.cfg.prefill_chunk.max(1);
        let prefilling = chunk > 1
            && self.slots.iter().any(|s| s.map_or(false, |s| s.fed < s.prompt_len));
        if prefilling {
            res.prefill_calls += 1;
            for b in 0..self.cfg.slots {
                let finished = match self.slots[b].as_mut() {
                    Some(s) if s.fed < s.prompt_len => {
                        let take = chunk.min(s.prompt_len - s.fed);
                        s.fed += take;
                        s.pos += take;
                        let mut fin = false;
                        if s.fed >= s.prompt_len {
                            if s.gen < s.max_new {
                                s.gen += 1;
                            }
                            if s.gen >= s.max_new {
                                fin = true;
                            }
                        }
                        fin || s.pos >= self.cfg.max_seq
                    }
                    _ => continue,
                };
                if finished {
                    self.retire(b, res);
                }
            }
        } else {
            if self.occupied() == 0 {
                // The real scheduler returns without an engine call (and
                // without recording occupancy) when nothing is in flight.
                return;
            }
            res.decode_steps += 1;
            for b in 0..self.cfg.slots {
                let finished = match self.slots[b].as_mut() {
                    Some(s) => {
                        s.pos += 1;
                        if s.fed < s.prompt_len {
                            s.fed += 1;
                        }
                        let mut fin = false;
                        if s.fed >= s.prompt_len {
                            if s.gen < s.max_new {
                                s.gen += 1;
                            }
                            if s.gen >= s.max_new {
                                fin = true;
                            }
                        }
                        fin || s.pos >= self.cfg.max_seq
                    }
                    None => continue,
                };
                if finished {
                    self.retire(b, res);
                }
            }
        }
        res.occupancy.push((self.occupied(), self.pending.len()));
    }
}

/// Replay `events` against the bookkeeping model, then drain to idle.
pub fn simulate(cfg: &SimConfig, events: &[SimEvent]) -> SimResult {
    let mut st = SimState {
        cfg: *cfg,
        slots: (0..cfg.slots).map(|_| None).collect(),
        pending: VecDeque::new(),
        next_id: 0,
    };
    let mut res = SimResult::default();
    for ev in events {
        match ev {
            SimEvent::Submit(r) => {
                let got = st.submit(*r);
                res.submits.push(got);
            }
            SimEvent::Cancel(id) => {
                let got = st.cancel(*id);
                res.cancels.push(got);
            }
            SimEvent::Step => st.step(&mut res),
        }
    }
    while !st.is_idle() {
        st.step(&mut res);
    }
    res
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::{GenRequest, MockEngine, Scheduler};
    use crate::testing::prop::{forall, Gen};

    /// Drive the REAL scheduler (over MockEngine) through the same trace
    /// the oracle saw, collecting the same observables.
    fn run_real(cfg: &SimConfig, events: &[SimEvent]) -> SimResult {
        let engine = MockEngine::new(cfg.slots, cfg.max_seq, 64)
            .with_prefill_chunk(cfg.prefill_chunk);
        let mut s = Scheduler::new(engine, cfg.max_queue).expect("scheduler");
        let mut res = SimResult::default();
        let record = |s: &mut Scheduler<MockEngine>, res: &mut SimResult| {
            let was_idle = s.is_idle();
            let done = s.step().expect("step");
            for c in done {
                res.completion_order.push(c.id);
                res.generated.insert(c.id, c.completion.len());
            }
            if !was_idle {
                res.occupancy.push((s.in_flight(), s.queue_depth()));
            }
        };
        for ev in events {
            match ev {
                SimEvent::Submit(r) => {
                    // Deterministic prompt bytes; content never affects the
                    // bookkeeping, only the sampled tokens.
                    let prompt = vec![b'q'; r.prompt_len];
                    res.submits.push(s.submit(GenRequest::greedy(&prompt, r.max_new)).ok());
                }
                SimEvent::Cancel(id) => {
                    res.cancels.push(s.cancel(*id).expect("cancel"));
                }
                SimEvent::Step => record(&mut s, &mut res),
            }
        }
        while !s.is_idle() {
            record(&mut s, &mut res);
        }
        res.decode_steps = s.engine().steps;
        res.prefill_calls = s.engine().prefill_calls;
        res
    }

    fn random_trace(g: &mut Gen) -> (SimConfig, Vec<SimEvent>) {
        let cfg = SimConfig {
            slots: g.int(1, 4),
            max_seq: g.int(4, 48),
            max_queue: g.int(1, 6),
            prefill_chunk: *g.pick(&[1usize, 1, 2, 3, 4, 8, 16]),
        };
        let n_events = g.int(4, 40);
        let mut events = Vec::with_capacity(n_events);
        for _ in 0..n_events {
            match g.int(0, 9) {
                0..=3 => {
                    // Mostly valid prompts; occasionally an invalid one so
                    // the rejection paths are mirrored too.
                    let prompt_len = if g.int(0, 19) == 0 {
                        *g.pick(&[0usize, cfg.max_seq, cfg.max_seq + 3])
                    } else {
                        g.int(1, (cfg.max_seq - 1).min(24))
                    };
                    events.push(SimEvent::Submit(SimRequest {
                        prompt_len,
                        max_new: g.int(0, 8),
                    }));
                }
                4..=8 => events.push(SimEvent::Step),
                _ => events.push(SimEvent::Cancel(g.int(0, 12) as u64)),
            }
        }
        (cfg, events)
    }

    fn check_equivalence(g: &mut Gen) -> Result<(), String> {
        let (cfg, events) = random_trace(g);
        let oracle = simulate(&cfg, &events);
        let real = run_real(&cfg, &events);
        if real.submits != oracle.submits {
            return Err(format!(
                "{cfg:?}: submit outcomes {:?} vs oracle {:?}",
                real.submits, oracle.submits
            ));
        }
        if real.cancels != oracle.cancels {
            return Err(format!(
                "{cfg:?}: cancel outcomes {:?} vs oracle {:?}",
                real.cancels, oracle.cancels
            ));
        }
        if real.completion_order != oracle.completion_order {
            return Err(format!(
                "{cfg:?}: completion order {:?} vs oracle {:?}",
                real.completion_order, oracle.completion_order
            ));
        }
        if real.generated != oracle.generated {
            return Err(format!(
                "{cfg:?}: token counts {:?} vs oracle {:?}",
                real.generated, oracle.generated
            ));
        }
        if real.occupancy != oracle.occupancy {
            return Err(format!(
                "{cfg:?}: occupancy trace {:?} vs oracle {:?}",
                real.occupancy, oracle.occupancy
            ));
        }
        if real.decode_steps != oracle.decode_steps
            || real.prefill_calls != oracle.prefill_calls
        {
            return Err(format!(
                "{cfg:?}: {} decode steps / {} prefill calls, oracle says {} / {}",
                real.decode_steps, real.prefill_calls, oracle.decode_steps, oracle.prefill_calls
            ));
        }
        Ok(())
    }

    // Three pinned seeds x 120 traces = 360 randomized cases in CI; any
    // failure prints (seed, case, case_seed) for exact reproduction.

    #[test]
    fn sim_trace_equivalence_seed_a() {
        forall(101, 120, check_equivalence);
    }

    #[test]
    fn sim_trace_equivalence_seed_b() {
        forall(202, 120, check_equivalence);
    }

    #[test]
    fn sim_trace_equivalence_seed_c() {
        forall(303, 120, check_equivalence);
    }

    /// Extra exploration knob: SPINQUANT_SIM_SEED=1234 cargo test — runs
    /// another 120 traces from an arbitrary seed without a rebuild.
    #[test]
    fn sim_trace_equivalence_env_seed() {
        if let Ok(seed) = std::env::var("SPINQUANT_SIM_SEED") {
            let seed: u64 = seed.parse().expect("SPINQUANT_SIM_SEED must be u64");
            forall(seed, 120, check_equivalence);
        }
    }

    #[test]
    fn oracle_smoke_single_request() {
        // Hand-checkable trace: one request, prompt 5, budget 2, chunk 4.
        let cfg = SimConfig { slots: 1, max_seq: 32, max_queue: 4, prefill_chunk: 4 };
        let events =
            [SimEvent::Submit(SimRequest { prompt_len: 5, max_new: 2 }), SimEvent::Step];
        let res = simulate(&cfg, &events);
        // Call 1 feeds 4 prompt tokens; drain: call 2 feeds 1 + samples
        // token 1; one decode step samples token 2 and retires.
        assert_eq!(res.prefill_calls, 2);
        assert_eq!(res.decode_steps, 1);
        assert_eq!(res.completion_order, vec![0]);
        assert_eq!(res.generated.get(&0), Some(&2));
        assert_eq!(res.occupancy, vec![(1, 0), (1, 0), (0, 0)]);
    }
}
