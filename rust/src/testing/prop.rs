//! Tiny property-test runner: seeded generators + `forall`.
//!
//! Not a proptest replacement (no shrinking), but gives us the important
//! part — many randomized cases per invariant, reproducible from the seed
//! printed on failure.

use crate::tensor::Tensor;
use crate::util::prng::Prng;

/// Random value generator handed to each property case.
pub struct Gen {
    pub rng: Prng,
}

impl Gen {
    pub fn int(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.rng.below(hi - lo + 1)
    }

    pub fn f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + self.rng.uniform() * (hi - lo)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 0
    }

    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below(xs.len())]
    }

    /// Gaussian tensor with entries scaled by `scale`.
    pub fn tensor(&mut self, shape: &[usize], scale: f32) -> Tensor {
        let n: usize = shape.iter().product();
        let data = (0..n).map(|_| self.rng.normal() * scale).collect();
        Tensor::new(shape.to_vec(), data)
    }

    /// Gaussian tensor with a few boosted "outlier" columns (the LLM
    /// activation shape this paper is about).
    pub fn outlier_tensor(&mut self, rows: usize, cols: usize, boost: f32) -> Tensor {
        let mut t = self.tensor(&[rows, cols], 1.0);
        let n_out = 1 + self.rng.below(3.min(cols));
        for _ in 0..n_out {
            let c = self.rng.below(cols);
            for r in 0..rows {
                t.data[r * cols + c] *= boost;
            }
        }
        t
    }
}

/// Run `cases` randomized checks of `prop`; panic with the failing seed.
pub fn forall<F>(seed: u64, cases: usize, mut prop: F)
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    for case in 0..cases {
        let case_seed = seed.wrapping_mul(1_000_003).wrapping_add(case as u64);
        let mut g = Gen { rng: Prng::new(case_seed) };
        if let Err(msg) = prop(&mut g) {
            panic!("property failed (seed={seed}, case={case}, case_seed={case_seed}): {msg}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivial_property() {
        forall(1, 50, |g| {
            let x = g.f32(-5.0, 5.0);
            if x.abs() <= 5.0 { Ok(()) } else { Err(format!("{x}")) }
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn forall_reports_failures() {
        forall(2, 50, |g| {
            let x = g.int(0, 100);
            if x < 90 { Ok(()) } else { Err(format!("x={x}")) }
        });
    }

    #[test]
    fn outlier_tensor_has_outliers() {
        let mut g = Gen { rng: Prng::new(3) };
        let t = g.outlier_tensor(64, 32, 30.0);
        assert!(t.kurtosis() > 10.0);
    }
}
