//! Continuous-batching scheduler + the legacy threaded FIFO front.
//!
//! [`Scheduler`] drives a [`DecodeEngine`] one engine call at a time.
//! Before every call it admits pending requests into free KV-cache slots
//! (so a request submitted mid-decode joins the running batch on the very
//! next step after a slot frees — no draining). What the call *is* depends
//! on the engine's prefill support:
//!
//! * engines with a multi-token prefill graph (`prefill_chunk() > 1`):
//!   a newly admitted request's prompt is consumed in `ceil(len/T)`
//!   batched prefill calls — all prefilling slots share each call — and
//!   the chunk that completes a prompt yields the logits for the request's
//!   first token. Only then does the request enter the per-token decode
//!   batch. Decode-phase slots idle during a prefill call (the classic
//!   chunked-prefill trade: much better TTFT, occasional decode hiccup).
//! * engines without one (`prefill_chunk() == 1`): prompt feeding and
//!   generation share the decode step exactly as before — one token per
//!   slot per step, prefilling and decoding slots batched together.
//!
//! Each step samples continuations per request and retires finished
//! requests. Admission is bounded: [`Scheduler::submit`] applies
//! backpressure once the queue is full instead of buffering unboundedly.
//! TTFT is always measured from *enqueue* (submit), never from admission
//! or step start, so queue wait is visible in the latency metrics.
//!
//! Over a *paged* engine (`kv_block_size()` is `Some`) the KV cache is a
//! pool of `block_size`-token pages and admission is by **token budget**,
//! not slot count: a prompt is admitted only when
//! `ceil(min(len + max_new, max_seq) / block_size)` pages are free, its
//! block table then grows lazily as decode crosses page boundaries, and if
//! the pool runs dry mid-flight (admission is a watermark, not a
//! reservation) the *youngest* in-flight request is evicted back to the
//! queue front — it restarts from scratch later, and the seeded sampler
//! makes the restarted generation identical. The eviction rule is
//! deterministic (largest request id first), which is what lets the pure
//! oracle in [`crate::testing::sim`] replay paged traces exactly.
//!
//! With [`Scheduler::with_prefix_cache`] page ownership is refcounted
//! copy-on-write (see [`crate::serve::prefix`]): a new request's longest
//! cached prompt prefix is mapped read-only into its block table at
//! admission, the watermark counts only its *non-shared* page demand,
//! prefill starts at the first uncached position (whole cached pages are
//! skipped, shrinking TTFT), and full prompt pages are donated to the
//! index as they fill so the next request with the same system prompt
//! reuses them. Completions are bit-identical with the cache on or off —
//! the cache removes recomputation, never changes content.
//!
//! # The decode-priority step composer (`--step-budget`)
//!
//! The drain-prefill-then-decode loop above has a latency failure mode:
//! one long prompt monopolises `ceil(len/T)` consecutive engine calls and
//! every in-flight request's inter-token latency spikes for the whole
//! burst. [`Scheduler::with_step_budget`] replaces that loop with a
//! Sarathi-style *step composer*. Each iteration builds a **step plan**
//! from the slot phases ([`crate::serve::slots::SlotPhase`]):
//!
//! 1. **Partition** — `Running` slots (prompt fully fed) form the decode
//!    set; `Warming` slots (still owing prompt tokens) are prefill
//!    candidates; queued requests stay `Cold` until admission.
//! 2. **Budget** — the decode set is admitted first and in full (decode
//!    priority: a running slot is *never* skipped, so the decode stall is
//!    structurally 0 steps). The remaining `B - decode_tokens` budget is
//!    filled with prompt chunks from warming slots in slot order, each
//!    take capped by the engine's prefill graph width `T`, the slot's
//!    remaining prompt, and the budget left — a prompt therefore splits
//!    across steps at arbitrary boundaries, reusing the ragged `n_valid`
//!    prefill graphs (no new PJRT artifacts). A **starvation guard**
//!    (`max(1, B/4)` tokens) floors the prefill share so a full decode
//!    batch can never stall admission-side progress (TTFT stays bounded).
//! 3. **Grow** (paged) — decode slots' pages first, then the planned
//!    prefill takes; an eviction mid-growth drops its slot from the plan
//!    (the freed budget is *not* redistributed, keeping the plan — and
//!    the oracle's replay of it — deterministic).
//! 4. **Execute** — one decode call over the surviving decode set, then
//!    at most one prefill call over the surviving takes: exactly the "one
//!    prefill call + one decode call per step" shape the PJRT bindings
//!    already support. Slots that complete their prompt in the prefill
//!    call sample their first token there and turn `Running` *next* step.
//!
//! With the budget off (the default) the original paths run untouched —
//! byte-for-byte, step-for-step identical to PR 4 — which is what the
//! sim-oracle regression suites anchor against. Generated bytes are
//! identical with the composer on or off (logits depend only on each
//! request's own history); only the schedule changes.
//!
//! # Failure model (the error kernel)
//!
//! Engine calls can fail. A classified [`ServeError`] from any
//! engine-touching path is absorbed by the scheduler's error kernel
//! instead of aborting the serve loop: a per-slot fault puts the blamed
//! request on a deterministic backoff counted in scheduler steps (and
//! quarantines it once it has individually faulted `retry_budget`
//! times); a step-wide transient fault pauses the whole engine on the
//! same backoff schedule (and evicts the call's participants to the
//! queue front for a warm restart through their donated prefix pages
//! when the fault streak exhausts the budget); a request carrying a
//! [`Deadline`] is shed at admission or mid-flight once it expires.
//! Every fault path is failure-atomic: engines advance no state on an
//! `Err`, so "don't advance the bookkeeping" is the whole rollback and
//! `free + used == total` holds for the page pool after every step.
//! Unclassified errors and [`ServeError::Fatal`] still propagate — they
//! mean a real engine bug, not an injected or transient fault. The full
//! taxonomy and guarantees live in the `serve` module docs ("Failure
//! model & recovery").
//!
//! PJRT handles are not `Send`, so the scheduler is single-threaded by
//! design; the batching parallelism lives *inside* the engine step. The
//! old one-request-at-a-time [`Server`] (worker thread + channels) is kept
//! for callers that want a threaded front over a factory closure.

use std::collections::VecDeque;
use std::sync::mpsc;
use std::time::Instant;

use anyhow::{anyhow, bail, Result};

use crate::serve::engine::{DecodeEngine, ServeError};
use crate::serve::metrics::ServingMetrics;
use crate::serve::sampling::Sampler;
use crate::serve::slots::{SlotMap, SlotPhase};
use crate::serve::trace::{EvictReason, FinishReason, TraceEvent, TraceRecord, TraceSink};
use crate::util::prng::Prng;

/// A request deadline (`serve --deadline-ms`).
///
/// `WallMs` is judged against the request's enqueue instant on the real
/// clock — the production form. `Steps` is judged against scheduler step
/// indices (expire once `step_index - submit_step >= k`), fully
/// deterministic, which is what the sim-oracle fault suites replay.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Deadline {
    /// Milliseconds after enqueue.
    WallMs(f64),
    /// Scheduler steps after enqueue.
    Steps(u64),
}

/// A generation request for the continuous-batching scheduler.
#[derive(Clone, Debug)]
pub struct GenRequest {
    pub prompt: Vec<u8>,
    pub max_new_tokens: usize,
    pub sampler: Sampler,
    /// Seed for this request's sampler PRNG (same seed + same model =>
    /// same completion, at any batch size).
    pub seed: u64,
    /// Optional deadline; an expired request is shed — queued or
    /// mid-flight — with [`FinishReason::DeadlineExpired`].
    pub deadline: Option<Deadline>,
}

impl GenRequest {
    pub fn greedy(prompt: &[u8], max_new_tokens: usize) -> Self {
        Self {
            prompt: prompt.to_vec(),
            max_new_tokens,
            sampler: Sampler::greedy(),
            seed: 0,
            deadline: None,
        }
    }

    pub fn sampled(prompt: &[u8], max_new_tokens: usize, sampler: Sampler, seed: u64) -> Self {
        Self { prompt: prompt.to_vec(), max_new_tokens, sampler, seed, deadline: None }
    }

    /// Shed this request once `ms` milliseconds have passed since enqueue.
    pub fn with_deadline_ms(mut self, ms: f64) -> Self {
        self.deadline = Some(Deadline::WallMs(ms));
        self
    }

    /// Shed this request once `steps` scheduler steps have passed since
    /// enqueue (deterministic; what the sim oracle replays).
    pub fn with_deadline_steps(mut self, steps: u64) -> Self {
        self.deadline = Some(Deadline::Steps(steps));
        self
    }
}

/// A finished generation.
#[derive(Clone, Debug)]
pub struct Completion {
    pub id: u64,
    pub prompt: Vec<u8>,
    pub completion: Vec<u8>,
    /// Enqueue (submit) -> first generated token (ms), queue wait included.
    /// None if nothing was generated (e.g. zero budget).
    pub ttft_ms: Option<f64>,
    /// Enqueue (submit) -> completion (ms), including queue wait.
    pub latency_ms: f64,
    /// How the request finished. `BudgetExhausted`/`CacheFull` are
    /// successes; `Quarantined`/`DeadlineExpired` are failures (the
    /// completion carries whatever was generated before the failure).
    pub reason: FinishReason,
}

/// Per-slot in-flight request state.
struct Active {
    id: u64,
    prompt: Vec<i32>,
    /// Prompt tokens fed so far. Starts at the cached-prefix length when
    /// the prefix cache mapped shared pages at admission — those tokens
    /// are skipped, never re-fed.
    fed: usize,
    generated: Vec<u8>,
    max_new: usize,
    sampler: Sampler,
    /// Original request seed, kept so an evicted request restarts with an
    /// identical sampler stream.
    seed: u64,
    rng: Prng,
    last_token: i32,
    submitted: Instant,
    ttft_us: Option<f64>,
    /// Submit -> first time this request's tokens entered an engine call
    /// (us). Survives eviction requeues — the first *ever* scheduling is
    /// what separates queue wait from prefill spread in TTFT.
    first_sched_us: Option<f64>,
    /// Engine-call iterations this slot sat through without producing a
    /// token since its last one (only counted while `Running`).
    stall_steps: usize,
    /// Engine-busy microseconds accumulated since this slot's last token.
    wait_us: f64,
    /// End-to-end page demand, computed once at submit (prompt and
    /// max_new are immutable); carried through eviction requeues.
    blocks_needed: usize,
    /// Individual (per-slot) engine faults this request has absorbed,
    /// carried through eviction requeues; at `retry_budget` the request
    /// is quarantined.
    faults: usize,
    /// Steps this slot still sits out after a per-slot fault (the
    /// deterministic backoff). A cooling slot joins no engine call; it
    /// rejoins on the `cooldown`-th step after the fault.
    cooldown: u64,
    /// Set between a fault that put this slot on backoff and its next
    /// *successful* engine call, which emits `SlotRecovered`.
    recovering: bool,
    /// Optional deadline, checked at the top of every step.
    deadline: Option<Deadline>,
    /// `step_index` at enqueue time — the epoch for `Deadline::Steps`.
    submit_step: u64,
}

/// One queued request, in admission-ready form: the prompt is already
/// converted to engine tokens and `blocks_needed` — the paged admission
/// demand `ceil(min(len + max_new, max_seq) / block_size)` (0 in dense
/// mode) — is computed once at submit time, so a watermark-blocked head
/// costs no per-step conversion or re-derivation.
struct Queued {
    id: u64,
    prompt: Vec<i32>,
    max_new: usize,
    sampler: Sampler,
    seed: u64,
    submitted: Instant,
    blocks_needed: usize,
    /// `Some` only for eviction requeues: the request was scheduled once
    /// already, and its queue-wait half of TTFT must keep that timestamp.
    first_sched_us: Option<f64>,
    /// Individual engine faults absorbed so far (see [`Active::faults`]).
    faults: usize,
    /// Admission defers while `step_index < not_before_step` — the
    /// queue-side half of the deterministic backoff (a deferred head
    /// blocks the queue: FIFO order is never reordered by faults).
    not_before_step: u64,
    deadline: Option<Deadline>,
    submit_step: u64,
}

/// The continuous-batching loop over one [`DecodeEngine`].
pub struct Scheduler<E: DecodeEngine> {
    engine: E,
    slots: SlotMap,
    active: Vec<Option<Active>>,
    pending: VecDeque<Queued>,
    max_queue: usize,
    next_id: u64,
    /// Paged mode: per-slot block tables padded to the logical page count
    /// with the out-of-range sentinel (`kv_blocks()`), in the exact layout
    /// the paged engine calls take. Maintained incrementally (rows refresh
    /// on admission / growth / release) so the hot path never reallocates
    /// them per step. Empty in dense mode.
    tables: Vec<Vec<i32>>,
    /// `Some(B)`: the decode-priority step composer is on with a per-step
    /// token budget of `B` (see the module docs); `None`: the original
    /// drain-prefill-then-decode paths run untouched.
    step_budget: Option<usize>,
    pub metrics: ServingMetrics,
    /// Flight-recorder sink shared with the [`SlotMap`] (page-plane
    /// events). `Off` by default: the disabled path is one branch per
    /// emission site, no ring buffer is ever allocated.
    trace: TraceSink,
    /// Individual faults a request may absorb before it is quarantined
    /// (per-slot faults), and consecutive step-wide faults the scheduler
    /// tolerates before evicting a call's participants for warm restart.
    retry_budget: usize,
    /// Steps taken so far — the clock every deterministic recovery
    /// decision (backoff, pause, step deadlines) is counted in.
    step_index: u64,
    /// While `step_index < pause_until`, the step-wide backoff is in
    /// force: deadlines are still swept but no admission or engine call
    /// runs.
    pause_until: u64,
    /// Consecutive step-wide faults with no successful engine call in
    /// between; reset on success, participants evicted when it reaches
    /// `retry_budget`.
    step_fault_streak: usize,
}

/// Default for [`Scheduler::with_retry_budget`]: a request may absorb
/// two faults (backoffs of 1 then 2 steps) and is quarantined on its
/// third.
pub const DEFAULT_RETRY_BUDGET: usize = 3;

impl<E: DecodeEngine> Scheduler<E> {
    /// `max_queue` bounds the admission queue (backpressure threshold); it
    /// does not bound in-flight requests, which are capped by the engine's
    /// slot count.
    pub fn new(engine: E, max_queue: usize) -> Result<Self> {
        if engine.slots() == 0 {
            bail!("engine has no slots");
        }
        let n = engine.slots();
        let max_seq = engine.max_seq();
        // A paged engine gets a paged SlotMap over its physical pool; the
        // budget can be restricted further with `with_kv_block_budget`.
        let (slots, tables) = match engine.kv_block_size() {
            Some(bs) => {
                let n_logical = max_seq.div_ceil(bs);
                let sentinel = engine.kv_blocks() as i32;
                (
                    SlotMap::paged(n, max_seq, engine.kv_blocks(), bs),
                    vec![vec![sentinel; n_logical]; n],
                )
            }
            None => (SlotMap::new(n, max_seq), Vec::new()),
        };
        Ok(Self {
            engine,
            slots,
            active: (0..n).map(|_| None).collect(),
            pending: VecDeque::new(),
            max_queue: max_queue.max(1),
            next_id: 0,
            tables,
            step_budget: None,
            metrics: ServingMetrics::new(),
            trace: TraceSink::Off,
            retry_budget: DEFAULT_RETRY_BUDGET,
            step_index: 0,
            pause_until: 0,
            step_fault_streak: 0,
        })
    }

    /// Set the retry budget (`serve --retry-budget N`, default
    /// [`DEFAULT_RETRY_BUDGET`]): a request is quarantined after `N`
    /// individual engine faults, and a step-wide fault streak of `N`
    /// evicts the call's participants to the queue front for warm
    /// restart.
    pub fn with_retry_budget(mut self, budget: usize) -> Result<Self> {
        if budget == 0 {
            bail!("--retry-budget must be >= 1 (1 = no retries: first fault quarantines)");
        }
        self.retry_budget = budget;
        Ok(self)
    }

    /// Deterministic backoff for the `attempt`-th consecutive fault,
    /// counted in scheduler steps (1, 2, 4, ... capped at 64) — never
    /// wall clock, so the sim oracle replays recovery exactly.
    fn backoff(attempt: usize) -> u64 {
        1u64 << attempt.saturating_sub(1).min(6)
    }

    /// Attach a flight recorder: a bounded ring buffer of `capacity`
    /// [`TraceRecord`]s (`serve --trace-buffer N`) that every scheduler
    /// decision and page-plane change is appended to as a typed
    /// [`TraceEvent`]. When full, the oldest record is dropped and
    /// [`Self::trace_dropped_events`] counts it. Call before submitting
    /// work so admission events are captured from the start.
    pub fn with_trace(mut self, capacity: usize) -> Self {
        self.trace = TraceSink::ring(capacity);
        self.slots.set_trace(self.trace.clone());
        self
    }

    /// The active trace sink (`TraceSink::Off` unless [`Self::with_trace`]
    /// ran — the off variant is a unit, no buffer exists).
    pub fn trace_sink(&self) -> &TraceSink {
        &self.trace
    }

    /// Snapshot of the ring buffer's surviving records, oldest first
    /// (empty when tracing is off).
    pub fn trace_records(&self) -> Vec<TraceRecord> {
        self.trace.records()
    }

    /// Records overwritten by ring wraparound since tracing began.
    pub fn trace_dropped_events(&self) -> u64 {
        self.trace.dropped_events()
    }

    /// Enable the decode-priority step composer (`serve --step-budget B`):
    /// every scheduler iteration runs the full decode batch first, then at
    /// most one prefill call whose total take is bounded by what remains
    /// of the `budget` (floored by the starvation guard), so one long
    /// prompt can no longer stall in-flight decodes for a whole prefill
    /// burst. Needs an engine with a multi-token prefill graph
    /// (`prefill_chunk() > 1` — the chunk-1 interleaved path has no burst
    /// to bound); call before submitting work.
    pub fn with_step_budget(mut self, budget: usize) -> Result<Self> {
        if budget == 0 {
            bail!("--step-budget must be >= 1 (omit the flag to disable the composer)");
        }
        if self.engine.prefill_chunk() <= 1 {
            bail!(
                "--step-budget needs an engine with a multi-token prefill graph \
                 (prefill chunk is 1: prompts already interleave per token)"
            );
        }
        if self.slots.active_count() > 0 || !self.pending.is_empty() {
            bail!("step budget must be set before submitting work");
        }
        self.step_budget = Some(budget);
        Ok(self)
    }

    /// The starvation guard: prompt tokens a budgeted step always reserves
    /// for prefill when any warming slot exists, even when the decode
    /// batch alone fills (or overflows) the budget.
    fn prefill_guard(budget: usize) -> usize {
        (budget / 4).max(1)
    }

    /// Restrict the paged admission budget to `blocks` pages (must not
    /// exceed the engine's physical pool). Call before submitting work —
    /// the page allocator is rebuilt. This is how a fixed KV-memory budget
    /// is imposed on an over-provisioned paged artifact (`serve
    /// --kv-blocks`, and the paged-vs-dense sweep in `benches/serving.rs`).
    pub fn with_kv_block_budget(mut self, blocks: usize) -> Result<Self> {
        let Some(bs) = self.engine.kv_block_size() else {
            bail!("--kv-blocks needs a paged engine");
        };
        if blocks == 0 || blocks > self.engine.kv_blocks() {
            bail!(
                "kv block budget {blocks} outside (0, {}] (engine pool size)",
                self.engine.kv_blocks()
            );
        }
        if self.slots.active_count() > 0 || !self.pending.is_empty() {
            bail!("kv block budget must be set before submitting work");
        }
        let mut slots = SlotMap::paged(self.engine.slots(), self.engine.max_seq(), blocks, bs);
        if self.slots.has_prefix_cache() {
            slots = slots.with_prefix_cache();
        }
        self.slots = slots;
        // The rebuilt SlotMap starts with an Off sink; re-attach ours so
        // `with_trace` composes with `with_kv_block_budget` in any order.
        self.slots.set_trace(self.trace.clone());
        Ok(self)
    }

    /// Enable refcounted copy-on-write prefix sharing (`serve
    /// --prefix-cache`): full prompt pages are donated to a
    /// content-addressed index as they fill, later requests map their
    /// longest cached prefix read-only at admission (admission then counts
    /// only the non-shared remainder against the page budget), and prefill
    /// starts at the first uncached position. Generated bytes are
    /// bit-identical with the cache on or off — sharing only removes
    /// recomputation. Paged engines only; call before submitting work.
    pub fn with_prefix_cache(mut self) -> Result<Self> {
        if !self.slots.is_paged() {
            bail!("--prefix-cache needs a paged engine");
        }
        if self.slots.active_count() > 0 || !self.pending.is_empty() {
            bail!("prefix cache must be enabled before submitting work");
        }
        if !self.slots.has_prefix_cache() {
            let slots = std::mem::replace(&mut self.slots, SlotMap::new(0, 0));
            self.slots = slots.with_prefix_cache();
        }
        Ok(self)
    }

    /// Pages a request needs end to end: its prompt plus its generation
    /// budget, capped at the cache's logical capacity (generation truncates
    /// there anyway).
    fn blocks_needed(&self, prompt_len: usize, max_new: usize) -> usize {
        // Invariant, not API-misuse: every caller gates on is_paged(),
        // and a paged SlotMap always owns a pool.
        let pool = self.slots.pool().expect("paged mode");
        pool.blocks_for((prompt_len + max_new).min(self.engine.max_seq()))
    }

    pub fn engine(&self) -> &E {
        &self.engine
    }

    pub fn engine_mut(&mut self) -> &mut E {
        &mut self.engine
    }

    /// KV storage width in bits of the underlying engine (16 = full
    /// precision). Scheduling decisions never depend on it — pages are
    /// counted in tokens, and `kv_memory_bytes` converts to bytes.
    pub fn kv_bits(&self) -> f32 {
        self.engine.kv_bits()
    }

    pub fn queue_depth(&self) -> usize {
        self.pending.len()
    }

    pub fn in_flight(&self) -> usize {
        self.slots.active_count()
    }

    pub fn slot_capacity(&self) -> usize {
        self.slots.capacity()
    }

    pub fn has_queue_capacity(&self) -> bool {
        self.pending.len() < self.max_queue
    }

    pub fn is_idle(&self) -> bool {
        self.pending.is_empty() && self.slots.active_count() == 0
    }

    /// Full bookkeeping audit (slot accounting, position bounds, pool
    /// `free + used == total`, exact page-refcount mirror). Cheap enough
    /// that the chaos property tests run it after every step; this is the
    /// check the error kernel's failure-atomicity guarantee is stated
    /// against.
    pub fn check_invariants(&self) -> Result<()> {
        self.slots.check_invariants()
    }

    /// Enqueue a request; fails with a backpressure error when the
    /// admission queue is full (callers should retry after draining).
    pub fn submit(&mut self, req: GenRequest) -> Result<u64> {
        if req.prompt.is_empty() {
            bail!("empty prompt");
        }
        if req.prompt.len() >= self.engine.max_seq() {
            bail!(
                "prompt of {} tokens cannot fit the {}-position KV cache",
                req.prompt.len(),
                self.engine.max_seq()
            );
        }
        // Computed once here, never re-derived per step: prompt and
        // max_new are immutable for the life of the request.
        let blocks_needed = if self.slots.is_paged() {
            let needed = self.blocks_needed(req.prompt.len(), req.max_new_tokens);
            // Invariant: is_paged() was checked one line up.
            let pool = self.slots.pool().expect("paged");
            if needed > pool.total_blocks() {
                bail!(
                    "request needs {needed} KV pages, the whole pool has {} \
                     (raise --kv-blocks or lower --max-new-tokens)",
                    pool.total_blocks()
                );
            }
            needed
        } else {
            0
        };
        if self.pending.len() >= self.max_queue {
            bail!(
                "admission queue full ({} pending, limit {}): backpressure",
                self.pending.len(),
                self.max_queue
            );
        }
        let id = self.next_id;
        self.next_id += 1;
        // One shared timestamp: the queued request's enqueue instant and
        // the Enqueued trace record agree exactly, so the timeline fold's
        // TTFT reproduces the metrics' to float rounding.
        let now = Instant::now();
        self.pending.push_back(Queued {
            id,
            prompt: req.prompt.iter().map(|&b| b as i32).collect(),
            max_new: req.max_new_tokens,
            sampler: req.sampler,
            seed: req.seed,
            submitted: now,
            blocks_needed,
            first_sched_us: None,
            faults: 0,
            not_before_step: 0,
            deadline: req.deadline,
            submit_step: self.step_index,
        });
        self.trace.emit_at(now, TraceEvent::Enqueued { id });
        Ok(id)
    }

    /// Phase of slot `b` in the composer's partition: `Cold` when free,
    /// `Warming` while it still owes prompt tokens, `Running` once it
    /// decodes one token per step.
    pub fn slot_phase(&self, b: usize) -> SlotPhase {
        match self.active.get(b).and_then(|s| s.as_ref()) {
            None => SlotPhase::Cold,
            Some(a) if a.fed < a.prompt.len() => SlotPhase::Warming,
            Some(_) => SlotPhase::Running,
        }
    }

    /// Snapshot of which slots are `Running` right now — taken at the top
    /// of a step, *before* paged growth can evict anyone, so stall
    /// accounting and the decode plan agree on one consistent view. A
    /// slot cooling down after a fault is excluded: it joins no engine
    /// call until its backoff expires.
    fn running_flags(&self) -> Vec<bool> {
        (0..self.active.len())
            .map(|b| {
                self.slot_phase(b) == SlotPhase::Running
                    && self.active[b].as_ref().is_some_and(|a| a.cooldown == 0)
            })
            .collect()
    }

    /// Cancel a request by id: drop it from the admission queue, or evict
    /// it mid-flight — its slot frees immediately and the next pending
    /// request joins the batch on the following step. Returns `false` if
    /// the id is unknown (already completed or never submitted).
    pub fn cancel(&mut self, id: u64) -> Result<bool> {
        if let Some(i) = self.pending.iter().position(|q| q.id == id) {
            self.pending.remove(i);
            return Ok(true);
        }
        for b in 0..self.active.len() {
            if self.active[b].as_ref().map(|a| a.id) == Some(id) {
                self.active[b] = None;
                self.slots.release(b)?;
                self.refresh_table_row(b);
                self.engine.reset_slot(b);
                self.trace.emit(TraceEvent::Evicted {
                    id,
                    slot: b,
                    reason: EvictReason::Cancelled,
                });
                return Ok(true);
            }
        }
        Ok(false)
    }

    /// Move pending requests into free slots (at most one per free slot).
    /// Paged mode additionally gates on the free-page token budget: the
    /// head request is admitted only if its *non-shared* page demand —
    /// `ceil((len + max_new)/bs)` minus the cached-prefix pages it maps —
    /// is claimable right now (a watermark, not a reservation: its first
    /// writable page is claimed here, the rest lazily), and admission
    /// stays FIFO: a too-big head blocks the queue rather than being
    /// jumped. With the prefix cache on, the head's longest cached prefix
    /// is mapped read-only into its block table and the scheduler will
    /// feed the prompt from the first uncached position.
    ///
    /// A head on fault backoff (`not_before_step` unmet) also blocks the
    /// queue. A classified fault from `adopt_prefix` rolls the admission
    /// back (slot released, prefix refcounts dropped, request requeued at
    /// the front with its fault charged) — or quarantines the request
    /// once the charge reaches the retry budget, which is why this
    /// returns failure [`Completion`]s.
    fn admit(&mut self) -> Result<Vec<Completion>> {
        let mut failed = Vec::new();
        while !self.pending.is_empty() && self.slots.free_count() > 0 {
            if self.pending.front().expect("non-empty").not_before_step > self.step_index {
                break;
            }
            let (slot, cached) = if self.slots.is_paged() {
                let head = self.pending.front().expect("non-empty");
                let Some(admitted) =
                    self.slots.admit_paged(head.id, &head.prompt, head.blocks_needed)?
                else {
                    break;
                };
                admitted
            } else {
                let head = self.pending.front().expect("non-empty");
                // Invariant: free_count() > 0 was checked by the loop
                // condition, so a free slot must exist.
                (self.slots.allocate(head.id).expect("free slot"), 0)
            };
            let q = self.pending.pop_front().expect("non-empty");
            self.refresh_table_row(slot);
            self.engine.reset_slot(slot);
            if cached > 0 {
                if let Err(err) = self.engine.adopt_prefix(slot, &self.tables[slot], cached) {
                    self.admission_fault(err, slot, q, &mut failed)?;
                    continue;
                }
            }
            self.metrics.record_admission(cached, q.prompt.len());
            if self.trace.is_on() {
                // Pages actually charged against the budget: end-to-end
                // demand minus the whole pages the prefix cache mapped.
                let pages_charged = match self.engine.kv_block_size() {
                    Some(bs) => q.blocks_needed - cached / bs,
                    None => 0,
                };
                self.trace.emit(TraceEvent::Admitted {
                    id: q.id,
                    slot,
                    pages_charged,
                    tokens_reused: cached,
                });
                if cached > 0 {
                    // Invariant: a nonzero cached prefix only exists in
                    // paged mode (the prefix cache requires it).
                    let bs = self.engine.kv_block_size().expect("cached prefix implies paged");
                    self.trace.emit(TraceEvent::PrefixHit {
                        id: q.id,
                        slot,
                        pages: cached / bs,
                    });
                }
            }
            self.active[slot] = Some(Active {
                id: q.id,
                prompt: q.prompt,
                fed: cached,
                generated: Vec::new(),
                max_new: q.max_new,
                sampler: q.sampler,
                seed: q.seed,
                rng: Prng::new(q.seed),
                last_token: 0,
                submitted: q.submitted,
                ttft_us: None,
                first_sched_us: q.first_sched_us,
                stall_steps: 0,
                wait_us: 0.0,
                blocks_needed: q.blocks_needed,
                faults: q.faults,
                cooldown: 0,
                recovering: false,
                deadline: q.deadline,
                submit_step: q.submit_step,
            });
        }
        Ok(failed)
    }

    /// Roll back an admission whose `adopt_prefix` call failed: the call
    /// advanced nothing (engines validate before touching state), so
    /// releasing the slot — which drops the watermark page and the mapped
    /// prefix refcounts — restores the exact pre-admission accounting.
    /// The request is requeued at the front with the fault charged, or
    /// quarantined once its charge reaches the retry budget.
    fn admission_fault(
        &mut self,
        err: anyhow::Error,
        slot: usize,
        q: Queued,
        failed: &mut Vec<Completion>,
    ) -> Result<()> {
        let serr = match err.downcast::<ServeError>() {
            Ok(e) => e,
            // Unclassified: a real engine bug — keep the abort behavior.
            Err(e) => return Err(e),
        };
        if let ServeError::Fatal { what } = serr {
            bail!("fatal engine fault during admission: {what}");
        }
        self.slots.release(slot)?;
        self.refresh_table_row(slot);
        self.engine.reset_slot(slot);
        match serr {
            ServeError::Slot { .. } => {
                self.metrics.record_slot_fault();
                self.trace.emit(TraceEvent::FaultInjected { slot: Some(slot) });
            }
            _ => {
                self.metrics.record_step_fault();
                self.trace.emit(TraceEvent::FaultInjected { slot: None });
            }
        }
        let attempt = q.faults + 1;
        if attempt >= self.retry_budget {
            self.metrics.record_quarantine();
            self.trace.emit(TraceEvent::RequestFailed {
                id: q.id,
                slot: Some(slot),
                faults: attempt,
            });
            failed.push(Completion {
                id: q.id,
                prompt: q.prompt.iter().map(|&t| t as u8).collect(),
                completion: Vec::new(),
                ttft_ms: None,
                latency_ms: q.submitted.elapsed().as_secs_f64() * 1e3,
                reason: FinishReason::Quarantined,
            });
        } else {
            let backoff = Self::backoff(attempt);
            self.metrics.record_retry();
            self.trace.emit(TraceEvent::RetryScheduled {
                slot: Some(slot),
                backoff_steps: backoff as usize,
                attempt,
            });
            self.pending.push_front(Queued {
                faults: attempt,
                not_before_step: self.step_index + backoff,
                ..q
            });
        }
        Ok(())
    }

    /// Evict the youngest (largest-id) in-flight request back to the queue
    /// *front*: its pages and slot free immediately, its generated tokens
    /// are discarded, and on re-admission it restarts from scratch — with
    /// the same id, the same enqueue timestamp (so TTFT keeps the full
    /// wait) and the same seed (so the completion is identical).
    fn evict_youngest(&mut self) -> Result<usize> {
        let victim = (0..self.active.len())
            .filter(|&b| self.active[b].is_some())
            .max_by_key(|&b| self.active[b].as_ref().expect("occupied").id)
            .ok_or_else(|| anyhow!("pool exhausted with no in-flight request to evict"))?;
        let a = self.active[victim].take().expect("occupied");
        self.slots.release(victim)?;
        self.refresh_table_row(victim);
        self.engine.reset_slot(victim);
        self.metrics.record_eviction();
        self.trace.emit(TraceEvent::Evicted {
            id: a.id,
            slot: victim,
            reason: EvictReason::PoolExhausted,
        });
        // Queue-front requeue keeps FIFO fairness (it was admitted before
        // anything still queued); this may transiently exceed `max_queue`,
        // which beats dropping the request on the floor. With the prefix
        // cache on, the pages it donated before eviction stay resident, so
        // the restart usually prefills only the uncached tail.
        self.pending.push_front(Queued {
            id: a.id,
            prompt: a.prompt,
            max_new: a.max_new,
            sampler: a.sampler,
            seed: a.seed,
            submitted: a.submitted,
            blocks_needed: a.blocks_needed,
            first_sched_us: a.first_sched_us,
            faults: a.faults,
            not_before_step: 0,
            deadline: a.deadline,
            submit_step: a.submit_step,
        });
        Ok(victim)
    }

    /// Evict slot `b` to the queue front because a step-wide fault streak
    /// exhausted the retry budget: same warm-restart path as pool
    /// eviction (the request restarts through its donated prefix pages,
    /// byte-identically), but tagged [`EvictReason::Fault`] and counted
    /// separately. The request keeps its individual fault charge and is
    /// re-admissible immediately — the *streak* was the engine's fault,
    /// not this request's.
    fn evict_for_fault(&mut self, b: usize) -> Result<()> {
        // Invariant: callers only pass occupied participant slots.
        let a = self.active[b].take().expect("fault-evicting an occupied slot");
        self.slots.release(b)?;
        self.refresh_table_row(b);
        self.engine.reset_slot(b);
        self.metrics.record_fault_eviction();
        self.trace.emit(TraceEvent::Evicted { id: a.id, slot: b, reason: EvictReason::Fault });
        self.pending.push_front(Queued {
            id: a.id,
            prompt: a.prompt,
            max_new: a.max_new,
            sampler: a.sampler,
            seed: a.seed,
            submitted: a.submitted,
            blocks_needed: a.blocks_needed,
            first_sched_us: a.first_sched_us,
            faults: a.faults,
            not_before_step: 0,
            deadline: a.deadline,
            submit_step: a.submit_step,
        });
        Ok(())
    }

    /// Grow slot `b`'s block table to cover `[0, target)`, evicting the
    /// youngest request (possibly `b` itself) while the pool is dry.
    /// Returns `false` when `b` was evicted in the process.
    fn grow_or_evict(&mut self, b: usize, target: usize) -> Result<bool> {
        loop {
            if self.active[b].is_none() {
                return Ok(false);
            }
            if self.slots.ensure_capacity(b, target)? {
                self.refresh_table_row(b);
                return Ok(true);
            }
            // Every in-flight request holds >= 1 page, so each eviction
            // makes progress; if `b` is the youngest it evicts itself.
            self.evict_youngest()?;
        }
    }

    /// Pre-step page growth for every occupied slot about to advance one
    /// token (the chunk-1 interleaved path included). Cooling slots are
    /// skipped — they join no call this step, so growing their tables
    /// now would be pure speculation the oracle would have to mirror.
    fn grow_for_decode(&mut self) -> Result<()> {
        for b in 0..self.active.len() {
            if self.active[b].as_ref().is_some_and(|a| a.cooldown == 0) {
                let target = self.slots.pos(b).expect("occupied") + 1;
                self.grow_or_evict(b, target)?;
            }
        }
        Ok(())
    }

    /// Pre-call page growth for every slot about to prefill a chunk
    /// (cooling slots excluded, as in `grow_for_decode`).
    fn grow_for_prefill(&mut self, chunk: usize) -> Result<()> {
        for b in 0..self.active.len() {
            let take = match &self.active[b] {
                Some(a) if a.cooldown == 0 && a.fed < a.prompt.len() => {
                    chunk.min(a.prompt.len() - a.fed)
                }
                _ => continue,
            };
            let target = self.slots.pos(b).expect("occupied") + take;
            self.grow_or_evict(b, target)?;
        }
        Ok(())
    }

    /// Rewrite slot `b`'s cached padded table row from the SlotMap's truth:
    /// allocated pages first, then the out-of-range sentinel — writes
    /// through unallocated or inactive entries are dropped by the graph, so
    /// a lane can never scribble on someone else's pages. Called whenever
    /// the slot's table changes (admission, growth, release); the decode /
    /// prefill hot path just hands `self.tables` to the engine.
    fn refresh_table_row(&mut self, b: usize) {
        if !self.slots.is_paged() {
            return;
        }
        let sentinel = self.engine.kv_blocks() as i32;
        let table = self.slots.table(b);
        for (j, e) in self.tables[b].iter_mut().enumerate() {
            *e = table.get(j).map(|&x| x as i32).unwrap_or(sentinel);
        }
    }

    /// Shared post-engine bookkeeping for one occupied slot: once its
    /// prompt is fully fed, sample the next token from `logits` (respecting
    /// the budget and stamping TTFT exactly once, from enqueue), then
    /// decide whether the request is finished — budget exhausted or KV
    /// cache full. Both the prefill and decode passes end in this exact
    /// logic, so stop semantics can never diverge between them.
    fn sample_and_check(
        &mut self,
        b: usize,
        logits: &[f32],
        new_pos: usize,
        max_seq: usize,
        new_tokens: &mut usize,
        stall: Option<usize>,
    ) -> bool {
        let a = self.active[b].as_mut().expect("occupied slot");
        let mut finished = false;
        if a.fed >= a.prompt.len() {
            // This call's logits predict the request's next token.
            if a.generated.len() < a.max_new {
                let sampler = a.sampler;
                let next = sampler.sample(logits, &mut a.rng);
                a.last_token = next as i32;
                a.generated.push(next as u8);
                *new_tokens += 1;
                // The TTFT stamp and the TokenDecoded record share one
                // Instant, so the trace-fold's TTFT matches the metrics'
                // exactly; with tracing off this block runs (and reads the
                // clock) only for the first token, as before.
                if a.ttft_us.is_none() || self.trace.is_on() {
                    let now = Instant::now();
                    if a.ttft_us.is_none() {
                        a.ttft_us = Some(
                            now.saturating_duration_since(a.submitted).as_secs_f64() * 1e6,
                        );
                    }
                    self.trace.emit_at(
                        now,
                        TraceEvent::TokenDecoded { id: a.id, slot: b, stall_steps: stall },
                    );
                }
            }
            if a.generated.len() >= a.max_new {
                finished = true;
            }
        }
        // Out of cache: stop whatever state we're in (possibly with a
        // truncated completion).
        finished || new_pos >= max_seq
    }

    /// Retire slot `b`: free it and convert its state into a [`Completion`].
    fn retire(&mut self, b: usize) -> Result<Completion> {
        let a = self.active[b].take().expect("retiring an occupied slot");
        self.slots.release(b)?;
        self.refresh_table_row(b);
        self.engine.reset_slot(b);
        let request_us = a.submitted.elapsed().as_secs_f64() * 1e6;
        self.metrics.record_completion(request_us, a.ttft_us);
        // TTFT's two halves, recorded exactly once per completed request
        // (here, not at first-token time: an eviction restart re-stamps
        // TTFT, and recording eagerly would double-count the pair). The
        // same clock stamps both, so queue + spread == ttft exactly; for
        // an evicted request the spread spans its restart — first_sched
        // keeps the first *ever* scheduling, which is the point of the
        // split.
        if let Some(ttft) = a.ttft_us {
            let queue = a.first_sched_us.unwrap_or(ttft).min(ttft);
            self.metrics.record_first_token(queue, ttft - queue);
        }
        let reason = if a.generated.len() >= a.max_new {
            FinishReason::BudgetExhausted
        } else {
            FinishReason::CacheFull
        };
        self.trace.emit(TraceEvent::Completed { id: a.id, slot: b, reason });
        Ok(Completion {
            id: a.id,
            prompt: a.prompt.iter().map(|&t| t as u8).collect(),
            completion: a.generated,
            ttft_ms: a.ttft_us.map(|us| us / 1e3),
            latency_ms: request_us / 1e3,
            reason,
        })
    }

    /// Retire slot `b` as a *failure* (quarantine or deadline expiry):
    /// free the slot exactly like [`Self::retire`], but record no
    /// completion metrics and emit no `Completed` event — the
    /// trace-vs-metrics cross-check counts successes only, and failures
    /// have their own counters (the caller emits the matching
    /// `RequestFailed`/`DeadlineExpired` event and failure metric).
    fn retire_failed(&mut self, b: usize, reason: FinishReason) -> Result<Completion> {
        // Invariant: callers only retire occupied slots.
        let a = self.active[b].take().expect("retiring an occupied slot");
        self.slots.release(b)?;
        self.refresh_table_row(b);
        self.engine.reset_slot(b);
        Ok(Completion {
            id: a.id,
            prompt: a.prompt.iter().map(|&t| t as u8).collect(),
            completion: a.generated,
            ttft_ms: a.ttft_us.map(|us| us / 1e3),
            latency_ms: a.submitted.elapsed().as_secs_f64() * 1e3,
            reason,
        })
    }

    /// The error kernel: classify a failed engine call and apply the
    /// recovery policy. `participants[b]` marks every slot the failed
    /// call would have advanced — none of it happened (engines validate
    /// and fail before touching state, see the [`DecodeEngine`] contract),
    /// so *not* advancing the bookkeeping is the complete rollback and
    /// pool/slot/prefix accounting is untouched.
    ///
    /// * `ServeError::Slot` — charge the blamed request; quarantine it at
    ///   `retry_budget` faults, otherwise put it on step-counted backoff.
    /// * `ServeError::Transient` — step-wide: pause the engine on the
    ///   streak's backoff; at `retry_budget` consecutive step-wide faults
    ///   evict the participants to the queue front (warm restart).
    /// * `ServeError::Fatal` / unclassified — propagate: a real engine
    ///   bug keeps the old abort-the-serve-loop behavior.
    fn handle_fault(
        &mut self,
        err: anyhow::Error,
        participants: &[bool],
        done: &mut Vec<Completion>,
    ) -> Result<()> {
        let serr = match err.downcast::<ServeError>() {
            Ok(e) => e,
            Err(e) => return Err(e),
        };
        match serr {
            ServeError::Fatal { what } => bail!("fatal engine fault: {what}"),
            ServeError::Slot { slot, .. } => {
                if slot >= self.active.len() || self.active[slot].is_none() {
                    // API misuse by the engine, surfaced as an error
                    // rather than the panic an unchecked index would be.
                    bail!("engine blamed slot {slot}, which is not occupied");
                }
                self.metrics.record_slot_fault();
                self.trace.emit(TraceEvent::FaultInjected { slot: Some(slot) });
                let a = self.active[slot].as_mut().expect("checked above");
                a.faults += 1;
                let attempt = a.faults;
                let id = a.id;
                if attempt >= self.retry_budget {
                    self.metrics.record_quarantine();
                    self.trace.emit(TraceEvent::RequestFailed {
                        id,
                        slot: Some(slot),
                        faults: attempt,
                    });
                    done.push(self.retire_failed(slot, FinishReason::Quarantined)?);
                } else {
                    let backoff = Self::backoff(attempt);
                    let a = self.active[slot].as_mut().expect("checked above");
                    a.cooldown = backoff;
                    a.recovering = true;
                    self.metrics.record_retry();
                    self.trace.emit(TraceEvent::RetryScheduled {
                        slot: Some(slot),
                        backoff_steps: backoff as usize,
                        attempt,
                    });
                }
            }
            ServeError::Transient { .. } => {
                self.metrics.record_step_fault();
                self.trace.emit(TraceEvent::FaultInjected { slot: None });
                self.step_fault_streak += 1;
                let attempt = self.step_fault_streak;
                if attempt >= self.retry_budget {
                    self.step_fault_streak = 0;
                    // Descending slot order: each push_front leaves the
                    // queue in ascending slot order, so re-admission
                    // refills the slots deterministically.
                    for b in (0..participants.len()).rev() {
                        if participants[b] && self.active[b].is_some() {
                            self.evict_for_fault(b)?;
                        }
                    }
                } else {
                    let backoff = Self::backoff(attempt);
                    self.pause_until = self.step_index + 1 + backoff;
                    for b in 0..participants.len() {
                        if participants[b] {
                            if let Some(a) = self.active[b].as_mut() {
                                a.recovering = true;
                            }
                        }
                    }
                    self.metrics.record_retry();
                    self.trace.emit(TraceEvent::RetryScheduled {
                        slot: None,
                        backoff_steps: backoff as usize,
                        attempt,
                    });
                }
            }
        }
        Ok(())
    }

    /// Post-success bookkeeping for one engine call: the step-wide fault
    /// streak resets, and every participant that was waiting out a
    /// retry emits `SlotRecovered` (ascending slot order).
    fn note_engine_success(&mut self, participants: &[bool]) {
        self.step_fault_streak = 0;
        for b in 0..participants.len() {
            if !participants[b] {
                continue;
            }
            if let Some(a) = self.active[b].as_mut() {
                if a.recovering {
                    a.recovering = false;
                    let id = a.id;
                    self.metrics.record_recovery();
                    self.trace.emit(TraceEvent::SlotRecovered { id, slot: b });
                }
            }
        }
    }

    /// Has this request's deadline passed? Step deadlines count whole
    /// scheduler steps since enqueue (deterministic); wall deadlines use
    /// the real clock.
    fn expired(&self, deadline: Option<Deadline>, submitted: Instant, submit_step: u64) -> bool {
        match deadline {
            None => false,
            Some(Deadline::WallMs(ms)) => submitted.elapsed().as_secs_f64() * 1e3 >= ms,
            Some(Deadline::Steps(k)) => self.step_index.saturating_sub(submit_step) >= k,
        }
    }

    /// Shed every expired request — queued first (admission-time
    /// shedding), then mid-flight — each with a failure [`Completion`]
    /// carrying [`FinishReason::DeadlineExpired`]. Runs at the top of
    /// every step, pause or not: a deadline must fire even while the
    /// engine is backing off.
    fn shed_expired(&mut self) -> Result<Vec<Completion>> {
        let mut done = Vec::new();
        let mut i = 0;
        while i < self.pending.len() {
            let q = &self.pending[i];
            let (deadline, submitted, submit_step) = (q.deadline, q.submitted, q.submit_step);
            if self.expired(deadline, submitted, submit_step) {
                let q = self.pending.remove(i).expect("index in range");
                self.metrics.record_deadline_shed_queued();
                self.trace.emit(TraceEvent::DeadlineExpired { id: q.id, queued: true });
                done.push(Completion {
                    id: q.id,
                    prompt: q.prompt.iter().map(|&t| t as u8).collect(),
                    completion: Vec::new(),
                    ttft_ms: None,
                    latency_ms: q.submitted.elapsed().as_secs_f64() * 1e3,
                    reason: FinishReason::DeadlineExpired,
                });
            } else {
                i += 1;
            }
        }
        for b in 0..self.active.len() {
            let expired = match self.active[b].as_ref() {
                Some(a) => self.expired(a.deadline, a.submitted, a.submit_step),
                None => false,
            };
            if expired {
                let id = self.active[b].as_ref().expect("checked above").id;
                self.metrics.record_deadline_shed_inflight();
                self.trace.emit(TraceEvent::DeadlineExpired { id, queued: false });
                done.push(self.retire_failed(b, FinishReason::DeadlineExpired)?);
            }
        }
        Ok(done)
    }

    /// One scheduler iteration: tick the step clock (cooldowns, pause,
    /// deadlines — recovery time is counted in steps, never wall clock),
    /// shed expired requests, then admit and — with a step budget — one
    /// composed decode-priority step, or — without one — either a batched
    /// prefill call (when the engine has a multi-token prefill graph and
    /// any non-cooling slot still owes prompt tokens) or a decode step,
    /// exactly as before. Returns the completions — successes *and*
    /// failures, see [`Completion::reason`] — that finished on this
    /// iteration (empty when idle).
    pub fn step(&mut self) -> Result<Vec<Completion>> {
        self.trace.begin_step();
        self.step_index += 1;
        for a in self.active.iter_mut().flatten() {
            if a.cooldown > 0 {
                a.cooldown -= 1;
            }
        }
        let mut done = self.shed_expired()?;
        if self.step_index < self.pause_until {
            // Step-wide backoff: the engine is left alone this step.
            return Ok(done);
        }
        done.extend(self.admit()?);
        let chunk = self.engine.prefill_chunk().max(1);
        // Running-slot snapshot for the plan partition and the stall
        // accounting, taken before growth can evict anyone.
        let running = self.running_flags();
        if let Some(budget) = self.step_budget {
            done.extend(self.composed_step(budget, chunk, &running)?);
            return Ok(done);
        }
        // A cooling slot owes nothing *this* step — routing must agree
        // with the passes' participation rules or a pass could build an
        // engine call with no active lane.
        let owes_prompt = |s: &Option<Active>| {
            s.as_ref().map_or(false, |a| a.cooldown == 0 && a.fed < a.prompt.len())
        };
        if chunk > 1 && self.active.iter().any(owes_prompt) {
            if self.slots.is_paged() {
                self.grow_for_prefill(chunk)?;
                // Growth can evict every prefilling slot (they are the
                // youngest by construction); skip the engine call — the
                // next iteration re-admits and carries on. (No engine call
                // ran, so decode-stall counters don't tick either.)
                if !self.active.iter().any(owes_prompt) {
                    return Ok(done);
                }
            }
            done.extend(self.prefill_pass(chunk, &running)?);
            return Ok(done);
        }
        if self.slots.is_paged() {
            self.grow_for_decode()?;
        }
        done.extend(self.decode_pass(&running)?);
        Ok(done)
    }

    /// One composed decode-priority iteration (see the module docs): plan
    /// the decode set and budgeted prefill takes from the phase partition,
    /// grow pages (decode slots first), then execute the decode call
    /// followed by at most one prefill call. Running slots therefore
    /// produce a token *every* iteration they survive — the decode stall
    /// the budget-off path suffers during a prefill burst is structurally
    /// zero here.
    fn composed_step(
        &mut self,
        budget: usize,
        chunk: usize,
        running: &[bool],
    ) -> Result<Vec<Completion>> {
        let n = self.engine.slots();
        let max_seq = self.engine.max_seq();
        // -- plan ----------------------------------------------------------
        let decode_tokens = running.iter().filter(|&&r| r).count();
        // Cooling slots sit the step out entirely: not in the decode set
        // (running_flags excluded them) and not prefill candidates.
        let warming = |s: &Option<Active>| {
            s.as_ref().map_or(false, |a| a.cooldown == 0 && a.fed < a.prompt.len())
        };
        let mut prefill_left = if self.active.iter().any(warming) {
            budget.saturating_sub(decode_tokens).max(Self::prefill_guard(budget))
        } else {
            0
        };
        let mut takes = vec![0usize; n];
        for b in 0..n {
            if prefill_left == 0 {
                break;
            }
            if let Some(a) = &self.active[b] {
                if a.cooldown == 0 && a.fed < a.prompt.len() {
                    let take = chunk.min(a.prompt.len() - a.fed).min(prefill_left);
                    takes[b] = take;
                    prefill_left -= take;
                }
            }
        }
        // The plan is fixed here; record it before growth can shrink the
        // surviving set (the trace shows what was *scheduled*, engine-call
        // events show what survived).
        let planned_take: usize = takes.iter().sum();
        if decode_tokens + planned_take > 0 {
            self.trace.emit(TraceEvent::StepComposed {
                decode_lanes: decode_tokens,
                prefill_take: planned_take,
                budget,
            });
        }
        // -- grow (paged): decode slots first, then the planned takes.
        // An eviction mid-growth silently drops its slot from the plan;
        // the freed budget is not redistributed (the plan is fixed once).
        if self.slots.is_paged() {
            for b in 0..n {
                if running[b] && self.active[b].is_some() {
                    let target = self.slots.pos(b).expect("occupied") + 1;
                    self.grow_or_evict(b, target)?;
                }
            }
            for b in 0..n {
                if takes[b] > 0 && self.active[b].is_some() {
                    let target = self.slots.pos(b).expect("occupied") + takes[b];
                    self.grow_or_evict(b, target)?;
                }
            }
        }
        let mut done = Vec::new();
        let mut decode_fed = 0usize;
        let mut prompt_fed = 0usize;
        let mut ran_decode = false;
        let mut ran_prefill = false;
        // -- decode call over the surviving decode set ---------------------
        let mut tokens = vec![0i32; n];
        let mut pos = vec![0i32; n];
        let mut active = vec![false; n];
        let mut any = false;
        for b in 0..n {
            let Some(a) = self.active[b].as_mut() else { continue };
            if running[b] {
                any = true;
                active[b] = true;
                tokens[b] = a.last_token;
                pos[b] = self.slots.pos(b).expect("occupied slot has a position") as i32;
                if a.first_sched_us.is_none() {
                    a.first_sched_us = Some(a.submitted.elapsed().as_secs_f64() * 1e6);
                }
            } else {
                // Warming lane idling through the decode call. The PJRT
                // decode graphs write a placeholder token at `pos[b]` for
                // every lane, active or not (only the prefill graphs drop
                // writes via n_valid) — so aim the placeholder at the
                // slot's own *next* position: still unwritten, never
                // attended before the prefill chunk overwrites it, and
                // (paged) inside the slot's own pages or dropped by the
                // table sentinel. Leaving pos 0 here would clobber the
                // warming prompt's first KV entry.
                pos[b] = self.slots.pos(b).expect("occupied slot has a position") as i32;
            }
        }
        if any {
            let t0 = Instant::now();
            let call = if self.slots.is_paged() {
                self.engine.step_paged(&tokens, &pos, &active, &self.tables)
            } else {
                self.engine.step(&tokens, &pos, &active)
            };
            let logits = match call {
                Ok(l) => l,
                Err(err) => {
                    // Nothing advanced; the planned prefill half is
                    // abandoned with the rest of the step.
                    self.handle_fault(err, &active, &mut done)?;
                    return Ok(done);
                }
            };
            if logits.len() != n {
                // Reachable under engine API misuse — an error, not the
                // panic an unchecked logits[b] index would become.
                bail!("engine returned {} logit rows for {n} slots", logits.len());
            }
            let step_us = t0.elapsed().as_secs_f64() * 1e6;
            self.note_engine_success(&active);
            ran_decode = true;
            let mut new_tokens = 0usize;
            for b in 0..n {
                if !active[b] || self.active[b].is_none() {
                    continue;
                }
                let new_pos = self.slots.advance(b)?;
                decode_fed += 1;
                // Every lane in the decode set is Running: its stall count
                // rides on the TokenDecoded record (read before the reset
                // below zeroes it).
                let stall = self.active[b].as_ref().map(|a| a.stall_steps);
                let finished = self
                    .sample_and_check(b, &logits[b], new_pos, max_seq, &mut new_tokens, stall);
                {
                    // Every surviving running slot sampled: record how long
                    // it waited for this token, then reset.
                    let a = self.active[b].as_mut().expect("occupied");
                    let stall = a.stall_steps;
                    let wait = a.wait_us + step_us;
                    a.stall_steps = 0;
                    a.wait_us = 0.0;
                    self.metrics.record_decode_token_wait(stall, wait);
                }
                if finished {
                    done.push(self.retire(b)?);
                }
            }
            self.metrics.record_step(
                step_us,
                new_tokens,
                self.slots.active_count(),
                self.pending.len(),
            );
        }
        // -- prefill call over the surviving planned takes -----------------
        let mut ptokens: Vec<Vec<i32>> = vec![Vec::new(); n];
        let mut pos0 = vec![0i32; n];
        let mut pactive = vec![false; n];
        let mut any_p = false;
        for b in 0..n {
            if takes[b] == 0 {
                continue;
            }
            if let Some(a) = self.active[b].as_mut() {
                any_p = true;
                pactive[b] = true;
                ptokens[b] = a.prompt[a.fed..a.fed + takes[b]].to_vec();
                pos0[b] = self.slots.pos(b).expect("occupied slot has a position") as i32;
                if a.first_sched_us.is_none() || self.trace.is_on() {
                    let now = Instant::now();
                    if a.first_sched_us.is_none() {
                        a.first_sched_us = Some(
                            now.saturating_duration_since(a.submitted).as_secs_f64() * 1e6,
                        );
                    }
                    self.trace.emit_at(
                        now,
                        TraceEvent::PrefillChunk {
                            id: a.id,
                            slot: b,
                            pos0: pos0[b] as usize,
                            take: takes[b],
                        },
                    );
                }
            }
        }
        if any_p {
            let t0 = Instant::now();
            let call = if self.slots.is_paged() {
                self.engine.prefill_paged(&ptokens, &pos0, &pactive, &self.tables)
            } else {
                self.engine.prefill(&ptokens, &pos0, &pactive)
            };
            let logits = match call {
                Ok(l) => l,
                Err(err) => {
                    // The decode half already ran and retired; keep its
                    // completions — only the prefill half is abandoned.
                    self.handle_fault(err, &pactive, &mut done)?;
                    return Ok(done);
                }
            };
            if logits.len() != n {
                bail!("engine returned {} logit rows for {n} slots", logits.len());
            }
            let prefill_us = t0.elapsed().as_secs_f64() * 1e6;
            self.note_engine_success(&pactive);
            ran_prefill = true;
            let mut new_tokens = 0usize;
            for b in 0..n {
                if !pactive[b] || self.active[b].is_none() {
                    continue;
                }
                let fed_now = ptokens[b].len();
                let new_pos = self.slots.advance_by(b, fed_now)?;
                self.active[b].as_mut().expect("active slot").fed += fed_now;
                prompt_fed += fed_now;
                if self.sample_and_check(b, &logits[b], new_pos, max_seq, &mut new_tokens, None)
                {
                    done.push(self.retire(b)?);
                }
            }
            self.metrics.record_prefill(
                prefill_us,
                prompt_fed,
                new_tokens,
                self.slots.active_count(),
                self.pending.len(),
            );
            // The prefill half of a mixed step counts toward the *next*
            // token's inter-token wait of every still-running slot (its
            // token this iteration was stamped before this call ran).
            for b in 0..n {
                if running[b] {
                    if let Some(a) = self.active[b].as_mut() {
                        a.wait_us += prefill_us;
                    }
                }
            }
        }
        if ran_decode || ran_prefill {
            self.metrics.record_token_mix(prompt_fed, decode_fed);
            self.emit_counters(prompt_fed, decode_fed);
        }
        if ran_decode && ran_prefill {
            self.metrics.record_mixed_step();
        }
        Ok(done)
    }

    /// One batched prefill call over every slot that still owes prompt
    /// tokens (decode-phase slots idle for this call). The chunk that
    /// completes a slot's prompt yields the logits predicting its first
    /// token, which is sampled right here — TTFT is set at the end of the
    /// last prefill chunk, `ceil(len/chunk)` engine calls after admission.
    fn prefill_pass(&mut self, chunk: usize, running: &[bool]) -> Result<Vec<Completion>> {
        let n = self.engine.slots();
        let max_seq = self.engine.max_seq();
        let mut tokens: Vec<Vec<i32>> = vec![Vec::new(); n];
        let mut pos0 = vec![0i32; n];
        let mut active = vec![false; n];
        for b in 0..n {
            if let Some(a) = self.active[b].as_mut() {
                if a.cooldown == 0 && a.fed < a.prompt.len() {
                    let take = chunk.min(a.prompt.len() - a.fed);
                    tokens[b] = a.prompt[a.fed..a.fed + take].to_vec();
                    pos0[b] = self.slots.pos(b).expect("occupied slot has a position") as i32;
                    active[b] = true;
                    if a.first_sched_us.is_none() || self.trace.is_on() {
                        let now = Instant::now();
                        if a.first_sched_us.is_none() {
                            a.first_sched_us = Some(
                                now.saturating_duration_since(a.submitted).as_secs_f64() * 1e6,
                            );
                        }
                        self.trace.emit_at(
                            now,
                            TraceEvent::PrefillChunk {
                                id: a.id,
                                slot: b,
                                pos0: pos0[b] as usize,
                                take,
                            },
                        );
                    }
                }
            }
        }

        let t0 = Instant::now();
        let call = if self.slots.is_paged() {
            self.engine.prefill_paged(&tokens, &pos0, &active, &self.tables)
        } else {
            self.engine.prefill(&tokens, &pos0, &active)
        };
        let logits = match call {
            Ok(l) => l,
            Err(err) => {
                let mut failed = Vec::new();
                self.handle_fault(err, &active, &mut failed)?;
                return Ok(failed);
            }
        };
        if logits.len() != n {
            bail!("engine returned {} logit rows for {n} slots", logits.len());
        }
        let step_us = t0.elapsed().as_secs_f64() * 1e6;
        self.note_engine_success(&active);

        let mut prompt_tokens = 0usize;
        let mut new_tokens = 0usize;
        let mut done = Vec::new();
        for b in 0..n {
            if !active[b] {
                continue;
            }
            let fed_now = tokens[b].len();
            let new_pos = self.slots.advance_by(b, fed_now)?;
            self.active[b].as_mut().expect("active slot").fed += fed_now;
            prompt_tokens += fed_now;
            // (new_pos >= max_seq is unreachable while submit() rejects
            // prompts >= max_seq, but sample_and_check keeps the guard so a
            // future admission policy can't silently overrun.)
            if self.sample_and_check(b, &logits[b], new_pos, max_seq, &mut new_tokens, None) {
                done.push(self.retire(b)?);
            }
        }
        // Running slots idled through this call — the decode hiccup this
        // class of pass causes is exactly what the stall histogram (and
        // the step composer) is about.
        for b in 0..n {
            if running[b] {
                if let Some(a) = self.active[b].as_mut() {
                    a.stall_steps += 1;
                    a.wait_us += step_us;
                }
            }
        }
        self.metrics.record_prefill(
            step_us,
            prompt_tokens,
            new_tokens,
            self.slots.active_count(),
            self.pending.len(),
        );
        self.metrics.record_token_mix(prompt_tokens, 0);
        self.emit_counters(prompt_tokens, 0);
        Ok(done)
    }

    /// One decode step over every occupied slot. With `prefill_chunk() == 1`
    /// this also feeds prompts one token at a time (prefilling and decoding
    /// slots batched together), preserving the original interleaved path.
    fn decode_pass(&mut self, running: &[bool]) -> Result<Vec<Completion>> {
        let n = self.engine.slots();
        let max_seq = self.engine.max_seq();
        let mut tokens = vec![0i32; n];
        let mut pos = vec![0i32; n];
        let mut active = vec![false; n];
        let mut any = false;
        let mut prompt_fed = 0usize;
        let mut decode_fed = 0usize;
        for b in 0..n {
            if let Some(a) = self.active[b].as_mut() {
                if a.cooldown > 0 {
                    // Cooling after a fault: joins no call — but the
                    // decode graphs write a placeholder token at pos[b]
                    // for every lane, active or not, so aim it at the
                    // slot's own next (unwritten) position exactly like
                    // the composer does for its idle lanes.
                    pos[b] = self.slots.pos(b).expect("occupied slot has a position") as i32;
                    continue;
                }
                any = true;
                active[b] = true;
                let warming = a.fed < a.prompt.len();
                if warming {
                    tokens[b] = a.prompt[a.fed];
                    prompt_fed += 1;
                } else {
                    tokens[b] = a.last_token;
                    decode_fed += 1;
                }
                pos[b] = self.slots.pos(b).expect("occupied slot has a position") as i32;
                // A warming lane on the interleaved path feeds one prompt
                // token per call — a PrefillChunk of take 1.
                if a.first_sched_us.is_none() || (warming && self.trace.is_on()) {
                    let now = Instant::now();
                    if a.first_sched_us.is_none() {
                        a.first_sched_us = Some(
                            now.saturating_duration_since(a.submitted).as_secs_f64() * 1e6,
                        );
                    }
                    if warming {
                        self.trace.emit_at(
                            now,
                            TraceEvent::PrefillChunk {
                                id: a.id,
                                slot: b,
                                pos0: pos[b] as usize,
                                take: 1,
                            },
                        );
                    }
                }
            }
        }
        if !any {
            return Ok(Vec::new());
        }

        let t0 = Instant::now();
        let call = if self.slots.is_paged() {
            self.engine.step_paged(&tokens, &pos, &active, &self.tables)
        } else {
            self.engine.step(&tokens, &pos, &active)
        };
        let logits = match call {
            Ok(l) => l,
            Err(err) => {
                let mut failed = Vec::new();
                self.handle_fault(err, &active, &mut failed)?;
                return Ok(failed);
            }
        };
        if logits.len() != n {
            bail!("engine returned {} logit rows for {n} slots", logits.len());
        }
        let step_us = t0.elapsed().as_secs_f64() * 1e6;
        self.note_engine_success(&active);

        let mut new_tokens = 0usize;
        let mut done = Vec::new();
        for b in 0..n {
            if !active[b] || self.active[b].is_none() {
                continue;
            }
            let new_pos = self.slots.advance(b)?;
            {
                let a = self.active[b].as_mut().expect("checked above");
                if a.fed < a.prompt.len() {
                    a.fed += 1;
                }
            }
            let stall = if running[b] {
                self.active[b].as_ref().map(|a| a.stall_steps)
            } else {
                None
            };
            let finished =
                self.sample_and_check(b, &logits[b], new_pos, max_seq, &mut new_tokens, stall);
            if running[b] {
                // A running slot always samples on a decode step: record
                // how many call iterations (and how much engine time) it
                // waited since its previous token, then reset.
                let a = self.active[b].as_mut().expect("checked above");
                let stall = a.stall_steps;
                let wait = a.wait_us + step_us;
                a.stall_steps = 0;
                a.wait_us = 0.0;
                self.metrics.record_decode_token_wait(stall, wait);
            }
            if finished {
                done.push(self.retire(b)?);
            }
        }
        self.metrics.record_step(step_us, new_tokens, self.slots.active_count(), self.pending.len());
        self.metrics.record_token_mix(prompt_fed, decode_fed);
        self.emit_counters(prompt_fed, decode_fed);
        Ok(done)
    }

    /// Emit one `Counters` sample (queue depth, in-flight, free pages,
    /// token mix of the call that just ran) — the Chrome exporter turns
    /// these into counter tracks. A single branch when tracing is off.
    fn emit_counters(&self, prompt_fed: usize, decode_fed: usize) {
        if !self.trace.is_on() {
            return;
        }
        self.trace.emit(TraceEvent::Counters {
            queue_depth: self.pending.len(),
            in_flight: self.slots.active_count(),
            free_pages: self.slots.pool().map(|p| p.free_blocks()).unwrap_or(0),
            prompt_fed,
            decode_fed,
        });
    }

    /// Step until every pending and in-flight request has completed.
    pub fn run(&mut self) -> Result<Vec<Completion>> {
        let mut all = Vec::new();
        while !self.is_idle() {
            all.extend(self.step()?);
        }
        Ok(all)
    }

    /// Serve a whole workload, feeding the admission queue as backpressure
    /// allows. Completions are returned in finish order.
    pub fn serve_all(
        &mut self,
        reqs: impl IntoIterator<Item = GenRequest>,
    ) -> Result<Vec<Completion>> {
        let mut it = reqs.into_iter();
        let mut next = it.next();
        let mut all = Vec::new();
        loop {
            while next.is_some() && self.has_queue_capacity() {
                self.submit(next.take().expect("checked"))?;
                next = it.next();
            }
            if next.is_none() && self.is_idle() {
                break;
            }
            all.extend(self.step()?);
        }
        Ok(all)
    }
}

// ---------------------------------------------------------------------------
// Legacy threaded front: a worker thread owns the PJRT state (it is !Send);
// clients submit prompts over a channel and receive completions.
// ---------------------------------------------------------------------------

/// A generation request for the threaded [`Server`].
pub struct Request {
    pub prompt: Vec<u8>,
    pub max_new_tokens: usize,
}

/// A completed [`Server`] generation.
#[derive(Debug)]
pub struct Response {
    pub id: usize,
    pub completion: Vec<u8>,
    pub latency_ms: f64,
    pub ms_per_token: f64,
}

enum Msg {
    Submit(usize, Request),
    Shutdown,
}

/// Single-worker serving front: FIFO queue + per-request KV-cache reset.
/// (PJRT handles are not `Send`, so the worker thread constructs everything
/// it needs via the factory closure and owns it for its lifetime.)
///
/// For batched serving, run a [`Scheduler`] on the owning thread instead.
pub struct Server {
    tx: mpsc::Sender<Msg>,
    rx_resp: mpsc::Receiver<Result<Response, String>>,
    handle: Option<std::thread::JoinHandle<()>>,
    next_id: usize,
    /// The worker's terminal status, written exactly once when the thread
    /// exits (init failure, clean shutdown, channel closure, or panic —
    /// the last via a drop guard) and surfaced by [`Self::worker_error`]
    /// and the `submit` rejection message, so a dead worker is
    /// diagnosable instead of a bare "worker dead".
    terminal: std::sync::Arc<std::sync::Mutex<Option<String>>>,
}

/// Stamps the worker's terminal status on the way out of the thread —
/// including unwinds: if the closure panicked before any explicit stamp,
/// the `Drop` impl records that.
struct TerminalGuard(std::sync::Arc<std::sync::Mutex<Option<String>>>);

impl TerminalGuard {
    fn stamp(&self, why: &str) {
        let mut t = self.0.lock().unwrap_or_else(|p| p.into_inner());
        if t.is_none() {
            *t = Some(why.to_string());
        }
    }
}

impl Drop for TerminalGuard {
    fn drop(&mut self) {
        self.stamp("worker panicked");
    }
}

impl Server {
    /// `factory` runs on the worker thread and must produce a closure that
    /// serves one request (typically wrapping a fresh GenerationSession).
    pub fn spawn<F, S>(factory: F) -> Self
    where
        F: FnOnce() -> Result<S> + Send + 'static,
        S: FnMut(&Request) -> Result<(Vec<u8>, f64)>,
    {
        let (tx, rx) = mpsc::channel::<Msg>();
        let (tx_resp, rx_resp) = mpsc::channel();
        let terminal = std::sync::Arc::new(std::sync::Mutex::new(None));
        let worker_terminal = terminal.clone();
        let handle = std::thread::spawn(move || {
            let guard = TerminalGuard(worker_terminal);
            let mut serve_one = match factory() {
                Ok(s) => s,
                Err(e) => {
                    let why = format!("worker init failed: {e:#}");
                    guard.stamp(&why);
                    let _ = tx_resp.send(Err(why));
                    return;
                }
            };
            while let Ok(msg) = rx.recv() {
                match msg {
                    Msg::Submit(id, req) => {
                        let t0 = Instant::now();
                        let resp = serve_one(&req)
                            .map(|(completion, ms_per_token)| Response {
                                id,
                                completion,
                                latency_ms: t0.elapsed().as_secs_f64() * 1e3,
                                ms_per_token,
                            })
                            .map_err(|e| format!("{e:#}"));
                        let _ = tx_resp.send(resp);
                    }
                    Msg::Shutdown => {
                        guard.stamp("worker shut down cleanly");
                        break;
                    }
                }
            }
            guard.stamp("request channel closed");
        });
        Self { tx, rx_resp, handle: Some(handle), next_id: 0, terminal }
    }

    /// Is the worker thread still running? (It exits on factory failure,
    /// shutdown, or panic.)
    pub fn worker_alive(&self) -> bool {
        self.handle.as_ref().map(|h| !h.is_finished()).unwrap_or(false)
    }

    /// Why the worker exited: `None` while it is still running (or before
    /// its exit was stamped), otherwise the stored terminal reason —
    /// "worker init failed: ...", "worker shut down cleanly", "request
    /// channel closed", or "worker panicked".
    pub fn worker_error(&self) -> Option<String> {
        if self.worker_alive() {
            return None;
        }
        self.terminal.lock().unwrap_or_else(|p| p.into_inner()).clone()
    }

    /// Enqueue a request. Fails — instead of silently dropping the message —
    /// when the worker thread has died, carrying the worker's terminal
    /// reason so callers can tell an init failure from a crash.
    pub fn submit(&mut self, req: Request) -> Result<usize> {
        if !self.worker_alive() {
            let why = self
                .worker_error()
                .unwrap_or_else(|| "no terminal status recorded".to_string());
            bail!("server worker is dead ({why}); request rejected");
        }
        let id = self.next_id;
        self.tx
            .send(Msg::Submit(id, req))
            .map_err(|_| anyhow!("server worker hung up; request rejected"))?;
        self.next_id += 1;
        Ok(id)
    }

    /// Receive the next completion. Fails fast (rather than blocking
    /// forever) once the worker has hung up and the response queue drained.
    pub fn recv(&self) -> Result<Response> {
        match self.rx_resp.recv() {
            Ok(Ok(r)) => Ok(r),
            Ok(Err(e)) => Err(anyhow!(e)),
            Err(_) => Err(anyhow!(
                "server worker hung up; no further responses will arrive"
            )),
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::engine::MockEngine;

    fn sched(slots: usize, max_seq: usize, max_queue: usize) -> Scheduler<MockEngine> {
        Scheduler::new(MockEngine::new(slots, max_seq, 64), max_queue).unwrap()
    }

    #[test]
    fn single_request_generates_exact_budget() {
        let mut s = sched(1, 64, 8);
        let id = s.submit(GenRequest::greedy(b"abc", 5)).unwrap();
        let done = s.run().unwrap();
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].id, id);
        assert_eq!(done[0].prompt, b"abc".to_vec());
        assert_eq!(done[0].completion.len(), 5);
        assert!(done[0].ttft_ms.is_some());
        // prompt(3) + 5 tokens, last one never fed back: 7 steps.
        assert_eq!(s.engine().steps, 7);
        assert_eq!(s.metrics.tokens_generated, 5);
        assert_eq!(s.metrics.requests_completed, 1);
        assert!(s.is_idle());
    }

    #[test]
    fn mid_flight_join_and_no_drain() {
        // THE continuous-batching acceptance test: with both slots busy, a
        // late request is admitted the step after a slot frees and finishes
        // while the long request is still decoding.
        let mut s = sched(2, 256, 16);
        let long = s.submit(GenRequest::greedy(b"LLLL", 60)).unwrap();
        let short = s.submit(GenRequest::greedy(b"ss", 3)).unwrap();
        // Run a few steps: both slots occupied, batch is full.
        for _ in 0..3 {
            s.step().unwrap();
            assert_eq!(s.in_flight(), 2);
            assert!(s.in_flight() <= s.slot_capacity());
        }
        // Submit mid-decode; no free slot yet, so it queues.
        let late = s.submit(GenRequest::greedy(b"late", 4)).unwrap();
        assert_eq!(s.queue_depth(), 1);

        let mut finish_order = Vec::new();
        let mut joined_at_step = None;
        let mut step_no = 3;
        while !s.is_idle() {
            let done = s.step().unwrap();
            step_no += 1;
            assert!(s.in_flight() <= s.slot_capacity(), "slot accounting exceeded capacity");
            if joined_at_step.is_none() && s.queue_depth() == 0 {
                joined_at_step = Some(step_no);
            }
            finish_order.extend(done.into_iter().map(|c| c.id));
        }
        // The short request freed its slot, the late request joined and
        // completed while `long` was still running.
        assert_eq!(finish_order[0], short);
        assert_eq!(finish_order[1], late);
        assert_eq!(finish_order[2], long);
        assert!(joined_at_step.is_some(), "late request never admitted");
        // Long runs 4 + 60 - 1 = 63 steps; late must be done well before.
        assert!(s.engine().steps < 70);
    }

    #[test]
    fn slot_reuse_restarts_positions() {
        // Two sequential short requests through a single slot: the second
        // must restart at pos 0 (MockEngine would error on position drift
        // or a missing reset).
        let mut s = sched(1, 16, 8);
        s.submit(GenRequest::greedy(b"one", 2)).unwrap();
        s.submit(GenRequest::greedy(b"two!", 2)).unwrap();
        let done = s.run().unwrap();
        assert_eq!(done.len(), 2);
        assert_eq!(done[0].completion.len(), 2);
        assert_eq!(done[1].completion.len(), 2);
    }

    #[test]
    fn backpressure_rejects_when_queue_full() {
        let mut s = sched(1, 64, 2);
        s.submit(GenRequest::greedy(b"a", 4)).unwrap();
        s.submit(GenRequest::greedy(b"b", 4)).unwrap();
        let err = s.submit(GenRequest::greedy(b"c", 4)).unwrap_err();
        assert!(err.to_string().contains("backpressure"), "{err:#}");
        // Draining restores capacity: the first step admits one request
        // into the slot, freeing a queue position.
        s.step().unwrap();
        assert!(s.has_queue_capacity());
        s.submit(GenRequest::greedy(b"c", 4)).unwrap();
        let done = s.run().unwrap();
        assert_eq!(done.len(), 3);
    }

    #[test]
    fn rejects_oversized_and_empty_prompts() {
        let mut s = sched(1, 8, 4);
        assert!(s.submit(GenRequest::greedy(b"", 4)).is_err());
        assert!(s.submit(GenRequest::greedy(&[7u8; 9], 4)).is_err());
    }

    #[test]
    fn cache_exhaustion_truncates_completion() {
        let mut s = sched(1, 6, 4);
        // prompt 4 + budget 10 can't fit in 6 positions: 2 tokens max.
        s.submit(GenRequest::greedy(b"abcd", 10)).unwrap();
        let done = s.run().unwrap();
        assert_eq!(done.len(), 1);
        assert!(done[0].completion.len() <= 3, "{:?}", done[0].completion);
        assert!(!done[0].completion.is_empty());
        assert!(s.is_idle());
    }

    #[test]
    fn deterministic_under_fixed_seed_and_batch_invariant() {
        // Same seed => identical tokens; and the generation for a given
        // request is identical at batch 1 and batch 4 (mock logits depend
        // only on history).
        let req = |seed| GenRequest::sampled(b"seeded", 12, Sampler::top_k(8, 3.0), seed);
        let mut s1 = sched(1, 64, 8);
        s1.submit(req(42)).unwrap();
        let d1 = s1.run().unwrap();

        let mut s2 = sched(1, 64, 8);
        s2.submit(req(42)).unwrap();
        let d2 = s2.run().unwrap();
        assert_eq!(d1[0].completion, d2[0].completion);

        let mut s4 = sched(4, 64, 8);
        s4.submit(req(42)).unwrap();
        for i in 0..3 {
            s4.submit(GenRequest::sampled(b"noise", 9, Sampler::top_k(4, 0.9), 100 + i)).unwrap();
        }
        let d4 = s4.run().unwrap();
        let ours = d4.iter().find(|c| c.prompt == b"seeded".to_vec()).unwrap();
        assert_eq!(ours.completion, d1[0].completion);

        // Different seed diverges (with overwhelming probability).
        let mut s3 = sched(1, 64, 8);
        s3.submit(req(43)).unwrap();
        let d3 = s3.run().unwrap();
        assert_ne!(d3[0].completion, d1[0].completion);
    }

    #[test]
    fn serve_all_drains_a_big_workload() {
        let mut s = sched(4, 64, 4);
        let reqs: Vec<GenRequest> = (0..20)
            .map(|i| {
                let prompt = vec![b'a' + (i % 23) as u8; 2 + (i % 5)];
                GenRequest::greedy(&prompt, 3 + (i % 7))
            })
            .collect();
        let done = s.serve_all(reqs).unwrap();
        assert_eq!(done.len(), 20);
        assert_eq!(s.metrics.requests_completed, 20);
        assert!(s.is_idle());
        // Batching actually happened: fewer steps than serial execution
        // would need.
        let serial: usize = done.iter().map(|c| c.prompt.len() + c.completion.len()).sum();
        assert!(s.engine().steps < serial);
    }

    #[test]
    fn cancel_evicts_in_flight_and_queued_requests() {
        let mut s = sched(1, 32, 8);
        let a = s.submit(GenRequest::greedy(b"aaaa", 20)).unwrap();
        let b = s.submit(GenRequest::greedy(b"bb", 2)).unwrap();
        s.step().unwrap(); // `a` occupies the only slot, `b` queues
        assert_eq!(s.in_flight(), 1);
        assert!(s.cancel(a).unwrap());
        assert_eq!(s.in_flight(), 0);
        // The queued request takes over the evicted slot and completes.
        let done = s.run().unwrap();
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].id, b);
        // Unknown / already-finished ids are a no-op.
        assert!(!s.cancel(a).unwrap());
        assert!(!s.cancel(99).unwrap());
        // Cancelling straight from the queue also works.
        let c = s.submit(GenRequest::greedy(b"cc", 2)).unwrap();
        let d = s.submit(GenRequest::greedy(b"dd", 2)).unwrap();
        assert!(s.cancel(d).unwrap());
        let done = s.run().unwrap();
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].id, c);
    }

    #[test]
    fn zero_budget_completes_after_prompt() {
        let mut s = sched(1, 16, 4);
        s.submit(GenRequest::greedy(b"xyz", 0)).unwrap();
        let done = s.run().unwrap();
        assert_eq!(done.len(), 1);
        assert!(done[0].completion.is_empty());
        assert!(done[0].ttft_ms.is_none());
    }

    // -- batched multi-token prefill --------------------------------------

    fn sched_prefill(
        slots: usize,
        max_seq: usize,
        max_queue: usize,
        chunk: usize,
    ) -> Scheduler<MockEngine> {
        Scheduler::new(MockEngine::new(slots, max_seq, 64).with_prefill_chunk(chunk), max_queue)
            .unwrap()
    }

    #[test]
    fn prefill_consumes_prompt_in_ceil_len_over_chunk_calls() {
        // THE prefill acceptance check: a 64-token prompt on a T=16 engine
        // reaches its first token after exactly ceil(64/16) = 4 prefill
        // calls, not 64 decode steps.
        let mut s = sched_prefill(1, 128, 8, 16);
        s.submit(GenRequest::greedy(&[b'p'; 64], 8)).unwrap();
        let done = s.run().unwrap();
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].completion.len(), 8);
        assert_eq!(s.engine().prefill_calls, 4);
        // The last prefill call sampled token 1; seven decode steps feed
        // tokens 1..=7 and sample tokens 2..=8 (token 8 is never fed back).
        assert_eq!(s.engine().steps, 7);
        assert_eq!(s.metrics.tokens_prefilled, 64);
        assert_eq!(s.metrics.prefill_us.len(), 4);
        assert_eq!(s.metrics.tokens_generated, 8);
        assert!(s.is_idle());
    }

    #[test]
    fn prefill_and_token_loop_produce_identical_completions() {
        // The prefill path is a pure latency optimisation: for any chunk
        // size the generated bytes must be identical to the token-by-token
        // path (mock logits depend only on history, and the L2 pytest
        // proves the same for the real graphs).
        let req =
            |seed| GenRequest::sampled(b"the quick brown fox", 12, Sampler::top_k(8, 0.9), seed);
        let mut a = sched(1, 64, 8);
        a.submit(req(5)).unwrap();
        let da = a.run().unwrap();
        for chunk in [2, 7, 16, 64] {
            let mut b = sched_prefill(1, 64, 8, chunk);
            b.submit(req(5)).unwrap();
            let db = b.run().unwrap();
            assert_eq!(da[0].completion, db[0].completion, "chunk {chunk}");
        }
    }

    #[test]
    fn prefill_multi_slot_staggered_and_mid_flight_join() {
        // Two prompts of different lengths prefill together (sharing
        // calls), a latecomer prefills into a freed slot mid-decode, and
        // everyone's budget comes out exact.
        let mut s = sched_prefill(2, 256, 16, 8);
        let long = s.submit(GenRequest::greedy(&[b'L'; 20], 40)).unwrap();
        let short = s.submit(GenRequest::greedy(&[b's'; 3], 3)).unwrap();
        // 20-token and 3-token prompts overlap in the first call; the long
        // prompt needs ceil(20/8) = 3 calls total.
        let mut done = Vec::new();
        while done.is_empty() {
            done.extend(s.step().unwrap());
        }
        assert_eq!(done[0].id, short);
        assert_eq!(s.engine().prefill_calls, 3);
        assert_eq!(s.metrics.tokens_prefilled, 23);
        // Latecomer joins while `long` is still decoding: one more prefill
        // call (4 tokens < chunk), then it decodes alongside `long`.
        let late = s.submit(GenRequest::greedy(b"late", 4)).unwrap();
        let rest = s.run().unwrap();
        let order: Vec<u64> = rest.iter().map(|c| c.id).collect();
        assert_eq!(order, vec![late, long]);
        assert_eq!(s.engine().prefill_calls, 4);
        assert_eq!(s.metrics.tokens_prefilled, 27);
        assert_eq!(rest[0].completion.len(), 4);
        assert_eq!(rest[1].completion.len(), 40);
    }

    #[test]
    fn prefill_zero_budget_completes_without_ttft() {
        let mut s = sched_prefill(1, 32, 4, 8);
        s.submit(GenRequest::greedy(b"xyz", 0)).unwrap();
        let done = s.run().unwrap();
        assert_eq!(done.len(), 1);
        assert!(done[0].completion.is_empty());
        assert!(done[0].ttft_ms.is_none());
        assert_eq!(s.engine().prefill_calls, 1);
        assert_eq!(s.engine().steps, 0);
    }

    #[test]
    fn cancel_mid_prefill_frees_slot_for_queued_request() {
        let mut s = sched_prefill(1, 128, 8, 8);
        let a = s.submit(GenRequest::greedy(&[b'a'; 30], 5)).unwrap();
        let b = s.submit(GenRequest::greedy(b"bb", 2)).unwrap();
        s.step().unwrap(); // `a` holds the slot, one chunk fed
        assert!(s.cancel(a).unwrap());
        assert_eq!(s.in_flight(), 0);
        // The queued request reuses the half-prefilled slot from pos 0
        // (MockEngine would reject a missing reset).
        let done = s.run().unwrap();
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].id, b);
        assert_eq!(done[0].completion.len(), 2);
    }

    #[test]
    fn ttft_measured_from_enqueue_not_step_start() {
        // Regression: TTFT must include the time a request sat in the
        // admission queue (enqueue -> first token); measuring from
        // admission or from the start of the producing step would hide
        // queue wait entirely.
        let mut s = sched(1, 64, 8);
        s.submit(GenRequest::greedy(b"abcd", 2)).unwrap();
        std::thread::sleep(std::time::Duration::from_millis(15));
        let done = s.run().unwrap();
        let ttft = done[0].ttft_ms.expect("generated a token");
        assert!(ttft >= 15.0, "TTFT {ttft}ms lost the queue wait");
        // The prefill path measures from the same clock...
        let mut s = sched_prefill(1, 64, 8, 8);
        s.submit(GenRequest::greedy(b"abcd", 2)).unwrap();
        std::thread::sleep(std::time::Duration::from_millis(15));
        let done = s.run().unwrap();
        let ttft = done[0].ttft_ms.expect("generated a token");
        assert!(ttft >= 15.0, "prefill TTFT {ttft}ms lost the queue wait");
        // ...and the aggregate metric carries the same number.
        assert!(s.metrics.ttft_ms_p50() >= 15.0);
    }

    // -- paged KV cache (block pool) --------------------------------------

    fn sched_paged(
        slots: usize,
        max_seq: usize,
        max_queue: usize,
        n_blocks: usize,
        bs: usize,
    ) -> Scheduler<MockEngine> {
        Scheduler::new(
            MockEngine::new(slots, max_seq, 64).with_block_pool(n_blocks, bs),
            max_queue,
        )
        .unwrap()
    }

    fn mixed_workload(n: usize) -> Vec<GenRequest> {
        (0..n)
            .map(|i| {
                let prompt = vec![b'a' + (i % 23) as u8; 2 + (i % 7)];
                GenRequest::sampled(
                    &prompt,
                    3 + (i % 9),
                    Sampler::top_k(8, 0.9),
                    500 + i as u64,
                )
            })
            .collect()
    }

    #[test]
    fn paged_with_full_pool_is_bit_identical_to_dense() {
        // With a full-size pool (slots x max_seq worth of pages) the token
        // budget never binds: admission, step counts, completion order and
        // every generated byte must match the dense scheduler exactly.
        let (slots, max_seq, bs) = (4, 64, 8);
        let mut dense = sched(slots, max_seq, 8);
        let d = dense.serve_all(mixed_workload(16)).unwrap();
        let mut paged = sched_paged(slots, max_seq, 8, slots * max_seq / bs, bs);
        let p = paged.serve_all(mixed_workload(16)).unwrap();
        assert_eq!(d.len(), p.len());
        for (a, b) in d.iter().zip(&p) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.completion, b.completion, "request {}", a.id);
        }
        assert_eq!(dense.engine().steps, paged.engine().steps);
        assert_eq!(paged.metrics.requests_evicted, 0);
    }

    #[test]
    fn paged_admits_by_token_budget_not_slot_reservation() {
        // 8 slots but only ~2 dense slots worth of memory: short requests
        // still fill every lane because admission counts pages, not
        // max_seq-sized reservations.
        let (slots, max_seq, bs) = (8, 64, 8);
        let mut s = sched_paged(slots, max_seq, 16, 2 * max_seq / bs, bs);
        for i in 0..8 {
            // prompt 4 + budget 3 => 1 page each.
            s.submit(GenRequest::sampled(b"abcd", 3, Sampler::top_k(4, 0.7), i)).unwrap();
        }
        s.step().unwrap();
        assert_eq!(s.in_flight(), 8, "token budget should admit all 8");
        let done = s.run().unwrap();
        assert_eq!(done.len(), 8);
        assert_eq!(s.metrics.requests_evicted, 0);
        // A dense scheduler at the same memory budget caps at 2 concurrent.
        let mut d = sched(2, max_seq, 16);
        for i in 0..8 {
            d.submit(GenRequest::sampled(b"abcd", 3, Sampler::top_k(4, 0.7), i)).unwrap();
        }
        d.step().unwrap();
        assert_eq!(d.in_flight(), 2);
    }

    #[test]
    fn paged_pool_exhaustion_evicts_youngest_and_restarts_identically() {
        // Two requests that each need 3 pages over a 4-page pool: the
        // watermark admits both, growth exhausts the pool, the younger is
        // evicted to the queue front, and both still complete with exactly
        // the bytes a solo (dense) run produces — the seeded restart is
        // invisible in the output.
        let req = |seed| GenRequest::sampled(b"abcd", 8, Sampler::top_k(8, 0.9), seed);
        let mut s = sched_paged(2, 32, 8, 4, 4);
        let a = s.submit(req(1)).unwrap();
        let b = s.submit(req(2)).unwrap();
        let done = s.run().unwrap();
        assert_eq!(done.len(), 2);
        assert!(s.metrics.requests_evicted >= 1, "pool of 4 pages must evict");
        // Eviction hits the youngest: `a` (older) finishes first.
        assert_eq!(done[0].id, a);
        assert_eq!(done[1].id, b);
        for (seed, id) in [(1, a), (2, b)] {
            let mut solo = sched(1, 32, 4);
            solo.submit(req(seed)).unwrap();
            let want = solo.run().unwrap();
            let got = done.iter().find(|c| c.id == id).expect("completed");
            assert_eq!(got.completion, want[0].completion, "request {id}");
        }
        // Everything was returned to the pool.
        assert_eq!(s.slots.pool().unwrap().used_blocks(), 0);
    }

    #[test]
    fn paged_rejects_requests_larger_than_the_whole_pool() {
        let mut s = sched_paged(2, 64, 8, 4, 4); // 16-token pool
        let err = s.submit(GenRequest::greedy(&[b'x'; 20], 30)).unwrap_err();
        assert!(err.to_string().contains("KV pages"), "{err:#}");
        // max_seq caps the demand: a huge budget on a short prompt is fine
        // when the pool covers max_seq... but not here (64 > 16).
        assert!(s.submit(GenRequest::greedy(b"ab", 1000)).is_err());
        // With a pool covering max_seq the same request is accepted.
        let mut s = sched_paged(2, 16, 8, 4, 4);
        s.submit(GenRequest::greedy(b"ab", 1000)).unwrap();
        let done = s.run().unwrap();
        assert_eq!(done.len(), 1, "truncated at max_seq but completed");
    }

    #[test]
    fn paged_prefill_grows_tables_across_chunk_boundaries() {
        // T=8 prefill over 4-token pages: each prefill call needs 2 fresh
        // pages; a 30-token prompt costs ceil(30/8) = 4 calls and
        // ceil(30/4) = 8 pages at its peak.
        let mut s = Scheduler::new(
            MockEngine::new(2, 64, 64).with_block_pool(16, 4).with_prefill_chunk(8),
            8,
        )
        .unwrap();
        s.submit(GenRequest::greedy(&[b'p'; 30], 4)).unwrap();
        let done = s.run().unwrap();
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].completion.len(), 4);
        assert_eq!(s.engine().prefill_calls, 4);
        assert_eq!(s.metrics.requests_evicted, 0);
        assert_eq!(s.slots.pool().unwrap().used_blocks(), 0);
    }

    #[test]
    fn paged_cancel_returns_pages() {
        let mut s = sched_paged(2, 32, 8, 8, 4);
        let a = s.submit(GenRequest::greedy(&[b'a'; 10], 10)).unwrap();
        for _ in 0..12 {
            s.step().unwrap();
        }
        assert!(s.slots.pool().unwrap().used_blocks() >= 3);
        assert!(s.cancel(a).unwrap());
        assert_eq!(s.slots.pool().unwrap().used_blocks(), 0);
    }

    #[test]
    fn paged_budget_restriction_is_enforced() {
        let e = MockEngine::new(4, 64, 64).with_block_pool(32, 8);
        let s = Scheduler::new(e, 8).unwrap().with_kv_block_budget(8).unwrap();
        assert_eq!(s.slots.pool().unwrap().total_blocks(), 8);
        let e = MockEngine::new(4, 64, 64).with_block_pool(32, 8);
        assert!(Scheduler::new(e, 8).unwrap().with_kv_block_budget(64).is_err());
        let dense = MockEngine::new(4, 64, 64);
        assert!(Scheduler::new(dense, 8).unwrap().with_kv_block_budget(8).is_err());
    }

    // -- prefix cache (refcounted copy-on-write page sharing) --------------

    fn sched_prefix(
        slots: usize,
        max_seq: usize,
        n_blocks: usize,
        bs: usize,
        chunk: usize,
    ) -> Scheduler<MockEngine> {
        let mut e = MockEngine::new(slots, max_seq, 64).with_block_pool(n_blocks, bs);
        if chunk > 1 {
            e = e.with_prefill_chunk(chunk);
        }
        Scheduler::new(e, 64).unwrap().with_prefix_cache().unwrap()
    }

    /// N requests sharing one system prompt: `shared` identical leading
    /// bytes, then a per-request suffix.
    fn shared_prefix_workload(n: usize, shared: usize, suffix: usize) -> Vec<GenRequest> {
        (0..n)
            .map(|i| {
                let mut p: Vec<u8> = (0..shared).map(|j| (32 + (j * 7) % 90) as u8).collect();
                p.extend((0..suffix).map(|j| (32 + ((i * 13 + j * 5) % 90)) as u8));
                GenRequest::sampled(&p, 4 + i % 5, Sampler::top_k(8, 0.9), 900 + i as u64)
            })
            .collect()
    }

    #[test]
    fn prefix_cache_on_off_bit_identical_completions() {
        // THE acceptance check: the prefix cache is a pure recomputation
        // remover — for a shared-prefix workload every generated byte must
        // match the cache-off paged run, while the cache-on run actually
        // reuses pages. Both the interleaved (chunk 1) and the batched
        // prefill path.
        for chunk in [1usize, 8] {
            let workload = || shared_prefix_workload(12, 16, 4);
            let mk = |prefix: bool| {
                let mut e = MockEngine::new(4, 64, 64).with_block_pool(24, 4);
                if chunk > 1 {
                    e = e.with_prefill_chunk(chunk);
                }
                let s = Scheduler::new(e, 64).unwrap();
                if prefix {
                    s.with_prefix_cache().unwrap()
                } else {
                    s
                }
            };
            let mut on = mk(true);
            let mut d_on = on.serve_all(workload()).unwrap();
            let mut off = mk(false);
            let mut d_off = off.serve_all(workload()).unwrap();
            d_on.sort_by_key(|c| c.id);
            d_off.sort_by_key(|c| c.id);
            assert_eq!(d_on.len(), d_off.len());
            for (a, b) in d_on.iter().zip(&d_off) {
                assert_eq!(a.id, b.id);
                assert_eq!(a.completion, b.completion, "chunk {chunk}, request {}", a.id);
            }
            assert!(on.metrics.tokens_reused > 0, "chunk {chunk}: cache never hit");
            assert_eq!(off.metrics.tokens_reused, 0);
        }
    }

    #[test]
    fn warm_request_prefills_only_the_uncached_tail() {
        // bs 8, 16 shared + 8 unique prompt tokens, prefill chunk 8: the
        // cold request costs ceil(24/8) = 3 prefill calls; the warm one
        // maps 2 cached pages and owes only its 8-token tail -> 1 call.
        let mut s = sched_prefix(2, 128, 32, 8, 8);
        let w = shared_prefix_workload(2, 16, 8);
        s.submit(w[0].clone()).unwrap();
        let d0 = s.run().unwrap();
        assert_eq!(s.engine().prefill_calls, 3);
        assert_eq!(s.metrics.tokens_reused, 0);
        s.submit(w[0].clone()).unwrap();
        let d1 = s.run().unwrap();
        assert_eq!(s.engine().prefill_calls, 4, "warm prompt owes one chunk");
        assert_eq!(s.metrics.tokens_reused, 16);
        assert_eq!(s.metrics.prefix_hits, 1);
        assert!((s.metrics.prefix_hit_rate() - 16.0 / 48.0).abs() < 1e-12);
        // Identical request + seed => identical bytes, cold or warm.
        assert_eq!(d0[0].completion, d1[0].completion);
        // A different suffix shares only the 16-token prefix.
        s.submit(w[1].clone()).unwrap();
        s.run().unwrap();
        assert_eq!(s.metrics.tokens_reused, 32);
    }

    #[test]
    fn shared_pages_shrink_physical_demand_at_the_same_budget() {
        // Pool of 4 pages x 4 tokens; each request needs 3 pages end to
        // end (prompt 9, budget 3) but the first 2 pages are a shared
        // prefix. Cold (cache off), two concurrent requests demand 6
        // physical pages and must evict; warm, they demand 2 shared + 2
        // exclusive = 4 and both run to completion untouched — strictly
        // more admitted concurrency from the same page budget.
        let reqs = shared_prefix_workload(3, 8, 1);
        let mut cold = Scheduler::new(MockEngine::new(2, 32, 64).with_block_pool(4, 4), 8)
            .unwrap();
        cold.submit(GenRequest { max_new_tokens: 3, ..reqs[1].clone() }).unwrap();
        cold.submit(GenRequest { max_new_tokens: 3, ..reqs[2].clone() }).unwrap();
        cold.run().unwrap();
        assert!(cold.metrics.requests_evicted >= 1, "6-page demand over 4 pages must evict");
        // Warm the cache with one full pass, then run the same pair.
        let mut s = sched_prefix(2, 32, 4, 4, 1);
        s.submit(GenRequest { max_new_tokens: 3, ..reqs[0].clone() }).unwrap();
        s.run().unwrap();
        assert_eq!(s.slots.prefix().unwrap().cached_pages(), 2);
        s.submit(GenRequest { max_new_tokens: 3, ..reqs[1].clone() }).unwrap();
        s.submit(GenRequest { max_new_tokens: 3, ..reqs[2].clone() }).unwrap();
        s.step().unwrap();
        assert_eq!(s.in_flight(), 2, "non-shared demand (1 page each) fits the watermark");
        let done = s.run().unwrap();
        assert_eq!(done.len(), 2);
        assert_eq!(s.metrics.requests_evicted, 0, "shared pages remove the pressure");
        assert_eq!(s.metrics.tokens_reused, 16, "two warm admissions x 8 shared tokens");
    }

    #[test]
    fn evicted_request_replays_byte_identically_with_prefix_cache() {
        // Satellite: two page-hungry requests over a 6-page pool force an
        // eviction; the victim re-admits through its own donated pages
        // (warm restart) and still produces exactly the bytes a solo dense
        // run yields.
        let prompt: Vec<u8> = (0..8).map(|j| b'A' + j).collect();
        let req = |seed| GenRequest::sampled(&prompt, 8, Sampler::top_k(8, 0.9), seed);
        let mut s = sched_prefix(2, 32, 6, 4, 1);
        let a = s.submit(req(1)).unwrap();
        let b = s.submit(req(2)).unwrap();
        let done = s.run().unwrap();
        assert_eq!(done.len(), 2);
        assert!(s.metrics.requests_evicted >= 1, "6 pages for 2x4-page demand must evict");
        assert!(s.metrics.tokens_reused > 0, "victim must restart through cached pages");
        for (seed, id) in [(1, a), (2, b)] {
            let mut solo = sched(1, 32, 4);
            solo.submit(req(seed)).unwrap();
            let want = solo.run().unwrap();
            let got = done.iter().find(|c| c.id == id).expect("completed");
            assert_eq!(got.completion, want[0].completion, "request {id}");
        }
        // Every page still resident is held by the index alone.
        let pool = s.slots.pool().unwrap();
        assert_eq!(pool.used_blocks(), s.slots.prefix().unwrap().cached_pages());
    }

    #[test]
    fn prefix_cache_requires_a_paged_engine_and_an_empty_scheduler() {
        let dense = Scheduler::new(MockEngine::new(2, 32, 64), 8).unwrap();
        assert!(dense.with_prefix_cache().is_err());
        let mut s = sched_prefix(2, 32, 8, 4, 1);
        s.submit(GenRequest::greedy(b"abc", 2)).unwrap();
        assert!(s.with_prefix_cache().is_err(), "must be set before submitting");
        // Budget restriction composes with the prefix cache in either order.
        let e = MockEngine::new(2, 64, 64).with_block_pool(16, 8);
        let s = Scheduler::new(e, 8)
            .unwrap()
            .with_prefix_cache()
            .unwrap()
            .with_kv_block_budget(8)
            .unwrap();
        assert!(s.slots.has_prefix_cache());
        assert_eq!(s.slots.pool().unwrap().total_blocks(), 8);
    }

    // -- decode-priority step composer (--step-budget) ---------------------

    #[test]
    fn step_budget_requires_prefill_engine_and_empty_scheduler() {
        // Chunk-1 engines have no prefill burst to bound.
        let s = sched(2, 64, 8);
        assert!(s.with_step_budget(8).is_err());
        // Budget 0 means "off": reject rather than silently disabling.
        let s = sched_prefill(2, 64, 8, 16);
        assert!(s.with_step_budget(0).is_err());
        // Must be configured before work arrives.
        let mut s = sched_prefill(2, 64, 8, 16);
        s.submit(GenRequest::greedy(b"abc", 2)).unwrap();
        assert!(s.with_step_budget(8).is_err());
        // Composes with paging and the prefix cache.
        let e = MockEngine::new(2, 64, 64).with_block_pool(16, 4).with_prefill_chunk(8);
        let s = Scheduler::new(e, 8)
            .unwrap()
            .with_prefix_cache()
            .unwrap()
            .with_step_budget(8)
            .unwrap();
        assert!(s.step_budget.is_some());
    }

    #[test]
    fn composer_decodes_every_iteration_and_bounds_the_prefill_take() {
        // THE composer acceptance check: a 40-token prompt joining one
        // in-flight decode. Budget-off, the decoder stalls for the whole
        // ceil(40/16) = 3-call prefill burst; budget-on, it produces a
        // token every iteration (stall 0) and no prefill call ever
        // carries more than max(B - decode_lanes, guard) prompt tokens.
        let newcomer = || GenRequest::greedy(&[b'p'; 40], 4);
        // -- budget off: the PR 4 behavior, now measured.
        let mut off = sched_prefill(2, 256, 8, 16);
        off.submit(GenRequest::greedy(b"ab", 30)).unwrap();
        off.step().unwrap(); // prefill "ab" + first token
        assert_eq!(off.slot_phase(0), SlotPhase::Running);
        off.submit(newcomer()).unwrap();
        let done = off.run().unwrap();
        assert_eq!(done.len(), 2);
        assert_eq!(off.metrics.max_decode_stall_steps(), 3, "3-call burst stalls the decoder");
        assert_eq!(off.metrics.mixed_steps, 0);
        // -- budget on: same workload, bounded hiccup.
        let mut on = sched_prefill(2, 256, 8, 16).with_step_budget(8).unwrap();
        on.submit(GenRequest::greedy(b"ab", 30)).unwrap();
        on.step().unwrap();
        assert_eq!(on.slot_phase(0), SlotPhase::Running);
        assert_eq!(on.slot_phase(1), SlotPhase::Cold);
        on.submit(newcomer()).unwrap();
        on.step().unwrap();
        assert_eq!(on.slot_phase(1), SlotPhase::Warming, "admitted, prompt split across steps");
        let done = on.run().unwrap();
        assert_eq!(done.len(), 2);
        assert_eq!(on.metrics.max_decode_stall_steps(), 0, "decode priority: no stall at all");
        // Budget 8 minus 1 decode lane leaves 7 prompt tokens per call.
        assert_eq!(on.engine().max_prefill_call_tokens, 7);
        // ceil(40/7) = 6 prefill calls for the newcomer, every one of them
        // composed with a decode call.
        assert_eq!(on.engine().prefill_calls, 7, "1 warmup + 6 newcomer calls");
        assert!(on.metrics.mixed_steps >= 6);
        // The schedule changed; the bytes must not have.
        let mut by_id_on: Vec<_> = done.iter().map(|c| (c.id, c.completion.clone())).collect();
        by_id_on.sort();
        let mut solo = sched(1, 256, 8);
        solo.submit(GenRequest::greedy(b"ab", 30)).unwrap();
        let want = solo.run().unwrap();
        assert_eq!(by_id_on[0].1, want[0].completion);
    }

    #[test]
    fn composer_starvation_guard_keeps_prefill_moving() {
        // 8 decode lanes over budget 4: once all 8 are running, the decode
        // batch alone overflows the budget, but the guard (max(1, 4/4) = 1)
        // still feeds the newcomer's prompt one token per step — prefill
        // never starves, and no call ever exceeds the plan.
        let mut s = sched_prefill(9, 512, 16, 16).with_step_budget(4).unwrap();
        for i in 0..8 {
            s.submit(GenRequest::sampled(b"abcd", 60, Sampler::top_k(8, 0.9), i)).unwrap();
        }
        // Warm up under the budget until every lane decodes.
        for _ in 0..100 {
            if (0..8).all(|b| s.slot_phase(b) == SlotPhase::Running) {
                break;
            }
            s.step().unwrap();
        }
        assert!((0..8).all(|b| s.slot_phase(b) == SlotPhase::Running));
        // No step may have fed more than the budget's prefill share
        // (decode lanes were still warming, so the share was 1..=4).
        assert!(s.engine().max_prefill_call_tokens <= 4);
        let calls_before = s.engine().prefill_calls;
        let late = s.submit(GenRequest::greedy(&[b'n'; 12], 2)).unwrap();
        let done = s.run().unwrap();
        assert_eq!(done.len(), 9);
        assert!(done.iter().any(|c| c.id == late));
        assert_eq!(s.metrics.max_decode_stall_steps(), 0);
        // 8 running lanes >= budget 4, so the guard's single token per
        // step is all the newcomer gets: exactly 12 one-token calls.
        assert_eq!(s.engine().prefill_calls - calls_before, 12);
        assert!(s.engine().max_prefill_call_tokens <= 4, "guard calls carried 1 token");
    }

    #[test]
    fn composer_splits_prompts_at_arbitrary_boundaries() {
        // Budget 5 under a T=16 graph: a 13-token prompt is consumed as
        // 5 + 5 + 3 — boundaries no artifact was built for, carried by the
        // ragged n_valid input.
        let mut s = sched_prefill(1, 64, 8, 16).with_step_budget(5).unwrap();
        s.submit(GenRequest::greedy(&[b'q'; 13], 2)).unwrap();
        let done = s.run().unwrap();
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].completion.len(), 2);
        assert_eq!(s.engine().prefill_calls, 3);
        assert_eq!(s.engine().prefill_tokens_fed, 13);
        assert_eq!(s.engine().max_prefill_call_tokens, 5);
        assert_eq!(s.engine().steps, 1, "token 1 from the last chunk, token 2 from decode");
        assert_eq!(s.metrics.tokens_prefilled, 13);
    }

    #[test]
    fn composer_is_byte_identical_with_paged_eviction_and_prefix_cache() {
        // Satellite: composer x prefix cache x paged eviction. Two
        // requests that each need 4 pages over a 5-page pool force an
        // eviction at every budget pacing (the survivor alone grows to 4
        // pages while the other holds one and needs a second); the victim
        // restarts warm through the survivor's donated pages. With the
        // composer on, every completion must still be byte-identical to
        // the budget-off run AND to a solo dense run.
        let prompt: Vec<u8> = (0..8).map(|j| b'A' + j).collect();
        let req = |seed| GenRequest::sampled(&prompt, 8, Sampler::top_k(8, 0.9), seed);
        let mk = |budget: usize| {
            let e = MockEngine::new(2, 32, 64).with_block_pool(5, 4).with_prefill_chunk(4);
            let s = Scheduler::new(e, 8).unwrap().with_prefix_cache().unwrap();
            if budget > 0 {
                s.with_step_budget(budget).unwrap()
            } else {
                s
            }
        };
        for budget in [0usize, 2, 3, 8] {
            let mut s = mk(budget);
            let a = s.submit(req(1)).unwrap();
            let b = s.submit(req(2)).unwrap();
            let done = s.run().unwrap();
            assert_eq!(done.len(), 2, "budget {budget}");
            assert!(
                s.metrics.requests_evicted >= 1,
                "budget {budget}: 2x4-page demand over 5 pages must evict"
            );
            for (seed, id) in [(1, a), (2, b)] {
                let mut solo = sched(1, 32, 4);
                solo.submit(req(seed)).unwrap();
                let want = solo.run().unwrap();
                let got = done.iter().find(|c| c.id == id).expect("completed");
                assert_eq!(got.completion, want[0].completion, "budget {budget}, request {id}");
            }
            // Pages all returned or index-held, same as budget off.
            let pool = s.slots.pool().unwrap();
            assert_eq!(pool.used_blocks(), s.slots.prefix().unwrap().cached_pages());
        }
    }

    #[test]
    fn composer_warm_prefix_skip_is_byte_identical_on_and_off() {
        // Warm restarts through cached prefix pages under the composer:
        // same reuse accounting, same bytes as the budget-off warm run.
        let run = |budget: usize| {
            let mut s = {
                let e =
                    MockEngine::new(2, 128, 64).with_block_pool(32, 8).with_prefill_chunk(8);
                let s = Scheduler::new(e, 64).unwrap().with_prefix_cache().unwrap();
                if budget > 0 {
                    s.with_step_budget(budget).unwrap()
                } else {
                    s
                }
            };
            let w = shared_prefix_workload(2, 16, 8);
            s.submit(w[0].clone()).unwrap();
            s.run().unwrap();
            s.submit(w[1].clone()).unwrap();
            let d = s.run().unwrap();
            (d[0].completion.clone(), s.metrics.tokens_reused)
        };
        let (off_bytes, off_reused) = run(0);
        for budget in [3usize, 8, 32] {
            let (bytes, reused) = run(budget);
            assert_eq!(bytes, off_bytes, "budget {budget}");
            assert_eq!(reused, off_reused, "budget {budget}: warm skip must be identical");
            assert!(reused >= 16, "second request must map the shared pages");
        }
    }

    #[test]
    fn ttft_splits_queue_wait_from_prefill_spread() {
        // Regression (satellite): a request that sat in the queue and a
        // request whose prompt spread across many budgeted steps both have
        // large TTFT — the split tells them apart.
        let mut s = sched_prefill(1, 128, 8, 16).with_step_budget(2).unwrap();
        s.submit(GenRequest::greedy(&[b'w'; 32], 2)).unwrap();
        std::thread::sleep(std::time::Duration::from_millis(15));
        let done = s.run().unwrap();
        let ttft = done[0].ttft_ms.expect("generated");
        assert_eq!(s.metrics.queue_us.len(), 1);
        assert_eq!(s.metrics.prefill_spread_us.len(), 1);
        let queue = s.metrics.queue_ms_p50();
        let spread = s.metrics.prefill_spread_ms_p50();
        assert!(queue >= 15.0, "queue wait {queue}ms lost the pre-step sleep");
        assert!(spread >= 0.0);
        // The two halves are stamped from one clock and sum exactly.
        assert!((queue + spread - ttft).abs() < 1e-6, "{queue} + {spread} != {ttft}");
        // 32 tokens at 2/step: the spread spans 16 prefill calls, so it
        // must dominate the post-admission latency (strictly positive).
        assert!(spread > 0.0);
        assert_eq!(s.engine().prefill_calls, 16);
    }

    // -- legacy threaded Server ------------------------------------------

    #[test]
    fn server_round_trips_requests() {
        let mut server = Server::spawn(|| {
            Ok(move |req: &Request| {
                // Echo worker: "generates" the reversed prompt.
                let mut out = req.prompt.clone();
                out.reverse();
                out.truncate(req.max_new_tokens);
                Ok((out, 0.5))
            })
        });
        let id0 = server.submit(Request { prompt: b"abc".to_vec(), max_new_tokens: 8 }).unwrap();
        let id1 = server.submit(Request { prompt: b"hello".to_vec(), max_new_tokens: 2 }).unwrap();
        let r0 = server.recv().unwrap();
        let r1 = server.recv().unwrap();
        assert_eq!(r0.id, id0);
        assert_eq!(r0.completion, b"cba".to_vec());
        assert_eq!(r1.id, id1);
        assert_eq!(r1.completion, b"ol".to_vec());
    }

    #[test]
    fn server_surfaces_dead_worker_instead_of_hanging() {
        type ServeFn = fn(&Request) -> Result<(Vec<u8>, f64)>;
        let mut server = Server::spawn::<_, ServeFn>(|| Err(anyhow!("boom")));
        // The init failure arrives as an error...
        let err = server.recv().unwrap_err();
        assert!(err.to_string().contains("worker init failed"), "{err:#}");
        // ...and recv fails fast afterwards instead of blocking forever.
        let err = server.recv().unwrap_err();
        assert!(err.to_string().contains("hung up"), "{err:#}");
        // Once the worker is observably dead, submit is rejected loudly
        // instead of dropping the request on the floor.
        for _ in 0..200 {
            if !server.worker_alive() {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
        assert!(!server.worker_alive());
        let err = server
            .submit(Request { prompt: b"x".to_vec(), max_new_tokens: 1 })
            .unwrap_err();
        assert!(err.to_string().contains("dead"), "{err:#}");
        // The terminal reason rides on the rejection and the accessor —
        // callers can tell an init failure from a crash.
        assert!(err.to_string().contains("worker init failed"), "{err:#}");
        let why = server.worker_error().expect("dead worker has a reason");
        assert!(why.contains("worker init failed"), "{why}");
    }

    #[test]
    fn server_surfaces_worker_panic_reason() {
        let mut server = Server::spawn(|| {
            Ok(move |_req: &Request| -> Result<(Vec<u8>, f64)> { panic!("kaboom") })
        });
        server.submit(Request { prompt: b"x".to_vec(), max_new_tokens: 1 }).unwrap();
        // The panic kills the worker before a response is sent.
        assert!(server.recv().is_err());
        for _ in 0..200 {
            if !server.worker_alive() {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
        assert!(!server.worker_alive());
        let why = server.worker_error().expect("dead worker has a reason");
        assert!(why.contains("panicked"), "{why}");
        let err = server
            .submit(Request { prompt: b"y".to_vec(), max_new_tokens: 1 })
            .unwrap_err();
        assert!(err.to_string().contains("panicked"), "{err:#}");
    }

    // -- error kernel: faults, retries, quarantine, deadlines --------------

    /// Wraps a [`MockEngine`] and fails scripted call indices (1-based
    /// count of engine calls actually attempted) with a given
    /// [`ServeError`] — the precise control a unit test needs, where the
    /// seeded [`crate::serve::engine::FaultInjector`] would need draw
    /// bookkeeping (the injector is exercised by the sim-oracle chaos
    /// suites instead).
    struct ScriptedFaults {
        inner: MockEngine,
        calls: u64,
        script: Vec<(u64, ServeError)>,
    }

    impl ScriptedFaults {
        fn new(inner: MockEngine, script: Vec<(u64, ServeError)>) -> Self {
            Self { inner, calls: 0, script }
        }

        fn fail_now(&mut self) -> Option<ServeError> {
            self.calls += 1;
            let call = self.calls;
            self.script.iter().find(|(c, _)| *c == call).map(|(_, e)| e.clone())
        }
    }

    impl DecodeEngine for ScriptedFaults {
        fn slots(&self) -> usize {
            self.inner.slots()
        }

        fn max_seq(&self) -> usize {
            self.inner.max_seq()
        }

        fn prefill_chunk(&self) -> usize {
            self.inner.prefill_chunk()
        }

        fn reset_slot(&mut self, slot: usize) {
            self.inner.reset_slot(slot);
        }

        fn kv_block_size(&self) -> Option<usize> {
            self.inner.kv_block_size()
        }

        fn kv_blocks(&self) -> usize {
            self.inner.kv_blocks()
        }

        fn step(
            &mut self,
            tokens: &[i32],
            pos: &[i32],
            active: &[bool],
        ) -> Result<Vec<Vec<f32>>> {
            if let Some(e) = self.fail_now() {
                return Err(e.into());
            }
            self.inner.step(tokens, pos, active)
        }

        fn prefill(
            &mut self,
            tokens: &[Vec<i32>],
            pos0: &[i32],
            active: &[bool],
        ) -> Result<Vec<Vec<f32>>> {
            if let Some(e) = self.fail_now() {
                return Err(e.into());
            }
            self.inner.prefill(tokens, pos0, active)
        }

        fn step_paged(
            &mut self,
            tokens: &[i32],
            pos: &[i32],
            active: &[bool],
            tables: &[Vec<i32>],
        ) -> Result<Vec<Vec<f32>>> {
            if let Some(e) = self.fail_now() {
                return Err(e.into());
            }
            self.inner.step_paged(tokens, pos, active, tables)
        }

        fn prefill_paged(
            &mut self,
            tokens: &[Vec<i32>],
            pos0: &[i32],
            active: &[bool],
            tables: &[Vec<i32>],
        ) -> Result<Vec<Vec<f32>>> {
            if let Some(e) = self.fail_now() {
                return Err(e.into());
            }
            self.inner.prefill_paged(tokens, pos0, active, tables)
        }

        fn adopt_prefix(&mut self, slot: usize, table: &[i32], cached: usize) -> Result<()> {
            if let Some(e) = self.fail_now() {
                return Err(e.into());
            }
            self.inner.adopt_prefix(slot, table, cached)
        }
    }

    fn slot_fault(slot: usize) -> ServeError {
        ServeError::Slot { slot, what: "scripted".into() }
    }

    fn step_fault() -> ServeError {
        ServeError::Transient { what: "scripted".into() }
    }

    #[test]
    fn slot_fault_retries_then_recovers_byte_identically() {
        let req = || GenRequest::sampled(b"abc", 5, Sampler::top_k(8, 0.9), 7);
        let e = ScriptedFaults::new(MockEngine::new(1, 64, 64), vec![(2, slot_fault(0))]);
        let mut s = Scheduler::new(e, 8).unwrap().with_trace(1024);
        let id = s.submit(req()).unwrap();
        let done = s.run().unwrap();
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].reason, FinishReason::BudgetExhausted);
        assert_eq!(s.metrics.slot_faults, 1);
        assert_eq!(s.metrics.retries_scheduled, 1);
        assert_eq!(s.metrics.slots_recovered, 1);
        assert_eq!(s.metrics.requests_quarantined, 0);
        assert_eq!(s.metrics.requests_completed, 1);
        // The faulted call advanced nothing: the retry replays it and the
        // bytes match a fault-free run exactly.
        let mut clean = sched(1, 64, 8);
        clean.submit(req()).unwrap();
        let want = clean.run().unwrap();
        assert_eq!(done[0].completion, want[0].completion);
        assert_eq!(s.engine().inner.steps, clean.engine().steps);
        let evs: Vec<TraceEvent> = s.trace_records().iter().map(|r| r.event).collect();
        assert!(evs.contains(&TraceEvent::FaultInjected { slot: Some(0) }));
        assert!(evs.contains(&TraceEvent::RetryScheduled {
            slot: Some(0),
            backoff_steps: 1,
            attempt: 1,
        }));
        assert!(evs.contains(&TraceEvent::SlotRecovered { id, slot: 0 }));
        // The trace/metrics cross-check covers the new failure counters.
        crate::serve::trace::verify_against_metrics(&s.trace_records(), &s.metrics).unwrap();
    }

    #[test]
    fn quarantine_after_retry_budget_individual_faults() {
        // Three scripted per-slot faults against the default budget of 3:
        // two retries (backoffs 1 then 2 steps), then quarantine. The
        // engine call indices count only calls actually attempted —
        // cooling steps make no call.
        let script = vec![(1, slot_fault(0)), (2, slot_fault(0)), (3, slot_fault(0))];
        let e = ScriptedFaults::new(MockEngine::new(1, 64, 64), script);
        let mut s = Scheduler::new(e, 8).unwrap().with_trace(1024);
        let id = s.submit(GenRequest::greedy(b"ab", 4)).unwrap();
        let done = s.run().unwrap();
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].id, id);
        assert_eq!(done[0].reason, FinishReason::Quarantined);
        assert!(done[0].completion.is_empty(), "nothing ever successfully fed");
        assert_eq!(s.metrics.slot_faults, 3);
        assert_eq!(s.metrics.retries_scheduled, 2);
        assert_eq!(s.metrics.requests_quarantined, 1);
        assert_eq!(s.metrics.requests_completed, 0, "a quarantine is not a completion");
        assert_eq!(s.metrics.slots_recovered, 0);
        assert_eq!(s.engine().inner.steps, 0, "no engine call ever succeeded");
        assert_eq!(s.in_flight(), 0, "the slot was freed");
        assert!(s.is_idle());
        let evs: Vec<TraceEvent> = s.trace_records().iter().map(|r| r.event).collect();
        assert!(evs.contains(&TraceEvent::RequestFailed { id, slot: Some(0), faults: 3 }));
        assert!(!evs.iter().any(|e| matches!(e, TraceEvent::Completed { .. })));
        crate::serve::trace::verify_against_metrics(&s.trace_records(), &s.metrics).unwrap();
    }

    #[test]
    fn poison_request_cannot_wedge_the_batch() {
        // Two healthy requests ride alongside one that faults every time
        // its slot is in the call... simulated here by blaming slot 0 on
        // three calls: the poison request is quarantined and the healthy
        // ones complete byte-identically to a fault-free run.
        let healthy = |seed| GenRequest::sampled(b"ok", 4, Sampler::top_k(8, 0.9), seed);
        let script = vec![(2, slot_fault(0)), (3, slot_fault(0)), (4, slot_fault(0))];
        let e = ScriptedFaults::new(MockEngine::new(3, 64, 64), script);
        let mut s = Scheduler::new(e, 8).unwrap();
        let poison = s.submit(GenRequest::greedy(b"poison", 4)).unwrap();
        let h1 = s.submit(healthy(1)).unwrap();
        let h2 = s.submit(healthy(2)).unwrap();
        let done = s.run().unwrap();
        assert_eq!(done.len(), 3);
        let by_id = |id| done.iter().find(|c| c.id == id).expect("present");
        assert_eq!(by_id(poison).reason, FinishReason::Quarantined);
        for (id, seed) in [(h1, 1), (h2, 2)] {
            assert_eq!(by_id(id).reason, FinishReason::BudgetExhausted);
            let mut solo = sched(1, 64, 8);
            solo.submit(healthy(seed)).unwrap();
            let want = solo.run().unwrap();
            assert_eq!(by_id(id).completion, want[0].completion, "request {id}");
        }
        assert_eq!(s.metrics.requests_quarantined, 1);
        assert_eq!(s.metrics.requests_completed, 2);
    }

    #[test]
    fn step_fault_streak_evicts_for_warm_restart() {
        let req = || GenRequest::sampled(b"ab", 3, Sampler::top_k(8, 0.9), 11);
        let script = vec![(1, step_fault()), (2, step_fault())];
        let e = ScriptedFaults::new(MockEngine::new(1, 64, 64), script);
        let mut s = Scheduler::new(e, 8).unwrap().with_retry_budget(2).unwrap().with_trace(1024);
        let id = s.submit(req()).unwrap();
        let done = s.run().unwrap();
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].reason, FinishReason::BudgetExhausted);
        assert_eq!(s.metrics.step_faults, 2);
        assert_eq!(s.metrics.retries_scheduled, 1, "streak hit the budget on fault 2");
        assert_eq!(s.metrics.requests_fault_evicted, 1);
        assert_eq!(s.metrics.requests_evicted, 0, "fault evictions are counted apart");
        assert_eq!(s.metrics.requests_quarantined, 0, "the engine was at fault, not the request");
        // The evicted request restarted from scratch with its seed: bytes
        // identical to a fault-free run.
        let mut clean = sched(1, 64, 8);
        clean.submit(req()).unwrap();
        let want = clean.run().unwrap();
        assert_eq!(done[0].completion, want[0].completion);
        let evs: Vec<TraceEvent> = s.trace_records().iter().map(|r| r.event).collect();
        assert!(evs.contains(&TraceEvent::Evicted { id, slot: 0, reason: EvictReason::Fault }));
        assert!(evs.contains(&TraceEvent::RetryScheduled {
            slot: None,
            backoff_steps: 1,
            attempt: 1,
        }));
        crate::serve::trace::verify_against_metrics(&s.trace_records(), &s.metrics).unwrap();
    }

    #[test]
    fn backoff_is_deterministic_across_reruns() {
        // Same scripted faults, two runs: the step-counted backoff (never
        // wall clock) makes the full oracle-scope event sequence — and
        // the engine call count — reproduce exactly.
        let run = || {
            let script = vec![(1, step_fault()), (2, slot_fault(0))];
            let e = ScriptedFaults::new(MockEngine::new(1, 64, 64), script);
            let mut s = Scheduler::new(e, 8).unwrap().with_trace(1024);
            s.submit(GenRequest::sampled(b"abc", 4, Sampler::top_k(8, 0.9), 3)).unwrap();
            let done = s.run().unwrap();
            let evs: Vec<TraceEvent> = s
                .trace_records()
                .iter()
                .map(|r| r.event)
                .filter(|e| e.in_oracle_scope())
                .collect();
            (evs, done[0].completion.clone(), s.engine().calls, s.engine().inner.steps)
        };
        let (ev1, bytes1, calls1, steps1) = run();
        let (ev2, bytes2, calls2, steps2) = run();
        assert_eq!(ev1, ev2);
        assert_eq!(bytes1, bytes2);
        assert_eq!(calls1, calls2);
        assert_eq!(steps1, steps2);
        // And the schedule actually backed off: faults happened.
        assert!(ev1.contains(&TraceEvent::FaultInjected { slot: None }));
        assert!(ev1.contains(&TraceEvent::FaultInjected { slot: Some(0) }));
    }

    #[test]
    fn deadline_sheds_queued_request_at_admission() {
        let mut s = sched(1, 64, 8).with_trace(1024);
        let long = s.submit(GenRequest::greedy(b"aaaa", 40)).unwrap();
        let doomed = s
            .submit(GenRequest::greedy(b"bbbb", 4).with_deadline_steps(2))
            .unwrap();
        let d1 = s.step().unwrap(); // long admitted, doomed queued
        assert!(d1.is_empty());
        let d2 = s.step().unwrap(); // step 2: doomed expires in the queue
        assert_eq!(d2.len(), 1);
        assert_eq!(d2[0].id, doomed);
        assert_eq!(d2[0].reason, FinishReason::DeadlineExpired);
        assert!(d2[0].completion.is_empty());
        assert!(d2[0].ttft_ms.is_none());
        assert_eq!(s.metrics.deadline_shed_queued, 1);
        assert_eq!(s.metrics.deadline_shed_inflight, 0);
        let rest = s.run().unwrap();
        assert_eq!(rest.len(), 1);
        assert_eq!(rest[0].id, long);
        assert_eq!(rest[0].reason, FinishReason::BudgetExhausted);
        assert_eq!(s.metrics.requests_completed, 1, "sheds are not completions");
        let evs: Vec<TraceEvent> = s.trace_records().iter().map(|r| r.event).collect();
        assert!(evs.contains(&TraceEvent::DeadlineExpired { id: doomed, queued: true }));
        crate::serve::trace::verify_against_metrics(&s.trace_records(), &s.metrics).unwrap();
    }

    #[test]
    fn deadline_sheds_in_flight_request_with_partial_output() {
        let mut s = sched(1, 64, 8).with_trace(1024);
        let id = s
            .submit(GenRequest::greedy(b"ab", 100).with_deadline_steps(3))
            .unwrap();
        let done = s.run().unwrap();
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].id, id);
        assert_eq!(done[0].reason, FinishReason::DeadlineExpired);
        // Steps 1-2 fed the prompt and sampled the first token; the shed
        // at step 3 keeps the partial output.
        assert_eq!(done[0].completion.len(), 1);
        assert!(done[0].ttft_ms.is_some());
        assert_eq!(s.metrics.deadline_shed_inflight, 1);
        assert_eq!(s.metrics.deadline_shed_queued, 0);
        assert_eq!(s.metrics.requests_completed, 0);
        assert_eq!(s.in_flight(), 0, "the slot was freed");
        assert!(s.is_idle());
        let evs: Vec<TraceEvent> = s.trace_records().iter().map(|r| r.event).collect();
        assert!(evs.contains(&TraceEvent::DeadlineExpired { id, queued: false }));
        crate::serve::trace::verify_against_metrics(&s.trace_records(), &s.metrics).unwrap();
    }

    #[test]
    fn wall_clock_deadline_sheds_after_elapsed_time() {
        let mut s = sched(1, 64, 8);
        s.submit(GenRequest::greedy(b"ab", 4).with_deadline_ms(5.0)).unwrap();
        std::thread::sleep(std::time::Duration::from_millis(10));
        let done = s.step().unwrap();
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].reason, FinishReason::DeadlineExpired);
        assert_eq!(s.metrics.deadline_shed_queued, 1);
    }

    #[test]
    fn retry_budget_validation_and_adopt_fault_rollback() {
        assert!(sched(1, 64, 8).with_retry_budget(0).is_err());
        // A scripted adopt_prefix fault at admission rolls the watermark
        // back (pool accounting intact) and requeues the request, which
        // then admits cleanly and completes byte-identically.
        let prompt: Vec<u8> = (0..8).map(|j| b'A' + j).collect();
        let req = |seed| GenRequest::sampled(&prompt, 4, Sampler::top_k(8, 0.9), seed);
        // Warm the cache, then fault the warm request's adopt call: with
        // chunk 1 the warmup costs 8 prompt feeds + 3 decode steps = 11
        // calls, so the adopt attempt is call 12.
        let e = ScriptedFaults::new(
            MockEngine::new(2, 32, 64).with_block_pool(16, 4),
            vec![(12, slot_fault(0))],
        );
        let mut s = Scheduler::new(e, 8).unwrap().with_prefix_cache().unwrap();
        s.submit(req(1)).unwrap();
        let cold = s.run().unwrap();
        assert_eq!(s.engine().calls, 11);
        s.submit(req(1)).unwrap();
        let warm = s.run().unwrap();
        assert_eq!(warm.len(), 1);
        assert_eq!(warm[0].reason, FinishReason::BudgetExhausted);
        assert_eq!(warm[0].completion, cold[0].completion, "retry after rollback is exact");
        assert_eq!(s.metrics.slot_faults, 1);
        assert_eq!(s.metrics.retries_scheduled, 1);
        // All transient pages returned: only index-held pages remain.
        let pool = s.slots.pool().unwrap();
        assert_eq!(pool.used_blocks(), s.slots.prefix().unwrap().cached_pages());
        s.slots.check_invariants().unwrap();
    }

    #[test]
    fn fatal_and_unclassified_errors_still_propagate() {
        let e = ScriptedFaults::new(
            MockEngine::new(1, 64, 64),
            vec![(1, ServeError::Fatal { what: "bad artifact".into() })],
        );
        let mut s = Scheduler::new(e, 8).unwrap();
        s.submit(GenRequest::greedy(b"ab", 2)).unwrap();
        let err = s.run().unwrap_err();
        assert!(err.to_string().contains("fatal engine fault"), "{err:#}");
    }

    /// The error-kernel sweep (satellite): inject a single transient
    /// fault at EVERY call index of a mixed prefill/decode/adopt workload
    /// in turn. Wherever the fault lands — mid-prefill, mid-decode, or on
    /// an admission `adopt_prefix` — the bookkeeping invariants hold
    /// after every step, every request still completes, and (one fault
    /// being below the retry budget) every byte matches the clean run.
    #[test]
    fn any_single_fault_index_preserves_invariants_and_bytes() {
        let engine = || MockEngine::new(2, 48, 64).with_prefill_chunk(2).with_block_pool(24, 4);
        let shared: Vec<u8> = (0..10).map(|j| b'a' + j).collect();
        let submit_all = |s: &mut Scheduler<ScriptedFaults>| {
            for seed in 0..3u64 {
                let mut p = shared.clone();
                p.push(b'z' + seed as u8);
                s.submit(GenRequest::sampled(&p, 4, Sampler::top_k(8, 0.9), seed)).unwrap();
            }
        };
        let mut clean = Scheduler::new(ScriptedFaults::new(engine(), vec![]), 8)
            .unwrap()
            .with_prefix_cache()
            .unwrap();
        submit_all(&mut clean);
        let want = clean.run().unwrap();
        assert_eq!(want.len(), 3);
        let want_for = |id: u64| want.iter().find(|c| c.id == id).map(|c| &c.completion);
        let total_calls = clean.engine().calls;
        assert!(total_calls > 10, "workload too small to sweep");
        for k in 1..=total_calls {
            let e = ScriptedFaults::new(engine(), vec![(k, step_fault())]);
            let mut s = Scheduler::new(e, 8).unwrap().with_prefix_cache().unwrap();
            submit_all(&mut s);
            let mut done = Vec::new();
            while !s.is_idle() {
                done.extend(
                    s.step().unwrap_or_else(|e| panic!("fault at call {k}: step failed: {e}")),
                );
                s.check_invariants().unwrap_or_else(|e| panic!("fault at call {k}: {e}"));
            }
            assert_eq!(done.len(), want.len(), "fault at call {k} lost a request");
            for c in &done {
                assert_eq!(
                    c.reason,
                    FinishReason::BudgetExhausted,
                    "fault at call {k}: request {} failed",
                    c.id
                );
                assert_eq!(
                    Some(&c.completion),
                    want_for(c.id),
                    "fault at call {k}: request {} diverged",
                    c.id
                );
            }
        }
    }
}
