//! Continuous-batching scheduler + the legacy threaded FIFO front.
//!
//! [`Scheduler`] drives a [`DecodeEngine`] one engine call at a time.
//! Before every call it admits pending requests into free KV-cache slots
//! (so a request submitted mid-decode joins the running batch on the very
//! next step after a slot frees — no draining). What the call *is* depends
//! on the engine's prefill support:
//!
//! * engines with a multi-token prefill graph (`prefill_chunk() > 1`):
//!   a newly admitted request's prompt is consumed in `ceil(len/T)`
//!   batched prefill calls — all prefilling slots share each call — and
//!   the chunk that completes a prompt yields the logits for the request's
//!   first token. Only then does the request enter the per-token decode
//!   batch. Decode-phase slots idle during a prefill call (the classic
//!   chunked-prefill trade: much better TTFT, occasional decode hiccup).
//! * engines without one (`prefill_chunk() == 1`): prompt feeding and
//!   generation share the decode step exactly as before — one token per
//!   slot per step, prefilling and decoding slots batched together.
//!
//! Each step samples continuations per request and retires finished
//! requests. Admission is bounded: [`Scheduler::submit`] applies
//! backpressure once the queue is full instead of buffering unboundedly.
//! TTFT is always measured from *enqueue* (submit), never from admission
//! or step start, so queue wait is visible in the latency metrics.
//!
//! PJRT handles are not `Send`, so the scheduler is single-threaded by
//! design; the batching parallelism lives *inside* the engine step. The
//! old one-request-at-a-time [`Server`] (worker thread + channels) is kept
//! for callers that want a threaded front over a factory closure.

use std::collections::VecDeque;
use std::sync::mpsc;
use std::time::Instant;

use anyhow::{anyhow, bail, Result};

use crate::serve::engine::DecodeEngine;
use crate::serve::metrics::ServingMetrics;
use crate::serve::sampling::Sampler;
use crate::serve::slots::SlotMap;
use crate::util::prng::Prng;

/// A generation request for the continuous-batching scheduler.
#[derive(Clone, Debug)]
pub struct GenRequest {
    pub prompt: Vec<u8>,
    pub max_new_tokens: usize,
    pub sampler: Sampler,
    /// Seed for this request's sampler PRNG (same seed + same model =>
    /// same completion, at any batch size).
    pub seed: u64,
}

impl GenRequest {
    pub fn greedy(prompt: &[u8], max_new_tokens: usize) -> Self {
        Self { prompt: prompt.to_vec(), max_new_tokens, sampler: Sampler::greedy(), seed: 0 }
    }

    pub fn sampled(prompt: &[u8], max_new_tokens: usize, sampler: Sampler, seed: u64) -> Self {
        Self { prompt: prompt.to_vec(), max_new_tokens, sampler, seed }
    }
}

/// A finished generation.
#[derive(Clone, Debug)]
pub struct Completion {
    pub id: u64,
    pub prompt: Vec<u8>,
    pub completion: Vec<u8>,
    /// Enqueue (submit) -> first generated token (ms), queue wait included.
    /// None if nothing was generated (e.g. zero budget).
    pub ttft_ms: Option<f64>,
    /// Enqueue (submit) -> completion (ms), including queue wait.
    pub latency_ms: f64,
}

/// Per-slot in-flight request state.
struct Active {
    id: u64,
    prompt: Vec<i32>,
    /// Prompt tokens fed so far.
    fed: usize,
    generated: Vec<u8>,
    max_new: usize,
    sampler: Sampler,
    rng: Prng,
    last_token: i32,
    submitted: Instant,
    ttft_us: Option<f64>,
}

/// The continuous-batching loop over one [`DecodeEngine`].
pub struct Scheduler<E: DecodeEngine> {
    engine: E,
    slots: SlotMap,
    active: Vec<Option<Active>>,
    pending: VecDeque<(u64, GenRequest, Instant)>,
    max_queue: usize,
    next_id: u64,
    pub metrics: ServingMetrics,
}

impl<E: DecodeEngine> Scheduler<E> {
    /// `max_queue` bounds the admission queue (backpressure threshold); it
    /// does not bound in-flight requests, which are capped by the engine's
    /// slot count.
    pub fn new(engine: E, max_queue: usize) -> Result<Self> {
        if engine.slots() == 0 {
            bail!("engine has no slots");
        }
        let n = engine.slots();
        let max_seq = engine.max_seq();
        Ok(Self {
            engine,
            slots: SlotMap::new(n, max_seq),
            active: (0..n).map(|_| None).collect(),
            pending: VecDeque::new(),
            max_queue: max_queue.max(1),
            next_id: 0,
            metrics: ServingMetrics::new(),
        })
    }

    pub fn engine(&self) -> &E {
        &self.engine
    }

    pub fn engine_mut(&mut self) -> &mut E {
        &mut self.engine
    }

    pub fn queue_depth(&self) -> usize {
        self.pending.len()
    }

    pub fn in_flight(&self) -> usize {
        self.slots.active_count()
    }

    pub fn slot_capacity(&self) -> usize {
        self.slots.capacity()
    }

    pub fn has_queue_capacity(&self) -> bool {
        self.pending.len() < self.max_queue
    }

    pub fn is_idle(&self) -> bool {
        self.pending.is_empty() && self.slots.active_count() == 0
    }

    /// Enqueue a request; fails with a backpressure error when the
    /// admission queue is full (callers should retry after draining).
    pub fn submit(&mut self, req: GenRequest) -> Result<u64> {
        if req.prompt.is_empty() {
            bail!("empty prompt");
        }
        if req.prompt.len() >= self.engine.max_seq() {
            bail!(
                "prompt of {} tokens cannot fit the {}-position KV cache",
                req.prompt.len(),
                self.engine.max_seq()
            );
        }
        if self.pending.len() >= self.max_queue {
            bail!(
                "admission queue full ({} pending, limit {}): backpressure",
                self.pending.len(),
                self.max_queue
            );
        }
        let id = self.next_id;
        self.next_id += 1;
        self.pending.push_back((id, req, Instant::now()));
        Ok(id)
    }

    /// Cancel a request by id: drop it from the admission queue, or evict
    /// it mid-flight — its slot frees immediately and the next pending
    /// request joins the batch on the following step. Returns `false` if
    /// the id is unknown (already completed or never submitted).
    pub fn cancel(&mut self, id: u64) -> Result<bool> {
        if let Some(i) = self.pending.iter().position(|(pid, _, _)| *pid == id) {
            self.pending.remove(i);
            return Ok(true);
        }
        for b in 0..self.active.len() {
            if self.active[b].as_ref().map(|a| a.id) == Some(id) {
                self.active[b] = None;
                self.slots.release(b)?;
                self.engine.reset_slot(b);
                return Ok(true);
            }
        }
        Ok(false)
    }

    /// Move pending requests into free slots (at most one per free slot).
    fn admit(&mut self) {
        while !self.pending.is_empty() && self.slots.free_count() > 0 {
            let (id, req, submitted) = self.pending.pop_front().expect("non-empty");
            let slot = self.slots.allocate(id).expect("free slot");
            self.engine.reset_slot(slot);
            self.active[slot] = Some(Active {
                id,
                prompt: req.prompt.iter().map(|&b| b as i32).collect(),
                fed: 0,
                generated: Vec::new(),
                max_new: req.max_new_tokens,
                sampler: req.sampler,
                rng: Prng::new(req.seed),
                last_token: 0,
                submitted,
                ttft_us: None,
            });
        }
    }

    /// Shared post-engine bookkeeping for one occupied slot: once its
    /// prompt is fully fed, sample the next token from `logits` (respecting
    /// the budget and stamping TTFT exactly once, from enqueue), then
    /// decide whether the request is finished — budget exhausted or KV
    /// cache full. Both the prefill and decode passes end in this exact
    /// logic, so stop semantics can never diverge between them.
    fn sample_and_check(
        &mut self,
        b: usize,
        logits: &[f32],
        new_pos: usize,
        max_seq: usize,
        new_tokens: &mut usize,
    ) -> bool {
        let a = self.active[b].as_mut().expect("occupied slot");
        let mut finished = false;
        if a.fed >= a.prompt.len() {
            // This call's logits predict the request's next token.
            if a.generated.len() < a.max_new {
                let sampler = a.sampler;
                let next = sampler.sample(logits, &mut a.rng);
                a.last_token = next as i32;
                a.generated.push(next as u8);
                *new_tokens += 1;
                if a.ttft_us.is_none() {
                    a.ttft_us = Some(a.submitted.elapsed().as_secs_f64() * 1e6);
                }
            }
            if a.generated.len() >= a.max_new {
                finished = true;
            }
        }
        // Out of cache: stop whatever state we're in (possibly with a
        // truncated completion).
        finished || new_pos >= max_seq
    }

    /// Retire slot `b`: free it and convert its state into a [`Completion`].
    fn retire(&mut self, b: usize) -> Result<Completion> {
        let a = self.active[b].take().expect("retiring an occupied slot");
        self.slots.release(b)?;
        let request_us = a.submitted.elapsed().as_secs_f64() * 1e6;
        self.metrics.record_completion(request_us, a.ttft_us);
        Ok(Completion {
            id: a.id,
            prompt: a.prompt.iter().map(|&t| t as u8).collect(),
            completion: a.generated,
            ttft_ms: a.ttft_us.map(|us| us / 1e3),
            latency_ms: request_us / 1e3,
        })
    }

    /// One scheduler iteration (a single engine call): admit, then either a
    /// batched prefill call — when the engine has a multi-token prefill
    /// graph and any slot still owes prompt tokens — or a decode step.
    /// Returns the completions that finished on this iteration (empty when
    /// idle).
    pub fn step(&mut self) -> Result<Vec<Completion>> {
        self.admit();
        let chunk = self.engine.prefill_chunk().max(1);
        if chunk > 1
            && self
                .active
                .iter()
                .any(|s| s.as_ref().map_or(false, |a| a.fed < a.prompt.len()))
        {
            return self.prefill_pass(chunk);
        }
        self.decode_pass()
    }

    /// One batched prefill call over every slot that still owes prompt
    /// tokens (decode-phase slots idle for this call). The chunk that
    /// completes a slot's prompt yields the logits predicting its first
    /// token, which is sampled right here — TTFT is set at the end of the
    /// last prefill chunk, `ceil(len/chunk)` engine calls after admission.
    fn prefill_pass(&mut self, chunk: usize) -> Result<Vec<Completion>> {
        let n = self.engine.slots();
        let max_seq = self.engine.max_seq();
        let mut tokens: Vec<Vec<i32>> = vec![Vec::new(); n];
        let mut pos0 = vec![0i32; n];
        let mut active = vec![false; n];
        for b in 0..n {
            if let Some(a) = &self.active[b] {
                if a.fed < a.prompt.len() {
                    let take = chunk.min(a.prompt.len() - a.fed);
                    tokens[b] = a.prompt[a.fed..a.fed + take].to_vec();
                    pos0[b] = self.slots.pos(b).expect("occupied slot has a position") as i32;
                    active[b] = true;
                }
            }
        }

        let t0 = Instant::now();
        let logits = self.engine.prefill(&tokens, &pos0, &active)?;
        let step_us = t0.elapsed().as_secs_f64() * 1e6;

        let mut prompt_tokens = 0usize;
        let mut new_tokens = 0usize;
        let mut done = Vec::new();
        for b in 0..n {
            if !active[b] {
                continue;
            }
            let fed_now = tokens[b].len();
            let new_pos = self.slots.advance_by(b, fed_now)?;
            self.active[b].as_mut().expect("active slot").fed += fed_now;
            prompt_tokens += fed_now;
            // (new_pos >= max_seq is unreachable while submit() rejects
            // prompts >= max_seq, but sample_and_check keeps the guard so a
            // future admission policy can't silently overrun.)
            if self.sample_and_check(b, &logits[b], new_pos, max_seq, &mut new_tokens) {
                done.push(self.retire(b)?);
            }
        }
        self.metrics.record_prefill(
            step_us,
            prompt_tokens,
            new_tokens,
            self.slots.active_count(),
            self.pending.len(),
        );
        Ok(done)
    }

    /// One decode step over every occupied slot. With `prefill_chunk() == 1`
    /// this also feeds prompts one token at a time (prefilling and decoding
    /// slots batched together), preserving the original interleaved path.
    fn decode_pass(&mut self) -> Result<Vec<Completion>> {
        let n = self.engine.slots();
        let max_seq = self.engine.max_seq();
        let mut tokens = vec![0i32; n];
        let mut pos = vec![0i32; n];
        let mut active = vec![false; n];
        let mut any = false;
        for b in 0..n {
            if let Some(a) = &self.active[b] {
                any = true;
                active[b] = true;
                tokens[b] = if a.fed < a.prompt.len() { a.prompt[a.fed] } else { a.last_token };
                pos[b] = self.slots.pos(b).expect("occupied slot has a position") as i32;
            }
        }
        if !any {
            return Ok(Vec::new());
        }

        let t0 = Instant::now();
        let logits = self.engine.step(&tokens, &pos, &active)?;
        let step_us = t0.elapsed().as_secs_f64() * 1e6;

        let mut new_tokens = 0usize;
        let mut done = Vec::new();
        for b in 0..n {
            if self.active[b].is_none() {
                continue;
            }
            let new_pos = self.slots.advance(b)?;
            {
                let a = self.active[b].as_mut().expect("checked above");
                if a.fed < a.prompt.len() {
                    a.fed += 1;
                }
            }
            if self.sample_and_check(b, &logits[b], new_pos, max_seq, &mut new_tokens) {
                done.push(self.retire(b)?);
            }
        }
        self.metrics.record_step(step_us, new_tokens, self.slots.active_count(), self.pending.len());
        Ok(done)
    }

    /// Step until every pending and in-flight request has completed.
    pub fn run(&mut self) -> Result<Vec<Completion>> {
        let mut all = Vec::new();
        while !self.is_idle() {
            all.extend(self.step()?);
        }
        Ok(all)
    }

    /// Serve a whole workload, feeding the admission queue as backpressure
    /// allows. Completions are returned in finish order.
    pub fn serve_all(
        &mut self,
        reqs: impl IntoIterator<Item = GenRequest>,
    ) -> Result<Vec<Completion>> {
        let mut it = reqs.into_iter();
        let mut next = it.next();
        let mut all = Vec::new();
        loop {
            while next.is_some() && self.has_queue_capacity() {
                self.submit(next.take().expect("checked"))?;
                next = it.next();
            }
            if next.is_none() && self.is_idle() {
                break;
            }
            all.extend(self.step()?);
        }
        Ok(all)
    }
}

// ---------------------------------------------------------------------------
// Legacy threaded front: a worker thread owns the PJRT state (it is !Send);
// clients submit prompts over a channel and receive completions.
// ---------------------------------------------------------------------------

/// A generation request for the threaded [`Server`].
pub struct Request {
    pub prompt: Vec<u8>,
    pub max_new_tokens: usize,
}

/// A completed [`Server`] generation.
#[derive(Debug)]
pub struct Response {
    pub id: usize,
    pub completion: Vec<u8>,
    pub latency_ms: f64,
    pub ms_per_token: f64,
}

enum Msg {
    Submit(usize, Request),
    Shutdown,
}

/// Single-worker serving front: FIFO queue + per-request KV-cache reset.
/// (PJRT handles are not `Send`, so the worker thread constructs everything
/// it needs via the factory closure and owns it for its lifetime.)
///
/// For batched serving, run a [`Scheduler`] on the owning thread instead.
pub struct Server {
    tx: mpsc::Sender<Msg>,
    rx_resp: mpsc::Receiver<Result<Response, String>>,
    handle: Option<std::thread::JoinHandle<()>>,
    next_id: usize,
}

impl Server {
    /// `factory` runs on the worker thread and must produce a closure that
    /// serves one request (typically wrapping a fresh GenerationSession).
    pub fn spawn<F, S>(factory: F) -> Self
    where
        F: FnOnce() -> Result<S> + Send + 'static,
        S: FnMut(&Request) -> Result<(Vec<u8>, f64)>,
    {
        let (tx, rx) = mpsc::channel::<Msg>();
        let (tx_resp, rx_resp) = mpsc::channel();
        let handle = std::thread::spawn(move || {
            let mut serve_one = match factory() {
                Ok(s) => s,
                Err(e) => {
                    let _ = tx_resp.send(Err(format!("worker init failed: {e:#}")));
                    return;
                }
            };
            while let Ok(msg) = rx.recv() {
                match msg {
                    Msg::Submit(id, req) => {
                        let t0 = Instant::now();
                        let resp = serve_one(&req)
                            .map(|(completion, ms_per_token)| Response {
                                id,
                                completion,
                                latency_ms: t0.elapsed().as_secs_f64() * 1e3,
                                ms_per_token,
                            })
                            .map_err(|e| format!("{e:#}"));
                        let _ = tx_resp.send(resp);
                    }
                    Msg::Shutdown => break,
                }
            }
        });
        Self { tx, rx_resp, handle: Some(handle), next_id: 0 }
    }

    /// Is the worker thread still running? (It exits on factory failure,
    /// shutdown, or panic.)
    pub fn worker_alive(&self) -> bool {
        self.handle.as_ref().map(|h| !h.is_finished()).unwrap_or(false)
    }

    /// Enqueue a request. Fails — instead of silently dropping the message —
    /// when the worker thread has died, so callers never end up waiting on
    /// a response that can no longer arrive.
    pub fn submit(&mut self, req: Request) -> Result<usize> {
        if !self.worker_alive() {
            bail!("server worker is dead; request rejected");
        }
        let id = self.next_id;
        self.tx
            .send(Msg::Submit(id, req))
            .map_err(|_| anyhow!("server worker hung up; request rejected"))?;
        self.next_id += 1;
        Ok(id)
    }

    /// Receive the next completion. Fails fast (rather than blocking
    /// forever) once the worker has hung up and the response queue drained.
    pub fn recv(&self) -> Result<Response> {
        match self.rx_resp.recv() {
            Ok(Ok(r)) => Ok(r),
            Ok(Err(e)) => Err(anyhow!(e)),
            Err(_) => Err(anyhow!(
                "server worker hung up; no further responses will arrive"
            )),
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::engine::MockEngine;

    fn sched(slots: usize, max_seq: usize, max_queue: usize) -> Scheduler<MockEngine> {
        Scheduler::new(MockEngine::new(slots, max_seq, 64), max_queue).unwrap()
    }

    #[test]
    fn single_request_generates_exact_budget() {
        let mut s = sched(1, 64, 8);
        let id = s.submit(GenRequest::greedy(b"abc", 5)).unwrap();
        let done = s.run().unwrap();
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].id, id);
        assert_eq!(done[0].prompt, b"abc".to_vec());
        assert_eq!(done[0].completion.len(), 5);
        assert!(done[0].ttft_ms.is_some());
        // prompt(3) + 5 tokens, last one never fed back: 7 steps.
        assert_eq!(s.engine().steps, 7);
        assert_eq!(s.metrics.tokens_generated, 5);
        assert_eq!(s.metrics.requests_completed, 1);
        assert!(s.is_idle());
    }

    #[test]
    fn mid_flight_join_and_no_drain() {
        // THE continuous-batching acceptance test: with both slots busy, a
        // late request is admitted the step after a slot frees and finishes
        // while the long request is still decoding.
        let mut s = sched(2, 256, 16);
        let long = s.submit(GenRequest::greedy(b"LLLL", 60)).unwrap();
        let short = s.submit(GenRequest::greedy(b"ss", 3)).unwrap();
        // Run a few steps: both slots occupied, batch is full.
        for _ in 0..3 {
            s.step().unwrap();
            assert_eq!(s.in_flight(), 2);
            assert!(s.in_flight() <= s.slot_capacity());
        }
        // Submit mid-decode; no free slot yet, so it queues.
        let late = s.submit(GenRequest::greedy(b"late", 4)).unwrap();
        assert_eq!(s.queue_depth(), 1);

        let mut finish_order = Vec::new();
        let mut joined_at_step = None;
        let mut step_no = 3;
        while !s.is_idle() {
            let done = s.step().unwrap();
            step_no += 1;
            assert!(s.in_flight() <= s.slot_capacity(), "slot accounting exceeded capacity");
            if joined_at_step.is_none() && s.queue_depth() == 0 {
                joined_at_step = Some(step_no);
            }
            finish_order.extend(done.into_iter().map(|c| c.id));
        }
        // The short request freed its slot, the late request joined and
        // completed while `long` was still running.
        assert_eq!(finish_order[0], short);
        assert_eq!(finish_order[1], late);
        assert_eq!(finish_order[2], long);
        assert!(joined_at_step.is_some(), "late request never admitted");
        // Long runs 4 + 60 - 1 = 63 steps; late must be done well before.
        assert!(s.engine().steps < 70);
    }

    #[test]
    fn slot_reuse_restarts_positions() {
        // Two sequential short requests through a single slot: the second
        // must restart at pos 0 (MockEngine would error on position drift
        // or a missing reset).
        let mut s = sched(1, 16, 8);
        s.submit(GenRequest::greedy(b"one", 2)).unwrap();
        s.submit(GenRequest::greedy(b"two!", 2)).unwrap();
        let done = s.run().unwrap();
        assert_eq!(done.len(), 2);
        assert_eq!(done[0].completion.len(), 2);
        assert_eq!(done[1].completion.len(), 2);
    }

    #[test]
    fn backpressure_rejects_when_queue_full() {
        let mut s = sched(1, 64, 2);
        s.submit(GenRequest::greedy(b"a", 4)).unwrap();
        s.submit(GenRequest::greedy(b"b", 4)).unwrap();
        let err = s.submit(GenRequest::greedy(b"c", 4)).unwrap_err();
        assert!(err.to_string().contains("backpressure"), "{err:#}");
        // Draining restores capacity: the first step admits one request
        // into the slot, freeing a queue position.
        s.step().unwrap();
        assert!(s.has_queue_capacity());
        s.submit(GenRequest::greedy(b"c", 4)).unwrap();
        let done = s.run().unwrap();
        assert_eq!(done.len(), 3);
    }

    #[test]
    fn rejects_oversized_and_empty_prompts() {
        let mut s = sched(1, 8, 4);
        assert!(s.submit(GenRequest::greedy(b"", 4)).is_err());
        assert!(s.submit(GenRequest::greedy(&[7u8; 9], 4)).is_err());
    }

    #[test]
    fn cache_exhaustion_truncates_completion() {
        let mut s = sched(1, 6, 4);
        // prompt 4 + budget 10 can't fit in 6 positions: 2 tokens max.
        s.submit(GenRequest::greedy(b"abcd", 10)).unwrap();
        let done = s.run().unwrap();
        assert_eq!(done.len(), 1);
        assert!(done[0].completion.len() <= 3, "{:?}", done[0].completion);
        assert!(!done[0].completion.is_empty());
        assert!(s.is_idle());
    }

    #[test]
    fn deterministic_under_fixed_seed_and_batch_invariant() {
        // Same seed => identical tokens; and the generation for a given
        // request is identical at batch 1 and batch 4 (mock logits depend
        // only on history).
        let req = |seed| GenRequest::sampled(b"seeded", 12, Sampler::top_k(8, 3.0), seed);
        let mut s1 = sched(1, 64, 8);
        s1.submit(req(42)).unwrap();
        let d1 = s1.run().unwrap();

        let mut s2 = sched(1, 64, 8);
        s2.submit(req(42)).unwrap();
        let d2 = s2.run().unwrap();
        assert_eq!(d1[0].completion, d2[0].completion);

        let mut s4 = sched(4, 64, 8);
        s4.submit(req(42)).unwrap();
        for i in 0..3 {
            s4.submit(GenRequest::sampled(b"noise", 9, Sampler::top_k(4, 0.9), 100 + i)).unwrap();
        }
        let d4 = s4.run().unwrap();
        let ours = d4.iter().find(|c| c.prompt == b"seeded".to_vec()).unwrap();
        assert_eq!(ours.completion, d1[0].completion);

        // Different seed diverges (with overwhelming probability).
        let mut s3 = sched(1, 64, 8);
        s3.submit(req(43)).unwrap();
        let d3 = s3.run().unwrap();
        assert_ne!(d3[0].completion, d1[0].completion);
    }

    #[test]
    fn serve_all_drains_a_big_workload() {
        let mut s = sched(4, 64, 4);
        let reqs: Vec<GenRequest> = (0..20)
            .map(|i| {
                let prompt = vec![b'a' + (i % 23) as u8; 2 + (i % 5)];
                GenRequest::greedy(&prompt, 3 + (i % 7))
            })
            .collect();
        let done = s.serve_all(reqs).unwrap();
        assert_eq!(done.len(), 20);
        assert_eq!(s.metrics.requests_completed, 20);
        assert!(s.is_idle());
        // Batching actually happened: fewer steps than serial execution
        // would need.
        let serial: usize = done.iter().map(|c| c.prompt.len() + c.completion.len()).sum();
        assert!(s.engine().steps < serial);
    }

    #[test]
    fn cancel_evicts_in_flight_and_queued_requests() {
        let mut s = sched(1, 32, 8);
        let a = s.submit(GenRequest::greedy(b"aaaa", 20)).unwrap();
        let b = s.submit(GenRequest::greedy(b"bb", 2)).unwrap();
        s.step().unwrap(); // `a` occupies the only slot, `b` queues
        assert_eq!(s.in_flight(), 1);
        assert!(s.cancel(a).unwrap());
        assert_eq!(s.in_flight(), 0);
        // The queued request takes over the evicted slot and completes.
        let done = s.run().unwrap();
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].id, b);
        // Unknown / already-finished ids are a no-op.
        assert!(!s.cancel(a).unwrap());
        assert!(!s.cancel(99).unwrap());
        // Cancelling straight from the queue also works.
        let c = s.submit(GenRequest::greedy(b"cc", 2)).unwrap();
        let d = s.submit(GenRequest::greedy(b"dd", 2)).unwrap();
        assert!(s.cancel(d).unwrap());
        let done = s.run().unwrap();
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].id, c);
    }

    #[test]
    fn zero_budget_completes_after_prompt() {
        let mut s = sched(1, 16, 4);
        s.submit(GenRequest::greedy(b"xyz", 0)).unwrap();
        let done = s.run().unwrap();
        assert_eq!(done.len(), 1);
        assert!(done[0].completion.is_empty());
        assert!(done[0].ttft_ms.is_none());
    }

    // -- batched multi-token prefill --------------------------------------

    fn sched_prefill(
        slots: usize,
        max_seq: usize,
        max_queue: usize,
        chunk: usize,
    ) -> Scheduler<MockEngine> {
        Scheduler::new(MockEngine::new(slots, max_seq, 64).with_prefill_chunk(chunk), max_queue)
            .unwrap()
    }

    #[test]
    fn prefill_consumes_prompt_in_ceil_len_over_chunk_calls() {
        // THE prefill acceptance check: a 64-token prompt on a T=16 engine
        // reaches its first token after exactly ceil(64/16) = 4 prefill
        // calls, not 64 decode steps.
        let mut s = sched_prefill(1, 128, 8, 16);
        s.submit(GenRequest::greedy(&[b'p'; 64], 8)).unwrap();
        let done = s.run().unwrap();
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].completion.len(), 8);
        assert_eq!(s.engine().prefill_calls, 4);
        // The last prefill call sampled token 1; seven decode steps feed
        // tokens 1..=7 and sample tokens 2..=8 (token 8 is never fed back).
        assert_eq!(s.engine().steps, 7);
        assert_eq!(s.metrics.tokens_prefilled, 64);
        assert_eq!(s.metrics.prefill_us.len(), 4);
        assert_eq!(s.metrics.tokens_generated, 8);
        assert!(s.is_idle());
    }

    #[test]
    fn prefill_and_token_loop_produce_identical_completions() {
        // The prefill path is a pure latency optimisation: for any chunk
        // size the generated bytes must be identical to the token-by-token
        // path (mock logits depend only on history, and the L2 pytest
        // proves the same for the real graphs).
        let req =
            |seed| GenRequest::sampled(b"the quick brown fox", 12, Sampler::top_k(8, 0.9), seed);
        let mut a = sched(1, 64, 8);
        a.submit(req(5)).unwrap();
        let da = a.run().unwrap();
        for chunk in [2, 7, 16, 64] {
            let mut b = sched_prefill(1, 64, 8, chunk);
            b.submit(req(5)).unwrap();
            let db = b.run().unwrap();
            assert_eq!(da[0].completion, db[0].completion, "chunk {chunk}");
        }
    }

    #[test]
    fn prefill_multi_slot_staggered_and_mid_flight_join() {
        // Two prompts of different lengths prefill together (sharing
        // calls), a latecomer prefills into a freed slot mid-decode, and
        // everyone's budget comes out exact.
        let mut s = sched_prefill(2, 256, 16, 8);
        let long = s.submit(GenRequest::greedy(&[b'L'; 20], 40)).unwrap();
        let short = s.submit(GenRequest::greedy(&[b's'; 3], 3)).unwrap();
        // 20-token and 3-token prompts overlap in the first call; the long
        // prompt needs ceil(20/8) = 3 calls total.
        let mut done = Vec::new();
        while done.is_empty() {
            done.extend(s.step().unwrap());
        }
        assert_eq!(done[0].id, short);
        assert_eq!(s.engine().prefill_calls, 3);
        assert_eq!(s.metrics.tokens_prefilled, 23);
        // Latecomer joins while `long` is still decoding: one more prefill
        // call (4 tokens < chunk), then it decodes alongside `long`.
        let late = s.submit(GenRequest::greedy(b"late", 4)).unwrap();
        let rest = s.run().unwrap();
        let order: Vec<u64> = rest.iter().map(|c| c.id).collect();
        assert_eq!(order, vec![late, long]);
        assert_eq!(s.engine().prefill_calls, 4);
        assert_eq!(s.metrics.tokens_prefilled, 27);
        assert_eq!(rest[0].completion.len(), 4);
        assert_eq!(rest[1].completion.len(), 40);
    }

    #[test]
    fn prefill_zero_budget_completes_without_ttft() {
        let mut s = sched_prefill(1, 32, 4, 8);
        s.submit(GenRequest::greedy(b"xyz", 0)).unwrap();
        let done = s.run().unwrap();
        assert_eq!(done.len(), 1);
        assert!(done[0].completion.is_empty());
        assert!(done[0].ttft_ms.is_none());
        assert_eq!(s.engine().prefill_calls, 1);
        assert_eq!(s.engine().steps, 0);
    }

    #[test]
    fn cancel_mid_prefill_frees_slot_for_queued_request() {
        let mut s = sched_prefill(1, 128, 8, 8);
        let a = s.submit(GenRequest::greedy(&[b'a'; 30], 5)).unwrap();
        let b = s.submit(GenRequest::greedy(b"bb", 2)).unwrap();
        s.step().unwrap(); // `a` holds the slot, one chunk fed
        assert!(s.cancel(a).unwrap());
        assert_eq!(s.in_flight(), 0);
        // The queued request reuses the half-prefilled slot from pos 0
        // (MockEngine would reject a missing reset).
        let done = s.run().unwrap();
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].id, b);
        assert_eq!(done[0].completion.len(), 2);
    }

    #[test]
    fn ttft_measured_from_enqueue_not_step_start() {
        // Regression: TTFT must include the time a request sat in the
        // admission queue (enqueue -> first token); measuring from
        // admission or from the start of the producing step would hide
        // queue wait entirely.
        let mut s = sched(1, 64, 8);
        s.submit(GenRequest::greedy(b"abcd", 2)).unwrap();
        std::thread::sleep(std::time::Duration::from_millis(15));
        let done = s.run().unwrap();
        let ttft = done[0].ttft_ms.expect("generated a token");
        assert!(ttft >= 15.0, "TTFT {ttft}ms lost the queue wait");
        // The prefill path measures from the same clock...
        let mut s = sched_prefill(1, 64, 8, 8);
        s.submit(GenRequest::greedy(b"abcd", 2)).unwrap();
        std::thread::sleep(std::time::Duration::from_millis(15));
        let done = s.run().unwrap();
        let ttft = done[0].ttft_ms.expect("generated a token");
        assert!(ttft >= 15.0, "prefill TTFT {ttft}ms lost the queue wait");
        // ...and the aggregate metric carries the same number.
        assert!(s.metrics.ttft_ms_p50() >= 15.0);
    }

    // -- legacy threaded Server ------------------------------------------

    #[test]
    fn server_round_trips_requests() {
        let mut server = Server::spawn(|| {
            Ok(move |req: &Request| {
                // Echo worker: "generates" the reversed prompt.
                let mut out = req.prompt.clone();
                out.reverse();
                out.truncate(req.max_new_tokens);
                Ok((out, 0.5))
            })
        });
        let id0 = server.submit(Request { prompt: b"abc".to_vec(), max_new_tokens: 8 }).unwrap();
        let id1 = server.submit(Request { prompt: b"hello".to_vec(), max_new_tokens: 2 }).unwrap();
        let r0 = server.recv().unwrap();
        let r1 = server.recv().unwrap();
        assert_eq!(r0.id, id0);
        assert_eq!(r0.completion, b"cba".to_vec());
        assert_eq!(r1.id, id1);
        assert_eq!(r1.completion, b"ol".to_vec());
    }

    #[test]
    fn server_surfaces_dead_worker_instead_of_hanging() {
        type ServeFn = fn(&Request) -> Result<(Vec<u8>, f64)>;
        let mut server = Server::spawn::<_, ServeFn>(|| Err(anyhow!("boom")));
        // The init failure arrives as an error...
        let err = server.recv().unwrap_err();
        assert!(err.to_string().contains("worker init failed"), "{err:#}");
        // ...and recv fails fast afterwards instead of blocking forever.
        let err = server.recv().unwrap_err();
        assert!(err.to_string().contains("hung up"), "{err:#}");
        // Once the worker is observably dead, submit is rejected loudly
        // instead of dropping the request on the floor.
        for _ in 0..200 {
            if !server.worker_alive() {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
        assert!(!server.worker_alive());
        let err = server
            .submit(Request { prompt: b"x".to_vec(), max_new_tokens: 1 })
            .unwrap_err();
        assert!(err.to_string().contains("dead"), "{err:#}");
    }
}
