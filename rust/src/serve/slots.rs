//! Slot-based KV-cache bookkeeping.
//!
//! The decode artifacts hold one KV cache per batch lane ("slot"); this
//! module owns the accounting: which slots are free, which request occupies
//! which slot, and how far each slot's cache has been written. The cache
//! *contents* live inside the engine (as PJRT literals); correctness of
//! slot reuse comes from the graphs' `idx <= pos` attention mask, so
//! [`SlotMap`] never needs to zero anything — it only has to keep positions
//! honest, which [`crate::serve::MockEngine`] cross-checks in tests.
//!
//! A [`SlotMap`] built with [`SlotMap::paged`] additionally carries one
//! block table per slot over a [`BlockPool`]: instead of assuming a dense
//! `[0, max_seq)` cache range, a slot's positions live in lazily allocated
//! `block_size`-token physical pages ([`SlotMap::ensure_capacity`] grows
//! the table at page boundaries, [`SlotMap::release`] returns the pages to
//! the pool). Positions may never advance past what the table covers.

use anyhow::{bail, Result};

use crate::serve::blocks::BlockPool;

/// Occupancy record for one slot.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SlotInfo {
    /// Request id occupying the slot.
    pub id: u64,
    /// Next cache position to be written (== tokens fed so far).
    pub pos: usize,
}

/// Allocate / free / advance over a fixed set of KV-cache slots with strict
/// capacity accounting: `active_count() + free_count() == capacity()` is an
/// invariant, and positions can never pass `max_seq`.
#[derive(Clone, Debug)]
pub struct SlotMap {
    max_seq: usize,
    state: Vec<Option<SlotInfo>>,
    /// Paged mode: the physical page allocator shared by every slot.
    pool: Option<BlockPool>,
    /// Paged mode: per-slot block table (logical page j -> physical page).
    /// Always empty for free slots and in dense mode.
    tables: Vec<Vec<u32>>,
}

impl SlotMap {
    pub fn new(capacity: usize, max_seq: usize) -> Self {
        Self { max_seq, state: vec![None; capacity], pool: None, tables: vec![Vec::new(); capacity] }
    }

    /// Paged variant: slots share `total_blocks` physical pages of
    /// `block_size` tokens, allocated lazily through
    /// [`SlotMap::ensure_capacity`].
    pub fn paged(capacity: usize, max_seq: usize, total_blocks: usize, block_size: usize) -> Self {
        Self {
            max_seq,
            state: vec![None; capacity],
            pool: Some(BlockPool::new(total_blocks, block_size)),
            tables: vec![Vec::new(); capacity],
        }
    }

    /// The page allocator, when this map is paged.
    pub fn pool(&self) -> Option<&BlockPool> {
        self.pool.as_ref()
    }

    pub fn is_paged(&self) -> bool {
        self.pool.is_some()
    }

    /// A slot's block table (empty when free or dense).
    pub fn table(&self, slot: usize) -> &[u32] {
        &self.tables[slot]
    }

    /// Grow `slot`'s block table until it covers cache positions
    /// `[0, target_pos)`, allocating pages from the pool. Returns `false`
    /// (keeping any pages already granted) when the pool runs dry — the
    /// scheduler then evicts a request and retries. Errors on free slots,
    /// dense maps, or a target past `max_seq`.
    pub fn ensure_capacity(&mut self, slot: usize, target_pos: usize) -> Result<bool> {
        let Some(pool) = self.pool.as_mut() else {
            bail!("ensure_capacity on a dense SlotMap");
        };
        if self.state.get(slot).copied().flatten().is_none() {
            bail!("slot {slot} grown while free");
        }
        if target_pos > self.max_seq {
            bail!("slot {slot}: target {target_pos} past max_seq {}", self.max_seq);
        }
        let needed = pool.blocks_for(target_pos);
        while self.tables[slot].len() < needed {
            match pool.allocate() {
                Some(b) => self.tables[slot].push(b),
                None => return Ok(false),
            }
        }
        Ok(true)
    }

    pub fn capacity(&self) -> usize {
        self.state.len()
    }

    pub fn max_seq(&self) -> usize {
        self.max_seq
    }

    pub fn active_count(&self) -> usize {
        self.state.iter().filter(|s| s.is_some()).count()
    }

    pub fn free_count(&self) -> usize {
        self.capacity() - self.active_count()
    }

    pub fn is_active(&self, slot: usize) -> bool {
        self.state.get(slot).map(|s| s.is_some()).unwrap_or(false)
    }

    /// Occupant of a slot, if any.
    pub fn info(&self, slot: usize) -> Option<SlotInfo> {
        self.state.get(slot).copied().flatten()
    }

    /// Next write position of an occupied slot.
    pub fn pos(&self, slot: usize) -> Option<usize> {
        self.info(slot).map(|s| s.pos)
    }

    /// Claim the lowest-numbered free slot for request `id`; positions start
    /// at 0. Returns `None` when every slot is occupied.
    pub fn allocate(&mut self, id: u64) -> Option<usize> {
        let slot = self.state.iter().position(|s| s.is_none())?;
        self.state[slot] = Some(SlotInfo { id, pos: 0 });
        Some(slot)
    }

    /// Release an occupied slot (returning its pages to the pool in paged
    /// mode); returns the request id it held.
    pub fn release(&mut self, slot: usize) -> Result<u64> {
        if slot >= self.state.len() {
            bail!("slot {slot} out of range (capacity {})", self.capacity());
        }
        match self.state[slot].take() {
            Some(info) => {
                if let Some(pool) = self.pool.as_mut() {
                    let blocks = std::mem::take(&mut self.tables[slot]);
                    pool.release(&blocks)?;
                }
                Ok(info.id)
            }
            None => bail!("slot {slot} released twice"),
        }
    }

    /// Advance an occupied slot's position by one written token; returns the
    /// new position. Fails if the slot is free or its cache is already full.
    pub fn advance(&mut self, slot: usize) -> Result<usize> {
        self.advance_by(slot, 1)
    }

    /// Advance an occupied slot's position by `n` written tokens (one
    /// batched prefill chunk); returns the new position. Fails if the slot
    /// is free or the advance would pass `max_seq` — positions stay honest
    /// even for multi-token writes.
    pub fn advance_by(&mut self, slot: usize, n: usize) -> Result<usize> {
        let max_seq = self.max_seq;
        // Paged: the advance must stay inside the pages the table covers —
        // a position without a page would scatter into the out-of-range
        // sentinel and silently drop the KV write.
        let covered = match (&self.pool, self.tables.get(slot)) {
            (Some(pool), Some(table)) => Some(table.len() * pool.block_size()),
            _ => None,
        };
        match self.state.get_mut(slot) {
            Some(Some(info)) => {
                if n == 0 {
                    bail!("slot {slot} advanced by zero tokens");
                }
                if info.pos + n > max_seq {
                    bail!(
                        "slot {slot}: advance by {n} passes KV capacity \
                         ({} + {n} > {max_seq})",
                        info.pos
                    );
                }
                if let Some(covered) = covered {
                    if info.pos + n > covered {
                        bail!(
                            "slot {slot}: advance by {n} passes its block table \
                             ({} + {n} > {covered} covered; ensure_capacity first)",
                            info.pos
                        );
                    }
                }
                info.pos += n;
                Ok(info.pos)
            }
            Some(None) => bail!("slot {slot} advanced while free"),
            None => bail!("slot {slot} out of range (capacity {})", self.capacity()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn allocate_up_to_capacity_then_none() {
        let mut m = SlotMap::new(2, 8);
        let a = m.allocate(10).unwrap();
        let b = m.allocate(11).unwrap();
        assert_ne!(a, b);
        assert_eq!(m.allocate(12), None);
        assert_eq!(m.active_count(), 2);
        assert_eq!(m.free_count(), 0);
    }

    #[test]
    fn release_frees_and_reuses_at_pos_zero() {
        let mut m = SlotMap::new(1, 8);
        let s = m.allocate(1).unwrap();
        m.advance(s).unwrap();
        m.advance(s).unwrap();
        assert_eq!(m.pos(s), Some(2));
        assert_eq!(m.release(s).unwrap(), 1);
        assert!(!m.is_active(s));
        let s2 = m.allocate(2).unwrap();
        assert_eq!(s2, s);
        assert_eq!(m.pos(s2), Some(0));
    }

    #[test]
    fn double_release_and_free_advance_fail() {
        let mut m = SlotMap::new(1, 8);
        let s = m.allocate(1).unwrap();
        m.release(s).unwrap();
        assert!(m.release(s).is_err());
        assert!(m.advance(s).is_err());
        assert!(m.release(99).is_err());
    }

    #[test]
    fn advance_stops_at_max_seq() {
        let mut m = SlotMap::new(1, 2);
        let s = m.allocate(1).unwrap();
        assert_eq!(m.advance(s).unwrap(), 1);
        assert_eq!(m.advance(s).unwrap(), 2);
        assert!(m.advance(s).is_err());
    }

    #[test]
    fn accounting_invariant_under_churn() {
        let mut m = SlotMap::new(3, 4);
        let mut held = Vec::new();
        for id in 0..10u64 {
            if let Some(s) = m.allocate(id) {
                held.push(s);
            }
            assert!(m.active_count() <= m.capacity());
            assert_eq!(m.active_count() + m.free_count(), m.capacity());
            if held.len() == 3 {
                let s = held.remove(0);
                m.release(s).unwrap();
            }
        }
    }

    #[test]
    fn advance_by_respects_capacity_and_rejects_zero() {
        let mut m = SlotMap::new(1, 8);
        let s = m.allocate(1).unwrap();
        assert_eq!(m.advance_by(s, 5).unwrap(), 5);
        assert!(m.advance_by(s, 0).is_err());
        assert!(m.advance_by(s, 4).is_err(), "5 + 4 > 8 must fail");
        assert_eq!(m.pos(s), Some(5), "failed advance must not move the position");
        assert_eq!(m.advance_by(s, 3).unwrap(), 8);
        assert!(m.advance(s).is_err());
        m.release(s).unwrap();
        assert!(m.advance_by(s, 1).is_err());
    }

    #[test]
    fn paged_grow_advance_release_roundtrip() {
        // 2 slots, 16-position logical range, 4 pages of 4 tokens shared.
        let mut m = SlotMap::paged(2, 16, 4, 4);
        assert!(m.is_paged());
        let a = m.allocate(1).unwrap();
        let b = m.allocate(2).unwrap();
        // No pages yet: advancing must fail until capacity is ensured.
        assert!(m.advance(a).is_err());
        assert!(m.ensure_capacity(a, 1).unwrap());
        assert_eq!(m.table(a).len(), 1);
        // The same target again is a no-op.
        assert!(m.ensure_capacity(a, 4).unwrap());
        assert_eq!(m.table(a).len(), 1);
        for _ in 0..4 {
            m.advance(a).unwrap();
        }
        // Position 4 needs a second page.
        assert!(m.advance(a).is_err());
        assert!(m.ensure_capacity(a, 5).unwrap());
        m.advance(a).unwrap();
        // Slot b grabs the remaining 2 pages; the pool is then dry.
        assert!(m.ensure_capacity(b, 8).unwrap());
        assert_eq!(m.pool().unwrap().free_blocks(), 0);
        assert!(!m.ensure_capacity(a, 9).unwrap(), "pool dry: growth must report false");
        // Tables never alias.
        let mut all: Vec<u32> = m.table(a).iter().chain(m.table(b)).copied().collect();
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len(), 4);
        // Releasing a slot returns its pages.
        m.release(b).unwrap();
        assert!(m.table(b).is_empty());
        assert_eq!(m.pool().unwrap().free_blocks(), 2);
        assert!(m.ensure_capacity(a, 9).unwrap());
        m.release(a).unwrap();
        assert_eq!(m.pool().unwrap().free_blocks(), 4);
        assert_eq!(m.pool().unwrap().used_blocks(), 0);
    }

    #[test]
    fn paged_rejects_growth_past_max_seq_and_dense_rejects_growth() {
        let mut m = SlotMap::paged(1, 8, 8, 4);
        let s = m.allocate(1).unwrap();
        assert!(m.ensure_capacity(s, 9).is_err());
        assert!(m.ensure_capacity(99, 1).is_err());
        m.release(s).unwrap();
        assert!(m.ensure_capacity(s, 1).is_err(), "free slot cannot grow");
        let mut d = SlotMap::new(1, 8);
        let s = d.allocate(1).unwrap();
        assert!(d.ensure_capacity(s, 1).is_err(), "dense map has no pages");
    }

    /// Property: under random paged allocate/grow/advance/release
    /// interleavings, pool accounting never leaks
    /// (`free + used == total`, used == sum of table lengths), tables cover
    /// exactly `ceil(covered_target/bs)` pages, no physical page is ever
    /// shared by two slots, and positions never pass the covered range.
    #[test]
    fn prop_paged_interleavings_keep_pool_honest() {
        use crate::testing::prop::forall;
        forall(0xb10c, 300, |g| {
            let cap = g.int(1, 4);
            let bs = g.int(1, 5);
            let max_blocks = g.int(1, 8);
            let max_seq = (max_blocks * bs).min(g.int(1, 24));
            let mut m = SlotMap::paged(cap, max_seq, max_blocks, bs);
            let mut held: Vec<usize> = Vec::new();
            let ops = g.int(5, 60);
            for op in 0..ops {
                match g.int(0, 3) {
                    0 => {
                        if let Some(s) = m.allocate(op as u64) {
                            if !m.table(s).is_empty() {
                                return Err(format!("op {op}: fresh slot {s} has pages"));
                            }
                            held.push(s);
                        }
                    }
                    1 => {
                        if !held.is_empty() {
                            let s = held.swap_remove(g.int(0, held.len() - 1));
                            m.release(s).unwrap();
                        }
                    }
                    2 => {
                        if !held.is_empty() {
                            let s = *g.pick(&held);
                            let target = g.int(0, max_seq);
                            let ok = m.ensure_capacity(s, target).map_err(|e| e.to_string())?;
                            let covered = m.table(s).len() * bs;
                            if ok && covered < target {
                                return Err(format!(
                                    "op {op}: grow to {target} granted only {covered}"
                                ));
                            }
                            if !ok && m.pool().unwrap().free_blocks() != 0 {
                                return Err(format!(
                                    "op {op}: growth failed with free pages left"
                                ));
                            }
                        }
                    }
                    _ => {
                        if !held.is_empty() {
                            let s = *g.pick(&held);
                            let pos = m.pos(s).expect("held slot");
                            let covered = m.table(s).len() * bs;
                            let r = m.advance(s);
                            if pos + 1 > covered.min(max_seq) {
                                if r.is_ok() {
                                    return Err(format!(
                                        "op {op}: advanced past coverage ({pos} + 1 > {covered})"
                                    ));
                                }
                            } else if r.is_err() {
                                return Err(format!("op {op}: covered advance failed"));
                            }
                        }
                    }
                }
                // Pool accounting vs the tables, every step.
                let pool = m.pool().unwrap();
                if pool.free_blocks() + pool.used_blocks() != pool.total_blocks() {
                    return Err(format!("op {op}: pool accounting leaked"));
                }
                let table_total: usize = (0..cap).map(|s| m.table(s).len()).sum();
                if table_total != pool.used_blocks() {
                    return Err(format!(
                        "op {op}: tables hold {table_total} pages, pool says {}",
                        pool.used_blocks()
                    ));
                }
                let mut all: Vec<u32> =
                    (0..cap).flat_map(|s| m.table(s).iter().copied()).collect();
                all.sort_unstable();
                let n = all.len();
                all.dedup();
                if all.len() != n {
                    return Err(format!("op {op}: physical page shared between slots"));
                }
            }
            Ok(())
        });
    }

    /// Property: under random allocate/free/advance/advance_by
    /// interleavings, the map never double-allocates an occupied slot,
    /// never leaks capacity (`active + free == capacity`, always), and a
    /// slot's position is monotone within one occupancy — it only moves by
    /// the granted advance, resets to zero on reallocation, and never
    /// passes `max_seq`. Checked against an independent mirror model.
    #[test]
    fn prop_random_interleavings_keep_accounting_honest() {
        use crate::testing::prop::forall;
        forall(0x510f, 300, |g| run_interleaving_case(g));
    }

    fn run_interleaving_case(g: &mut crate::testing::prop::Gen) -> Result<(), String> {
        let cap = g.int(1, 6);
        let max_seq = g.int(1, 12);
        let mut m = SlotMap::new(cap, max_seq);
        // Mirror model: slot -> (id, pos).
        let mut model: Vec<Option<(u64, usize)>> = vec![None; cap];
        let mut next_id = 0u64;
        let ops = g.int(5, 80);
        for op in 0..ops {
            match g.int(0, 3) {
                0 => {
                    // allocate: must pick the lowest free slot, at pos 0,
                    // and never land on an occupied one.
                    let expect = model.iter().position(|s| s.is_none());
                    let got = m.allocate(next_id);
                    if got != expect {
                        return Err(format!("op {op}: allocate {got:?}, expected {expect:?}"));
                    }
                    if let Some(s) = got {
                        if model[s].is_some() {
                            return Err(format!("op {op}: slot {s} double-allocated"));
                        }
                        if m.pos(s) != Some(0) {
                            return Err(format!("op {op}: fresh slot {s} not at pos 0"));
                        }
                        model[s] = Some((next_id, 0));
                        next_id += 1;
                    }
                }
                1 => {
                    // release an arbitrary slot (occupied or not).
                    let s = g.int(0, cap - 1);
                    match (m.release(s), model[s]) {
                        (Ok(id), Some((mid, _))) if id == mid => model[s] = None,
                        (Err(_), None) => {}
                        (r, state) => {
                            return Err(format!("op {op}: release({s}) = {r:?} vs {state:?}"))
                        }
                    }
                }
                _ => {
                    // advance by 1 or by a random chunk.
                    let s = g.int(0, cap - 1);
                    let n = if g.bool() { 1 } else { g.int(1, 6) };
                    match (m.advance_by(s, n), model[s]) {
                        (Ok(p), Some((id, pos))) => {
                            if pos + n > max_seq || p != pos + n {
                                return Err(format!(
                                    "op {op}: advance_by({s}, {n}) = {p} from pos {pos} \
                                     (max_seq {max_seq})"
                                ));
                            }
                            model[s] = Some((id, p));
                        }
                        (Err(_), Some((_, pos))) if pos + n > max_seq => {}
                        (Err(_), None) => {}
                        (r, state) => {
                            return Err(format!(
                                "op {op}: advance_by({s}, {n}) = {r:?} vs {state:?}"
                            ))
                        }
                    }
                }
            }
            // Capacity can never leak, whatever the interleaving.
            let occupied = model.iter().filter(|s| s.is_some()).count();
            if m.active_count() != occupied || m.free_count() != cap - occupied {
                return Err(format!(
                    "op {op}: accounting {} active / {} free, model says {occupied}/{}",
                    m.active_count(),
                    m.free_count(),
                    cap - occupied
                ));
            }
            // Positions agree with the mirror everywhere.
            for s in 0..cap {
                if m.pos(s) != model[s].map(|(_, p)| p) {
                    return Err(format!("op {op}: slot {s} pos {:?} drifted", m.pos(s)));
                }
            }
        }
        Ok(())
    }
}
