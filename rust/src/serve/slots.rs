//! Slot-based KV-cache bookkeeping.
//!
//! The decode artifacts hold one KV cache per batch lane ("slot"); this
//! module owns the accounting: which slots are free, which request occupies
//! which slot, and how far each slot's cache has been written. The cache
//! *contents* live inside the engine (as PJRT literals); correctness of
//! slot reuse comes from the graphs' `idx <= pos` attention mask, so
//! [`SlotMap`] never needs to zero anything — it only has to keep positions
//! honest, which [`crate::serve::MockEngine`] cross-checks in tests.

use anyhow::{bail, Result};

/// Occupancy record for one slot.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SlotInfo {
    /// Request id occupying the slot.
    pub id: u64,
    /// Next cache position to be written (== tokens fed so far).
    pub pos: usize,
}

/// Allocate / free / advance over a fixed set of KV-cache slots with strict
/// capacity accounting: `active_count() + free_count() == capacity()` is an
/// invariant, and positions can never pass `max_seq`.
#[derive(Clone, Debug)]
pub struct SlotMap {
    max_seq: usize,
    state: Vec<Option<SlotInfo>>,
}

impl SlotMap {
    pub fn new(capacity: usize, max_seq: usize) -> Self {
        Self { max_seq, state: vec![None; capacity] }
    }

    pub fn capacity(&self) -> usize {
        self.state.len()
    }

    pub fn max_seq(&self) -> usize {
        self.max_seq
    }

    pub fn active_count(&self) -> usize {
        self.state.iter().filter(|s| s.is_some()).count()
    }

    pub fn free_count(&self) -> usize {
        self.capacity() - self.active_count()
    }

    pub fn is_active(&self, slot: usize) -> bool {
        self.state.get(slot).map(|s| s.is_some()).unwrap_or(false)
    }

    /// Occupant of a slot, if any.
    pub fn info(&self, slot: usize) -> Option<SlotInfo> {
        self.state.get(slot).copied().flatten()
    }

    /// Next write position of an occupied slot.
    pub fn pos(&self, slot: usize) -> Option<usize> {
        self.info(slot).map(|s| s.pos)
    }

    /// Claim the lowest-numbered free slot for request `id`; positions start
    /// at 0. Returns `None` when every slot is occupied.
    pub fn allocate(&mut self, id: u64) -> Option<usize> {
        let slot = self.state.iter().position(|s| s.is_none())?;
        self.state[slot] = Some(SlotInfo { id, pos: 0 });
        Some(slot)
    }

    /// Release an occupied slot; returns the request id it held.
    pub fn release(&mut self, slot: usize) -> Result<u64> {
        if slot >= self.state.len() {
            bail!("slot {slot} out of range (capacity {})", self.capacity());
        }
        match self.state[slot].take() {
            Some(info) => Ok(info.id),
            None => bail!("slot {slot} released twice"),
        }
    }

    /// Advance an occupied slot's position by one written token; returns the
    /// new position. Fails if the slot is free or its cache is already full.
    pub fn advance(&mut self, slot: usize) -> Result<usize> {
        self.advance_by(slot, 1)
    }

    /// Advance an occupied slot's position by `n` written tokens (one
    /// batched prefill chunk); returns the new position. Fails if the slot
    /// is free or the advance would pass `max_seq` — positions stay honest
    /// even for multi-token writes.
    pub fn advance_by(&mut self, slot: usize, n: usize) -> Result<usize> {
        let max_seq = self.max_seq;
        match self.state.get_mut(slot) {
            Some(Some(info)) => {
                if n == 0 {
                    bail!("slot {slot} advanced by zero tokens");
                }
                if info.pos + n > max_seq {
                    bail!(
                        "slot {slot}: advance by {n} passes KV capacity \
                         ({} + {n} > {max_seq})",
                        info.pos
                    );
                }
                info.pos += n;
                Ok(info.pos)
            }
            Some(None) => bail!("slot {slot} advanced while free"),
            None => bail!("slot {slot} out of range (capacity {})", self.capacity()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn allocate_up_to_capacity_then_none() {
        let mut m = SlotMap::new(2, 8);
        let a = m.allocate(10).unwrap();
        let b = m.allocate(11).unwrap();
        assert_ne!(a, b);
        assert_eq!(m.allocate(12), None);
        assert_eq!(m.active_count(), 2);
        assert_eq!(m.free_count(), 0);
    }

    #[test]
    fn release_frees_and_reuses_at_pos_zero() {
        let mut m = SlotMap::new(1, 8);
        let s = m.allocate(1).unwrap();
        m.advance(s).unwrap();
        m.advance(s).unwrap();
        assert_eq!(m.pos(s), Some(2));
        assert_eq!(m.release(s).unwrap(), 1);
        assert!(!m.is_active(s));
        let s2 = m.allocate(2).unwrap();
        assert_eq!(s2, s);
        assert_eq!(m.pos(s2), Some(0));
    }

    #[test]
    fn double_release_and_free_advance_fail() {
        let mut m = SlotMap::new(1, 8);
        let s = m.allocate(1).unwrap();
        m.release(s).unwrap();
        assert!(m.release(s).is_err());
        assert!(m.advance(s).is_err());
        assert!(m.release(99).is_err());
    }

    #[test]
    fn advance_stops_at_max_seq() {
        let mut m = SlotMap::new(1, 2);
        let s = m.allocate(1).unwrap();
        assert_eq!(m.advance(s).unwrap(), 1);
        assert_eq!(m.advance(s).unwrap(), 2);
        assert!(m.advance(s).is_err());
    }

    #[test]
    fn accounting_invariant_under_churn() {
        let mut m = SlotMap::new(3, 4);
        let mut held = Vec::new();
        for id in 0..10u64 {
            if let Some(s) = m.allocate(id) {
                held.push(s);
            }
            assert!(m.active_count() <= m.capacity());
            assert_eq!(m.active_count() + m.free_count(), m.capacity());
            if held.len() == 3 {
                let s = held.remove(0);
                m.release(s).unwrap();
            }
        }
    }

    #[test]
    fn advance_by_respects_capacity_and_rejects_zero() {
        let mut m = SlotMap::new(1, 8);
        let s = m.allocate(1).unwrap();
        assert_eq!(m.advance_by(s, 5).unwrap(), 5);
        assert!(m.advance_by(s, 0).is_err());
        assert!(m.advance_by(s, 4).is_err(), "5 + 4 > 8 must fail");
        assert_eq!(m.pos(s), Some(5), "failed advance must not move the position");
        assert_eq!(m.advance_by(s, 3).unwrap(), 8);
        assert!(m.advance(s).is_err());
        m.release(s).unwrap();
        assert!(m.advance_by(s, 1).is_err());
    }

    /// Property: under random allocate/free/advance/advance_by
    /// interleavings, the map never double-allocates an occupied slot,
    /// never leaks capacity (`active + free == capacity`, always), and a
    /// slot's position is monotone within one occupancy — it only moves by
    /// the granted advance, resets to zero on reallocation, and never
    /// passes `max_seq`. Checked against an independent mirror model.
    #[test]
    fn prop_random_interleavings_keep_accounting_honest() {
        use crate::testing::prop::forall;
        forall(0x510f, 300, |g| run_interleaving_case(g));
    }

    fn run_interleaving_case(g: &mut crate::testing::prop::Gen) -> Result<(), String> {
        let cap = g.int(1, 6);
        let max_seq = g.int(1, 12);
        let mut m = SlotMap::new(cap, max_seq);
        // Mirror model: slot -> (id, pos).
        let mut model: Vec<Option<(u64, usize)>> = vec![None; cap];
        let mut next_id = 0u64;
        let ops = g.int(5, 80);
        for op in 0..ops {
            match g.int(0, 3) {
                0 => {
                    // allocate: must pick the lowest free slot, at pos 0,
                    // and never land on an occupied one.
                    let expect = model.iter().position(|s| s.is_none());
                    let got = m.allocate(next_id);
                    if got != expect {
                        return Err(format!("op {op}: allocate {got:?}, expected {expect:?}"));
                    }
                    if let Some(s) = got {
                        if model[s].is_some() {
                            return Err(format!("op {op}: slot {s} double-allocated"));
                        }
                        if m.pos(s) != Some(0) {
                            return Err(format!("op {op}: fresh slot {s} not at pos 0"));
                        }
                        model[s] = Some((next_id, 0));
                        next_id += 1;
                    }
                }
                1 => {
                    // release an arbitrary slot (occupied or not).
                    let s = g.int(0, cap - 1);
                    match (m.release(s), model[s]) {
                        (Ok(id), Some((mid, _))) if id == mid => model[s] = None,
                        (Err(_), None) => {}
                        (r, state) => {
                            return Err(format!("op {op}: release({s}) = {r:?} vs {state:?}"))
                        }
                    }
                }
                _ => {
                    // advance by 1 or by a random chunk.
                    let s = g.int(0, cap - 1);
                    let n = if g.bool() { 1 } else { g.int(1, 6) };
                    match (m.advance_by(s, n), model[s]) {
                        (Ok(p), Some((id, pos))) => {
                            if pos + n > max_seq || p != pos + n {
                                return Err(format!(
                                    "op {op}: advance_by({s}, {n}) = {p} from pos {pos} \
                                     (max_seq {max_seq})"
                                ));
                            }
                            model[s] = Some((id, p));
                        }
                        (Err(_), Some((_, pos))) if pos + n > max_seq => {}
                        (Err(_), None) => {}
                        (r, state) => {
                            return Err(format!(
                                "op {op}: advance_by({s}, {n}) = {r:?} vs {state:?}"
                            ))
                        }
                    }
                }
            }
            // Capacity can never leak, whatever the interleaving.
            let occupied = model.iter().filter(|s| s.is_some()).count();
            if m.active_count() != occupied || m.free_count() != cap - occupied {
                return Err(format!(
                    "op {op}: accounting {} active / {} free, model says {occupied}/{}",
                    m.active_count(),
                    m.free_count(),
                    cap - occupied
                ));
            }
            // Positions agree with the mirror everywhere.
            for s in 0..cap {
                if m.pos(s) != model[s].map(|(_, p)| p) {
                    return Err(format!("op {op}: slot {s} pos {:?} drifted", m.pos(s)));
                }
            }
        }
        Ok(())
    }
}
