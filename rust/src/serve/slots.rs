//! Slot-based KV-cache bookkeeping.
//!
//! The decode artifacts hold one KV cache per batch lane ("slot"); this
//! module owns the accounting: which slots are free, which request occupies
//! which slot, and how far each slot's cache has been written. The cache
//! *contents* live inside the engine (as PJRT literals); correctness of
//! slot reuse comes from the graphs' `idx <= pos` attention mask, so
//! [`SlotMap`] never needs to zero anything — it only has to keep positions
//! honest, which [`crate::serve::MockEngine`] cross-checks in tests.

use anyhow::{bail, Result};

/// Occupancy record for one slot.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SlotInfo {
    /// Request id occupying the slot.
    pub id: u64,
    /// Next cache position to be written (== tokens fed so far).
    pub pos: usize,
}

/// Allocate / free / advance over a fixed set of KV-cache slots with strict
/// capacity accounting: `active_count() + free_count() == capacity()` is an
/// invariant, and positions can never pass `max_seq`.
#[derive(Clone, Debug)]
pub struct SlotMap {
    max_seq: usize,
    state: Vec<Option<SlotInfo>>,
}

impl SlotMap {
    pub fn new(capacity: usize, max_seq: usize) -> Self {
        Self { max_seq, state: vec![None; capacity] }
    }

    pub fn capacity(&self) -> usize {
        self.state.len()
    }

    pub fn max_seq(&self) -> usize {
        self.max_seq
    }

    pub fn active_count(&self) -> usize {
        self.state.iter().filter(|s| s.is_some()).count()
    }

    pub fn free_count(&self) -> usize {
        self.capacity() - self.active_count()
    }

    pub fn is_active(&self, slot: usize) -> bool {
        self.state.get(slot).map(|s| s.is_some()).unwrap_or(false)
    }

    /// Occupant of a slot, if any.
    pub fn info(&self, slot: usize) -> Option<SlotInfo> {
        self.state.get(slot).copied().flatten()
    }

    /// Next write position of an occupied slot.
    pub fn pos(&self, slot: usize) -> Option<usize> {
        self.info(slot).map(|s| s.pos)
    }

    /// Claim the lowest-numbered free slot for request `id`; positions start
    /// at 0. Returns `None` when every slot is occupied.
    pub fn allocate(&mut self, id: u64) -> Option<usize> {
        let slot = self.state.iter().position(|s| s.is_none())?;
        self.state[slot] = Some(SlotInfo { id, pos: 0 });
        Some(slot)
    }

    /// Release an occupied slot; returns the request id it held.
    pub fn release(&mut self, slot: usize) -> Result<u64> {
        if slot >= self.state.len() {
            bail!("slot {slot} out of range (capacity {})", self.capacity());
        }
        match self.state[slot].take() {
            Some(info) => Ok(info.id),
            None => bail!("slot {slot} released twice"),
        }
    }

    /// Advance an occupied slot's position by one written token; returns the
    /// new position. Fails if the slot is free or its cache is already full.
    pub fn advance(&mut self, slot: usize) -> Result<usize> {
        let max_seq = self.max_seq;
        match self.state.get_mut(slot) {
            Some(Some(info)) => {
                if info.pos >= max_seq {
                    bail!("slot {slot}: KV cache full ({max_seq} positions)");
                }
                info.pos += 1;
                Ok(info.pos)
            }
            Some(None) => bail!("slot {slot} advanced while free"),
            None => bail!("slot {slot} out of range (capacity {})", self.capacity()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn allocate_up_to_capacity_then_none() {
        let mut m = SlotMap::new(2, 8);
        let a = m.allocate(10).unwrap();
        let b = m.allocate(11).unwrap();
        assert_ne!(a, b);
        assert_eq!(m.allocate(12), None);
        assert_eq!(m.active_count(), 2);
        assert_eq!(m.free_count(), 0);
    }

    #[test]
    fn release_frees_and_reuses_at_pos_zero() {
        let mut m = SlotMap::new(1, 8);
        let s = m.allocate(1).unwrap();
        m.advance(s).unwrap();
        m.advance(s).unwrap();
        assert_eq!(m.pos(s), Some(2));
        assert_eq!(m.release(s).unwrap(), 1);
        assert!(!m.is_active(s));
        let s2 = m.allocate(2).unwrap();
        assert_eq!(s2, s);
        assert_eq!(m.pos(s2), Some(0));
    }

    #[test]
    fn double_release_and_free_advance_fail() {
        let mut m = SlotMap::new(1, 8);
        let s = m.allocate(1).unwrap();
        m.release(s).unwrap();
        assert!(m.release(s).is_err());
        assert!(m.advance(s).is_err());
        assert!(m.release(99).is_err());
    }

    #[test]
    fn advance_stops_at_max_seq() {
        let mut m = SlotMap::new(1, 2);
        let s = m.allocate(1).unwrap();
        assert_eq!(m.advance(s).unwrap(), 1);
        assert_eq!(m.advance(s).unwrap(), 2);
        assert!(m.advance(s).is_err());
    }

    #[test]
    fn accounting_invariant_under_churn() {
        let mut m = SlotMap::new(3, 4);
        let mut held = Vec::new();
        for id in 0..10u64 {
            if let Some(s) = m.allocate(id) {
                held.push(s);
            }
            assert!(m.active_count() <= m.capacity());
            assert_eq!(m.active_count() + m.free_count(), m.capacity());
            if held.len() == 3 {
                let s = held.remove(0);
                m.release(s).unwrap();
            }
        }
    }
}
