//! Slot-based KV-cache bookkeeping.
//!
//! The decode artifacts hold one KV cache per batch lane ("slot"); this
//! module owns the accounting: which slots are free, which request occupies
//! which slot, and how far each slot's cache has been written. The cache
//! *contents* live inside the engine (as PJRT literals); correctness of
//! slot reuse comes from the graphs' `idx <= pos` attention mask, so
//! [`SlotMap`] never needs to zero anything — it only has to keep positions
//! honest, which [`crate::serve::MockEngine`] cross-checks in tests.
//!
//! A [`SlotMap`] built with [`SlotMap::paged`] additionally carries one
//! block table per slot over a [`BlockPool`]: instead of assuming a dense
//! `[0, max_seq)` cache range, a slot's positions live in lazily allocated
//! `block_size`-token physical pages ([`SlotMap::ensure_capacity`] grows
//! the table at page boundaries, [`SlotMap::release`] drops the slot's page
//! references). Positions may never advance past what the table covers.
//!
//! With [`SlotMap::with_prefix_cache`] the map additionally keeps a
//! [`PrefixIndex`] over the pool and page ownership becomes
//! **refcounted copy-on-write**:
//!
//! * [`SlotMap::admit_paged`] maps the longest run of cached full pages
//!   matching a new request's prompt into its block table *read-only*
//!   (each mapped page is retained, never written — the match is capped
//!   one token short of the prompt, so the first written page is always a
//!   freshly allocated copy whose tokens are recomputed through prefill:
//!   copy-on-write by recompute, which is why the PJRT graphs need no
//!   change).
//! * [`SlotMap::advance_by`] donates pages to the index the moment they
//!   fill entirely inside the prompt (the index takes its own reference),
//!   so a release later drops only the slot's references and the pages
//!   stay resident for the next request with the same prefix.
//! * Pool pressure first evicts LRU index pages nobody else references
//!   (`refcount == 1`); pages mapped by live slots are structurally
//!   unevictable.

use anyhow::{bail, Result};

use crate::serve::blocks::BlockPool;
use crate::serve::prefix::{chain_of, chain_step, PrefixIndex, CHAIN_ROOT};
use crate::serve::trace::{TraceEvent, TraceSink};

/// Lifecycle phase of one slot with respect to its request's prompt — the
/// partition the decode-priority step composer plans each step by:
///
/// * `Cold` — no occupant (the request, if any, is still queued).
/// * `Warming` — occupied, still owes prompt tokens. Eligible for budgeted
///   prefill chunks; its prompt may split across steps at arbitrary
///   boundaries (partial-prompt positions: `pos` tracks exactly the prompt
///   prefix written so far, cached prefix pages included).
/// * `Running` — prompt fully fed; produces one token per decode call and
///   is scheduled *first* under a step budget, so a newcomer's prefill can
///   never stall it.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SlotPhase {
    Cold,
    Warming,
    Running,
}

/// Occupancy record for one slot.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SlotInfo {
    /// Request id occupying the slot.
    pub id: u64,
    /// Next cache position to be written (== tokens fed so far, cached
    /// prefix tokens included).
    pub pos: usize,
}

/// Allocate / free / advance over a fixed set of KV-cache slots with strict
/// capacity accounting: `active_count() + free_count() == capacity()` is an
/// invariant, and positions can never pass `max_seq`.
#[derive(Clone, Debug)]
pub struct SlotMap {
    max_seq: usize,
    state: Vec<Option<SlotInfo>>,
    /// Paged mode: the physical page allocator shared by every slot.
    pool: Option<BlockPool>,
    /// Paged mode: per-slot block table (logical page j -> physical page).
    /// Always empty for free slots and in dense mode.
    tables: Vec<Vec<u32>>,
    /// Prefix-cache mode: the content-addressed index of donated pages.
    prefix: Option<PrefixIndex>,
    /// Prefix-cache mode: the prompt each occupied slot was admitted with
    /// (content key for page donation). Empty otherwise.
    prompts: Vec<Vec<i32>>,
    /// Prefix-cache mode: leading table pages mapped read-only from the
    /// index at admission. The slot's positions start past them and it
    /// never writes them.
    shared: Vec<usize>,
    /// Prefix-cache mode: the running chain value over each slot's
    /// processed prompt pages (mapped at admission + donated since), so
    /// registering a page never re-walks the prompt.
    chains: Vec<u64>,
    /// Flight-recorder sink for page-plane events (shared with the
    /// scheduler's; `Off` unless the scheduler attached one).
    trace: TraceSink,
}

impl SlotMap {
    pub fn new(capacity: usize, max_seq: usize) -> Self {
        Self {
            max_seq,
            state: vec![None; capacity],
            pool: None,
            tables: vec![Vec::new(); capacity],
            prefix: None,
            prompts: vec![Vec::new(); capacity],
            shared: vec![0; capacity],
            chains: vec![CHAIN_ROOT; capacity],
            trace: TraceSink::Off,
        }
    }

    /// Attach (or replace) the flight-recorder sink page-plane events are
    /// emitted into. The scheduler shares its own sink here so request
    /// lifecycle and page refcount events interleave in one stream.
    pub fn set_trace(&mut self, sink: TraceSink) {
        self.trace = sink;
    }

    /// Paged variant: slots share `total_blocks` physical pages of
    /// `block_size` tokens, allocated lazily through
    /// [`SlotMap::ensure_capacity`].
    pub fn paged(capacity: usize, max_seq: usize, total_blocks: usize, block_size: usize) -> Self {
        Self {
            pool: Some(BlockPool::new(total_blocks, block_size)),
            ..Self::new(capacity, max_seq)
        }
    }

    /// Enable the content-addressed prefix cache (paged maps only): full
    /// prompt pages are donated to a [`PrefixIndex`] as they fill and
    /// mapped read-only into later requests with the same prefix.
    pub fn with_prefix_cache(mut self) -> Self {
        assert!(self.pool.is_some(), "prefix cache needs a paged SlotMap");
        self.prefix = Some(PrefixIndex::new());
        self
    }

    /// The page allocator, when this map is paged.
    pub fn pool(&self) -> Option<&BlockPool> {
        self.pool.as_ref()
    }

    /// The prefix index, when the prefix cache is enabled.
    pub fn prefix(&self) -> Option<&PrefixIndex> {
        self.prefix.as_ref()
    }

    pub fn is_paged(&self) -> bool {
        self.pool.is_some()
    }

    pub fn has_prefix_cache(&self) -> bool {
        self.prefix.is_some()
    }

    /// A slot's block table (empty when free or dense).
    pub fn table(&self, slot: usize) -> &[u32] {
        &self.tables[slot]
    }

    /// Leading pages of a slot's table mapped read-only from the prefix
    /// index (0 when the cache is off or the prompt missed).
    pub fn shared_pages(&self, slot: usize) -> usize {
        self.shared[slot]
    }

    /// Pages an admission or growth can draw on right now: free pages plus
    /// cached index pages nobody else references (evictable under
    /// pressure). This is the paged admission watermark's supply side —
    /// shared pages a request would map are *not* in here; they are
    /// subtracted from the demand side instead (see [`SlotMap::admit_paged`]).
    pub fn available_pages(&self) -> usize {
        let Some(pool) = self.pool.as_ref() else { return 0 };
        let evictable = self
            .prefix
            .as_ref()
            .map(|idx| idx.evictable_pages(|p| pool.refcount(p) == 1))
            .unwrap_or(0);
        pool.free_blocks() + evictable
    }

    /// Claim one page: from the free list, else by evicting the LRU index
    /// page only the index still references. `None` when even eviction
    /// cannot help (every page is referenced by a live slot or the index's
    /// survivors).
    fn allocate_page(&mut self) -> Option<u32> {
        let pool = self.pool.as_mut()?;
        if let Some(b) = pool.allocate() {
            self.trace.emit(TraceEvent::PageAllocated { block: b, refcount: 1 });
            return Some(b);
        }
        let prefix = self.prefix.as_mut()?;
        let page = prefix.evict_lru(|p| pool.refcount(p) == 1)?;
        pool.release(&[page]).expect("evicted page held exactly the index reference");
        self.trace.emit(TraceEvent::PageReleased { block: page, refcount: 0 });
        let b = pool.allocate()?;
        self.trace.emit(TraceEvent::PageAllocated { block: b, refcount: 1 });
        Some(b)
    }

    pub fn capacity(&self) -> usize {
        self.state.len()
    }

    pub fn max_seq(&self) -> usize {
        self.max_seq
    }

    pub fn active_count(&self) -> usize {
        self.state.iter().filter(|s| s.is_some()).count()
    }

    pub fn free_count(&self) -> usize {
        self.capacity() - self.active_count()
    }

    pub fn is_active(&self, slot: usize) -> bool {
        self.state.get(slot).map(|s| s.is_some()).unwrap_or(false)
    }

    /// Occupant of a slot, if any.
    pub fn info(&self, slot: usize) -> Option<SlotInfo> {
        self.state.get(slot).copied().flatten()
    }

    /// Next write position of an occupied slot.
    pub fn pos(&self, slot: usize) -> Option<usize> {
        self.info(slot).map(|s| s.pos)
    }

    /// Grow `slot`'s block table until it covers cache positions
    /// `[0, target_pos)`, allocating pages from the pool (evicting LRU
    /// unreferenced index pages under pressure). Returns `false` (keeping
    /// any pages already granted) when nothing more can be claimed — the
    /// scheduler then evicts a request and retries. Errors on free slots,
    /// dense maps, or a target past `max_seq`.
    pub fn ensure_capacity(&mut self, slot: usize, target_pos: usize) -> Result<bool> {
        let Some(pool) = self.pool.as_ref() else {
            bail!("ensure_capacity on a dense SlotMap");
        };
        if self.state.get(slot).copied().flatten().is_none() {
            bail!("slot {slot} grown while free");
        }
        if target_pos > self.max_seq {
            bail!("slot {slot}: target {target_pos} past max_seq {}", self.max_seq);
        }
        let needed = pool.blocks_for(target_pos);
        while self.tables[slot].len() < needed {
            match self.allocate_page() {
                Some(b) => self.tables[slot].push(b),
                None => return Ok(false),
            }
        }
        Ok(true)
    }

    /// Claim the lowest-numbered free slot for request `id`; positions start
    /// at 0. Returns `None` when every slot is occupied. (Dense admission —
    /// paged schedulers go through [`SlotMap::admit_paged`].)
    pub fn allocate(&mut self, id: u64) -> Option<usize> {
        let slot = self.state.iter().position(|s| s.is_none())?;
        self.state[slot] = Some(SlotInfo { id, pos: 0 });
        Some(slot)
    }

    /// Paged admission transaction: map the longest cached prefix of
    /// `prompt` read-only into the lowest free slot's table, check the
    /// free-page watermark against the *non-shared* remainder of
    /// `blocks_needed` (the request's end-to-end page demand), and claim
    /// the first writable page. Returns `Ok(None)` — with every side
    /// effect rolled back except LRU clock bumps on the matched entries —
    /// when there is no free slot or the watermark fails; the caller keeps
    /// the request queued.
    ///
    /// On success returns `(slot, cached_tokens)`: the slot's position
    /// starts at `cached_tokens` (a page-boundary multiple, strictly less
    /// than `prompt.len()`), so the scheduler feeds the prompt from the
    /// first uncached position and the request's first write lands in the
    /// freshly claimed page — never in a shared one.
    pub fn admit_paged(
        &mut self,
        id: u64,
        prompt: &[i32],
        blocks_needed: usize,
    ) -> Result<Option<(usize, usize)>> {
        let Some(pool) = self.pool.as_ref() else {
            bail!("admit_paged on a dense SlotMap");
        };
        let bs = pool.block_size();
        let Some(slot) = self.state.iter().position(|s| s.is_none()) else {
            return Ok(None);
        };
        // Longest cached run of full prompt pages, capped one token short
        // of the prompt: the last prompt token is always recomputed into a
        // fresh page (the COW copy), because its step must produce logits.
        let max_pages = if prompt.is_empty() { 0 } else { (prompt.len() - 1) / bs };
        let matched: Vec<u32> = match self.prefix.as_mut() {
            Some(idx) => idx.lookup(prompt, bs, max_pages),
            None => Vec::new(),
        };
        let pool = self.pool.as_mut().expect("checked paged");
        for &p in &matched {
            pool.retain(p)?;
            self.trace.emit(TraceEvent::PageRetained {
                block: p,
                refcount: pool.refcount(p) as usize,
            });
        }
        // The demand must exceed the cached prefix (it always does for a
        // scheduler-computed demand, since the match is capped one token
        // short of the prompt) — otherwise the watermark below would be
        // vacuous and the first-page claim unsound.
        if blocks_needed <= matched.len() {
            self.pool.as_mut().expect("paged").release(&matched)?;
            bail!(
                "demand of {blocks_needed} pages does not exceed the {} matched \
                 prefix pages (demand must cover the whole request)",
                matched.len()
            );
        }
        // Watermark: only the non-shared remainder must be claimable. The
        // matched pages are retained already, so `available_pages` cannot
        // double-count them as evictable supply.
        let needed_fresh = blocks_needed - matched.len();
        if self.available_pages() < needed_fresh {
            let pool = self.pool.as_mut().expect("paged");
            pool.release(&matched)?;
            for &p in &matched {
                self.trace.emit(TraceEvent::PageReleased {
                    block: p,
                    refcount: pool.refcount(p) as usize,
                });
            }
            return Ok(None);
        }
        // First writable page now, before the slot is occupied, so every
        // error path leaves the map untouched — and every in-flight
        // request holds at least one exclusive page, which is what keeps
        // scheduler-level eviction able to free (or at least donate)
        // memory. The watermark just guaranteed needed_fresh >= 1 pages
        // are claimable.
        let Some(page) = self.allocate_page() else {
            self.pool.as_mut().expect("paged").release(&matched)?;
            bail!("slot {slot}: watermark passed but no page claimable");
        };
        let cached = matched.len() * bs;
        debug_assert!(prompt.is_empty() || cached < prompt.len());
        self.shared[slot] = matched.len();
        self.chains[slot] = chain_of(prompt, matched.len(), bs);
        self.tables[slot] = matched;
        self.tables[slot].push(page);
        self.prompts[slot] = if self.prefix.is_some() { prompt.to_vec() } else { Vec::new() };
        self.state[slot] = Some(SlotInfo { id, pos: cached });
        Ok(Some((slot, cached)))
    }

    /// Release an occupied slot, dropping the slot's page references (the
    /// prefix index keeps donated pages resident through its own); returns
    /// the request id it held. The pool release is batch-atomic, so a
    /// failure leaves slot and pool bookkeeping untouched and agreeing.
    pub fn release(&mut self, slot: usize) -> Result<u64> {
        if slot >= self.state.len() {
            bail!("slot {slot} out of range (capacity {})", self.capacity());
        }
        if self.state[slot].is_none() {
            bail!("slot {slot} released twice");
        }
        if let Some(pool) = self.pool.as_mut() {
            // Validate-then-free: on error nothing (pool or slot) changes.
            pool.release(&self.tables[slot])?;
            for &p in &self.tables[slot] {
                self.trace.emit(TraceEvent::PageReleased {
                    block: p,
                    refcount: pool.refcount(p) as usize,
                });
            }
            self.tables[slot].clear();
        }
        let info = self.state[slot].take().expect("checked occupied");
        self.prompts[slot].clear();
        self.shared[slot] = 0;
        self.chains[slot] = CHAIN_ROOT;
        Ok(info.id)
    }

    /// Advance an occupied slot's position by one written token; returns the
    /// new position. Fails if the slot is free or its cache is already full.
    pub fn advance(&mut self, slot: usize) -> Result<usize> {
        self.advance_by(slot, 1)
    }

    /// Advance an occupied slot's position by `n` written tokens (one
    /// batched prefill chunk); returns the new position. Fails if the slot
    /// is free or the advance would pass `max_seq` — positions stay honest
    /// even for multi-token writes.
    ///
    /// Prefix-cache mode: pages that this advance fills *entirely within
    /// the prompt* become immutable and are donated to the index right
    /// here (earliest possible sharing — a request admitted next step
    /// already hits them). The index takes its own pool reference;
    /// duplicate content keeps the existing entry and the new page stays
    /// slot-exclusive.
    pub fn advance_by(&mut self, slot: usize, n: usize) -> Result<usize> {
        self.advance_inner(slot, n, true)
    }

    /// Advance by `n` **speculative** (not yet verified-committed) tokens:
    /// identical position accounting to [`SlotMap::advance_by`], but prefix
    /// donation is structurally skipped. Draft tokens live past the prompt,
    /// so the donation filter (`page end <= prompt.len()`) would already
    /// reject their pages — this entry point makes the deferral a contract
    /// rather than a coincidence: no page a speculative advance touches can
    /// ever reach the [`PrefixIndex`], so a later [`SlotMap::rewind_by`]
    /// can never strand a rejected token in (or adopt one from) the index.
    pub fn advance_speculative(&mut self, slot: usize, n: usize) -> Result<usize> {
        self.advance_inner(slot, n, false)
    }

    fn advance_inner(&mut self, slot: usize, n: usize, donate: bool) -> Result<usize> {
        let max_seq = self.max_seq;
        // Paged: the advance must stay inside the pages the table covers —
        // a position without a page would scatter into the out-of-range
        // sentinel and silently drop the KV write.
        let covered = match (&self.pool, self.tables.get(slot)) {
            (Some(pool), Some(table)) => Some(table.len() * pool.block_size()),
            _ => None,
        };
        let (old_pos, new_pos) = match self.state.get_mut(slot) {
            Some(Some(info)) => {
                if n == 0 {
                    bail!("slot {slot} advanced by zero tokens");
                }
                if info.pos + n > max_seq {
                    bail!(
                        "slot {slot}: advance by {n} passes KV capacity \
                         ({} + {n} > {max_seq})",
                        info.pos
                    );
                }
                if let Some(covered) = covered {
                    if info.pos + n > covered {
                        bail!(
                            "slot {slot}: advance by {n} passes its block table \
                             ({} + {n} > {covered} covered; ensure_capacity first)",
                            info.pos
                        );
                    }
                }
                let old = info.pos;
                info.pos += n;
                (old, info.pos)
            }
            Some(None) => bail!("slot {slot} advanced while free"),
            None => bail!("slot {slot} out of range (capacity {})", self.capacity()),
        };
        if donate && self.prefix.is_some() && !self.prompts[slot].is_empty() {
            self.donate_filled_pages(slot, old_pos, new_pos)?;
        }
        Ok(new_pos)
    }

    /// Rewind an occupied slot's position by `n` tokens — the rollback half
    /// of speculative decoding, unwinding what a speculative advance did:
    /// the position moves back and, in paged mode, pages that no longer
    /// cover any position are released back to the pool (a draft window
    /// that grew across a page boundary and then got rejected must not leak
    /// the freshly grown pages). Returns the new position; `n == 0` is a
    /// no-op.
    ///
    /// Guards (all validated before anything changes, so a failed rewind
    /// leaves slot and pool untouched and agreeing):
    /// * `n` must not exceed the current position;
    /// * the new position may not enter the slot's read-only shared pages
    ///   (those tokens were never speculative);
    /// * prefix-cache mode: the new position may not drop below the slot's
    ///   processed-prompt frontier — pages up to there have been donated
    ///   (or chain-walked) and re-advancing over them would double-donate.
    ///   Draft tokens always live past the prompt, so a speculative rewind
    ///   never hits this guard; it exists to reject API misuse loudly.
    ///
    /// Released pages are provably never index-resident (the frontier guard
    /// keeps every donated page inside the kept range), and the paranoid
    /// cross-check below turns any violation into a loud error rather than
    /// a refcount leak.
    pub fn rewind_by(&mut self, slot: usize, n: usize) -> Result<usize> {
        let info = match self.state.get(slot) {
            Some(Some(info)) => *info,
            Some(None) => bail!("slot {slot} rewound while free"),
            None => bail!("slot {slot} out of range (capacity {})", self.capacity()),
        };
        if n == 0 {
            return Ok(info.pos);
        }
        if n > info.pos {
            bail!("slot {slot}: rewind by {n} passes position 0 (pos {})", info.pos);
        }
        let new_pos = info.pos - n;
        if let Some(pool) = self.pool.as_ref() {
            let bs = pool.block_size();
            if new_pos < self.shared[slot] * bs {
                bail!(
                    "slot {slot}: rewind to {new_pos} enters its {} read-only shared pages",
                    self.shared[slot]
                );
            }
            if self.prefix.is_some() && !self.prompts[slot].is_empty() {
                let processed =
                    (info.pos / bs).min(self.prompts[slot].len() / bs) * bs;
                if new_pos < processed {
                    bail!(
                        "slot {slot}: rewind to {new_pos} drops below its processed-prompt \
                         frontier {processed} (donated pages cannot be unwound)"
                    );
                }
            }
            let keep = pool.blocks_for(new_pos);
            if keep < self.tables[slot].len() {
                let released: Vec<u32> = self.tables[slot][keep..].to_vec();
                if let Some(idx) = self.prefix.as_ref() {
                    let resident = idx.pages();
                    for &p in &released {
                        if resident.contains(&p) {
                            bail!(
                                "slot {slot}: rewind would release page {p}, which is \
                                 index-resident (unverified tokens were donated?!)"
                            );
                        }
                    }
                }
                // Validate-then-free (batch-atomic): on error nothing —
                // pool or slot — changes.
                let pool = self.pool.as_mut().expect("checked paged");
                pool.release(&released)?;
                for &p in &released {
                    self.trace.emit(TraceEvent::PageReleased {
                        block: p,
                        refcount: pool.refcount(p) as usize,
                    });
                }
                self.tables[slot].truncate(keep);
            }
        }
        let info = self.state[slot].as_mut().expect("checked occupied");
        info.pos = new_pos;
        Ok(new_pos)
    }

    /// Donate every page that filled in `(old_pos, new_pos]` and lies
    /// wholly inside the slot's prompt to the prefix index, advancing the
    /// slot's running chain value as each page is processed.
    fn donate_filled_pages(&mut self, slot: usize, old_pos: usize, new_pos: usize) -> Result<()> {
        let pool = self.pool.as_mut().expect("prefix cache implies paged");
        let prefix = self.prefix.as_mut().expect("checked");
        let bs = pool.block_size();
        let prompt = &self.prompts[slot];
        let mut donated = 0usize;
        for j in (old_pos / bs)..(new_pos / bs) {
            let end = (j + 1) * bs;
            if end > prompt.len() || j < self.shared[slot] {
                continue;
            }
            let page = self.tables[slot][j];
            let parent = self.chains[slot];
            if prefix.register(parent, &prompt[..end], bs, page) {
                pool.retain(page)?;
                self.trace.emit(TraceEvent::PageRetained {
                    block: page,
                    refcount: pool.refcount(page) as usize,
                });
                donated += 1;
            }
            self.chains[slot] = chain_step(parent, &prompt[j * bs..end]);
        }
        if donated > 0 {
            self.trace.emit(TraceEvent::PrefixDonated { slot, pages: donated });
        }
        Ok(())
    }

    /// Full bookkeeping audit, cheap enough to run after every step in the
    /// chaos property tests: slot-capacity accounting, per-slot position
    /// bounds (inside `max_seq`, inside the covered table range, never
    /// inside read-only shared pages), clean free-slot state, and — in
    /// paged mode — the pool's own audit plus an exact refcount mirror
    /// (`refcount(page) == table occurrences + index membership`). This is
    /// the invariant the error kernel's failure-atomicity guarantee is
    /// stated against.
    pub fn check_invariants(&self) -> Result<()> {
        if self.active_count() + self.free_count() != self.capacity() {
            bail!(
                "slot accounting broke: {} active + {} free != {} capacity",
                self.active_count(),
                self.free_count(),
                self.capacity()
            );
        }
        let bs = self.pool.as_ref().map(|p| p.block_size());
        for (slot, info) in self.state.iter().enumerate() {
            match info {
                Some(info) => {
                    if info.pos > self.max_seq {
                        bail!("slot {slot}: pos {} past max_seq {}", info.pos, self.max_seq);
                    }
                    if let Some(bs) = bs {
                        let covered = self.tables[slot].len() * bs;
                        if info.pos > covered {
                            bail!("slot {slot}: pos {} past covered {covered}", info.pos);
                        }
                        if info.pos < self.shared[slot] * bs {
                            bail!(
                                "slot {slot}: pos {} inside its {} read-only shared pages",
                                info.pos,
                                self.shared[slot]
                            );
                        }
                    }
                }
                None => {
                    if !self.tables[slot].is_empty() {
                        bail!("free slot {slot} still holds {} pages", self.tables[slot].len());
                    }
                    if !self.prompts[slot].is_empty() || self.shared[slot] != 0 {
                        bail!("free slot {slot} has stale prompt/shared state");
                    }
                }
            }
        }
        let Some(pool) = self.pool.as_ref() else { return Ok(()) };
        pool.check_invariants()?;
        let mut refs = vec![0u32; pool.total_blocks()];
        for table in &self.tables {
            for &p in table {
                match refs.get_mut(p as usize) {
                    Some(r) => *r += 1,
                    None => bail!("table maps out-of-range page {p}"),
                }
            }
        }
        if let Some(idx) = self.prefix.as_ref() {
            for &p in &idx.pages() {
                match refs.get_mut(p as usize) {
                    Some(r) => *r += 1,
                    None => bail!("prefix index holds out-of-range page {p}"),
                }
            }
        }
        for (p, &want) in refs.iter().enumerate() {
            let got = pool.refcount(p as u32);
            if got != want {
                bail!("page {p}: refcount {got}, but tables+index hold {want} references");
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn allocate_up_to_capacity_then_none() {
        let mut m = SlotMap::new(2, 8);
        let a = m.allocate(10).unwrap();
        let b = m.allocate(11).unwrap();
        assert_ne!(a, b);
        assert_eq!(m.allocate(12), None);
        assert_eq!(m.active_count(), 2);
        assert_eq!(m.free_count(), 0);
    }

    #[test]
    fn release_frees_and_reuses_at_pos_zero() {
        let mut m = SlotMap::new(1, 8);
        let s = m.allocate(1).unwrap();
        m.advance(s).unwrap();
        m.advance(s).unwrap();
        assert_eq!(m.pos(s), Some(2));
        assert_eq!(m.release(s).unwrap(), 1);
        assert!(!m.is_active(s));
        let s2 = m.allocate(2).unwrap();
        assert_eq!(s2, s);
        assert_eq!(m.pos(s2), Some(0));
    }

    #[test]
    fn double_release_and_free_advance_fail() {
        let mut m = SlotMap::new(1, 8);
        let s = m.allocate(1).unwrap();
        m.release(s).unwrap();
        assert!(m.release(s).is_err());
        assert!(m.advance(s).is_err());
        assert!(m.release(99).is_err());
    }

    #[test]
    fn advance_stops_at_max_seq() {
        let mut m = SlotMap::new(1, 2);
        let s = m.allocate(1).unwrap();
        assert_eq!(m.advance(s).unwrap(), 1);
        assert_eq!(m.advance(s).unwrap(), 2);
        assert!(m.advance(s).is_err());
    }

    #[test]
    fn accounting_invariant_under_churn() {
        let mut m = SlotMap::new(3, 4);
        let mut held = Vec::new();
        for id in 0..10u64 {
            if let Some(s) = m.allocate(id) {
                held.push(s);
            }
            assert!(m.active_count() <= m.capacity());
            assert_eq!(m.active_count() + m.free_count(), m.capacity());
            if held.len() == 3 {
                let s = held.remove(0);
                m.release(s).unwrap();
            }
        }
    }

    #[test]
    fn advance_by_respects_capacity_and_rejects_zero() {
        let mut m = SlotMap::new(1, 8);
        let s = m.allocate(1).unwrap();
        assert_eq!(m.advance_by(s, 5).unwrap(), 5);
        assert!(m.advance_by(s, 0).is_err());
        assert!(m.advance_by(s, 4).is_err(), "5 + 4 > 8 must fail");
        assert_eq!(m.pos(s), Some(5), "failed advance must not move the position");
        assert_eq!(m.advance_by(s, 3).unwrap(), 8);
        assert!(m.advance(s).is_err());
        m.release(s).unwrap();
        assert!(m.advance_by(s, 1).is_err());
    }

    #[test]
    fn paged_grow_advance_release_roundtrip() {
        // 2 slots, 16-position logical range, 4 pages of 4 tokens shared.
        let mut m = SlotMap::paged(2, 16, 4, 4);
        assert!(m.is_paged());
        let a = m.allocate(1).unwrap();
        let b = m.allocate(2).unwrap();
        // No pages yet: advancing must fail until capacity is ensured.
        assert!(m.advance(a).is_err());
        assert!(m.ensure_capacity(a, 1).unwrap());
        assert_eq!(m.table(a).len(), 1);
        // The same target again is a no-op.
        assert!(m.ensure_capacity(a, 4).unwrap());
        assert_eq!(m.table(a).len(), 1);
        for _ in 0..4 {
            m.advance(a).unwrap();
        }
        // Position 4 needs a second page.
        assert!(m.advance(a).is_err());
        assert!(m.ensure_capacity(a, 5).unwrap());
        m.advance(a).unwrap();
        // Slot b grabs the remaining 2 pages; the pool is then dry.
        assert!(m.ensure_capacity(b, 8).unwrap());
        assert_eq!(m.pool().unwrap().free_blocks(), 0);
        assert!(!m.ensure_capacity(a, 9).unwrap(), "pool dry: growth must report false");
        // Tables never alias (no prefix cache here).
        let mut all: Vec<u32> = m.table(a).iter().chain(m.table(b)).copied().collect();
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len(), 4);
        // Releasing a slot returns its pages.
        m.release(b).unwrap();
        assert!(m.table(b).is_empty());
        assert_eq!(m.pool().unwrap().free_blocks(), 2);
        assert!(m.ensure_capacity(a, 9).unwrap());
        m.release(a).unwrap();
        assert_eq!(m.pool().unwrap().free_blocks(), 4);
        assert_eq!(m.pool().unwrap().used_blocks(), 0);
    }

    #[test]
    fn paged_rejects_growth_past_max_seq_and_dense_rejects_growth() {
        let mut m = SlotMap::paged(1, 8, 8, 4);
        let s = m.allocate(1).unwrap();
        assert!(m.ensure_capacity(s, 9).is_err());
        assert!(m.ensure_capacity(99, 1).is_err());
        m.release(s).unwrap();
        assert!(m.ensure_capacity(s, 1).is_err(), "free slot cannot grow");
        let mut d = SlotMap::new(1, 8);
        let s = d.allocate(1).unwrap();
        assert!(d.ensure_capacity(s, 1).is_err(), "dense map has no pages");
    }

    #[test]
    fn invariant_audit_covers_dense_paged_and_prefix_maps() {
        let mut d = SlotMap::new(2, 8);
        d.check_invariants().unwrap();
        let s = d.allocate(1).unwrap();
        d.advance(s).unwrap();
        d.check_invariants().unwrap();
        let mut m = SlotMap::paged(2, 16, 4, 4).with_prefix_cache();
        let prompt: Vec<i32> = (0..8).collect();
        let (a, _) = m.admit_paged(1, &prompt, 3).unwrap().unwrap();
        m.ensure_capacity(a, 8).unwrap();
        m.advance_by(a, 8).unwrap();
        m.check_invariants().unwrap();
        m.release(a).unwrap();
        m.check_invariants().unwrap();
        // Corruption is caught: a page reference the tables don't hold.
        m.pool.as_mut().unwrap().retain(m.prefix().unwrap().pages()[0]).unwrap();
        assert!(m.check_invariants().is_err());
    }

    // -- prefix cache (refcounted copy-on-write sharing) -------------------

    /// Feed `prompt[pos..pos+n]` into an admitted slot the way the
    /// scheduler does: grow, then advance (donation happens inside).
    fn feed(m: &mut SlotMap, slot: usize, n: usize) {
        let pos = m.pos(slot).unwrap();
        assert!(m.ensure_capacity(slot, pos + n).unwrap());
        m.advance_by(slot, n).unwrap();
    }

    #[test]
    fn prefix_admission_maps_shared_pages_and_cow_caps_the_match() {
        // Pool of 8 pages x 4 tokens, prompt of exactly 2 pages.
        let mut m = SlotMap::paged(3, 32, 8, 4).with_prefix_cache();
        let prompt: Vec<i32> = (0..8).collect();
        // Cold admission: nothing cached, 1 fresh page claimed.
        let (a, cached) = m.admit_paged(1, &prompt, 3).unwrap().unwrap();
        assert_eq!(cached, 0);
        assert_eq!(m.table(a).len(), 1);
        assert_eq!(m.shared_pages(a), 0);
        // Feed the whole prompt: pages 0 and 1 fill inside the prompt and
        // are donated as they fill.
        feed(&mut m, a, 4);
        assert_eq!(m.prefix().unwrap().cached_pages(), 1);
        feed(&mut m, a, 4);
        assert_eq!(m.prefix().unwrap().cached_pages(), 2);
        // Donated pages are shared: slot ref + index ref.
        let p0 = m.table(a)[0];
        assert_eq!(m.pool().unwrap().refcount(p0), 2);
        // Warm admission with the same prompt: the match is capped one
        // token short of the prompt, so only page 0 is mapped (page 1
        // holds the last prompt token -> recomputed into a fresh page:
        // copy-on-write by recompute).
        let (b, cached) = m.admit_paged(2, &prompt, 3).unwrap().unwrap();
        assert_eq!(cached, 4);
        assert_eq!(m.shared_pages(b), 1);
        assert_eq!(m.table(b)[0], p0, "page 0 aliased read-only");
        assert_ne!(m.table(b)[1], m.table(a)[1], "written page is a fresh copy");
        assert_eq!(m.pool().unwrap().refcount(p0), 3);
        assert_eq!(m.pos(b), Some(4), "positions start after the cached prefix");
        // A longer prompt with the same two leading pages maps both.
        let mut long = prompt.clone();
        long.extend([90, 91, 92]);
        let (c, cached) = m.admit_paged(3, &long, 3).unwrap().unwrap();
        assert_eq!(cached, 8);
        assert_eq!(m.shared_pages(c), 2);
        // Releasing every slot keeps donated pages resident via the index.
        for s in [a, b, c] {
            m.release(s).unwrap();
        }
        assert_eq!(m.prefix().unwrap().cached_pages(), 2);
        assert_eq!(m.pool().unwrap().used_blocks(), 2, "index keeps 2 pages resident");
        assert_eq!(m.available_pages(), 8, "but both are evictable under pressure");
    }

    #[test]
    fn prefix_pressure_evicts_lru_unreferenced_pages_only() {
        // 3 pages of 2 tokens. Request A fills + donates page 0, then
        // releases; the page stays resident. New allocations prefer free
        // pages, then evict the LRU donated page.
        let mut m = SlotMap::paged(2, 8, 3, 2).with_prefix_cache();
        let pa: Vec<i32> = vec![7, 8, 9];
        let (a, _) = m.admit_paged(1, &pa, 2).unwrap().unwrap();
        feed(&mut m, a, 2);
        m.release(a).unwrap();
        assert_eq!(m.prefix().unwrap().cached_pages(), 1);
        assert_eq!(m.available_pages(), 3);
        // B maps the cached page read-only; it is now referenced, hence
        // unevictable, and available drops by one.
        let (b, cached) = m.admit_paged(2, &pa, 2).unwrap().unwrap();
        assert_eq!(cached, 2);
        assert_eq!(m.available_pages(), 1);
        // C needs 2 fresh pages but only 1 is claimable: watermark refuses.
        let pc: Vec<i32> = vec![50, 51, 52];
        assert!(m.admit_paged(3, &pc, 2).unwrap().is_none());
        // A 1-page request passes, draining the last free page.
        let (c, _) = m.admit_paged(3, &[60], 1).unwrap().unwrap();
        // Growth for b under a dry pool: no index page has refcount 1
        // (the only cached page is mapped by b), so growth reports false.
        assert!(!m.ensure_capacity(b, 5).unwrap());
        // Releasing c frees its page; growth then succeeds.
        m.release(c).unwrap();
        assert!(m.ensure_capacity(b, 5).unwrap());
        m.release(b).unwrap();
        // Now the cached page is unreferenced: a fresh 3-page demand
        // evicts it from the index under pressure.
        let (d, _) = m.admit_paged(4, &[70, 71, 72, 73, 74], 3).unwrap().unwrap();
        assert!(m.ensure_capacity(d, 5).unwrap());
        assert_eq!(m.prefix().unwrap().cached_pages(), 0, "LRU page evicted under pressure");
        assert_eq!(m.table(d).len(), 3);
    }

    #[test]
    fn prefix_pages_with_generated_tokens_are_never_donated() {
        let mut m = SlotMap::paged(1, 16, 4, 4).with_prefix_cache();
        // Prompt of 6 tokens: page 0 is prompt-covered, page 1 is not
        // (positions 4..8 span prompt tail + generated tokens).
        let prompt: Vec<i32> = (10..16).collect();
        let (a, _) = m.admit_paged(1, &prompt, 3).unwrap().unwrap();
        feed(&mut m, a, 6); // prompt
        feed(&mut m, a, 2); // generated, fills page 1
        assert_eq!(m.prefix().unwrap().cached_pages(), 1, "only the prompt page");
        m.release(a).unwrap();
        assert_eq!(m.pool().unwrap().used_blocks(), 1);
    }

    // -- speculative rewind (accept-prefix rollback) -----------------------

    #[test]
    fn rewind_restores_position_dense_and_zero_is_noop() {
        let mut m = SlotMap::new(1, 8);
        let s = m.allocate(1).unwrap();
        m.advance_by(s, 5).unwrap();
        assert_eq!(m.rewind_by(s, 0).unwrap(), 5, "n == 0 is a no-op");
        assert_eq!(m.rewind_by(s, 3).unwrap(), 2);
        assert_eq!(m.pos(s), Some(2));
        // Rewind composes with re-advance: the slot is fully usable.
        m.advance_by(s, 6).unwrap();
        assert_eq!(m.pos(s), Some(8));
        assert!(m.rewind_by(s, 9).is_err(), "rewind past position 0");
        assert_eq!(m.pos(s), Some(8), "failed rewind changes nothing");
        assert!(m.rewind_by(7, 1).is_err(), "slot out of range");
        m.release(s).unwrap();
        assert!(m.rewind_by(s, 1).is_err(), "free slot cannot rewind");
        m.check_invariants().unwrap();
    }

    #[test]
    fn paged_rewind_releases_pages_past_the_boundary() {
        let mut m = SlotMap::paged(1, 32, 8, 4);
        let s = m.allocate(1).unwrap();
        assert!(m.ensure_capacity(s, 11).unwrap());
        m.advance_by(s, 11).unwrap();
        assert_eq!(m.table(s).len(), 3);
        let free = m.pool().unwrap().free_blocks();
        // 11 -> 5 crosses one page boundary: exactly one page comes back.
        assert_eq!(m.rewind_by(s, 6).unwrap(), 5);
        assert_eq!(m.table(s).len(), 2);
        assert_eq!(m.pool().unwrap().free_blocks(), free + 1);
        m.check_invariants().unwrap();
        // Rewinding to zero releases everything the slot held.
        assert_eq!(m.rewind_by(s, 5).unwrap(), 0);
        assert_eq!(m.table(s).len(), 0);
        assert_eq!(m.pool().unwrap().used_blocks(), 0);
        m.check_invariants().unwrap();
        // And the slot grows + advances again afterwards.
        assert!(m.ensure_capacity(s, 3).unwrap());
        m.advance_by(s, 3).unwrap();
        assert_eq!(m.pos(s), Some(3));
        m.check_invariants().unwrap();
    }

    #[test]
    fn rewind_guards_shared_pages_and_donation_frontier() {
        let mut m = SlotMap::paged(2, 32, 8, 4).with_prefix_cache();
        let prompt: Vec<i32> = (0..8).collect();
        let (a, _) = m.admit_paged(1, &prompt, 4).unwrap().unwrap();
        feed(&mut m, a, 8); // donates pages 0 and 1
        let (b, cached) = m.admit_paged(2, &prompt, 4).unwrap().unwrap();
        assert_eq!(cached, 4, "page 0 mapped read-only");
        feed(&mut m, b, 4); // finish the prompt: pos 8
        feed(&mut m, b, 2); // generated tokens: pos 10
        // Generated tokens roll back fine...
        assert_eq!(m.rewind_by(b, 2).unwrap(), 8);
        // ...but the processed-prompt frontier is a wall,
        assert!(m.rewind_by(b, 1).is_err(), "donated prompt pages cannot be unwound");
        // and the read-only shared page doubly so.
        assert!(m.rewind_by(b, 5).is_err(), "shared pages are off limits");
        assert_eq!(m.pos(b), Some(8), "failed rewinds left the position alone");
        m.check_invariants().unwrap();
    }

    /// Satellite regression: a draft window that grew across a page
    /// boundary and then got rejected must leave no trace — the grown
    /// pages return to the pool and are never index-resident, because
    /// `advance_speculative` structurally skips donation.
    #[test]
    fn rewound_speculative_pages_are_never_index_resident() {
        let mut m = SlotMap::paged(1, 32, 8, 4).with_prefix_cache();
        let prompt: Vec<i32> = (0..8).collect();
        let (s, _) = m.admit_paged(1, &prompt, 4).unwrap().unwrap();
        feed(&mut m, s, 8); // prompt committed: pages 0 and 1 donated
        assert_eq!(m.prefix().unwrap().cached_pages(), 2);
        // A 6-token draft window grows the table across a page boundary.
        assert!(m.ensure_capacity(s, 14).unwrap());
        m.advance_speculative(s, 6).unwrap();
        assert_eq!(m.pos(s), Some(14));
        assert_eq!(m.table(s).len(), 4);
        assert_eq!(
            m.prefix().unwrap().cached_pages(),
            2,
            "unverified draft pages never reach the index"
        );
        // The whole window is rejected: both grown pages come back clean.
        let grown: Vec<u32> = m.table(s)[2..].to_vec();
        assert_eq!(m.rewind_by(s, 6).unwrap(), 8);
        assert_eq!(m.table(s).len(), 2);
        for &p in &grown {
            assert_eq!(m.pool().unwrap().refcount(p), 0, "rejected page left resident");
            assert!(
                !m.prefix().unwrap().pages().contains(&p),
                "rejected page {p} is index-resident"
            );
        }
        assert_eq!(m.prefix().unwrap().cached_pages(), 2);
        m.check_invariants().unwrap();
    }

    #[test]
    fn advance_speculative_never_donates_even_inside_the_prompt() {
        let mut m = SlotMap::paged(1, 16, 4, 4).with_prefix_cache();
        let prompt: Vec<i32> = (0..8).collect();
        let (s, _) = m.admit_paged(1, &prompt, 3).unwrap().unwrap();
        assert!(m.ensure_capacity(s, 8).unwrap());
        m.advance_speculative(s, 8).unwrap();
        assert_eq!(
            m.prefix().unwrap().cached_pages(),
            0,
            "speculative writes are never donated, prompt-covered or not"
        );
        m.check_invariants().unwrap();
    }

    /// Property (satellite): random interleavings of paged+prefix
    /// admit / grow / advance (with donation) / release keep
    /// `free + Σ(refcount > 0) == total`, every page's refcount equal to
    /// its table occurrences plus its index membership, shared prefix
    /// pages read-only (positions never enter them), and donated pages
    /// resident until evicted.
    #[test]
    fn prop_prefix_interleavings_keep_refcounts_honest() {
        use crate::testing::prop::forall;
        forall(0xc0de, 250, |g| {
            let cap = g.int(1, 3);
            let bs = g.int(1, 4);
            let max_blocks = g.int(2, 8);
            let max_seq = (max_blocks * bs).min(g.int(2, 20)).max(2);
            let mut m = SlotMap::paged(cap, max_seq, max_blocks, bs).with_prefix_cache();
            let mut held: Vec<usize> = Vec::new();
            // A tiny alphabet + short prompts makes prefix coincidences
            // (and the sharing they trigger) common.
            let mut mk_prompt = |g: &mut crate::testing::prop::Gen| -> Vec<i32> {
                (0..g.int(1, max_seq - 1)).map(|_| g.int(0, 2) as i32).collect()
            };
            for op in 0..g.int(5, 60) {
                match g.int(0, 3) {
                    0 => {
                        let prompt = mk_prompt(g);
                        // End-to-end demand the way the scheduler computes
                        // it; like `submit`, demands the pool can never
                        // hold are rejected up front — admit_paged relies
                        // on `demand > matched`.
                        let total = (prompt.len() + g.int(0, 6)).min(max_seq).div_ceil(bs);
                        if total > max_blocks {
                            continue;
                        }
                        if let Some((s, cached)) =
                            m.admit_paged(op as u64, &prompt, total).map_err(|e| e.to_string())?
                        {
                            if cached >= prompt.len() {
                                return Err(format!("op {op}: cached {cached} covers prompt"));
                            }
                            if cached % bs != 0 || m.pos(s) != Some(cached) {
                                return Err(format!("op {op}: bad cached start {cached}"));
                            }
                            held.push(s);
                        }
                    }
                    1 => {
                        if !held.is_empty() {
                            let s = held.swap_remove(g.int(0, held.len() - 1));
                            m.release(s).map_err(|e| e.to_string())?;
                        }
                    }
                    _ => {
                        if !held.is_empty() {
                            let s = *g.pick(&held);
                            let pos = m.pos(s).expect("held");
                            let n = g.int(1, 4).min(max_seq - pos);
                            if n > 0 && m.ensure_capacity(s, pos + n).map_err(|e| e.to_string())? {
                                m.advance_by(s, n).map_err(|e| e.to_string())?;
                            }
                        }
                    }
                }
                let pool = m.pool().unwrap();
                if pool.free_blocks() + pool.used_blocks() != pool.total_blocks() {
                    return Err(format!("op {op}: resident invariant broke"));
                }
                // Mirror refcounts: table occurrences + index membership.
                let index_pages = m.prefix().unwrap().pages();
                for page in 0..pool.total_blocks() as u32 {
                    let in_tables =
                        (0..cap).flat_map(|s| m.table(s)).filter(|&&p| p == page).count();
                    let in_index = index_pages.iter().filter(|&&p| p == page).count();
                    if in_index > 1 {
                        return Err(format!("op {op}: page {page} indexed twice"));
                    }
                    if pool.refcount(page) as usize != in_tables + in_index {
                        return Err(format!(
                            "op {op}: page {page} refcount {} vs {} table refs + {} index",
                            pool.refcount(page),
                            in_tables,
                            in_index
                        ));
                    }
                }
                // Shared prefix pages are read-only: the occupant's own
                // writes all land at positions past them.
                for s in &held {
                    let shared_end = m.shared_pages(*s) * bs;
                    if m.pos(*s).expect("held") < shared_end {
                        return Err(format!("op {op}: slot {s} position inside shared pages"));
                    }
                }
            }
            Ok(())
        });
    }

    /// Property: under random paged allocate/grow/advance/release
    /// interleavings (prefix cache off), pool accounting never leaks
    /// (`free + used == total`, used == sum of table lengths), tables cover
    /// exactly `ceil(covered_target/bs)` pages, no physical page is ever
    /// shared by two slots, and positions never pass the covered range.
    #[test]
    fn prop_paged_interleavings_keep_pool_honest() {
        use crate::testing::prop::forall;
        forall(0xb10c, 300, |g| {
            let cap = g.int(1, 4);
            let bs = g.int(1, 5);
            let max_blocks = g.int(1, 8);
            let max_seq = (max_blocks * bs).min(g.int(1, 24));
            let mut m = SlotMap::paged(cap, max_seq, max_blocks, bs);
            let mut held: Vec<usize> = Vec::new();
            let ops = g.int(5, 60);
            for op in 0..ops {
                match g.int(0, 3) {
                    0 => {
                        if let Some(s) = m.allocate(op as u64) {
                            if !m.table(s).is_empty() {
                                return Err(format!("op {op}: fresh slot {s} has pages"));
                            }
                            held.push(s);
                        }
                    }
                    1 => {
                        if !held.is_empty() {
                            let s = held.swap_remove(g.int(0, held.len() - 1));
                            m.release(s).unwrap();
                        }
                    }
                    2 => {
                        if !held.is_empty() {
                            let s = *g.pick(&held);
                            let target = g.int(0, max_seq);
                            let ok = m.ensure_capacity(s, target).map_err(|e| e.to_string())?;
                            let covered = m.table(s).len() * bs;
                            if ok && covered < target {
                                return Err(format!(
                                    "op {op}: grow to {target} granted only {covered}"
                                ));
                            }
                            if !ok && m.pool().unwrap().free_blocks() != 0 {
                                return Err(format!(
                                    "op {op}: growth failed with free pages left"
                                ));
                            }
                        }
                    }
                    _ => {
                        if !held.is_empty() {
                            let s = *g.pick(&held);
                            let pos = m.pos(s).expect("held slot");
                            let covered = m.table(s).len() * bs;
                            let r = m.advance(s);
                            if pos + 1 > covered.min(max_seq) {
                                if r.is_ok() {
                                    return Err(format!(
                                        "op {op}: advanced past coverage ({pos} + 1 > {covered})"
                                    ));
                                }
                            } else if r.is_err() {
                                return Err(format!("op {op}: covered advance failed"));
                            }
                        }
                    }
                }
                // Pool accounting vs the tables, every step.
                let pool = m.pool().unwrap();
                if pool.free_blocks() + pool.used_blocks() != pool.total_blocks() {
                    return Err(format!("op {op}: pool accounting leaked"));
                }
                let table_total: usize = (0..cap).map(|s| m.table(s).len()).sum();
                if table_total != pool.used_blocks() {
                    return Err(format!(
                        "op {op}: tables hold {table_total} pages, pool says {}",
                        pool.used_blocks()
                    ));
                }
                let mut all: Vec<u32> =
                    (0..cap).flat_map(|s| m.table(s).iter().copied()).collect();
                all.sort_unstable();
                let n = all.len();
                all.dedup();
                if all.len() != n {
                    return Err(format!("op {op}: physical page shared between slots"));
                }
            }
            Ok(())
        });
    }

    /// Property: under random allocate/free/advance/advance_by
    /// interleavings, the map never double-allocates an occupied slot,
    /// never leaks capacity (`active + free == capacity`, always), and a
    /// slot's position is monotone within one occupancy — it only moves by
    /// the granted advance, resets to zero on reallocation, and never
    /// passes `max_seq`. Checked against an independent mirror model.
    #[test]
    fn prop_random_interleavings_keep_accounting_honest() {
        use crate::testing::prop::forall;
        forall(0x510f, 300, |g| run_interleaving_case(g));
    }

    fn run_interleaving_case(g: &mut crate::testing::prop::Gen) -> Result<(), String> {
        let cap = g.int(1, 6);
        let max_seq = g.int(1, 12);
        let mut m = SlotMap::new(cap, max_seq);
        // Mirror model: slot -> (id, pos).
        let mut model: Vec<Option<(u64, usize)>> = vec![None; cap];
        let mut next_id = 0u64;
        let ops = g.int(5, 80);
        for op in 0..ops {
            match g.int(0, 3) {
                0 => {
                    // allocate: must pick the lowest free slot, at pos 0,
                    // and never land on an occupied one.
                    let expect = model.iter().position(|s| s.is_none());
                    let got = m.allocate(next_id);
                    if got != expect {
                        return Err(format!("op {op}: allocate {got:?}, expected {expect:?}"));
                    }
                    if let Some(s) = got {
                        if model[s].is_some() {
                            return Err(format!("op {op}: slot {s} double-allocated"));
                        }
                        if m.pos(s) != Some(0) {
                            return Err(format!("op {op}: fresh slot {s} not at pos 0"));
                        }
                        model[s] = Some((next_id, 0));
                        next_id += 1;
                    }
                }
                1 => {
                    // release an arbitrary slot (occupied or not).
                    let s = g.int(0, cap - 1);
                    match (m.release(s), model[s]) {
                        (Ok(id), Some((mid, _))) if id == mid => model[s] = None,
                        (Err(_), None) => {}
                        (r, state) => {
                            return Err(format!("op {op}: release({s}) = {r:?} vs {state:?}"))
                        }
                    }
                }
                _ => {
                    // advance by 1 or by a random chunk.
                    let s = g.int(0, cap - 1);
                    let n = if g.bool() { 1 } else { g.int(1, 6) };
                    match (m.advance_by(s, n), model[s]) {
                        (Ok(p), Some((id, pos))) => {
                            if pos + n > max_seq || p != pos + n {
                                return Err(format!(
                                    "op {op}: advance_by({s}, {n}) = {p} from pos {pos} \
                                     (max_seq {max_seq})"
                                ));
                            }
                            model[s] = Some((id, p));
                        }
                        (Err(_), Some((_, pos))) if pos + n > max_seq => {}
                        (Err(_), None) => {}
                        (r, state) => {
                            return Err(format!(
                                "op {op}: advance_by({s}, {n}) = {r:?} vs {state:?}"
                            ))
                        }
                    }
                }
            }
            // Capacity can never leak, whatever the interleaving.
            let occupied = model.iter().filter(|s| s.is_some()).count();
            if m.active_count() != occupied || m.free_count() != cap - occupied {
                return Err(format!(
                    "op {op}: accounting {} active / {} free, model says {occupied}/{}",
                    m.active_count(),
                    m.free_count(),
                    cap - occupied
                ));
            }
            // Positions agree with the mirror everywhere.
            for s in 0..cap {
                if m.pos(s) != model[s].map(|(_, p)| p) {
                    return Err(format!("op {op}: slot {s} pos {:?} drifted", m.pos(s)));
                }
            }
        }
        Ok(())
    }

    /// Property (satellite): adding `rewind_by` and `advance_speculative`
    /// to random paged interleavings preserves every prior invariant
    /// (`free + Σ(refcount > 0) == total`, used pages == table pages, no
    /// page shared by two slots) and adds the rewind contract, checked
    /// against a mirror position model: a granted rewind moves the
    /// position by exactly `n` and truncates the table to
    /// `ceil(pos / bs)` pages; a denied rewind (free slot, past zero)
    /// changes nothing.
    #[test]
    fn prop_rewind_interleavings_keep_pool_honest() {
        use crate::testing::prop::forall;
        forall(0x4e71, 300, |g| {
            let cap = g.int(1, 4);
            let bs = g.int(1, 5);
            let max_blocks = g.int(1, 8);
            let max_seq = (max_blocks * bs).min(g.int(1, 24));
            let mut m = SlotMap::paged(cap, max_seq, max_blocks, bs);
            // Mirror: slot -> position; the pool is checked structurally.
            let mut model: Vec<Option<usize>> = vec![None; cap];
            let mut held: Vec<usize> = Vec::new();
            for op in 0..g.int(5, 80) {
                match g.int(0, 4) {
                    0 => {
                        if let Some(s) = m.allocate(op as u64) {
                            model[s] = Some(0);
                            held.push(s);
                        }
                    }
                    1 => {
                        if !held.is_empty() {
                            let s = held.swap_remove(g.int(0, held.len() - 1));
                            m.release(s).map_err(|e| e.to_string())?;
                            model[s] = None;
                        }
                    }
                    2 => {
                        if !held.is_empty() {
                            let s = *g.pick(&held);
                            let pos = model[s].expect("held");
                            let n = g.int(1, 4).min(max_seq - pos);
                            if n > 0
                                && m.ensure_capacity(s, pos + n).map_err(|e| e.to_string())?
                            {
                                // With the prefix cache off, speculative and
                                // committed advances must account identically.
                                let got = if g.bool() {
                                    m.advance_speculative(s, n)
                                } else {
                                    m.advance_by(s, n)
                                }
                                .map_err(|e| e.to_string())?;
                                if got != pos + n {
                                    return Err(format!(
                                        "op {op}: advance {got} != {}",
                                        pos + n
                                    ));
                                }
                                model[s] = Some(got);
                            }
                        }
                    }
                    _ => {
                        // Rewind an arbitrary slot by an arbitrary
                        // (sometimes illegal) amount.
                        let s = g.int(0, cap - 1);
                        let n = g.int(0, max_seq + 1);
                        match (m.rewind_by(s, n), model[s]) {
                            (Ok(p), Some(pos)) if n <= pos => {
                                if p != pos - n {
                                    return Err(format!(
                                        "op {op}: rewind_by({s}, {n}) = {p} from pos {pos}"
                                    ));
                                }
                                model[s] = Some(p);
                                let keep = m.pool().unwrap().blocks_for(p);
                                if n > 0 && m.table(s).len() != keep {
                                    return Err(format!(
                                        "op {op}: table holds {} pages after rewind to \
                                         {p}, which needs {keep}",
                                        m.table(s).len()
                                    ));
                                }
                            }
                            (Err(_), Some(pos)) if n > pos => {}
                            (Err(_), None) => {}
                            (r, state) => {
                                return Err(format!(
                                    "op {op}: rewind_by({s}, {n}) = {r:?} vs {state:?}"
                                ))
                            }
                        }
                    }
                }
                // Structural audit plus the same pool checks as the
                // non-rewind suite, after every op.
                m.check_invariants().map_err(|e| format!("op {op}: {e}"))?;
                let pool = m.pool().unwrap();
                if pool.free_blocks() + pool.used_blocks() != pool.total_blocks() {
                    return Err(format!("op {op}: pool accounting leaked"));
                }
                let table_total: usize = (0..cap).map(|s| m.table(s).len()).sum();
                if table_total != pool.used_blocks() {
                    return Err(format!(
                        "op {op}: tables hold {table_total} pages, pool says {}",
                        pool.used_blocks()
                    ));
                }
                let mut all: Vec<u32> =
                    (0..cap).flat_map(|s| m.table(s).iter().copied()).collect();
                all.sort_unstable();
                let n = all.len();
                all.dedup();
                if all.len() != n {
                    return Err(format!("op {op}: physical page shared between slots"));
                }
                for s in 0..cap {
                    if m.pos(s) != model[s] {
                        return Err(format!("op {op}: slot {s} pos {:?} drifted", m.pos(s)));
                    }
                }
            }
            Ok(())
        });
    }
}
