//! Paged KV-cache block pool: physical pages + per-request block tables.
//!
//! The dense serving path reserves a full `max_seq`-sized KV region per
//! slot, so resident cache memory scales with `slots x max_seq` no matter
//! how short the requests are. [`BlockPool`] is the allocator behind the
//! paged path: the cache is a pool of `block_size`-token physical pages
//! (the `decode_*_paged` / `prefill_*_paged` artifacts address them through
//! a per-slot block table), pages are allocated lazily as a request's
//! position crosses page boundaries, and the scheduler admits by *free-page
//! token budget* — so memory scales with tokens actually in flight.
//!
//! Accounting is strict: `free_blocks() + used_blocks() == total_blocks()`
//! is an invariant, double-frees and unknown frees are errors, and the
//! randomized [`SlotMap`](crate::serve::SlotMap) property tests cross-check
//! the pool against a mirror model.
//!
//! KV memory per pool, at `kv_bits` per cache element:
//!
//! ```text
//! bytes = blocks x block_size x 2 (K and V) x n_layers x n_heads x d_head
//!         x kv_bits / 8
//! ```
//!
//! (see [`kv_memory_bytes`]); the serving bench prints this next to its
//! paged-vs-dense sweep so the "same memory, more requests" claim is
//! auditable.

use anyhow::{bail, Result};

/// Fixed-size pool of physical KV pages with strict accounting.
///
/// Block ids are `u32` indices into the engine's physical cache
/// (`cache_k/v` dimension 1). Freed blocks are recycled LIFO so recently
/// touched pages are reused first.
#[derive(Clone, Debug)]
pub struct BlockPool {
    block_size: usize,
    /// Free physical block ids (LIFO).
    free: Vec<u32>,
    /// Per-block in-use flag — makes double-free a loud error instead of
    /// silent pool corruption.
    used: Vec<bool>,
}

impl BlockPool {
    pub fn new(total_blocks: usize, block_size: usize) -> Self {
        assert!(block_size > 0, "block_size must be positive");
        // LIFO pop order: block 0 first, matching the identity layout in
        // the single-request case.
        let free: Vec<u32> = (0..total_blocks as u32).rev().collect();
        Self { block_size, free, used: vec![false; total_blocks] }
    }

    pub fn block_size(&self) -> usize {
        self.block_size
    }

    pub fn total_blocks(&self) -> usize {
        self.used.len()
    }

    pub fn free_blocks(&self) -> usize {
        self.free.len()
    }

    pub fn used_blocks(&self) -> usize {
        self.total_blocks() - self.free_blocks()
    }

    /// Pages needed to hold `tokens` cache positions.
    pub fn blocks_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.block_size)
    }

    /// Claim one free page. `None` when the pool is exhausted.
    pub fn allocate(&mut self) -> Option<u32> {
        let b = self.free.pop()?;
        debug_assert!(!self.used[b as usize]);
        self.used[b as usize] = true;
        Some(b)
    }

    /// Return pages to the pool. Double-frees and out-of-range ids fail.
    pub fn release(&mut self, blocks: &[u32]) -> Result<()> {
        for &b in blocks {
            match self.used.get_mut(b as usize) {
                Some(u) if *u => {
                    *u = false;
                    self.free.push(b);
                }
                Some(_) => bail!("block {b} freed twice"),
                None => bail!("block {b} out of range ({} blocks)", self.total_blocks()),
            }
        }
        Ok(())
    }
}

/// Resident KV-cache bytes for a pool of `blocks` pages of `block_size`
/// tokens at `kv_bits` per element: the formula behind the paged-vs-dense
/// memory budgets in `benches/serving.rs` (K and V both cached, hence the
/// factor 2).
pub fn kv_memory_bytes(
    blocks: usize,
    block_size: usize,
    n_layers: usize,
    n_heads: usize,
    d_head: usize,
    kv_bits: f64,
) -> usize {
    let elems = blocks * block_size * 2 * n_layers * n_heads * d_head;
    (elems as f64 * kv_bits / 8.0).ceil() as usize
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn allocate_to_exhaustion_then_none() {
        let mut p = BlockPool::new(3, 16);
        let a = p.allocate().unwrap();
        let b = p.allocate().unwrap();
        let c = p.allocate().unwrap();
        assert_eq!(p.allocate(), None);
        assert_eq!(p.free_blocks(), 0);
        assert_eq!(p.used_blocks(), 3);
        let mut ids = vec![a, b, c];
        ids.sort_unstable();
        assert_eq!(ids, vec![0, 1, 2], "every physical page handed out once");
    }

    #[test]
    fn release_recycles_and_rejects_double_free() {
        let mut p = BlockPool::new(2, 8);
        let a = p.allocate().unwrap();
        p.release(&[a]).unwrap();
        assert!(p.release(&[a]).is_err(), "double free must fail");
        assert!(p.release(&[99]).is_err(), "out of range must fail");
        assert_eq!(p.free_blocks() + p.used_blocks(), p.total_blocks());
    }

    #[test]
    fn blocks_for_rounds_up() {
        let p = BlockPool::new(8, 16);
        assert_eq!(p.blocks_for(0), 0);
        assert_eq!(p.blocks_for(1), 1);
        assert_eq!(p.blocks_for(16), 1);
        assert_eq!(p.blocks_for(17), 2);
    }

    #[test]
    fn accounting_invariant_under_churn() {
        let mut p = BlockPool::new(5, 4);
        let mut held: Vec<u32> = Vec::new();
        let mut rng = crate::util::prng::Prng::new(7);
        for _ in 0..200 {
            if rng.next_u64() & 1 == 0 {
                if let Some(b) = p.allocate() {
                    held.push(b);
                }
            } else if !held.is_empty() {
                let i = rng.below(held.len());
                let b = held.swap_remove(i);
                p.release(&[b]).unwrap();
            }
            assert_eq!(p.free_blocks() + p.used_blocks(), p.total_blocks());
            assert_eq!(p.used_blocks(), held.len());
        }
    }

    #[test]
    fn kv_memory_formula() {
        // sq-2m at 4-bit KV: blocks x bs x 2 x L x H x dh x 0.5 bytes.
        let bytes = kv_memory_bytes(32, 16, 4, 4, 32, 4.0);
        assert_eq!(bytes, 32 * 16 * 2 * 4 * 4 * 32 / 2);
        // fp32 reference for the dense comparison.
        assert_eq!(kv_memory_bytes(1, 1, 1, 1, 1, 32.0), 2 * 4);
    }
}
