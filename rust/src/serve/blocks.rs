//! Paged KV-cache block pool: refcounted physical pages + per-request
//! block tables.
//!
//! The dense serving path reserves a full `max_seq`-sized KV region per
//! slot, so resident cache memory scales with `slots x max_seq` no matter
//! how short the requests are. [`BlockPool`] is the allocator behind the
//! paged path: the cache is a pool of `block_size`-token physical pages
//! (the `decode_*_paged` / `prefill_*_paged` artifacts address them through
//! a per-slot block table), pages are allocated lazily as a request's
//! position crosses page boundaries, and the scheduler admits by *free-page
//! token budget* — so memory scales with tokens actually in flight.
//!
//! Ownership is **refcounted**, not exclusive: a physical page can be
//! mapped read-only by several block tables at once (shared prompt-prefix
//! pages, see [`crate::serve::prefix`]) and by the prefix index itself.
//! [`BlockPool::allocate`] hands out a page at refcount 1,
//! [`BlockPool::retain`] adds a reference (a slot mapping a cached page, or
//! the prefix index keeping a full page resident), and
//! [`BlockPool::release`] drops references — a page returns to the free
//! list only when its last reference is gone, so eviction can never
//! reclaim a page another holder still references.
//!
//! Accounting is strict: `free_blocks() + used_blocks() == total_blocks()`
//! is an invariant where `used_blocks()` counts pages with `refcount > 0`;
//! releasing a free page (double-free) and retaining a free page are
//! errors, releases are *batch-atomic* (the whole batch is validated
//! before any page is freed, so a bad id mid-list can no longer corrupt
//! the accounting half-way), and the randomized
//! [`SlotMap`](crate::serve::SlotMap) property tests cross-check the pool
//! against a mirror model under retain/release/COW/donate interleavings.
//!
//! KV memory per pool, at `kv_bits` per cache element. Sub-byte widths are
//! packed **per page** (each layer's K or V page is its own packed buffer,
//! so a ragged tail rounds up once per page, not once over the pool) and
//! carry per-group quantization metadata — one group per token per head
//! (`d_head` elements, matching the `_kvq` fake-quant axis), 2 bytes for a
//! symmetric scale, 4 for an asymmetric scale+zero pair:
//!
//! ```text
//! page_payload = ceil(block_size x n_heads x d_head x kv_bits / 8)
//! page_meta    = kv_bits < 16 ? block_size x n_heads x (sym ? 2 : 4) : 0
//! bytes        = blocks x 2 (K and V) x n_layers x (page_payload + page_meta)
//! ```
//!
//! (see [`kv_memory_bytes`]); the serving bench prints this next to its
//! paged-vs-dense sweep so the "same memory, more requests" claim is
//! auditable. Note the formula counts *physical* pages: with prefix
//! sharing the same bytes can back many logical tables, which is exactly
//! where the extra concurrency in the `prefix_cache` bench section comes
//! from.

use anyhow::{bail, Result};

/// Fixed-size pool of refcounted physical KV pages with strict accounting.
///
/// Block ids are `u32` indices into the engine's physical cache
/// (`cache_k/v` dimension 1). Freed blocks are recycled LIFO so recently
/// touched pages are reused first.
#[derive(Clone, Debug)]
pub struct BlockPool {
    block_size: usize,
    /// Free physical block ids (LIFO).
    free: Vec<u32>,
    /// Per-block reference count; 0 = free. Makes double-free and
    /// use-after-free loud errors instead of silent pool corruption.
    refcount: Vec<u32>,
}

impl BlockPool {
    pub fn new(total_blocks: usize, block_size: usize) -> Self {
        assert!(block_size > 0, "block_size must be positive");
        // LIFO pop order: block 0 first, matching the identity layout in
        // the single-request case.
        let free: Vec<u32> = (0..total_blocks as u32).rev().collect();
        Self { block_size, free, refcount: vec![0; total_blocks] }
    }

    pub fn block_size(&self) -> usize {
        self.block_size
    }

    pub fn total_blocks(&self) -> usize {
        self.refcount.len()
    }

    pub fn free_blocks(&self) -> usize {
        self.free.len()
    }

    /// Pages with at least one live reference.
    pub fn used_blocks(&self) -> usize {
        self.total_blocks() - self.free_blocks()
    }

    /// Live references on one page (0 = free). Out-of-range ids read as 0.
    pub fn refcount(&self, block: u32) -> u32 {
        self.refcount.get(block as usize).copied().unwrap_or(0)
    }

    /// Pages needed to hold `tokens` cache positions.
    pub fn blocks_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.block_size)
    }

    /// Claim one free page at refcount 1. `None` when the pool is
    /// exhausted.
    pub fn allocate(&mut self) -> Option<u32> {
        let b = self.free.pop()?;
        debug_assert_eq!(self.refcount[b as usize], 0);
        self.refcount[b as usize] = 1;
        Some(b)
    }

    /// Add a reference to an already-live page (a slot mapping a shared
    /// prefix page, or the prefix index pinning a donated page). Retaining
    /// a free page is an error — references can only be added to pages
    /// some holder already owns.
    pub fn retain(&mut self, block: u32) -> Result<()> {
        match self.refcount.get_mut(block as usize) {
            Some(rc) if *rc > 0 => {
                *rc += 1;
                Ok(())
            }
            Some(_) => bail!("block {block} retained while free"),
            None => bail!("block {block} out of range ({} blocks)", self.total_blocks()),
        }
    }

    /// Drop one reference per listed page; pages whose last reference goes
    /// return to the free list. The batch is validated as a whole before
    /// anything is freed — out-of-range ids or more drops than a page has
    /// references fail with the pool untouched, so callers' bookkeeping
    /// can never end up disagreeing with a half-applied release.
    pub fn release(&mut self, blocks: &[u32]) -> Result<()> {
        // Validate without allocating: batches are per-request page lists
        // (a handful of entries), so the quadratic duplicate count is
        // cheaper than building a map on the serving hot path.
        for (i, &b) in blocks.iter().enumerate() {
            let Some(&rc) = self.refcount.get(b as usize) else {
                bail!("block {b} out of range ({} blocks)", self.total_blocks());
            };
            if rc == 0 {
                bail!("block {b} freed twice");
            }
            if blocks[..i].contains(&b) {
                continue; // counted at its first occurrence
            }
            let drops = blocks[i..].iter().filter(|&&x| x == b).count() as u32;
            if rc < drops {
                bail!("block {b}: {drops} refs dropped but only {rc} held");
            }
        }
        // Validated: apply. Free-list push order follows the batch order so
        // the LIFO recycling stays deterministic.
        for &b in blocks {
            let rc = &mut self.refcount[b as usize];
            *rc -= 1;
            if *rc == 0 {
                self.free.push(b);
            }
        }
        Ok(())
    }

    /// Full-accounting audit: `free + Σ(refcount > 0) == total`, every
    /// free-listed page has refcount 0 and appears exactly once. The error
    /// kernel's failure-atomicity guarantee is stated against this check —
    /// the chaos property test runs it after every scheduler step.
    pub fn check_invariants(&self) -> Result<()> {
        if self.free_blocks() + self.used_blocks() != self.total_blocks() {
            bail!(
                "pool accounting broke: {} free + {} used != {} total",
                self.free_blocks(),
                self.used_blocks(),
                self.total_blocks()
            );
        }
        let mut on_free_list = vec![false; self.total_blocks()];
        for &b in &self.free {
            let Some(seen) = on_free_list.get_mut(b as usize) else {
                bail!("free list holds out-of-range block {b}");
            };
            if *seen {
                bail!("block {b} on the free list twice");
            }
            *seen = true;
            if self.refcount[b as usize] != 0 {
                bail!("block {b} free-listed with refcount {}", self.refcount[b as usize]);
            }
        }
        for (b, &rc) in self.refcount.iter().enumerate() {
            if rc == 0 && !on_free_list[b] {
                bail!("block {b} has refcount 0 but is not on the free list");
            }
        }
        Ok(())
    }
}

/// Resident KV-cache bytes for a pool of `blocks` pages of `block_size`
/// tokens at `kv_bits` per element: the formula behind the paged-vs-dense
/// memory budgets in `benches/serving.rs` (K and V both cached, hence the
/// factor 2). Physical pages only: shared (refcount > 1) pages are counted
/// once, which is the whole point of prefix sharing — the pool invariant
/// `free + Σ(refcount > 0) == total` means resident bytes never exceed
/// this figure no matter how many tables alias a page.
///
/// Sub-byte packing rounds up **per page**, not once over the whole pool
/// (each layer's K or V page is its own packed buffer, so its tail byte
/// can't be shared with the next page), and quantized widths (< 16 bits)
/// additionally carry per-group metadata: one group per token per head —
/// the `d_head`-element groups the `_kvq` fake-quant path uses — at 2
/// bytes (f16 scale) when `symmetric`, 4 (scale + zero) otherwise. The
/// previous single-`ceil`-over-the-pool version under-counted both, which
/// made the bench's "equal byte budget" comparison quietly favor int4.
pub fn kv_memory_bytes(
    blocks: usize,
    block_size: usize,
    n_layers: usize,
    n_heads: usize,
    d_head: usize,
    kv_bits: f64,
    symmetric: bool,
) -> usize {
    let page_elems = block_size * n_heads * d_head;
    let page_payload = (page_elems as f64 * kv_bits / 8.0).ceil() as usize;
    let page_meta =
        if kv_bits < 16.0 { block_size * n_heads * if symmetric { 2 } else { 4 } } else { 0 };
    blocks * 2 * n_layers * (page_payload + page_meta)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn allocate_to_exhaustion_then_none() {
        let mut p = BlockPool::new(3, 16);
        let a = p.allocate().unwrap();
        let b = p.allocate().unwrap();
        let c = p.allocate().unwrap();
        assert_eq!(p.allocate(), None);
        assert_eq!(p.free_blocks(), 0);
        assert_eq!(p.used_blocks(), 3);
        let mut ids = vec![a, b, c];
        ids.sort_unstable();
        assert_eq!(ids, vec![0, 1, 2], "every physical page handed out once");
    }

    #[test]
    fn release_recycles_and_rejects_double_free() {
        let mut p = BlockPool::new(2, 8);
        let a = p.allocate().unwrap();
        p.release(&[a]).unwrap();
        assert!(p.release(&[a]).is_err(), "double free must fail");
        assert!(p.release(&[99]).is_err(), "out of range must fail");
        assert_eq!(p.free_blocks() + p.used_blocks(), p.total_blocks());
    }

    #[test]
    fn retain_shares_and_release_frees_only_at_zero() {
        let mut p = BlockPool::new(2, 8);
        let a = p.allocate().unwrap();
        p.retain(a).unwrap();
        p.retain(a).unwrap();
        assert_eq!(p.refcount(a), 3);
        assert_eq!(p.used_blocks(), 1, "shared page is resident once");
        p.release(&[a]).unwrap();
        p.release(&[a]).unwrap();
        assert_eq!(p.refcount(a), 1);
        assert_eq!(p.free_blocks(), 1, "page still held");
        p.release(&[a]).unwrap();
        assert_eq!(p.refcount(a), 0);
        assert_eq!(p.free_blocks(), 2, "last release frees");
        // Retaining a free page must fail: references are only added to
        // pages some holder already owns.
        assert!(p.retain(a).is_err());
        assert!(p.retain(99).is_err());
    }

    #[test]
    fn release_batch_is_atomic_on_bad_id() {
        // Regression (satellite): a bad id mid-batch used to free the
        // earlier pages before bailing, leaving the pool and the caller's
        // bookkeeping disagreeing. The whole batch must now be validated
        // first, so a failed release leaves the pool byte-identical.
        let mut p = BlockPool::new(4, 8);
        let a = p.allocate().unwrap();
        let b = p.allocate().unwrap();
        let before_free = p.free_blocks();
        let before_rc: Vec<u32> = (0..4).map(|i| p.refcount(i)).collect();
        assert!(p.release(&[a, 99, b]).is_err(), "out-of-range mid-batch");
        assert_eq!(p.free_blocks(), before_free, "no page freed by a failed batch");
        assert_eq!((0..4).map(|i| p.refcount(i)).collect::<Vec<_>>(), before_rc);
        // Same for a double-free mid-batch...
        let c = p.allocate().unwrap();
        assert!(p.release(&[a, c, c]).is_err(), "c held once but dropped twice");
        assert_eq!(p.refcount(a), 1);
        assert_eq!(p.refcount(c), 1);
        // ...while a batch that drops a multiply-held page twice is fine.
        p.retain(c).unwrap();
        p.release(&[a, c, c]).unwrap();
        assert_eq!(p.refcount(a), 0);
        assert_eq!(p.refcount(c), 0);
        assert_eq!(p.free_blocks() + p.used_blocks(), p.total_blocks());
    }

    #[test]
    fn blocks_for_rounds_up() {
        let p = BlockPool::new(8, 16);
        assert_eq!(p.blocks_for(0), 0);
        assert_eq!(p.blocks_for(1), 1);
        assert_eq!(p.blocks_for(16), 1);
        assert_eq!(p.blocks_for(17), 2);
    }

    #[test]
    fn accounting_invariant_under_churn() {
        let mut p = BlockPool::new(5, 4);
        let mut held: Vec<u32> = Vec::new();
        let mut rng = crate::util::prng::Prng::new(7);
        for _ in 0..200 {
            if rng.next_u64() & 1 == 0 {
                if let Some(b) = p.allocate() {
                    held.push(b);
                }
            } else if !held.is_empty() {
                let i = rng.below(held.len());
                let b = held.swap_remove(i);
                p.release(&[b]).unwrap();
            }
            assert_eq!(p.free_blocks() + p.used_blocks(), p.total_blocks());
            assert_eq!(p.used_blocks(), held.len());
        }
    }

    /// Property: under random allocate/retain/release interleavings the
    /// refcounts track a mirror model exactly and the resident-page
    /// invariant `free + Σ(refcount > 0) == total` never breaks.
    #[test]
    fn prop_refcount_interleavings_keep_invariant() {
        use crate::testing::prop::forall;
        forall(0x5efc, 300, |g| {
            let total = g.int(1, 6);
            let mut p = BlockPool::new(total, 4);
            // Mirror: refs held per page, as a flat list of (page) handles.
            let mut handles: Vec<u32> = Vec::new();
            for op in 0..g.int(5, 80) {
                match g.int(0, 2) {
                    0 => {
                        if let Some(b) = p.allocate() {
                            handles.push(b);
                        } else if p.free_blocks() > 0 {
                            return Err(format!("op {op}: allocation failed with free pages"));
                        }
                    }
                    1 => {
                        if !handles.is_empty() {
                            let b = *g.pick(&handles);
                            p.retain(b).map_err(|e| format!("op {op}: {e}"))?;
                            handles.push(b);
                        }
                    }
                    _ => {
                        if !handles.is_empty() {
                            let i = g.int(0, handles.len() - 1);
                            let b = handles.swap_remove(i);
                            p.release(&[b]).map_err(|e| format!("op {op}: {e}"))?;
                        }
                    }
                }
                if p.free_blocks() + p.used_blocks() != p.total_blocks() {
                    return Err(format!("op {op}: resident invariant broke"));
                }
                for page in 0..total as u32 {
                    let want = handles.iter().filter(|&&h| h == page).count() as u32;
                    if p.refcount(page) != want {
                        return Err(format!(
                            "op {op}: page {page} refcount {} vs mirror {want}",
                            p.refcount(page)
                        ));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn invariant_audit_passes_under_churn_and_catches_corruption() {
        let mut p = BlockPool::new(5, 4);
        let a = p.allocate().unwrap();
        let b = p.allocate().unwrap();
        p.retain(a).unwrap();
        p.check_invariants().unwrap();
        p.release(&[a, b]).unwrap();
        p.check_invariants().unwrap();
        // Corrupt the pool directly: a live page smuggled onto the free
        // list must be caught.
        p.free.push(a);
        assert!(p.check_invariants().is_err());
    }

    #[test]
    fn kv_memory_formula() {
        // sq-2m at symmetric 4-bit KV. Per page per layer per K/V side:
        // payload ceil(16*4*32 * 4 / 8) = 1024 bytes, metadata 16 tokens x
        // 4 heads x 2 bytes = 128, so 1152 per packed page.
        let bytes = kv_memory_bytes(32, 16, 4, 4, 32, 4.0, true);
        assert_eq!(bytes, 32 * 2 * 4 * 1152);
        // Asymmetric doubles the metadata (scale + zero per group).
        assert_eq!(kv_memory_bytes(32, 16, 4, 4, 32, 4.0, false), 32 * 2 * 4 * 1280);
        // >= 16 bits: no quantization metadata, pure payload.
        // fp32 reference for the dense comparison.
        assert_eq!(kv_memory_bytes(1, 1, 1, 1, 1, 32.0, true), 2 * 4);
        assert_eq!(kv_memory_bytes(8, 16, 4, 4, 32, 16.0, true), 8 * 16 * 2 * 4 * 4 * 32 * 2);
    }

    #[test]
    fn kv_memory_rounds_per_packed_page() {
        // Regression (satellite): one `.ceil()` over the whole pool let
        // partial tail bytes from different pages share a byte, which is
        // physically impossible — each page is its own packed buffer. With
        // 3 elements per page at 4 bits, each page's payload is 2 bytes
        // (ceil(1.5)), not 1.5 pooled: 2 blocks x 2 sides x (2 payload +
        // 1 token x 1 head x 2 meta) = 16, where the old formula said
        // ceil(12 x 4 / 8) = 6.
        assert_eq!(kv_memory_bytes(2, 1, 1, 1, 3, 4.0, true), 16);
    }
}
