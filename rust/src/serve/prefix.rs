//! Content-addressed prefix cache over the paged KV block pool.
//!
//! [`PrefixIndex`] maps *full, immutable* KV pages to the exact token
//! prefix that produced them: page `j` of a prompt is stored with its
//! whole cumulative prefix `prompt[..(j+1)*bs]`, and a lookup walks the
//! prompt page by page, stopping at the first miss — the longest cached
//! prefix comes out as a run of physical page ids a new request can map
//! read-only into its block table (see
//! [`SlotMap::admit_paged`](crate::serve::SlotMap::admit_paged)).
//!
//! A hash *chain* (`chain_step` folded page by page, [`CHAIN_ROOT`] at the
//! start) is used purely as the bucket key; matching always compares the
//! stored exact prefix, so two different prefixes can never alias a page
//! even under a constructed 64-bit collision — the comparison cost is
//! O(prefix) per matched page, which is fine at KV-cache page counts.
//! Callers thread the running chain value through registration
//! ([`SlotMap`](crate::serve::SlotMap) keeps one per slot), so donating a
//! page costs O(block_size), not a re-walk of the whole prompt.
//!
//! Ownership contract: every indexed page carries **one pool reference
//! owned by the index** (retained by the caller when
//! [`PrefixIndex::register`] accepts a page, dropped when
//! [`PrefixIndex::evict_lru`] hands it back). A page whose only remaining
//! reference is the index's (`refcount == 1`) is *unreferenced but
//! resident* — it stays cached until pool pressure evicts it in LRU order.
//! Pages also referenced by live slots (`refcount > 1`) are never
//! evictable, which is what makes "eviction can never reclaim a page
//! another slot still references" a structural guarantee rather than a
//! scheduler promise.
//!
//! Only pages wholly covered by a request's *prompt* are ever registered:
//! cache behavior is then a pure function of submitted prompts, which is
//! what lets the seeded oracle in [`crate::testing::sim`] replay
//! shared-prefix traces exactly (generated tokens would make hits depend
//! on sampler output), and it matches the workload this exists for —
//! N concurrent requests repeating one system prompt / few-shot preamble.

use std::collections::HashMap;

/// FNV-1a offset basis / prime — the chain seed and fold for bucket keys.
const CHAIN_BASIS: u64 = 0xcbf2_9ce4_8422_2325;
const CHAIN_PRIME: u64 = 0x0000_0100_0000_01b3;

/// The chain value before any page (the parent of a prompt's first page).
pub const CHAIN_ROOT: u64 = CHAIN_BASIS;

/// Fold one page of tokens into a parent chain value.
pub fn chain_step(parent: u64, tokens: &[i32]) -> u64 {
    let mut h = parent ^ CHAIN_BASIS;
    for &t in tokens {
        h = (h ^ t as u32 as u64).wrapping_mul(CHAIN_PRIME);
    }
    h
}

/// Chain value after `pages` full pages of `prompt`.
pub fn chain_of(prompt: &[i32], pages: usize, block_size: usize) -> u64 {
    (0..pages)
        .fold(CHAIN_ROOT, |h, j| chain_step(h, &prompt[j * block_size..(j + 1) * block_size]))
}

/// One cached full page: the exact token prefix through it, its physical
/// page id, and its LRU stamp.
#[derive(Clone, Debug)]
struct Entry {
    /// The whole prompt prefix this page completes — the identity match
    /// key (the bucket hash is only a shortcut to it).
    prefix: Vec<i32>,
    /// Physical page in the [`BlockPool`](crate::serve::BlockPool); the
    /// index owns one reference to it.
    page: u32,
    /// Logical LRU clock of the last lookup hit or registration.
    last_use: u64,
}

/// The content-addressed index of full, immutable prompt pages.
#[derive(Clone, Debug, Default)]
pub struct PrefixIndex {
    /// Chain-key buckets; exact cumulative-prefix comparison inside.
    map: HashMap<u64, Vec<Entry>>,
    /// Logical clock: bumped once per touched entry, so LRU order is a
    /// deterministic function of the operation sequence (no wall clock —
    /// the sim oracle replays it exactly).
    clock: u64,
    /// Cached pages (== total entries across buckets).
    pages: usize,
}

impl PrefixIndex {
    pub fn new() -> Self {
        Self::default()
    }

    /// Pages currently cached (each holds one pool reference).
    pub fn cached_pages(&self) -> usize {
        self.pages
    }

    /// The longest run of cached pages matching `prompt`'s leading full
    /// pages, capped at `max_pages` — physical ids in page order. Every
    /// hit bumps the entry's LRU stamp (in page order), including on
    /// admission attempts that later fail their watermark; the oracle
    /// mirrors exactly this.
    pub fn lookup(&mut self, prompt: &[i32], block_size: usize, max_pages: usize) -> Vec<u32> {
        let mut out = Vec::new();
        let mut parent = CHAIN_ROOT;
        for j in 0..max_pages.min(prompt.len() / block_size) {
            let end = (j + 1) * block_size;
            let key = chain_step(parent, &prompt[j * block_size..end]);
            let Some(bucket) = self.map.get_mut(&key) else { break };
            let Some(e) = bucket.iter_mut().find(|e| e.prefix[..] == prompt[..end]) else {
                break;
            };
            self.clock += 1;
            e.last_use = self.clock;
            out.push(e.page);
            parent = key;
        }
        out
    }

    /// Offer the page completing `prefix` (a whole number of pages; its
    /// last `block_size` tokens are the page content), stored in physical
    /// `page`, to the index. `parent` is the chain value over
    /// `prefix[..prefix.len() - block_size]` — callers thread it so
    /// registration never re-walks the prompt. Returns `true` when the
    /// entry was inserted — the caller must then add the index's pool
    /// reference (`pool.retain(page)`); `false` when an identical prefix
    /// is already cached (a concurrent request prefilled the same content
    /// into its own page — the duplicate stays slot-exclusive and is
    /// freed with the slot).
    pub fn register(&mut self, parent: u64, prefix: &[i32], block_size: usize, page: u32) -> bool {
        debug_assert!(
            prefix.len() >= block_size && prefix.len() % block_size == 0,
            "prefix must end on a page boundary"
        );
        debug_assert_eq!(
            parent,
            chain_of(prefix, prefix.len() / block_size - 1, block_size),
            "parent chain out of sync with the prefix"
        );
        let tokens = &prefix[prefix.len() - block_size..];
        let key = chain_step(parent, tokens);
        let bucket = self.map.entry(key).or_default();
        if bucket.iter().any(|e| e.prefix[..] == *prefix) {
            return false;
        }
        self.clock += 1;
        bucket.push(Entry { prefix: prefix.to_vec(), page, last_use: self.clock });
        self.pages += 1;
        true
    }

    /// Physical ids of every cached page (order unspecified) — for
    /// accounting cross-checks and stats, not for lookup.
    pub fn pages(&self) -> Vec<u32> {
        self.map.values().flatten().map(|e| e.page).collect()
    }

    /// Cached pages that `evictable` accepts (callers pass
    /// `pool.refcount(page) == 1`, i.e. only the index still holds them).
    pub fn evictable_pages(&self, evictable: impl Fn(u32) -> bool) -> usize {
        self.map.values().flatten().filter(|e| evictable(e.page)).count()
    }

    /// Drop the least-recently-used entry among those whose page
    /// `evictable` accepts, returning the physical page so the caller can
    /// release the index's pool reference. `None` when nothing is
    /// evictable. Chain interiors may be evicted before their children
    /// (the child entry then sits unreachable until its prefix is
    /// re-donated or its own turn comes) — both sides of the oracle
    /// equivalence model this identically.
    pub fn evict_lru(&mut self, evictable: impl Fn(u32) -> bool) -> Option<u32> {
        let (&key, oldest) = self
            .map
            .iter()
            .filter_map(|(k, bucket)| {
                bucket
                    .iter()
                    .filter(|e| evictable(e.page))
                    .min_by_key(|e| e.last_use)
                    .map(|e| (k, e.last_use))
            })
            .min_by_key(|&(_, last_use)| last_use)?;
        let bucket = self.map.get_mut(&key).expect("bucket exists");
        let i = bucket
            .iter()
            .position(|e| e.last_use == oldest && evictable(e.page))
            .expect("entry exists");
        let page = bucket.swap_remove(i).page;
        if bucket.is_empty() {
            self.map.remove(&key);
        }
        self.pages -= 1;
        Some(page)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toks(xs: &[i32]) -> Vec<i32> {
        xs.to_vec()
    }

    /// Register page `j` of `prompt` the way SlotMap does, computing the
    /// parent chain from scratch (tests only — the real caller threads it).
    fn register_page(
        idx: &mut PrefixIndex,
        prompt: &[i32],
        j: usize,
        bs: usize,
        page: u32,
    ) -> bool {
        idx.register(chain_of(prompt, j, bs), &prompt[..(j + 1) * bs], bs, page)
    }

    #[test]
    fn lookup_walks_the_chain_and_stops_at_first_miss() {
        let mut idx = PrefixIndex::new();
        let prompt = toks(&[1, 2, 3, 4, 5, 6, 7, 8]);
        assert!(register_page(&mut idx, &prompt, 0, 2, 10));
        assert!(register_page(&mut idx, &prompt, 1, 2, 11));
        assert!(register_page(&mut idx, &prompt, 3, 2, 13)); // page 2 deliberately absent
        assert_eq!(idx.cached_pages(), 3);
        // Pages 0 and 1 match; page 2 misses, so page 3 is unreachable
        // even though it is cached.
        assert_eq!(idx.lookup(&prompt, 2, 4), vec![10, 11]);
        // max_pages caps the walk.
        assert_eq!(idx.lookup(&prompt, 2, 1), vec![10]);
        // A different prompt with the same first page shares only page 0.
        let other = toks(&[1, 2, 9, 9]);
        assert_eq!(idx.lookup(&other, 2, 2), vec![10]);
        // Partial trailing page never matches (full pages only).
        assert_eq!(idx.lookup(&toks(&[1, 2, 3]), 2, 2), vec![10]);
    }

    #[test]
    fn register_dedups_identical_prefixes() {
        let mut idx = PrefixIndex::new();
        let prompt = toks(&[5, 6, 7, 8]);
        assert!(register_page(&mut idx, &prompt, 0, 2, 3));
        // A concurrent request prefilled the same content into page 9: the
        // original entry wins, the duplicate stays slot-owned.
        assert!(!register_page(&mut idx, &prompt, 0, 2, 9));
        assert_eq!(idx.cached_pages(), 1);
        assert_eq!(idx.lookup(&prompt, 2, 2), vec![3]);
        // Same page tokens behind a different prefix are a different entry.
        let shifted = toks(&[0, 0, 5, 6]);
        assert!(register_page(&mut idx, &shifted, 1, 2, 4));
        assert_eq!(idx.cached_pages(), 2);
    }

    #[test]
    fn evict_lru_prefers_least_recent_and_respects_refcounts() {
        let mut idx = PrefixIndex::new();
        let a = toks(&[1, 1]);
        let b = toks(&[2, 2]);
        let c = toks(&[3, 3]);
        assert!(register_page(&mut idx, &a, 0, 2, 0));
        assert!(register_page(&mut idx, &b, 0, 2, 1));
        assert!(register_page(&mut idx, &c, 0, 2, 2));
        // Touch `a` so `b` becomes the LRU entry.
        assert_eq!(idx.lookup(&a, 2, 1), vec![0]);
        // Page 1 is pinned (refcount > 1 in the caller's pool): the filter
        // must skip it and take the next-oldest, page 2.
        assert_eq!(idx.evictable_pages(|p| p != 1), 2);
        assert_eq!(idx.evict_lru(|p| p != 1), Some(2));
        assert_eq!(idx.evict_lru(|p| p != 1), Some(0));
        assert_eq!(idx.evict_lru(|p| p != 1), None, "only the pinned page remains");
        assert_eq!(idx.cached_pages(), 1);
        assert_eq!(idx.evict_lru(|_| true), Some(1));
        assert_eq!(idx.cached_pages(), 0);
    }

    #[test]
    fn matching_is_by_exact_prefix_not_by_hash() {
        // The chain hash is only a bucket key: entries store and compare
        // their exact cumulative prefix, so even a colliding key can never
        // hand out a page computed under a different context.
        let mut idx = PrefixIndex::new();
        let a = toks(&[7, 7]);
        assert!(register_page(&mut idx, &a, 0, 2, 0));
        let b = toks(&[7, 8]);
        assert!(idx.lookup(&b, 2, 1).is_empty(), "different content must miss");
        // The same second-page tokens behind different first pages are
        // distinct entries, each matched only behind its own exact prefix.
        let long_a = toks(&[7, 7, 9, 9]);
        let long_b = toks(&[7, 8, 9, 9]);
        assert!(register_page(&mut idx, &long_a, 1, 2, 1));
        assert!(register_page(&mut idx, &long_b, 0, 2, 2));
        assert!(register_page(&mut idx, &long_b, 1, 2, 3));
        assert_eq!(idx.lookup(&long_a, 2, 2), vec![0, 1]);
        assert_eq!(idx.lookup(&long_b, 2, 2), vec![2, 3]);
    }

    #[test]
    fn evicted_interior_relinks_after_redonation() {
        // Chain [A, B]: evict A while B survives; B is unreachable until A
        // is re-donated with the same content, after which the old B entry
        // is reachable again (content addressing, not identity chaining).
        let mut idx = PrefixIndex::new();
        let p = toks(&[1, 2, 3, 4]);
        assert!(register_page(&mut idx, &p, 0, 2, 0));
        assert!(register_page(&mut idx, &p, 1, 2, 1));
        assert_eq!(idx.evict_lru(|pg| pg == 0), Some(0));
        assert!(idx.lookup(&p, 2, 2).is_empty(), "orphaned child unreachable");
        assert!(register_page(&mut idx, &p, 0, 2, 5));
        assert_eq!(idx.lookup(&p, 2, 2), vec![5, 1], "old child reachable again");
    }

    #[test]
    fn clock_orders_eviction_deterministically() {
        let mut idx = PrefixIndex::new();
        for (i, t) in [[1, 1], [2, 2], [3, 3], [4, 4]].iter().enumerate() {
            assert!(register_page(&mut idx, &toks(t), 0, 2, i as u32));
        }
        // Reverse-touch: eviction order becomes registration order of the
        // untouched, then touch order.
        assert_eq!(idx.lookup(&toks(&[2, 2]), 2, 1), vec![1]);
        assert_eq!(idx.lookup(&toks(&[1, 1]), 2, 1), vec![0]);
        let order: Vec<u32> = std::iter::from_fn(|| idx.evict_lru(|_| true)).collect();
        assert_eq!(order, vec![2, 3, 1, 0]);
    }
}
