//! Flight-recorder event trace for the serving stack.
//!
//! [`ServingMetrics`] reports end-of-run aggregates; when `inter-tok p99`
//! or TTFT regresses they cannot say *which* request stalled, *why*
//! (eviction, prefix miss, composer mix, pool pressure) or *when*. This
//! module is the attribution layer: every scheduler decision and every
//! resource-plane transition emits one typed [`TraceEvent`] into a bounded
//! ring buffer ([`TraceRing`], `--trace-buffer N`, drop-oldest with a
//! `dropped_events` counter), step-indexed and timestamped.
//!
//! The event vocabulary, in lifecycle order:
//!
//! * [`TraceEvent::Enqueued`] — the request entered the admission queue.
//! * [`TraceEvent::Admitted`] — it won a slot: which one, how many fresh
//!   pages the watermark charged, how many prompt tokens were mapped from
//!   the prefix cache; followed by [`TraceEvent::PrefixHit`] when that
//!   reuse was non-zero.
//! * [`TraceEvent::PrefillChunk`] — one prompt chunk entered an engine
//!   call (`pos0`, `take`); the first one marks "first scheduled", the
//!   boundary `ServingMetrics` splits TTFT at.
//! * [`TraceEvent::TokenDecoded`] — a token was sampled; for a *running*
//!   slot it carries the engine-call stall count the decode-stall
//!   histogram records.
//! * [`TraceEvent::DraftProposed`] / [`TraceEvent::DraftAccepted`] /
//!   [`TraceEvent::DraftRejected`] — the speculative plane: a draft
//!   window entered the step's verify call, and how much of it survived
//!   greedy acceptance (rejected drafts are rolled back through
//!   `SlotMap::rewind_by` and never appear as decoded tokens).
//! * [`TraceEvent::StepComposed`] — the step composer's plan for one
//!   iteration (decode lanes vs budgeted prefill take).
//! * [`TraceEvent::PrefixDonated`] / [`TraceEvent::PageAllocated`] /
//!   [`TraceEvent::PageRetained`] / [`TraceEvent::PageReleased`] — the
//!   resource plane: COW prefix donations and refcounted page traffic.
//! * [`TraceEvent::Evicted`] — the slot was torn down mid-flight
//!   (pool-exhaustion requeue, cancel, or fault-requeue).
//! * [`TraceEvent::Completed`] — retirement, with the finish reason.
//! * [`TraceEvent::FaultInjected`] / [`TraceEvent::RetryScheduled`] /
//!   [`TraceEvent::SlotRecovered`] / [`TraceEvent::RequestFailed`] /
//!   [`TraceEvent::DeadlineExpired`] — the error kernel: engine faults
//!   (per-slot or step-wide), the deterministic step-counted backoff the
//!   recovery policy schedules, successful recoveries, quarantines after
//!   retry exhaustion, and deadline sheds. All oracle-scope: the sim
//!   replays the fault schedule and must predict every one of these.
//! * [`TraceEvent::Counters`] — per-engine-call gauges (queue depth,
//!   in-flight, free pages, fed-token mix) for counter tracks.
//!
//! The sink ([`TraceSink`]) is an **enum, not a trait object**: the
//! disabled path is a two-variant branch on the hot loop (no vtable, no
//! allocation — the bench's `trace` section records on/off step latency to
//! hold that claim). On top of the raw stream:
//!
//! * [`fold_timelines`] reconstructs per-request lifecycle spans (queued →
//!   prefill spread → decode, with stall gaps), tolerant of ring
//!   wraparound truncating old requests' prefixes.
//! * [`verify_against_metrics`] cross-checks a complete (no-drop) stream
//!   against [`ServingMetrics`] — token counts, stall histogram, eviction
//!   and reuse counters, and the `ttft == queue + spread` split, exactly —
//!   so the trace is provably not write-only telemetry.
//! * [`chrome_trace`] exports Chrome trace-event / Perfetto JSON (one
//!   track per slot, a queue track, counter tracks) through
//!   [`crate::util::json`]; `spinquant serve --trace out.json` writes it.
//!
//! The scheduler's twin obligation lives in [`crate::testing::sim`]: the
//! bookkeeping oracle emits the same *decision* events (everything but the
//! page/counter plane), and the pinned-seed equivalence suites compare the
//! two streams event for event, modulo timestamps.

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::collections::VecDeque;
use std::rc::Rc;
use std::time::Instant;

use crate::serve::metrics::ServingMetrics;
use crate::util::json::{self, Json};

/// Why a slot was torn down before completion.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EvictReason {
    /// Paged pool ran dry; the request was requeued (front) to restart.
    PoolExhausted,
    /// `Scheduler::cancel` hit a mid-flight request.
    Cancelled,
    /// A step-wide engine fault exhausted its retry budget; the slot was
    /// requeued (front) for a warm restart through its donated pages.
    Fault,
}

/// Why a request retired.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinishReason {
    /// Generated its full `max_new_tokens` budget.
    BudgetExhausted,
    /// Ran out of KV-cache positions (`max_seq`) first.
    CacheFull,
    /// Individually faulted `retry_budget` times and was isolated so it
    /// can no longer wedge the batch (poison-request quarantine).
    Quarantined,
    /// Missed its request deadline and was shed (at admission or
    /// mid-flight).
    DeadlineExpired,
}

/// One typed scheduler/resource event. `Copy` and field-only (no heap) so
/// emission is a ring-buffer write, and `PartialEq` so the sim oracle's
/// stream can be compared against the real scheduler's exactly.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TraceEvent {
    Enqueued { id: u64 },
    Admitted { id: u64, slot: usize, pages_charged: usize, tokens_reused: usize },
    PrefixHit { id: u64, slot: usize, pages: usize },
    PrefillChunk { id: u64, slot: usize, pos0: usize, take: usize },
    /// `stall_steps` is `Some` only for a token produced by a slot that was
    /// *running* (prompt fully fed) at the start of the iteration — exactly
    /// the tokens the decode-stall histogram samples.
    TokenDecoded { id: u64, slot: usize, stall_steps: Option<usize> },
    /// A window of `tokens` draft tokens entered the step's verify call
    /// for this slot. Emitted at plan time: a verify fault leaves it
    /// standing with no matching accept/reject record — the step backs
    /// off and proposes afresh on retry.
    DraftProposed { id: u64, slot: usize, tokens: usize },
    /// `accepted` of the proposed drafts agreed with the target engine
    /// (the longest agreeing prefix). The bonus correction token is
    /// counted by its own [`TraceEvent::TokenDecoded`], never here.
    DraftAccepted { id: u64, slot: usize, accepted: usize },
    /// `rejected` drafts diverged from the target and were rolled back —
    /// positions and freshly grown pages rewound as if never written.
    DraftRejected { id: u64, slot: usize, rejected: usize },
    Evicted { id: u64, slot: usize, reason: EvictReason },
    Completed { id: u64, slot: usize, reason: FinishReason },
    StepComposed { decode_lanes: usize, prefill_take: usize, budget: usize },
    /// An engine call faulted; `slot` is `Some` for a per-slot fault
    /// (one request blamed) and `None` for a step-wide one (every
    /// participant of the call affected).
    FaultInjected { slot: Option<usize> },
    /// The error kernel scheduled a deterministic retry: the affected
    /// slot (or the whole step when `None`) sits out `backoff_steps`
    /// scheduler steps before attempt `attempt + 1`.
    RetryScheduled { slot: Option<usize>, backoff_steps: usize, attempt: usize },
    /// A slot that had a retry pending advanced through a successful
    /// engine call again.
    SlotRecovered { id: u64, slot: usize },
    /// A request exhausted its retry budget and was quarantined
    /// (`slot` is `None` when it failed from the admission queue).
    RequestFailed { id: u64, slot: Option<usize>, faults: usize },
    /// A request missed its deadline and was shed — from the queue
    /// (`queued`) or mid-flight.
    DeadlineExpired { id: u64, queued: bool },
    PrefixDonated { slot: usize, pages: usize },
    PageAllocated { block: u32, refcount: usize },
    PageRetained { block: u32, refcount: usize },
    PageReleased { block: u32, refcount: usize },
    /// Per-engine-call gauges (emitted after each decode/prefill call).
    Counters {
        queue_depth: usize,
        in_flight: usize,
        free_pages: usize,
        prompt_fed: usize,
        decode_fed: usize,
    },
}

impl TraceEvent {
    /// Whether the sim oracle models this event. Scheduler *decisions* are
    /// oracle-checked; the physical page plane and timing gauges are
    /// real-scheduler-only (the oracle has no pool layout and no clock).
    pub fn in_oracle_scope(&self) -> bool {
        !matches!(
            self,
            TraceEvent::PageAllocated { .. }
                | TraceEvent::PageRetained { .. }
                | TraceEvent::PageReleased { .. }
                | TraceEvent::Counters { .. }
        )
    }
}

/// One ring-buffer entry: the event plus its envelope — the scheduler
/// iteration it happened in and microseconds since the sink was created.
/// Timestamps live here, not in [`TraceEvent`], so oracle equivalence can
/// compare events directly ("exact sequence modulo timestamps").
#[derive(Clone, Copy, Debug)]
pub struct TraceRecord {
    pub step: u64,
    pub t_us: f64,
    pub event: TraceEvent,
}

/// Bounded drop-oldest event buffer.
#[derive(Debug)]
pub struct TraceRing {
    cap: usize,
    buf: VecDeque<TraceRecord>,
    dropped: u64,
    step: u64,
    epoch: Instant,
}

impl TraceRing {
    fn push(&mut self, t_us: f64, event: TraceEvent) {
        if self.buf.len() == self.cap {
            self.buf.pop_front();
            self.dropped += 1;
        }
        self.buf.push_back(TraceRecord { step: self.step, t_us, event });
    }
}

/// The sink the serving stack emits into. An enum — deliberately not a
/// `dyn` trait object — so the tracing-off hot path is one branch on a
/// discriminant with nothing allocated behind it. Cloning shares the ring
/// (`Rc`), which is how the scheduler and its `SlotMap` write into one
/// buffer.
#[derive(Clone, Debug, Default)]
pub enum TraceSink {
    /// Tracing disabled: every emit is a no-op branch.
    #[default]
    Off,
    Ring(Rc<RefCell<TraceRing>>),
}

impl TraceSink {
    /// A recording sink over a fresh ring of `capacity` records (minimum
    /// 1); `t_us` timestamps are measured from this call.
    pub fn ring(capacity: usize) -> Self {
        let cap = capacity.max(1);
        TraceSink::Ring(Rc::new(RefCell::new(TraceRing {
            cap,
            buf: VecDeque::with_capacity(cap.min(4096)),
            dropped: 0,
            step: 0,
            epoch: Instant::now(),
        })))
    }

    #[inline]
    pub fn is_on(&self) -> bool {
        matches!(self, TraceSink::Ring(_))
    }

    /// Record `event` stamped with the current time (no-op when off).
    #[inline]
    pub fn emit(&self, event: TraceEvent) {
        if let TraceSink::Ring(r) = self {
            let mut r = r.borrow_mut();
            let t_us = r.epoch.elapsed().as_secs_f64() * 1e6;
            r.push(t_us, event);
        }
    }

    /// Record `event` stamped with a clock reading the caller already took
    /// — emission points share one `Instant::now()` with the metrics stamp
    /// they sit next to, so the reconstructed timelines agree with
    /// [`ServingMetrics`] down to float rounding.
    #[inline]
    pub fn emit_at(&self, now: Instant, event: TraceEvent) {
        if let TraceSink::Ring(r) = self {
            let mut r = r.borrow_mut();
            let t_us = now.saturating_duration_since(r.epoch).as_secs_f64() * 1e6;
            r.push(t_us, event);
        }
    }

    /// Advance the step index stamped into subsequent records.
    pub fn begin_step(&self) {
        if let TraceSink::Ring(r) = self {
            r.borrow_mut().step += 1;
        }
    }

    /// Snapshot of the buffered records, oldest first.
    pub fn records(&self) -> Vec<TraceRecord> {
        match self {
            TraceSink::Off => Vec::new(),
            TraceSink::Ring(r) => r.borrow().buf.iter().copied().collect(),
        }
    }

    /// Events evicted from the ring so far (0 when off or within budget).
    pub fn dropped_events(&self) -> u64 {
        match self {
            TraceSink::Off => 0,
            TraceSink::Ring(r) => r.borrow().dropped,
        }
    }
}

/// One request's reconstructed lifecycle. Times are ring-relative
/// microseconds; fields stay `None` when the corresponding events were
/// dropped by wraparound (partial timelines are still well-formed).
#[derive(Clone, Debug, Default)]
pub struct Timeline {
    pub id: u64,
    pub enqueued_us: Option<f64>,
    /// First prefill chunk *ever* (survives eviction restarts, like the
    /// scheduler's queue-wait stamp).
    pub first_sched_us: Option<f64>,
    /// First token after the *last* admission (eviction restarts reset it,
    /// matching the TTFT the metrics record at retirement).
    pub first_token_us: Option<f64>,
    pub completed_us: Option<f64>,
    pub finish: Option<FinishReason>,
    pub admissions: usize,
    /// Pool-exhaustion evictions only; cancels set `cancelled`.
    pub evictions: usize,
    /// Fault-requeue evictions (retry exhaustion on a step-wide fault).
    pub fault_evictions: usize,
    pub cancelled: bool,
    /// Tokens generated since the last admission (what the completion
    /// reports; tokens lost to eviction restarts are not counted here).
    pub tokens: usize,
    /// Stall-step samples (running-lane tokens), across the whole
    /// lifetime — the per-request slice of the decode-stall histogram.
    pub stalls: Vec<usize>,
    /// Prompt tokens fed through prefill chunks, cumulative across
    /// restarts.
    pub prompt_tokens_fed: usize,
    /// Prompt tokens mapped from the prefix cache, summed over admissions.
    pub tokens_reused: usize,
}

impl Timeline {
    /// The TTFT split exactly as `ServingMetrics::record_first_token`
    /// computes it: `(queue_us, spread_us)` relative to enqueue, with
    /// `queue + spread == ttft`. `None` unless the timeline completed with
    /// a first token and its enqueue survived in the ring.
    pub fn ttft_split(&self) -> Option<(f64, f64)> {
        self.completed_us?;
        let enq = self.enqueued_us?;
        let ttft = self.first_token_us? - enq;
        let first_sched = self.first_sched_us.map_or(ttft, |t| t - enq);
        let queue = first_sched.min(ttft);
        Some((queue, ttft - queue))
    }
}

fn timeline(out: &mut BTreeMap<u64, Timeline>, id: u64) -> &mut Timeline {
    let t = out.entry(id).or_default();
    t.id = id;
    t
}

/// Fold a record stream into per-request timelines. Tolerates partial
/// streams (ring wraparound): a request whose early events were dropped
/// simply has those fields `None`.
pub fn fold_timelines(records: &[TraceRecord]) -> BTreeMap<u64, Timeline> {
    let mut out = BTreeMap::new();
    for r in records {
        match r.event {
            TraceEvent::Enqueued { id } => {
                timeline(&mut out, id).enqueued_us = Some(r.t_us);
            }
            TraceEvent::Admitted { id, tokens_reused, .. } => {
                let t = timeline(&mut out, id);
                t.admissions += 1;
                t.tokens_reused += tokens_reused;
                // A restart re-generates from scratch: TTFT is the first
                // token after the LAST admission.
                t.first_token_us = None;
                t.tokens = 0;
            }
            TraceEvent::PrefillChunk { id, take, .. } => {
                let t = timeline(&mut out, id);
                if t.first_sched_us.is_none() {
                    t.first_sched_us = Some(r.t_us);
                }
                t.prompt_tokens_fed += take;
            }
            TraceEvent::TokenDecoded { id, stall_steps, .. } => {
                let t = timeline(&mut out, id);
                if t.first_token_us.is_none() {
                    t.first_token_us = Some(r.t_us);
                }
                t.tokens += 1;
                if let Some(s) = stall_steps {
                    t.stalls.push(s);
                }
            }
            TraceEvent::Evicted { id, reason, .. } => {
                let t = timeline(&mut out, id);
                match reason {
                    EvictReason::PoolExhausted => t.evictions += 1,
                    EvictReason::Cancelled => t.cancelled = true,
                    EvictReason::Fault => t.fault_evictions += 1,
                }
            }
            TraceEvent::Completed { id, reason, .. } => {
                let t = timeline(&mut out, id);
                t.completed_us = Some(r.t_us);
                t.finish = Some(reason);
            }
            // Failure retirements terminate the lifecycle without a
            // `Completed` (they never count as a served request), so
            // only the finish reason is recorded — `completed_us` stays
            // `None` and `ttft_split` correctly yields nothing.
            TraceEvent::RequestFailed { id, .. } => {
                timeline(&mut out, id).finish = Some(FinishReason::Quarantined);
            }
            TraceEvent::DeadlineExpired { id, .. } => {
                timeline(&mut out, id).finish = Some(FinishReason::DeadlineExpired);
            }
            _ => {}
        }
    }
    out
}

/// Timestamp slack for cross-checking trace times against metrics times:
/// both sides stamp from the *same* `Instant::now()` at every shared
/// emission point, so the residual is pure float rounding (~1e-9 us); one
/// nanosecond of slack is six orders of magnitude of margin.
const T_EPS_US: f64 = 1e-3;

fn sorted(mut v: Vec<f64>) -> Vec<f64> {
    v.sort_by(|a, b| a.partial_cmp(b).expect("latencies are finite"));
    v
}

/// Cross-check a **complete** (no events dropped) record stream against
/// the metrics the same run recorded. This is the "trace is not write-only
/// telemetry" guarantee: every aggregate the metrics report must be
/// re-derivable from the event stream —
///
/// * token / completion / eviction / prefix-reuse counts, exactly;
/// * the decode-stall histogram, as an exact multiset;
/// * per-request TTFT and its queue/spread split, to [`T_EPS_US`];
/// * per-timeline monotonicity (enqueue <= first-sched <= first-token <=
///   completion).
pub fn verify_against_metrics(
    records: &[TraceRecord],
    m: &ServingMetrics,
) -> Result<(), String> {
    let mut tokens = 0usize;
    let mut stalls = Vec::new();
    let mut evictions = 0usize;
    let mut fault_evictions = 0usize;
    let mut reused = 0usize;
    let mut hits = 0usize;
    let mut completions = 0usize;
    let mut step_faults = 0usize;
    let mut slot_faults = 0usize;
    let mut retries = 0usize;
    let mut recovered = 0usize;
    let mut quarantined = 0usize;
    let mut shed_queued = 0usize;
    let mut shed_inflight = 0usize;
    let mut drafts_proposed = 0usize;
    let mut drafts_accepted = 0usize;
    for r in records {
        match r.event {
            TraceEvent::TokenDecoded { stall_steps, .. } => {
                tokens += 1;
                if let Some(s) = stall_steps {
                    stalls.push(s as f64);
                }
            }
            TraceEvent::Evicted { reason: EvictReason::PoolExhausted, .. } => evictions += 1,
            TraceEvent::Evicted { reason: EvictReason::Fault, .. } => fault_evictions += 1,
            TraceEvent::Admitted { tokens_reused, .. } => reused += tokens_reused,
            TraceEvent::PrefixHit { .. } => hits += 1,
            TraceEvent::Completed { .. } => completions += 1,
            TraceEvent::FaultInjected { slot: None } => step_faults += 1,
            TraceEvent::FaultInjected { slot: Some(_) } => slot_faults += 1,
            TraceEvent::RetryScheduled { .. } => retries += 1,
            TraceEvent::SlotRecovered { .. } => recovered += 1,
            TraceEvent::RequestFailed { .. } => quarantined += 1,
            TraceEvent::DeadlineExpired { queued: true, .. } => shed_queued += 1,
            TraceEvent::DeadlineExpired { queued: false, .. } => shed_inflight += 1,
            TraceEvent::DraftProposed { tokens, .. } => drafts_proposed += tokens,
            TraceEvent::DraftAccepted { accepted, .. } => drafts_accepted += accepted,
            _ => {}
        }
    }
    if tokens != m.tokens_generated {
        return Err(format!("trace has {tokens} TokenDecoded, metrics {}", m.tokens_generated));
    }
    if completions != m.requests_completed {
        return Err(format!("trace has {completions} Completed, metrics {}", m.requests_completed));
    }
    if evictions != m.requests_evicted {
        return Err(format!("trace has {evictions} evictions, metrics {}", m.requests_evicted));
    }
    if reused != m.tokens_reused {
        return Err(format!("trace reuses {reused} tokens, metrics {}", m.tokens_reused));
    }
    if hits != m.prefix_hits {
        return Err(format!("trace has {hits} prefix hits, metrics {}", m.prefix_hits));
    }
    // The error-kernel plane must re-derive exactly as well: fault events
    // are decisions, not telemetry.
    for (name, got, want) in [
        ("step faults", step_faults, m.step_faults),
        ("slot faults", slot_faults, m.slot_faults),
        ("retries scheduled", retries, m.retries_scheduled),
        ("slots recovered", recovered, m.slots_recovered),
        ("quarantines", quarantined, m.requests_quarantined),
        ("fault evictions", fault_evictions, m.requests_fault_evicted),
        ("queued deadline sheds", shed_queued, m.deadline_shed_queued),
        ("in-flight deadline sheds", shed_inflight, m.deadline_shed_inflight),
        // The speculative plane too: both sides count proposals at plan
        // time and acceptances after the verify call, so they agree even
        // when a verify fault strands a proposal without a verdict.
        ("draft tokens proposed", drafts_proposed, m.draft_tokens_proposed),
        ("draft tokens accepted", drafts_accepted, m.draft_tokens_accepted),
    ] {
        if got != want {
            return Err(format!("trace has {got} {name}, metrics {want}"));
        }
    }
    let stalls = sorted(stalls);
    let metric_stalls = sorted(m.decode_stall_steps.values().to_vec());
    if stalls != metric_stalls {
        return Err(format!(
            "stall histogram diverged: trace {stalls:?} vs metrics {metric_stalls:?}"
        ));
    }

    let timelines = fold_timelines(records);
    let mut splits = Vec::new();
    let mut ttfts = Vec::new();
    for t in timelines.values() {
        let marks = [t.enqueued_us, t.first_sched_us, t.first_token_us, t.completed_us];
        let mut prev = f64::NEG_INFINITY;
        for v in marks.into_iter().flatten() {
            if v + T_EPS_US < prev {
                return Err(format!("request {}: timeline not monotone: {marks:?}", t.id));
            }
            prev = v;
        }
        if let Some((queue, spread)) = t.ttft_split() {
            splits.push((queue, spread));
            ttfts.push(queue + spread);
        }
    }
    let ttfts = sorted(ttfts);
    let metric_ttfts = sorted(m.ttft_us.values().to_vec());
    if ttfts.len() != metric_ttfts.len() {
        return Err(format!(
            "trace reconstructs {} TTFTs, metrics recorded {}",
            ttfts.len(),
            metric_ttfts.len()
        ));
    }
    for (a, b) in ttfts.iter().zip(&metric_ttfts) {
        if (a - b).abs() > T_EPS_US {
            return Err(format!("TTFT mismatch: trace {a} us vs metrics {b} us"));
        }
    }
    splits.sort_by(|a, b| a.partial_cmp(b).expect("latencies are finite"));
    let mut metric_splits: Vec<(f64, f64)> = m
        .queue_us
        .values()
        .iter()
        .copied()
        .zip(m.prefill_spread_us.values().iter().copied())
        .collect();
    metric_splits.sort_by(|a, b| a.partial_cmp(b).expect("latencies are finite"));
    if splits.len() != metric_splits.len() {
        return Err(format!(
            "trace reconstructs {} TTFT splits, metrics recorded {}",
            splits.len(),
            metric_splits.len()
        ));
    }
    for ((tq, ts), (mq, ms)) in splits.iter().zip(&metric_splits) {
        if (tq - mq).abs() > T_EPS_US || (ts - ms).abs() > T_EPS_US {
            return Err(format!(
                "TTFT split mismatch: trace ({tq}, {ts}) vs metrics ({mq}, {ms}) us"
            ));
        }
    }
    Ok(())
}

fn chrome_event(name: String, ph: &str, tid: usize, extra: Vec<(&str, Json)>) -> Json {
    let mut pairs = vec![
        ("name", json::s(&name)),
        ("ph", json::s(ph)),
        ("pid", json::num(1.0)),
        ("tid", json::num(tid as f64)),
    ];
    pairs.extend(extra);
    json::obj(pairs)
}

fn chrome_span(name: String, tid: usize, t0: f64, t1: f64) -> Json {
    chrome_event(
        name,
        "X",
        tid,
        vec![("ts", json::num(t0)), ("dur", json::num((t1 - t0).max(0.0)))],
    )
}

fn chrome_counter(name: &str, ts: f64, value: f64) -> Json {
    chrome_event(name.to_string(), "C", 0, vec![
        ("ts", json::num(ts)),
        ("args", json::obj(vec![("value", json::num(value))])),
    ])
}

/// Export a record stream as Chrome trace-event JSON (load in
/// `chrome://tracing` or Perfetto). Track layout: `tid 0` is the admission
/// queue (one span per queued interval) plus the counter tracks; `tid
/// s + 1` is slot `s`, carrying each occupant's prefill span, then its
/// decode span, with instant markers at evictions. Spans left open by
/// wraparound or still-live requests are closed at the last timestamp.
pub fn chrome_trace(records: &[TraceRecord], dropped_events: u64) -> Json {
    let mut events = Vec::new();
    let max_slot = records
        .iter()
        .filter_map(|r| match r.event {
            TraceEvent::Admitted { slot, .. }
            | TraceEvent::PrefillChunk { slot, .. }
            | TraceEvent::TokenDecoded { slot, .. }
            | TraceEvent::Evicted { slot, .. }
            | TraceEvent::Completed { slot, .. } => Some(slot),
            _ => None,
        })
        .max();
    events.push(chrome_event("process_name".into(), "M", 0, vec![(
        "args",
        json::obj(vec![("name", json::s("spinquant-serve"))]),
    )]));
    events.push(chrome_event("thread_name".into(), "M", 0, vec![(
        "args",
        json::obj(vec![("name", json::s("queue"))]),
    )]));
    for slot in 0..=max_slot.unwrap_or(0) {
        events.push(chrome_event("thread_name".into(), "M", slot + 1, vec![(
            "args",
            json::obj(vec![("name", json::s(&format!("slot {slot}")))]),
        )]));
    }

    let mut queue_open: BTreeMap<u64, f64> = BTreeMap::new();
    let mut slot_open: BTreeMap<usize, (u64, &'static str, f64)> = BTreeMap::new();
    let mut last_ts = 0.0f64;
    for r in records {
        last_ts = last_ts.max(r.t_us);
        match r.event {
            TraceEvent::Enqueued { id } => {
                queue_open.insert(id, r.t_us);
            }
            TraceEvent::Admitted { id, slot, .. } => {
                if let Some(t0) = queue_open.remove(&id) {
                    events.push(chrome_span(format!("req{id} queued"), 0, t0, r.t_us));
                }
                // A span left open here means its Completed/Evicted record
                // was dropped by wraparound: close it at the handover.
                if let Some((oid, phase, t0)) = slot_open.insert(slot, (id, "prefill", r.t_us)) {
                    events.push(chrome_span(format!("req{oid} {phase}"), slot + 1, t0, r.t_us));
                }
            }
            TraceEvent::TokenDecoded { id, slot, .. } => {
                if let Some(&(oid, phase, t0)) = slot_open.get(&slot) {
                    if phase == "prefill" && oid == id {
                        events.push(chrome_span(format!("req{id} prefill"), slot + 1, t0, r.t_us));
                        slot_open.insert(slot, (id, "decode", r.t_us));
                    }
                }
            }
            TraceEvent::Evicted { id, slot, reason } => {
                if let Some((oid, phase, t0)) = slot_open.remove(&slot) {
                    events.push(chrome_span(format!("req{oid} {phase}"), slot + 1, t0, r.t_us));
                }
                events.push(chrome_event(
                    format!("req{id} evicted ({reason:?})"),
                    "i",
                    slot + 1,
                    vec![("ts", json::num(r.t_us)), ("s", json::s("t"))],
                ));
                if reason != EvictReason::Cancelled {
                    // Back to the queue front (pool-exhaustion or fault
                    // requeue): reopen its queue span.
                    queue_open.insert(id, r.t_us);
                }
            }
            TraceEvent::Completed { id, slot, .. } => {
                if let Some((_, phase, t0)) = slot_open.remove(&slot) {
                    events.push(chrome_span(format!("req{id} {phase}"), slot + 1, t0, r.t_us));
                }
            }
            TraceEvent::FaultInjected { slot } => {
                let tid = slot.map_or(0, |s| s + 1);
                events.push(chrome_event(
                    "fault".to_string(),
                    "i",
                    tid,
                    vec![("ts", json::num(r.t_us)), ("s", json::s("t"))],
                ));
            }
            TraceEvent::RequestFailed { id, slot, .. } => {
                if let Some(s) = slot {
                    if let Some((oid, phase, t0)) = slot_open.remove(&s) {
                        events.push(chrome_span(format!("req{oid} {phase}"), s + 1, t0, r.t_us));
                    }
                }
                if let Some(t0) = queue_open.remove(&id) {
                    events.push(chrome_span(format!("req{id} queued"), 0, t0, r.t_us));
                }
                events.push(chrome_event(
                    format!("req{id} quarantined"),
                    "i",
                    slot.map_or(0, |s| s + 1),
                    vec![("ts", json::num(r.t_us)), ("s", json::s("t"))],
                ));
            }
            TraceEvent::DeadlineExpired { id, queued } => {
                if let Some(t0) = queue_open.remove(&id) {
                    events.push(chrome_span(format!("req{id} queued"), 0, t0, r.t_us));
                }
                if !queued {
                    // Mid-flight shed: its slot span is closed by the
                    // Evicted-free teardown path emitting this event last,
                    // so find and close the span that names this request.
                    if let Some((&s, &(oid, phase, t0))) =
                        slot_open.iter().find(|(_, (oid, _, _))| *oid == id)
                    {
                        events.push(chrome_span(format!("req{oid} {phase}"), s + 1, t0, r.t_us));
                        slot_open.remove(&s);
                    }
                }
                events.push(chrome_event(
                    format!("req{id} deadline expired"),
                    "i",
                    0,
                    vec![("ts", json::num(r.t_us)), ("s", json::s("t"))],
                ));
            }
            TraceEvent::StepComposed { decode_lanes, prefill_take, .. } => {
                events.push(chrome_counter("decode_lanes", r.t_us, decode_lanes as f64));
                events.push(chrome_counter("prefill_take", r.t_us, prefill_take as f64));
            }
            TraceEvent::Counters { queue_depth, in_flight, free_pages, prompt_fed, decode_fed } => {
                events.push(chrome_counter("queue_depth", r.t_us, queue_depth as f64));
                events.push(chrome_counter("in_flight", r.t_us, in_flight as f64));
                events.push(chrome_counter("free_pages", r.t_us, free_pages as f64));
                let fed = prompt_fed + decode_fed;
                let share = if fed > 0 { prompt_fed as f64 / fed as f64 } else { 0.0 };
                events.push(chrome_counter("prefill_share", r.t_us, share));
            }
            _ => {}
        }
    }
    for (id, t0) in queue_open {
        events.push(chrome_span(format!("req{id} queued"), 0, t0, last_ts));
    }
    for (slot, (id, phase, t0)) in slot_open {
        events.push(chrome_span(format!("req{id} {phase}"), slot + 1, t0, last_ts));
    }
    json::obj(vec![
        ("traceEvents", Json::Arr(events)),
        ("displayTimeUnit", json::s("ms")),
        ("otherData", json::obj(vec![("dropped_events", json::num(dropped_events as f64))])),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::{GenRequest, MockEngine, Scheduler};
    use crate::testing::prop::forall;

    fn rec(step: u64, t_us: f64, event: TraceEvent) -> TraceRecord {
        TraceRecord { step, t_us, event }
    }

    #[test]
    fn ring_drops_oldest_and_counts() {
        let sink = TraceSink::ring(4);
        for i in 0..6 {
            sink.emit(TraceEvent::Enqueued { id: i });
        }
        let records = sink.records();
        assert_eq!(records.len(), 4);
        assert_eq!(sink.dropped_events(), 2);
        assert_eq!(records[0].event, TraceEvent::Enqueued { id: 2 });
        assert_eq!(records[3].event, TraceEvent::Enqueued { id: 5 });
    }

    #[test]
    fn off_sink_is_inert() {
        let sink = TraceSink::Off;
        assert!(!sink.is_on());
        sink.emit(TraceEvent::Enqueued { id: 0 });
        sink.emit_at(Instant::now(), TraceEvent::Enqueued { id: 1 });
        sink.begin_step();
        assert!(sink.records().is_empty());
        assert_eq!(sink.dropped_events(), 0);
    }

    #[test]
    fn step_index_stamps_records() {
        let sink = TraceSink::ring(8);
        sink.begin_step();
        sink.emit(TraceEvent::Enqueued { id: 0 });
        sink.begin_step();
        sink.emit(TraceEvent::Enqueued { id: 1 });
        let records = sink.records();
        assert_eq!(records[0].step, 1);
        assert_eq!(records[1].step, 2);
    }

    #[test]
    fn fold_reconstructs_single_lifecycle() {
        let records = [
            rec(1, 0.0, TraceEvent::Enqueued { id: 7 }),
            rec(2, 10.0, TraceEvent::Admitted { id: 7, slot: 0, pages_charged: 2, tokens_reused: 4 }),
            rec(2, 12.0, TraceEvent::PrefillChunk { id: 7, slot: 0, pos0: 4, take: 5 }),
            rec(3, 20.0, TraceEvent::TokenDecoded { id: 7, slot: 0, stall_steps: None }),
            rec(4, 30.0, TraceEvent::TokenDecoded { id: 7, slot: 0, stall_steps: Some(0) }),
            rec(4, 31.0, TraceEvent::Completed { id: 7, slot: 0, reason: FinishReason::BudgetExhausted }),
        ];
        let tl = fold_timelines(&records);
        let t = &tl[&7];
        assert_eq!(t.enqueued_us, Some(0.0));
        assert_eq!(t.first_sched_us, Some(12.0));
        assert_eq!(t.first_token_us, Some(20.0));
        assert_eq!(t.completed_us, Some(31.0));
        assert_eq!(t.finish, Some(FinishReason::BudgetExhausted));
        assert_eq!(t.tokens, 2);
        assert_eq!(t.stalls, vec![0]);
        assert_eq!(t.prompt_tokens_fed, 5);
        assert_eq!(t.tokens_reused, 4);
        // ttft = 20; queue = 12; spread = 8.
        assert_eq!(t.ttft_split(), Some((12.0, 8.0)));
    }

    #[test]
    fn fold_resets_first_token_on_readmission() {
        let records = [
            rec(1, 0.0, TraceEvent::Enqueued { id: 3 }),
            rec(1, 5.0, TraceEvent::Admitted { id: 3, slot: 1, pages_charged: 1, tokens_reused: 0 }),
            rec(1, 6.0, TraceEvent::PrefillChunk { id: 3, slot: 1, pos0: 0, take: 2 }),
            rec(2, 9.0, TraceEvent::TokenDecoded { id: 3, slot: 1, stall_steps: None }),
            rec(3, 12.0, TraceEvent::Evicted { id: 3, slot: 1, reason: EvictReason::PoolExhausted }),
            rec(4, 20.0, TraceEvent::Admitted { id: 3, slot: 0, pages_charged: 1, tokens_reused: 0 }),
            rec(4, 21.0, TraceEvent::PrefillChunk { id: 3, slot: 0, pos0: 0, take: 2 }),
            rec(5, 25.0, TraceEvent::TokenDecoded { id: 3, slot: 0, stall_steps: None }),
            rec(5, 26.0, TraceEvent::Completed { id: 3, slot: 0, reason: FinishReason::BudgetExhausted }),
        ];
        let tl = fold_timelines(&records);
        let t = &tl[&3];
        assert_eq!(t.admissions, 2);
        assert_eq!(t.evictions, 1);
        // TTFT restarts with the re-admission; queue wait keeps the FIRST
        // schedule (t=6), exactly like the scheduler's stamps.
        assert_eq!(t.first_token_us, Some(25.0));
        assert_eq!(t.first_sched_us, Some(6.0));
        assert_eq!(t.tokens, 1);
        assert_eq!(t.ttft_split(), Some((6.0, 19.0)));
    }

    #[test]
    fn verify_cross_checks_hand_built_metrics() {
        let records = [
            rec(1, 0.0, TraceEvent::Enqueued { id: 0 }),
            rec(1, 4.0, TraceEvent::Admitted { id: 0, slot: 0, pages_charged: 1, tokens_reused: 2 }),
            rec(1, 4.5, TraceEvent::PrefixHit { id: 0, slot: 0, pages: 1 }),
            rec(1, 5.0, TraceEvent::PrefillChunk { id: 0, slot: 0, pos0: 2, take: 3 }),
            rec(2, 9.0, TraceEvent::TokenDecoded { id: 0, slot: 0, stall_steps: None }),
            rec(3, 14.0, TraceEvent::TokenDecoded { id: 0, slot: 0, stall_steps: Some(1) }),
            rec(3, 15.0, TraceEvent::Completed { id: 0, slot: 0, reason: FinishReason::BudgetExhausted }),
        ];
        let mut m = ServingMetrics::new();
        m.tokens_generated = 2;
        m.requests_completed = 1;
        m.tokens_reused = 2;
        m.prefix_hits = 1;
        m.decode_stall_steps.push(1.0);
        m.ttft_us.push(9.0);
        m.queue_us.push(5.0);
        m.prefill_spread_us.push(4.0);
        verify_against_metrics(&records, &m).unwrap();
        // Any single divergence is caught.
        let mut bad = m.clone();
        bad.decode_stall_steps.push(5.0);
        assert!(verify_against_metrics(&records, &bad).is_err());
        let mut bad = m.clone();
        bad.ttft_us = crate::util::timer::Samples::new();
        bad.ttft_us.push(9.5);
        assert!(verify_against_metrics(&records, &bad).is_err());
        let mut bad = m.clone();
        bad.tokens_generated = 3;
        assert!(verify_against_metrics(&records, &bad).is_err());
        let mut bad = m;
        bad.queue_us = crate::util::timer::Samples::new();
        bad.queue_us.push(6.0);
        assert!(verify_against_metrics(&records, &bad).is_err());
    }

    #[test]
    fn oracle_scope_excludes_physical_plane() {
        assert!(TraceEvent::Enqueued { id: 0 }.in_oracle_scope());
        assert!(TraceEvent::StepComposed { decode_lanes: 1, prefill_take: 2, budget: 4 }
            .in_oracle_scope());
        assert!(TraceEvent::PrefixDonated { slot: 0, pages: 1 }.in_oracle_scope());
        // The error-kernel plane is a scheduler decision stream: all of it
        // is replayed by the oracle.
        assert!(TraceEvent::FaultInjected { slot: None }.in_oracle_scope());
        assert!(TraceEvent::RetryScheduled { slot: Some(1), backoff_steps: 2, attempt: 1 }
            .in_oracle_scope());
        assert!(TraceEvent::SlotRecovered { id: 0, slot: 1 }.in_oracle_scope());
        assert!(TraceEvent::RequestFailed { id: 0, slot: None, faults: 3 }.in_oracle_scope());
        assert!(TraceEvent::DeadlineExpired { id: 0, queued: true }.in_oracle_scope());
        // The speculative plane is a decision stream: the oracle predicts
        // every proposal, acceptance and rollback.
        assert!(TraceEvent::DraftProposed { id: 0, slot: 1, tokens: 4 }.in_oracle_scope());
        assert!(TraceEvent::DraftAccepted { id: 0, slot: 1, accepted: 2 }.in_oracle_scope());
        assert!(TraceEvent::DraftRejected { id: 0, slot: 1, rejected: 2 }.in_oracle_scope());
        assert!(!TraceEvent::PageAllocated { block: 0, refcount: 1 }.in_oracle_scope());
        assert!(!TraceEvent::PageRetained { block: 0, refcount: 2 }.in_oracle_scope());
        assert!(!TraceEvent::PageReleased { block: 0, refcount: 0 }.in_oracle_scope());
        assert!(!TraceEvent::Counters {
            queue_depth: 0,
            in_flight: 0,
            free_pages: 0,
            prompt_fed: 0,
            decode_fed: 0
        }
        .in_oracle_scope());
    }

    #[test]
    fn tracing_does_not_change_scheduling() {
        // Trace-off byte-identity with the PR 5 paths: the sink is a
        // branch, never a behavior change.
        let run = |traced: bool| {
            let engine = MockEngine::new(2, 64, 64).with_prefill_chunk(4);
            let mut s = Scheduler::new(engine, 16).expect("scheduler");
            if traced {
                s = s.with_trace(1 << 12);
            }
            for len in [3usize, 10, 7] {
                s.submit(GenRequest::greedy(&vec![9u8; len], 5)).expect("submit");
            }
            let mut done = Vec::new();
            while !s.is_idle() {
                done.extend(s.step().expect("step"));
            }
            let outs: Vec<(u64, Vec<u8>)> =
                done.into_iter().map(|c| (c.id, c.completion)).collect();
            (outs, s.engine().steps, s.engine().prefill_calls)
        };
        assert_eq!(run(false), run(true));
    }

    #[test]
    fn trace_off_sink_is_never_allocated() {
        let s = Scheduler::new(MockEngine::new(1, 16, 64), 4).expect("scheduler");
        assert!(matches!(s.trace_sink(), TraceSink::Off));
        assert!(s.trace_records().is_empty());
        assert_eq!(s.trace_dropped_events(), 0);
    }

    #[test]
    fn ring_wraparound_keeps_live_timelines_well_formed() {
        let engine = MockEngine::new(2, 64, 64).with_prefill_chunk(4);
        let mut s = Scheduler::new(engine, 16).expect("scheduler").with_trace(24);
        let mut last = 0u64;
        for _ in 0..6 {
            last = s.submit(GenRequest::greedy(&[5u8; 10], 4)).expect("submit");
            while !s.is_idle() {
                s.step().expect("step");
            }
        }
        assert!(s.trace_dropped_events() > 0, "24-record ring must wrap over 6 requests");
        let records = s.trace_records();
        // Order survives the wrap.
        for w in records.windows(2) {
            assert!(w[0].step <= w[1].step);
            assert!(w[0].t_us <= w[1].t_us + T_EPS_US);
        }
        // The newest request's lifecycle is complete and internally
        // consistent even though older requests were truncated.
        let tl = fold_timelines(&records);
        let t = &tl[&last];
        assert_eq!(t.tokens, 4);
        assert!(t.completed_us.is_some());
        assert_eq!(t.finish, Some(FinishReason::BudgetExhausted));
        let (queue, spread) = t.ttft_split().expect("full lifecycle survived");
        assert!(queue >= 0.0 && spread >= 0.0);
    }

    #[test]
    fn metrics_vs_trace_fold_property() {
        // Seeded random workloads over every scheduler shape: a complete
        // trace must re-derive the metrics exactly.
        forall(2024, 60, |g| {
            let slots = g.int(1, 4);
            let max_seq = g.int(6, 48);
            let chunk = *g.pick(&[1usize, 2, 4, 8]);
            let paged = g.bool();
            let block_size = *g.pick(&[1usize, 2, 4, 8]);
            let full = slots * max_seq.div_ceil(block_size);
            let mut engine = MockEngine::new(slots, max_seq, 64).with_prefill_chunk(chunk);
            if paged {
                engine = engine.with_block_pool(g.int(1, full.max(2)), block_size);
            }
            let mut s = Scheduler::new(engine, g.int(1, 6))
                .map_err(|e| e.to_string())?
                .with_trace(1 << 16);
            if paged && g.bool() {
                s = s.with_prefix_cache().map_err(|e| e.to_string())?;
            }
            if chunk > 1 && g.bool() {
                s = s
                    .with_step_budget(*g.pick(&[2usize, 4, 8]))
                    .map_err(|e| e.to_string())?;
            }
            for _ in 0..g.int(4, 30) {
                match g.int(0, 9) {
                    0..=3 => {
                        let len = g.int(1, (max_seq - 1).min(24));
                        let fill = g.int(0, 60) as u8;
                        let _ = s.submit(GenRequest::greedy(&vec![fill; len], g.int(0, 8)));
                    }
                    4..=8 => {
                        s.step().map_err(|e| e.to_string())?;
                    }
                    _ => {
                        s.cancel(g.int(0, 12) as u64).map_err(|e| e.to_string())?;
                    }
                }
            }
            while !s.is_idle() {
                s.step().map_err(|e| e.to_string())?;
            }
            if s.trace_dropped_events() != 0 {
                return Err("trace ring overflowed a 64k budget".into());
            }
            verify_against_metrics(&s.trace_records(), &s.metrics)
        });
    }

    #[test]
    fn chrome_export_is_valid_and_tracked() {
        let engine =
            MockEngine::new(2, 64, 64).with_prefill_chunk(4).with_block_pool(16, 4);
        let mut s = Scheduler::new(engine, 8)
            .expect("scheduler")
            .with_trace(1 << 12)
            .with_prefix_cache()
            .expect("prefix cache")
            .with_step_budget(4)
            .expect("budget");
        for _ in 0..3 {
            s.submit(GenRequest::greedy(&[1u8; 9], 3)).expect("submit");
        }
        while !s.is_idle() {
            s.step().expect("step");
        }
        let j = chrome_trace(&s.trace_records(), s.trace_dropped_events());
        // Round-trips through the parser and keeps the format contract.
        let parsed = Json::parse(&j.to_string()).expect("valid JSON");
        let evs = parsed.req("traceEvents").unwrap().as_arr().unwrap();
        assert!(!evs.is_empty());
        let (mut saw_x, mut saw_c, mut saw_m) = (false, false, false);
        for e in evs {
            assert!(e.get("pid").is_some() && e.get("name").is_some());
            match e.req("ph").unwrap().as_str().unwrap() {
                "X" => {
                    saw_x = true;
                    assert!(e.req("dur").unwrap().as_f64().unwrap() >= 0.0);
                    assert!(e.req("ts").unwrap().as_f64().unwrap() >= 0.0);
                }
                "C" => saw_c = true,
                "M" => saw_m = true,
                "i" => {}
                other => panic!("unexpected phase {other:?}"),
            }
        }
        assert!(saw_x && saw_c && saw_m, "spans, counters and metadata all present");
        assert_eq!(
            parsed.req("otherData").unwrap().req("dropped_events").unwrap().as_f64(),
            Some(0.0)
        );
    }
}
