//! Token samplers: greedy, temperature, top-k, top-p (nucleus).
//!
//! Every sampler draws from a caller-supplied [`Prng`], so a fixed seed
//! reproduces a generation exactly; `temperature <= 0` degrades to greedy
//! by construction. All comparisons are NaN-safe: NaN logits (which a
//! numerically blown-up quantized model can emit) are treated as -inf
//! instead of panicking mid-serve.

use anyhow::{bail, Result};

use crate::util::prng::Prng;

/// NaN-safe argmax: NaN entries are skipped (treated as -inf); returns 0
/// for empty or all-NaN input. Regression guard for the old
/// `partial_cmp().unwrap()` panic on NaN logits.
pub fn argmax(xs: &[f32]) -> usize {
    let mut best: Option<(usize, f32)> = None;
    for (i, &v) in xs.iter().enumerate() {
        if v.is_nan() {
            continue;
        }
        match best {
            Some((_, bv)) if bv >= v => {}
            _ => best = Some((i, v)),
        }
    }
    best.map(|(i, _)| i).unwrap_or(0)
}

/// Candidate-set policy applied before the softmax draw.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum SamplerKind {
    /// Always the argmax; temperature is ignored.
    Greedy,
    /// Full-vocabulary softmax at `temperature`.
    Temperature,
    /// Keep the k most likely tokens, renormalize.
    TopK(usize),
    /// Keep the smallest prefix of the sorted distribution with cumulative
    /// probability >= p, renormalize.
    TopP(f32),
}

/// A decoding policy: candidate selection + temperature.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Sampler {
    pub kind: SamplerKind,
    pub temperature: f32,
}

impl Sampler {
    pub fn greedy() -> Self {
        Self { kind: SamplerKind::Greedy, temperature: 0.0 }
    }

    pub fn temperature(t: f32) -> Self {
        Self { kind: SamplerKind::Temperature, temperature: t }
    }

    pub fn top_k(k: usize, t: f32) -> Self {
        Self { kind: SamplerKind::TopK(k), temperature: t }
    }

    pub fn top_p(p: f32, t: f32) -> Self {
        Self { kind: SamplerKind::TopP(p), temperature: t }
    }

    /// Parse a CLI sampler spec (`--sampler` + knobs).
    pub fn parse(name: &str, temperature: f32, top_k: usize, top_p: f32) -> Result<Self> {
        Ok(match name.to_ascii_lowercase().as_str() {
            "greedy" | "argmax" => Self::greedy(),
            "temperature" | "temp" | "softmax" => Self::temperature(temperature),
            "top-k" | "topk" | "top_k" => Self::top_k(top_k, temperature),
            "top-p" | "topp" | "top_p" | "nucleus" => Self::top_p(top_p, temperature),
            other => bail!("unknown sampler {other:?} (greedy|temperature|top-k|top-p)"),
        })
    }

    pub fn name(&self) -> String {
        match self.kind {
            SamplerKind::Greedy => "greedy".to_string(),
            SamplerKind::Temperature => format!("temperature(t={})", self.temperature),
            SamplerKind::TopK(k) => format!("top-k(k={k}, t={})", self.temperature),
            SamplerKind::TopP(p) => format!("top-p(p={p}, t={})", self.temperature),
        }
    }

    /// Draw one token index from `logits`. Deterministic given (`self`,
    /// `logits`, the PRNG state).
    ///
    /// Candidate selection is *partial*: top-k and top-p pull their k /
    /// nucleus prefix out with `select_nth_unstable_by` and only sort that
    /// prefix, and the temperature path never orders the vocabulary at all
    /// — the old implementation's full `V log V` sort per generated token
    /// was the dominant scheduler-side cost at real vocab sizes (see the
    /// `sampler` section of `benches/serving.rs` for before/after numbers).
    pub fn sample(&self, logits: &[f32], rng: &mut Prng) -> usize {
        if logits.is_empty() {
            return 0;
        }
        if matches!(self.kind, SamplerKind::Greedy) || self.temperature <= 0.0 {
            return argmax(logits);
        }
        // Candidate indices, NaNs dropped (unordered).
        let mut idx: Vec<usize> = (0..logits.len()).filter(|&i| !logits[i].is_nan()).collect();
        if idx.is_empty() {
            return 0;
        }
        let desc = |&a: &usize, &b: &usize| logits[b].total_cmp(&logits[a]);
        let (idx, ws) = match self.kind {
            SamplerKind::TopK(k) => {
                // Partition the k largest to the front, then order just
                // that prefix (the draw below walks weights in descending
                // order, matching the old full-sort behaviour for distinct
                // logits; exactly tied logits at the boundary may resolve
                // to a different — equally probable — tied index, since
                // the selection is unstable).
                let k = k.clamp(1, idx.len());
                if k < idx.len() {
                    idx.select_nth_unstable_by(k - 1, desc);
                    idx.truncate(k);
                }
                idx.sort_unstable_by(desc);
                let m = logits[idx[0]];
                let ws: Vec<f32> =
                    idx.iter().map(|&i| ((logits[i] - m) / self.temperature).exp()).collect();
                (idx, ws)
            }
            SamplerKind::TopP(p) => {
                // The nucleus needs the total softmax mass (over *all*
                // candidates) and the sorted order only up to the cutoff:
                // grow a sorted prefix geometrically until it holds the
                // target mass, instead of sorting the whole vocabulary.
                let m = idx.iter().map(|&i| logits[i]).fold(f32::NEG_INFINITY, f32::max);
                let weight = |i: usize| ((logits[i] - m) / self.temperature).exp();
                let total: f32 = idx.iter().map(|&i| weight(i)).sum();
                let target = p.clamp(0.0, 1.0) * total;
                let n = idx.len();
                let mut prefix = 16.min(n);
                let cut = loop {
                    if prefix < n {
                        idx.select_nth_unstable_by(prefix - 1, desc);
                        idx[..prefix].sort_unstable_by(desc);
                    } else {
                        idx.sort_unstable_by(desc);
                    }
                    // Cumulative mass in descending order, exactly as the
                    // full-sort implementation summed it.
                    let mut cum = 0.0f32;
                    let mut cut = None;
                    for (j, &i) in idx[..prefix].iter().enumerate() {
                        cum += weight(i);
                        if cum >= target {
                            cut = Some(j + 1);
                            break;
                        }
                    }
                    match cut {
                        Some(c) => break c,
                        None if prefix == n => break n,
                        // Nucleus bigger than the prefix: widen and retry.
                        None => prefix = (prefix * 4).min(n),
                    }
                };
                idx.truncate(cut);
                let ws: Vec<f32> = idx.iter().map(|&i| weight(i)).collect();
                (idx, ws)
            }
            _ => {
                // Temperature over the full support needs no order at all;
                // the argmax is swapped to the front so the cold-temperature
                // limit still degrades to greedy exactly.
                let mut best = 0usize;
                for (j, &i) in idx.iter().enumerate() {
                    if logits[i] > logits[idx[best]] {
                        best = j;
                    }
                }
                idx.swap(0, best);
                let m = logits[idx[0]];
                let ws: Vec<f32> =
                    idx.iter().map(|&i| ((logits[i] - m) / self.temperature).exp()).collect();
                (idx, ws)
            }
        };
        let sum: f32 = ws.iter().sum();
        if sum <= 0.0 || !sum.is_finite() {
            return idx[0];
        }
        let mut r = rng.uniform() * sum;
        for (j, &w) in ws.iter().enumerate() {
            if r < w {
                return idx[j];
            }
            r -= w;
        }
        *idx.last().unwrap()
    }
}

/// Speculative acceptance over one verified window.
///
/// `rows[i]` is the target engine's logits row after feeding the window's
/// token `i` (row 0 follows the slot's committed last token, row `i > 0`
/// follows draft `i - 1`), and `drafts` are the window's proposed tokens —
/// `rows.len() == drafts.len() + 1` in a full window. Walking rows in
/// order, each row is sampled with `sampler` and committed; the walk stops
/// at the first committed token that disagrees with its draft (every later
/// row follows a token the target just refused, so its logits are
/// counterfactual) or once `budget` tokens are committed (`budget >= 1`;
/// a verify window always commits at least its first sample).
///
/// Returns `(committed, accepted)`: the tokens to commit, in order, and
/// how many drafts agreed. The last committed token is always a fresh
/// target sample — the free correction on rejection, the bonus token on
/// full acceptance. A draft whose token matched but fell past the commit
/// budget is not counted accepted (it bought nothing).
///
/// Rows are consumed strictly in order and each row's logits are exactly
/// what a non-speculative run would have computed at that position, so
/// the PRNG draw sequence matches sequential decoding draw for draw —
/// greedy draws nothing, every other sampler draws exactly one uniform
/// per committed token. That is the byte-identity anchor: speculation
/// changes *when* logits are computed, never what is sampled from them.
pub fn accept_speculative(
    sampler: &Sampler,
    rows: &[Vec<f32>],
    drafts: &[i32],
    rng: &mut Prng,
    budget: usize,
) -> (Vec<usize>, usize) {
    let mut committed = Vec::with_capacity(rows.len());
    let mut accepted = 0usize;
    for (i, row) in rows.iter().enumerate() {
        let tok = sampler.sample(row, rng);
        committed.push(tok);
        if committed.len() >= budget {
            break;
        }
        if i < drafts.len() && tok as i32 == drafts[i] {
            accepted += 1;
        } else {
            break;
        }
    }
    (committed, accepted)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_works() {
        assert_eq!(argmax(&[0.1, 3.0, -1.0]), 1);
        assert_eq!(argmax(&[]), 0);
    }

    #[test]
    fn argmax_survives_nan() {
        // Regression: the old partial_cmp().unwrap() panicked here.
        assert_eq!(argmax(&[1.0, f32::NAN, 3.0, 2.0]), 2);
        assert_eq!(argmax(&[f32::NAN, f32::NAN]), 0);
        assert_eq!(argmax(&[f32::NAN, 0.5]), 1);
        assert_eq!(argmax(&[f32::NEG_INFINITY, -1.0]), 1);
    }

    #[test]
    fn zero_temperature_matches_greedy() {
        let logits = [0.3, 2.0, -1.5, 1.9];
        let mut rng = Prng::new(1);
        for s in [
            Sampler::temperature(0.0),
            Sampler::top_k(3, 0.0),
            Sampler::top_p(0.9, 0.0),
            Sampler::greedy(),
        ] {
            assert_eq!(s.sample(&logits, &mut rng), argmax(&logits), "{}", s.name());
        }
    }

    #[test]
    fn same_seed_same_draws() {
        let s = Sampler::top_k(8, 1.3);
        let mut a = Prng::new(42);
        let mut b = Prng::new(42);
        let mut p = Prng::new(9);
        for _ in 0..50 {
            let logits: Vec<f32> = (0..32).map(|_| p.normal() * 2.0).collect();
            assert_eq!(s.sample(&logits, &mut a), s.sample(&logits, &mut b));
        }
    }

    #[test]
    fn top_k_one_is_greedy() {
        let s = Sampler::top_k(1, 2.0);
        let mut rng = Prng::new(5);
        let mut p = Prng::new(6);
        for _ in 0..20 {
            let logits: Vec<f32> = (0..16).map(|_| p.normal()).collect();
            assert_eq!(s.sample(&logits, &mut rng), argmax(&logits));
        }
    }

    #[test]
    fn top_p_tiny_is_greedy() {
        let s = Sampler::top_p(1e-6, 1.0);
        let mut rng = Prng::new(5);
        let logits = [0.0, 5.0, 1.0, 4.9];
        for _ in 0..20 {
            assert_eq!(s.sample(&logits, &mut rng), 1);
        }
    }

    #[test]
    fn temperature_sampling_covers_support_but_respects_peaks() {
        let s = Sampler::temperature(1.0);
        let mut rng = Prng::new(7);
        let logits = [2.0f32, 2.0, -30.0];
        let mut counts = [0usize; 3];
        for _ in 0..500 {
            counts[s.sample(&logits, &mut rng)] += 1;
        }
        assert!(counts[0] > 100 && counts[1] > 100, "{counts:?}");
        assert_eq!(counts[2], 0);
    }

    #[test]
    fn sampling_with_nan_logits_never_panics() {
        let logits = [f32::NAN, 1.0, f32::NAN, 0.5];
        let mut rng = Prng::new(3);
        for s in [Sampler::temperature(1.0), Sampler::top_k(2, 1.0), Sampler::top_p(0.9, 1.0)] {
            let t = s.sample(&logits, &mut rng);
            assert!(t == 1 || t == 3, "{}", s.name());
        }
    }

    // -- randomized statistical properties (crate::testing::prop) ---------

    #[test]
    fn prop_top_k_never_samples_outside_the_k_set() {
        use crate::testing::prop::forall;
        forall(0x70c1, 300, |g| {
            let n = g.int(2, 64);
            let logits: Vec<f32> = (0..n).map(|_| g.rng.normal() * 3.0).collect();
            let k = g.int(1, n);
            let s = Sampler::top_k(k, g.f32(0.05, 3.0));
            let mut rng = Prng::new(g.rng.next_u64());
            let tok = s.sample(&logits, &mut rng);
            // Independent k-set: the k largest logits under the same
            // total_cmp order the sampler sorts with.
            let mut idx: Vec<usize> = (0..n).collect();
            idx.sort_by(|&a, &b| logits[b].total_cmp(&logits[a]));
            idx.truncate(k);
            if idx.contains(&tok) {
                Ok(())
            } else {
                Err(format!("token {tok} outside top-{k} set {idx:?}"))
            }
        });
    }

    #[test]
    fn prop_top_p_never_samples_outside_the_nucleus() {
        use crate::testing::prop::forall;
        forall(0x70f2, 300, |g| {
            let n = g.int(2, 64);
            let logits: Vec<f32> = (0..n).map(|_| g.rng.normal() * 3.0).collect();
            let p = g.f32(0.05, 1.0);
            let temp = g.f32(0.2, 2.0);
            let s = Sampler::top_p(p, temp);
            let mut rng = Prng::new(g.rng.next_u64());
            let tok = s.sample(&logits, &mut rng);
            // Independent nucleus: smallest prefix of the sorted softmax
            // whose cumulative mass reaches p (same arithmetic order as
            // the sampler so the boundary token agrees bit-for-bit).
            let mut idx: Vec<usize> = (0..n).collect();
            idx.sort_by(|&a, &b| logits[b].total_cmp(&logits[a]));
            let m = logits[idx[0]];
            let ws: Vec<f32> = idx.iter().map(|&i| ((logits[i] - m) / temp).exp()).collect();
            let total: f32 = ws.iter().sum();
            let target = p * total;
            let mut cum = 0.0f32;
            let mut cut = ws.len();
            for (j, &w) in ws.iter().enumerate() {
                cum += w;
                if cum >= target {
                    cut = j + 1;
                    break;
                }
            }
            let nucleus = &idx[..cut];
            if nucleus.contains(&tok) {
                Ok(())
            } else {
                Err(format!("token {tok} outside p={p} nucleus {nucleus:?}"))
            }
        });
    }

    #[test]
    fn prop_temperature_to_zero_converges_to_argmax() {
        use crate::testing::prop::forall;
        // With a unique max (gap >= 1), t = 0.01 makes any non-argmax draw
        // ~e^{-100} likely; over a seeded PRNG this is exact in practice.
        forall(0x7e20, 200, |g| {
            let n = g.int(2, 32);
            let mut logits: Vec<f32> = (0..n).map(|_| g.rng.normal()).collect();
            let best = g.int(0, n - 1);
            let top = logits.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
            logits[best] = top + 1.0;
            let mut rng = Prng::new(g.rng.next_u64());
            for kind in [
                Sampler::temperature(0.01),
                Sampler::top_k(n, 0.01),
                Sampler::top_p(1.0, 0.01),
            ] {
                let tok = kind.sample(&logits, &mut rng);
                if tok != best {
                    return Err(format!("{}: drew {tok}, argmax {best}", kind.name()));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn argmax_rate_increases_as_temperature_falls() {
        // The convergence is monotone in practice: colder sampling hits the
        // argmax at least as often, reaching 100% well before t = 0.02.
        let logits = [1.0f32, 3.0, 2.5, 0.0];
        let best = argmax(&logits);
        let mut prev_hits = 0usize;
        for (i, t) in [2.0f32, 0.5, 0.02].into_iter().enumerate() {
            let s = Sampler::temperature(t);
            let mut rng = Prng::new(99);
            let hits =
                (0..400).filter(|_| s.sample(&logits, &mut rng) == best).count();
            assert!(
                hits >= prev_hits,
                "cooling {t} lowered the argmax rate: {hits} < {prev_hits}"
            );
            if i == 2 {
                assert_eq!(hits, 400, "t=0.02 should be argmax-only, got {hits}/400");
            }
            prev_hits = hits;
        }
    }

    /// The old implementation, kept verbatim as a reference: full
    /// descending sort of the vocabulary, then truncate to k.
    fn full_sort_top_k_reference(logits: &[f32], k: usize, temp: f32, rng: &mut Prng) -> usize {
        let mut idx: Vec<usize> = (0..logits.len()).filter(|&i| !logits[i].is_nan()).collect();
        idx.sort_by(|&a, &b| logits[b].total_cmp(&logits[a]));
        let m = logits[idx[0]];
        let mut ws: Vec<f32> = idx.iter().map(|&i| ((logits[i] - m) / temp).exp()).collect();
        let k = k.clamp(1, idx.len());
        idx.truncate(k);
        ws.truncate(k);
        let sum: f32 = ws.iter().sum();
        if sum <= 0.0 || !sum.is_finite() {
            return idx[0];
        }
        let mut r = rng.uniform() * sum;
        for (j, &w) in ws.iter().enumerate() {
            if r < w {
                return idx[j];
            }
            r -= w;
        }
        *idx.last().unwrap()
    }

    #[test]
    fn prop_partial_top_k_is_bit_identical_to_full_sort() {
        // The select_nth-based top-k is a pure perf change: same k-set,
        // same descending weight walk, same PRNG consumption — so every
        // draw must match the old full-sort implementation exactly.
        // (Caveat: bit-identity holds for distinct logits, as drawn here;
        // exact ties at the k boundary are order-ambiguous under unstable
        // selection and may legitimately pick a different tied index.)
        use crate::testing::prop::forall;
        forall(0x70c3, 300, |g| {
            let n = g.int(2, 128);
            let logits: Vec<f32> = (0..n).map(|_| g.rng.normal() * 3.0).collect();
            let k = g.int(1, n + 2); // occasionally k > n: clamp path
            let temp = g.f32(0.05, 3.0);
            let seed = g.rng.next_u64();
            let s = Sampler::top_k(k, temp);
            let got = s.sample(&logits, &mut Prng::new(seed));
            let want = full_sort_top_k_reference(&logits, k, temp, &mut Prng::new(seed));
            if got == want {
                Ok(())
            } else {
                Err(format!("partial drew {got}, full sort drew {want} (k={k}, n={n})"))
            }
        });
    }

    // -- speculative acceptance --------------------------------------------

    fn one_hot(n: usize, i: usize) -> Vec<f32> {
        let mut v = vec![0.0; n];
        v[i] = 1.0;
        v
    }

    #[test]
    fn accept_speculative_keeps_longest_agreeing_prefix_plus_correction() {
        let s = Sampler::greedy();
        let mut rng = Prng::new(1);
        let rows = vec![one_hot(8, 3), one_hot(8, 5), one_hot(8, 2)];
        // Full agreement: both drafts accepted + the bonus token.
        let (c, a) = accept_speculative(&s, &rows, &[3, 5], &mut rng, 16);
        assert_eq!((c.as_slice(), a), ([3usize, 5, 2].as_slice(), 2));
        // First draft diverges: one correction token, nothing accepted.
        let (c, a) = accept_speculative(&s, &rows, &[4, 5], &mut rng, 16);
        assert_eq!((c.as_slice(), a), ([3usize].as_slice(), 0));
        // Second draft diverges: accepted prefix of 1 + correction.
        let (c, a) = accept_speculative(&s, &rows, &[3, 4], &mut rng, 16);
        assert_eq!((c.as_slice(), a), ([3usize, 5].as_slice(), 1));
        // The commit budget caps the walk even under full agreement.
        let (c, a) = accept_speculative(&s, &rows, &[3, 5], &mut rng, 2);
        assert_eq!((c.as_slice(), a), ([3usize, 5].as_slice(), 1));
        let (c, a) = accept_speculative(&s, &rows, &[3, 5], &mut rng, 1);
        assert_eq!((c.as_slice(), a), ([3usize].as_slice(), 0));
        // Empty draft window: a plain decode step in verify clothing.
        let (c, a) = accept_speculative(&s, &rows[..1], &[], &mut rng, 16);
        assert_eq!((c.as_slice(), a), ([3usize].as_slice(), 0));
    }

    #[test]
    fn prop_accept_speculative_consumes_rng_exactly_like_sequential_decoding() {
        use crate::testing::prop::forall;
        forall(0x5bec, 300, |g| {
            let n = g.int(2, 32);
            let k = g.int(0, 4);
            let rows: Vec<Vec<f32>> = (0..k + 1)
                .map(|_| (0..n).map(|_| g.rng.normal() * 2.0).collect())
                .collect();
            let s = *g.pick(&vec![
                Sampler::greedy(),
                Sampler::temperature(0.8),
                Sampler::top_k(4, 1.1),
                Sampler::top_p(0.9, 0.7),
            ]);
            let seed = g.rng.next_u64();
            let budget = g.int(1, k + 1);
            // What sequential decoding over these rows would sample.
            let mut seq_rng = Prng::new(seed);
            let seq: Vec<usize> = rows.iter().map(|r| s.sample(r, &mut seq_rng)).collect();
            // Drafts: the sequential samples themselves (full agreement),
            // sometimes corrupted mid-window with an unmatchable token.
            let mut drafts: Vec<i32> = seq[..k].iter().map(|&t| t as i32).collect();
            if k > 0 && g.bool() {
                drafts[g.int(0, k - 1)] = n as i32 + 1;
            }
            let mut rng = Prng::new(seed);
            let (committed, accepted) = accept_speculative(&s, &rows, &drafts, &mut rng, budget);
            // The committed tokens are exactly a sequential prefix...
            if committed.as_slice() != &seq[..committed.len()] {
                return Err(format!("committed {committed:?} diverges from sequential {seq:?}"));
            }
            if committed.is_empty() || committed.len() > budget || accepted > committed.len() {
                return Err(format!("malformed result ({committed:?}, {accepted})"));
            }
            // ...and the PRNG advanced exactly as sampling them one step
            // at a time would have: the very next draw agrees.
            let mut check = Prng::new(seed);
            for r in rows.iter().take(committed.len()) {
                s.sample(r, &mut check);
            }
            if rng.next_u64() != check.next_u64() {
                return Err("PRNG drifted from sequential decoding".into());
            }
            Ok(())
        });
    }

    #[test]
    fn parse_specs() {
        assert_eq!(Sampler::parse("greedy", 0.7, 5, 0.9).unwrap(), Sampler::greedy());
        assert_eq!(
            Sampler::parse("top-k", 0.7, 5, 0.9).unwrap(),
            Sampler::top_k(5, 0.7)
        );
        assert_eq!(
            Sampler::parse("nucleus", 0.7, 5, 0.9).unwrap(),
            Sampler::top_p(0.9, 0.7)
        );
        assert!(Sampler::parse("bogus", 1.0, 1, 1.0).is_err());
    }
}
