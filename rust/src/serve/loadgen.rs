//! Open-loop load generator for the HTTP/SSE front.
//!
//! *Open-loop* is the load-model that matters for "millions of users":
//! arrivals follow a seeded Poisson process and are launched **on
//! schedule whether or not earlier requests have finished** — a slow
//! server faces a growing backlog exactly as it would in production.
//! (A closed-loop client that waits for each response before sending the
//! next one silently throttles itself to the server's pace and hides
//! tail latency — the classic coordinated-omission trap. TTFT here is
//! measured from the *scheduled* arrival instant, not from when the
//! client thread got around to connecting, for the same reason.)
//!
//! The generator precomputes the full arrival schedule from one seed
//! (exponential inter-arrivals at the offered RPS, per-request prompt and
//! output lengths uniform over configured ranges, tenant picked with a
//! 1/(rank+1) Zipf-ish skew), then drives the real front over loopback:
//! worker threads own only their sockets while the scheduler stays on the
//! driver thread, which alternates spawning due arrivals with
//! [`HttpFront::poll`].
//!
//! [`LoadReport::to_json`] emits the `serving_load` point shape the CI
//! schema pins: offered/goodput RPS, TTFT p50/p99, inter-token p99, shed
//! and error counts.

use std::sync::mpsc;
use std::thread;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::serve::engine::DecodeEngine;
use crate::serve::http::{blocking_request, HttpFront, StreamOutcome};
use crate::serve::scheduler::Scheduler;
use crate::util::json::{self, Json};
use crate::util::prng::Prng;
use crate::util::timer::Samples;

/// Knobs for one open-loop run (one RPS point of a sweep).
#[derive(Clone, Debug)]
pub struct LoadGenConfig {
    /// Offered arrival rate, requests/sec.
    pub rps: f64,
    /// Arrival window: requests are scheduled in [0, duration_secs).
    pub duration_secs: f64,
    /// Seed for the whole schedule (arrivals, lengths, tenants, sampling
    /// seeds). Same seed ⇒ byte-identical offered load.
    pub seed: u64,
    /// Number of distinct tenant keys; tenant `t0` is the hottest
    /// (weight ∝ 1/(rank+1)).
    pub tenants: usize,
    /// Uniform prompt-length range `[lo, hi]` in bytes.
    pub prompt_len: (usize, usize),
    /// Uniform `max_new_tokens` range `[lo, hi]`.
    pub max_new: (usize, usize),
    /// Per-read client socket timeout; also bounds the post-window drain.
    pub timeout_secs: f64,
}

impl Default for LoadGenConfig {
    fn default() -> Self {
        Self {
            rps: 50.0,
            duration_secs: 1.0,
            seed: 0,
            tenants: 4,
            prompt_len: (8, 24),
            max_new: (4, 16),
            timeout_secs: 10.0,
        }
    }
}

/// One precomputed arrival.
#[derive(Clone, Debug)]
pub struct Arrival {
    /// Scheduled offset from the run start, seconds.
    pub at_secs: f64,
    pub tenant: String,
    /// Ready-to-send `/generate` JSON body.
    pub body: String,
}

/// Expand a config into its full deterministic arrival schedule.
pub fn build_schedule(cfg: &LoadGenConfig) -> Vec<Arrival> {
    let mut rng = Prng::new(cfg.seed);
    // Tenant weights ∝ 1/(rank+1); sample by cumulative mass.
    let weights: Vec<f64> = (0..cfg.tenants.max(1)).map(|i| 1.0 / (i + 1) as f64).collect();
    let total_w: f64 = weights.iter().sum();
    let mut plan = Vec::new();
    let mut t = 0.0f64;
    loop {
        // Exponential inter-arrival at rate `rps`. uniform() < 1.0 always,
        // so ln(1-u) is finite.
        let u = rng.uniform() as f64;
        t += -(1.0 - u).ln() / cfg.rps.max(1e-9);
        if t >= cfg.duration_secs {
            break;
        }
        let mut pick = rng.uniform() as f64 * total_w;
        let mut tenant = 0usize;
        for (i, w) in weights.iter().enumerate() {
            if pick < *w {
                tenant = i;
                break;
            }
            pick -= w;
        }
        let (plo, phi) = cfg.prompt_len;
        let plen = plo + rng.below(phi.saturating_sub(plo) + 1);
        let prompt: String =
            (0..plen.max(1)).map(|_| (b'a' + rng.below(26) as u8) as char).collect();
        let (nlo, nhi) = cfg.max_new;
        let max_new = nlo.max(1) + rng.below(nhi.saturating_sub(nlo) + 1);
        let seed = rng.next_u64();
        plan.push(Arrival {
            at_secs: t,
            tenant: format!("t{tenant}"),
            body: format!(
                "{{\"prompt\":\"{prompt}\",\"max_new_tokens\":{max_new},\"seed\":{seed}}}"
            ),
        });
    }
    plan
}

/// Aggregated outcome of one open-loop run.
#[derive(Debug, Default)]
pub struct LoadReport {
    /// Requests the schedule offered (sent or attempted).
    pub offered: usize,
    /// Streams that reached their `done` event.
    pub completed: usize,
    /// 429 responses (rate-limit or watermark shed).
    pub shed: usize,
    /// Transport failures and timeouts.
    pub errors: usize,
    /// Wall-clock of the whole run (arrival window + drain), seconds.
    pub elapsed_secs: f64,
    /// `completed / elapsed_secs`.
    pub goodput_rps: f64,
    /// TTFT measured from the *scheduled* arrival instant (µs samples).
    pub ttft_us: Samples,
    /// Gaps between consecutive token events within a stream (µs).
    pub inter_token_us: Samples,
}

impl LoadReport {
    /// The `serving_load` point shape the CI jq schema requires.
    pub fn to_json(&self, offered_rps: f64) -> Json {
        json::obj(vec![
            ("offered_rps", json::num(offered_rps)),
            ("offered", json::num(self.offered as f64)),
            ("completed", json::num(self.completed as f64)),
            ("shed_429", json::num(self.shed as f64)),
            ("errors", json::num(self.errors as f64)),
            ("elapsed_secs", json::num(self.elapsed_secs)),
            ("goodput_rps", json::num(self.goodput_rps)),
            ("ttft_p50_ms", json::num(self.ttft_us.percentile_us(50.0) / 1e3)),
            ("ttft_p99_ms", json::num(self.ttft_us.percentile_us(99.0) / 1e3)),
            (
                "inter_token_p99_ms",
                json::num(self.inter_token_us.percentile_us(99.0) / 1e3),
            ),
        ])
    }
}

/// Drive `front`/`sched` with the offered load described by `cfg`.
///
/// The scheduler never leaves this thread (PJRT handles are not `Send`);
/// each worker thread owns exactly one socket. The driver loop spawns
/// arrivals when they come due, polls the front, and drains finished
/// workers until everything offered has resolved (or the hard deadline —
/// window + timeout + slack — expires, with stragglers counted as
/// errors).
pub fn run_open_loop<E: DecodeEngine>(
    front: &mut HttpFront,
    sched: &mut Scheduler<E>,
    cfg: &LoadGenConfig,
) -> Result<LoadReport> {
    let plan = build_schedule(cfg);
    let addr = front.local_addr()?;
    let timeout = Duration::from_secs_f64(cfg.timeout_secs);
    let (tx, rx) = mpsc::channel::<(f64, Result<StreamOutcome>)>();
    let mut handles = Vec::new();
    let mut report = LoadReport { offered: plan.len(), ..LoadReport::default() };

    let t0 = Instant::now();
    let hard_deadline =
        t0 + Duration::from_secs_f64(cfg.duration_secs + cfg.timeout_secs + 5.0);
    let mut next = 0usize;
    let mut resolved = 0usize;
    let mut outcomes: Vec<(f64, Result<StreamOutcome>)> = Vec::new();
    while resolved < plan.len() {
        let now_secs = t0.elapsed().as_secs_f64();
        let mut progressed = false;
        while next < plan.len() && plan[next].at_secs <= now_secs {
            let a = plan[next].clone();
            let due = t0 + Duration::from_secs_f64(a.at_secs);
            let tx = tx.clone();
            handles.push(thread::spawn(move || {
                // Open-loop accounting: latency is charged from the
                // *scheduled* instant, so driver lateness counts against
                // the server, not in its favor.
                let lag_ms =
                    Instant::now().saturating_duration_since(due).as_secs_f64() * 1e3;
                let res = blocking_request(addr, &a.body, &a.tenant, timeout);
                let _ = tx.send((lag_ms, res));
            }));
            next += 1;
            progressed = true;
        }
        front.poll(sched)?;
        while let Ok(done) = rx.try_recv() {
            outcomes.push(done);
            resolved += 1;
            progressed = true;
        }
        if Instant::now() > hard_deadline {
            break;
        }
        if !progressed && sched.is_idle() {
            // Nothing due, nothing in flight server-side: don't busy-spin.
            thread::sleep(Duration::from_micros(200));
        }
    }
    drop(tx);
    for h in handles {
        let _ = h.join();
    }
    while let Ok(done) = rx.try_recv() {
        outcomes.push(done);
    }
    report.elapsed_secs = t0.elapsed().as_secs_f64();

    for (lag_ms, res) in outcomes {
        match res {
            Err(_) => report.errors += 1,
            Ok(o) if o.status == 429 => report.shed += 1,
            Ok(o) if o.status == 200 && o.done.is_some() => {
                report.completed += 1;
                if let Some(&first) = o.token_at_ms.first() {
                    report.ttft_us.push((lag_ms + first) * 1e3);
                }
                for w in o.token_at_ms.windows(2) {
                    report.inter_token_us.push((w[1] - w[0]) * 1e3);
                }
            }
            Ok(_) => report.errors += 1,
        }
    }
    // Stragglers past the hard deadline never reported back.
    report.errors += report.offered - (report.completed + report.shed + report.errors);
    report.goodput_rps = if report.elapsed_secs > 0.0 {
        report.completed as f64 / report.elapsed_secs
    } else {
        0.0
    };
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::engine::MockEngine;
    use crate::serve::http::HttpFrontConfig;

    fn cfg(rps: f64, duration: f64, seed: u64) -> LoadGenConfig {
        LoadGenConfig { rps, duration_secs: duration, seed, ..LoadGenConfig::default() }
    }

    #[test]
    fn schedule_is_seeded_and_reproducible() {
        let a = build_schedule(&cfg(100.0, 2.0, 9));
        let b = build_schedule(&cfg(100.0, 2.0, 9));
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.at_secs, y.at_secs);
            assert_eq!(x.body, y.body);
            assert_eq!(x.tenant, y.tenant);
        }
        let c = build_schedule(&cfg(100.0, 2.0, 10));
        assert!(
            a.len() != c.len() || a.iter().zip(&c).any(|(x, y)| x.body != y.body),
            "different seeds must produce different load"
        );
    }

    #[test]
    fn arrivals_are_poisson_at_the_offered_rate() {
        let plan = build_schedule(&cfg(200.0, 5.0, 3));
        let expect = 200.0 * 5.0;
        assert!(
            (plan.len() as f64) > expect * 0.8 && (plan.len() as f64) < expect * 1.2,
            "offered {} vs expected ~{expect}",
            plan.len()
        );
        let mut last = 0.0;
        for a in &plan {
            assert!(a.at_secs > last, "arrivals must be strictly ordered");
            last = a.at_secs;
        }
        assert!(last < 5.0, "no arrival outside the window");
    }

    #[test]
    fn tenant_skew_prefers_low_ranks() {
        let plan = build_schedule(&cfg(500.0, 4.0, 12));
        let count = |t: &str| plan.iter().filter(|a| a.tenant == t).count();
        assert!(
            count("t0") > count("t3"),
            "rank-0 tenant must dominate the tail ({} vs {})",
            count("t0"),
            count("t3")
        );
    }

    /// End-to-end smoke: a short real open-loop run over loopback against
    /// a MockEngine scheduler completes requests and produces a
    /// well-formed report.
    #[test]
    fn open_loop_drives_the_real_front() {
        let mut sched = Scheduler::new(MockEngine::new(4, 128, 64), 64).unwrap();
        let mut front = HttpFront::bind("127.0.0.1:0", HttpFrontConfig::default()).unwrap();
        front.install_token_hook(&mut sched);
        let c = LoadGenConfig {
            rps: 100.0,
            duration_secs: 0.2,
            seed: 7,
            max_new: (2, 6),
            timeout_secs: 10.0,
            ..LoadGenConfig::default()
        };
        let r = run_open_loop(&mut front, &mut sched, &c).unwrap();
        assert!(r.offered > 0);
        assert_eq!(r.errors, 0, "loopback run must not drop requests");
        assert_eq!(r.completed + r.shed, r.offered);
        assert!(r.completed > 0);
        assert!(r.goodput_rps > 0.0);
        assert!(r.ttft_us.len() == r.completed);
        let j = r.to_json(c.rps);
        for key in
            ["offered_rps", "goodput_rps", "ttft_p50_ms", "ttft_p99_ms", "inter_token_p99_ms", "shed_429"]
        {
            assert!(j.get(key).is_some(), "report missing {key}");
        }
        // The report must serialize to strict JSON (no NaN/inf).
        assert!(Json::parse(&j.to_string()).is_ok());
    }
}
